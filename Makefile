# One-command verify recipes (see ROADMAP.md "Tier-1 verify").

PY ?= python
export PYTHONPATH := src:$(PYTHONPATH)

# extra pytest flags for the tier-1 lane, e.g. the CI PR lane's
# PYTEST_ARGS='-m "not slow"' (nightly CI runs the full lane)
PYTEST_ARGS ?=

.PHONY: test test-fast spmd mesh-hwa mesh-hwa-fsdp mesh-hwa-bf16 bench \
	bench-kernels bench-attn bench-sync bench-comms bench-serve \
	bench-check train-smoke docs-check hwa-lint hwa-lint-smoke \
	fault-check fault-check-smoke serve-demo

# tier-1: docs sanity + the full CPU suite (SPMD checks run in their own
# subprocesses)
test: docs-check
	$(PY) -m pytest -x -q $(PYTEST_ARGS)

# tier-1 minus the `slow` lane (hypothesis-heavy property tests) — what
# the CI tier1 job runs on PRs to stay under ~10 minutes
test-fast:
	$(MAKE) test PYTEST_ARGS='-m "not slow"'

# README quickstart targets in dry-run mode + intra-repo doc link check
docs-check:
	$(PY) tools/docs_check.py

# 8-host-device subprocess checks only (SPMD + mesh-native HWA)
spmd:
	$(PY) -m pytest -q tests/test_spmd.py tests/test_mesh_hwa.py

# drive the mesh-native HWA trainer end-to-end on 8 forced host devices
mesh-hwa:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	$(PY) -m repro.launch.train --mesh-native --steps 8 --sync-period 4 \
	    --batch-size 8 --seq-len 16 --k 2

# same smoke with FSDP rules + a real model axis: mixed data×model
# tilings sync through the GROUPED mesh-resident packed layout (this
# used to hard-error into the legacy GSPMD assembly)
mesh-hwa-fsdp:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	$(PY) -m repro.launch.train --mesh-native --steps 8 --sync-period 4 \
	    --batch-size 8 --seq-len 16 --k 2 --fsdp --tp 2

# compressed WA precision end-to-end: bf16 ring storage + bf16 cross-pod
# payload on the two-level tree (the f32 totals stay Kahan-compensated)
mesh-hwa-bf16:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	$(PY) -m repro.launch.train --mesh-native --steps 8 --sync-period 2 \
	    --batch-size 8 --seq-len 16 --k 4 --sync-tree two-level \
	    --outer-every 2 --wa-dtype bf16 --comms-dtype bf16

# communication-amortization numbers from real lowered HLO
bench:
	$(PY) -m benchmarks.run --only mesh_comm

# packed-vs-per-leaf WA kernel numbers; writes BENCH_kernels.json at the
# repo root (cross-PR perf trajectory)
bench-kernels:
	$(PY) -m benchmarks.run --only kernels

# attention blocks only (fwd + custom-vjp bwd + train-step comparison),
# print-only: BENCH_kernels.json merging stays with bench-kernels so a
# partial run can't drop the other kernel blocks
bench-attn:
	$(PY) -m benchmarks.kernel_bench --attn-only

# flat-vs-two-level sync-tree traffic on the pod-carved (2,2,2) mesh;
# appends the sync/tree block to BENCH_kernels.json
bench-sync:
	$(PY) -m benchmarks.run --only sync_tree

# compressed WA ring + cross-pod payload (bf16 / fp8 vs f32): HBM and
# ICI-byte ratios plus bounded-ULP parity, from real lowered HLO and
# real sync outputs; appends the sync/comms block to BENCH_kernels.json
bench-comms:
	$(PY) -m benchmarks.run --only comms

# continuous batching vs static batching at ragged occupancy (tokens/s,
# token-slot work ratio, step-trace count); appends the serve block to
# BENCH_kernels.json
bench-serve:
	$(PY) -m benchmarks.run --only serve

# paged serving engine end-to-end: continuous batching + paged KV cache
# on a smoke model (block tables, single fixed-shape jitted decode step)
serve-demo:
	$(PY) -m repro.launch.serve --arch granite-3-2b --engine paged \
	    --batch 4 --prompt-len 12 --new-tokens 12

# regression-guard BENCH_kernels.json against the committed structural
# thresholds (launch counts, collective counts, padding waste) — wall
# times are machine-dependent and deliberately unchecked
bench-check:
	$(PY) tools/bench_check.py

# static SPMD contract checker: compile the full bundle matrix (flat /
# two-level / grouped-FSDP sync, inner sync, train steps; 1-device and
# (2,2,2) test meshes) and check each lowered jaxpr + post-SPMD HLO
# against its declarative contract — collectives, Pallas-launch budgets,
# donation/aliasing, dtype discipline, manual-subgroup hazards.
# Writes the machine-readable report to lint_report.json.
hwa-lint:
	$(PY) tools/hwa_lint.py --json lint_report.json

# PR-lane subset (the CI lint job; REPRO_LINT_SMOKE=1 selects the same)
hwa-lint-smoke:
	$(PY) tools/hwa_lint.py --smoke --json lint_report.json

# deterministic fault-injection harness: NaN-poisoned replicas, kill-
# mid-save preemptions, bit-flipped checkpoints, transient IO — each leg
# an end-to-end scenario with a hard pass/fail verdict. Writes the
# machine-readable report to fault_report.json.
fault-check:
	$(PY) tools/fault_check.py --json fault_report.json

# PR-lane subset (the CI resilience job; REPRO_FAULT_SMOKE=1 likewise)
fault-check-smoke:
	$(PY) tools/fault_check.py --smoke --json fault_report.json
