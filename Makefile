# One-command verify recipes (see ROADMAP.md "Tier-1 verify").

PY ?= python
export PYTHONPATH := src:$(PYTHONPATH)

.PHONY: test spmd mesh-hwa bench bench-kernels bench-sync train-smoke \
	docs-check

# tier-1: docs sanity + the full CPU suite (SPMD checks run in their own
# subprocesses)
test: docs-check
	$(PY) -m pytest -x -q

# README quickstart targets in dry-run mode + intra-repo doc link check
docs-check:
	$(PY) tools/docs_check.py

# 8-host-device subprocess checks only (SPMD + mesh-native HWA)
spmd:
	$(PY) -m pytest -q tests/test_spmd.py tests/test_mesh_hwa.py

# drive the mesh-native HWA trainer end-to-end on 8 forced host devices
mesh-hwa:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	$(PY) -m repro.launch.train --mesh-native --steps 8 --sync-period 4 \
	    --batch-size 8 --seq-len 16 --k 2

# communication-amortization numbers from real lowered HLO
bench:
	$(PY) -m benchmarks.run --only mesh_comm

# packed-vs-per-leaf WA kernel numbers; writes BENCH_kernels.json at the
# repo root (cross-PR perf trajectory)
bench-kernels:
	$(PY) -m benchmarks.run --only kernels

# flat-vs-two-level sync-tree traffic on the pod-carved (2,2,2) mesh;
# appends the sync/tree block to BENCH_kernels.json
bench-sync:
	$(PY) -m benchmarks.run --only sync_tree
