"""Pallas paged-attention decode kernel (TPU target, validated in
interpret mode) + the jnp gather reference.

The serving tier stores K/V in a page pool ``(n_pages, page_size, Hkv,
D)`` addressed through per-sequence block tables ``(B, table_width)`` —
a logical ring at page granularity (``models.cache.paged_slot_pages``).
One decode step attends ONE query token per sequence against its live
pages:

- grid = (B, Hkv, TW) with the table-slot axis innermost ("arbitrary"
  semantics → sequential), so the online-softmax accumulators (m, l,
  acc) live in VMEM scratch across the page sweep — the same structure
  as ``flash_attention._flash_kernel`` with (q block → GQA group) and
  (k block → one K/V page).
- the block table and sequence lengths ride in as SCALAR-PREFETCH
  operands (``pltpu.PrefetchScalarGridSpec``): the K/V BlockSpec index
  maps read ``tables[b, j]`` to DMA the *physical* page for the
  sequence's j-th ring slot — the data-dependent gather that makes the
  cache paged.
- masking mirrors the flash kernels' band math at page granularity:
  a slot is dead when its ring position math yields a negative logical
  page or the whole page falls outside the sliding window; in-page
  positions are masked by recency (kpos <= q_pos) and window. A
  sequence with len 0 (inactive batch slot) produces an all-masked row
  → the flash-style safe division emits zeros, never NaN.

The jnp reference (:func:`paged_attention_ref`) performs the same
gather with ``jnp.take`` + ``naive_attention`` and is both the CPU hot
path (interpret-mode Pallas is emulation-slow) and the test oracle's
counterpart.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash_attention import NEG_INF, CompilerParams
from repro.models.attention import naive_attention
from repro.models.cache import paged_slot_pages


def paged_attention_ref(q, k_pages, v_pages, tables, lens, *, window=None,
                        logit_softcap=0.0):
    """Gather-based reference. q: (B, Hq, D) — the ONE current token per
    sequence (post-RoPE); k_pages/v_pages: (NP, ps, Hkv, D); tables:
    (B, TW) physical page per ring slot; lens: (B,) tokens written
    (query position = lens-1). Returns (B, Hq, D)."""
    B, Hq, D = q.shape
    ps = k_pages.shape[1]
    TW = tables.shape[1]
    cur_page = (lens - 1) // ps                       # (B,) floor: -1 if empty
    base = paged_slot_pages(TW, cur_page)             # (B, TW) logical pages
    k_pos = base[..., None] * ps + jnp.arange(ps)     # (B, TW, ps)
    k_pos = jnp.where(base[..., None] >= 0, k_pos, -1)
    k_pos = jnp.where(k_pos <= (lens - 1)[:, None, None], k_pos, -1)
    k = jnp.take(k_pages, tables, axis=0)             # (B, TW, ps, Hkv, D)
    v = jnp.take(v_pages, tables, axis=0)
    Hkv = k.shape[3]
    k = k.reshape(B, TW * ps, Hkv, D)
    v = v.reshape(B, TW * ps, Hkv, D)
    q_pos = (lens - 1)[:, None]                       # (B, 1)
    out = naive_attention(q[:, None], k, v, q_pos, k_pos.reshape(B, TW * ps),
                          window=window, logit_softcap=logit_softcap)
    return out[:, 0]


def _paged_kernel(tbl_ref, lens_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, page_size: int,
                  table_width: int, window: int | None,
                  logit_softcap: float, dscale: float):
    b = pl.program_id(0)
    j = pl.program_id(2)                               # table (ring) slot
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    ln = lens_ref[b]
    q_pos = ln - 1
    # ring math at page granularity (cache_positions lifted to pages):
    # slot j holds the largest logical page m' <= cur with m' % TW == j
    cur = jax.lax.div(q_pos, page_size)
    rem = jax.lax.rem(cur, table_width)
    base = jnp.where(j <= rem, cur - rem + j, cur - rem + j - table_width)
    live = jnp.logical_and(ln > 0, base >= 0)
    if window is not None:
        # whole page below the band → skip (banded-compute trick)
        live = jnp.logical_and(
            live, base * page_size + page_size - 1 >= q_pos - (window - 1))

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)      # (ps, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * dscale
        if logit_softcap:
            s = logit_softcap * jnp.tanh(s / logit_softcap)
        kpos = base * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (1, page_size), 1)              # (1, ps)
        mask = kpos <= q_pos
        if window is not None:
            mask = jnp.logical_and(mask, q_pos - kpos < window)
        s = jnp.where(mask, s, NEG_INF)                # (G, ps) via broadcast

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1)[:, None])
        alpha = jnp.exp(m_prev - m_new)
        # re-mask p: a fully-masked page has s - m_new == 0 rows whose
        # bare exp would claim weight 1 (same guard as the flash kernel)
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        l_scr[...] = alpha * l_prev + jnp.sum(p, axis=1)[:, None]
        acc_scr[...] = alpha * acc_scr[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(j == nj - 1)
    def _finalize():
        l = l_scr[...]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0] = (acc_scr[...] / safe).astype(o_ref.dtype)


def paged_attention_pallas(q, k_pages, v_pages, tables, lens, *,
                           window: int | None = None,
                           logit_softcap: float = 0.0,
                           interpret: bool = True):
    """Pallas launch. Same contract as :func:`paged_attention_ref`.

    Table entries must be valid pool indices (``TRASH_PAGE`` = 0 for
    ring slots not yet allocated — the lens/ring masking hides them, the
    index map just needs somewhere legal to DMA from). Pads head_dim to
    the 128-lane MXU width like ``ops.flash_attention``.
    """
    B, Hq, D = q.shape
    ps, Hkv = k_pages.shape[1], k_pages.shape[2]
    G = Hq // Hkv
    TW = tables.shape[1]
    dscale = 1.0 / (D ** 0.5)

    pad_d = (-D) % 128
    if pad_d:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_d)))
        padp = ((0, 0), (0, 0), (0, 0), (0, pad_d))
        k_pages = jnp.pad(k_pages, padp)
        v_pages = jnp.pad(v_pages, padp)
    Dp = D + pad_d

    qg = q.reshape(B, Hkv, G, Dp) if Hkv > 1 else q.reshape(B, 1, G, Dp)

    kernel = functools.partial(
        _paged_kernel, page_size=ps, table_width=TW, window=window,
        logit_softcap=logit_softcap, dscale=dscale)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, TW),
        in_specs=[
            pl.BlockSpec((1, 1, G, Dp),
                         lambda b, h, j, tbl, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, ps, 1, Dp),
                         lambda b, h, j, tbl, ln: (tbl[b, j], 0, h, 0)),
            pl.BlockSpec((1, ps, 1, Dp),
                         lambda b, h, j, tbl, ln: (tbl[b, j], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dp),
                               lambda b, h, j, tbl, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, Dp), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, Dp), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(tables.astype(jnp.int32), lens.astype(jnp.int32), qg, k_pages, v_pages)
    return out.reshape(B, Hq, Dp)[..., :D]


def paged_attention(q, k_pages, v_pages, tables, lens, *, window=None,
                    logit_softcap=0.0, impl: str = "jnp",
                    interpret: bool | None = None):
    """Dispatch: ``impl`` "jnp" (gather reference — the CPU hot path) or
    "pallas" (the scalar-prefetch kernel; interpret-mode off TPU)."""
    if impl == "pallas":
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        return paged_attention_pallas(q, k_pages, v_pages, tables, lens,
                                      window=window,
                                      logit_softcap=logit_softcap,
                                      interpret=interpret)
    return paged_attention_ref(q, k_pages, v_pages, tables, lens,
                               window=window, logit_softcap=logit_softcap)
