"""Pallas flash-attention backward: the two recompute sweeps.

Recompute-based backward (Dao et al., arXiv 2205.14135; Rabe & Staats,
arXiv 2112.05682): nothing O(S·T) is stashed — the forward saves only
(O, lse) per row, and each sweep rebuilds the block scores
S = (Q·Kᵀ)·dscale it needs, recovering the probabilities as
p = exp(softcap(S) − lse) and the score gradient as

    Δ_i  = Σ_d dO_i · O_i                       (one XLA reduction)
    dS   = p ⊙ (dO·Vᵀ − Δ) ⊙ softcap'(S) · dscale

Two launches (ARCHITECTURE.md §7 has the tiling diagram):

  dq sweep    grid (B, Hq, nq, nk), k innermost ("arbitrary") — the dq
              accumulator for one q block lives in VMEM across the k
              sweep: dq_i = Σ_j dS_ij · K_j. GQA indexes K/V at h // G.
  dk/dv sweep grid (B, Hkv, nk, nq), q innermost — dk/dv accumulators
              for one KV block live in VMEM across the q sweep, and the
              G query heads of the group accumulate into their shared
              kv head inside the block (q/dO arrive as (block_q, G, D)
              slabs): dv_j = Σ_i Σ_g p_ijᵀ·dO_ig, dk_j = Σ_i Σ_g dS_ijᵀ·Q_ig.

Both sweeps reuse the forward's block-skip predicate, so causal /
sliding-window bands skip dead blocks entirely. Fully-masked rows carry
lse == NEG_INF and zero dO·O, so every gradient contribution is
re-masked to exactly zero (no NaN from the −1e30 fill).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash_attention import (CompilerParams, NEG_INF,
                                            band_mask, block_live)


def _block_p_ds(q, kb, vb, do, lse, delta, q_start, k_start, *,
                block_q: int, block_k: int, causal: bool,
                window: int | None, logit_softcap: float, dscale: float):
    """Recompute one (block_q, block_k) tile's p and dS from f32 operands.

    lse/delta are (block_q, 1) columns. A fully-masked row carries
    lse == NEG_INF; exp(s - NEG_INF) would overflow, so the row's lse is
    swapped for 0 first — its p entries are all re-masked to 0 anyway.
    """
    s = jax.lax.dot_general(q, kb, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * dscale
    if logit_softcap:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    mask = band_mask(q_start, k_start, block_q, block_k, causal, window)
    lse_safe = jnp.where(lse > 0.5 * NEG_INF, lse, 0.0)
    p = jnp.where(mask, jnp.exp(s - lse_safe), 0.0)
    dp = jax.lax.dot_general(do, vb, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta)
    if logit_softcap:
        # d/dx [c·tanh(x/c)] = 1 − tanh²(x/c); s here is already the
        # capped value c·tanh(x/c), so tanh(x/c) = s/c without recompute
        ds = ds * (1.0 - jnp.square(s / logit_softcap))
    return p, ds * dscale


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               acc_scr, *, block_q: int, block_k: int, causal: bool,
               window: int | None, logit_softcap: float, dscale: float):
    i = pl.program_id(2)               # q block (parallel)
    j = pl.program_id(3)               # k block (innermost, sequential)
    nk = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = i * block_q
    k_start = j * block_k

    @pl.when(block_live(q_start, k_start, block_q, block_k, causal, window))
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)          # (bq, d)
        kb = k_ref[0, :, 0, :].astype(jnp.float32)         # (bk, d)
        vb = v_ref[0, :, 0, :].astype(jnp.float32)
        do = do_ref[0, :, 0, :].astype(jnp.float32)
        lse = lse_ref[0, 0, :][:, None]                    # (bq, 1)
        delta = delta_ref[0, 0, :][:, None]
        _, ds = _block_p_ds(
            q, kb, vb, do, lse, delta, q_start, k_start,
            block_q=block_q, block_k=block_k, causal=causal, window=window,
            logit_softcap=logit_softcap, dscale=dscale)
        acc_scr[...] += jax.lax.dot_general(
            ds, kb, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(j == nk - 1)
    def _finalize():
        dq_ref[0, :, 0, :] = acc_scr[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_scr, dv_scr, *, block_q: int,
                block_k: int, group: int, causal: bool, window: int | None,
                logit_softcap: float, dscale: float):
    j = pl.program_id(2)               # k block (parallel)
    i = pl.program_id(3)               # q block (innermost, sequential)
    nq = pl.num_programs(3)

    @pl.when(i == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q_start = i * block_q
    k_start = j * block_k

    @pl.when(block_live(q_start, k_start, block_q, block_k, causal, window))
    def _compute():
        kb = k_ref[0, :, 0, :].astype(jnp.float32)         # (bk, d)
        vb = v_ref[0, :, 0, :].astype(jnp.float32)
        # GQA head-group accumulation: the G query heads sharing this kv
        # head each contribute a (bq, bk) tile into the SAME dk/dv block
        for g in range(group):
            q = q_ref[0, :, g, :].astype(jnp.float32)      # (bq, d)
            do = do_ref[0, :, g, :].astype(jnp.float32)
            lse = lse_ref[0, g, :][:, None]                # (bq, 1)
            delta = delta_ref[0, g, :][:, None]
            p, ds = _block_p_ds(
                q, kb, vb, do, lse, delta, q_start, k_start,
                block_q=block_q, block_k=block_k, causal=causal,
                window=window, logit_softcap=logit_softcap, dscale=dscale)
            dv_scr[...] += jax.lax.dot_general(
                p, do, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            dk_scr[...] += jax.lax.dot_general(
                ds, q, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)

    @pl.when(i == nq - 1)
    def _finalize():
        dk_ref[0, :, 0, :] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0, :, 0, :] = dv_scr[...].astype(dv_ref.dtype)


def flash_attention_bwd_pallas(q, k, v, out, lse, dout, *, causal: bool,
                               window: int | None, logit_softcap: float,
                               block_q: int, block_k: int, dscale: float,
                               interpret: bool = True):
    """(dq, dk, dv) via the two recompute sweeps. Shapes as the forward;
    lse is the forward's (B, Hq, S) f32 residual."""
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    nq, nk = S // block_q, T // block_k

    # Δ_i = Σ_d dO·O per row — elementwise, stays in XLA (not a launch)
    delta = jnp.einsum("bshd,bshd->bhs", dout.astype(jnp.float32),
                       out.astype(jnp.float32))

    common = dict(block_q=block_q, block_k=block_k, causal=causal,
                  window=window, logit_softcap=logit_softcap, dscale=dscale)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, **common),
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, D), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, block_k, 1, D),
                         lambda b, h, i, j: (b, j, h // G, 0)),
            pl.BlockSpec((1, block_k, 1, D),
                         lambda b, h, i, j: (b, j, h // G, 0)),
            pl.BlockSpec((1, block_q, 1, D), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, i, j: (b, h, i)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, i, j: (b, h, i)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, D),
                               lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, Hq, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, D), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v, dout, lse, delta)

    # q/dO/lse/Δ arrive as whole GQA groups: block size G over the head
    # dim at head-block index h covers query heads [h·G, (h+1)·G)
    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, group=G, **common),
        grid=(B, Hkv, nk, nq),
        in_specs=[
            pl.BlockSpec((1, block_q, G, D), lambda b, h, j, i: (b, i, h, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, j, i: (b, j, h, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, j, i: (b, j, h, 0)),
            pl.BlockSpec((1, block_q, G, D), lambda b, h, j, i: (b, i, h, 0)),
            pl.BlockSpec((1, G, block_q), lambda b, h, j, i: (b, h, i)),
            pl.BlockSpec((1, G, block_q), lambda b, h, j, i: (b, h, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, j, i: (b, j, h, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, j, i: (b, j, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, Hkv, D), k.dtype),
            jax.ShapeDtypeStruct((B, T, Hkv, D), v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_k, D), jnp.float32),
            pltpu.VMEM((block_k, D), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v, dout, lse, delta)

    return dq, dk, dv
