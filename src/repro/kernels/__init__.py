"""Pallas TPU kernels for the paper's hot spots + framework compute.

- wa_update.py        : fused HWA slide-window update + K-replica mean
- flash_attention.py  : causal GQA flash attention (window, softcap)
- ops.py              : jit'd public wrappers (padding, interpret fallback)
- ref.py              : pure-jnp oracles (allclose targets for tests)
"""
