"""Pallas flash-attention kernels (TPU target, validated in interpret mode).

Causal GQA attention with optional sliding window and logit softcap —
the framework's perf-critical compute layer for training/prefill
(the decode step is matmul-thin and stays in XLA; see
``repro.models.attention.run_attention``).

Forward tiling (ARCHITECTURE.md §7): grid = (B, Hq, nq, nk) with the key
axis innermost ("arbitrary" semantics → sequential), so the
online-softmax accumulators (m, l, acc) live in VMEM scratch across the
nk sweep. Block shapes are (block_q, head_dim) / (block_k, head_dim)
with head_dim padded to 128 by ``ops.py`` — MXU-aligned. Causality and
the sliding window are enforced both by *block skipping* (pl.when —
skipped blocks cost no MXU work, the banded-compute trick) and an
in-block position mask. Alongside O the forward emits the per-row
logsumexp — the residual the recompute-based backward
(``flash_attention_bwd``) rebuilds block scores from, instead of
stashing the O(S·T) probability tensor.

The whole fwd+bwd pipeline sits under one ``jax.custom_vjp``
(:func:`flash_attention_pallas`), so ``jax.grad`` through the Pallas op
costs exactly 1 forward + 2 backward launches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

# jax 0.4.x names it TPUCompilerParams; newer jax renamed to
# CompilerParams (same drift-shim spirit as repro.common.compat)
CompilerParams = getattr(pltpu, "TPUCompilerParams",
                         getattr(pltpu, "CompilerParams", None))


def block_live(q_start, k_start, block_q: int, block_k: int, causal: bool,
               window: int | None):
    """Block-level skip predicate shared by the forward and both backward
    sweeps: a (q-block, k-block) pair is dead when the causal triangle or
    the sliding-window band excludes every (row, col) position in it."""
    live = True
    if causal:
        live = k_start <= q_start + block_q - 1
    if window is not None:
        live = jnp.logical_and(
            live, k_start + block_k - 1 >= q_start - (window - 1))
    return live


def band_mask(q_start, k_start, block_q: int, block_k: int, causal: bool,
              window: int | None):
    """In-block (block_q, block_k) boolean mask for the causal/window band."""
    q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                               (block_q, block_k), 0)
    k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                               (block_q, block_k), 1)
    mask = k_pos <= q_pos if causal else k_pos >= 0
    if window is not None:
        mask = jnp.logical_and(mask, q_pos - k_pos < window)
    return mask


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr,
                  *, block_q: int, block_k: int, causal: bool,
                  window: int | None, logit_softcap: float, dscale: float):
    i = pl.program_id(2)               # q block
    j = pl.program_id(3)               # k block
    nk = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = i * block_q
    k_start = j * block_k

    @pl.when(block_live(q_start, k_start, block_q, block_k, causal, window))
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)          # (bq, d)
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # (bk, d)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * dscale
        if logit_softcap:
            s = logit_softcap * jnp.tanh(s / logit_softcap)
        mask = band_mask(q_start, k_start, block_q, block_k, causal, window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1)[:, None]                # (bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        # A fully-masked row has m_new == NEG_INF, so s - m_new == 0 and
        # the bare exp would claim p == 1 per masked entry (a bogus
        # uniform mean of v). Re-masking p keeps l at 0 there, which
        # _finalize turns into a zero output row and an lse of NEG_INF.
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        l_new = alpha * l_prev + jnp.sum(p, axis=1)[:, None]
        acc_scr[...] = alpha * acc_scr[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(j == nk - 1)
    def _finalize():
        l = l_scr[...]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, :, 0, :] = (acc_scr[...] / safe).astype(o_ref.dtype)
        lse = jnp.where(l[:, 0] > 0.0,
                        m_scr[:, 0] + jnp.log(safe[:, 0]), NEG_INF)
        lse_ref[0, 0, :] = lse


def _flash_forward(q, k, v, causal, window, logit_softcap, block_q, block_k,
                   dscale, interpret):
    """Raw forward launch. Returns (out (B,S,Hq,D) q.dtype,
    lse (B,Hq,S) f32) — lse is the backward's recompute residual."""
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    grid = (B, Hq, S // block_q, T // block_k)

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, causal=causal,
        window=window, logit_softcap=logit_softcap, dscale=dscale)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, D), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, block_k, 1, D),
                         lambda b, h, i, j: (b, j, h // G, 0)),
            pl.BlockSpec((1, block_k, 1, D),
                         lambda b, h, i, j: (b, j, h // G, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, 1, D), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, i, j: (b, h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, Hq, D), q.dtype),
            jax.ShapeDtypeStruct((B, Hq, S), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def _flash(q, k, v, causal, window, logit_softcap, block_q, block_k, dscale,
           interpret):
    out, _ = _flash_forward(q, k, v, causal, window, logit_softcap,
                            block_q, block_k, dscale, interpret)
    return out


def _flash_fwd(q, k, v, causal, window, logit_softcap, block_q, block_k,
               dscale, interpret):
    out, lse = _flash_forward(q, k, v, causal, window, logit_softcap,
                              block_q, block_k, dscale, interpret)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, logit_softcap, block_q, block_k, dscale,
               interpret, res, dout):
    # local import: flash_attention_bwd imports NEG_INF/mask helpers from
    # this module, so the dependency must stay one-way at import time
    from repro.kernels.flash_attention_bwd import flash_attention_bwd_pallas
    q, k, v, out, lse = res
    return flash_attention_bwd_pallas(
        q, k, v, out, lse, dout, causal=causal, window=window,
        logit_softcap=logit_softcap, block_q=block_q, block_k=block_k,
        dscale=dscale, interpret=interpret)


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           window: int | None = None,
                           logit_softcap: float = 0.0,
                           block_q: int = 128, block_k: int = 128,
                           sm_scale: float | None = None,
                           interpret: bool = True):
    """q: (B, S, Hq, D); k/v: (B, T, Hkv, D); Hq = G·Hkv. D % 128 == 0
    (ops.py pads; pass sm_scale=1/sqrt(unpadded_D)). Returns (B,S,Hq,D).

    Differentiable: ``jax.grad`` hits the custom VJP — the backward
    recomputes block scores from the saved (q, k, v, O, lse) residuals
    and runs the two Pallas sweeps in ``flash_attention_bwd`` (dq with k
    innermost, then dk/dv with q innermost).
    """
    B, S, Hq, D = q.shape
    T = k.shape[1]
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    assert S % block_q == 0 and T % block_k == 0, (S, block_q, T, block_k)
    dscale = float(sm_scale) if sm_scale is not None else float(D) ** -0.5
    return _flash(q, k, v, causal, window, float(logit_softcap),
                  block_q, block_k, dscale, interpret)
