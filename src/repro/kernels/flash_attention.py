"""Pallas flash-attention kernel (TPU target, validated in interpret mode).

Causal GQA attention with optional sliding window and logit softcap —
the framework's perf-critical compute layer for training/prefill
(the decode step is matmul-thin and stays in XLA; see
``repro.models.attention.run_attention``).

Tiling (DESIGN.md §6): grid = (B, Hq, nq, nk) with the key axis innermost
("arbitrary" semantics → sequential), so the online-softmax accumulators
(m, l, acc) live in VMEM scratch across the nk sweep. Block shapes are
(block_q, head_dim) / (block_k, head_dim) with head_dim padded to 128 by
``ops.py`` — MXU-aligned. Causality and the sliding window are enforced
both by *block skipping* (pl.when — skipped blocks cost no MXU work, the
banded-compute trick) and an in-block position mask.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  block_q: int, block_k: int, seq_k: int, causal: bool,
                  window: int | None, logit_softcap: float, dscale: float):
    i = pl.program_id(2)               # q block
    j = pl.program_id(3)               # k block
    nk = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = i * block_q
    k_start = j * block_k

    # Block-level skip: entirely-masked blocks do no work.
    live = True
    if causal:
        live = k_start <= q_start + block_q - 1
    if window is not None:
        live = jnp.logical_and(live,
                               k_start + block_k - 1 >= q_start - (window - 1))

    @pl.when(live)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)          # (bq, d)
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # (bk, d)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * dscale
        if logit_softcap:
            s = logit_softcap * jnp.tanh(s / logit_softcap)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
        mask = k_pos <= q_pos if causal else k_pos >= 0
        if window is not None:
            mask = jnp.logical_and(mask, q_pos - k_pos < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1)[:, None]                # (bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1)[:, None]
        acc_scr[...] = alpha * acc_scr[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(j == nk - 1)
    def _finalize():
        l = l_scr[...]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, :, 0, :] = (acc_scr[...] / safe).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           window: int | None = None,
                           logit_softcap: float = 0.0,
                           block_q: int = 128, block_k: int = 128,
                           sm_scale: float | None = None,
                           interpret: bool = True):
    """q: (B, S, Hq, D); k/v: (B, T, Hkv, D); Hq = G·Hkv. D % 128 == 0
    (ops.py pads; pass sm_scale=1/sqrt(unpadded_D)). Returns (B,S,Hq,D).
    """
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    assert S % block_q == 0 and T % block_k == 0, (S, block_q, T, block_k)
    grid = (B, Hq, S // block_q, T // block_k)
    dscale = sm_scale if sm_scale is not None else 1.0 / (D ** 0.5)

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, seq_k=T,
        causal=causal, window=window, logit_softcap=logit_softcap,
        dscale=dscale)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, D), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, block_k, 1, D),
                         lambda b, h, i, j: (b, j, h // G, 0)),
            pl.BlockSpec((1, block_k, 1, D),
                         lambda b, h, i, j: (b, j, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, D),
                               lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, Hq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
