"""Jit'd public wrappers around the Pallas kernels.

Two families:

- **packed** (`*_packed`, `hwa_sync_packed`): operate on the contiguous
  tile-aligned buffers of ``repro.common.packing`` — one launch for the
  whole parameter set, zero per-call padding, ring/total donated in place.
  This is the hot path the WA state machine runs on.
- **per-leaf** (`wa_window_update`, `online_mean`): flatten + pad ONE
  parameter leaf per call. Kept as the benchmark baseline and for ad-hoc
  single-array use; a tree-mapped sync over L leaves costs L launches and
  re-pads (defeating donation) every call.

Plus head-dim padding for attention, and interpret-mode fallback off-TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.packing import ALIGN
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.wa_update import (TILE_COLS, TILE_ROWS, online_mean_2d,
                                     wa_sync_fused_2d, wa_sync_fused_c_2d,
                                     wa_window_update_2d,
                                     wa_window_update_c_2d)

# A packed buffer reshapes to (P // TILE_COLS, TILE_COLS) with the row
# count a TILE_ROWS multiple — the kernels' exact tiling, no padding.
assert ALIGN == TILE_ROWS * TILE_COLS, (ALIGN, TILE_ROWS, TILE_COLS)

#: ring dtypes the fused kernels handle in-kernel: f32 on the original
#: kernels, bf16 on the ``*_c`` (compressed, Kahan-total) variants. fp8
#: rings need per-block scale state and run the jnp path instead
#: (``launch.sync.packed`` / ``core.offline`` gate on this set, and
#: ``packed_sync_launch_budget`` mirrors it).
KERNEL_RING_DTYPES = (jnp.dtype(jnp.float32), jnp.dtype(jnp.bfloat16))


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _tiles(buf):
    """(… , P) -> (…, P // TILE_COLS, TILE_COLS) view, P % ALIGN == 0."""
    assert buf.shape[-1] % ALIGN == 0, buf.shape
    return buf.reshape(buf.shape[:-1] + (-1, TILE_COLS))


@functools.partial(jax.jit, donate_argnums=(0, 1))
def wa_window_update_packed(ring, total, new, idx, full_flag, inv_count):
    """Fused slide-window update over the WHOLE packed parameter set.

    ring: (I, P) f32; total/new: (P,) f32 with P % ALIGN == 0 (a
    ``packing.PackSpec.padded`` buffer). Exactly one kernel launch; ring
    and total are donated and updated in place (no per-call pad/reshape
    copies — the reshapes here are metadata-only bitcasts).
    Returns (ring', total', avg).
    """
    I, Pn = ring.shape
    ring_o, total_o, avg = wa_window_update_2d(
        _tiles(ring), _tiles(total), _tiles(new),
        jnp.asarray(idx, jnp.int32), jnp.asarray(full_flag, jnp.float32),
        jnp.asarray(inv_count, jnp.float32), interpret=_interpret())
    return (ring_o.reshape(I, Pn), total_o.reshape(Pn), avg.reshape(Pn))


@functools.partial(jax.jit, static_argnames=("inv_k",))
def online_mean_packed(stacked, inv_k: float | None = None):
    """(K, P) packed replicas -> (P,) f32 mean. One kernel launch.

    With ``inv_k`` set, computes the partial mean ``sum × inv_k`` instead
    (the mesh-resident sync's pre-psum contribution when the replica
    stack is itself sharded over a mesh axis)."""
    K, Pn = stacked.shape
    return online_mean_2d(_tiles(stacked), interpret=_interpret(),
                          inv_k=inv_k).reshape(Pn)


@functools.partial(jax.jit, donate_argnums=(1, 2))
def hwa_sync_packed(stacked, ring, total, idx, full_flag, inv_count):
    """The whole HWA sync in ONE launch over packed state.

    stacked: (K, P) packed replicas; ring: (I, P); total: (P,) — f32,
    P % ALIGN == 0. Fuses the K-replica mean with the slide-window update:
    (K+2)·N reads + 3·N writes, no intermediate W̄ round-trip through HBM.
    Returns (ring', total', avg); W̄ for the replica restart is ring'[idx].
    """
    I, Pn = ring.shape
    ring_o, total_o, avg = wa_sync_fused_2d(
        _tiles(stacked), _tiles(ring), _tiles(total),
        jnp.asarray(idx, jnp.int32), jnp.asarray(full_flag, jnp.float32),
        jnp.asarray(inv_count, jnp.float32), interpret=_interpret())
    return (ring_o.reshape(I, Pn), total_o.reshape(Pn), avg.reshape(Pn))


@functools.partial(jax.jit, donate_argnums=(0, 1, 2))
def wa_window_update_packed_c(ring, total, comp, new, idx, full_flag,
                              inv_count):
    """Compressed-ring sibling of :func:`wa_window_update_packed`:
    ring (I, P) bf16, total/comp (P,) f32 (Kahan pair). One launch;
    ring/total/comp donated. Returns (ring', total', comp', avg)."""
    I, Pn = ring.shape
    ring_o, total_o, comp_o, avg = wa_window_update_c_2d(
        _tiles(ring), _tiles(total), _tiles(comp), _tiles(new),
        jnp.asarray(idx, jnp.int32), jnp.asarray(full_flag, jnp.float32),
        jnp.asarray(inv_count, jnp.float32), interpret=_interpret())
    return (ring_o.reshape(I, Pn), total_o.reshape(Pn),
            comp_o.reshape(Pn), avg.reshape(Pn))


@functools.partial(jax.jit, donate_argnums=(1, 2, 3))
def hwa_sync_packed_c(stacked, ring, total, comp, idx, full_flag,
                      inv_count):
    """Compressed-ring sibling of :func:`hwa_sync_packed`: the whole sync
    in ONE launch with the K-mean, the bf16 slot write and the
    Kahan-compensated f32 total fused. Returns (ring', total', comp',
    avg); W̄ for the replica restart is ``ring'[idx].astype(f32)``."""
    I, Pn = ring.shape
    ring_o, total_o, comp_o, avg = wa_sync_fused_c_2d(
        _tiles(stacked), _tiles(ring), _tiles(total), _tiles(comp),
        jnp.asarray(idx, jnp.int32), jnp.asarray(full_flag, jnp.float32),
        jnp.asarray(inv_count, jnp.float32), interpret=_interpret())
    return (ring_o.reshape(I, Pn), total_o.reshape(Pn),
            comp_o.reshape(Pn), avg.reshape(Pn))


def _pad_flat(x, tile=TILE_ROWS * TILE_COLS):
    n = int(np.prod(x.shape))
    pad = (-n) % tile
    flat = jnp.pad(x.reshape(-1), (0, pad))
    return flat.reshape(-1, TILE_COLS), n


@functools.partial(jax.jit, static_argnames=())
def wa_window_update(ring, total, new, idx, full_flag, inv_count):
    """Fused slide-window update for one parameter leaf.

    ring: (I, *shape) f32; total: (*shape) f32; new: (*shape) any float.
    Returns (ring', total', avg) in the original shapes (avg f32).
    """
    I = ring.shape[0]
    shape = total.shape
    ring2d = ring.reshape(I, -1)
    n = ring2d.shape[1]
    pad = (-n) % (TILE_ROWS * TILE_COLS)
    ring2d = jnp.pad(ring2d, ((0, 0), (0, pad))).reshape(I, -1, TILE_COLS)
    total2d, _ = _pad_flat(total)
    new2d, _ = _pad_flat(new.astype(jnp.float32))
    ring_o, total_o, avg_o = wa_window_update_2d(
        ring2d, total2d, new2d, jnp.asarray(idx, jnp.int32),
        jnp.asarray(full_flag, jnp.float32),
        jnp.asarray(inv_count, jnp.float32), interpret=_interpret())
    ring_out = ring_o.reshape(I, -1)[:, :n].reshape(ring.shape)
    total_out = total_o.reshape(-1)[:n].reshape(shape)
    avg = avg_o.reshape(-1)[:n].reshape(shape)
    return ring_out, total_out, avg


@jax.jit
def online_mean(stacked):
    """(K, *shape) -> mean over replicas, original dtype of ``stacked``."""
    K = stacked.shape[0]
    shape = stacked.shape[1:]
    x2d = stacked.reshape(K, -1)
    n = x2d.shape[1]
    pad = (-n) % (TILE_ROWS * TILE_COLS)
    x2d = jnp.pad(x2d, ((0, 0), (0, pad))).reshape(K, -1, TILE_COLS)
    out = online_mean_2d(x2d, interpret=_interpret())
    return out.reshape(-1)[:n].reshape(shape).astype(stacked.dtype)


def flash_attention(q, k, v, q_pos=None, k_pos=None, *, window=None,
                    logit_softcap=0.0, block_q=128, block_k=128):
    """run_attention-compatible wrapper (training/prefill layout:
    contiguous positions starting at 0). Pads head_dim to 128 and ragged
    sequence lengths up to a block multiple; differentiable end-to-end
    (the kernel's custom VJP composes with the pad/slice here).

    Padding is grad-exact: padded key positions sit ABOVE every real
    query position, so the causal mask hides them; padded query rows are
    sliced off, their cotangent is zero, and zero dO contributes zero to
    dk/dv. Zero head-dim columns likewise produce zero gradient columns.
    """
    D, S, T = q.shape[-1], q.shape[1], k.shape[1]
    sm_scale = 1.0 / (D ** 0.5)
    pad_d = (-D) % 128
    if pad_d:
        padw = [(0, 0)] * 3 + [(0, pad_d)]
        q = jnp.pad(q, padw)
        k = jnp.pad(k, padw)
        v = jnp.pad(v, padw)
    bq, bk = min(block_q, S), min(block_k, T)
    pad_s, pad_t = (-S) % bq, (-T) % bk
    seqpad = lambda x, n: jnp.pad(x, ((0, 0), (0, n), (0, 0), (0, 0)))
    if pad_s:
        q = seqpad(q, pad_s)
    if pad_t:
        k = seqpad(k, pad_t)
        v = seqpad(v, pad_t)
    out = flash_attention_pallas(
        q, k, v, causal=True, window=window, logit_softcap=logit_softcap,
        block_q=bq, block_k=bk, sm_scale=sm_scale,
        interpret=_interpret())
    return out[:, :S, :, :D]
