"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wa_window_update_ref(ring, total, new, idx, full_flag, inv_count):
    """ring: (I, *shape); total/new: (*shape). Returns (ring', total', avg).

    The ring may be stored in a lower precision (e.g. bf16 — a 2× memory
    saving for huge models, at the cost of slight drift in the running
    total; see EXPERIMENTS.md §Perf pair 3). ``total`` stays f32.
    """
    newf = new.astype(jnp.float32)
    old = ring[idx].astype(jnp.float32) * full_flag
    total2 = total + newf - old
    ring2 = jax.lax.dynamic_update_index_in_dim(
        ring, newf.astype(ring.dtype), idx, 0)
    return ring2, total2, total2 * inv_count


def online_mean_ref(stacked):
    """(K, *shape) -> f32 mean over axis 0."""
    return jnp.mean(stacked.astype(jnp.float32), axis=0)


def wa_sync_fused_ref(stacked, ring, total, idx, full_flag, inv_count):
    """Fused sync oracle: K-replica mean then window update.

    Matches the fused kernel bitwise: mean = sum * (1/K), not jnp.mean's
    sum / K (the two differ by up to 1 ULP for non-power-of-two K).
    Returns (ring', total', avg); W̄ is ring'[idx].
    """
    K = stacked.shape[0]
    mean = jnp.sum(stacked.astype(jnp.float32), axis=0) * (1.0 / K)
    return wa_window_update_ref(ring, total, mean, idx, full_flag, inv_count)


def wa_window_update_c_ref(ring, scales, total, comp, new, idx, full_flag,
                           inv_count):
    """Compressed-ring window update oracle: ring stored bf16 (``scales``
    None) or block-scaled fp8 (``scales``: (I, blocks) f32), running total
    f32 with Kahan compensation ``comp``.

    Unlike :func:`wa_window_update_ref`, the total accumulates the
    DEQUANTIZED value the slot will actually hold, so evicting the slot I
    cycles later removes exactly what was added — the total is always the
    (compensated-f32) sum of the ring's decoded contents, and the only
    error vs the f32 oracle is the per-slot quantization itself.

    Returns (ring', scales', total', comp', avg).
    """
    from repro.common.quant import decode_slot, encode_slot, kahan_add
    newf = new.astype(jnp.float32)
    slot, s_new = encode_slot(newf, ring.dtype)
    stored = decode_slot(slot, s_new)
    old = decode_slot(ring[idx], None if scales is None else scales[idx])
    total2, comp2 = kahan_add(total, comp, stored - old * full_flag)
    ring2 = jax.lax.dynamic_update_index_in_dim(ring, slot, idx, 0)
    scales2 = None if scales is None else \
        jax.lax.dynamic_update_index_in_dim(scales, s_new, idx, 0)
    return ring2, scales2, total2, comp2, total2 * inv_count


def wa_sync_fused_c_ref(stacked, ring, scales, total, comp, idx, full_flag,
                        inv_count):
    """Fused sync oracle over a compressed ring (mean as sum × 1/K, like
    :func:`wa_sync_fused_ref`). Returns (ring', scales', total', comp',
    avg); W̄ is the DECODED ring'[idx] (the mean itself, pre-quantization,
    is ``decode`` of what the caller reads back)."""
    K = stacked.shape[0]
    mean = jnp.sum(stacked.astype(jnp.float32), axis=0) * (1.0 / K)
    return wa_window_update_c_ref(ring, scales, total, comp, mean, idx,
                                  full_flag, inv_count)


def attention_ref(q, k, v, *, causal=True, window=None, logit_softcap=0.0,
                  sm_scale=None):
    """Naive GQA attention. q: (B,S,Hq,D); k/v: (B,T,Hkv,D)."""
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = sm_scale if sm_scale is not None else 1.0 / (D ** 0.5)
    qg = q.reshape(B, S, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bskgd,btkd->bkgst", qg, k.astype(jnp.float32)) * scale
    if logit_softcap:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(T)[None, :]
    mask = kp <= qp if causal else jnp.ones((S, T), bool)
    if window is not None:
        mask = mask & (qp - kp < window)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    out = out.reshape(B, S, Hq, D)
    # fully-masked rows -> zero output (matches kernel's l==0 guard)
    out = jnp.where(jnp.any(mask, axis=-1)[None, :, None, None], out, 0.0)
    return out.astype(q.dtype)
