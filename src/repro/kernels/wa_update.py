"""Fused HWA weight-averaging kernels (Pallas, TPU target).

The paper's per-cycle hot spot is elementwise arithmetic over the full
parameter set (DESIGN.md §2). Two kernels:

1. ``wa_window_update_kernel`` — fused slide-window update. Naively the
   ring update is three HBM passes (read old slot + read/write sum;
   write slot; read sum + write avg ⇒ 6N reads + 3N writes). Fused, each
   VMEM tile does::

       old       = ring[idx, tile]            (read)
       total'    = total + new - full*old     (read total, read new)
       ring[idx] = new                        (write)
       avg       = total' * inv_count         (write; total' written too)

   ⇒ 3N reads + 3N writes (total/ring-slot/avg), one pass. The ring slot
   index and the ``full``/``inv_count`` scalars are scalar-prefetched so
   the BlockSpec index_map can address ring row ``idx`` directly in HBM —
   the untouched I−1 rows are never moved.

2. ``online_mean_kernel`` — K-replica mean (W̄ = (1/K)Σ W^k) fused with
   the f32 cast, tiled so each program reads K sub-tiles and writes one.

3. ``wa_sync_fused_kernel`` — the ENTIRE sync in one pass over packed
   state: K-replica mean and slide-window update fused, so W̄ never
   round-trips through HBM. Each tile does::

       mean      = (1/K) Σ_k stacked[k, tile]   (K reads)
       old       = ring[idx, tile]              (read)
       total'    = total + mean - full*old      (read total)
       ring[idx] = mean                         (write — ring slot IS W̄)
       total'                                    (write)
       avg       = total' * inv_count           (write)

   ⇒ (K+2)·N reads + 3·N writes, vs (K+3)·N reads + 4·N writes for the
   two-kernel pipeline (mean: K reads + 1 write; update: 3 reads + 3
   writes) with an intermediate W̄ buffer in HBM. The caller recovers W̄
   for the replica restart as ``ring'[idx]``.

All kernels operate on 2-D (rows, 128·k) views. The packed path
(``repro.common.packing``) feeds them one tile-aligned buffer for the
whole parameter set — zero per-call padding; the legacy per-leaf wrappers
in ``ops.py`` flatten/pad each leaf. ``ref.py`` holds the jnp oracles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# VMEM tile: (8, 1024) f32 = 32 KiB per operand; 6 operands ≈ 192 KiB —
# comfortably within the ~16 MiB VMEM budget, wide enough to stream HBM.
TILE_ROWS = 8
TILE_COLS = 1024


# One scalar-prefetch operand carries [idx, full_flag_bits,
# inv_count_bits] (i32; the f32 scalars are bitcast). Encoder and decoder
# below are the single source of truth for that positional layout — both
# window-update kernels decode through them.


def _pack_scalars(idx, full_flag, inv_count):
    return jnp.stack([
        idx.astype(jnp.int32),
        jax.lax.bitcast_convert_type(full_flag.astype(jnp.float32), jnp.int32),
        jax.lax.bitcast_convert_type(inv_count.astype(jnp.float32), jnp.int32),
    ])


def _unpack_scalars(scalars_ref):
    """(full_flag, inv_count) as f32; the idx slot is only read by the
    ring BlockSpec index_map (scalar prefetch)."""
    return (jax.lax.bitcast_convert_type(scalars_ref[1], jnp.float32),
            jax.lax.bitcast_convert_type(scalars_ref[2], jnp.float32))


# Shared BlockSpecs: the ring is addressed at HBM row ``idx`` straight
# from the prefetched scalars (the untouched I−1 rows are never moved);
# flat operands tile the (R, C) plane.
_RING_SPEC = pl.BlockSpec((1, TILE_ROWS, TILE_COLS),
                          lambda i, j, s: (s[0], i, j))
_FLAT_SPEC = pl.BlockSpec((TILE_ROWS, TILE_COLS), lambda i, j, s: (i, j))


def _wa_window_update_kernel(scalars_ref, ring_ref, total_ref, new_ref,
                             ring_out_ref, total_out_ref, avg_ref):
    """One (TILE_ROWS, TILE_COLS) tile of the fused window update."""
    full, inv_count = _unpack_scalars(scalars_ref)
    old = ring_ref[0]                       # ring block is (1, rows, cols)
    new = new_ref[...]
    total = total_ref[...] + new - full * old
    ring_out_ref[0] = new
    total_out_ref[...] = total
    avg_ref[...] = total * inv_count


def wa_window_update_2d(ring, total, new, idx, full_flag, inv_count,
                        *, interpret: bool = True):
    """ring: (I, R, C) f32; total/new: (R, C) f32; idx: scalar int32.

    Returns (ring', total', avg). R % TILE_ROWS == 0, C % TILE_COLS == 0.
    """
    I, R, C = ring.shape
    assert total.shape == (R, C) and new.shape == (R, C)
    assert R % TILE_ROWS == 0 and C % TILE_COLS == 0, (R, C)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(R // TILE_ROWS, C // TILE_COLS),
        in_specs=[_RING_SPEC, _FLAT_SPEC, _FLAT_SPEC],
        out_specs=[_RING_SPEC, _FLAT_SPEC, _FLAT_SPEC],
    )
    ring_out, total_out, avg = pl.pallas_call(
        _wa_window_update_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct(ring.shape, jnp.float32),
                   jax.ShapeDtypeStruct(total.shape, jnp.float32),
                   jax.ShapeDtypeStruct(total.shape, jnp.float32)],
        input_output_aliases={1: 0, 2: 1},   # ring->ring_out, total->total_out
        interpret=interpret,
    )(_pack_scalars(idx, full_flag, inv_count), ring, total, new)
    return ring_out, total_out, avg


def _wa_sync_fused_kernel(scalars_ref, stacked_ref, ring_ref, total_ref,
                          ring_out_ref, total_out_ref, avg_ref, *,
                          inv_k: float):
    """One tile of the fused K-replica-mean + window update (whole sync)."""
    full, inv_count = _unpack_scalars(scalars_ref)
    mean = jnp.sum(stacked_ref[...].astype(jnp.float32), axis=0) * inv_k
    old = ring_ref[0]                       # ring block is (1, rows, cols)
    total = total_ref[...] + mean - full * old
    ring_out_ref[0] = mean                  # the slot IS W̄_e
    total_out_ref[...] = total
    avg_ref[...] = total * inv_count


def wa_sync_fused_2d(stacked, ring, total, idx, full_flag, inv_count,
                     *, interpret: bool = True):
    """Whole HWA sync, one launch. stacked: (K, R, C); ring: (I, R, C);
    total: (R, C) — all f32, R % TILE_ROWS == 0, C % TILE_COLS == 0.

    Returns (ring', total', avg) with ring'[idx] = W̄ = mean_k stacked[k]
    and avg = W̿. ring/total are donated (aliased in place).
    """
    K, R, C = stacked.shape
    assert ring.shape[1:] == (R, C) and total.shape == (R, C), \
        (stacked.shape, ring.shape, total.shape)
    assert R % TILE_ROWS == 0 and C % TILE_COLS == 0, (R, C)
    stacked_spec = pl.BlockSpec((K, TILE_ROWS, TILE_COLS),
                                lambda i, j, s: (0, i, j))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(R // TILE_ROWS, C // TILE_COLS),
        in_specs=[stacked_spec, _RING_SPEC, _FLAT_SPEC],
        out_specs=[_RING_SPEC, _FLAT_SPEC, _FLAT_SPEC],
    )
    ring_out, total_out, avg = pl.pallas_call(
        functools.partial(_wa_sync_fused_kernel, inv_k=1.0 / K),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct(ring.shape, jnp.float32),
                   jax.ShapeDtypeStruct(total.shape, jnp.float32),
                   jax.ShapeDtypeStruct(total.shape, jnp.float32)],
        input_output_aliases={2: 0, 3: 1},   # ring->ring_out, total->total_out
        interpret=interpret,
    )(_pack_scalars(idx, full_flag, inv_count), stacked, ring, total)
    return ring_out, total_out, avg


def _wa_window_update_c_kernel(scalars_ref, ring_ref, total_ref, comp_ref,
                               new_ref, ring_out_ref, total_out_ref,
                               comp_out_ref, avg_ref):
    """Compressed-ring tile: ring stored in a narrow dtype (bf16), total
    f32 with Kahan compensation. The down/up-casts ride the same single
    pass — every byte is already in VMEM."""
    full, inv_count = _unpack_scalars(scalars_ref)
    old = ring_ref[0].astype(jnp.float32)
    slot = new_ref[...].astype(ring_out_ref.dtype)
    stored = slot.astype(jnp.float32)
    total0 = total_ref[...]
    y = (stored - full * old) - comp_ref[...]
    total = total0 + y
    ring_out_ref[0] = slot
    total_out_ref[...] = total
    comp_out_ref[...] = (total - total0) - y
    avg_ref[...] = total * inv_count


def wa_window_update_c_2d(ring, total, comp, new, idx, full_flag, inv_count,
                          *, interpret: bool = True):
    """Compressed-ring fused window update. ring: (I, R, C) bf16;
    total/comp/new: (R, C) f32. Returns (ring', total', comp', avg);
    ring/total/comp are donated (aliased in place). Matches
    ``ref.wa_window_update_c_ref`` bitwise (scales=None)."""
    I, R, C = ring.shape
    assert total.shape == (R, C) and comp.shape == (R, C) \
        and new.shape == (R, C)
    assert R % TILE_ROWS == 0 and C % TILE_COLS == 0, (R, C)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(R // TILE_ROWS, C // TILE_COLS),
        in_specs=[_RING_SPEC, _FLAT_SPEC, _FLAT_SPEC, _FLAT_SPEC],
        out_specs=[_RING_SPEC, _FLAT_SPEC, _FLAT_SPEC, _FLAT_SPEC],
    )
    ring_out, total_out, comp_out, avg = pl.pallas_call(
        _wa_window_update_c_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct(ring.shape, ring.dtype),
                   jax.ShapeDtypeStruct(total.shape, jnp.float32),
                   jax.ShapeDtypeStruct(comp.shape, jnp.float32),
                   jax.ShapeDtypeStruct(total.shape, jnp.float32)],
        # ring->ring_out, total->total_out, comp->comp_out
        input_output_aliases={1: 0, 2: 1, 3: 2},
        interpret=interpret,
    )(_pack_scalars(idx, full_flag, inv_count), ring, total, comp, new)
    return ring_out, total_out, comp_out, avg


def _wa_sync_fused_c_kernel(scalars_ref, stacked_ref, ring_ref, total_ref,
                            comp_ref, ring_out_ref, total_out_ref,
                            comp_out_ref, avg_ref, *, inv_k: float):
    """Fused sync tile over a compressed ring: K-mean, narrow-dtype slot
    write, Kahan-compensated f32 total — one pass."""
    full, inv_count = _unpack_scalars(scalars_ref)
    mean = jnp.sum(stacked_ref[...].astype(jnp.float32), axis=0) * inv_k
    old = ring_ref[0].astype(jnp.float32)
    slot = mean.astype(ring_out_ref.dtype)
    stored = slot.astype(jnp.float32)
    total0 = total_ref[...]
    y = (stored - full * old) - comp_ref[...]
    total = total0 + y
    ring_out_ref[0] = slot
    total_out_ref[...] = total
    comp_out_ref[...] = (total - total0) - y
    avg_ref[...] = total * inv_count


def wa_sync_fused_c_2d(stacked, ring, total, comp, idx, full_flag,
                       inv_count, *, interpret: bool = True):
    """Whole compressed-ring HWA sync, one launch. stacked: (K, R, C)
    f32; ring: (I, R, C) bf16; total/comp: (R, C) f32. Returns (ring',
    total', comp', avg); W̄ is the caller's ``decode(ring'[idx])``."""
    K, R, C = stacked.shape
    assert ring.shape[1:] == (R, C) and total.shape == (R, C) \
        and comp.shape == (R, C)
    assert R % TILE_ROWS == 0 and C % TILE_COLS == 0, (R, C)
    stacked_spec = pl.BlockSpec((K, TILE_ROWS, TILE_COLS),
                                lambda i, j, s: (0, i, j))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(R // TILE_ROWS, C // TILE_COLS),
        in_specs=[stacked_spec, _RING_SPEC, _FLAT_SPEC, _FLAT_SPEC],
        out_specs=[_RING_SPEC, _FLAT_SPEC, _FLAT_SPEC, _FLAT_SPEC],
    )
    ring_out, total_out, comp_out, avg = pl.pallas_call(
        functools.partial(_wa_sync_fused_c_kernel, inv_k=1.0 / K),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct(ring.shape, ring.dtype),
                   jax.ShapeDtypeStruct(total.shape, jnp.float32),
                   jax.ShapeDtypeStruct(comp.shape, jnp.float32),
                   jax.ShapeDtypeStruct(total.shape, jnp.float32)],
        # ring->ring_out, total->total_out, comp->comp_out
        input_output_aliases={2: 0, 3: 1, 4: 2},
        interpret=interpret,
    )(_pack_scalars(idx, full_flag, inv_count), stacked, ring, total, comp)
    return ring_out, total_out, comp_out, avg


def _online_mean_kernel(x_ref, o_ref, *, inv_k: float):
    # x_ref: (K, TILE_ROWS, TILE_COLS) — reduce the replica axis in VMEM.
    o_ref[...] = jnp.sum(x_ref[...].astype(jnp.float32), axis=0) * inv_k


def online_mean_2d(stacked, *, interpret: bool = True,
                   inv_k: float | None = None):
    """stacked: (K, R, C) -> (R, C) f32 mean over axis 0.

    ``inv_k`` overrides the 1/K scale — the mesh-resident sync path uses
    it to compute a PARTIAL mean (local sum × 1/K_global) whose psum over
    the replica mesh axis is the global mean.
    """
    K, R, C = stacked.shape
    assert R % TILE_ROWS == 0 and C % TILE_COLS == 0, (R, C)
    grid = (R // TILE_ROWS, C // TILE_COLS)
    return pl.pallas_call(
        functools.partial(_online_mean_kernel,
                          inv_k=1.0 / K if inv_k is None else inv_k),
        grid=grid,
        in_specs=[pl.BlockSpec((K, TILE_ROWS, TILE_COLS),
                               lambda i, j: (0, i, j))],
        out_specs=pl.BlockSpec((TILE_ROWS, TILE_COLS), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((R, C), jnp.float32),
        interpret=interpret,
    )(stacked)
