"""hwa-lint: compile the bundle matrix and check every declarative
contract. Importable core of ``tools/hwa_lint.py`` (which only sets
XLA_FLAGS for the forced host devices before jax loads).

The matrix mirrors the configurations the repo's guarantees are stated
for (tests/mesh_hwa_check.py, docs/ARCHITECTURE.md): flat / two-level /
grouped-FSDP sync, the tree's inner sync, the train steps — on the
(2,2,2) test mesh, the pod-carved tree mesh, and a single device.
Contracts come from the builders (``StepBundle.contract``); a case can
override one to state something stronger than the family default.

``REPRO_LINT_SMOKE=1`` (or ``--smoke``) runs the PR-lane subset — one
case per pass family — leaving the full matrix to the nightly job.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys
from typing import Any, Callable

REQUIRED_DEVICES = 8

#: env var selecting the PR-lane smoke subset
SMOKE_ENV = "REPRO_LINT_SMOKE"


@dataclasses.dataclass
class LintCase:
    """One bundle×mesh configuration of the lint matrix."""
    name: str
    build: Callable[[], tuple]     # () -> (bundle, mesh)
    smoke: bool = False            # part of the PR-lane subset
    contract: Any = None           # override; default = bundle.contract


def default_cases() -> list[LintCase]:
    """The real-bundle matrix (needs the 8 forced host devices)."""
    import jax

    if len(jax.devices()) < REQUIRED_DEVICES:
        raise RuntimeError(
            f"hwa-lint needs {REQUIRED_DEVICES} devices for the test "
            f"meshes (found {len(jax.devices())}); run via "
            "tools/hwa_lint.py, which sets "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8 before "
            "importing jax")

    from repro.configs import get_smoke_config
    from repro.core.hwa import HWAConfig
    from repro.launch.mesh import make_test_mesh, make_tree_test_mesh
    from repro.launch.specs import input_specs
    from repro.launch.sync.plan import SyncPlan, build_hwa_bundles
    from repro.launch.sync.topology import TwoLevel
    from repro.models.registry import build_model
    from repro.models.types import InputShape
    from repro.sharding.rules import make_tp_rules

    cfg = get_smoke_config("granite-3-2b")
    lm = build_model(cfg)
    lm_fp = build_model(cfg.with_(attn_impl="flash_pallas"))
    shape = InputShape("tiny", seq_len=16, global_batch=8, kind="train")
    specs, dims = input_specs(cfg, shape)

    mesh = make_test_mesh((2, 2, 2), ("replica", "data", "model"))
    rules = make_tp_rules(mesh, replica_axis="replica")
    rules_f = make_tp_rules(mesh, replica_axis="replica", fsdp=True)
    mesh_t = make_tree_test_mesh()          # (pod=2, replica=2, model=2)
    rules_t = make_tp_rules(mesh_t, replica_axis=("pod", "replica"))
    mesh_1 = make_test_mesh((1, 1, 1), ("replica", "data", "model"))
    rules_1 = make_tp_rules(mesh_1, replica_axis="replica")

    hwa2 = HWAConfig(n_replicas=2, window=3)
    hwa2k = HWAConfig(n_replicas=2, window=3, use_kernels=True)
    hwa4k = HWAConfig(n_replicas=4, window=3, use_kernels=True)
    hwa4t = HWAConfig(n_replicas=4, window=3, use_kernels=True,
                      outer_every=2)
    hwa2r = HWAConfig(n_replicas=2, window=3, resilient=True)
    hwa4tr = HWAConfig(n_replicas=4, window=3, outer_every=2,
                       resilient=True)
    topo = TwoLevel("replica", "pod", outer_every=2)

    def train(lm_, rules_, hwa, **kw):
        plan = SyncPlan(hwa=hwa, optimizer="sgd", **kw)
        return build_hwa_bundles(lm_, rules_, plan, specs, dims).train

    def sync(rules_, hwa, **kw):
        return build_hwa_bundles(lm, rules_, SyncPlan(hwa=hwa, **kw)).sync

    return [
        LintCase(
            "train/mesh-native@2x2x2", smoke=True,
            build=lambda: (train(lm, rules, hwa2), mesh)),
        # flash-pallas train step: fully-manual shard_map (Pallas is
        # opaque to GSPMD) with an EXACT LaunchBudget — 1 attention fwd
        # + 2 recompute-bwd sweeps inside the single layer-scan eqn
        LintCase(
            "train/mesh-native-flash-pallas@2x2x2", smoke=True,
            build=lambda: (train(lm_fp, rules, hwa2), mesh)),
        LintCase(
            "train/hwa-vmap@2x2x2",
            build=lambda: (train(lm, rules, hwa2, mesh_native=False),
                           mesh)),
        LintCase(
            "sync/flat-resident@2x2x2", smoke=True,
            build=lambda: (sync(rules, hwa2), mesh)),
        LintCase(
            "sync/flat-resident-kernel@2x2x2", smoke=True,
            build=lambda: (sync(rules, hwa2k), mesh)),
        LintCase(
            "sync/flat-vmap-k4-kernel@2x2x2",
            build=lambda: (sync(rules, hwa4k, mesh_native=False), mesh)),
        LintCase(
            "sync/fsdp-grouped-kernel@2x2x2",
            build=lambda: (sync(rules_f, hwa2k), mesh)),
        LintCase(
            "sync/two-level-outer-kernel@tree",
            build=lambda: (build_hwa_bundles(
                lm, rules_t, SyncPlan(hwa=hwa4t, topology=topo)).sync,
                mesh_t)),
        # compressed precision corners (PR 10): bf16 ring storage keeps
        # the fused kernel; bf16 comms cast the cross-pod payload; fp8
        # replaces the outer all-reduce with an all-gather pair
        # (payload + per-block scales) and pushes via the jnp reference
        LintCase(
            "sync/flat-resident-bf16-ring@2x2x2", smoke=True,
            build=lambda: (sync(rules, hwa2k, wa_dtype="bf16"), mesh)),
        LintCase(
            "sync/two-level-outer-bf16-comms@tree",
            build=lambda: (build_hwa_bundles(
                lm, rules_t, SyncPlan(hwa=hwa4t, topology=topo,
                                      wa_dtype="bf16",
                                      comms_dtype="bf16")).sync, mesh_t)),
        LintCase(
            "sync/two-level-outer-fp8@tree",
            build=lambda: (build_hwa_bundles(
                lm, rules_t, SyncPlan(hwa=hwa4t, topology=topo,
                                      wa_dtype="fp8",
                                      comms_dtype="fp8")).sync, mesh_t)),
        # resilient (alive-masked) sync: exactly 2 replica-level
        # all-reduces (k_alive + masked weights) plus the budgeted
        # non-replica health-stats psum — still zero assembly traffic
        LintCase(
            "sync/flat-resident-resilient@2x2x2", smoke=True,
            build=lambda: (sync(rules, hwa2r), mesh)),
        LintCase(
            "sync/fsdp-grouped-resilient@2x2x2",
            build=lambda: (sync(rules_f, hwa2r), mesh)),
        LintCase(
            "sync/two-level-outer-resilient@tree",
            build=lambda: (build_hwa_bundles(
                lm, rules_t, SyncPlan(hwa=hwa4tr, topology=topo)).sync,
                mesh_t)),
        LintCase(
            "sync/two-level-inner@tree",
            build=lambda: (build_hwa_bundles(
                lm, rules_t,
                SyncPlan(hwa=hwa4t, topology=topo)).inner_sync, mesh_t)),
        LintCase(
            "sync/legacy-kernel@1dev", smoke=True,
            build=lambda: (sync(rules_1, hwa2k, mesh_native=False),
                           mesh_1)),
        # serving decode step: no collectives anywhere, exactly 1 paged-
        # attention launch (one pattern attention spec under flash_pallas,
        # counted once inside the layer-scan eqn), donated state buffers
        LintCase(
            "serve/paged-decode@1dev", smoke=True,
            build=lambda: (_paged_bundle(lm_fp), mesh_1)),
    ]


def _paged_bundle(lm):
    from repro.serve.engine import make_paged_decode_bundle
    return make_paged_decode_bundle(lm, max_batch=2, max_seq_len=64,
                                    max_new=4, page_size=4)


def run_case(case: LintCase) -> dict:
    """Build and lint one case; a build/compile crash becomes a failing
    report entry instead of killing the matrix."""
    from repro.analysis.passes import run_passes
    from repro.analysis.report import bundle_entry

    try:
        bundle, mesh = case.build()
        results = run_passes(bundle, mesh, contract=case.contract)
    except Exception as e:                      # noqa: BLE001
        return bundle_entry([], error=f"{type(e).__name__}: {e}")
    return bundle_entry(results)


def run_lint(cases: list[LintCase] | None = None, smoke: bool = False,
             log=print) -> dict:
    from repro.analysis.report import build_report

    cases = default_cases() if cases is None else cases
    if smoke:
        cases = [c for c in cases if c.smoke]
    bundles = {}
    for case in cases:
        log(f"lint: {case.name} ...")
        bundles[case.name] = run_case(case)
    return build_report(bundles, smoke=smoke)


def main(argv: list[str] | None = None) -> int:
    from repro.analysis.report import report_ok, summarize, to_json

    ap = argparse.ArgumentParser(
        prog="hwa_lint",
        description="Declarative SPMD contract checker over the compiled "
                    "bundle matrix (collectives, launch budgets, "
                    "donation, dtype, manual-subgroup hazards).")
    ap.add_argument("--smoke", action="store_true",
                    help="PR-lane subset (also via "
                         f"{SMOKE_ENV}=1)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the machine-readable report here")
    ap.add_argument("--only", metavar="SUBSTR", default=None,
                    help="run only cases whose name contains SUBSTR")
    ap.add_argument("--list", action="store_true",
                    help="list matrix case names and exit")
    args = ap.parse_args(argv)

    smoke = args.smoke or os.environ.get(SMOKE_ENV) == "1"
    cases = default_cases()
    if args.list:
        for c in cases:
            print(("[smoke] " if c.smoke else "        ") + c.name)
        return 0
    if args.only:
        cases = [c for c in cases if args.only in c.name]
        if not cases:
            print(f"no lint case matches {args.only!r}", file=sys.stderr)
            return 2
    report = run_lint(cases, smoke=smoke)
    if args.json:
        with open(args.json, "w") as f:
            f.write(to_json(report) + "\n")
        print(f"report written to {args.json}")
    print(summarize(report))
    return 0 if report_ok(report) else 1


if __name__ == "__main__":
    sys.exit(main())
