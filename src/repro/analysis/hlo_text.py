"""Post-SPMD HLO text parsing: instructions, shapes, replica groups.

The lowest layer of ``repro.analysis``: turn XLA's ``as_text()`` dump
into structured records the passes consume. Everything here is pure
string → data; the traffic model and contract checks live in
``analysis.collectives``, the pass framework in ``analysis.passes``.

**Instruction-form matching.** Each HLO line defines one instruction::

    %name = f32[8]{0} all-reduce(f32[8]{0} %operand), replica_groups=...

The OPCODE is the token between the result type and the operand list's
opening paren. Matching the opcode positionally (instead of substring
scans over the whole line) is load-bearing: the historical
``"-done" in line`` skip dropped any line merely *mentioning* an async
``-done`` value as an operand — e.g. a real all-reduce consuming
``%all-reduce-done.3`` vanished from the stats, silently voiding the
collective contracts. Here only instructions whose own opcode carries the
``-done`` suffix are classified as async completions (their ``-start``
half already carries the payload), and operand mentions are inert.
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# one HLO instruction: [ROOT] %name = <type> <opcode>(...
# the result type may be a tuple "(f32[4]{0}, f32[4]{0})" (async starts),
# so it is matched lazily up to the LAST token before the operand paren.
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(?.*?\)?)\s+([\w\-]+)\(")

#: opcodes the collective-traffic model covers (base form, no async
#: suffix). ``-start``/``-done`` pairs are folded onto the base op.
COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter",
                  "all-to-all", "collective-permute")

_ASYNC_SUFFIXES = ("-start", "-done")


@dataclasses.dataclass(frozen=True)
class HloInstruction:
    """One parsed HLO instruction line.

    ``opcode`` is the raw opcode (``all-reduce-start``); ``base_op`` has
    any async suffix stripped (``all-reduce``) and ``suffix`` is the
    stripped part (``"-start"``, ``"-done"`` or ``""``).
    """
    name: str
    result_type: str
    opcode: str
    line: str

    @property
    def base_op(self) -> str:
        for suf in _ASYNC_SUFFIXES:
            if self.opcode.endswith(suf):
                return self.opcode[:-len(suf)]
        return self.opcode

    @property
    def suffix(self) -> str:
        for suf in _ASYNC_SUFFIXES:
            if self.opcode.endswith(suf):
                return suf
        return ""

    @property
    def result_bytes(self) -> int:
        return shape_bytes(self.result_type)

    @property
    def result_dtypes(self) -> tuple[str, ...]:
        """Distinct dtypes appearing in the result type, in order."""
        out = []
        for dtype, _ in _SHAPE_RE.findall(self.result_type):
            if dtype in _DTYPE_BYTES and dtype not in out:
                out.append(dtype)
        return tuple(out)


def shape_bytes(type_str: str) -> int:
    """Total bytes of every shape token in an HLO type string (tuple
    types sum their members)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def line_dtypes(line: str) -> tuple[str, ...]:
    """Distinct shape dtypes mentioned anywhere on an HLO line (operands
    included) — the f64-leak scan matches TOKENS, not substrings, so an
    op_name metadata string containing "f64" cannot false-positive."""
    out = []
    for dtype, _ in _SHAPE_RE.findall(line):
        if dtype in _DTYPE_BYTES and dtype not in out:
            out.append(dtype)
    return tuple(out)


_DTYPE_TOKENS = {
    "float64": "f64", "float32": "f32", "float16": "f16",
    "bfloat16": "bf16", "float8_e4m3fn": "f8e4m3fn",
    "float8_e5m2": "f8e5m2", "bool": "pred", "int64": "s64",
    "int32": "s32", "int16": "s16", "int8": "s8", "uint64": "u64",
    "uint32": "u32", "uint16": "u16", "uint8": "u8", "complex64": "c64",
    "complex128": "c128",
}


def dtype_token(dtype) -> str:
    """HLO dtype token of a numpy/jax dtype (float32 → ``f32``)."""
    import numpy as np
    name = np.dtype(dtype).name
    return _DTYPE_TOKENS.get(name, name)


def parse_instruction(line: str) -> HloInstruction | None:
    """Parse one HLO line into an :class:`HloInstruction`, or None for
    non-instruction lines (headers, braces, comments)."""
    m = _INSTR_RE.match(line)
    if not m:
        return None
    return HloInstruction(name=m.group(1), result_type=m.group(2),
                          opcode=m.group(3), line=line)


def iter_instructions(hlo_text: str):
    """Every parsed instruction of an HLO module dump, in text order."""
    for line in hlo_text.splitlines():
        inst = parse_instruction(line)
        if inst is not None:
            yield inst


def collective_instructions(hlo_text: str) -> list[HloInstruction]:
    """Every collective instruction, async pairs counted ONCE.

    ``-start`` carries the op (its result holds the payload buffers);
    the matching ``-done`` is dropped by ITS OWN opcode — never by a
    substring scan, so collectives that merely consume a ``-done`` value
    as an operand are kept (see module docstring).
    """
    out = []
    for inst in iter_instructions(hlo_text):
        if inst.base_op in COLLECTIVE_OPS and inst.suffix != "-done":
            out.append(inst)
    return out


# ------------------------------------------------ replica-group structure
#
# Which devices does each collective pair up? XLA prints groups in two
# forms: explicit ``replica_groups={{0,4},{1,5}}`` and iota
# ``replica_groups=[n,g]<=[dims]`` with an optional ``T(perm)`` transpose;
# collective-permute carries ``source_target_pairs`` instead.

_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{(\{[\d,]*\}(?:,\{[\d,]*\})*)\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_PAIRS_RE = re.compile(r"source_target_pairs=\{((?:\{\d+,\d+\},?)*)\}")


def parse_replica_groups(line: str) -> list[list[int]] | None:
    """Participant groups of one HLO collective line, or None if absent.

    Members are *logical* partition indices (positions in the jit's
    device assignment, i.e. mesh.devices.flat order), not physical device
    ids. collective-permute carries source_target_pairs instead; each
    pair is returned as a two-member group.
    """
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return [[int(x) for x in g.split(",") if x]
                for g in re.findall(r"\{([\d,]*)\}", m.group(1))]
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        n, g = int(m.group(1)), int(m.group(2))
        dims = [int(d) for d in m.group(3).split(",")]
        import numpy as np
        arr = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            arr = arr.transpose([int(d) for d in m.group(4).split(",")])
        return [list(map(int, row)) for row in arr.reshape(n, g)]
    m = _PAIRS_RE.search(line)
    if m:
        return [[int(a), int(b)] for a, b in
                re.findall(r"\{(\d+),(\d+)\}", m.group(1))]
    return None


def parse_iota_group_size(line: str) -> int | None:
    """Group size g of the compact iota form ``replica_groups=[n,g]``,
    or None when the line uses another form."""
    m = _GROUPS_RE.search(line)
    return int(m.group(2)) if m else None


def axis_coords(mesh) -> dict[str, dict[int, int]]:
    """logical partition index (mesh.devices.flat position — what HLO
    replica_groups refer to) → coordinate along each mesh axis."""
    import numpy as np
    shape = mesh.devices.shape
    out: dict[str, dict[int, int]] = {a: {} for a in mesh.axis_names}
    for pos, idx in enumerate(np.ndindex(*shape)):
        for a, c in zip(mesh.axis_names, idx):
            out[a][pos] = c
    return out


# ------------------------------------------------ input/output aliasing
#
# Donation surfaces in two places: the compiled module header's
# ``input_output_alias={ {out}: (param, {path}, may-alias), ... }`` and
# the lowered StableHLO's per-arg ``tf.aliasing_output`` attributes. A
# donation XLA could not honor simply VANISHES from both (jax warns once
# at lowering, easily lost in CI logs) — which is exactly why the
# donation pass re-derives the declared set and diffs it here.


def parse_input_output_alias(hlo_text: str) -> set[int] | None:
    """Parameter numbers that are donation/alias SOURCES in a compiled
    module's ``input_output_alias`` header, or None when the header has
    no such config at all (every donation dropped, or none declared)."""
    key = "input_output_alias={"
    start = hlo_text.find(key)
    if start < 0:
        return None
    i = start + len(key)
    depth = 1
    while i < len(hlo_text) and depth:
        if hlo_text[i] == "{":
            depth += 1
        elif hlo_text[i] == "}":
            depth -= 1
        i += 1
    seg = hlo_text[start + len(key):i - 1]
    return {int(p) for p in re.findall(r"\(\s*(\d+)\s*,", seg)}


_ALIAS_ATTR_RE = re.compile(
    r"%arg(\d+):[^)]*?tf\.aliasing_output\s*=\s*(\d+)")
_DONOR_ATTR_RE = re.compile(r"%arg(\d+):[^)]*?jax\.buffer_donor")


def parse_lowered_donations(stablehlo_text: str) -> set[int]:
    """Flat argument indices carrying an aliasing/donor attribute in a
    LOWERED (StableHLO) module's @main signature. Backend-independent
    counterpart of :func:`parse_input_output_alias` (the compiled header
    is authoritative; this catches drops that happen at lowering)."""
    sig_at = stablehlo_text.find("@main")
    text = stablehlo_text if sig_at < 0 else \
        stablehlo_text[sig_at:stablehlo_text.find("\n", sig_at) + 1 or None]
    out = {int(m.group(1)) for m in _ALIAS_ATTR_RE.finditer(text)}
    out |= {int(m.group(1)) for m in _DONOR_ATTR_RE.finditer(text)}
    return out


# --------------------------------------------------- kernel-launch counting
#
# The packed WA path's contract is O(1) launches per sync regardless of
# parameter-leaf count. Counted structurally: ``pallas_call`` equations in
# the jaxpr (robust in interpret mode, where the lowered HLO has no
# custom-call marker), or ``custom-call`` ops targeting the TPU/Mosaic
# kernel entry points in compiled HLO text.

_PALLAS_CC_RE = re.compile(
    r'custom-call.*custom_call_target="(?:tpu_custom_call|mosaic|'
    r'__gpu\$xla\.gpu\.triton)"')


def count_pallas_calls(obj) -> int:
    """Number of Pallas kernel launches in a jaxpr (or ClosedJaxpr, or
    anything with a ``.jaxpr``) or in lowered/compiled HLO text."""
    if isinstance(obj, str):
        return sum(1 for line in obj.splitlines()
                   if _PALLAS_CC_RE.search(line))
    jaxpr = obj
    while hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    count = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            count += 1
        for param in eqn.params.values():
            for sub in (param if isinstance(param, (list, tuple)) else
                        (param,)):
                if hasattr(sub, "eqns") or hasattr(sub, "jaxpr"):
                    count += count_pallas_calls(sub)
    return count
