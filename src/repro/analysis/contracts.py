"""Declarative per-bundle SPMD contracts — pure data, no jax imports.

A :class:`BundleContract` states what a compiled StepBundle's program
must look like; the passes in ``analysis.passes`` check each piece and
``tools/hwa_lint.py`` runs the whole matrix. Builders attach a contract
to the bundles they assemble (``StepBundle.contract``) AT BUILD TIME —
the builder knows the topology, kernel gating and pack layout it chose,
so the declaration can be exact (e.g. the precise Pallas-launch count)
without a second source of truth. New bundles (the ROADMAP MoE/SSM sweep,
multi-host) get lint coverage by declaring a contract here and adding a
matrix entry in ``analysis.lint`` — not by writing new test assertions.

Every field set to ``None`` means "unchecked" — contracts state only the
guarantees a bundle actually makes.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping


@dataclasses.dataclass(frozen=True)
class CollectiveContract:
    """What the compiled program's collectives must be, per level.

    ``axis`` names the replica-population mesh axes (one name, or a tuple
    for a joint population like a two-level ``("pod", "replica")`` stack
    reduced flat); ``ops`` maps HLO base opcode → EXACT count of
    collectives crossing those axes (ops not listed must not appear).
    With ``outer_axis`` set, ``ops`` constrains the inner-only crossings,
    ``outer_ops`` the outer-only ones, and any group spanning both levels
    is a miswired composition (always a violation). ``assembly_free``
    demands the collectives crossing the remaining (non-level) mesh axes
    match ``other_ops`` EXACTLY — the default ``{}`` keeps the historical
    zero-assembly claim. ``other_ops`` exists for budgeted exceptions
    like the resilient sync's replica-health all-reduce, which crosses
    the data/model axes (to aggregate per-replica finiteness stats over
    each replica's shards) but never the replica population; a collective
    spanning BOTH a level axis and a non-level axis stays a violation
    regardless. ``axis=()`` + ``assembly_free=True`` + empty
    ``other_ops`` = "no collectives anywhere".
    """
    axis: str | tuple[str, ...] = ()
    ops: Mapping[str, int] = dataclasses.field(default_factory=dict)
    outer_axis: str | None = None
    outer_ops: Mapping[str, int] = dataclasses.field(default_factory=dict)
    assembly_free: bool = True
    other_ops: Mapping[str, int] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class LaunchBudget:
    """Pallas-launch budget, counted structurally in the jaxpr (branches
    of a ``cond`` included — the budget is a static program property)."""
    min: int = 0
    max: int = 0

    @classmethod
    def exact(cls, n: int) -> "LaunchBudget":
        return cls(min=n, max=n)


@dataclasses.dataclass(frozen=True)
class DtypePolicy:
    """Precision discipline for the compiled program.

    ``forbid``: HLO dtype tokens that must not appear ANYWHERE in the
    compiled text (f64 leaks — a stray Python float in the sync math
    silently doubles comm bytes). ``collective_dtypes``: allowed payload
    dtypes of every collective instruction (None = unchecked); the sync
    bundles pin this to ``("f32",)`` by default, and the compressed-comms
    bundles declare their exact payload set — the narrow-float token plus
    its same-width integer wire view (``("f32", "bf16", "u16")`` /
    ``("f32", "f8e4m3fn", "u8")``) — budgeted per-bundle exceptions
    rather than a global free-for-all.
    ``float_args``: allowed tokens for every inexact (floating) leaf of
    the bundle's abstract args (None = unchecked) — pins the packed
    ring/total and parameter state; a bf16-ring variant declares
    ``("f32", "bf16")`` explicitly.
    """
    forbid: tuple[str, ...] = ("f64",)
    collective_dtypes: tuple[str, ...] | None = None
    float_args: tuple[str, ...] | None = None


@dataclasses.dataclass(frozen=True)
class DonationPolicy:
    """Donation/aliasing verification of ``donate_argnums``.

    XLA only WARNS (once, at lowering) when it drops a donation; a
    dropped WA-buffer donation silently doubles window HBM. The pass
    re-derives each donated arg's flat parameter numbers and requires
    every one to appear as an alias source in the compiled module's
    ``input_output_alias`` config. ``ignore_scalar_leaves`` skips rank-0
    leaves (optimizer step counters — byte-free, and XLA legitimately
    folds them).
    """
    check: bool = True
    ignore_scalar_leaves: bool = True


@dataclasses.dataclass(frozen=True)
class HazardPolicy:
    """Manual-subgroup loop hazard (XLA 0.4.x fatal).

    ``while``/``scan`` inside a shard_map with manual axes fatals in the
    0.4.x partitioner (hlo_sharding_util.cc IsManualSubgroup) for
    partial-auto regions; ``ModelConfig.scan_unroll`` is the workaround
    the mesh-native builders force. The pass flags the pattern statically
    in the jaxpr so a new bundle fails lint with a pointer to the
    workaround instead of a partitioner crash. ``include_fully_manual``
    extends the flag to fully-manual regions too (no current bundle puts
    loops there; conservative default on 0.4.x). Pallas kernel bodies are
    exempt — their loops never reach the SPMD partitioner.
    """
    check: bool = True
    include_fully_manual: bool = True


@dataclasses.dataclass(frozen=True)
class BundleContract:
    """The full declarative contract of one StepBundle.

    ``collectives``/``launch`` default to None (unchecked) because only
    the builder knows them; ``dtypes``/``donation``/``hazard`` default to
    the universal discipline every bundle in this repo keeps (no f64,
    honored donations, no loops under manual shard_map).
    """
    collectives: CollectiveContract | None = None
    launch: LaunchBudget | None = None
    dtypes: DtypePolicy | None = DtypePolicy()
    donation: DonationPolicy | None = DonationPolicy()
    hazard: HazardPolicy | None = HazardPolicy()
    notes: str = ""


#: the universal baseline for bundles with no builder-attached contract
DEFAULT_CONTRACT = BundleContract()

#: strict f32 discipline of the WA sync bundles: collective payloads and
#: every floating arg leaf (params, packed ring/total) stay f32
SYNC_DTYPES_F32 = DtypePolicy(collective_dtypes=("f32",),
                              float_args=("f32",))


def sync_contract(axis, *, launches: int, outer_axis=None,
                  n_collectives: int = 1, outer_collectives: int = 0,
                  outer_ops: Mapping[str, int] | None = None,
                  other_ops: Mapping[str, int] | None = None,
                  collective_dtypes: tuple[str, ...] = ("f32",),
                  float_args: tuple[str, ...] = ("f32",),
                  notes: str = "") -> BundleContract:
    """Contract factory for WA sync bundles: ``n_collectives`` weight
    all-reduces over ``axis`` (0 when the replica stack is device-local;
    2 for the resilient alive-masked sync — k_alive + masked weights),
    optionally one level up over ``outer_axis``, non-level crossings
    pinned to ``other_ops`` (default: zero assembly traffic), an exact
    launch budget, and strict payload-dtype discipline.

    ``collective_dtypes`` defaults to the historical f32-only payload
    pin; the compressed-comms bundles widen it per bundle (e.g.
    ``("f32", "bf16", "u16")`` for the bf16 bit-view gather, ``("f32",
    "f8e4m3fn", "u8")`` for the fp8 gather pair) — a budgeted
    exception, not a global free-for-all. ``outer_ops`` overrides the
    default ``{"all-reduce": outer_collectives}`` outer-level census
    for shapes like the compressed paths, whose outer wire op is
    all-gather (bit-view payload, + scales for fp8), not all-reduce."""
    if outer_ops is None:
        outer_ops = ({"all-reduce": outer_collectives}
                     if outer_collectives else {})
    return BundleContract(
        collectives=CollectiveContract(
            axis=axis,
            ops={"all-reduce": n_collectives} if n_collectives else {},
            outer_axis=outer_axis,
            outer_ops=dict(outer_ops),
            assembly_free=True,
            other_ops=dict(other_ops) if other_ops else {}),
        launch=LaunchBudget.exact(launches),
        dtypes=DtypePolicy(collective_dtypes=collective_dtypes,
                           float_args=float_args),
        notes=notes)


def decode_contract(*, launches: int, notes: str = "") -> BundleContract:
    """Contract factory for serving decode steps: NO collectives anywhere
    (the paged engine is a single-device fixed-shape program — any
    collective means the serving mesh leaked into the hot path), an exact
    structural Pallas-launch budget (the paged-attention gather kernel
    per pattern attention spec, counted once inside the layer-scan eqn),
    donated cache/token/output buffers, and no f64. Collective payload
    dtypes are trivially unconstrained (there are none)."""
    return BundleContract(
        collectives=CollectiveContract(axis=(), ops={}, assembly_free=True),
        launch=LaunchBudget.exact(launches),
        notes=notes)


def train_contract(replica_axes=None, *, launches: int | None = None,
                   notes: str = "") -> BundleContract:
    """Contract factory for train steps: collective-free over the replica
    axes when given (the mesh-native H-fold amortization guarantee —
    data/model collectives unconstrained), no f64, loops-under-manual
    hazard-clean. Collective payload dtypes unchecked (the model may
    legitimately use attention kernels / integer gathers). ``launches``
    pins the exact structural Pallas-launch count when the builder knows
    it — the flash-pallas train step declares 3 (1 attention fwd + 2
    recompute-bwd sweeps inside the single layer-scan eqn; the compiled
    HLO physically carries 3 × n_layers), valid only when remat is off
    (recompute remat would re-run forwards inside the backward)."""
    collectives = None
    if replica_axes is not None:
        collectives = CollectiveContract(axis=replica_axes, ops={},
                                         assembly_free=False)
    launch = LaunchBudget.exact(launches) if launches is not None else None
    return BundleContract(collectives=collectives, launch=launch,
                          notes=notes)
