"""The static-analysis passes: each checks one facet of a compiled
StepBundle against its declarative contract.

Pass inventory (canonical report order):

- ``collectives``    — :class:`~repro.analysis.contracts.CollectiveContract`
  over the post-SPMD HLO (exact per-level op counts, zero assembly).
- ``launch_budget``  — Pallas-launch count in the jaxpr vs the declared
  :class:`~repro.analysis.contracts.LaunchBudget` (O(1)-launches claim).
- ``donation``       — every declared ``donate_argnums`` leaf appears as
  an alias source in the compiled ``input_output_alias`` config (a
  dropped donation doubles WA HBM and XLA only warns).
- ``dtype``          — no forbidden dtypes anywhere, collective payloads
  and floating args in the allowed sets (f32 discipline; the future
  bf16/fp8 compressed-comms enforcement point).
- ``manual_hazard``  — no ``while``/``scan`` under manual shard_map
  regions (the XLA 0.4.x IsManualSubgroup fatal ``scan_unroll`` works
  around), detected statically in the jaxpr BEFORE compiling.

Execution order differs from report order: the hazard pass runs first on
the jaxpr alone, and a flagged bundle is NOT compiled (the fatal it
predicts is a process abort, not an exception) — the compile-dependent
passes then report ``skipped`` with the reason.
"""
from __future__ import annotations

import dataclasses

from repro.analysis.collectives import check_collective_contract
from repro.analysis.contracts import DEFAULT_CONTRACT, BundleContract
from repro.analysis.hlo_text import (collective_instructions,
                                     count_pallas_calls, dtype_token,
                                     line_dtypes, parse_input_output_alias)

#: canonical pass order in reports (the execution order is different —
#: manual_hazard gates the compile)
PASS_NAMES = ("collectives", "launch_budget", "donation", "dtype",
              "manual_hazard")

_EVIDENCE_CAP = 8


def _trim(line: str, n: int = 200) -> str:
    line = line.strip()
    return line if len(line) <= n else line[:n] + "…"


@dataclasses.dataclass
class PassResult:
    """Verdict of one pass on one bundle."""
    name: str
    ok: bool
    violations: list
    evidence: list
    skipped: bool = False

    def as_json(self) -> dict:
        return {"ok": bool(self.ok), "skipped": bool(self.skipped),
                "violations": list(self.violations),
                "evidence": list(self.evidence)}


def _skipped(name: str, why: str) -> PassResult:
    return PassResult(name=name, ok=True, violations=[], evidence=[why],
                      skipped=True)


class BundleArtifacts:
    """Lazily-computed analysis inputs for one (bundle, mesh) pair.

    The jaxpr is cheap (abstract tracing, no compile); ``compiled_text``
    triggers the full jit compile once and is shared by every
    compile-dependent pass.
    """

    def __init__(self, bundle, mesh):
        self.bundle = bundle
        self.mesh = mesh
        self._jaxpr = None
        self._compiled_text = None

    @property
    def jaxpr(self):
        if self._jaxpr is None:
            import jax
            self._jaxpr = jax.make_jaxpr(self.bundle.fn)(
                *self.bundle.abstract_args)
        return self._jaxpr

    @property
    def compiled_text(self) -> str:
        if self._compiled_text is None:
            self._compiled_text = \
                self.bundle.lower(self.mesh).compile().as_text()
        return self._compiled_text


# ------------------------------------------------------------ the passes


def collectives_pass(art: BundleArtifacts,
                     contract: BundleContract) -> PassResult:
    if contract.collectives is None:
        return _skipped("collectives", "no collective contract declared")
    res = check_collective_contract(art.compiled_text, art.mesh,
                                    contract.collectives)
    return PassResult(
        name="collectives", ok=res["ok"], violations=res["violations"],
        evidence=[_trim(ln) for ln in res["evidence"][:_EVIDENCE_CAP]])


def launch_budget_pass(art: BundleArtifacts,
                       contract: BundleContract) -> PassResult:
    budget = contract.launch
    if budget is None:
        return _skipped("launch_budget", "no launch budget declared")
    n = count_pallas_calls(art.jaxpr)
    ok = budget.min <= n <= budget.max
    violations = [] if ok else [
        f"pallas launch count {n} outside budget "
        f"[{budget.min}, {budget.max}]"]
    return PassResult(name="launch_budget", ok=ok, violations=violations,
                      evidence=[f"pallas_call eqns in jaxpr: {n}"])


def donation_pass(art: BundleArtifacts,
                  contract: BundleContract) -> PassResult:
    policy = contract.donation
    if policy is None or not policy.check:
        return _skipped("donation", "donation check disabled")
    import jax
    bundle = art.bundle
    donated: dict[int, str] = {}        # flat param number -> description
    offset = 0
    for i, arg in enumerate(bundle.abstract_args):
        leaves = jax.tree.leaves(arg)
        if i in bundle.donate_argnums:
            for j, leaf in enumerate(leaves):
                if policy.ignore_scalar_leaves and getattr(
                        leaf, "ndim", len(leaf.shape)) == 0:
                    continue
                donated[offset + j] = (
                    f"arg {i} leaf {j} "
                    f"{dtype_token(leaf.dtype)}{list(leaf.shape)}")
        offset += len(leaves)
    if not donated:
        return PassResult(name="donation", ok=True, violations=[],
                          evidence=["no (non-scalar) donated leaves"])
    aliased = parse_input_output_alias(art.compiled_text)
    if aliased is None:
        return PassResult(
            name="donation", ok=False,
            violations=[f"all {len(donated)} declared donations dropped: "
                        "compiled module has no input_output_alias "
                        "config"],
            evidence=[donated[p] for p in sorted(donated)[:_EVIDENCE_CAP]])
    missing = sorted(set(donated) - aliased)
    violations = [f"donation dropped: param {p} ({donated[p]}) is not an "
                  "input_output_alias source" for p in missing]
    return PassResult(
        name="donation", ok=not missing, violations=violations,
        evidence=[f"declared {len(donated)} donated params, "
                  f"{len(donated) - len(missing)} aliased by XLA"])


def dtype_pass(art: BundleArtifacts,
               contract: BundleContract) -> PassResult:
    policy = contract.dtypes
    if policy is None:
        return _skipped("dtype", "no dtype policy declared")
    violations: list[str] = []
    evidence: list[str] = []
    forbid = set(policy.forbid)
    if forbid:
        found: dict[str, int] = {}
        for line in art.compiled_text.splitlines():
            bad = [t for t in line_dtypes(line) if t in forbid]
            if bad:
                for t in bad:
                    found[t] = found.get(t, 0) + 1
                if len(evidence) < _EVIDENCE_CAP:
                    evidence.append(_trim(line))
        for t in sorted(found):
            violations.append(f"forbidden dtype {t} appears on "
                              f"{found[t]} line(s) of the compiled "
                              "program")
    if policy.collective_dtypes is not None:
        allowed = set(policy.collective_dtypes)
        for inst in collective_instructions(art.compiled_text):
            bad = [t for t in inst.result_dtypes if t not in allowed]
            if bad:
                violations.append(
                    f"collective payload dtype {'/'.join(bad)} not in "
                    f"allowed {sorted(allowed)} ({inst.base_op})")
                if len(evidence) < _EVIDENCE_CAP:
                    evidence.append(_trim(inst.line))
    if policy.float_args is not None:
        import jax
        import jax.numpy as jnp
        allowed_f = set(policy.float_args)
        for i, arg in enumerate(art.bundle.abstract_args):
            for j, leaf in enumerate(jax.tree.leaves(arg)):
                # jnp.issubdtype, not np: ml_dtypes (bf16, fp8) are not
                # np.floating subtypes and would silently pass
                if not jnp.issubdtype(leaf.dtype, jnp.floating):
                    continue
                tok = dtype_token(leaf.dtype)
                if tok not in allowed_f:
                    violations.append(
                        f"floating arg leaf (arg {i} leaf {j}) is {tok}, "
                        f"allowed {sorted(allowed_f)}")
    if not violations and not evidence:
        evidence = ["no forbidden dtypes; payloads/args within policy"]
    return PassResult(name="dtype", ok=not violations,
                      violations=violations, evidence=evidence)


_LOOP_PRIMS = ("while", "scan")


def manual_loop_hazards(jaxpr, include_fully_manual: bool = True) -> list:
    """Statically find ``while``/``scan`` eqns under manual shard_map
    regions anywhere in a jaxpr (ClosedJaxpr accepted).

    Pallas kernel bodies are NOT descended into: their internal loops
    lower through Mosaic/interpret, never the SPMD partitioner. A
    ``scan`` with ``unroll >= length`` (``scan_unroll=True`` sets
    ``unroll=length``) lowers loop-free — no while ever reaches the
    partitioner — so it is exempt; that is precisely the workaround this
    pass points to. Returns ``[(prim_name, context_dict), ...]`` with
    the enclosing region's manual axes and partial-auto flag.
    """
    hazards: list = []

    def walk(j, ctx):
        while hasattr(j, "jaxpr"):
            j = j.jaxpr
        if not hasattr(j, "eqns"):
            return
        for eqn in j.eqns:
            name = eqn.primitive.name
            if name == "pallas_call":
                continue
            if name in _LOOP_PRIMS and ctx is not None:
                unrolled = (name == "scan" and
                            eqn.params.get("unroll", 1)
                            >= eqn.params.get("length", float("inf")))
                if not unrolled:
                    hazards.append((name, ctx))
            sub_ctx = ctx
            if name == "shard_map":
                mesh = eqn.params.get("mesh")
                auto = eqn.params.get("auto") or frozenset()
                axis_names = tuple(getattr(mesh, "axis_names", ()))
                manual = tuple(a for a in axis_names if a not in auto)
                partial = bool(auto) and bool(manual)
                fully = bool(manual) and not auto
                if partial or (fully and include_fully_manual):
                    sub_ctx = {"manual_axes": manual,
                               "auto_axes": tuple(sorted(auto)),
                               "partial_auto": partial}
            for param in eqn.params.values():
                for sub in (param if isinstance(param, (list, tuple))
                            else (param,)):
                    if hasattr(sub, "eqns") or hasattr(sub, "jaxpr"):
                        walk(sub, sub_ctx)

    walk(jaxpr, None)
    return hazards


def manual_hazard_pass(art: BundleArtifacts,
                       contract: BundleContract) -> PassResult:
    policy = contract.hazard
    if policy is None or not policy.check:
        return _skipped("manual_hazard", "hazard check disabled")
    hazards = manual_loop_hazards(
        art.jaxpr, include_fully_manual=policy.include_fully_manual)
    violations = []
    evidence = []
    for name, ctx in hazards:
        kind = ("partial-auto" if ctx["partial_auto"] else "fully-manual")
        violations.append(
            f"`{name}` inside a {kind} manual shard_map region (manual "
            f"axes {ctx['manual_axes']}) — XLA 0.4.x fatals on loops "
            "under manual subgroups (IsManualSubgroup); unroll the loop "
            "(ModelConfig.scan_unroll=True) or hoist it out of the "
            "manual region")
        evidence.append(f"{name} under manual_axes={ctx['manual_axes']} "
                        f"auto={ctx['auto_axes']}")
    if not hazards:
        evidence = ["no while/scan under manual shard_map regions"]
    return PassResult(name="manual_hazard", ok=not hazards,
                      violations=violations,
                      evidence=evidence[:_EVIDENCE_CAP])


def run_passes(bundle, mesh, contract: BundleContract | None = None
               ) -> list[PassResult]:
    """Run every pass on one bundle, in canonical report order.

    ``contract`` defaults to the builder-attached ``bundle.contract``
    (or the universal baseline). The hazard pass executes FIRST: a
    flagged bundle would abort the process at compile time, so the
    compile-dependent passes are reported as skipped instead.
    """
    contract = (contract if contract is not None
                else getattr(bundle, "contract", None) or DEFAULT_CONTRACT)
    art = BundleArtifacts(bundle, mesh)
    hazard = manual_hazard_pass(art, contract)
    launch = launch_budget_pass(art, contract)
    if hazard.ok:
        coll = collectives_pass(art, contract)
        donation = donation_pass(art, contract)
        dtype = dtype_pass(art, contract)
    else:
        why = ("not compiled: manual-subgroup hazard detected (the XLA "
               "0.4.x fatal is a process abort, not an exception)")
        coll = _skipped("collectives", why)
        donation = _skipped("donation", why)
        dtype = _skipped("dtype", why)
    return [coll, launch, donation, dtype, hazard]
