"""Collective-traffic model, axis-crossing classification, and the
declarative collective contract check.

The compiled module is the *per-device* program (verified: cost_analysis
flops ≈ global/chips). Collective results are parsed from ``as_text()``
via ``analysis.hlo_text``; per-device traffic model (bytes moved over ICI
per device):

    all-reduce        : 2 × result_bytes × (g-1)/g   (ring: RS + AG phases)
    all-gather        : result_bytes × (g-1)/g       (result = gathered)
    reduce-scatter    : result_bytes × (g-1)          (result = one shard)
    all-to-all        : result_bytes × (g-1)/g
    collective-permute: result_bytes

with g the participating group size parsed from ``replica_groups=[n,g]``.

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses

from repro.analysis.hlo_text import (axis_coords, collective_instructions,
                                     parse_instruction,
                                     parse_iota_group_size,
                                     parse_replica_groups, shape_bytes)

PEAK_FLOPS = 197e12     # bf16 per chip
HBM_BW = 819e9          # bytes/s per chip
ICI_BW = 50e9           # bytes/s per link


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    bytes_by_op: dict
    traffic_bytes: float     # modeled per-device ICI traffic

    @property
    def total_result_bytes(self) -> float:
        return float(sum(self.bytes_by_op.values()))


def collective_stats(hlo_text: str) -> CollectiveStats:
    counts: dict = {}
    bytes_by_op: dict = {}
    traffic = 0.0
    for inst in collective_instructions(hlo_text):
        op = inst.base_op
        b = inst.result_bytes
        g = parse_iota_group_size(inst.line)
        if g is None:
            # explicit-list groups ({{0,4},{1,5},...}) and permute pairs
            groups = parse_replica_groups(inst.line)
            g = max((len(grp) for grp in groups), default=1) if groups else 1
        if g <= 1:
            factor = 0.0
        elif op == "all-reduce":
            factor = 2.0 * (g - 1) / g
        elif op == "all-gather":
            factor = (g - 1) / g
        elif op == "reduce-scatter":
            factor = float(g - 1)
        elif op == "all-to-all":
            factor = (g - 1) / g
        else:  # collective-permute
            factor = 1.0
        counts[op] = counts.get(op, 0) + 1
        bytes_by_op[op] = bytes_by_op.get(op, 0) + b
        traffic += b * factor
    return CollectiveStats(counts=counts, bytes_by_op=bytes_by_op,
                           traffic_bytes=traffic)


def collectives_crossing_axis(hlo_text: str, mesh, axis: str
                              ) -> list[tuple[str, str]]:
    """(op, hlo line) of every collective whose groups span ``axis``.

    A group "spans" the axis when two of its members sit at different
    coordinates along it. A collective whose participants cannot be
    parsed at all is conservatively counted as crossing — a false
    positive beats silently voiding the no-replica-traffic guarantee.
    """
    coords = axis_coords(mesh)[axis]
    hits = []
    for inst in collective_instructions(hlo_text):
        groups = parse_replica_groups(inst.line)
        if groups is None:
            hits.append((inst.base_op, inst.line.strip()))
            continue
        for grp in groups:
            if len({coords.get(d, -1) for d in grp}) > 1:
                hits.append((inst.base_op, inst.line.strip()))
                break
    return hits


def result_bytes(hits) -> int:
    """Total RESULT bytes of ``(op, hlo line)`` collective hits (as
    returned by :func:`collectives_crossing_axis` /
    :func:`sync_collective_audit`). Result type only — counting the whole
    line would also include operand shapes and double the figure."""
    total = 0
    for op, line in hits:
        inst = parse_instruction(line)
        total += shape_bytes(inst.result_type) if inst else 0
    return total


def sync_collective_audit(hlo_text: str, mesh, replica_axis: str = "replica",
                          outer_axis: str | None = None,
                          n_groups: int | None = None) -> dict:
    """Structural audit of an HWA sync step's collectives, per level.

    **Flat** (``outer_axis=None``): the mesh-resident packed sync's
    contract is exactly ONE collective — the weight all-reduce
    (pmean/psum) over the replica axis — and ZERO collectives crossing
    any other mesh axis (i.e. the packed-W̄ assembly and the W̿ unpack
    are shard-local).

    **Grouped** (``n_groups`` set): the mixed-tiling (FSDP) grouped
    layout keeps the SAME collective contract — the per-group window
    buffers change the kernel-launch budget (≤ ``n_groups``
    pallas_calls, counted separately via ``hlo_text.count_pallas_calls``
    on the jaxpr — interpret-mode HLO has no custom-call marker), not
    the traffic: partials are concatenated before the one replica
    all-reduce and every group's assembly stays shard-local. The
    ``grouped_sync_ok`` verdict asserts that HLO side.

    **Two-level** (``outer_axis`` set, e.g. ``"pod"``): each collective
    is classified by which of the two replica-population axes its
    ``replica_groups`` actually span —

    - *inner-only*: crosses ``replica_axis`` but NOT ``outer_axis`` (a
      per-pod reduction with pod-local groups);
    - *outer-only*: crosses ``outer_axis`` but NOT ``replica_axis`` (the
      cross-pod all-reduce of already-pod-reduced partials);
    - *mixed*: spans both — a MISWIRED grouping (e.g. one joint
      all-reduce where the tree promises a composition), rejected by
      both per-level verdicts below.

    The per-level expectations the tree bundles are audited against:

    - ``inner_sync_ok`` — an INNER sync crosses ONLY the inner groups:
      exactly one inner-only all-reduce, zero outer crossings, zero
      mixed, assembly-free;
    - ``outer_sync_ok`` — an OUTER sync adds exactly one cross-pod
      all-reduce on top: one inner-only + one outer-only all-reduce,
      zero mixed, assembly-free.

    Returns::

        {"replica": [(op, line), ...],   # all collectives crossing replica
         "outer":   [(op, line), ...],   # all crossing outer_axis ([] if None)
         "mixed":   [(op, line), ...],   # crossing both (miswired grouping)
         "other":   {axis: [(op, line), ...]},
         "replica_allreduce_only": bool, # replica hits are 1 all-reduce
         "assembly_free": bool,          # no crossings outside the levels
         "inner_sync_ok": bool,
         "outer_sync_ok": bool}

    Used by tests/mesh_hwa_check.py, tests/test_sync_topology.py and
    benchmarks/kernel_bench.py / benchmarks/sync_tree.py.
    """
    replica = collectives_crossing_axis(hlo_text, mesh, replica_axis)
    outer = (collectives_crossing_axis(hlo_text, mesh, outer_axis)
             if outer_axis is not None else [])
    outer_lines = {line for _, line in outer}
    replica_lines = {line for _, line in replica}
    mixed = [h for h in replica if h[1] in outer_lines]
    inner_only = [h for h in replica if h[1] not in outer_lines]
    outer_only = [h for h in outer if h[1] not in replica_lines]
    other = {ax: collectives_crossing_axis(hlo_text, mesh, ax)
             for ax in mesh.axis_names
             if ax != replica_axis and ax != outer_axis}
    assembly_free = not any(hits for hits in other.values())
    one_ar = lambda hits: len(hits) == 1 and hits[0][0] == "all-reduce"
    out = {
        "replica": replica,
        "outer": outer,
        "mixed": mixed,
        "other": other,
        "replica_allreduce_only": (
            len(replica) == 1 and replica[0][0] == "all-reduce"),
        "assembly_free": assembly_free,
        "inner_sync_ok": (one_ar(inner_only) and not outer
                          and assembly_free),
        "outer_sync_ok": (one_ar(inner_only) and one_ar(outer_only)
                          and not mixed and assembly_free),
    }
    if n_groups is not None:
        out["n_groups"] = n_groups
        out["grouped_sync_ok"] = (out["replica_allreduce_only"]
                                  and assembly_free)
    return out


def check_collective_contract(hlo_text: str, mesh, contract) -> dict:
    """Check compiled HLO against a declarative
    :class:`~repro.analysis.contracts.CollectiveContract`.

    The generalization of :func:`sync_collective_audit`'s hard-wired
    verdicts: the contract states exact per-op counts for the collectives
    crossing the replica axes (``ops``), optionally a second level over
    ``outer_axis`` (``outer_ops``) where a group spanning BOTH levels is
    always a miswiring, and exact per-op counts for collectives crossing
    ONLY the remaining mesh axes (``assembly_free`` + ``other_ops`` — the
    zero-assembly claim by default, a budgeted exception list for e.g.
    the resilient sync's health-stats all-reduce otherwise). ``axis=()``
    with ``assembly_free=True`` and empty ``other_ops`` therefore means
    "no collectives anywhere" (single-device / K-resident syncs).

    Returns ``{"ok": bool, "violations": [str], "counts": {op: n},
    "outer_counts": {op: n}, "evidence": [str]}`` — evidence lines are
    the offending (or, when clean, the matched) HLO collectives.
    """
    axes = ((contract.axis,) if isinstance(contract.axis, str)
            else tuple(contract.axis))
    inner_hits: dict[str, str] = {}        # line -> op, dedup joint axes
    for ax in axes:
        for op, line in collectives_crossing_axis(hlo_text, mesh, ax):
            inner_hits[line] = op
    outer_hits: dict[str, str] = {}
    if contract.outer_axis is not None:
        for op, line in collectives_crossing_axis(hlo_text, mesh,
                                                  contract.outer_axis):
            outer_hits[line] = op
    mixed = [ln for ln in inner_hits if ln in outer_hits]
    inner_only = {ln: op for ln, op in inner_hits.items()
                  if ln not in outer_hits}
    outer_only = {ln: op for ln, op in outer_hits.items()
                  if ln not in inner_hits}

    def _count(hits):
        counts: dict[str, int] = {}
        for op in hits.values():
            counts[op] = counts.get(op, 0) + 1
        return counts

    counts = _count(inner_only)
    outer_counts = _count(outer_only)
    violations: list[str] = []
    evidence: list[str] = []

    def _match(level, got, want):
        for op in sorted(set(got) | set(want)):
            g, w = got.get(op, 0), want.get(op, 0)
            if g != w:
                violations.append(
                    f"{level}: expected {w} × {op} crossing "
                    f"{axes if level == 'inner' else contract.outer_axis}, "
                    f"found {g}")

    _match("inner", counts, dict(contract.ops))
    if contract.outer_axis is not None:
        _match("outer", outer_counts, dict(contract.outer_ops))
        for ln in mixed:
            violations.append(
                f"miswired grouping: {inner_hits[ln]} spans both {axes} "
                f"and {contract.outer_axis}")
            evidence.append(ln)
    if contract.assembly_free:
        level_axes = set(axes) | ({contract.outer_axis}
                                  if contract.outer_axis else set())
        level_lines = set(inner_hits) | set(outer_hits)
        other_hits: dict[str, str] = {}    # line -> op, dedup joint axes
        for ax in mesh.axis_names:
            if ax in level_axes:
                continue
            for op, line in collectives_crossing_axis(hlo_text, mesh, ax):
                if line in level_lines:
                    # spans a level axis AND a non-level axis: miswired
                    # level traffic, never a budgeted "other" collective
                    if line not in evidence:
                        violations.append(
                            f"assembly traffic: {op} crosses both the "
                            f"level axes and non-level axis {ax!r}")
                        evidence.append(line)
                else:
                    other_hits[line] = op
        want_other = dict(getattr(contract, "other_ops", {}) or {})
        got_other = _count(other_hits)
        for op in sorted(set(got_other) | set(want_other)):
            g, w = got_other.get(op, 0), want_other.get(op, 0)
            if g != w:
                violations.append(
                    f"assembly traffic: expected {w} × {op} crossing "
                    f"non-level axes, found {g}")
        evidence.extend(ln for ln in other_hits if ln not in evidence)
    evidence.extend(ln for ln in inner_hits if ln not in evidence)
    evidence.extend(ln for ln in outer_only if ln not in evidence)
    return {"ok": not violations, "violations": violations,
            "counts": counts, "outer_counts": outer_counts,
            "evidence": evidence}


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   traffic_bytes: float) -> dict:
    compute_s = flops_per_device / PEAK_FLOPS
    memory_s = bytes_per_device / HBM_BW
    collective_s = traffic_bytes / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    terms["dominant"] = dominant
    terms["bound_s"] = terms[dominant]
    return terms
