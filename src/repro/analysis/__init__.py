"""Static analysis over lowered jaxprs and post-SPMD HLO.

Layers:

- :mod:`~repro.analysis.hlo_text`    — HLO-text parsing primitives
  (instructions, replica groups, aliasing config, dtype tokens).
- :mod:`~repro.analysis.collectives` — collective census, axis-crossing
  classification, the sync audit, roofline terms.
- :mod:`~repro.analysis.contracts`   — the declarative per-bundle
  contract schema (pure data, importable without jax).
- :mod:`~repro.analysis.passes`      — the checks: collectives, launch
  budget, donation/aliasing, dtype discipline, manual-subgroup hazards.
- :mod:`~repro.analysis.report`      — machine-readable JSON report.
- :mod:`~repro.analysis.lint`        — the bundle×mesh matrix runner
  behind ``tools/hwa_lint.py`` / ``make hwa-lint``.

``repro.launch.hlo`` remains the stable facade for the pre-existing
public names (ports of the old monolith); new code imports from here.
"""
from repro.analysis.collectives import (HBM_BW, ICI_BW, PEAK_FLOPS,
                                        CollectiveStats,
                                        check_collective_contract,
                                        collective_stats,
                                        collectives_crossing_axis,
                                        result_bytes, roofline_terms,
                                        sync_collective_audit)
from repro.analysis.contracts import (DEFAULT_CONTRACT, BundleContract,
                                      CollectiveContract, DonationPolicy,
                                      DtypePolicy, HazardPolicy,
                                      LaunchBudget, sync_contract,
                                      train_contract)
from repro.analysis.hlo_text import (HloInstruction, axis_coords,
                                     collective_instructions,
                                     count_pallas_calls, dtype_token,
                                     iter_instructions,
                                     parse_input_output_alias,
                                     parse_instruction,
                                     parse_lowered_donations,
                                     parse_replica_groups)
from repro.analysis.passes import (PASS_NAMES, BundleArtifacts, PassResult,
                                   manual_loop_hazards, run_passes)
from repro.analysis.report import (build_report, bundle_entry, report_ok,
                                   summarize, to_json)

__all__ = [
    "PEAK_FLOPS", "HBM_BW", "ICI_BW",
    "CollectiveStats", "collective_stats", "collectives_crossing_axis",
    "result_bytes", "roofline_terms", "sync_collective_audit",
    "check_collective_contract",
    "BundleContract", "CollectiveContract", "LaunchBudget", "DtypePolicy",
    "DonationPolicy", "HazardPolicy", "DEFAULT_CONTRACT",
    "sync_contract", "train_contract",
    "HloInstruction", "parse_instruction", "iter_instructions",
    "collective_instructions", "parse_replica_groups", "axis_coords",
    "parse_input_output_alias", "parse_lowered_donations", "dtype_token",
    "count_pallas_calls",
    "PASS_NAMES", "PassResult", "BundleArtifacts", "manual_loop_hazards",
    "run_passes",
    "bundle_entry", "build_report", "report_ok", "to_json", "summarize",
]
