"""Machine-readable lint report: build, serialize, summarize.

Schema (version 1)::

    {"schema": 1,
     "smoke": bool,                     # PR smoke subset vs full matrix
     "bundles": {
        "<case name>": {
           "ok": bool,
           "passes": {
              "<pass>": {"ok": bool, "skipped": bool,
                         "violations": [str], "evidence": [str]},
              ...},
           "error": str,               # only when the case failed to build
        }, ...},
     "ok": bool,
     "n_bundles": int, "n_violations": int}

The report is plain JSON — CI uploads it as an artifact and downstream
tooling (dashboards, the nightly diff) consumes it without importing
this package. ``report_ok(json.loads(json.dumps(r)))`` is the round-trip
contract the tests pin.
"""
from __future__ import annotations

import json

from repro.analysis.passes import PassResult

SCHEMA_VERSION = 1


def bundle_entry(results: list[PassResult], error: str | None = None
                 ) -> dict:
    """One case's report entry from its pass results (or a build error,
    which fails the case with a pseudo-entry)."""
    if error is not None:
        return {"ok": False, "passes": {}, "error": error}
    return {"ok": all(r.ok for r in results),
            "passes": {r.name: r.as_json() for r in results}}


def build_report(bundles: dict[str, dict], smoke: bool = False) -> dict:
    n_violations = sum(
        len(p.get("violations", ())) for entry in bundles.values()
        for p in entry.get("passes", {}).values())
    n_violations += sum(1 for entry in bundles.values() if "error" in entry)
    return {"schema": SCHEMA_VERSION,
            "smoke": bool(smoke),
            "bundles": bundles,
            "ok": all(entry["ok"] for entry in bundles.values()),
            "n_bundles": len(bundles),
            "n_violations": n_violations}


def report_ok(report: dict) -> bool:
    """The exit-code predicate, stable under a JSON round-trip."""
    return bool(report.get("ok")) and report.get("n_bundles", 0) > 0


def to_json(report: dict) -> str:
    return json.dumps(report, indent=2, sort_keys=True)


def summarize(report: dict) -> str:
    """Human-readable per-bundle × per-pass table for the console."""
    lines = []
    for name in sorted(report["bundles"]):
        entry = report["bundles"][name]
        if "error" in entry:
            lines.append(f"ERROR {name}: {entry['error']}")
            continue
        verdicts = []
        for pname, p in entry["passes"].items():
            mark = ("skip" if p["skipped"] else
                    "ok" if p["ok"] else "FAIL")
            verdicts.append(f"{pname}={mark}")
        head = "PASS " if entry["ok"] else "FAIL "
        lines.append(head + name + "  [" + " ".join(verdicts) + "]")
        for p in entry["passes"].values():
            for v in p["violations"]:
                lines.append(f"    - {v}")
    mode = "smoke subset" if report.get("smoke") else "full matrix"
    lines.append(
        f"{'OK' if report_ok(report) else 'FAIL'} hwa-lint ({mode}): "
        f"{report['n_bundles']} bundle configs, "
        f"{report['n_violations']} violation(s)")
    return "\n".join(lines)
