"""Disk-backed store of outer-weight checkpoints (paper Algorithm 2 input).

The HWA offline module consumes outer weights W̄_e saved at each
synchronization cycle. At scale the window lives on-device (see
``repro.core.offline``); the store is the paper-faithful file path —
Algorithm 2 literally reads "Checkpoints of Outer Weights" — and enables
post-hoc window sweeps (trying multiple I, §III-B) without retraining.
"""
from __future__ import annotations

import os
import re
import warnings
from typing import Any

import jax

from repro.checkpoint.io import _read_raw, load_pytree, save_pytree
from repro.common.pytree import tree_add, tree_scale, tree_zeros_like


class OuterWeightStore:
    """``keep_last`` bounds the store: after every save, cycles older
    than the newest N are deleted (long runs would otherwise grow one
    full parameter set per sync cycle, unboundedly). ``None`` keeps
    everything (the post-hoc window-sweep use case needs history)."""

    def __init__(self, directory: str, keep_last: int | None = None):
        if keep_last is not None and keep_last < 1:
            raise ValueError(f"keep_last must be >= 1, got {keep_last}")
        self.directory = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)

    def _path(self, cycle: int) -> str:
        return os.path.join(self.directory, f"outer_{cycle:06d}.npz")

    def save(self, cycle: int, outer_weights: Any) -> None:
        save_pytree(self._path(cycle), outer_weights)
        if self.keep_last is not None:
            for old in self.cycles()[:-self.keep_last]:
                try:
                    os.remove(self._path(old))
                except OSError as e:          # pragma: no cover - racey FS
                    warnings.warn(f"retention: could not remove outer "
                                  f"checkpoint {old}: {e}")

    def verify(self) -> dict[int, str]:
        """``{cycle: problem}`` for every stored checkpoint that cannot
        be read back (truncated/corrupted npz). Empty dict == all good."""
        bad: dict[int, str] = {}
        for c in self.cycles():
            try:
                _read_raw(self._path(c))
            except Exception as e:
                bad[c] = f"{type(e).__name__}: {e}"
        return bad

    def load(self, cycle: int, like: Any) -> Any:
        return load_pytree(self._path(cycle), like)

    def cycles(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            m = re.fullmatch(r"outer_(\d+)\.npz", name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def window_average(self, end_cycle: int, window: int, like: Any,
                       stride: int = 1) -> Any:
        """W̿_e = mean of W̄_t for t in the slide window ending at e.

        ``stride`` implements the paper's sparse-window remark (§III-B):
        average only cycles with index in multiples of ``stride``.

        A partial or unparsable ``outer_*.npz`` inside the window (torn
        write, bit rot) is skipped with a warning instead of poisoning
        the whole sweep; the average renormalizes over the cycles that
        actually loaded. Raises only when NO cycle in the window is
        readable.
        """
        cycles = [c for c in self.cycles()
                  if end_cycle - window * stride < c <= end_cycle
                  and (c - end_cycle) % stride == 0]
        if not cycles:
            raise ValueError(f"no checkpoints in window ending at {end_cycle}")
        acc = tree_zeros_like(jax.tree.map(lambda x: x.astype("float32"), like))
        n_used = 0
        for c in cycles:
            try:
                w = self.load(c, like)
            except Exception as e:
                warnings.warn(f"skipping unreadable outer checkpoint "
                              f"{c} ({self._path(c)}): "
                              f"{type(e).__name__}: {e}")
                continue
            acc = tree_add(acc, jax.tree.map(lambda x: x.astype("float32"), w))
            n_used += 1
        if not n_used:
            raise ValueError(f"no READABLE checkpoints in window ending at "
                             f"{end_cycle} ({len(cycles)} present, all "
                             f"corrupt — see warnings)")
        avg = tree_scale(acc, 1.0 / n_used)
        return jax.tree.map(lambda a, t: a.astype(t.dtype), avg, like)
