"""Disk-backed store of outer-weight checkpoints (paper Algorithm 2 input).

The HWA offline module consumes outer weights W̄_e saved at each
synchronization cycle. At scale the window lives on-device (see
``repro.core.offline``); the store is the paper-faithful file path —
Algorithm 2 literally reads "Checkpoints of Outer Weights" — and enables
post-hoc window sweeps (trying multiple I, §III-B) without retraining.
"""
from __future__ import annotations

import os
import re
from typing import Any

import jax

from repro.checkpoint.io import load_pytree, save_pytree
from repro.common.pytree import tree_add, tree_scale, tree_zeros_like


class OuterWeightStore:
    def __init__(self, directory: str):
        self.directory = directory
        os.makedirs(directory, exist_ok=True)

    def _path(self, cycle: int) -> str:
        return os.path.join(self.directory, f"outer_{cycle:06d}.npz")

    def save(self, cycle: int, outer_weights: Any) -> None:
        save_pytree(self._path(cycle), outer_weights)

    def load(self, cycle: int, like: Any) -> Any:
        return load_pytree(self._path(cycle), like)

    def cycles(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            m = re.fullmatch(r"outer_(\d+)\.npz", name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def window_average(self, end_cycle: int, window: int, like: Any,
                       stride: int = 1) -> Any:
        """W̿_e = mean of W̄_t for t in the slide window ending at e.

        ``stride`` implements the paper's sparse-window remark (§III-B):
        average only cycles with index in multiples of ``stride``.
        """
        cycles = [c for c in self.cycles()
                  if end_cycle - window * stride < c <= end_cycle
                  and (c - end_cycle) % stride == 0]
        if not cycles:
            raise ValueError(f"no checkpoints in window ending at {end_cycle}")
        acc = tree_zeros_like(jax.tree.map(lambda x: x.astype("float32"), like))
        for c in cycles:
            w = self.load(c, like)
            acc = tree_add(acc, jax.tree.map(lambda x: x.astype("float32"), w))
        avg = tree_scale(acc, 1.0 / len(cycles))
        return jax.tree.map(lambda a, t: a.astype(t.dtype), avg, like)
