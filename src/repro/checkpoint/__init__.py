from repro.checkpoint.io import save_pytree, load_pytree
from repro.checkpoint.store import OuterWeightStore
