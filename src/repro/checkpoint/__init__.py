from repro.checkpoint.io import (save_pytree, load_pytree,
                                 save_window_state, load_window_state)
from repro.checkpoint.store import OuterWeightStore
