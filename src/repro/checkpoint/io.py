"""Pytree checkpointing on top of ``.npz`` (offline container: no orbax).

Leaves are flattened with '/'-joined key paths so arbitrary nested
dict/list pytrees round-trip exactly (shapes, dtypes, values).
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

from repro.common.compat import tree_flatten_with_path

_SEP = "|"

# numpy's npz format cannot store ml_dtypes (bfloat16, fp8); round-trip
# them through a same-width integer view with the true dtype in metadata.
_VIEW = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
         "float8_e5m2": np.uint8}


def _keystr(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return _SEP.join(parts)


def save_pytree(path: str, tree: Any) -> None:
    flat, treedef = tree_flatten_with_path(tree)
    arrays = {}
    keys = []
    dtypes = []
    for i, (kpath, leaf) in enumerate(flat):
        name = f"leaf_{i}"
        arr = np.asarray(leaf)
        dtypes.append(str(arr.dtype))
        if str(arr.dtype) in _VIEW:
            arr = arr.view(_VIEW[str(arr.dtype)])
        arrays[name] = arr
        keys.append(_keystr(kpath))
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, __keys__=np.asarray(json.dumps(keys)),
                 __dtypes__=np.asarray(json.dumps(dtypes)),
                 __treedef__=np.asarray(str(treedef)), **arrays)
    os.replace(tmp, path)


def _read_raw(path: str) -> tuple[list, list]:
    """(keys, leaves) exactly as stored, dtype views undone."""
    with np.load(path, allow_pickle=False) as data:
        keys = json.loads(str(data["__keys__"]))
        dtypes = json.loads(str(data["__dtypes__"]))
        leaves = []
        for i, dt in enumerate(dtypes):
            arr = data[f"leaf_{i}"]
            if dt in _VIEW:
                arr = arr.view(dt)
            leaves.append(arr)
    return keys, leaves


def load_pytree(path: str, like: Any) -> Any:
    """Load into the structure of ``like`` (checked against stored keys)."""
    keys, leaves = _read_raw(path)
    flat, treedef = tree_flatten_with_path(like)
    if len(flat) != len(leaves):
        raise ValueError(f"checkpoint has {len(leaves)} leaves, "
                         f"template has {len(flat)}")
    for (kpath, tmpl), key, leaf in zip(flat, keys, leaves):
        if _keystr(kpath) != key:
            raise ValueError(f"leaf mismatch: {key} vs {_keystr(kpath)}")
        if tuple(tmpl.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch at {key}: "
                             f"{leaf.shape} vs {tmpl.shape}")
    return jax.tree.unflatten(treedef,
                              [l.astype(t[1].dtype) for t, l in zip(flat, leaves)])


# ------------------------------------------------- packed WA window state
#
# The slide-window state (repro.core.offline.WindowState) is held packed:
# one (I, P) ring + one (P,) total over the whole parameter set. Saving it
# is a plain 4-leaf pytree save; loading migrates pre-packing checkpoints
# (one ring/total leaf PER PARAMETER) by re-packing them into the layout
# described by the template's PackSpec — bit-identically, since packing is
# layout-only.


def save_window_state(path: str, state: Any) -> None:
    """Save a (packed) WindowState: ring/total buffers + counters."""
    save_pytree(path, {"ring": state.ring, "total": state.total,
                       "count": state.count, "next_idx": state.next_idx})


def load_window_state(path: str, like: Any) -> Any:
    """Load a WindowState saved by :func:`save_window_state` — or migrate
    an old per-leaf checkpoint — into the packed layout of ``like``
    (a WindowState template whose ``spec`` fixes offsets and treedef)."""
    from repro.core.offline import WindowState

    keys, leaves = _read_raw(path)
    spec = like.spec
    by_group: dict[str, list] = {}
    for key, leaf in zip(keys, leaves):
        group, _, subkey = key.partition(_SEP)
        by_group.setdefault(group, []).append((subkey, leaf))

    # key paths of the packed layout's leaves, in flatten order — the
    # migration must match stored per-leaf keys against these, not rely
    # on position alone (two same-shape leaves could silently swap)
    # key paths depend only on the treedef, so zero-size leaves suffice
    dummy = jax.tree.unflatten(
        spec.treedef, [np.zeros(0, np.float32)] * spec.n_leaves)
    flat_dummy, _ = tree_flatten_with_path(dummy)
    expected_keys = [_keystr(p) for p, _ in flat_dummy]

    def grab(group):
        if group not in by_group:
            raise ValueError(f"window-state checkpoint missing '{group}' "
                             f"(stored keys: {keys})")
        return by_group[group]

    def repack(group_items, lead: tuple, dtype):
        if len(group_items) == 1 and group_items[0][1].shape == \
                lead + (spec.padded,):
            return jnp.asarray(group_items[0][1], dtype)   # already packed
        # migration: one stored leaf per parameter, in flatten order
        if len(group_items) != spec.n_leaves:
            raise ValueError(
                f"cannot migrate: checkpoint has {len(group_items)} leaves,"
                f" packed template expects {spec.n_leaves} (or 1 packed)")
        parts = []
        for (subkey, arr), ls, want in zip(group_items, spec.leaves,
                                           expected_keys):
            if subkey != want:
                raise ValueError(f"migration key mismatch: stored leaf "
                                 f"'{subkey}' where template expects "
                                 f"'{want}'")
            if tuple(arr.shape) != lead + ls.shape:
                raise ValueError(f"migration shape mismatch: {arr.shape} "
                                 f"vs {lead + ls.shape}")
            parts.append(np.asarray(arr, np.float32).reshape(lead + (ls.size,)))
        pad = spec.padded - spec.size
        if pad:
            parts.append(np.zeros(lead + (pad,), np.float32))
        return jnp.asarray(np.concatenate(parts, axis=-1), dtype)

    ring = None
    if like.ring is not None:
        ring = repack(grab("ring"), (like.window,), like.ring.dtype)
    total = repack(grab("total"), (), jnp.float32)
    count = jnp.asarray(grab("count")[0][1], jnp.int32)
    next_idx = jnp.asarray(grab("next_idx")[0][1], jnp.int32)
    return WindowState(ring=ring, total=total, count=count,
                       next_idx=next_idx, window=like.window,
                       kind=like.kind, spec=spec)
