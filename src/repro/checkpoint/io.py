"""Pytree checkpointing on top of ``.npz`` (offline container: no orbax).

Leaves are flattened with '/'-joined key paths so arbitrary nested
dict/list pytrees round-trip exactly (shapes, dtypes, values).
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import ml_dtypes
import numpy as np

from repro.common.compat import tree_flatten_with_path

_SEP = "|"

# numpy's npz format cannot store ml_dtypes (bfloat16, fp8); round-trip
# them through a same-width integer view with the true dtype in metadata.
_VIEW = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
         "float8_e5m2": np.uint8}


def _keystr(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return _SEP.join(parts)


def save_pytree(path: str, tree: Any) -> None:
    flat, treedef = tree_flatten_with_path(tree)
    arrays = {}
    keys = []
    dtypes = []
    for i, (kpath, leaf) in enumerate(flat):
        name = f"leaf_{i}"
        arr = np.asarray(leaf)
        dtypes.append(str(arr.dtype))
        if str(arr.dtype) in _VIEW:
            arr = arr.view(_VIEW[str(arr.dtype)])
        arrays[name] = arr
        keys.append(_keystr(kpath))
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, __keys__=np.asarray(json.dumps(keys)),
                 __dtypes__=np.asarray(json.dumps(dtypes)),
                 __treedef__=np.asarray(str(treedef)), **arrays)
    os.replace(tmp, path)


def load_pytree(path: str, like: Any) -> Any:
    """Load into the structure of ``like`` (checked against stored keys)."""
    with np.load(path, allow_pickle=False) as data:
        keys = json.loads(str(data["__keys__"]))
        dtypes = json.loads(str(data["__dtypes__"]))
        leaves = []
        for i, dt in enumerate(dtypes):
            arr = data[f"leaf_{i}"]
            if dt in _VIEW:
                arr = arr.view(dt)
            leaves.append(arr)
    flat, treedef = tree_flatten_with_path(like)
    if len(flat) != len(leaves):
        raise ValueError(f"checkpoint has {len(leaves)} leaves, "
                         f"template has {len(flat)}")
    for (kpath, tmpl), key, leaf in zip(flat, keys, leaves):
        if _keystr(kpath) != key:
            raise ValueError(f"leaf mismatch: {key} vs {_keystr(kpath)}")
        if tuple(tmpl.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch at {key}: "
                             f"{leaf.shape} vs {tmpl.shape}")
    return jax.tree.unflatten(treedef,
                              [l.astype(t[1].dtype) for t, l in zip(flat, leaves)])
