"""Pytree checkpointing on top of ``.npz`` (offline container: no orbax).

Leaves are flattened with '/'-joined key paths so arbitrary nested
dict/list pytrees round-trip exactly (shapes, dtypes, values).
"""
from __future__ import annotations

import json
import os
import uuid
from typing import Any

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

from repro.common.compat import tree_flatten_with_path

_SEP = "|"

# numpy's npz format cannot store ml_dtypes (bfloat16, fp8); round-trip
# them through a same-width integer view with the true dtype in metadata.
_VIEW = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
         "float8_e5m2": np.uint8}


def _keystr(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return _SEP.join(parts)


def save_pytree(path: str, tree: Any) -> None:
    flat, treedef = tree_flatten_with_path(tree)
    arrays = {}
    keys = []
    dtypes = []
    for i, (kpath, leaf) in enumerate(flat):
        name = f"leaf_{i}"
        arr = np.asarray(leaf)
        dtypes.append(str(arr.dtype))
        if str(arr.dtype) in _VIEW:
            arr = arr.view(_VIEW[str(arr.dtype)])
        arrays[name] = arr
        keys.append(_keystr(kpath))
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    # unique tmp name: a fixed `path + ".tmp"` collides under concurrent
    # writers (one writer's os.replace publishes the other's half-written
    # file); fsync before the atomic rename, or a crash right after
    # replace can publish a name pointing at un-flushed (truncated) data
    tmp = f"{path}.tmp.{os.getpid()}.{uuid.uuid4().hex[:8]}"
    try:
        with open(tmp, "wb") as f:
            np.savez(f, __keys__=np.asarray(json.dumps(keys)),
                     __dtypes__=np.asarray(json.dumps(dtypes)),
                     __treedef__=np.asarray(str(treedef)), **arrays)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        _fsync_dir(os.path.dirname(os.path.abspath(path)))
    finally:
        if os.path.exists(tmp):
            try:
                os.remove(tmp)
            except OSError:
                pass


def _fsync_dir(dirname: str) -> None:
    """Make a just-completed rename durable (the entry lives in the
    directory, not the file). Best effort — not every platform allows
    opening a directory."""
    try:
        fd = os.open(dirname or ".", os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def _read_raw(path: str) -> tuple[list, list]:
    """(keys, leaves) exactly as stored, dtype views undone."""
    with np.load(path, allow_pickle=False) as data:
        keys = json.loads(str(data["__keys__"]))
        dtypes = json.loads(str(data["__dtypes__"]))
        leaves = []
        for i, dt in enumerate(dtypes):
            arr = data[f"leaf_{i}"]
            if dt in _VIEW:
                arr = arr.view(dt)
            leaves.append(arr)
    return keys, leaves


def load_pytree(path: str, like: Any) -> Any:
    """Load into the structure of ``like`` (checked against stored keys)."""
    keys, leaves = _read_raw(path)
    flat, treedef = tree_flatten_with_path(like)
    if len(flat) != len(leaves):
        raise ValueError(f"checkpoint has {len(leaves)} leaves, "
                         f"template has {len(flat)}")
    for (kpath, tmpl), key, leaf in zip(flat, keys, leaves):
        if _keystr(kpath) != key:
            raise ValueError(f"leaf mismatch: {key} vs {_keystr(kpath)}")
        if tuple(tmpl.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch at {key}: "
                             f"{leaf.shape} vs {tmpl.shape}")
    return jax.tree.unflatten(treedef,
                              [l.astype(t[1].dtype) for t, l in zip(flat, leaves)])


# ------------------------------------------------- packed WA window state
#
# The slide-window state (repro.core.offline.WindowState) is held packed:
# one (I, P) ring + one (P,) total over the whole parameter set. Saving it
# is a plain pytree save PLUS the PackSpec layout as JSON metadata.
# Loading handles three cases, all bit-exactly (packing is layout-only):
#
#   1. stored layout == template layout        -> direct load;
#   2. stored layout != template layout        -> repack (e.g. a state
#      saved under one mesh's shard-aware layout restored under another
#      mesh's, or on a single device);
#   3. pre-packing checkpoint (one ring/total leaf PER PARAMETER)
#      -> migrate by packing the stored leaves into the template layout.


def _contiguous_spec(spec):
    """The default contiguous (shards=1) layout of a spec's leaf set —
    what every checkpoint written before layout metadata existed used."""
    from repro.common.packing import pack_spec
    flat = [jax.ShapeDtypeStruct(ls.shape, ls.dtype) for ls in spec.leaves]
    return pack_spec(jax.tree.unflatten(spec.treedef, flat),
                     align=spec.align)


def save_window_state(path: str, state: Any) -> None:
    """Save a (packed) WindowState: ring/total buffers + counters + the
    packed layout (so a different mesh can repack on load).

    Grouped (mixed-tiling) window states hold ring/total as PER-GROUP
    buffer tuples at runtime; on disk the canonical form is always the
    single logical buffer (group ranges contiguous), so they are merged
    here and re-split on load — bit-exact both ways (pure concat). The
    merge runs on HOST copies: the runtime buffers are device-resident
    and differently sharded per group, and an eager concat across
    differently-sharded operands is exactly the pattern XLA 0.4.37's CPU
    SPMD partitioner miscompiles (see tests/mesh_hwa_check.py)."""
    from repro.common.packing import spec_to_json

    def _merge_host(parts):
        if not isinstance(parts, (tuple, list)):
            return parts
        arrs = [np.asarray(p) for p in parts]
        return arrs[0] if len(arrs) == 1 else \
            np.concatenate(arrs, axis=arrs[0].ndim - 1)

    ring, total = state.ring, state.total
    comp = getattr(state, "comp", None)
    scales = getattr(state, "scales", None)
    if state.spec is not None:
        if ring is not None:
            ring = _merge_host(ring)
        total = _merge_host(total)
        if comp is not None:
            comp = _merge_host(comp)
        if scales is not None:
            # per-group scale blocks concatenate to the merged buffer's
            # blocks exactly: group ranges are ALIGN multiples
            scales = _merge_host(scales)
    tree = {"ring": ring, "total": total,
            "count": state.count, "next_idx": state.next_idx}
    if comp is not None:
        tree["comp"] = comp
    if scales is not None:
        tree["scales"] = scales
    if state.spec is not None:
        tree["spec_json"] = np.asarray(spec_to_json(state.spec))
    save_pytree(path, tree)


def load_wa_snapshot(path: str):
    """W̿ snapshot source for the serving tier: (packed f32 buffer,
    PackSpec) straight from a window-state checkpoint, with NO template
    — the serving publisher repacks into its own layout
    (``repro.serve.publish.WeightPublisher``). Ring checkpoints store
    the running sum (divide by count); streaming ones store the mean."""
    from repro.common.packing import spec_from_json

    keys, leaves = _read_raw(path)
    tree = {k: v for k, v in zip(keys, leaves)}
    if "spec_json" not in tree:
        raise ValueError(f"{path}: not a layout-described window-state "
                         f"checkpoint (keys: {keys})")
    spec = spec_from_json(str(tree["spec_json"]))
    total = np.asarray(tree["total"], np.float32)
    if total.shape != (spec.padded,):
        raise ValueError(f"{path}: packed total {total.shape} does not "
                         f"match its stored layout ({spec.padded})")
    count = max(int(tree["count"]), 1)
    if "ring" in tree and tree["ring"] is not None:
        total = total / count                 # ring kind: running sum
    return jnp.asarray(total), spec


def _split_scale_groups(scales, spec):
    """Per-group views of an fp8 scale buffer ``(..., padded // align)``:
    group ranges are ALIGN multiples, so block boundaries land exactly on
    group boundaries."""
    return tuple(
        jax.lax.slice_in_dim(scales, g.offset // spec.align,
                             (g.offset + g.padded) // spec.align,
                             axis=scales.ndim - 1)
        for g in spec.group_table())


def load_window_state(path: str, like: Any) -> Any:
    """Load a WindowState saved by :func:`save_window_state` — repacking
    across layout changes, or migrating an old per-leaf checkpoint — into
    the packed layout of ``like`` (a WindowState template whose ``spec``
    fixes offsets and treedef).

    **Precision migration.** The template's ring dtype wins. When it
    matches the stored ring (and, for fp8, the stored layout), the load
    is bit-exact — compressed rings round-trip through integer views
    untouched. When it differs (f32 checkpoint into a bf16/fp8 window,
    or a compressed checkpoint back into f32), the stored ring is
    DECODED to f32, repacked, and re-encoded slot-by-slot under the
    template's dtype; the running total is then recomputed as the sum of
    the re-encoded (dequantized) slots and the Kahan compensation reset
    to zero — restoring the compressed-accounting invariant (future
    evictions subtract exactly the bits a slot stores). Migration into
    GROUPED compressed layouts is not supported (load f32, then resync).
    """
    from repro.common.packing import repack as repack_buf, spec_from_json
    from repro.core.offline import WindowState

    keys, leaves = _read_raw(path)
    spec = like.spec
    by_group: dict[str, list] = {}
    for key, leaf in zip(keys, leaves):
        group, _, subkey = key.partition(_SEP)
        by_group.setdefault(group, []).append((subkey, leaf))

    stored_spec = None
    if "spec_json" in by_group:
        stored_spec = spec_from_json(str(by_group.pop("spec_json")[0][1]))

    # key paths of the packed layout's leaves, in flatten order — the
    # migration must match stored per-leaf keys against these, not rely
    # on position alone (two same-shape leaves could silently swap)
    # key paths depend only on the treedef, so zero-size leaves suffice
    dummy = jax.tree.unflatten(
        spec.treedef, [np.zeros(0, np.float32)] * spec.n_leaves)
    flat_dummy, _ = tree_flatten_with_path(dummy)
    expected_keys = [_keystr(p) for p, _ in flat_dummy]

    def grab(group):
        if group not in by_group:
            raise ValueError(f"window-state checkpoint missing '{group}' "
                             f"(stored keys: {keys})")
        return by_group[group]

    def restore(group_items, lead: tuple, dtype):
        if len(group_items) == 1:
            arr = group_items[0][1]
            if stored_spec is not None and \
                    not spec.same_layout(stored_spec):
                # saved under a different (e.g. other-mesh shard-aware)
                # layout: bit-exact repack into the template's
                if arr.shape != lead + (stored_spec.padded,):
                    raise ValueError(f"packed buffer {arr.shape} does not "
                                     f"match its stored layout "
                                     f"({stored_spec.padded})")
                return repack_buf(jnp.asarray(arr, dtype), stored_spec,
                                  spec).astype(dtype)
            if arr.shape == lead + (spec.padded,):
                return jnp.asarray(arr, dtype)           # layout unchanged
            # pre-layout-metadata checkpoint (no spec_json): the only
            # layout ever written back then was the default contiguous
            # one — rederive it from the template's leaves and repack
            legacy = _contiguous_spec(spec)
            if stored_spec is None and \
                    arr.shape == lead + (legacy.padded,):
                return repack_buf(jnp.asarray(arr, dtype), legacy,
                                  spec).astype(dtype)
            raise ValueError(f"packed buffer shape {arr.shape} does not "
                             f"match template ({lead + (spec.padded,)})")
        # migration: one stored leaf per parameter, in flatten order
        if len(group_items) != spec.n_leaves:
            raise ValueError(
                f"cannot migrate: checkpoint has {len(group_items)} leaves,"
                f" packed template expects {spec.n_leaves} (or 1 packed)")
        from repro.common.packing import pack_leaves
        parts = []
        for (subkey, arr), ls, want in zip(group_items, spec.leaves,
                                           expected_keys):
            if subkey != want:
                raise ValueError(f"migration key mismatch: stored leaf "
                                 f"'{subkey}' where template expects "
                                 f"'{want}'")
            if tuple(arr.shape) != lead + ls.shape:
                raise ValueError(f"migration shape mismatch: {arr.shape} "
                                 f"vs {lead + ls.shape}")
            parts.append(jnp.asarray(np.asarray(arr, np.float32)))
        return pack_leaves(parts, spec, n_lead=len(lead)).astype(dtype)

    from repro.common.packing import split_groups
    count = jnp.asarray(grab("count")[0][1], jnp.int32)
    next_idx = jnp.asarray(grab("next_idx")[0][1], jnp.int32)
    like_comp = getattr(like, "comp", None)
    like_scales = getattr(like, "scales", None)
    if like.ring is None:                                      # streaming
        total = restore(grab("total"), (), jnp.float32)
        if isinstance(like.total, tuple):
            total = split_groups(total, spec)
        return WindowState(ring=None, total=total, count=count,
                           next_idx=next_idx, window=like.window,
                           kind=like.kind, spec=spec)

    ring_grouped = isinstance(like.ring, tuple)
    rd = np.dtype((like.ring[0] if ring_grouped else like.ring).dtype)
    items = grab("ring")
    # per-leaf (pre-packing) checkpoints only ever stored f32
    stored_rd = (np.dtype(items[0][1].dtype) if len(items) == 1
                 else np.dtype(np.float32))
    stored_scales = by_group.get("scales")
    layout_same = stored_spec is None or spec.same_layout(stored_spec)
    direct = stored_rd == rd and (stored_scales is None or layout_same)

    if direct:
        ring = restore(items, (like.window,), rd)
        if ring_grouped:        # template holds per-group runtime buffers
            ring = split_groups(ring, spec)
        total = restore(grab("total"), (), jnp.float32)
        if isinstance(like.total, tuple):
            total = split_groups(total, spec)
        comp = scales = None
        if like_comp is not None:
            # absent in pre-compression checkpoints of the same dtype
            # (impossible — comp exists iff the ring is compressed — but
            # zeros are the correct fresh compensation either way)
            comp = (restore(by_group["comp"], (), jnp.float32)
                    if "comp" in by_group
                    else jax.tree.map(jnp.zeros_like, total))
            if isinstance(like_comp, tuple) and not isinstance(comp, tuple):
                comp = split_groups(comp, spec)
        if like_scales is not None:
            if stored_scales is None:
                raise ValueError("fp8 window template but the checkpoint "
                                 "stores no 'scales'")
            scales = jnp.asarray(stored_scales[0][1], jnp.float32)
            if isinstance(like_scales, tuple):
                scales = _split_scale_groups(scales, spec)
        return WindowState(ring=ring, total=total, count=count,
                           next_idx=next_idx, window=like.window,
                           kind=like.kind, spec=spec,
                           comp=comp, scales=scales)

    # ---- precision migration: decode -> repack (f32) -> re-encode
    from repro.common.quant import decode_slot, encode_slot
    if ring_grouped:
        raise ValueError("precision migration into a GROUPED window "
                         "layout is unsupported: load under the stored "
                         "ring dtype (or f32) and let the next syncs "
                         "refill the window")
    if len(items) == 1 and stored_scales is not None:
        # fp8 checkpoint: decode under the STORED layout first (its
        # scales describe the stored block positions), then repack f32
        arr = items[0][1]
        s_spec = stored_spec if stored_spec is not None else spec
        if arr.shape != (like.window, s_spec.padded):
            raise ValueError(f"packed fp8 ring {arr.shape} does not match "
                             f"its stored layout ({s_spec.padded})")
        decoded = decode_slot(jnp.asarray(arr),
                              jnp.asarray(stored_scales[0][1], jnp.float32))
        if not layout_same:
            decoded = repack_buf(decoded, stored_spec, spec)
        f32_ring = decoded
    else:
        # f32/bf16 stored (packed, possibly other-layout, or per-leaf):
        # the existing restore machinery handles every layout case
        f32_ring = restore(items, (like.window,), jnp.float32)
    ring, scales = encode_slot(f32_ring, rd)
    # recompute the running total as the sum of the re-encoded slots:
    # unfilled slots are zeros, so the plain row sum equals the sum over
    # the count filled entries — and future evictions subtract exactly
    # what a slot decodes to (the compressed-accounting invariant)
    total = jnp.sum(decode_slot(ring, scales), axis=0)
    comp = jnp.zeros_like(total) if like_comp is not None else None
    if like_scales is None:
        scales = None
    return WindowState(ring=ring, total=total, count=count,
                       next_idx=next_idx, window=like.window,
                       kind=like.kind, spec=spec, comp=comp, scales=scales)
