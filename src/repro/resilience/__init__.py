"""Fault tolerance for HWA: replica health + elastic degradation,
preemption-safe checkpoint sessions, and deterministic fault injection.

Three layers (docs/ARCHITECTURE.md §6):

- :mod:`repro.resilience.health` — the alive-mask math: per-replica
  finiteness/divergence probes over the packed sync buffer (mesh path)
  and over stacked pytrees (core path), and the renormalized
  ``1/K_alive`` masked mean that is bitwise identical to today's plain
  mean when every replica is healthy.
- :mod:`repro.resilience.session` — :class:`CheckpointSession`: a
  versioned checkpoint directory (per-step subdirs, manifest written
  last with per-array CRC32s, retention/GC, ``latest`` hint) layered on
  the atomic npz writers in ``checkpoint/io.py``; ``latest_intact()``
  falls back past torn or corrupted checkpoints.
- :mod:`repro.resilience.faults` — deterministic fault injectors
  (NaN-poisoned replicas, kill-mid-save, bit flips, transient IO
  errors) used by ``tools/fault_check.py`` / ``make fault-check``.
"""
from repro.resilience.faults import (InjectedIOError, KillAt,
                                     SimulatedCrash, TransientIO,
                                     flip_bit, poison_replica,
                                     truncate_file)
from repro.resilience.health import (alive_from_stats, masked_mean_axis0,
                                     packed_health_stats,
                                     quarantine_opt_state,
                                     replica_alive_mask, renormalized_inv)
from repro.resilience.session import CheckpointSession

__all__ = [
    "CheckpointSession",
    "InjectedIOError",
    "KillAt",
    "SimulatedCrash",
    "TransientIO",
    "alive_from_stats",
    "flip_bit",
    "masked_mean_axis0",
    "packed_health_stats",
    "poison_replica",
    "quarantine_opt_state",
    "renormalized_inv",
    "replica_alive_mask",
    "truncate_file",
]
