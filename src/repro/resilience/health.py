"""Replica health probes and the alive-masked (elastic) K-mean.

Two formulations, each 0 ULP identical to the path it degrades from
when every replica is alive:

- **Packed / mesh path** (used inside ``launch/sync/packed.py``'s
  fully-manual shard_map body): the probe runs over the packed f32
  ``(k_local, P_local)`` sync buffer. The masked partial is
  ``halving_sum_axis0(where(alive, sbuf, 0)) * inv`` with ``inv``
  pinned to the trace-time ``float32(1/K)`` whenever ``k_alive == K``
  — a ``where`` with an all-true mask is the identity and the
  multiplier is the exact same f32 scalar today's path uses, so the
  all-healthy output is bitwise identical to the non-resilient sync.
- **Core / stacked path** (used by ``core.hwa.hwa_sync``): the target
  is ``jnp.mean(x, axis=0)`` (a sum *divided* by the count, possibly
  computed in a wider dtype), so instead of replaying its internals the
  masked mean computes both and selects —
  ``where(all_alive, jnp.mean(x, 0), masked)`` — which guarantees exact
  equality in the healthy case for every leaf dtype.

Divergence (RMS) thresholds are APPROXIMATE by design: the packed
buffer counts padding zeros and replicated leaves once per shard copy,
so ``max_param_rms`` is a coarse blow-up detector, not a norm. The
finiteness verdict is exact in both formulations.

All-dead degradation: when every replica trips the probe there is
nothing left to average, so the mask is dropped and the sync degrades
to today's plain mean (the run is unsalvageable either way; the
``k_alive == 0`` metric makes it observable instead of silently
restarting from zeros).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def packed_health_stats(sbuf: jax.Array) -> jax.Array:
    """Per-replica ``(k_local, 2)`` f32 health stats of a packed buffer:
    ``[:, 0]`` = non-finite element count, ``[:, 1]`` = finite-masked
    sum of squares. Both are sums, so aggregating a replica's stats over
    its parameter shards is a single psum over the non-replica axes."""
    finite = jnp.isfinite(sbuf)
    nonfinite = jnp.sum((~finite).astype(jnp.float32), axis=1)
    masked = jnp.where(finite, sbuf, jnp.float32(0.0))
    sumsq = jnp.sum(masked * masked, axis=1)
    return jnp.stack([nonfinite, sumsq], axis=1)


def alive_from_stats(stats: jax.Array, n_elems: float,
                     max_rms: float | None) -> jax.Array:
    """``(k_local,)`` bool alive mask from (already psum-aggregated)
    health stats. ``n_elems`` is the static per-replica element count
    the sumsq was accumulated over (local width × number of devices the
    stats psum crossed — replication factors cancel, see module doc)."""
    alive = stats[:, 0] == 0.0
    if max_rms is not None:
        ms = stats[:, 1] / jnp.float32(n_elems)
        alive = alive & (ms <= jnp.float32(max_rms) ** 2)
    return alive


def renormalized_inv(k_alive: jax.Array, n_replicas: int) -> jax.Array:
    """The masked-mean multiplier ``1/k_alive`` as an f32 scalar.

    Pinned to the trace-time ``float32(1/K)`` when all replicas are
    alive — a runtime ``1.0 / float(K)`` could differ by 1 ULP from the
    constant the non-resilient path folds in, which would break the
    all-healthy bitwise-parity guarantee."""
    return jnp.where(k_alive >= n_replicas,
                     jnp.float32(1.0 / n_replicas),
                     jnp.float32(1.0) / jnp.maximum(k_alive,
                                                    jnp.float32(1.0)))


def replica_alive_mask(stacked, max_rms: float | None = None) -> jax.Array:
    """``(K,)`` bool alive mask of a stacked (leading replica dim)
    pytree: a replica is alive iff every one of its leaves is finite
    (and, with ``max_rms``, its overall RMS is below the threshold)."""
    leaves = [l for l in jax.tree.leaves(stacked)
              if jnp.issubdtype(jnp.asarray(l).dtype, jnp.floating)]
    if not leaves:
        raise ValueError("replica_alive_mask: no floating leaves")
    k = leaves[0].shape[0]
    nonfinite = jnp.zeros((k,), jnp.float32)
    sumsq = jnp.zeros((k,), jnp.float32)
    n_elems = 0
    for leaf in leaves:
        x = jnp.asarray(leaf)
        axes = tuple(range(1, x.ndim))
        finite = jnp.isfinite(x)
        nonfinite = nonfinite + jnp.sum((~finite).astype(jnp.float32),
                                        axis=axes)
        xf = jnp.where(finite, x, 0).astype(jnp.float32)
        sumsq = sumsq + jnp.sum(xf * xf, axis=axes)
        n_elems += int(x.size // x.shape[0])
    stats = jnp.stack([nonfinite, sumsq], axis=1)
    return alive_from_stats(stats, float(n_elems), max_rms)


def masked_mean_axis0(stacked, alive: jax.Array):
    """Alive-masked mean over the leading replica dim of a stacked
    pytree, bitwise identical to ``jnp.mean(x, axis=0)`` per leaf when
    every replica is alive (computed via select, so the parity holds
    for any leaf dtype / accumulation width ``jnp.mean`` picks). Dead
    replicas contribute nothing; the divisor renormalizes to the alive
    count. All-dead degrades to the plain mean (module doc)."""
    k = int(alive.shape[0])
    k_alive = jnp.sum(alive.astype(jnp.float32))
    all_alive = k_alive >= k
    # all-dead: drop the mask entirely (plain mean of everyone)
    use = alive | (k_alive == 0.0)
    denom = jnp.where(k_alive > 0.0, jnp.maximum(k_alive, 1.0),
                      jnp.float32(k))

    def one(x):
        x = jnp.asarray(x)
        mean_all = jnp.mean(x, axis=0)
        mask = use.reshape((k,) + (1,) * (x.ndim - 1))
        s = jnp.sum(jnp.where(mask, x.astype(jnp.float32), 0.0), axis=0)
        masked = (s / denom).astype(mean_all.dtype)
        return jnp.where(all_alive, mean_all, masked)

    return jax.tree.map(one, stacked)


def quarantine_opt_state(opt_state, alive: jax.Array):
    """Zero the per-replica optimizer slots of dead replicas (zeros ==
    the fresh-init moments/counters of this repo's sgd/adamw states), so
    a quarantined replica restarts from W̄ with a clean optimizer instead
    of NaN momentum. Leaves whose leading dim is not the replica dim
    pass through untouched; with all replicas alive every ``where`` is
    the identity."""
    k = int(alive.shape[0])

    def one(o):
        o = jnp.asarray(o)
        if o.ndim == 0 or o.shape[0] != k:
            return o
        mask = alive.reshape((k,) + (1,) * (o.ndim - 1))
        return jnp.where(mask, o, jnp.zeros_like(o))

    return jax.tree.map(one, opt_state)
