"""Deterministic fault injectors for the resilience harness.

An *injector* is a callable ``(point: str, path: str) -> None`` that
:class:`~repro.resilience.session.CheckpointSession` fires at named IO
points (``"array_write"``, ``"window_write"``, ``"manifest_write"``)
right after the corresponding file write, inside the retried region.
Two exception classes split the failure modes:

- :class:`InjectedIOError` subclasses :class:`OSError` — the class the
  session's capped-backoff retry loop catches — so a
  :class:`TransientIO` fault exercises the retry path and the save
  ultimately succeeds.
- :class:`SimulatedCrash` subclasses :class:`BaseException` so it
  ESCAPES the retry loop (and any stray ``except Exception``),
  modelling a preemption/SIGKILL: the save is torn exactly where the
  fault fired and the process would be gone.

Everything here is deterministic — occurrence counters, fixed byte
offsets — so ``tools/fault_check.py`` runs are reproducible.
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np


class SimulatedCrash(BaseException):
    """Process death mid-save (preemption). BaseException on purpose:
    it must not be swallowed by IO retry loops."""


class InjectedIOError(OSError):
    """A transient IO failure (flaky NFS, throttled object store)."""


@dataclasses.dataclass
class KillAt:
    """Raise :class:`SimulatedCrash` at the Nth firing of ``point``,
    optionally truncating the just-written file first (a torn write
    the crash then publishes nothing for — the manifest-last protocol
    means the checkpoint is left without a valid manifest)."""
    point: str
    occurrence: int = 1
    truncate_frac: float | None = None
    seen: int = 0

    def __call__(self, point: str, path: str) -> None:
        if point != self.point:
            return
        self.seen += 1
        if self.seen == self.occurrence:
            if (self.truncate_frac is not None and path
                    and os.path.exists(path)):
                truncate_file(path, self.truncate_frac)
            raise SimulatedCrash(
                f"injected kill at {point!r} #{self.occurrence} ({path})")


@dataclasses.dataclass
class TransientIO:
    """Raise :class:`InjectedIOError` on the first ``times`` firings of
    ``point``; subsequent firings pass (the retry loop wins)."""
    point: str
    times: int = 1
    seen: int = 0

    def __call__(self, point: str, path: str) -> None:
        if point != self.point:
            return
        self.seen += 1
        if self.seen <= self.times:
            raise InjectedIOError(
                f"injected transient IO error at {point!r} "
                f"#{self.seen}/{self.times} ({path})")


def truncate_file(path: str, frac: float = 0.5) -> int:
    """Truncate ``path`` to ``frac`` of its size (torn write). Returns
    the new size."""
    size = os.path.getsize(path)
    new = max(0, int(size * frac))
    with open(path, "r+b") as f:
        f.truncate(new)
    return new


def flip_bit(path: str, offset: int | None = None, bit: int = 0) -> int:
    """Flip one bit of the byte at ``offset`` (default: mid-file —
    deterministically inside the payload of any non-trivial npz).
    Returns the offset flipped. Either the zip structure breaks (load
    fails) or an array's bytes change (CRC32 mismatch) — both must be
    caught by :meth:`CheckpointSession.verify`."""
    size = os.path.getsize(path)
    if size == 0:
        raise ValueError(f"flip_bit: {path} is empty")
    off = size // 2 if offset is None else offset
    with open(path, "r+b") as f:
        f.seek(off)
        b = f.read(1)
        f.seek(off)
        f.write(bytes([b[0] ^ (1 << bit)]))
    return off


def poison_replica(tree, replica: int, value: float = float("nan")):
    """Set replica ``replica``'s slice of every floating stacked leaf to
    ``value`` (default NaN) — the deterministic 'replica went insane'
    injection. Host-side on purpose: works on sharded arrays without
    touching the eager GSPMD paths, returns fresh uncommitted arrays."""
    import jax
    import jax.numpy as jnp

    def one(x):
        a = np.array(x)
        if not np.issubdtype(a.dtype, np.floating):
            return x
        a[replica] = value
        return jnp.asarray(a)

    return jax.tree.map(one, tree)
