"""fault-check: deterministic fault-injection harness over the
resilience stack. Importable core of ``tools/fault_check.py`` (which
only sets XLA_FLAGS for the forced host devices before jax loads).

Every leg is a deterministic end-to-end scenario with a hard pass/fail
verdict — no flakiness budget, no retries at the harness level:

  masked-parity    all-healthy alive-masked mean is BITWISE identical to
                   the plain K-mean (tree level and packed-buffer level)
  nan-replica      a NaN-poisoned replica is quarantined at sync; the
                   run reaches the final step with finite W̿
  resume-exact     checkpoint at N/2, rerun with --resume: final state
                   bit-identical to the uninterrupted run
  kill-mid-save    a simulated preemption truncating the manifest
                   mid-write leaves a torn, skipped checkpoint; the
                   session falls back to the previous intact one
  corrupt-fallback bit-flip the newest checkpoint: CRC verification
                   rejects it and --resume recomputes from the previous
                   intact save, bit-exactly matching the clean run
  transient-io     injected OSErrors during a save are retried with
                   capped backoff; exhaustion surfaces the error
  store-partial    a truncated outer_*.npz is skipped (with a warning)
                   by the window average; retention keeps the last N
  session-gc       the checkpoint session retains ``keep`` newest steps
                   and the newest survivor always verifies

``REPRO_FAULT_SMOKE=1`` (or ``--smoke``) runs the PR-lane subset,
leaving the full set to the nightly job.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import tempfile
import warnings
from typing import Callable

REQUIRED_DEVICES = 8

#: env var selecting the PR-lane smoke subset
SMOKE_ENV = "REPRO_FAULT_SMOKE"


@dataclasses.dataclass
class Leg:
    """One deterministic fault scenario."""
    name: str
    run: Callable[[], str]         # returns a detail line; raises on fail
    smoke: bool = False


# ------------------------------------------------------------- helpers


def _require_devices():
    import jax
    if len(jax.devices()) < REQUIRED_DEVICES:
        raise RuntimeError(
            f"fault-check needs {REQUIRED_DEVICES} devices for the "
            f"mesh legs (found {len(jax.devices())}); run via "
            "tools/fault_check.py, which sets "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8 before "
            "importing jax")


def _mesh_args(**kw):
    """An argparse.Namespace for ``launch.train.run_mesh_native`` with
    the launcher's defaults (tiny smoke config)."""
    ns = argparse.Namespace(
        arch="granite-3-2b", k=2, tp=1, fsdp=False, sync_tree="flat",
        pods=0, outer_every=2, window=3, seq_len=16, batch_size=4,
        lr=0.3, seed=0, steps=8, sync_period=2, attn_impl="",
        resilient=False, max_param_rms=0.0, inject_nan="",
        wa_dtype="f32", comms_dtype="f32",
        checkpoint_dir="", checkpoint_every=0, keep=3, resume=False)
    for k, v in kw.items():
        setattr(ns, k, v)
    return ns


def _trees_equal(a, b) -> bool:
    import jax
    import numpy as np
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    if len(la) != len(lb):
        return False
    for x, y in zip(la, lb):
        xa, ya = np.asarray(x), np.asarray(y)
        if xa.dtype != ya.dtype or xa.shape != ya.shape:
            return False
        if not np.array_equal(xa, ya):
            return False
    return True


def _check(cond: bool, msg: str) -> None:
    if not cond:
        raise AssertionError(msg)


def _demo_tree(seed: int = 0):
    import numpy as np
    rng = np.random.default_rng(seed)
    return {"w": rng.standard_normal((5, 7)).astype(np.float32),
            "b": rng.standard_normal((11,)).astype(np.float32)}


# ---------------------------------------------------------------- legs


def leg_masked_parity() -> str:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.common.pytree import tree_mean_axis0
    from repro.core.online import halving_sum_axis0
    from repro.resilience.health import masked_mean_axis0, renormalized_inv

    rng = np.random.default_rng(0)
    K = 4
    tree = {
        "w": jnp.asarray(rng.standard_normal((K, 3, 5)).astype(np.float32)),
        "b": jnp.asarray(rng.standard_normal((K, 7)).astype(np.float32)),
        # integer leaf (the adamw step count): masked path must keep
        # the exact dtype-faithful selection
        "count": jnp.arange(K, dtype=jnp.int32),
    }
    all_alive = jnp.ones((K,), jnp.bool_)
    got = jax.jit(masked_mean_axis0)(tree, all_alive)
    want = tree_mean_axis0(tree)
    _check(_trees_equal(got, want),
           "all-alive masked_mean_axis0 != tree_mean_axis0 (tree level)")

    # one dead replica: result is finite and ≈ the mean of the survivors
    dead = 2
    poisoned = dict(tree)
    poisoned["w"] = tree["w"].at[dead].set(jnp.nan)
    alive = all_alive.at[dead].set(False)
    got = jax.jit(masked_mean_axis0)(poisoned, alive)
    _check(bool(jnp.all(jnp.isfinite(got["w"]))),
           "masked mean leaked the NaN replica")
    keep = [i for i in range(K) if i != dead]
    ref = np.asarray(tree["w"], np.float64)[keep].mean(0)
    _check(float(np.abs(np.asarray(got["w"], np.float64) - ref).max())
           < 1e-6, "masked mean deviates from the survivors' mean")

    # packed-buffer level: the exact formula the mesh sync runs
    inv_pin = renormalized_inv(jnp.float32(K), K)
    _check(np.asarray(inv_pin).tobytes()
           == np.float32(1.0 / K).tobytes(),
           "renormalized_inv does not pin the trace-time f32 1/K")
    sbuf = jnp.asarray(rng.standard_normal((K, 257)).astype(np.float32))
    plain = halving_sum_axis0(sbuf) * jnp.float32(1.0 / K)
    masked = halving_sum_axis0(
        jnp.where(all_alive[:, None], sbuf, jnp.float32(0.0))) * inv_pin
    _check(np.array_equal(np.asarray(plain), np.asarray(masked)),
           "all-alive packed masked mean != plain packed mean")
    return "all-alive masked mean bitwise == plain mean (tree + packed)"


def leg_nan_replica() -> str:
    _require_devices()
    from repro.launch.train import run_mesh_native

    out = run_mesh_native(_mesh_args(steps=8, resilient=True,
                                     inject_nan="2:1"))
    _check(out["wa_finite"], "W̿ went non-finite despite the alive mask")
    _check(out["k_alive_min"] == 1,
           f"expected the poisoned sync to see k_alive=1, got "
           f"{out['k_alive_min']}")
    final = [h for h in out["history"] if h.get("sync") == "outer"][-1]
    _check(final["k_alive"] == 2,
           f"re-seeded replica did not recover (final k_alive "
           f"{final['k_alive']})")
    return (f"poisoned replica quarantined (k_alive dipped to "
            f"{out['k_alive_min']}, recovered to {final['k_alive']}), "
            f"W̿ finite at step {out['history'][-1]['step']}")


def leg_resume_exact() -> str:
    _require_devices()
    from repro.launch.train import run_mesh_native

    clean = run_mesh_native(_mesh_args(steps=8))
    with tempfile.TemporaryDirectory() as d:
        run_mesh_native(_mesh_args(steps=4, checkpoint_dir=d,
                                   checkpoint_every=4))
        resumed = run_mesh_native(_mesh_args(steps=8, checkpoint_dir=d,
                                             checkpoint_every=4,
                                             resume=True))
    _check(_trees_equal(clean["_state"], resumed["_state"]),
           "resumed final state differs from the uninterrupted run")
    return "checkpoint@4 + --resume reproduces the 8-step run bit-exactly"


def leg_kill_mid_save() -> str:
    from repro.resilience.faults import KillAt, SimulatedCrash
    from repro.resilience.session import CheckpointSession

    t4, t8 = _demo_tree(4), _demo_tree(8)
    with tempfile.TemporaryDirectory() as d:
        crash = CheckpointSession(
            d, fault_injector=KillAt("manifest_write", occurrence=2,
                                     truncate_frac=0.4))
        crash.save(4, {"state": t4})
        died = False
        try:
            crash.save(8, {"state": t8})
        except SimulatedCrash:
            died = True
        _check(died, "KillAt did not fire on the second manifest write")

        fresh = CheckpointSession(d)
        ok8, _ = fresh.verify(8)
        _check(not ok8, "torn step-8 checkpoint verifies")
        _check(fresh.latest_intact() == 4,
               f"latest_intact {fresh.latest_intact()} != 4")
        _check(_trees_equal(fresh.load(4, "state", t4), t4),
               "fallback checkpoint does not round-trip")
        fresh.save(8, {"state": t8})      # post-crash rewrite heals it
        _check(fresh.latest_intact() == 8, "healed step 8 not intact")
    return ("preemption mid-manifest leaves a torn dir; session falls "
            "back to step 4 and heals on the next save")


def leg_corrupt_fallback() -> str:
    _require_devices()
    from repro.launch.train import run_mesh_native
    from repro.resilience.faults import flip_bit
    from repro.resilience.session import CheckpointSession

    clean = run_mesh_native(_mesh_args(steps=8))
    with tempfile.TemporaryDirectory() as d:
        run_mesh_native(_mesh_args(steps=8, checkpoint_dir=d,
                                   checkpoint_every=4))
        sess = CheckpointSession(d)
        _check(sess.latest_intact() == 8, "expected intact step 8")
        flip_bit(os.path.join(sess.step_dir(8), "inner.npz"))
        _check(sess.latest_intact() == 4,
               "CRC verification accepted the bit-flipped checkpoint")
        resumed = run_mesh_native(_mesh_args(steps=8, checkpoint_dir=d,
                                             checkpoint_every=4,
                                             resume=True))
    _check(_trees_equal(clean["_state"], resumed["_state"]),
           "resume-from-fallback differs from the uninterrupted run")
    return ("bit-flipped newest checkpoint rejected by CRC; resume "
            "recomputed from step 4 bit-exactly")


def leg_transient_io() -> str:
    from repro.resilience.faults import InjectedIOError, TransientIO
    from repro.resilience.session import CheckpointSession

    tree = _demo_tree(1)
    with tempfile.TemporaryDirectory() as d:
        sess = CheckpointSession(
            d, retries=3, backoff=0.0,
            fault_injector=TransientIO("array_write", times=2),
            sleep=lambda s: None)
        sess.save(4, {"state": tree})
        _check(sess.io_retries == 2,
               f"expected 2 retried OSErrors, counted {sess.io_retries}")
        _check(sess.latest_intact() == 4, "retried save not intact")
    with tempfile.TemporaryDirectory() as d:
        sess = CheckpointSession(
            d, retries=2, backoff=0.0,
            fault_injector=TransientIO("array_write", times=10),
            sleep=lambda s: None)
        exhausted = False
        try:
            sess.save(4, {"state": tree})
        except InjectedIOError:
            exhausted = True
        _check(exhausted, "retry exhaustion did not surface the OSError")
        _check(CheckpointSession(d).latest_intact() is None,
               "failed save left an 'intact' checkpoint")
    return "2 transient OSErrors retried to success; exhaustion surfaces"


def leg_store_partial() -> str:
    import numpy as np

    from repro.checkpoint.store import OuterWeightStore
    from repro.resilience.faults import truncate_file

    like = _demo_tree(2)
    with tempfile.TemporaryDirectory() as d:
        store = OuterWeightStore(d)
        trees = {c: _demo_tree(10 + c) for c in (1, 2, 3)}
        for c, t in trees.items():
            store.save(c, t)
        truncate_file(store._path(2), frac=0.5)
        bad = store.verify()
        _check(list(bad) == [2], f"verify flagged {sorted(bad)} != [2]")
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            avg = store.window_average(3, window=3, like=like)
        _check(any("skipping unreadable" in str(w.message)
                   for w in caught), "no skip warning for the torn cycle")
        ref = {k: ((trees[1][k].astype(np.float64)
                    + trees[3][k].astype(np.float64)) / 2)
               for k in like}
        _check(max(float(np.abs(np.asarray(avg[k], np.float64)
                                - ref[k]).max()) for k in like) < 1e-6,
               "window average did not renormalize over readable cycles")
    with tempfile.TemporaryDirectory() as d:
        store = OuterWeightStore(d, keep_last=2)
        for c in range(1, 5):
            store.save(c, like)
        _check(store.cycles() == [3, 4],
               f"retention kept {store.cycles()} != [3, 4]")
    return "torn outer checkpoint skipped+warned; keep_last=2 retains [3,4]"


def leg_session_gc() -> str:
    from repro.resilience.session import CheckpointSession

    tree = _demo_tree(3)
    with tempfile.TemporaryDirectory() as d:
        sess = CheckpointSession(d, keep=2)
        for step in (4, 8, 12):
            sess.save(step, {"state": tree})
        _check(sess.steps() == [8, 12],
               f"gc kept {sess.steps()} != [8, 12]")
        _check(sess.latest_intact() == 12, "newest survivor not intact")
    return "keep=2 retains [8, 12]; newest survivor verifies"


def default_legs() -> list[Leg]:
    return [
        Leg("masked-parity", leg_masked_parity, smoke=True),
        Leg("nan-replica", leg_nan_replica),
        Leg("resume-exact", leg_resume_exact, smoke=True),
        Leg("kill-mid-save", leg_kill_mid_save, smoke=True),
        Leg("corrupt-fallback", leg_corrupt_fallback),
        Leg("transient-io", leg_transient_io, smoke=True),
        Leg("store-partial", leg_store_partial),
        Leg("session-gc", leg_session_gc),
    ]


# -------------------------------------------------------------- driver


def run_leg(leg: Leg) -> dict:
    from repro.resilience.faults import SimulatedCrash
    try:
        detail = leg.run()
        return {"ok": True, "detail": detail}
    except SimulatedCrash as e:     # a leg leaked its own injected crash
        return {"ok": False, "error": f"leaked SimulatedCrash: {e}"}
    except Exception as e:          # noqa: BLE001
        return {"ok": False, "error": f"{type(e).__name__}: {e}"}


def run_fault_check(legs: list[Leg] | None = None, smoke: bool = False,
                    log=print) -> dict:
    legs = default_legs() if legs is None else legs
    if smoke:
        legs = [l for l in legs if l.smoke]
    results = {}
    for leg in legs:
        log(f"fault-check: {leg.name} ...")
        results[leg.name] = run_leg(leg)
        status = "ok" if results[leg.name]["ok"] else "FAIL"
        log(f"fault-check: {leg.name}: {status} — "
            f"{results[leg.name].get('detail', results[leg.name].get('error'))}")
    return {"legs": results, "smoke": smoke,
            "ok": all(r["ok"] for r in results.values())}


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="fault_check",
        description="Deterministic fault-injection harness: NaN "
                    "poisoning, kill-mid-save, bit flips, transient IO — "
                    "each leg a hard pass/fail scenario.")
    ap.add_argument("--smoke", action="store_true",
                    help=f"PR-lane subset (also via {SMOKE_ENV}=1)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the machine-readable report here")
    ap.add_argument("--only", metavar="SUBSTR", default=None,
                    help="run only legs whose name contains SUBSTR")
    ap.add_argument("--list", action="store_true",
                    help="list leg names and exit")
    args = ap.parse_args(argv)

    smoke = args.smoke or os.environ.get(SMOKE_ENV) == "1"
    legs = default_legs()
    if args.list:
        for l in legs:
            print(("[smoke] " if l.smoke else "        ") + l.name)
        return 0
    if args.only:
        legs = [l for l in legs if args.only in l.name]
        if not legs:
            print(f"no fault leg matches {args.only!r}", file=sys.stderr)
            return 2
    report = run_fault_check(legs, smoke=smoke)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2)
            f.write("\n")
        print(f"report written to {args.json}")
    n = len(report["legs"])
    if report["ok"]:
        print(f"fault-check: ALL_OK ({n} legs)")
        return 0
    failed = [k for k, r in report["legs"].items() if not r["ok"]]
    print(f"fault-check: FAILED ({len(failed)}/{n}): {', '.join(failed)}")
    return 1


if __name__ == "__main__":
    sys.exit(main())
