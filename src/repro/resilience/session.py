"""Preemption-safe checkpoint sessions: manifest-last, CRC-verified,
retained, resumable.

Layout of a session directory::

    <dir>/
      step_00000040/
        inner.npz           # one npz per named pytree (atomic writes)
        window.npz          # packed WindowState (optional)
        manifest.json       # written LAST, atomically — the commit point
      step_00000080/ ...
      latest                # text hint: newest step (never trusted)

The **manifest-last protocol** is what makes a kill at ANY point safe:
array files are written first (each itself atomic via the hardened
``checkpoint.io.save_pytree`` — unique tmp + fsync + rename), and the
manifest — carrying per-array CRC32s, shapes, dtypes and file sizes —
is published last. A checkpoint without a valid, matching manifest is
simply not a checkpoint; :meth:`latest_intact` scans steps newest-first
and falls back past torn (no manifest) and corrupted (CRC/size/load
mismatch) directories to the newest one that verifies.

Transient IO errors (``OSError``) during a save are retried with capped
exponential backoff; :class:`~repro.resilience.faults.SimulatedCrash`
is a ``BaseException`` precisely so it escapes this loop. ``gc()`` runs
only after a successful manifest publish, so the newest surviving
checkpoint is always intact.

What "resume bit-exactly" needs from the trainer: params, optimizer
state, the packed window ring/total/counters, and the step counter —
the data pipelines and mesh-native batch keys are stateless functions
of ``(seed, step)``, so restoring the step IS restoring the RNG and
data-pipeline position.
"""
from __future__ import annotations

import json
import os
import shutil
import time
import zlib
from typing import Any, Callable, Mapping

import numpy as np

from repro.checkpoint.io import (_read_raw, load_pytree, load_window_state,
                                 save_pytree, save_window_state)

MANIFEST = "manifest.json"
MANIFEST_VERSION = 1
_STEP_RE = "step_"


def _crc_entries(path: str) -> dict[str, dict]:
    """Per-array integrity records of an npz written by this repo's
    writers, keyed by stored leaf key (views undone — the CRC is over
    the logical bytes, identical whether bf16 is read as uint16 or not)."""
    keys, leaves = _read_raw(path)
    out: dict[str, dict] = {}
    for i, (key, arr) in enumerate(zip(keys, leaves)):
        a = np.ascontiguousarray(arr)
        out[f"{i}:{key}"] = {
            "crc32": zlib.crc32(a.tobytes()) & 0xFFFFFFFF,
            "dtype": str(a.dtype),
            "shape": list(a.shape),
        }
    return out


class CheckpointSession:
    """A versioned, preemption-safe checkpoint directory (module doc).

    ``fault_injector`` is a ``(point, path) -> None`` callable fired
    after each file write *inside the retried region* — the hook the
    fault-injection harness uses; ``None`` in production.
    """

    def __init__(self, directory: str, *, keep: int = 3, retries: int = 3,
                 backoff: float = 0.05, max_backoff: float = 1.0,
                 fault_injector: Callable[[str, str], None] | None = None,
                 sleep: Callable[[float], None] = time.sleep):
        if keep < 1:
            raise ValueError(f"keep must be >= 1, got {keep}")
        self.directory = directory
        self.keep = keep
        self.retries = retries
        self.backoff = backoff
        self.max_backoff = max_backoff
        self.fault_injector = fault_injector
        self._sleep = sleep
        self.io_retries = 0          # total retried OSErrors (observability)
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------ paths

    def step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}")

    def steps(self) -> list[int]:
        """All step numbers with a checkpoint directory (intact or not)."""
        out = []
        for name in os.listdir(self.directory):
            if name.startswith(_STEP_RE) and name[len(_STEP_RE):].isdigit():
                if os.path.isdir(os.path.join(self.directory, name)):
                    out.append(int(name[len(_STEP_RE):]))
        return sorted(out)

    # ------------------------------------------------------------- save

    def _fire(self, point: str, path: str) -> None:
        if self.fault_injector is not None:
            self.fault_injector(point, path)

    def _write(self, point: str, path: str, write: Callable[[], None]) -> None:
        """Run one file write with capped-backoff retry on OSError. The
        fault hook fires after the write, inside the retried region, so
        an injected transient error forces a clean rewrite."""
        delay = self.backoff
        for attempt in range(self.retries + 1):
            try:
                write()
                self._fire(point, path)
                return
            except OSError:
                if attempt == self.retries:
                    raise
                self.io_retries += 1
                self._sleep(min(delay, self.max_backoff))
                delay *= 2.0

    def save(self, step: int, trees: Mapping[str, Any], *,
             window: Any = None, meta: Mapping[str, Any] | None = None
             ) -> str:
        """Write one checkpoint; returns its directory. Commit point is
        the manifest publish — a crash anywhere before it leaves a torn,
        ignorable directory and the previous checkpoint authoritative."""
        d = self.step_dir(step)
        os.makedirs(d, exist_ok=True)
        files: dict[str, dict] = {}

        def record(fname: str) -> None:
            path = os.path.join(d, fname)
            files[fname] = {"size": os.path.getsize(path),
                            "arrays": _crc_entries(path)}

        for name in sorted(trees):
            if not name.isidentifier():
                raise ValueError(f"tree name {name!r} is not a plain "
                                 f"identifier")
            path = os.path.join(d, f"{name}.npz")
            self._write("array_write", path,
                        lambda p=path, t=trees[name]: save_pytree(p, t))
            record(f"{name}.npz")
        if window is not None:
            path = os.path.join(d, "window.npz")
            self._write("window_write", path,
                        lambda: save_window_state(path, window))
            record("window.npz")

        manifest = {"version": MANIFEST_VERSION, "step": step,
                    "files": files, "meta": dict(meta or {})}
        mpath = os.path.join(d, MANIFEST)
        tmp = f"{mpath}.tmp.{os.getpid()}"

        def write_manifest() -> None:
            with open(tmp, "w", encoding="utf-8") as f:
                json.dump(manifest, f, indent=1, sort_keys=True)
                f.flush()
                os.fsync(f.fileno())
            self._fire("manifest_write", tmp)
            os.replace(tmp, mpath)

        try:
            self._write("manifest_publish", mpath, write_manifest)
        finally:
            if os.path.exists(tmp):
                try:
                    os.remove(tmp)
                except OSError:
                    pass
        # hint only — latest_intact() never trusts it
        with open(os.path.join(self.directory, "latest"), "w",
                  encoding="utf-8") as f:
            f.write(f"{step}\n")
        self.gc()
        return d

    # ----------------------------------------------------------- verify

    def manifest(self, step: int) -> dict:
        with open(os.path.join(self.step_dir(step), MANIFEST),
                  encoding="utf-8") as f:
            return json.load(f)

    def meta(self, step: int) -> dict:
        return self.manifest(step).get("meta", {})

    def verify(self, step: int) -> tuple[bool, list[str]]:
        """Deep-check one checkpoint: manifest present/parsable, every
        file present with the recorded size, loadable, and every array
        matching its recorded CRC32/dtype/shape."""
        problems: list[str] = []
        d = self.step_dir(step)
        try:
            manifest = self.manifest(step)
        except Exception as e:
            return False, [f"manifest unreadable: {type(e).__name__}: {e}"]
        if manifest.get("version") != MANIFEST_VERSION:
            return False, [f"manifest version "
                           f"{manifest.get('version')!r} != "
                           f"{MANIFEST_VERSION}"]
        for fname, rec in manifest.get("files", {}).items():
            path = os.path.join(d, fname)
            if not os.path.exists(path):
                problems.append(f"{fname}: missing")
                continue
            size = os.path.getsize(path)
            if size != rec.get("size"):
                problems.append(f"{fname}: size {size} != recorded "
                                f"{rec.get('size')}")
                continue
            try:
                got = _crc_entries(path)
            except Exception as e:
                problems.append(f"{fname}: unreadable: "
                                f"{type(e).__name__}: {e}")
                continue
            want = rec.get("arrays", {})
            if set(got) != set(want):
                problems.append(f"{fname}: array keys changed")
                continue
            for key, w in want.items():
                g = got[key]
                for field in ("crc32", "dtype", "shape"):
                    if g[field] != w[field]:
                        problems.append(
                            f"{fname}:{key}: {field} {g[field]!r} != "
                            f"recorded {w[field]!r}")
        return not problems, problems

    def latest_intact(self) -> int | None:
        """Newest step whose checkpoint verifies; ``None`` when no
        intact checkpoint exists. Scans newest-first, so a torn newest
        save falls back to the previous intact one."""
        for step in reversed(self.steps()):
            ok, _ = self.verify(step)
            if ok:
                return step
        return None

    # ------------------------------------------------------------- load

    def load(self, step: int, name: str, like: Any) -> Any:
        return load_pytree(os.path.join(self.step_dir(step),
                                        f"{name}.npz"), like)

    def load_window(self, step: int, like: Any) -> Any:
        return load_window_state(os.path.join(self.step_dir(step),
                                              "window.npz"), like)

    # --------------------------------------------------------------- gc

    def gc(self) -> list[int]:
        """Drop all but the newest ``keep`` checkpoint directories.
        Called only after a successful save (so the newest survivor is
        intact by construction). Returns the removed steps."""
        removed = []
        for step in self.steps()[:-self.keep]:
            try:
                shutil.rmtree(self.step_dir(step))
                removed.append(step)
            except OSError:          # pragma: no cover - racey FS
                pass
        return removed
