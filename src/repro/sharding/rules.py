"""Logical-axis → mesh-axis sharding rules.

Every parameter leaf produced by the model builders carries a tuple of
*logical dim names* (e.g. ``("layers", "embed", "kv_heads", "head_dim")``).
``ShardingRules`` turns those into concrete ``PartitionSpec``s against a
mesh, with two hard guarantees:

1. **Divisibility** — a dim is only sharded if its size divides the mesh
   axis product; otherwise the rule silently falls through to the next
   candidate dim. This is what resolves GQA archs whose ``kv_heads`` don't
   divide the 16-way model axis: the spec falls through to ``head_dim``
   (DESIGN.md §4 table).
2. **No axis reuse** — a mesh axis is used at most once per leaf.

This keeps all 10 assigned architectures shardable on both production
meshes with one rule table per parallelism style.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Candidate mesh-axis assignments per logical dim, in priority order.
# Values are tuples of mesh-axis names (a tuple shards one array dim over
# several mesh axes jointly, e.g. batch over ("pod", "data")).
LogicalRules = dict[str, tuple[str, ...]]


def _axes_size(mesh: Mesh, axes: tuple[str, ...]) -> int:
    return math.prod(mesh.shape[a] for a in axes)


def spec_for_dims(mesh: Mesh, rules: LogicalRules, dims: Sequence[str | None],
                  shape: Sequence[int]) -> P:
    """Resolve one leaf's logical dims into a PartitionSpec."""
    assert len(dims) == len(shape), (dims, shape)
    used: set[str] = set()
    out: list[Any] = []
    for name, size in zip(dims, shape):
        assignment = None
        if name is not None and name in rules:
            axes = tuple(a for a in rules[name] if a in mesh.shape)
            if axes and not (set(axes) & used):
                if size % _axes_size(mesh, axes) == 0 and size > 0:
                    assignment = axes if len(axes) > 1 else axes[0]
                    used.update(axes)
        out.append(assignment)
    while out and out[-1] is None:  # canonical short form
        out.pop()
    return P(*out)


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    rules: LogicalRules

    def spec(self, dims: Sequence[str | None], shape: Sequence[int]) -> P:
        return spec_for_dims(self.mesh, self.rules, dims, shape)

    def tree_specs(self, params: Any, dim_tree: Any) -> Any:
        """PartitionSpec pytree for ``params`` given matching logical dims."""
        return jax.tree.map(
            lambda p, d: self.spec(d, p.shape), params, dim_tree,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x))

    def tree_shardings(self, params: Any, dim_tree: Any) -> Any:
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            self.tree_specs(params, dim_tree))

    def constrain(self, x: jax.Array, dims: Sequence[str | None]) -> jax.Array:
        """with_sharding_constraint by logical dims (no-op off-mesh)."""
        spec = self.spec(dims, x.shape)
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))


def named_sharding(mesh: Mesh, *spec) -> NamedSharding:
    return NamedSharding(mesh, P(*spec))


# shard_map in/out specs for the mesh-native HWA path: the map is *manual*
# over the replica axis only (data/model stay auto-sharded by GSPMD), so
# specs may mention nothing but the replica axis.

def stacked_replica_specs(tree: Any, axis: str = "replica") -> Any:
    """P(axis) on the leading stacked-K dim of every leaf."""
    return jax.tree.map(lambda _: P(axis), tree)


def replicated_specs(tree: Any) -> Any:
    """P() for every leaf: replica-invariant state (window ring/totals,
    counters) that every replica holds and updates identically."""
    return jax.tree.map(lambda _: P(), tree)


def make_tp_rules(mesh: Mesh, *, expert_parallel: bool = False,
                  replica_axis: str | tuple[str, ...] | None = None,
                  fsdp: bool = False,
                  sequence_parallel: bool = False) -> ShardingRules:
    """Default data+tensor-parallel rule table.

    - batch over every data-like axis present ("pod","data") so the plain
      (non-HWA) train step uses the full mesh for data parallelism;
    - vocab / mlp / heads / kv_heads / head_dim over "model" (priority is
      positional per leaf: earlier dims win the axis, later dims fall
      through — giving the GQA head_dim fallback);
    - ``fsdp``: additionally shard the "embed" weight dim over the data
      axes (ZeRO-3 style; params + optimizer moments fully sharded,
      per-block all-gather inside the layer scan). Required to fit the
      ≥12B trainings on 16 GB chips (EXPERIMENTS.md §Dry-run);
    - ``sequence_parallel``: residual-stream activations between blocks
      carry ("batch", "act_seq", None) constraints with act_seq → model
      (Megatron-SP) so saved activations shard over the model axis too;
    - experts over "model" only when expert_parallel (otherwise experts
      stay replicated/looped and their d_ff dim is sharded);
    - "replica" marks the stacked-K axis of HWA state (maps to the pod
      axis on the multi-pod mesh). It may name SEVERAL mesh axes jointly
      — the two-level sync tree's pod-carved ``("pod", "replica")`` pair
      (launch/sync/topology.py), pod-major so pods are contiguous
      replica blocks; those axes are then withheld from data
      parallelism.
    """
    replica_axes = ((replica_axis,) if isinstance(replica_axis, str)
                    else tuple(replica_axis or ()))
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape
                      and a not in replica_axes)
    rules: LogicalRules = {
        "batch": data_axes,
        "vocab": ("model",),
        "mlp": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "head_dim": ("model",),
        "ssm_heads": ("model",),
        "conv_out": ("model",),
        "embed": data_axes if fsdp else (),
        "layers": (),     # scan axis, never sharded
        "seq": (),
        "act_seq": ("model",) if sequence_parallel else (),
    }
    if expert_parallel:
        rules["experts"] = ("model",)
    if replica_axes:
        rules["replica"] = replica_axes
    return ShardingRules(mesh=mesh, rules=rules)
