from repro.sharding.rules import (
    ShardingRules,
    make_tp_rules,
    spec_for_dims,
    named_sharding,
)
