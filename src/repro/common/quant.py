"""Precision helpers for the compressed WA state & comms path.

The slide-window ring (I, P) dominates WA HBM and the two-level tree's
cross-pod all-reduce dominates sync bytes; both can drop to bf16 — or
fp8-e4m3 with per-block scales — while the running total stays f32 with
compensated (Kahan) summation. This module owns the three ingredients:

- **dtype tokens** (``f32`` / ``bf16`` / ``fp8``): the CLI- and
  SyncPlan-level names, mapped to jnp dtypes and HLO tokens;
- **block-scaled fp8 (de)quantization**: one f32 scale per ``ALIGN``
  (= 8·1024) element block of a packed buffer. A block is exactly one
  (8, 1024) kernel tile and every segment/group range of a
  :class:`~repro.common.packing.PackSpec` is an ``ALIGN`` multiple, so
  scales line up 1:1 with both the Pallas grid and the shard-aware
  layout (the "per-segment scale" metadata a PackSpec carries);
- **error-budget helpers**: Kahan compensated add for the f32 running
  total, and ULP distance in a chosen dtype's integer ladder — the
  measure the bounded-ULP parity harness and ``benchmarks/thresholds.json``
  budgets are stated in.

Everything here is elementwise/local: no collectives, no mesh.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.packing import ALIGN

#: elements covered by one fp8 scale — one packed ALIGN block == one
#: (8, 1024) kernel tile (asserted against kernels.wa_update in
#: kernels.ops)
SCALE_BLOCK = ALIGN

#: largest finite float8_e4m3fn value (no inf in e4m3fn)
FP8_MAX = 448.0

#: CLI/SyncPlan token -> storage dtype
WA_DTYPES = {
    "f32": jnp.float32,
    "bf16": jnp.bfloat16,
    "fp8": jnp.float8_e4m3fn,
}

#: token -> dtype-discipline (HLO) token, as repro.analysis.hlo_text
#: emits them
HLO_TOKENS = {"f32": "f32", "bf16": "bf16", "fp8": "f8e4m3fn"}


def wa_dtype(token):
    """The jnp storage dtype of a precision token (dtypes pass through)."""
    if isinstance(token, str) and token in WA_DTYPES:
        return WA_DTYPES[token]
    return jnp.dtype(token)


def wa_token(dtype) -> str:
    """The precision token of a storage dtype (tokens pass through)."""
    if isinstance(dtype, str) and dtype in WA_DTYPES:
        return dtype
    name = np.dtype(dtype).name
    for tok, dt in WA_DTYPES.items():
        if np.dtype(dt).name == name:
            return tok
    raise ValueError(f"no WA precision token for dtype {name!r} "
                     f"(expected one of {sorted(WA_DTYPES)})")


def is_compressed(token) -> bool:
    return wa_token(token) != "f32"


def needs_scales(token) -> bool:
    """fp8 needs per-block scales; f32/bf16 share f32's exponent range."""
    return wa_token(token) == "fp8"


def n_scale_blocks(padded: int, block: int = SCALE_BLOCK) -> int:
    if padded % block != 0:
        raise ValueError(f"padded length {padded} is not a multiple of "
                         f"the scale block ({block})")
    return padded // block


# ------------------------------------------------ block-scaled fp8 codec


def block_scales(x, block: int = SCALE_BLOCK):
    """Per-block f32 scales of ``x`` (..., P): amax/FP8_MAX, 1.0 for
    all-zero blocks (so dequantize(quantize(0)) == 0 without dividing
    by zero)."""
    bx = jnp.reshape(x, x.shape[:-1] + (-1, block))
    amax = jnp.max(jnp.abs(bx), axis=-1)
    return jnp.where(amax > 0, amax / FP8_MAX, 1.0).astype(jnp.float32)


def quantize_fp8(x, scales, block: int = SCALE_BLOCK):
    """Quantize f32 ``x`` (..., P) to fp8-e4m3 with per-block ``scales``
    (..., P/block). Values are clipped to ±FP8_MAX·scale first — e4m3fn
    has no inf, an unclipped overflow would round to NaN."""
    bx = jnp.reshape(x, x.shape[:-1] + (-1, block))
    bx = bx / scales[..., None].astype(bx.dtype)
    bx = jnp.clip(bx, -FP8_MAX, FP8_MAX)
    return jnp.reshape(bx.astype(jnp.float8_e4m3fn), x.shape)


def dequantize_fp8(q, scales, block: int = SCALE_BLOCK):
    """Inverse of :func:`quantize_fp8` up to the e4m3 rounding: fp8
    payload × its per-block scale, in f32."""
    bq = jnp.reshape(q.astype(jnp.float32), q.shape[:-1] + (-1, block))
    return jnp.reshape(bq * scales[..., None], q.shape)


def encode_slot(x, token, block: int = SCALE_BLOCK):
    """(slot, scales) ring representation of an f32 packed buffer:
    identity for f32, a cast for bf16, block-scaled fp8 (scales
    non-None) for fp8."""
    tok = wa_token(token)
    if tok == "f32":
        return x.astype(jnp.float32), None
    if tok == "bf16":
        return x.astype(jnp.bfloat16), None
    s = block_scales(x, block)
    return quantize_fp8(x, s, block), s


def decode_slot(slot, scales=None, block: int = SCALE_BLOCK):
    """f32 value of a ring slot: cast back, or fp8 × scales."""
    if scales is None:
        return slot.astype(jnp.float32)
    return dequantize_fp8(slot, scales, block)


# -------------------------------------------------- compensated summation


def kahan_add(total, comp, delta):
    """One compensated (Kahan) accumulation step: ``(total', comp')``
    with ``total' + comp'`` carrying ``total + delta`` to roughly twice
    f32 precision. ``comp`` holds the running low-order error; start it
    at zeros. With ``comp == 0`` the returned total is bit-identical to
    the plain ``total + delta`` (the f32 default path never diverges)."""
    y = delta - comp
    t = total + y
    return t, (t - total) - y


# ------------------------------------------------------------ ULP ladder

_UINTS = {8: jnp.uint8, 16: jnp.uint16, 32: jnp.uint32}


def _ulp_key(x):
    """Monotone unsigned key of a float array: consecutive representable
    values (of x's dtype) differ by exactly 1, across the sign too
    (+0 and -0 both map to the same key). Stays in the dtype's own-width
    unsigned arithmetic — no x64 needed."""
    bits = jnp.finfo(x.dtype).bits
    ut = _UINTS[bits]
    u = jax.lax.bitcast_convert_type(x, ut)
    sign_bit = np.asarray(1 << (bits - 1), np.dtype(ut))
    mag = u & (sign_bit - 1)                 # sign-magnitude payload
    # offset-binary: negatives below sign_bit, positives above; ±0 meet
    # at sign_bit. Both branches stay inside the unsigned range.
    return jnp.where(u & sign_bit != 0, sign_bit - mag, sign_bit + mag)


def ulp_distance(a, b, dtype=None):
    """Elementwise distance between ``a`` and ``b`` in units of
    ``dtype``'s representable-value ladder (steps between the two values
    after rounding both into ``dtype``). ``dtype=None`` uses the narrower
    of the two operand dtypes — the natural budget unit when comparing a
    compressed value against its f32 oracle. NaNs compare astronomically
    far from everything (including other NaNs); budgets treat that as a
    failure, which is the point."""
    a = jnp.asarray(a)
    b = jnp.asarray(b)
    if dtype is None:
        dtype = a.dtype if jnp.finfo(a.dtype).bits <= \
            jnp.finfo(b.dtype).bits else b.dtype
    dtype = wa_dtype(dtype)
    ka = _ulp_key(a.astype(dtype))
    kb = _ulp_key(b.astype(dtype))
    d = jnp.maximum(ka, kb) - jnp.minimum(ka, kb)   # exact in unsigned
    return d.astype(jnp.uint32)


def max_ulp(a, b, dtype=None) -> int:
    """max of :func:`ulp_distance` as a python int (0 for empty)."""
    d = ulp_distance(a, b, dtype)
    return int(jnp.max(d)) if d.size else 0


def rel_ulp_error(ref, got, dtype, floor=None) -> float:
    """Worst error in units of ``dtype`` ULPs AT THE REFERENCE'S WORKING
    SCALE: ``max |got - ref| / (eps(dtype) · max(|ref|, floor))``.

    This is the budget unit of the bounded-ULP parity harness. The raw
    ladder distance (:func:`ulp_distance`) is the right metric for codec
    round-trips (value and its quantization share a magnitude), but means
    and totals CANCEL: a window average can land near zero where the
    ladder is dense, while its absolute error is set by the magnitudes of
    the slots that were averaged — a ~1-ULP-of-the-data error reads as
    thousands of near-zero ULPs. ``floor`` (default: the RMS of ``ref``)
    pins the scale to the data. A value ≤ k means: within k quantization
    steps of the compressed dtype at the buffer's own scale.
    """
    ref = jnp.asarray(ref, jnp.float32)
    got = jnp.asarray(got, jnp.float32)
    if ref.size == 0:
        return 0.0
    if floor is None:
        floor = jnp.sqrt(jnp.mean(jnp.square(ref)))
    floor = jnp.maximum(jnp.asarray(floor, jnp.float32),
                        jnp.float32(np.finfo(np.float32).tiny))
    eps = jnp.float32(jnp.finfo(wa_dtype(dtype)).eps)
    scale = jnp.maximum(jnp.abs(ref), floor)
    return float(jnp.max(jnp.abs(got - ref) / (eps * scale)))
