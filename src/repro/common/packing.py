"""Parameter packing: an arbitrary pytree as ONE tile-aligned flat buffer.

The WA hot path (online mean W̄, slide-window update W̿ — Algorithms 1 & 2)
is elementwise over the full parameter set, yet a transformer holds it as
hundreds of ragged leaves. Updating per leaf costs one kernel launch per
leaf and pads each leaf up to a tile multiple (a 128-element bias padded
64×), and re-padding on every call defeats buffer donation. Packing fixes
all three: flatten every leaf into one contiguous buffer, pad ONCE at the
end to an ``ALIGN`` multiple, and keep the WA state in that layout
persistently — O(1) launches, <1% padding, donation-friendly.

The layout is described by a static :class:`PackSpec` (offsets/shapes
table + treedef) computed from abstract shapes, so it is identical under
``jit``/``eval_shape`` and hashable (usable as pytree metadata).

**Shard-aware layout** (``shards > 1``). On a multi-device mesh the packed
buffer is sharded over a *packed super-axis* — a tuple of mesh axes
(``spec.axes``) whose device count is ``spec.shards``. So that packing is
a purely LOCAL operation on every device (zero assembly collectives), the
buffer is laid out segment-major: it is ``shards`` equal segments of
``seg_len`` elements, and segment ``s`` holds, for every leaf in flatten
order,

- the leaf's shard ``s`` along its ``shard_dim`` (flattened row-major),
  when the leaf is sharded over the super-axis, or
- a full copy of the leaf, when the leaf is replicated over the
  super-axis (the copy is duplicated into EVERY segment so the per-device
  program is uniform — replicated leaves are small biases/norms, so the
  duplication cost is noise against the matrices).

Device ``s`` of the super-axis then owns exactly segment ``s``, and that
segment is computable from the device's local leaf shards alone:
``pack(local_tree, spec.local_spec())`` == its slice of the global
``pack(tree, spec)``. ``shards == 1`` (the default) degenerates to the
original contiguous layout bit-for-bit.

Packing is elementwise-layout-only: no arithmetic touches the values, so
any elementwise update on the packed buffer is bit-identical (0 ULP) to
the same update applied per leaf. :func:`repack` converts a buffer
between two layouts of the same leaf set (e.g. checkpoints moving
between mesh shapes) with the same 0-ULP guarantee.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

# One (8, 1024) f32 VMEM tile worth of elements. Must equal
# ``kernels.wa_update.TILE_ROWS * TILE_COLS`` (asserted in kernels.ops) so
# a packed buffer reshapes to (rows, 1024) with rows % 8 == 0 and feeds the
# Pallas kernels with zero per-call padding. Each SEGMENT of a sharded
# layout is padded to an ALIGN multiple, so the per-device slice tiles
# exactly too.
ALIGN = 8 * 1024


@dataclasses.dataclass(frozen=True)
class LeafSpec:
    """Placement of one pytree leaf inside the packed buffer.

    ``offset`` is the WITHIN-SEGMENT offset (== the global offset when
    ``shards == 1``). ``shard_dim`` names the leaf dim split over the
    packed super-axis, or None for a leaf replicated into every segment.
    """
    offset: int
    size: int
    shape: tuple[int, ...]
    dtype: str
    shard_dim: int | None = None


@dataclasses.dataclass(frozen=True)
class PackSpec:
    """Static description of a packed pytree: where every leaf lives.

    Hashable (treedef + tuples), so it can ride along as pytree metadata
    (``register_dataclass`` meta field) and as a ``jit`` static argument.
    ``axes`` records the mesh axes of the packed super-axis (layout
    metadata only — packing itself never touches a mesh).
    """
    treedef: Any                     # jax PyTreeDef (None for specs
                                     # rehydrated from checkpoint metadata)
    leaves: tuple[LeafSpec, ...]
    size: int                        # total useful elements (no duplicates)
    padded: int                      # buffer length == shards * seg_len
    align: int = ALIGN
    shards: int = 1
    axes: tuple[str, ...] = ()

    @property
    def n_leaves(self) -> int:
        return len(self.leaves)

    @property
    def seg_len(self) -> int:
        return self.padded // self.shards

    @property
    def pad_waste(self) -> float:
        """Non-useful fraction: (padding + replicated duplicates) / useful."""
        return (self.padded - self.size) / max(self.size, 1)

    def piece_size(self, ls: LeafSpec) -> int:
        return ls.size // self.shards if ls.shard_dim is not None else ls.size

    def local_spec(self) -> "PackSpec":
        """The per-device view of a sharded layout: one segment, local leaf
        shapes (``shard_dim`` divided by ``shards``), same offsets.

        Inside a manual ``shard_map`` whose in_specs shard each leaf over
        the super-axis on its ``shard_dim``, ``pack(local_tree,
        spec.local_spec())`` equals the device's ``seg_len`` slice of the
        global ``pack(tree, spec)`` — the invariant that makes the
        mesh-resident WA path collective-free.
        """
        if self.shards == 1:
            return self
        leaves = []
        for ls in self.leaves:
            if ls.shard_dim is None:
                leaves.append(LeafSpec(offset=ls.offset, size=ls.size,
                                       shape=ls.shape, dtype=ls.dtype))
            else:
                shape = list(ls.shape)
                shape[ls.shard_dim] //= self.shards
                leaves.append(LeafSpec(offset=ls.offset,
                                       size=ls.size // self.shards,
                                       shape=tuple(shape), dtype=ls.dtype))
        return PackSpec(treedef=self.treedef, leaves=tuple(leaves),
                        size=sum(l.size for l in leaves),
                        padded=self.seg_len, align=self.align)

    def same_layout(self, other: "PackSpec") -> bool:
        """Layout equality ignoring the treedef (checkpoint-rehydrated
        specs have none)."""
        return (self.leaves == other.leaves and self.padded == other.padded
                and self.shards == other.shards and self.align == other.align)


def pack_spec(tree: PyTree, align: int = ALIGN, *, shards: int = 1,
              shard_dims: Sequence[int | None] | None = None,
              axes: tuple[str, ...] = ()) -> PackSpec:
    """Compute the packed layout of ``tree`` (arrays or ShapeDtypeStructs).

    ``shards``/``shard_dims``/``axes`` select the shard-aware layout:
    ``shard_dims`` is a flat sequence (flatten order) giving, per leaf,
    the dim split over the packed super-axis, or None to replicate the
    leaf into every segment. Each named dim must divide by ``shards``.
    """
    flat, treedef = jax.tree.flatten(tree)
    if shards < 1:
        raise ValueError(f"shards must be >= 1, got {shards}")
    if shard_dims is None:
        sd_flat: list[int | None] = [None] * len(flat)
    else:
        sd_flat = list(shard_dims)
        if len(sd_flat) != len(flat):
            raise ValueError(f"shard_dims has {len(sd_flat)} entries for "
                             f"{len(flat)} leaves")
    leaves = []
    offset = 0
    for leaf, sd in zip(flat, sd_flat):
        shape = tuple(int(d) for d in leaf.shape)
        size = int(np.prod(shape, dtype=np.int64)) if shape else 1
        if shards == 1:
            sd = None
        if sd is not None:
            if not (0 <= sd < len(shape)) or size == 0 or \
                    shape[sd] % shards != 0:
                raise ValueError(f"leaf {shape} cannot shard dim {sd} "
                                 f"{shards}-ways")
        leaves.append(LeafSpec(offset=offset, size=size, shape=shape,
                               dtype=np.dtype(leaf.dtype).name,
                               shard_dim=sd))
        offset += size // shards if sd is not None else size
    seg_len = max(align, -(-offset // align) * align)
    return PackSpec(treedef=treedef, leaves=tuple(leaves),
                    size=sum(l.size for l in leaves),
                    padded=shards * seg_len, align=align, shards=shards,
                    axes=tuple(axes))


def _check(tree: PyTree, spec: PackSpec) -> list:
    flat, treedef = jax.tree.flatten(tree)
    if treedef != spec.treedef:
        raise ValueError(f"tree structure {treedef} does not match "
                         f"PackSpec structure {spec.treedef}")
    for leaf, ls in zip(flat, spec.leaves):
        if tuple(leaf.shape) != ls.shape:
            raise ValueError(f"leaf shape {leaf.shape} != spec {ls.shape}")
    return flat


def _piece(leaf, ls: LeafSpec, spec: PackSpec, s: int, n_lead: int):
    """Leaf's segment-``s`` contribution, flattened (lead dims kept)."""
    lead = tuple(leaf.shape[:n_lead])
    if ls.shard_dim is None or spec.shards == 1:
        return jnp.reshape(leaf, lead + (ls.size,))
    c = ls.shape[ls.shard_dim] // spec.shards
    sl = jax.lax.slice_in_dim(leaf, s * c, (s + 1) * c,
                              axis=ls.shard_dim + n_lead)
    return jnp.reshape(sl, lead + (ls.size // spec.shards,))


def pack_leaves(flat: Sequence[Any], spec: PackSpec, dtype=jnp.float32,
                n_lead: int = 0) -> jax.Array:
    """Pack already-flattened leaves (``n_lead`` shared leading batch dims
    per leaf, e.g. the K of :func:`pack_stacked` or a ring's I rows)."""
    lead = tuple(flat[0].shape[:n_lead]) if flat else ()
    segs = []
    for s in range(spec.shards):
        parts = [_piece(leaf, ls, spec, s, n_lead).astype(dtype)
                 for leaf, ls in zip(flat, spec.leaves)]
        used = sum(p.shape[-1] for p in parts)
        if spec.seg_len > used:
            parts.append(jnp.zeros(lead + (spec.seg_len - used,), dtype))
        segs.append(jnp.concatenate(parts, axis=-1))
    return jnp.concatenate(segs, axis=-1) if spec.shards > 1 else segs[0]


def pack(tree: PyTree, spec: PackSpec | None = None,
         dtype=jnp.float32) -> jax.Array:
    """Flatten ``tree`` into one ``(spec.padded,)`` buffer of ``dtype``.

    The pad region is zero-filled; elementwise updates on the buffer keep
    it zero, so nothing ever needs re-padding.
    """
    spec = spec or pack_spec(tree)
    return pack_leaves(_check(tree, spec), spec, dtype)


def pack_stacked(tree: PyTree, spec: PackSpec, dtype=jnp.float32) -> jax.Array:
    """Pack a tree whose leaves carry a leading stacked axis K → (K, padded).

    ``spec`` describes the *unstacked* leaves; every leaf must share the
    same leading dim (the K replicas of Algorithm 1).
    """
    flat, treedef = jax.tree.flatten(tree)
    if treedef != spec.treedef:
        raise ValueError("stacked tree structure does not match PackSpec")
    if not flat:
        raise ValueError("pack_stacked needs at least one leaf to infer K")
    K = flat[0].shape[0]
    for leaf, ls in zip(flat, spec.leaves):
        if tuple(leaf.shape) != (K,) + ls.shape:
            raise ValueError(f"stacked leaf {leaf.shape} != (K,)+{ls.shape}")
    return pack_leaves(flat, spec, dtype, n_lead=1)


def _unpack_one(buf: jax.Array, spec: PackSpec, ls: LeafSpec):
    """One leaf's view of the packed buffer (lead dims preserved)."""
    lead = buf.shape[:-1]
    if ls.shard_dim is None or spec.shards == 1:
        x = jax.lax.slice_in_dim(buf, ls.offset, ls.offset + ls.size,
                                 axis=buf.ndim - 1)
        return jnp.reshape(x, lead + ls.shape)
    piece = ls.size // spec.shards
    local = list(ls.shape)
    local[ls.shard_dim] //= spec.shards
    parts = []
    for s in range(spec.shards):
        off = s * spec.seg_len + ls.offset
        x = jax.lax.slice_in_dim(buf, off, off + piece, axis=buf.ndim - 1)
        parts.append(jnp.reshape(x, lead + tuple(local)))
    return jnp.concatenate(parts, axis=len(lead) + ls.shard_dim)


def unpack(buf: jax.Array, spec: PackSpec, like: PyTree | None = None
           ) -> PyTree:
    """Slice the packed buffer back into leaf views.

    Leading batch dims of ``buf`` (e.g. a ring row set ``(I, padded)``) are
    preserved on every leaf. Dtypes come from ``like`` when given, else
    from the spec (the dtypes of the tree the spec was computed from).
    """
    like_flat = _check(like, spec) if like is not None else None
    leaves = []
    for i, ls in enumerate(spec.leaves):
        dt = like_flat[i].dtype if like_flat is not None else ls.dtype
        leaves.append(_unpack_one(buf, spec, ls).astype(dt))
    return jax.tree.unflatten(spec.treedef, leaves)


def unpack_leaf(buf: jax.Array, spec: PackSpec, index: int,
                dtype=None) -> jax.Array:
    """View of a single leaf (by flatten order) of the packed buffer."""
    ls = spec.leaves[index]
    return _unpack_one(buf, spec, ls).astype(dtype or ls.dtype)


def repack(buf: jax.Array, src: PackSpec, dst: PackSpec) -> jax.Array:
    """Layout-convert a packed buffer between two PackSpecs of the same
    leaf set (bit-exact — packing never touches values). Leading batch
    dims (e.g. ring rows) are preserved. Used by checkpoint loading when
    a buffer saved under one mesh's shard-aware layout is restored under
    another's."""
    if tuple(l.shape for l in src.leaves) != \
            tuple(l.shape for l in dst.leaves):
        raise ValueError("repack: leaf shapes differ between layouts")
    leaves = [_unpack_one(buf, src, ls) for ls in src.leaves]
    return pack_leaves(leaves, dst, buf.dtype, n_lead=buf.ndim - 1)


# ------------------------------------------- layout (de)serialization
#
# Checkpoints store the layout next to the buffers so a window state saved
# under one mesh's shard-aware layout can be rehydrated (treedef-less) and
# repacked under another's. JSON keeps the .npz container dependency-free.


def spec_to_json(spec: PackSpec) -> str:
    return json.dumps({
        "align": spec.align, "shards": spec.shards, "axes": list(spec.axes),
        "size": spec.size, "padded": spec.padded,
        "leaves": [[ls.offset, ls.size, list(ls.shape), ls.dtype,
                    ls.shard_dim] for ls in spec.leaves]})


def spec_from_json(s: str) -> PackSpec:
    """Rehydrate a layout saved by :func:`spec_to_json`. The treedef is
    not serializable; the result supports the flat/leaf-level operations
    (``pack_leaves``/``unpack_leaf``/:func:`repack`) but not tree-level
    pack/unpack."""
    d = json.loads(s)
    leaves = tuple(LeafSpec(offset=o, size=n, shape=tuple(sh), dtype=dt,
                            shard_dim=sd)
                   for o, n, sh, dt, sd in d["leaves"])
    return PackSpec(treedef=None, leaves=leaves, size=d["size"],
                    padded=d["padded"], align=d["align"],
                    shards=d["shards"], axes=tuple(d["axes"]))
