"""Parameter packing: an arbitrary pytree as ONE tile-aligned flat buffer.

The WA hot path (online mean W̄, slide-window update W̿ — Algorithms 1 & 2)
is elementwise over the full parameter set, yet a transformer holds it as
hundreds of ragged leaves. Updating per leaf costs one kernel launch per
leaf and pads each leaf up to a tile multiple (a 128-element bias padded
64×), and re-padding on every call defeats buffer donation. Packing fixes
all three: flatten every leaf into one contiguous buffer, pad ONCE at the
end to an ``ALIGN`` multiple, and keep the WA state in that layout
persistently — O(1) launches, <1% padding, donation-friendly.

The layout is described by a static :class:`PackSpec` (offsets/shapes
table + treedef) computed from abstract shapes, so it is identical under
``jit``/``eval_shape`` and hashable (usable as pytree metadata).

Packing is elementwise-layout-only: no arithmetic touches the values, so
any elementwise update on the packed buffer is bit-identical (0 ULP) to
the same update applied per leaf.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

# One (8, 1024) f32 VMEM tile worth of elements. Must equal
# ``kernels.wa_update.TILE_ROWS * TILE_COLS`` (asserted in kernels.ops) so
# a packed buffer reshapes to (rows, 1024) with rows % 8 == 0 and feeds the
# Pallas kernels with zero per-call padding.
ALIGN = 8 * 1024


@dataclasses.dataclass(frozen=True)
class LeafSpec:
    """Placement of one pytree leaf inside the packed buffer."""
    offset: int
    size: int
    shape: tuple[int, ...]
    dtype: str


@dataclasses.dataclass(frozen=True)
class PackSpec:
    """Static description of a packed pytree: where every leaf lives.

    Hashable (treedef + tuples), so it can ride along as pytree metadata
    (``register_dataclass`` meta field) and as a ``jit`` static argument.
    """
    treedef: Any                     # jax PyTreeDef
    leaves: tuple[LeafSpec, ...]
    size: int                        # total useful elements
    padded: int                      # buffer length, multiple of ``align``
    align: int = ALIGN

    @property
    def n_leaves(self) -> int:
        return len(self.leaves)

    @property
    def pad_waste(self) -> float:
        """Padded-but-useless fraction: bytes padded / bytes useful."""
        return (self.padded - self.size) / max(self.size, 1)


def pack_spec(tree: PyTree, align: int = ALIGN) -> PackSpec:
    """Compute the packed layout of ``tree`` (arrays or ShapeDtypeStructs)."""
    flat, treedef = jax.tree.flatten(tree)
    leaves = []
    offset = 0
    for leaf in flat:
        shape = tuple(int(d) for d in leaf.shape)
        size = int(np.prod(shape, dtype=np.int64)) if shape else 1
        leaves.append(LeafSpec(offset=offset, size=size, shape=shape,
                               dtype=np.dtype(leaf.dtype).name))
        offset += size
    padded = max(align, -(-offset // align) * align)
    return PackSpec(treedef=treedef, leaves=tuple(leaves), size=offset,
                    padded=padded, align=align)


def _check(tree: PyTree, spec: PackSpec) -> list:
    flat, treedef = jax.tree.flatten(tree)
    if treedef != spec.treedef:
        raise ValueError(f"tree structure {treedef} does not match "
                         f"PackSpec structure {spec.treedef}")
    for leaf, ls in zip(flat, spec.leaves):
        if tuple(leaf.shape) != ls.shape:
            raise ValueError(f"leaf shape {leaf.shape} != spec {ls.shape}")
    return flat


def pack(tree: PyTree, spec: PackSpec | None = None,
         dtype=jnp.float32) -> jax.Array:
    """Flatten ``tree`` into one ``(spec.padded,)`` buffer of ``dtype``.

    The pad region is zero-filled; elementwise updates on the buffer keep
    it zero, so nothing ever needs re-padding.
    """
    spec = spec or pack_spec(tree)
    flat = _check(tree, spec)
    parts = [jnp.ravel(l).astype(dtype) for l in flat]
    if spec.padded > spec.size:
        parts.append(jnp.zeros((spec.padded - spec.size,), dtype))
    return jnp.concatenate(parts)


def pack_stacked(tree: PyTree, spec: PackSpec, dtype=jnp.float32) -> jax.Array:
    """Pack a tree whose leaves carry a leading stacked axis K → (K, padded).

    ``spec`` describes the *unstacked* leaves; every leaf must share the
    same leading dim (the K replicas of Algorithm 1).
    """
    flat, treedef = jax.tree.flatten(tree)
    if treedef != spec.treedef:
        raise ValueError("stacked tree structure does not match PackSpec")
    if not flat:
        raise ValueError("pack_stacked needs at least one leaf to infer K")
    K = flat[0].shape[0]
    parts = []
    for leaf, ls in zip(flat, spec.leaves):
        if tuple(leaf.shape) != (K,) + ls.shape:
            raise ValueError(f"stacked leaf {leaf.shape} != (K,)+{ls.shape}")
        parts.append(jnp.reshape(leaf, (K, ls.size)).astype(dtype))
    if spec.padded > spec.size:
        parts.append(jnp.zeros((K, spec.padded - spec.size), dtype))
    return jnp.concatenate(parts, axis=1)


def unpack(buf: jax.Array, spec: PackSpec, like: PyTree | None = None
           ) -> PyTree:
    """Slice the packed buffer back into leaf views.

    Leading batch dims of ``buf`` (e.g. a ring row set ``(I, padded)``) are
    preserved on every leaf. Dtypes come from ``like`` when given, else
    from the spec (the dtypes of the tree the spec was computed from).
    """
    lead = buf.shape[:-1]
    like_flat = _check(like, spec) if like is not None else None
    leaves = []
    for i, ls in enumerate(spec.leaves):
        dt = like_flat[i].dtype if like_flat is not None else ls.dtype
        x = jax.lax.slice_in_dim(buf, ls.offset, ls.offset + ls.size,
                                 axis=buf.ndim - 1)
        leaves.append(jnp.reshape(x, lead + ls.shape).astype(dt))
    return jax.tree.unflatten(spec.treedef, leaves)


def unpack_leaf(buf: jax.Array, spec: PackSpec, index: int,
                dtype=None) -> jax.Array:
    """View of a single leaf (by flatten order) of the packed buffer."""
    ls = spec.leaves[index]
    x = jax.lax.slice_in_dim(buf, ls.offset, ls.offset + ls.size,
                             axis=buf.ndim - 1)
    return jnp.reshape(x, buf.shape[:-1] + ls.shape).astype(dtype or ls.dtype)
