"""Parameter packing: an arbitrary pytree as ONE tile-aligned flat buffer.

The WA hot path (online mean W̄, slide-window update W̿ — Algorithms 1 & 2)
is elementwise over the full parameter set, yet a transformer holds it as
hundreds of ragged leaves. Updating per leaf costs one kernel launch per
leaf and pads each leaf up to a tile multiple (a 128-element bias padded
64×), and re-padding on every call defeats buffer donation. Packing fixes
all three: flatten every leaf into one contiguous buffer, pad ONCE at the
end to an ``ALIGN`` multiple, and keep the WA state in that layout
persistently — O(1) launches, <1% padding, donation-friendly.

The layout is described by a static :class:`PackSpec` (offsets/shapes
table + treedef) computed from abstract shapes, so it is identical under
``jit``/``eval_shape`` and hashable (usable as pytree metadata).

**Shard-aware layout** (``shards > 1``). On a multi-device mesh the packed
buffer is sharded over a *packed super-axis* — a tuple of mesh axes
(``spec.axes``) whose device count is ``spec.shards``. So that packing is
a purely LOCAL operation on every device (zero assembly collectives), the
buffer is laid out segment-major: it is ``shards`` equal segments of
``seg_len`` elements, and segment ``s`` holds, for every leaf in flatten
order,

- the leaf's shard ``s`` along its ``shard_dim`` (flattened row-major),
  when the leaf is sharded over the super-axis, or
- a full copy of the leaf, when the leaf is replicated over the
  super-axis (the copy is duplicated into EVERY segment so the per-device
  program is uniform — replicated leaves are small biases/norms, so the
  duplication cost is noise against the matrices).

Device ``s`` of the super-axis then owns exactly segment ``s``, and that
segment is computable from the device's local leaf shards alone:
``pack(local_tree, spec.local_spec())`` == its slice of the global
``pack(tree, spec)``. ``shards == 1`` (the default) degenerates to the
original contiguous layout bit-for-bit.

Packing is elementwise-layout-only: no arithmetic touches the values, so
any elementwise update on the packed buffer is bit-identical (0 ULP) to
the same update applied per leaf. :func:`repack` converts a buffer
between two layouts of the same leaf set (e.g. checkpoints moving
between mesh shapes) with the same 0-ULP guarantee.

**Grouped layout** (``spec.groups``). A single super-axis cannot align
mixed tilings — FSDP trees shard some leaves over the data axes, some
over model, some over both at once. The grouped layout partitions the
leaves by their *placement key* (the ordered sequence of hot
PartitionSpec entries): each :class:`PackGroup` owns a contiguous range
of the buffer laid out exactly like an independent segment-major pack —
``shards`` segments of ``seg_len`` elements over its own super-axis —
and leaves replicated over every hot axis form a ``shards == 1`` group
stored once. A leaf may tile over SEVERAL dims at once (``LeafSpec.tiles``,
e.g. dim 1 over ``data`` and dim 2 over ``model``); segment ``s`` of its
group then holds the block at the row-major coordinate decomposition of
``s`` over the tile parts, which is exactly the block a device at those
mesh coordinates owns. Each group range is therefore independently
shardable over its own axes (``P((None,) * lead + (group.axes,))``), and
every device's slice of every group is computable from its local leaf
blocks alone — the mesh-resident invariant, extended to mixed tilings.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

# One (8, 1024) f32 VMEM tile worth of elements. Must equal
# ``kernels.wa_update.TILE_ROWS * TILE_COLS`` (asserted in kernels.ops) so
# a packed buffer reshapes to (rows, 1024) with rows % 8 == 0 and feeds the
# Pallas kernels with zero per-call padding. Each SEGMENT of a sharded
# layout is padded to an ALIGN multiple, so the per-device slice tiles
# exactly too.
ALIGN = 8 * 1024


@dataclasses.dataclass(frozen=True)
class LeafSpec:
    """Placement of one pytree leaf inside the packed buffer.

    ``offset`` is the WITHIN-SEGMENT offset (== the global offset when
    ``shards == 1``). ``shard_dim`` names the leaf dim split over the
    packed super-axis, or None for a leaf replicated into every segment.
    ``group`` indexes the :class:`PackGroup` the leaf lives in (always 0
    for single-range layouts). ``tiles`` is the multi-dim placement of a
    grouped layout — ``((dim, parts), ...)`` in ascending dim order, one
    entry per tiled dim — or None to derive the single-dim placement from
    ``shard_dim`` and the group's shard count.
    """
    offset: int
    size: int
    shape: tuple[int, ...]
    dtype: str
    shard_dim: int | None = None
    group: int = 0
    tiles: tuple[tuple[int, int], ...] | None = None


def _leaf_tiles(ls: LeafSpec, shards: int) -> tuple[tuple[int, int], ...]:
    """Normalized tiling of a leaf within a group of ``shards`` segments:
    () for a leaf held whole in every segment."""
    if ls.tiles is not None:
        return ls.tiles
    if ls.shard_dim is None or shards == 1:
        return ()
    return ((ls.shard_dim, shards),)


@dataclasses.dataclass(frozen=True)
class PackGroup:
    """One contiguous range of a grouped packed layout.

    The range ``[offset, offset + shards * seg_len)`` is laid out like an
    independent segment-major pack: ``shards`` segments of ``seg_len``
    elements (an ``align`` multiple each), sharded jointly over the mesh
    axes ``axes`` (layout metadata — packing itself never touches a
    mesh). ``axes == ()`` with ``shards == 1`` is the replicated group:
    its leaves are stored once and every device holds the full range.
    """
    shards: int
    axes: tuple[str, ...]
    seg_len: int
    offset: int

    @property
    def padded(self) -> int:
        return self.shards * self.seg_len


@dataclasses.dataclass(frozen=True)
class PackSpec:
    """Static description of a packed pytree: where every leaf lives.

    Hashable (treedef + tuples), so it can ride along as pytree metadata
    (``register_dataclass`` meta field) and as a ``jit`` static argument.
    ``axes`` records the mesh axes of the packed super-axis (layout
    metadata only — packing itself never touches a mesh).

    ``ring_dtype`` names the storage dtype of WA ring buffers laid out by
    this spec (``float32`` default; ``bfloat16`` / ``float8_e4m3fn`` for
    the compressed WA state). It is precision metadata, NOT layout: two
    specs differing only in ``ring_dtype`` satisfy :meth:`same_layout`
    and repack bit-exactly. An fp8 ring carries one f32 scale per
    ``align``-element block (:attr:`scale_blocks` per ring row); blocks
    line up with segment/group boundaries because every segment length is
    an ``align`` multiple, so the scales shard exactly like the buffer.
    """
    treedef: Any                     # jax PyTreeDef (None for specs
                                     # rehydrated from checkpoint metadata)
    leaves: tuple[LeafSpec, ...]
    size: int                        # total useful elements (no duplicates)
    padded: int                      # buffer length == shards * seg_len
    align: int = ALIGN
    shards: int = 1
    axes: tuple[str, ...] = ()
    groups: tuple[PackGroup, ...] = ()   # grouped layout; () == one range
                                         # described by shards/axes
    ring_dtype: str = "float32"      # WA ring storage dtype (precision
                                     # metadata; layout-neutral)

    @property
    def n_leaves(self) -> int:
        return len(self.leaves)

    @property
    def n_groups(self) -> int:
        return len(self.groups) if self.groups else 1

    @property
    def is_grouped(self) -> bool:
        return bool(self.groups)

    @property
    def seg_len(self) -> int:
        """Per-segment length of a SINGLE-range layout (grouped layouts
        carry per-group ``seg_len`` in :meth:`group_table`)."""
        return self.padded // self.shards

    def group_table(self) -> tuple[PackGroup, ...]:
        """The layout as PackGroups — grouped layouts verbatim, single-
        range layouts as the one degenerate group covering the buffer."""
        if self.groups:
            return self.groups
        return (PackGroup(shards=self.shards, axes=self.axes,
                          seg_len=self.padded // self.shards, offset=0),)

    @property
    def pad_waste(self) -> float:
        """Non-useful fraction: (padding + replicated duplicates) / useful."""
        return (self.padded - self.size) / max(self.size, 1)

    def piece_size(self, ls: LeafSpec) -> int:
        tiles = _leaf_tiles(ls, self.group_table()[ls.group].shards)
        parts = math.prod(p for _, p in tiles) if tiles else 1
        return ls.size // parts

    def local_spec(self) -> "PackSpec":
        """The per-device view of a sharded layout: one segment per group,
        local leaf shapes (each tiled dim divided by its parts), same
        within-segment offsets.

        Inside a manual ``shard_map`` whose in_specs shard each leaf over
        its group's super-axis on its tiled dims, ``pack(local_tree,
        spec.local_spec())`` equals the device's slice of the global
        ``pack(tree, spec)`` (segment ``s`` of every group, ``s`` the
        device's coordinate along that group's axes) — the invariant that
        makes the mesh-resident WA path collective-free. The local view of
        a grouped layout keeps its groups (all ``shards == 1``, offsets
        re-based to the concatenation of the per-group segments).
        """
        if not self.groups and self.shards == 1:
            return self
        gt = self.group_table()
        leaves = []
        for ls in self.leaves:
            tiles = _leaf_tiles(ls, gt[ls.group].shards)
            if not tiles:
                leaves.append(LeafSpec(offset=ls.offset, size=ls.size,
                                       shape=ls.shape, dtype=ls.dtype,
                                       group=ls.group))
            else:
                shape = list(ls.shape)
                for d, p in tiles:
                    shape[d] //= p
                parts = math.prod(p for _, p in tiles)
                leaves.append(LeafSpec(offset=ls.offset,
                                       size=ls.size // parts,
                                       shape=tuple(shape), dtype=ls.dtype,
                                       group=ls.group))
        if not self.groups:
            return PackSpec(treedef=self.treedef, leaves=tuple(leaves),
                            size=sum(l.size for l in leaves),
                            padded=self.seg_len, align=self.align,
                            ring_dtype=self.ring_dtype)
        lgroups = []
        off = 0
        for g in gt:
            lgroups.append(PackGroup(shards=1, axes=(), seg_len=g.seg_len,
                                     offset=off))
            off += g.seg_len
        return PackSpec(treedef=self.treedef, leaves=tuple(leaves),
                        size=sum(l.size for l in leaves), padded=off,
                        align=self.align, groups=tuple(lgroups),
                        ring_dtype=self.ring_dtype)

    def same_layout(self, other: "PackSpec") -> bool:
        """Layout equality ignoring the treedef (checkpoint-rehydrated
        specs have none) and ``ring_dtype`` (precision, not layout)."""
        return (self.leaves == other.leaves and self.padded == other.padded
                and self.shards == other.shards and self.align == other.align
                and self.groups == other.groups)

    # ------------------------------------------ precision metadata

    @property
    def scale_block(self) -> int:
        """Elements per fp8 scale: one ``align`` block == one kernel tile."""
        return self.align

    @property
    def scale_blocks(self) -> int:
        """fp8 scales per ring row over the whole buffer."""
        return self.padded // self.align

    def group_scale_blocks(self, g: PackGroup) -> int:
        """fp8 scales per ring row of one group's range."""
        return g.padded // self.align

    def with_ring_dtype(self, dtype) -> "PackSpec":
        """This layout with its WA ring precision set (dtype or token —
        ``f32``/``bf16``/``fp8`` — accepted); layout untouched."""
        from repro.common.quant import wa_dtype
        name = np.dtype(wa_dtype(dtype)).name
        if name == self.ring_dtype:
            return self
        return dataclasses.replace(self, ring_dtype=name)


def pack_spec(tree: PyTree, align: int = ALIGN, *, shards: int = 1,
              shard_dims: Sequence[int | None] | None = None,
              axes: tuple[str, ...] = ()) -> PackSpec:
    """Compute the packed layout of ``tree`` (arrays or ShapeDtypeStructs).

    ``shards``/``shard_dims``/``axes`` select the shard-aware layout:
    ``shard_dims`` is a flat sequence (flatten order) giving, per leaf,
    the dim split over the packed super-axis, or None to replicate the
    leaf into every segment. Each named dim must divide by ``shards``.
    """
    flat, treedef = jax.tree.flatten(tree)
    if shards < 1:
        raise ValueError(f"shards must be >= 1, got {shards}")
    if shard_dims is None:
        sd_flat: list[int | None] = [None] * len(flat)
    else:
        sd_flat = list(shard_dims)
        if len(sd_flat) != len(flat):
            raise ValueError(f"shard_dims has {len(sd_flat)} entries for "
                             f"{len(flat)} leaves")
    leaves = []
    offset = 0
    for leaf, sd in zip(flat, sd_flat):
        shape = tuple(int(d) for d in leaf.shape)
        size = int(np.prod(shape, dtype=np.int64)) if shape else 1
        if shards == 1:
            sd = None
        if sd is not None:
            if not (0 <= sd < len(shape)) or size == 0 or \
                    shape[sd] % shards != 0:
                raise ValueError(f"leaf {shape} cannot shard dim {sd} "
                                 f"{shards}-ways")
        leaves.append(LeafSpec(offset=offset, size=size, shape=shape,
                               dtype=np.dtype(leaf.dtype).name,
                               shard_dim=sd))
        offset += size // shards if sd is not None else size
    seg_len = max(align, -(-offset // align) * align)
    return PackSpec(treedef=treedef, leaves=tuple(leaves),
                    size=sum(l.size for l in leaves),
                    padded=shards * seg_len, align=align, shards=shards,
                    axes=tuple(axes))


# A per-leaf placement for the grouped layout: ((dim, axes), ...) pairs in
# ascending dim order — leaf dim ``dim`` tiles over the mesh axes ``axes``
# jointly — or () for a leaf replicated over every hot axis.
Placement = tuple[tuple[int, tuple[str, ...]], ...]


def pack_spec_grouped(tree: PyTree, align: int = ALIGN, *,
                      placements: Sequence[Placement],
                      axis_sizes: dict[str, int]) -> PackSpec:
    """Compute a GROUPED packed layout of ``tree`` for mixed tilings.

    ``placements`` gives, per leaf (flatten order), which dims tile over
    which mesh axes (``axis_sizes`` maps axis name → device count).
    Leaves sharing a placement key — the ordered sequence of axes tuples
    — share a :class:`PackGroup`; groups are laid out contiguously in
    first-appearance order, each segment-major over its own super-axis.
    Leaves with an empty placement form a ``shards == 1`` group stored
    once (no per-segment duplication). Every tiled dim must divide by its
    axes' device product.
    """
    flat, treedef = jax.tree.flatten(tree)
    pls = [tuple(pl) for pl in placements]
    if len(pls) != len(flat):
        raise ValueError(f"placements has {len(pls)} entries for "
                         f"{len(flat)} leaves")
    keys: list[tuple[tuple[str, ...], ...]] = []
    for pl in pls:
        key = tuple(tuple(axes) for _, axes in pl)
        if key not in keys:
            keys.append(key)
    if not keys:
        keys.append(())
    offsets = [0] * len(keys)
    leaves = []
    for leaf, pl in zip(flat, pls):
        shape = tuple(int(d) for d in leaf.shape)
        size = int(np.prod(shape, dtype=np.int64)) if shape else 1
        key = tuple(tuple(axes) for _, axes in pl)
        gi = keys.index(key)
        tiles = []
        for dim, axes in pl:
            parts = math.prod(axis_sizes[a] for a in axes)
            if not (0 <= dim < len(shape)) or size == 0 or \
                    shape[dim] % parts != 0:
                raise ValueError(f"leaf {shape} cannot tile dim {dim} "
                                 f"{parts}-ways over {tuple(axes)}")
            tiles.append((dim, parts))
        dims_used = [d for d, _ in tiles]
        if dims_used != sorted(set(dims_used)):
            raise ValueError(f"placement dims must be distinct and "
                             f"ascending, got {dims_used}")
        parts_total = math.prod(p for _, p in tiles) if tiles else 1
        if len(tiles) == 1:
            ls = LeafSpec(offset=offsets[gi], size=size, shape=shape,
                          dtype=np.dtype(leaf.dtype).name,
                          shard_dim=tiles[0][0], group=gi)
        else:
            ls = LeafSpec(offset=offsets[gi], size=size, shape=shape,
                          dtype=np.dtype(leaf.dtype).name, group=gi,
                          tiles=tuple(tiles) if tiles else None)
        leaves.append(ls)
        offsets[gi] += size // parts_total
    groups = []
    goff = 0
    for key, used in zip(keys, offsets):
        flat_axes = tuple(a for axes in key for a in axes)
        shards = math.prod(axis_sizes[a] for a in flat_axes) if flat_axes \
            else 1
        seg_len = max(align, -(-used // align) * align)
        groups.append(PackGroup(shards=shards, axes=flat_axes,
                                seg_len=seg_len, offset=goff))
        goff += shards * seg_len
    return PackSpec(treedef=treedef, leaves=tuple(leaves),
                    size=sum(l.size for l in leaves), padded=goff,
                    align=align, groups=tuple(groups))


def _check(tree: PyTree, spec: PackSpec) -> list:
    flat, treedef = jax.tree.flatten(tree)
    if treedef != spec.treedef:
        raise ValueError(f"tree structure {treedef} does not match "
                         f"PackSpec structure {spec.treedef}")
    for leaf, ls in zip(flat, spec.leaves):
        if tuple(leaf.shape) != ls.shape:
            raise ValueError(f"leaf shape {leaf.shape} != spec {ls.shape}")
    return flat


def _piece(leaf, ls: LeafSpec, group: PackGroup, s: int, n_lead: int):
    """Leaf's segment-``s`` contribution to its group, flattened (lead
    dims kept). ``s`` decomposes row-major over the leaf's tile parts —
    the coordinate order of the group's joint super-axis."""
    lead = tuple(leaf.shape[:n_lead])
    tiles = _leaf_tiles(ls, group.shards)
    if not tiles:
        return jnp.reshape(leaf, lead + (ls.size,))
    suffix = []
    acc = 1
    for _, p in reversed(tiles):
        suffix.append(acc)
        acc *= p
    suffix.reverse()
    x = leaf
    n = ls.size
    for (d, p), suf in zip(tiles, suffix):
        c = (s // suf) % p
        w = x.shape[d + n_lead] // p
        x = jax.lax.slice_in_dim(x, c * w, (c + 1) * w, axis=d + n_lead)
        n //= p
    return jnp.reshape(x, lead + (n,))


def pack_leaves(flat: Sequence[Any], spec: PackSpec, dtype=jnp.float32,
                n_lead: int = 0) -> jax.Array:
    """Pack already-flattened leaves (``n_lead`` shared leading batch dims
    per leaf, e.g. the K of :func:`pack_stacked` or a ring's I rows)."""
    lead = tuple(flat[0].shape[:n_lead]) if flat else ()
    gt = spec.group_table()
    members: list[list] = [[] for _ in gt]
    for leaf, ls in zip(flat, spec.leaves):
        members[ls.group].append((leaf, ls))
    segs = []
    for g, mem in zip(gt, members):
        for s in range(g.shards):
            parts = [_piece(leaf, ls, g, s, n_lead).astype(dtype)
                     for leaf, ls in mem]
            used = sum(p.shape[-1] for p in parts)
            if g.seg_len > used:
                parts.append(jnp.zeros(lead + (g.seg_len - used,), dtype))
            segs.append(jnp.concatenate(parts, axis=-1))
    return jnp.concatenate(segs, axis=-1) if len(segs) > 1 else segs[0]


def pack(tree: PyTree, spec: PackSpec | None = None,
         dtype=jnp.float32) -> jax.Array:
    """Flatten ``tree`` into one ``(spec.padded,)`` buffer of ``dtype``.

    The pad region is zero-filled; elementwise updates on the buffer keep
    it zero, so nothing ever needs re-padding.
    """
    spec = spec or pack_spec(tree)
    return pack_leaves(_check(tree, spec), spec, dtype)


def pack_stacked(tree: PyTree, spec: PackSpec, dtype=jnp.float32) -> jax.Array:
    """Pack a tree whose leaves carry a leading stacked axis K → (K, padded).

    ``spec`` describes the *unstacked* leaves; every leaf must share the
    same leading dim (the K replicas of Algorithm 1).
    """
    flat, treedef = jax.tree.flatten(tree)
    if treedef != spec.treedef:
        raise ValueError("stacked tree structure does not match PackSpec")
    if not flat:
        raise ValueError("pack_stacked needs at least one leaf to infer K")
    K = flat[0].shape[0]
    for leaf, ls in zip(flat, spec.leaves):
        if tuple(leaf.shape) != (K,) + ls.shape:
            raise ValueError(f"stacked leaf {leaf.shape} != (K,)+{ls.shape}")
    return pack_leaves(flat, spec, dtype, n_lead=1)


def _unpack_one(buf: jax.Array, spec: PackSpec, ls: LeafSpec):
    """One leaf's view of the packed buffer (lead dims preserved)."""
    lead = buf.shape[:-1]
    g = spec.group_table()[ls.group]
    tiles = _leaf_tiles(ls, g.shards)
    if not tiles:
        off = g.offset + ls.offset      # replicated: segment 0's copy
        x = jax.lax.slice_in_dim(buf, off, off + ls.size, axis=buf.ndim - 1)
        return jnp.reshape(x, lead + ls.shape)
    parts = math.prod(p for _, p in tiles)
    piece = ls.size // parts
    local = list(ls.shape)
    for d, p in tiles:
        local[d] //= p
    pieces = []
    for s in range(g.shards):
        off = g.offset + s * g.seg_len + ls.offset
        x = jax.lax.slice_in_dim(buf, off, off + piece, axis=buf.ndim - 1)
        pieces.append(jnp.reshape(x, lead + tuple(local)))

    def assemble(arrs, ts):
        d, p = ts[0]
        if len(ts) == 1:
            return jnp.concatenate(arrs, axis=len(lead) + d)
        chunk = len(arrs) // p
        subs = [assemble(arrs[i * chunk:(i + 1) * chunk], ts[1:])
                for i in range(p)]
        return jnp.concatenate(subs, axis=len(lead) + d)

    return assemble(pieces, tiles)


def unpack(buf: jax.Array, spec: PackSpec, like: PyTree | None = None
           ) -> PyTree:
    """Slice the packed buffer back into leaf views.

    Leading batch dims of ``buf`` (e.g. a ring row set ``(I, padded)``) are
    preserved on every leaf. Dtypes come from ``like`` when given, else
    from the spec (the dtypes of the tree the spec was computed from).
    """
    like_flat = _check(like, spec) if like is not None else None
    leaves = []
    for i, ls in enumerate(spec.leaves):
        dt = like_flat[i].dtype if like_flat is not None else ls.dtype
        leaves.append(_unpack_one(buf, spec, ls).astype(dt))
    return jax.tree.unflatten(spec.treedef, leaves)


def unpack_leaf(buf: jax.Array, spec: PackSpec, index: int,
                dtype=None) -> jax.Array:
    """View of a single leaf (by flatten order) of the packed buffer."""
    ls = spec.leaves[index]
    return _unpack_one(buf, spec, ls).astype(dtype or ls.dtype)


def repack(buf: jax.Array, src: PackSpec, dst: PackSpec) -> jax.Array:
    """Layout-convert a packed buffer between two PackSpecs of the same
    leaf set (bit-exact — packing never touches values). Leading batch
    dims (e.g. ring rows) are preserved. Used by checkpoint loading when
    a buffer saved under one mesh's shard-aware layout is restored under
    another's."""
    if tuple(l.shape for l in src.leaves) != \
            tuple(l.shape for l in dst.leaves):
        raise ValueError("repack: leaf shapes differ between layouts")
    leaves = [_unpack_one(buf, src, ls) for ls in src.leaves]
    return pack_leaves(leaves, dst, buf.dtype, n_lead=buf.ndim - 1)


# -------------------------------------------------- grouped-buffer views
#
# A grouped layout is ONE logical buffer (checkpoints and repack see it
# that way), but at runtime each group range shards over a DIFFERENT
# super-axis, which a single array's PartitionSpec cannot express — so
# the mesh sync bundles carry grouped window state as per-group buffer
# tuples. These helpers convert between the two representations (pure
# slicing/concat: bit-exact both ways).


def split_groups(buf: jax.Array, spec: PackSpec) -> tuple[jax.Array, ...]:
    """Per-group sub-buffers of a packed buffer (lead dims preserved)."""
    return tuple(
        jax.lax.slice_in_dim(buf, g.offset, g.offset + g.padded,
                             axis=buf.ndim - 1)
        for g in spec.group_table())


def merge_groups(parts, spec: PackSpec) -> jax.Array:
    """Inverse of :func:`split_groups`: concatenate per-group buffers
    back into the single logical buffer (a bare array passes through)."""
    if not isinstance(parts, (tuple, list)):
        return parts
    parts = tuple(parts)
    if len(parts) != spec.n_groups:
        raise ValueError(f"{len(parts)} group buffers for a "
                         f"{spec.n_groups}-group layout")
    return parts[0] if len(parts) == 1 else \
        jnp.concatenate(parts, axis=parts[0].ndim - 1)


def window_buffers(spec: PackSpec, window: int, ring_dtype=jnp.float32,
                   make=jnp.zeros):
    """Allocate zeroed (ring, total) window buffers matching a sync
    bundle's ``pack_spec`` contract: bare ``(I, padded)`` / ``(padded,)``
    arrays for single-range layouts, per-group tuples for grouped ones
    (each group buffer shards over its own super-axis). ``make(shape,
    dtype)`` swaps the allocator — ``jax.ShapeDtypeStruct`` gives the
    bundle's abstract args (the ONE place this shape contract lives).
    The total is ALWAYS f32, whatever the ring stores; compressed rings
    carry their companions (fp8 scales, Kahan compensation) via
    :func:`window_aux_buffers`."""
    if not spec.is_grouped:
        return (make((window, spec.padded), ring_dtype),
                make((spec.padded,), jnp.float32))
    gt = spec.group_table()
    return (tuple(make((window, g.padded), ring_dtype) for g in gt),
            tuple(make((g.padded,), jnp.float32) for g in gt))


def window_aux_buffers(spec: PackSpec, window: int, ring_dtype,
                       make=jnp.zeros):
    """The compressed ring's companion buffers ``(scales, comp)``, shaped
    like :func:`window_buffers` shapes ring/total (per-group tuples for
    grouped layouts):

    - ``scales``: per-block f32 fp8 scales, ``(I, padded // align)`` —
      ``None`` unless the ring dtype is fp8. Initialized to ONES (the
      scale of an all-zero block), matching a zeroed ring.
    - ``comp``: the Kahan compensation of the f32 running total,
      ``(padded,)`` f32 zeros — ``None`` for an f32 ring (the default
      path stays bit-identical with no extra state).
    """
    from repro.common.quant import is_compressed, needs_scales
    if not is_compressed(ring_dtype):
        return None, None

    def ones(shape, dtype):
        if make is jnp.zeros:
            return jnp.ones(shape, dtype)
        return make(shape, dtype)

    gt = spec.group_table()
    if not spec.is_grouped:
        scales = ones((window, spec.scale_blocks), jnp.float32) \
            if needs_scales(ring_dtype) else None
        return scales, make((spec.padded,), jnp.float32)
    scales = tuple(ones((window, spec.group_scale_blocks(g)), jnp.float32)
                   for g in gt) if needs_scales(ring_dtype) else None
    return scales, tuple(make((g.padded,), jnp.float32) for g in gt)


# ------------------------------------------- layout (de)serialization
#
# Checkpoints store the layout next to the buffers so a window state saved
# under one mesh's shard-aware layout can be rehydrated (treedef-less) and
# repacked under another's. JSON keeps the .npz container dependency-free.


def spec_to_json(spec: PackSpec) -> str:
    d = {
        "align": spec.align, "shards": spec.shards, "axes": list(spec.axes),
        "size": spec.size, "padded": spec.padded,
        "leaves": [[ls.offset, ls.size, list(ls.shape), ls.dtype,
                    ls.shard_dim, ls.group,
                    [list(t) for t in ls.tiles] if ls.tiles is not None
                    else None] for ls in spec.leaves]}
    if spec.groups:
        d["groups"] = [[g.shards, list(g.axes), g.seg_len, g.offset]
                       for g in spec.groups]
    if spec.ring_dtype != "float32":
        d["ring_dtype"] = spec.ring_dtype    # omitted == f32: records
    return json.dumps(d)                     # written pre-compression
                                             # rehydrate unchanged


def spec_from_json(s: str) -> PackSpec:
    """Rehydrate a layout saved by :func:`spec_to_json` (including
    pre-grouped-layout records, whose leaf rows have no group/tiles
    columns). The treedef is not serializable; the result supports the
    flat/leaf-level operations (``pack_leaves``/``unpack_leaf``/
    :func:`repack`) but not tree-level pack/unpack."""
    d = json.loads(s)
    leaves = []
    for row in d["leaves"]:
        o, n, sh, dt, sd = row[:5]
        gi = row[5] if len(row) > 5 else 0
        tiles = row[6] if len(row) > 6 else None
        leaves.append(LeafSpec(
            offset=o, size=n, shape=tuple(sh), dtype=dt, shard_dim=sd,
            group=gi,
            tiles=tuple(tuple(t) for t in tiles) if tiles is not None
            else None))
    groups = tuple(PackGroup(shards=gs, axes=tuple(ax), seg_len=sl,
                             offset=go)
                   for gs, ax, sl, go in d.get("groups", []))
    return PackSpec(treedef=None, leaves=tuple(leaves), size=d["size"],
                    padded=d["padded"], align=d["align"],
                    shards=d["shards"], axes=tuple(d["axes"]),
                    groups=groups,
                    ring_dtype=d.get("ring_dtype", "float32"))
