"""Pytree arithmetic helpers used throughout the framework.

All weight-averaging math in ``repro.core`` is expressed through these
helpers so that the HWA update rules read like the paper's equations.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a: PyTree, s) -> PyTree:
    return jax.tree.map(lambda x: x * s, a)


def tree_lerp(a: PyTree, b: PyTree, t) -> PyTree:
    """(1 - t) * a + t * b, elementwise over matching leaves."""
    return jax.tree.map(lambda x, y: x + t * (y - x), a, b)


def tree_zeros_like(a: PyTree) -> PyTree:
    return jax.tree.map(jnp.zeros_like, a)


def tree_num_params(a: PyTree) -> int:
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(a) if hasattr(l, "shape")))


def tree_num_bytes(a: PyTree) -> int:
    total = 0
    for leaf in jax.tree.leaves(a):
        if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
            total += int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
    return total


def tree_l2_norm(a: PyTree):
    sq = sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(a))
    return jnp.sqrt(sq)


def tree_cast(a: PyTree, dtype) -> PyTree:
    return jax.tree.map(lambda x: x.astype(dtype), a)


def tree_stack(trees: list[PyTree]) -> PyTree:
    """Stack a list of identical pytrees along a new leading axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def tree_unstack(tree: PyTree, n: int) -> list[PyTree]:
    return [jax.tree.map(lambda x: x[i], tree) for i in range(n)]


def tree_mean_axis0(tree: PyTree) -> PyTree:
    """Mean over the leading (replica) axis of every leaf."""
    return jax.tree.map(lambda x: jnp.mean(x, axis=0), tree)


def tree_all_finite(a: PyTree):
    flags = [jnp.all(jnp.isfinite(l)) for l in jax.tree.leaves(a)
             if jnp.issubdtype(jnp.asarray(l).dtype, jnp.floating)]
    if not flags:
        return jnp.asarray(True)
    return functools.reduce(jnp.logical_and, flags)
