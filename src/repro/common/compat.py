"""JAX cross-version compatibility shims.

Compat policy (the repo's one rule for version drift): **every call into a
JAX API that moved, was renamed, or grew a replacement between 0.4.x and
≥0.5 goes through this module** — never a direct ``jax.<new_api>`` call
with a local try/except at the call site. Each shim prefers the newest
public API when present and falls back to the oldest one the pinned
container (jax 0.4.37) ships, so the same source runs unmodified on both.
Shims are plain functions/objects resolved at import time where possible
(zero per-call overhead) and covered by ``tests/test_compat.py``, which
monkeypatches both branches.

Currently papered-over drift:

- ``jax.tree.flatten_with_path`` / ``jax.tree.map_with_path`` (≥0.5 /
  late 0.4): fall back to ``jax.tree_util.tree_flatten_with_path`` /
  ``tree_map_with_path`` (present since 0.4.6).
- ``jax.set_mesh`` (≥0.6) / ``jax.sharding.use_mesh`` (0.5.x): fall back
  to the ``Mesh`` context manager (``with mesh:``), which all 0.4.x
  releases support.
- ``jax.make_mesh`` (≥0.4.34): fall back to
  ``mesh_utils.create_device_mesh`` + ``jax.sharding.Mesh``.
- ``jax.shard_map`` (≥0.8, experimental graduation): fall back to
  ``jax.experimental.shard_map.shard_map``.
"""
from __future__ import annotations

import contextlib
from typing import Any

import jax

__all__ = ["tree_flatten_with_path", "tree_map_with_path", "use_mesh",
           "make_mesh", "shard_map"]


# ------------------------------------------------------------ pytree paths

if hasattr(jax.tree, "flatten_with_path"):          # jax ≥ 0.5
    tree_flatten_with_path = jax.tree.flatten_with_path
else:                                               # jax 0.4.x
    tree_flatten_with_path = jax.tree_util.tree_flatten_with_path

if hasattr(jax.tree, "map_with_path"):
    tree_map_with_path = jax.tree.map_with_path
else:
    tree_map_with_path = jax.tree_util.tree_map_with_path


# ------------------------------------------------------------------- mesh

def use_mesh(mesh) -> contextlib.AbstractContextManager:
    """Context manager installing ``mesh`` as the ambient mesh.

    ``jax.set_mesh`` (≥0.6) → ``jax.sharding.use_mesh`` (0.5.x) → the
    ``Mesh`` object's own context manager (0.4.x).
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return mesh


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """``jax.make_mesh`` with a pre-0.4.34 fallback via mesh_utils."""
    if devices is None and hasattr(jax, "make_mesh"):
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))
    from jax.experimental import mesh_utils
    devs = mesh_utils.create_device_mesh(tuple(axis_shapes), devices=devices)
    return jax.sharding.Mesh(devs, tuple(axis_names))


# -------------------------------------------------------------- shard_map

def _resolve_shard_map():
    if hasattr(jax, "shard_map"):                   # jax ≥ 0.8
        return jax.shard_map
    from jax.experimental.shard_map import shard_map as _sm  # 0.4.x–0.7
    return _sm


def shard_map(f, mesh, *, in_specs, out_specs, auto=frozenset(),
              check_rep=None, check_vma=None):
    """``shard_map`` across the keyword drift.

    0.4.x–0.7 take ``check_rep``/``auto`` keywords; ≥0.8 renamed
    ``check_rep`` to ``check_vma`` and replaced ``auto`` with mesh
    ``axis_types``. Callers may pass either replication-check spelling;
    both default to disabled. We try the old keywords first and degrade to
    the new-style call on TypeError — on new versions the mesh built by
    :func:`make_mesh` carries every axis as manual, which is only correct
    for fully-manual maps, so callers that need partial-auto on ≥0.8
    should migrate the mesh's axis_types (noted here so the failure mode
    is a documented one, not a silent one).
    """
    check = check_rep if check_rep is not None else \
        (check_vma if check_vma is not None else False)
    sm = _resolve_shard_map()
    try:
        return sm(f, mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=check, auto=auto)
    except TypeError:
        if auto:
            raise NotImplementedError(
                "this jax's shard_map has no auto= keyword; dropping it "
                "would silently turn a partial-auto map fully manual. "
                "Migrate the mesh to axis_types-based auto axes "
                "(see repro.common.compat docstring).")
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check)
