"""Deterministic synthetic-but-learnable datasets.

The container is offline (no CIFAR/ImageNet), so the paper's claims are
validated on tasks with a real train/test generalization gap:

- ``make_markov_lm_dataset``: sequences from a fixed random 2nd-order
  Markov chain over the vocabulary. A model must learn the transition
  structure; a finite train set can be memorized, fresh test sequences
  cannot — so test loss measures generalization exactly as the paper's
  test accuracy does.
- ``make_prototype_image_dataset``: Gaussian class prototypes in pixel
  space + per-sample noise + a fraction of label noise ("hard samples",
  §IV-C's memorization discussion). Used by the paper-faithful
  ResNet+BN+SGD pipeline.

Everything is generated from explicit PRNG keys — fully reproducible.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class SyntheticDataset:
    """A finite train split plus a held-out test split."""
    train_inputs: jax.Array
    train_targets: jax.Array
    test_inputs: jax.Array
    test_targets: jax.Array
    kind: str = "lm"  # "lm" | "image"

    @property
    def n_train(self) -> int:
        return int(self.train_inputs.shape[0])

    @property
    def n_test(self) -> int:
        return int(self.test_inputs.shape[0])


def _sample_markov(key, trans, n_seq: int, seq_len: int) -> jax.Array:
    """Sample ``n_seq`` sequences from a 1st-order chain ``trans``(v, v)."""
    vocab = trans.shape[0]
    k0, k1 = jax.random.split(key)
    first = jax.random.randint(k0, (n_seq,), 0, vocab)
    logits = jnp.log(trans + 1e-9)

    def step(prev, k):
        nxt = jax.random.categorical(k, logits[prev])
        return nxt, nxt

    keys = jax.random.split(k1, seq_len - 1)
    _, rest = jax.lax.scan(step, first, keys)
    return jnp.concatenate([first[None], rest], axis=0).T  # (n_seq, seq_len)


def make_markov_lm_dataset(vocab: int = 256, seq_len: int = 128,
                           n_train: int = 2048, n_test: int = 512,
                           seed: int = 0, concentration: float = 0.3
                           ) -> SyntheticDataset:
    """LM dataset: inputs are tokens, targets are next tokens."""
    key = jax.random.key(seed)
    kt, ktr, kte = jax.random.split(key, 3)
    # Sparse-ish random transition matrix: low concentration -> low entropy
    # -> learnable structure with an achievable-but-nonzero loss floor.
    alpha = jnp.full((vocab,), concentration)
    trans = jax.random.dirichlet(kt, alpha, shape=(vocab,))
    train = _sample_markov(ktr, trans, n_train, seq_len + 1)
    test = _sample_markov(kte, trans, n_test, seq_len + 1)
    return SyntheticDataset(
        train_inputs=train[:, :-1], train_targets=train[:, 1:],
        test_inputs=test[:, :-1], test_targets=test[:, 1:], kind="lm")


def make_prototype_image_dataset(n_classes: int = 10, image_size: int = 16,
                                 channels: int = 3, n_train: int = 4096,
                                 n_test: int = 1024, noise: float = 0.7,
                                 label_noise: float = 0.05, seed: int = 0
                                 ) -> SyntheticDataset:
    """Image classification with Gaussian class prototypes + label noise."""
    key = jax.random.key(seed)
    kp, ktr, kte, kl = jax.random.split(key, 4)
    shape = (image_size, image_size, channels)
    protos = jax.random.normal(kp, (n_classes,) + shape)

    def split(k, n):
        ky, kx = jax.random.split(k)
        y = jax.random.randint(ky, (n,), 0, n_classes)
        x = protos[y] + noise * jax.random.normal(kx, (n,) + shape)
        return x.astype(jnp.float32), y

    xtr, ytr = split(ktr, n_train)
    xte, yte = split(kte, n_test)
    if label_noise > 0:
        k1, k2 = jax.random.split(kl)
        flip = jax.random.bernoulli(k1, label_noise, (n_train,))
        rand_y = jax.random.randint(k2, (n_train,), 0, n_classes)
        ytr = jnp.where(flip, rand_y, ytr)  # "hard samples" to memorize
    return SyntheticDataset(train_inputs=xtr, train_targets=ytr,
                            test_inputs=xte, test_targets=yte, kind="image")
