from repro.data.synthetic import (
    SyntheticDataset,
    make_markov_lm_dataset,
    make_prototype_image_dataset,
)
from repro.data.pipeline import DataPipeline, replica_batch_indices
