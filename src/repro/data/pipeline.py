"""Sharded data pipeline with per-replica sampling orders.

The paper (Alg. 1, line 6) requires each of the K replicas to see batches
"with different sampling orders". We realize this inside jit: for replica k
at step i, batch indices come from a per-(replica, epoch) permutation of
the finite train set, so within an epoch each replica does
without-replacement SGD in its own order — exactly torch's
``DataLoader(shuffle=True)`` per process.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.data.synthetic import SyntheticDataset


def replica_batch_indices(key: jax.Array, replica_id, step,
                          n_train: int, batch_size: int) -> jax.Array:
    """Deterministic without-replacement batch indices for one replica.

    ``replica_id`` and ``step`` may be traced scalars, so this works both
    under vmap over replicas and inside a scanned training loop.
    """
    steps_per_epoch = max(n_train // batch_size, 1)
    epoch = step // steps_per_epoch
    pos = step % steps_per_epoch
    k = jax.random.fold_in(jax.random.fold_in(key, replica_id), epoch)
    perm = jax.random.permutation(k, n_train)
    return jax.lax.dynamic_slice_in_dim(perm, pos * batch_size, batch_size)


@dataclasses.dataclass
class DataPipeline:
    """Batches a :class:`SyntheticDataset` for K replicas."""
    dataset: SyntheticDataset
    batch_size: int
    n_replicas: int = 1
    seed: int = 0

    def __post_init__(self):
        self._key = jax.random.key(self.seed)

    @property
    def steps_per_epoch(self) -> int:
        return max(self.dataset.n_train // self.batch_size, 1)

    def replica_batch(self, replica_id, step):
        """(inputs, targets) for one replica at one step; jit-safe."""
        idx = replica_batch_indices(self._key, replica_id, step,
                                    self.dataset.n_train, self.batch_size)
        return (jnp.take(self.dataset.train_inputs, idx, axis=0),
                jnp.take(self.dataset.train_targets, idx, axis=0))

    def stacked_batch(self, step):
        """Batches for all K replicas, stacked on axis 0: (K, B, ...)."""
        ids = jnp.arange(self.n_replicas)
        return jax.vmap(lambda r: self.replica_batch(r, step))(ids)

    def eval_batches(self, batch_size: int | None = None):
        """Host-side iterator over the test split (drops the remainder)."""
        bs = batch_size or self.batch_size
        n = (self.dataset.n_test // bs) * bs
        for i in range(0, n, bs):
            yield (self.dataset.test_inputs[i:i + bs],
                   self.dataset.test_targets[i:i + bs])
