from repro.train.trainer import Task, TrainConfig, Trainer, lm_task
