"""Training orchestration: HWA + every paper baseline under one loop.

Methods (paper §V experiment set):
  base      — SGD, step-decay LR ×0.1 every ``decay_every`` (paper Baseline)
  ca        — SGD, cosine LR over the whole budget
  swa       — offline WA: Stage I regular LR, Stage II constant sampling LR,
              running average of every-H checkpoints (SWA [15])
  ema       — exponential moving average of weights
  lookahead — Lookahead optimizer [32]
  sam       — sharpness-aware minimization [35]
  online    — low-frequency online WA only (HWA with I=1)
  pmsgd     — parallel mini-batch SGD (sync every step, K replicas)
  hwa       — the full method (K replicas, period H, window I)

The trainer evaluates the *method-appropriate* weights (W̿ for HWA, the
running average for SWA/EMA, slow weights for Lookahead) and tracks the
best snapshot (paper §IV-C early-stopping remark).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.baselines import (ema_init, ema_update, lookahead_init,
                                  lookahead_update, sam_gradient, swa_init,
                                  swa_params, swa_update)
from repro.core.hwa import HWAConfig, HWAState, hwa_init, hwa_inner_step, \
    hwa_sync
from repro.data.pipeline import DataPipeline
from repro.models.registry import LM
from repro.optim import (adamw, apply_updates, cosine_schedule, sgd,
                         step_decay_schedule, swa_constant_schedule)

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    method: str = "hwa"
    total_steps: int = 1000
    batch_size: int = 16
    base_lr: float = 0.1
    optimizer: str = "sgd"          # sgd | adamw
    momentum: float = 0.9
    weight_decay: float = 5e-4
    decay_every_frac: float = 0.33  # step-decay interval (method=base)
    hwa: HWAConfig = HWAConfig()
    swa_start_frac: float = 0.75
    swa_lr: float = 0.05
    ema_decay: float = 0.99
    lookahead_k: int = 5
    lookahead_alpha: float = 0.5
    sam_rho: float = 0.05
    eval_every: int = 0             # 0 → every sync cycle
    seed: int = 0
    # preemption-safe checkpointing (resilience.CheckpointSession); the
    # data pipeline and schedules are stateless functions of (seed, step),
    # so restoring the saved state + step resumes bit-exactly
    checkpoint_dir: str = ""        # "" → no checkpointing
    checkpoint_every: int = 0       # steps between saves (0 → off)
    checkpoint_keep: int = 3        # retained checkpoints
    resume: bool = False            # restart from the newest intact save


@dataclasses.dataclass
class Task:
    init: Callable[[jax.Array], PyTree]
    loss_fn: Callable[[PyTree, Any], tuple[jax.Array, dict]]
    pipeline: DataPipeline
    name: str = "task"


def lm_task(lm: LM, pipeline: DataPipeline, name: str | None = None) -> Task:
    def loss_fn(params, batch):
        if isinstance(batch, tuple):
            batch = {"tokens": batch[0], "targets": batch[1]}
        return lm.loss(params, batch)
    return Task(init=lm.init, loss_fn=loss_fn, pipeline=pipeline,
                name=name or lm.cfg.name)


def _make_optimizer(tc: TrainConfig):
    if tc.optimizer == "adamw":
        return adamw(weight_decay=tc.weight_decay)
    return sgd(momentum=tc.momentum, weight_decay=tc.weight_decay)


def _make_schedule(tc: TrainConfig):
    if tc.method == "base":
        return step_decay_schedule(
            tc.base_lr, max(int(tc.total_steps * tc.decay_every_frac), 1))
    sched = cosine_schedule(tc.base_lr, tc.total_steps)
    if tc.method == "swa":
        return swa_constant_schedule(
            sched, int(tc.total_steps * tc.swa_start_frac), tc.swa_lr)
    return sched


class Trainer:
    def __init__(self, task: Task, tc: TrainConfig):
        self.task = task
        self.tc = tc
        self.optimizer = _make_optimizer(tc)
        self.schedule = _make_schedule(tc)
        self.is_parallel = tc.method in ("hwa", "online", "pmsgd")
        if tc.method == "online":
            self.hwa_cfg = dataclasses.replace(tc.hwa, window=1)
        elif tc.method == "pmsgd":
            self.hwa_cfg = dataclasses.replace(tc.hwa, sync_period=1, window=1)
        else:
            self.hwa_cfg = tc.hwa
        self.sync_period = self.hwa_cfg.sync_period or \
            task.pipeline.steps_per_epoch
        if tc.method == "pmsgd":
            self.sync_period = 1
        self._build_steps()

    # -------------------------------------------------------- jit steps

    def _build_steps(self):
        task, tc, opt = self.task, self.tc, self.optimizer
        loss_fn, sched = task.loss_fn, self.schedule

        @jax.jit
        def hwa_step(state: HWAState, step):
            batches = task.pipeline.stacked_batch(step)
            return hwa_inner_step(self.hwa_cfg, state, batches, loss_fn,
                                  opt, sched(step))

        @jax.jit
        def sync_step(state: HWAState):
            return hwa_sync(self.hwa_cfg, state)

        @jax.jit
        def single_step(params, opt_state, step):
            batch = task.pipeline.replica_batch(0, step)
            if tc.method == "sam":
                (loss, metrics), grads = sam_gradient(loss_fn, params, batch,
                                                      rho=tc.sam_rho)
            else:
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, batch)
            updates, opt_state = opt.update(grads, opt_state, params,
                                            sched(step))
            return apply_updates(params, updates), opt_state, loss, metrics

        @jax.jit
        def eval_batch(params, inputs, targets):
            loss, metrics = loss_fn(params, {"tokens": inputs,
                                             "targets": targets})
            return metrics["loss"], metrics.get("acc", jnp.zeros(()))

        self._hwa_step, self._sync_step = hwa_step, sync_step
        self._single_step, self._eval_batch = single_step, eval_batch
        self._swa_update = jax.jit(swa_update)
        self._ema_update = jax.jit(ema_update)
        self._lookahead_update = jax.jit(lookahead_update)

    # ------------------------------------------------------------ eval

    def evaluate(self, params) -> dict:
        losses, accs = [], []
        for inputs, targets in self.task.pipeline.eval_batches():
            l, a = self._eval_batch(params, inputs, targets)
            losses.append(float(l))
            accs.append(float(a))
        return {"test_loss": sum(losses) / max(len(losses), 1),
                "test_acc": sum(accs) / max(len(accs), 1)}

    # ------------------------------------------------------------- run

    def run(self, eval_views: bool = False, log: bool = False) -> dict:
        tc = self.tc
        key = jax.random.key(tc.seed)
        params = self.task.init(key)
        history = []
        best = {"test_acc": -1.0, "test_loss": float("inf"), "step": 0}
        eval_every = tc.eval_every or self.sync_period

        def record(step, train_loss, eval_params, views=None):
            rec = {"step": step, "train_loss": float(train_loss)}
            rec.update(self.evaluate(eval_params))
            if views:
                for name, p in views.items():
                    v = self.evaluate(p)
                    rec[f"{name}_loss"] = v["test_loss"]
                    rec[f"{name}_acc"] = v["test_acc"]
            history.append(rec)
            if rec["test_acc"] > best["test_acc"]:
                best.update({"test_acc": rec["test_acc"],
                             "test_loss": rec["test_loss"], "step": step})
            if log:
                print(f"[{self.task.name}/{tc.method}] step {step} "
                      f"train {rec['train_loss']:.4f} "
                      f"test {rec['test_loss']:.4f} acc {rec['test_acc']:.4f}")
            return rec

        session = None
        if tc.checkpoint_dir and tc.checkpoint_every > 0:
            from repro.resilience.session import CheckpointSession
            session = CheckpointSession(tc.checkpoint_dir,
                                        keep=tc.checkpoint_keep)
        if session is None and tc.resume:
            raise ValueError("resume=True needs checkpoint_dir and "
                             "checkpoint_every set")
        if session is not None and not self.is_parallel:
            raise ValueError("checkpointing covers the K-replica methods "
                             f"(hwa/online/pmsgd), not {tc.method!r}")

        if self.is_parallel:
            state = hwa_init(self.hwa_cfg, params, self.optimizer)
            train_loss = jnp.zeros(())
            start_step = 0
            if session is not None and tc.resume:
                latest = session.latest_intact()
                if latest is not None:
                    state = session.load(latest, "hwa", state)
                    meta = session.meta(latest)
                    start_step = int(meta["step"])
                    history = list(meta.get("history", []))
                    best.update(meta.get("best", {}))
                    train_loss = jnp.asarray(meta.get("train_loss", 0.0))
                    if log:
                        print(f"[{self.task.name}/{tc.method}] resumed "
                              f"from step {start_step} "
                              f"({session.step_dir(start_step)})")
            for step in range(start_step, tc.total_steps):
                state, metrics = self._hwa_step(state, step)
                train_loss = metrics["loss"]
                if (step + 1) % self.sync_period == 0:
                    views = None
                    if eval_views:
                        # snapshot BEFORE the sync resets inner <- outer
                        views = {
                            "inner": jax.tree.map(lambda x: x[0],
                                                  state.inner),
                            "outer": jax.tree.map(
                                lambda x: jnp.mean(x, 0).astype(x.dtype),
                                state.inner),
                        }
                    state, _ = self._sync_step(state)
                    if ((step + 1) // self.sync_period) % max(
                            eval_every // self.sync_period, 1) == 0:
                        record(step + 1, train_loss, state.wa, views)
                if session is not None and \
                        (step + 1) % tc.checkpoint_every == 0:
                    # HWAState is one registered-dataclass pytree (the
                    # WindowState layout rides in its meta fields), so a
                    # single named tree round-trips everything bit-exactly
                    session.save(step + 1, {"hwa": state},
                                 meta={"step": step + 1, "history": history,
                                       "best": dict(best),
                                       "train_loss": float(train_loss)})
            final_params = state.wa
        else:
            opt_state = self.optimizer.init(params)
            swa_state = swa_init(params) if tc.method == "swa" else None
            ema_state = (ema_init(params, tc.ema_decay)
                         if tc.method == "ema" else None)
            la_state = (lookahead_init(params, tc.lookahead_k,
                                       tc.lookahead_alpha)
                        if tc.method == "lookahead" else None)
            swa_start = int(tc.total_steps * tc.swa_start_frac)
            swa_period = self.task.pipeline.steps_per_epoch
            train_loss = jnp.zeros(())
            for step in range(tc.total_steps):
                params, opt_state, train_loss, _ = self._single_step(
                    params, opt_state, step)
                if tc.method == "ema":
                    ema_state = self._ema_update(ema_state, params)
                if tc.method == "lookahead" and (step + 1) % tc.lookahead_k == 0:
                    la_state, params = self._lookahead_update(la_state, params)
                if (tc.method == "swa" and step + 1 > swa_start
                        and (step + 1) % swa_period == 0):
                    swa_state = self._swa_update(swa_state, params)
                if (step + 1) % eval_every == 0:
                    eval_params = params
                    if tc.method == "swa" and int(swa_state.n) > 0:
                        eval_params = swa_params(swa_state, params)
                    elif tc.method == "ema":
                        eval_params = jax.tree.map(
                            lambda a, p: a.astype(p.dtype),
                            ema_state.avg, params)
                    record(step + 1, train_loss, eval_params)
            final_params = params
            if tc.method == "swa" and int(swa_state.n) > 0:
                final_params = swa_params(swa_state, params)
            elif tc.method == "ema":
                final_params = jax.tree.map(lambda a, p: a.astype(p.dtype),
                                            ema_state.avg, params)

        final = self.evaluate(final_params)
        return {"history": history, "best": best, "final": final,
                "params": final_params}
