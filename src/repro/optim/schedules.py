"""Learning-rate schedules.

All schedules are pure callables ``step -> lr`` (jnp-friendly, so they can
be traced inside the compiled train step). The paper uses:

- step decay ×0.1 / 60 epochs   (its "Baseline")
- cosine over the whole budget  (its "CA" and the schedule under HWA)
- constant / cyclic sampling LR (what SWA needs in Stage II — implemented
  to reproduce the paper's Fig. 2 LR-sensitivity analysis)
"""
from __future__ import annotations

import jax.numpy as jnp


def constant_schedule(lr: float):
    def sched(step):
        return jnp.asarray(lr, jnp.float32) + 0.0 * step
    return sched


def cosine_schedule(base_lr: float, total_steps: int, final_lr: float = 0.0):
    def sched(step):
        frac = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return final_lr + (base_lr - final_lr) * cos
    return sched


def step_decay_schedule(base_lr: float, decay_every: int, gamma: float = 0.1):
    def sched(step):
        k = jnp.floor(step / max(decay_every, 1))
        return base_lr * gamma ** k
    return sched


def warmup_cosine_schedule(base_lr: float, warmup_steps: int, total_steps: int,
                           final_lr: float = 0.0):
    cos = cosine_schedule(base_lr, max(total_steps - warmup_steps, 1), final_lr)
    def sched(step):
        warm = base_lr * step / max(warmup_steps, 1)
        return jnp.where(step < warmup_steps, warm, cos(step - warmup_steps))
    return sched


def cyclic_schedule(lr_max: float, lr_min: float, cycle_steps: int):
    """SWA's cyclical sampling LR: linear saw from lr_max down to lr_min."""
    def sched(step):
        t = jnp.mod(step, cycle_steps) / max(cycle_steps - 1, 1)
        return lr_max - (lr_max - lr_min) * t
    return sched


def swa_constant_schedule(base_sched, swa_start_step: int, swa_lr: float):
    """The paper's offline-WA Stage I/II split: regular schedule until
    ``swa_start_step``, then a constant sampling LR (Fig. 2)."""
    def sched(step):
        return jnp.where(step < swa_start_step, base_sched(step),
                         jnp.asarray(swa_lr, jnp.float32))
    return sched
