"""Minimal functional optimizer API (built from scratch — no optax).

An ``Optimizer`` is a pair of pure functions:

  init(params)                      -> opt_state
  update(grads, opt_state, params, lr) -> (updates, opt_state)

``updates`` are *additive* deltas: new_params = params + updates.
Learning-rate schedules are plain callables ``step -> lr`` evaluated by the
training loop and passed in as a traced scalar, so one compiled step works
for the whole schedule.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[..., tuple[PyTree, PyTree]]
    name: str = "optimizer"


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)
