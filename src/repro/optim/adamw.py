"""AdamW with fp32 moments (decoupled weight decay)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.base import Optimizer


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        count = state["count"] + 1
        c = count.astype(jnp.float32)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        mhat_scale = 1.0 / (1.0 - b1 ** c)
        vhat_scale = 1.0 / (1.0 - b2 ** c)

        def upd(m_, v_, p):
            step = m_ * mhat_scale / (jnp.sqrt(v_ * vhat_scale) + eps)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return -lr * step

        updates = jax.tree.map(upd, m, v, params)
        return updates, {"m": m, "v": v, "count": count}

    return Optimizer(init=init, update=update, name="adamw")
