"""SGD with momentum / Nesterov / decoupled weight decay.

This is the optimizer used throughout the paper (momentum 0.9, weight decay
5e-4, cosine or step LR decay).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.base import Optimizer


def sgd(momentum: float = 0.0, nesterov: bool = False,
        weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return {}
        return {"mu": jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)}

    def update(grads, state, params, lr):
        # Coupled L2 weight decay (the paper's torch-SGD semantics:
        # grad <- grad + wd * param).
        if weight_decay:
            grads = jax.tree.map(
                lambda g, p: g + weight_decay * p.astype(g.dtype), grads, params)
        if momentum == 0.0:
            updates = jax.tree.map(lambda g: -lr * g, grads)
            return updates, state
        mu = jax.tree.map(
            lambda m, g: momentum * m + g.astype(jnp.float32), state["mu"], grads)
        if nesterov:
            step_dir = jax.tree.map(
                lambda m, g: momentum * m + g.astype(jnp.float32), mu, grads)
        else:
            step_dir = mu
        updates = jax.tree.map(lambda d: (-lr * d), step_dir)
        return updates, {"mu": mu}

    return Optimizer(init=init, update=update, name="sgd")
