from repro.optim.base import Optimizer, apply_updates
from repro.optim.sgd import sgd
from repro.optim.adamw import adamw
from repro.optim.schedules import (
    constant_schedule,
    cosine_schedule,
    step_decay_schedule,
    warmup_cosine_schedule,
    cyclic_schedule,
    swa_constant_schedule,
)
