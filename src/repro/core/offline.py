"""Offline WA module (paper §III-B, Algorithm 2): slide-window averaging.

    W̿_e = (1/I) Σ_{t=e-I+1..e} W̄_t

Three implementations with one state container:

- **ring** (exact): a ring buffer of the last I outer weights + a running
  f32 sum. Update cost is O(params) HBM traffic independent of I; memory is
  I× params *per shard*. The fused Pallas kernel (`repro.kernels.wa_update`)
  cuts the update from 6 reads + 3 writes to 3 reads + 3 writes
  (ring slot + total + new in; ring slot + total + avg out), one pass.
- **streaming** (beyond paper, O(1) memory): a windowed running mean
  ``wa += (outer - wa)/min(count, I)`` — SWA's running average whose gain
  is clamped at 1/I, an EMA-like approximation of the slide window for
  models too large to buffer I copies of.
- **sparse** stride (paper §III-B remark): only every ``stride``-th cycle
  enters the window (handled by the caller skipping updates).

**Packed state.** The window state is held PERSISTENTLY PACKED
(``repro.common.packing``): ``ring`` is one ``(I, P)`` buffer and
``total`` one ``(P,)`` buffer over the whole parameter set, packed once at
:func:`window_init` and never per update. The update is therefore O(1)
kernel launches regardless of leaf count, with zero per-call padding and
real buffer donation; only the final W̿ is unpacked back to leaf views.
Packing is layout-only, so results are bit-identical (0 ULP) to the
per-leaf formulation. On multi-device meshes the sync bundles use a
SHARD-AWARE layout (``PackSpec.shards > 1``) whose ``padded`` size
differs — always build buffers from the spec the state actually carries
(``state.spec`` / ``bundle.pack_spec``), never a freshly computed
default one (docs/ARCHITECTURE.md describes the layout).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.common.packing import PackSpec, pack, pack_spec, unpack

PyTree = Any


@dataclasses.dataclass
class WindowState:
    ring: jax.Array | None   # (I, P) packed outer weights (ring mode),
                             # stored in spec.ring_dtype (f32 default)
    total: jax.Array         # (P,) f32 running sum (ring) / mean (streaming)
    count: jax.Array         # filled slots (≤ I)
    next_idx: jax.Array      # ring write cursor
    window: int
    kind: str = "ring"       # ring | streaming
    spec: PackSpec | None = None   # static packed layout of the param tree
    comp: jax.Array | None = None    # (P,) f32 Kahan compensation of the
                                     # total (compressed rings only)
    scales: jax.Array | None = None  # (I, P // align) f32 per-block fp8
                                     # scales (fp8 rings only)


jax.tree_util.register_dataclass(
    WindowState,
    data_fields=["ring", "total", "count", "next_idx", "comp", "scales"],
    meta_fields=["window", "kind", "spec"])


def window_init(params_like: PyTree, window: int, kind: str = "ring",
                ring_dtype=jnp.float32) -> WindowState:
    """Pack once; every later update runs on the packed buffers in place.

    ``ring_dtype`` (dtype or ``f32``/``bf16``/``fp8`` token) selects the
    compressed WA state: the ring is stored narrow, the f32 total gains a
    Kahan compensation buffer, and an fp8 ring gets per-block scales
    (``common.quant``). The f32 default allocates neither — its state and
    arithmetic are bit-identical to the pre-compression path.
    """
    from repro.common.quant import is_compressed, needs_scales, wa_dtype
    ring_dtype = wa_dtype(ring_dtype)
    spec = pack_spec(params_like)
    ring = comp = scales = None
    if kind == "ring":
        ring = jnp.zeros((window, spec.padded), ring_dtype)
        if is_compressed(ring_dtype):
            spec = spec.with_ring_dtype(ring_dtype)
            comp = jnp.zeros((spec.padded,), jnp.float32)
            if needs_scales(ring_dtype):
                scales = jnp.ones((window, spec.scale_blocks), jnp.float32)
    return WindowState(ring=ring, total=jnp.zeros((spec.padded,), jnp.float32),
                       count=jnp.zeros((), jnp.int32),
                       next_idx=jnp.zeros((), jnp.int32),
                       window=window, kind=kind, spec=spec,
                       comp=comp, scales=scales)


def window_update(state: WindowState, outer: PyTree, *,
                  use_kernel: bool = False) -> tuple[WindowState, PyTree]:
    """Push W̄_e; return (new state, current W̿_e). jit-safe.

    One fused op over the whole packed parameter set (one ``pallas_call``
    when ``use_kernel``); only W̿ is unpacked, the ring never is.
    """
    if state.kind == "streaming":
        return streaming_window_update(state, outer)
    new_state, avg = window_update_packed(
        state, pack(outer, state.spec), use_kernel=use_kernel)
    return new_state, unpack(avg, state.spec, like=outer)


def window_update_packed(state: WindowState, new: jax.Array, *,
                         use_kernel: bool = False
                         ) -> tuple[WindowState, jax.Array]:
    """Packed-in/packed-out window update: ``new`` is a (P,) f32 buffer;
    returns (new state, packed W̿). The no-unpack hot path for callers
    that already hold packed outer weights (e.g. the fused sync)."""
    if state.kind == "streaming":
        n = jnp.minimum(state.count + 1, state.window).astype(jnp.float32)
        total = state.total + (new - state.total) / n
        return WindowState(
            ring=None, total=total,
            count=jnp.minimum(state.count + 1, state.window),
            next_idx=state.next_idx, window=state.window,
            kind="streaming", spec=state.spec), total
    I = state.window
    idx = state.next_idx
    full_flag = (state.count >= I).astype(jnp.float32)
    new_count = jnp.minimum(state.count + 1, I)
    inv_count = 1.0 / new_count.astype(jnp.float32)

    comp, scales = state.comp, state.scales
    if state.ring.dtype == jnp.float32:
        # f32 default: the pre-compression path, bit-identical (no comp)
        if use_kernel:
            from repro.kernels import ops as kops
            ring, total, avg = kops.wa_window_update_packed(
                state.ring, state.total, new, idx, full_flag, inv_count)
        else:
            from repro.kernels.ref import wa_window_update_ref
            ring, total, avg = wa_window_update_ref(
                state.ring, state.total, new, idx, full_flag, inv_count)
    else:
        # compressed ring: dequantized-value accounting + Kahan total
        if comp is None:
            comp = jnp.zeros_like(state.total)
        if use_kernel and state.ring.dtype == jnp.bfloat16:
            from repro.kernels import ops as kops
            ring, total, comp, avg = kops.wa_window_update_packed_c(
                state.ring, state.total, comp, new, idx, full_flag,
                inv_count)
        else:
            from repro.kernels.ref import wa_window_update_c_ref
            ring, scales, total, comp, avg = wa_window_update_c_ref(
                state.ring, scales, state.total, comp, new, idx,
                full_flag, inv_count)

    new_state = WindowState(ring=ring, total=total, count=new_count,
                            next_idx=jnp.mod(idx + 1, I), window=I,
                            kind=state.kind, spec=state.spec,
                            comp=comp, scales=scales)
    return new_state, avg


def streaming_window_update(state: WindowState, outer: PyTree
                            ) -> tuple[WindowState, PyTree]:
    new_state, total = window_update_packed(state, pack(outer, state.spec))
    return new_state, unpack(total, state.spec, like=outer)


def window_average_packed(state: WindowState) -> jax.Array:
    """Current W̿ as the packed (P,) f32 buffer (no unpacking)."""
    if state.kind == "streaming":
        return state.total
    denom = jnp.maximum(state.count, 1).astype(jnp.float32)
    return state.total / denom


def window_average(state: WindowState, like: PyTree) -> PyTree:
    """Current W̿ in the dtype of ``like``."""
    return unpack(window_average_packed(state), state.spec, like=like)
