"""Offline WA module (paper §III-B, Algorithm 2): slide-window averaging.

    W̿_e = (1/I) Σ_{t=e-I+1..e} W̄_t

Three implementations with one state container:

- **ring** (exact): a ring buffer of the last I outer weights + a running
  f32 sum. Update cost is O(params) HBM traffic independent of I; memory is
  I× params *per shard* (the buffer inherits the params' sharding —
  DESIGN.md §2). The fused Pallas kernel (`repro.kernels.wa_update`) cuts
  the update from 6 reads + 3 writes to 3 reads + 2 writes.
- **streaming** (beyond paper, O(1) memory): a windowed running mean
  ``wa += (outer - wa)/min(count, I)`` — SWA's running average whose gain
  is clamped at 1/I, an EMA-like approximation of the slide window for
  models too large to buffer I copies of.
- **sparse** stride (paper §III-B remark): only every ``stride``-th cycle
  enters the window (handled by the caller skipping updates).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.common.pytree import tree_scale, tree_zeros_like

PyTree = Any


@dataclasses.dataclass
class WindowState:
    ring: PyTree | None      # (I, ...) stacked outer weights (ring mode)
    total: PyTree            # f32 running sum (ring) or running mean (streaming)
    count: jax.Array         # filled slots (≤ I)
    next_idx: jax.Array      # ring write cursor
    window: int
    kind: str = "ring"       # ring | streaming


jax.tree_util.register_dataclass(
    WindowState, data_fields=["ring", "total", "count", "next_idx"],
    meta_fields=["window", "kind"])


def window_init(params_like: PyTree, window: int, kind: str = "ring"
                ) -> WindowState:
    f32 = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params_like)
    ring = None
    if kind == "ring":
        ring = jax.tree.map(
            lambda x: jnp.zeros((window,) + x.shape, jnp.float32), params_like)
    return WindowState(ring=ring, total=f32,
                       count=jnp.zeros((), jnp.int32),
                       next_idx=jnp.zeros((), jnp.int32),
                       window=window, kind=kind)


def window_update(state: WindowState, outer: PyTree, *,
                  use_kernel: bool = False) -> tuple[WindowState, PyTree]:
    """Push W̄_e; return (new state, current W̿_e). jit-safe."""
    if state.kind == "streaming":
        return streaming_window_update(state, outer)
    I = state.window
    idx = state.next_idx
    full_flag = (state.count >= I).astype(jnp.float32)
    new_count = jnp.minimum(state.count + 1, I)
    inv_count = 1.0 / new_count.astype(jnp.float32)

    if use_kernel:
        from repro.kernels import ops as kops

        def upd(ring, total, new):
            return kops.wa_window_update(ring, total, new, idx, full_flag,
                                         inv_count)
    else:
        from repro.kernels.ref import wa_window_update_ref as upd_ref

        def upd(ring, total, new):
            return upd_ref(ring, total, new.astype(jnp.float32), idx,
                           full_flag, inv_count)

    triples = jax.tree.map(upd, state.ring, state.total, outer)
    is_triple = lambda x: isinstance(x, tuple) and len(x) == 3
    new_ring = jax.tree.map(lambda t: t[0], triples, is_leaf=is_triple)
    new_total = jax.tree.map(lambda t: t[1], triples, is_leaf=is_triple)
    wa = jax.tree.map(lambda t, o: t[2].astype(o.dtype), triples, outer,
                      is_leaf=is_triple)

    new_state = WindowState(ring=new_ring, total=new_total, count=new_count,
                            next_idx=jnp.mod(idx + 1, I), window=I,
                            kind=state.kind)
    return new_state, wa


def streaming_window_update(state: WindowState, outer: PyTree
                            ) -> tuple[WindowState, PyTree]:
    n = jnp.minimum(state.count + 1, state.window).astype(jnp.float32)
    new_total = jax.tree.map(
        lambda m, x: m + (x.astype(jnp.float32) - m) / n, state.total, outer)
    new_state = WindowState(ring=None, total=new_total,
                            count=jnp.minimum(state.count + 1, state.window),
                            next_idx=state.next_idx, window=state.window,
                            kind="streaming")
    wa = jax.tree.map(lambda m, x: m.astype(x.dtype), new_total, outer)
    return new_state, wa


def window_average(state: WindowState, like: PyTree) -> PyTree:
    """Current W̿ in the dtype of ``like``."""
    denom = jnp.maximum(state.count, 1).astype(jnp.float32)
    if state.kind == "streaming":
        return jax.tree.map(lambda m, x: m.astype(x.dtype), state.total, like)
    return jax.tree.map(lambda s, x: (s / denom).astype(x.dtype),
                        state.total, like)
