"""BatchNorm-statistics recompute (paper Algorithm 2, line 3).

After forming averaged weights W̿, BN running statistics are invalid (they
belong to no trained model). The standard SWA/HWA fix: one pass over
training data collecting per-batch mean/var under W̿ and averaging them.
Only the paper-faithful ResNet-CIFAR config carries BN; the transformer
archs are RMSNorm/LayerNorm (stateless) — documented no-op (DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.convnet import apply_resnet


def recompute_bn_stats(cfg, params, bn_state_template, batches):
    """Average the batch statistics observed under ``params``.

    ``batches`` is an iterable of input arrays (NHWC). Returns a fresh
    bn_state with mean of batch means and mean of batch vars.
    """
    acc = jax.tree.map(jnp.zeros_like, bn_state_template)
    n = 0

    @jax.jit
    def batch_stats(x):
        # train=True recomputes batch statistics; with BN_MOMENTUM m the
        # new state is m*old + (1-m)*batch, so batch = (new - m*old)/(1-m).
        from repro.models.convnet import BN_MOMENTUM
        _, new_state = apply_resnet(cfg, params, bn_state_template, x,
                                    train=True)
        return jax.tree.map(
            lambda new, old: (new - BN_MOMENTUM * old) / (1.0 - BN_MOMENTUM),
            new_state, bn_state_template)

    for x in batches:
        stats = batch_stats(x)
        acc = jax.tree.map(jnp.add, acc, stats)
        n += 1
    if n == 0:
        return bn_state_template
    return jax.tree.map(lambda a: a / n, acc)
