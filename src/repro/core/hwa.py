"""Hierarchical Weight Averaging — the paper's training framework.

State machine (Algorithms 1 & 2):

  every step   : each of the K replicas takes one optimizer step on its own
                 batch (different sampling orders)           [hwa_inner_step]
  every H steps: W̄_e = mean_k W^k ; every replica ← W̄_e ;
                 slide-window update → W̿_e                   [hwa_sync]

``inner`` state is stacked on a leading K axis (vmap on one device; the
``replica``/``pod`` mesh axis at scale). Special cases: K=1 ∧ I>1 →
slide-window offline WA (generalized SWA); K>1 ∧ I=1 → low-frequency
online WA (local SGD); K=1 ∧ I=1 → plain SGD.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.common.pytree import tree_mean_axis0
from repro.core.offline import WindowState, window_init, window_update
from repro.core.online import broadcast_to_replicas, online_average, \
    online_average_named, replica_divergence
from repro.optim.base import Optimizer, apply_updates

PyTree = Any


@dataclasses.dataclass(frozen=True)
class HWAConfig:
    n_replicas: int = 2          # K (paper Table IV: 2-4; K=2 suffices)
    sync_period: int = 0         # H; 0 → one epoch (paper default H = N/B)
    window: int = 20             # I (paper Fig. 13: {20, 50})
    window_stride: int = 1       # sparse window (§III-B): every J-th cycle
    window_kind: str = "ring"    # ring | streaming (O(1)-memory, beyond paper)
    avg_opt_state: bool = False  # also average optimizer moments at sync
    use_kernels: bool = False    # fused Pallas WA update path


@dataclasses.dataclass
class HWAState:
    inner: PyTree                # (K, ...) stacked replica params
    inner_opt: PyTree            # (K, ...) stacked optimizer state
    window_state: WindowState    # offline module state
    wa: PyTree                   # current W̿ (unstacked)
    cycle: jax.Array             # e — completed synchronization cycles
    step: jax.Array              # i — global optimizer steps taken


jax.tree_util.register_dataclass(
    HWAState,
    data_fields=["inner", "inner_opt", "window_state", "wa", "cycle", "step"],
    meta_fields=[])


def hwa_init(cfg: HWAConfig, params: PyTree, optimizer: Optimizer) -> HWAState:
    """All replicas start from the same initialization (Algorithm 1 line 1
    with a shared init; replicas diverge through data order)."""
    inner = broadcast_to_replicas(params, cfg.n_replicas)
    inner_opt = jax.vmap(optimizer.init)(inner)
    return HWAState(
        inner=inner, inner_opt=inner_opt,
        window_state=window_init(params, cfg.window, cfg.window_kind),
        wa=params, cycle=jnp.zeros((), jnp.int32),
        step=jnp.zeros((), jnp.int32))


def hwa_inner_step(cfg: HWAConfig, state: HWAState, batches: PyTree,
                   loss_fn: Callable, optimizer: Optimizer, lr) -> tuple[HWAState, PyTree]:
    """One SGD step per replica (Algorithm 1 lines 5-7). ``batches`` leaves
    have a leading K axis (different sampling order per replica)."""

    def one(params, opt, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        updates, opt2 = optimizer.update(grads, opt, params, lr)
        return apply_updates(params, updates), opt2, loss, metrics

    inner, inner_opt, losses, metrics = jax.vmap(one)(
        state.inner, state.inner_opt, batches)
    new_state = HWAState(inner=inner, inner_opt=inner_opt,
                         window_state=state.window_state, wa=state.wa,
                         cycle=state.cycle, step=state.step + 1)
    scalar = {k: jnp.mean(v) for k, v in metrics.items()
              if isinstance(v, jax.Array)
              and jnp.issubdtype(v.dtype, jnp.floating) and v.ndim <= 1}
    return new_state, {"loss": jnp.mean(losses),
                       "per_replica_loss": losses, **scalar}


def _window_push(cfg: HWAConfig, outer: PyTree, window_state: WindowState,
                 cycle: jax.Array) -> tuple[WindowState, PyTree, jax.Array]:
    """Shared Algorithm-2 tail of both sync paths: push W̄ into the slide
    window unless the cycle misses ``window_stride`` (sparse window,
    §III-B), with W̿ = W̄ until the first entry exists.

    Returns (window state, W̿_e, incremented cycle counter).
    """
    new_cycle = cycle + 1
    take = jnp.mod(new_cycle - 1, cfg.window_stride) == 0

    def do_update(ws):
        return window_update(ws, outer, use_kernel=cfg.use_kernels)

    def skip_update(ws):
        from repro.core.offline import window_average
        return ws, window_average(ws, like=outer)

    if cfg.window_stride == 1:
        new_ws, wa = do_update(window_state)
    else:
        new_ws, wa = jax.lax.cond(take, do_update, skip_update, window_state)
    first = new_ws.count == 0
    wa = jax.tree.map(lambda w, o: jnp.where(first, o, w), wa, outer)
    return new_ws, wa, new_cycle


def hwa_sync(cfg: HWAConfig, state: HWAState) -> tuple[HWAState, PyTree]:
    """End-of-cycle sync (Algorithm 1 lines 8-12 + Algorithm 2).

    Returns (new state, metrics). The window update is skipped on cycles
    not matching ``window_stride`` (sparse window, §III-B).
    """
    div = replica_divergence(state.inner)
    outer = online_average(state.inner, use_kernel=cfg.use_kernels)
    inner = broadcast_to_replicas(outer, cfg.n_replicas)
    if cfg.avg_opt_state:
        opt_mean = tree_mean_axis0(state.inner_opt)
        inner_opt = broadcast_to_replicas(opt_mean, cfg.n_replicas)
    else:
        inner_opt = state.inner_opt

    window_state, wa, cycle = _window_push(cfg, outer, state.window_state,
                                           state.cycle)
    new_state = HWAState(inner=inner, inner_opt=inner_opt,
                         window_state=window_state, wa=wa,
                         cycle=cycle, step=state.step)
    return new_state, {"replica_divergence": div, "cycle": cycle}


# ------------------------------------------------- mesh-native (per-replica)
#
# The functions below are the *local* view of Algorithms 1 & 2: they see one
# replica's unstacked params and communicate through a named axis (the
# ``replica`` mesh axis under shard_map, or a vmap axis_name on one device).
# The stacked functions above and these local ones compute identical math —
# tests/mesh_hwa_check.py verifies it numerically on a forced-host mesh.


def hwa_local_inner_step(params: PyTree, opt_state: PyTree, batch: PyTree,
                         loss_fn: Callable, optimizer: Optimizer, lr
                         ) -> tuple[PyTree, PyTree, jax.Array, dict]:
    """One replica's SGD step (Algorithm 1 lines 5-7), no leading K axis.

    Deliberately collective-free over the replica axis: inter-replica
    traffic may only happen in :func:`hwa_sync_named`, every H steps.
    """
    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        params, batch)
    updates, opt2 = optimizer.update(grads, opt_state, params, lr)
    return apply_updates(params, updates), opt2, loss, metrics


def hwa_sync_named(cfg: HWAConfig, params: PyTree,
                   window_state: WindowState, cycle: jax.Array,
                   axis_name: str = "replica"
                   ) -> tuple[PyTree, WindowState, PyTree, jax.Array]:
    """Mesh-native end-of-cycle sync: W̄_e = pmean(W^k) over ``axis_name``
    — the single inter-replica collective of the whole cycle — then the
    slide-window update, computed identically (replica-invariantly) on
    every replica since pmean leaves all replicas with the same W̄_e.

    Returns (restarted params, window state, W̿_e, new cycle counter).
    """
    outer = online_average_named(params, axis_name)
    new_ws, wa, new_cycle = _window_push(cfg, outer, window_state, cycle)
    return outer, new_ws, wa, new_cycle
