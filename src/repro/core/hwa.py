"""Hierarchical Weight Averaging — the paper's training framework.

State machine (Algorithms 1 & 2):

  every step   : each of the K replicas takes one optimizer step on its own
                 batch (different sampling orders)           [hwa_inner_step]
  every H steps: W̄_e = mean_k W^k ; every replica ← W̄_e ;
                 slide-window update → W̿_e                   [hwa_sync]

``inner`` state is stacked on a leading K axis (vmap on one device; the
``replica``/``pod`` mesh axis at scale). Special cases: K=1 ∧ I>1 →
slide-window offline WA (generalized SWA); K>1 ∧ I=1 → low-frequency
online WA (local SGD); K=1 ∧ I=1 → plain SGD.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.common.pytree import tree_mean_axis0
from repro.core.offline import WindowState, window_init
from repro.core.online import broadcast_to_replicas, online_average, \
    online_average_named, replica_divergence
from repro.optim.base import Optimizer, apply_updates

PyTree = Any


@dataclasses.dataclass(frozen=True)
class HWAConfig:
    n_replicas: int = 2          # K (paper Table IV: 2-4; K=2 suffices)
    sync_period: int = 0         # H; 0 → one epoch (paper default H = N/B)
    window: int = 20             # I (paper Fig. 13: {20, 50})
    window_stride: int = 1       # sparse window (§III-B): every J-th cycle
    window_kind: str = "ring"    # ring | streaming (O(1)-memory, beyond paper)
    avg_opt_state: bool = False  # also average optimizer moments at sync
    use_kernels: bool = False    # fused Pallas WA update path
    outer_every: int = 1         # H₂, the two-level sync tree's outer
                                 # period: every H steps pods average
                                 # INTERNALLY; only every H·H₂ steps does
                                 # the cross-pod all-reduce + window push
                                 # run (launch/sync/topology.py TwoLevel).
                                 # 1 ≡ flat sync (every sync is global).
    resilient: bool = False      # elastic membership: exclude NaN'd /
                                 # diverged replicas from the K-mean via
                                 # an alive-mask with renormalized
                                 # 1/K_alive (bitwise identical to the
                                 # plain mean when all alive); the dead
                                 # replica restarts from W̄ with a fresh
                                 # optimizer (repro.resilience.health).
    max_param_rms: float | None = None
                                 # resilient-only divergence probe: a
                                 # replica whose overall parameter RMS
                                 # exceeds this is quarantined even if
                                 # finite (approximate on the packed
                                 # path — padding/replication counted;
                                 # None = finiteness check only).


@dataclasses.dataclass
class HWAState:
    inner: PyTree                # (K, ...) stacked replica params
    inner_opt: PyTree            # (K, ...) stacked optimizer state
    window_state: WindowState    # offline module state
    wa: PyTree                   # current W̿ (unstacked)
    cycle: jax.Array             # e — completed synchronization cycles
    step: jax.Array              # i — global optimizer steps taken


jax.tree_util.register_dataclass(
    HWAState,
    data_fields=["inner", "inner_opt", "window_state", "wa", "cycle", "step"],
    meta_fields=[])


def hwa_init(cfg: HWAConfig, params: PyTree, optimizer: Optimizer,
             ring_dtype=jnp.float32) -> HWAState:
    """All replicas start from the same initialization (Algorithm 1 line 1
    with a shared init; replicas diverge through data order).

    ``ring_dtype`` (dtype or ``f32``/``bf16``/``fp8`` token) selects the
    compressed slide-window state (``core.offline.window_init``); the f32
    default is bit-identical to the pre-compression path."""
    inner = broadcast_to_replicas(params, cfg.n_replicas)
    inner_opt = jax.vmap(optimizer.init)(inner)
    return HWAState(
        inner=inner, inner_opt=inner_opt,
        window_state=window_init(params, cfg.window, cfg.window_kind,
                                 ring_dtype=ring_dtype),
        wa=params, cycle=jnp.zeros((), jnp.int32),
        step=jnp.zeros((), jnp.int32))


def hwa_inner_step(cfg: HWAConfig, state: HWAState, batches: PyTree,
                   loss_fn: Callable, optimizer: Optimizer, lr) -> tuple[HWAState, PyTree]:
    """One SGD step per replica (Algorithm 1 lines 5-7). ``batches`` leaves
    have a leading K axis (different sampling order per replica)."""

    def one(params, opt, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        updates, opt2 = optimizer.update(grads, opt, params, lr)
        return apply_updates(params, updates), opt2, loss, metrics

    inner, inner_opt, losses, metrics = jax.vmap(one)(
        state.inner, state.inner_opt, batches)
    new_state = HWAState(inner=inner, inner_opt=inner_opt,
                         window_state=state.window_state, wa=state.wa,
                         cycle=state.cycle, step=state.step + 1)
    scalar = {k: jnp.mean(v) for k, v in metrics.items()
              if isinstance(v, jax.Array)
              and jnp.issubdtype(v.dtype, jnp.floating) and v.ndim <= 1}
    return new_state, {"loss": jnp.mean(losses),
                       "per_replica_loss": losses, **scalar}


def window_push_packed(cfg: HWAConfig, new_buf: jax.Array,
                       window_state: WindowState, cycle: jax.Array,
                       use_kernel: bool | None = None
                       ) -> tuple[WindowState, jax.Array, jax.Array]:
    """Packed-in/packed-out Algorithm-2 tail: push the packed W̄ buffer
    into the slide window unless the cycle misses ``window_stride``
    (sparse window, §III-B), with W̿ = W̄ until the first entry exists.

    Returns (window state, packed W̿_e, incremented cycle counter). Keeps
    everything in the packed (P,) layout so callers control when (and
    under what sharding) the final unpack happens. ``use_kernel``
    overrides ``cfg.use_kernels``; on multi-device meshes kernels are
    only safe inside a fully-manual shard_map on local buffer slices
    (``launch.sync.packed._local_packed_sync``) — a bare Pallas call is opaque
    to the GSPMD partitioner, which would run it per-shard with
    global-shape semantics and corrupt values.
    """
    from repro.core.offline import window_average_packed, \
        window_update_packed

    use_kernel = cfg.use_kernels if use_kernel is None else use_kernel
    new_cycle = cycle + 1
    take = jnp.mod(new_cycle - 1, cfg.window_stride) == 0

    def do_update(ws):
        return window_update_packed(ws, new_buf, use_kernel=use_kernel)

    def skip_update(ws):
        return ws, window_average_packed(ws)

    if cfg.window_stride == 1:
        new_ws, avg = do_update(window_state)
    else:
        new_ws, avg = jax.lax.cond(take, do_update, skip_update,
                                   window_state)
    avg = jnp.where(new_ws.count == 0, new_buf, avg)
    return new_ws, avg, new_cycle


def _window_push(cfg: HWAConfig, outer: PyTree, window_state: WindowState,
                 cycle: jax.Array) -> tuple[WindowState, PyTree, jax.Array]:
    """Tree-level wrapper of :func:`window_push_packed`: packs W̄ once,
    unpacks only the final W̿."""
    from repro.common.packing import pack, unpack

    new_ws, avg, new_cycle = window_push_packed(
        cfg, pack(outer, window_state.spec), window_state, cycle)
    return new_ws, unpack(avg, window_state.spec, like=outer), new_cycle


def _sync_fused(cfg: HWAConfig, state: HWAState
                ) -> tuple[PyTree, WindowState, PyTree, jax.Array]:
    """Whole sync in ONE fused kernel launch over packed state.

    Packs the K replicas into (K, P), then a single ``pallas_call``
    computes the replica mean AND the window update — (K+2) reads +
    3 writes, no W̄ round-trip through HBM. W̄ for the restart is read
    back from the just-written ring slot; only W̄/W̿ are unpacked.
    """
    from repro.common.packing import pack_stacked, unpack
    from repro.kernels import ops as kops

    ws = state.window_state
    I = ws.window
    stacked = pack_stacked(state.inner, ws.spec)
    idx = ws.next_idx
    full_flag = (ws.count >= I).astype(jnp.float32)
    new_count = jnp.minimum(ws.count + 1, I)
    inv_count = 1.0 / new_count.astype(jnp.float32)
    ring, total, avg = kops.hwa_sync_packed(
        stacked, ws.ring, ws.total, idx, full_flag, inv_count)
    new_ws = WindowState(ring=ring, total=total, count=new_count,
                         next_idx=jnp.mod(idx + 1, I), window=I,
                         kind=ws.kind, spec=ws.spec)
    outer = unpack(ring[idx], ws.spec)        # the slot just written IS W̄_e
    wa = unpack(avg, ws.spec)
    return outer, new_ws, wa, state.cycle + 1


def _sync_fused_c(cfg: HWAConfig, state: HWAState
                  ) -> tuple[PyTree, WindowState, PyTree, jax.Array]:
    """Compressed-ring (bf16) sibling of :func:`_sync_fused`: one fused
    launch with the K-mean, narrow slot write and Kahan-compensated f32
    total (``kernels.ops.hwa_sync_packed_c``). The restart W̄ is the
    DECODED just-written slot — every replica restarts from the same
    bf16-rounded mean, so the ring slot and the live replicas agree
    bitwise."""
    from repro.common.packing import pack_stacked, unpack
    from repro.kernels import ops as kops

    ws = state.window_state
    I = ws.window
    stacked = pack_stacked(state.inner, ws.spec)
    idx = ws.next_idx
    full_flag = (ws.count >= I).astype(jnp.float32)
    new_count = jnp.minimum(ws.count + 1, I)
    inv_count = 1.0 / new_count.astype(jnp.float32)
    comp = ws.comp if ws.comp is not None else jnp.zeros_like(ws.total)
    ring, total, comp, avg = kops.hwa_sync_packed_c(
        stacked, ws.ring, ws.total, comp, idx, full_flag, inv_count)
    new_ws = WindowState(ring=ring, total=total, count=new_count,
                         next_idx=jnp.mod(idx + 1, I), window=I,
                         kind=ws.kind, spec=ws.spec, comp=comp,
                         scales=ws.scales)
    outer = unpack(ring[idx], ws.spec)        # decoded slot IS W̄_e
    wa = unpack(avg, ws.spec)
    return outer, new_ws, wa, state.cycle + 1


def hwa_sync(cfg: HWAConfig, state: HWAState) -> tuple[HWAState, PyTree]:
    """End-of-cycle sync (Algorithm 1 lines 8-12 + Algorithm 2).

    Returns (new state, metrics). The window update is skipped on cycles
    not matching ``window_stride`` (sparse window, §III-B). On the kernel
    path with a dense f32 ring window the sync is one fused launch
    (:func:`_sync_fused`); otherwise mean and window update run as two
    packed single-launch steps.

    With ``cfg.resilient`` the mean is the alive-masked elastic mean
    (``repro.resilience.health``): a NaN'd or diverged replica is
    excluded from W̄, restarts from W̄ like everyone else, and gets its
    per-replica optimizer slots zeroed (fresh init) instead of carrying
    poisoned moments into the next cycle. Bitwise identical to the
    non-resilient jnp path when every replica is healthy; the Pallas
    kernels are bypassed (they cannot mask) and the alive count is
    reported as the ``k_alive`` metric.
    """
    div = replica_divergence(state.inner)
    ws = state.window_state
    alive = None
    if cfg.resilient:
        from repro.resilience.health import (masked_mean_axis0,
                                             quarantine_opt_state,
                                             replica_alive_mask)
        alive = replica_alive_mask(state.inner, max_rms=cfg.max_param_rms)
        outer = masked_mean_axis0(state.inner, alive)
        window_state, wa, cycle = _window_push(cfg, outer,
                                               state.window_state,
                                               state.cycle)
    elif (cfg.use_kernels and ws.kind == "ring" and cfg.window_stride == 1
            and ws.ring is not None and ws.ring.dtype == jnp.float32):
        outer, window_state, wa, cycle = _sync_fused(cfg, state)
    elif (cfg.use_kernels and ws.kind == "ring" and cfg.window_stride == 1
            and ws.ring is not None and ws.ring.dtype == jnp.bfloat16):
        outer, window_state, wa, cycle = _sync_fused_c(cfg, state)
    elif cfg.use_kernels and jax.tree.leaves(state.inner):
        # two packed launches (mean, window push) with no intermediate
        # unpack/re-pack round-trip of the full parameter set
        from repro.common.packing import pack_stacked, unpack
        from repro.kernels import ops as kops
        buf = kops.online_mean_packed(pack_stacked(state.inner, ws.spec))
        outer = unpack(buf, ws.spec)
        window_state, avg, cycle = window_push_packed(cfg, buf, ws,
                                                      state.cycle)
        wa = unpack(avg, ws.spec)
    else:
        outer = online_average(state.inner)
        window_state, wa, cycle = _window_push(cfg, outer,
                                               state.window_state,
                                               state.cycle)
    inner = broadcast_to_replicas(outer, cfg.n_replicas)
    if cfg.avg_opt_state:
        if alive is not None:
            from repro.resilience.health import masked_mean_axis0
            opt_mean = masked_mean_axis0(state.inner_opt, alive)
        else:
            opt_mean = tree_mean_axis0(state.inner_opt)
        inner_opt = broadcast_to_replicas(opt_mean, cfg.n_replicas)
    elif alive is not None:
        # quarantine: dead replicas restart from W̄ (the broadcast above)
        # with fresh — zeroed — optimizer slots
        inner_opt = quarantine_opt_state(state.inner_opt, alive)
    else:
        inner_opt = state.inner_opt
    new_state = HWAState(inner=inner, inner_opt=inner_opt,
                         window_state=window_state, wa=wa,
                         cycle=cycle, step=state.step)
    metrics = {"replica_divergence": div, "cycle": cycle}
    if alive is not None:
        metrics["k_alive"] = jnp.sum(alive.astype(jnp.int32))
    return new_state, metrics


# ------------------------------------------------- mesh-native (per-replica)
#
# The functions below are the *local* view of Algorithms 1 & 2: they see one
# replica's unstacked params and communicate through a named axis (the
# ``replica`` mesh axis under shard_map, or a vmap axis_name on one device).
# The stacked functions above and these local ones compute identical math —
# tests/mesh_hwa_check.py verifies it numerically on a forced-host mesh.


def hwa_local_inner_step(params: PyTree, opt_state: PyTree, batch: PyTree,
                         loss_fn: Callable, optimizer: Optimizer, lr
                         ) -> tuple[PyTree, PyTree, jax.Array, dict]:
    """One replica's SGD step (Algorithm 1 lines 5-7), no leading K axis.

    Deliberately collective-free over the replica axis: inter-replica
    traffic may only happen in :func:`hwa_sync_named`, every H steps.
    """
    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        params, batch)
    updates, opt2 = optimizer.update(grads, opt_state, params, lr)
    return apply_updates(params, updates), opt2, loss, metrics


def hwa_sync_named(cfg: HWAConfig, params: PyTree,
                   window_state: WindowState, cycle: jax.Array,
                   axis_name: str = "replica"
                   ) -> tuple[PyTree, WindowState, PyTree, jax.Array]:
    """Named-axis end-of-cycle sync: W̄_e = pmean(W^k) over ``axis_name``
    — the single inter-replica collective of the whole cycle — then the
    slide-window update, computed identically (replica-invariantly) on
    every replica since pmean leaves all replicas with the same W̄_e.

    Returns (restarted params, window state, W̿_e, new cycle counter).

    .. warning:: Safe under ``vmap(axis_name=...)``; do NOT call inside a
       partial-auto ``shard_map`` on jax 0.4.x — the window push packs W̄
       from auto-sharded leaves, and XLA miscompiles that assembly in
       manual subgroups (values come back 2×, the IsManualSubgroup bug
       class). The mesh-native sync bundle
       (``launch.sync.bundles.make_mesh_hwa_sync_step``) therefore runs the
       WHOLE sync — psum, window push, unpack — inside a FULLY-manual
       shard_map over a shard-aware packed layout (no auto axes, no
       subgroup to miscompile, no assembly collectives); use that
       structure on meshes.
    """
    outer = online_average_named(params, axis_name)
    new_ws, wa, new_cycle = _window_push(cfg, outer, window_state, cycle)
    return outer, new_ws, wa, new_cycle
