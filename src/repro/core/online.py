"""Online WA module (paper §III-A, Algorithm 1 lines 8-12).

The K inner replicas are held *stacked* on a leading axis (sharded over the
``replica``/``pod`` mesh axis at scale — DESIGN.md §2). The synchronization
operation is then a mean over axis 0 followed by a broadcast back:

    W̄_e      = (1/K) Σ_k W^k_{e,H}        (outer weights)
    W^k_{e+1,0} ← W̄_e                       (restart every replica)

Under pjit with the leading axis sharded over the replica axis, this lowers
to exactly one weight all-reduce across replicas per synchronization cycle
— the paper's H-fold communication reduction vs. per-step gradient
all-reduce, realized at pod granularity.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.common.pytree import tree_mean_axis0


def online_average(stacked_params: Any, *, use_kernel: bool = False) -> Any:
    """Outer weights W̄_e from stacked inner weights (K, ...).

    The kernel path packs the K replicas into one (K, P) tile-aligned
    buffer (``repro.common.packing``) and reduces it in exactly ONE
    ``pallas_call`` regardless of leaf count; the result is unpacked back
    to leaf views in the original dtypes.
    """
    if use_kernel and jax.tree.leaves(stacked_params):
        from repro.common.packing import pack_spec, pack_stacked, unpack
        from repro.kernels import ops as kops
        spec = pack_spec(jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
            stacked_params))
        buf = pack_stacked(stacked_params, spec)
        return unpack(kops.online_mean_packed(buf), spec)
    return tree_mean_axis0(stacked_params)


def broadcast_to_replicas(outer: Any, n_replicas: int) -> Any:
    """W^k ← W̄ for every k (the restart)."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_replicas,) + x.shape).astype(x.dtype),
        outer)


# --------------------------------------------- grouped / subgroup means
#
# The two-level sync tree (launch/sync/topology.py) computes the global
# mean as a COMPOSITION of grouped reductions: per-pod partial sums of
# 1/K-pre-scaled replicas, then a sum of the pod partials. Floating-point
# addition is not associative, so "composition == flat" is only a 0-ULP
# statement when the reduction ORDER is pinned. ``jnp.sum``'s order is an
# XLA implementation detail (measured on the CPU backend it is neither
# sequential nor pairwise for wide rows), so the canonical order lives
# here instead: a contiguous-pairing binary tree.


def halving_sum_axis0(x: jax.Array) -> jax.Array:
    """Sum over axis 0 by a fixed contiguous-pairing binary tree.

    Adjacent pairs are added, then adjacent partial pairs, and so on (an
    odd trailing element is carried to the next round). Two properties
    the sync tree is built on:

    1. **composition** — split axis 0 into G contiguous groups of a
       power-of-two size, halving-sum each group, then halving-sum the G
       partials: that performs EXACTLY the additions of the flat halving
       sum, in the same order — bit-identical, not merely close;
    2. **mesh equivalence** — a psum over a size-2 mesh axis is one IEEE
       add (commutative, hence order-free), so a chain of 2-way
       collectives over contiguous replica blocks reproduces this tree's
       bits. That is how the two-level sync's grouped psum composition
       matches the flat path to 0 ULP (docs/ARCHITECTURE.md §4).
    """
    while x.shape[0] > 1:
        n = x.shape[0]
        half = x[0:n - (n % 2):2] + x[1:n:2]
        x = jnp.concatenate([half, x[n - 1:]], axis=0) if n % 2 else half
    return x[0]


def online_average_canonical(stacked_params: Any) -> Any:
    """Flat K-replica mean with a *defined* reduction order: every
    replica pre-scaled by 1/K (mirroring the mesh path's pre-scaled
    partial psums; exact for power-of-two K), then :func:`halving_sum_axis0`.

    This is the host-side reference the grouped/two-level means are
    bit-compared against (tests/test_sync_topology.py, mesh_hwa_check).
    Agrees with :func:`online_average` to normal float tolerance; the
    0-ULP claims are between canonical/grouped/mesh formulations only.
    """
    def one(x):
        k = x.shape[0]
        return halving_sum_axis0(x.astype(jnp.float32) * (1.0 / k)).astype(x.dtype)
    return jax.tree.map(one, stacked_params)


def online_average_grouped(stacked_params: Any, n_groups: int) -> Any:
    """Two-level (grouped) K-replica mean: axis 0 split into ``n_groups``
    contiguous pods, per-pod halving sums of the 1/K-pre-scaled replicas,
    then a halving sum over the pod partials — the exact arithmetic the
    two-level sync tree performs with its inner/outer psum composition.

    Bit-identical to :func:`online_average_canonical` whenever the group
    size K/n_groups is a power of two (so for EVERY factorization of a
    power-of-two K) — the property pinned by the hypothesis test in
    tests/test_sync_topology.py.
    """
    def one(x):
        k = x.shape[0]
        if n_groups < 1 or k % n_groups:
            raise ValueError(f"{n_groups} groups do not divide K={k}")
        scaled = x.astype(jnp.float32) * (1.0 / k)
        grouped = scaled.reshape((n_groups, k // n_groups) + x.shape[1:])
        partials = jax.vmap(halving_sum_axis0)(grouped)   # per-pod sums
        return halving_sum_axis0(partials).astype(x.dtype)
    return jax.tree.map(one, stacked_params)


def pod_mean_grouped(stacked_params: Any, n_groups: int) -> Any:
    """Per-pod means, stacked: (K, ...) → (n_groups, ...) where group g
    is the mean of its K/n_groups contiguous replicas — the host oracle
    for the INNER (pod-local) sync level's restart values. Same halving
    order and pre-scaling as the mesh path (exact for power-of-two group
    sizes)."""
    def one(x):
        k = x.shape[0]
        if n_groups < 1 or k % n_groups:
            raise ValueError(f"{n_groups} groups do not divide K={k}")
        per = k // n_groups
        grouped = x.astype(jnp.float32).reshape((n_groups, per) + x.shape[1:])
        return jax.vmap(
            lambda g: halving_sum_axis0(g * (1.0 / per)))(grouped).astype(x.dtype)
    return jax.tree.map(one, stacked_params)


def online_average_named(params: Any, axis_name: str = "replica") -> Any:
    """Outer weights W̄_e in the mesh-native path: each replica holds its
    own *unstacked* params and the average is a single ``pmean`` over the
    named mesh axis — the one inter-replica collective per sync cycle.

    Only valid inside ``shard_map``/``vmap`` binding ``axis_name``.
    """
    return jax.tree.map(lambda x: jax.lax.pmean(x, axis_name), params)


def replica_divergence_named(params: Any, axis_name: str = "replica"
                             ) -> jax.Array:
    """Mesh-native :func:`replica_divergence` (costs a second collective —
    keep it out of the hot sync path unless the metric is wanted)."""
    mean = online_average_named(params, axis_name)
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)
                                - m.astype(jnp.float32)))
             for x, m in zip(jax.tree.leaves(params), jax.tree.leaves(mean)))
    return jax.lax.pmean(jnp.sqrt(sq), axis_name)


def replica_divergence(stacked_params: Any) -> jax.Array:
    """Mean L2 distance of each replica from the average — the 'restart'
    magnitude the paper visualizes in Fig. 12 (exposed as a metric)."""
    mean = tree_mean_axis0(stacked_params)
    sq = [jnp.sum(jnp.square(x.astype(jnp.float32)
                             - m[None].astype(jnp.float32)), axis=tuple(range(1, x.ndim)))
          for x, m in zip(jax.tree.leaves(stacked_params), jax.tree.leaves(mean))]
    return jnp.sqrt(sum(sq)).mean()
