"""Online WA module (paper §III-A, Algorithm 1 lines 8-12).

The K inner replicas are held *stacked* on a leading axis (sharded over the
``replica``/``pod`` mesh axis at scale — DESIGN.md §2). The synchronization
operation is then a mean over axis 0 followed by a broadcast back:

    W̄_e      = (1/K) Σ_k W^k_{e,H}        (outer weights)
    W^k_{e+1,0} ← W̄_e                       (restart every replica)

Under pjit with the leading axis sharded over the replica axis, this lowers
to exactly one weight all-reduce across replicas per synchronization cycle
— the paper's H-fold communication reduction vs. per-step gradient
all-reduce, realized at pod granularity.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.common.pytree import tree_mean_axis0


def online_average(stacked_params: Any, *, use_kernel: bool = False) -> Any:
    """Outer weights W̄_e from stacked inner weights (K, ...).

    The kernel path packs the K replicas into one (K, P) tile-aligned
    buffer (``repro.common.packing``) and reduces it in exactly ONE
    ``pallas_call`` regardless of leaf count; the result is unpacked back
    to leaf views in the original dtypes.
    """
    if use_kernel and jax.tree.leaves(stacked_params):
        from repro.common.packing import pack_spec, pack_stacked, unpack
        from repro.kernels import ops as kops
        spec = pack_spec(jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
            stacked_params))
        buf = pack_stacked(stacked_params, spec)
        return unpack(kops.online_mean_packed(buf), spec)
    return tree_mean_axis0(stacked_params)


def broadcast_to_replicas(outer: Any, n_replicas: int) -> Any:
    """W^k ← W̄ for every k (the restart)."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_replicas,) + x.shape).astype(x.dtype),
        outer)


def online_average_named(params: Any, axis_name: str = "replica") -> Any:
    """Outer weights W̄_e in the mesh-native path: each replica holds its
    own *unstacked* params and the average is a single ``pmean`` over the
    named mesh axis — the one inter-replica collective per sync cycle.

    Only valid inside ``shard_map``/``vmap`` binding ``axis_name``.
    """
    return jax.tree.map(lambda x: jax.lax.pmean(x, axis_name), params)


def replica_divergence_named(params: Any, axis_name: str = "replica"
                             ) -> jax.Array:
    """Mesh-native :func:`replica_divergence` (costs a second collective —
    keep it out of the hot sync path unless the metric is wanted)."""
    mean = online_average_named(params, axis_name)
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)
                                - m.astype(jnp.float32)))
             for x, m in zip(jax.tree.leaves(params), jax.tree.leaves(mean)))
    return jax.lax.pmean(jnp.sqrt(sq), axis_name)


def replica_divergence(stacked_params: Any) -> jax.Array:
    """Mean L2 distance of each replica from the average — the 'restart'
    magnitude the paper visualizes in Fig. 12 (exposed as a metric)."""
    mean = tree_mean_axis0(stacked_params)
    sq = [jnp.sum(jnp.square(x.astype(jnp.float32)
                             - m[None].astype(jnp.float32)), axis=tuple(range(1, x.ndim)))
          for x, m in zip(jax.tree.leaves(stacked_params), jax.tree.leaves(mean))]
    return jnp.sqrt(sum(sq)).mean()
