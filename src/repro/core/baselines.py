"""The paper's comparison methods, rebuilt (Table II/III/V-VIII baselines).

- SWA  [15]  — offline WA: running average of checkpoints sampled every H
  steps after ``swa_start``, with a constant/cyclic sampling LR
  (`repro.optim.schedules.swa_constant_schedule`).
- EMA        — exponential moving average (common offline-WA variant).
- Lookahead [32] — slow/fast weights; slow += α(fast − slow) every h steps,
  fast ← slow.
- SAM  [35]  — sharpness-aware minimization: gradient at the adversarially
  perturbed point W + ρ g/‖g‖.
- Online-only WA / local SGD [9-14] — HWAConfig(window=1).
- Parallel mini-batch SGD [16, 30]  — HWAConfig(sync_period=1, window=1)
  (weight-averaging every step ≡ gradient averaging for plain SGD).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.common.pytree import tree_lerp

PyTree = Any


# ------------------------------------------------------------------ SWA


@dataclasses.dataclass
class SWAState:
    avg: PyTree
    n: jax.Array


jax.tree_util.register_dataclass(SWAState, data_fields=["avg", "n"],
                                 meta_fields=[])


def swa_init(params: PyTree) -> SWAState:
    return SWAState(avg=jax.tree.map(lambda x: x.astype(jnp.float32), params),
                    n=jnp.zeros((), jnp.int32))


def swa_update(state: SWAState, params: PyTree) -> SWAState:
    """avg <- (avg * n + params) / (n + 1)."""
    n = state.n.astype(jnp.float32)
    avg = jax.tree.map(
        lambda a, p: a + (p.astype(jnp.float32) - a) / (n + 1.0),
        state.avg, params)
    return SWAState(avg=avg, n=state.n + 1)


def swa_params(state: SWAState, like: PyTree) -> PyTree:
    return jax.tree.map(lambda a, x: a.astype(x.dtype), state.avg, like)


# ------------------------------------------------------------------ EMA


@dataclasses.dataclass
class EMAState:
    avg: PyTree
    decay: float


jax.tree_util.register_dataclass(EMAState, data_fields=["avg"],
                                 meta_fields=["decay"])


def ema_init(params: PyTree, decay: float = 0.999) -> EMAState:
    return EMAState(avg=jax.tree.map(lambda x: x.astype(jnp.float32), params),
                    decay=decay)


def ema_update(state: EMAState, params: PyTree) -> EMAState:
    avg = tree_lerp(state.avg,
                    jax.tree.map(lambda x: x.astype(jnp.float32), params),
                    1.0 - state.decay)
    return EMAState(avg=avg, decay=state.decay)


# ------------------------------------------------------------- Lookahead


@dataclasses.dataclass
class LookaheadState:
    slow: PyTree
    k: int
    alpha: float


jax.tree_util.register_dataclass(LookaheadState, data_fields=["slow"],
                                 meta_fields=["k", "alpha"])


def lookahead_init(params: PyTree, k: int = 5, alpha: float = 0.5
                   ) -> LookaheadState:
    return LookaheadState(
        slow=jax.tree.map(lambda x: x.astype(jnp.float32), params),
        k=k, alpha=alpha)


def lookahead_update(state: LookaheadState, fast: PyTree
                     ) -> tuple[LookaheadState, PyTree]:
    """Call every k fast steps: slow += α(fast − slow); fast ← slow."""
    slow = tree_lerp(state.slow,
                     jax.tree.map(lambda x: x.astype(jnp.float32), fast),
                     state.alpha)
    new_fast = jax.tree.map(lambda s, f: s.astype(f.dtype), slow, fast)
    return LookaheadState(slow=slow, k=state.k, alpha=state.alpha), new_fast


# ------------------------------------------------------------------ SAM


def sam_gradient(loss_fn: Callable, params: PyTree, batch,
                 rho: float = 0.05):
    """Two-pass SAM gradient: ∇L(W + ρ ∇L(W)/‖∇L(W)‖)."""
    (loss, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                         for l in jax.tree.leaves(g)))
    scale = rho / jnp.maximum(gnorm, 1e-12)
    perturbed = jax.tree.map(
        lambda p, gl: (p.astype(jnp.float32)
                       + scale * gl.astype(jnp.float32)).astype(p.dtype),
        params, g)
    (_, _), g_sam = jax.value_and_grad(loss_fn, has_aux=True)(perturbed, batch)
    return (loss, metrics), g_sam
