from repro.core.hwa import (HWAConfig, HWAState, hwa_init, hwa_inner_step,
                            hwa_local_inner_step, hwa_sync, hwa_sync_named,
                            window_push_packed)
from repro.core.online import (online_average, online_average_named,
                               broadcast_to_replicas, replica_divergence,
                               replica_divergence_named)
from repro.core.offline import (
    WindowState, window_init, window_update, window_average,
    window_update_packed, window_average_packed, streaming_window_update,
)
from repro.core.baselines import (
    SWAState, swa_init, swa_update,
    EMAState, ema_init, ema_update,
    LookaheadState, lookahead_init, lookahead_update,
    sam_gradient,
)
