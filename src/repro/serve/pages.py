"""Host-side page-pool bookkeeping for the paged serving engine.

The device side (``models.cache``) sees only a page pool ``(L, n_pages,
page_size, Hkv, D)`` and per-slot block tables ``(max_slots, TW)``; THIS
module owns which physical page backs which (slot, ring-position) pair:

- **admission reservation**: a request is admitted only when its exact
  worst-case page need — ``min(TW, ceil(total_len / page_size))`` ring
  slots, known up front because ``n_new`` is part of the request — fits
  in the unreserved free pool. An admitted sequence can therefore ALWAYS
  get its next page; no mid-decode OOM, no preemption needed.
- **lazy assignment**: physical pages are taken from the free list only
  when a sequence first touches a ring slot (``touch``); once the ring
  wraps (sliding windows), slots are reused in place — zero further
  allocation and zero copy traffic for eviction.
- **defrag**: live pages can be compacted to the low end of the pool
  (``defrag`` returns the old→new permutation; the engine applies it to
  the device pools with one gather) so a long-running server can shrink
  its pool snapshot / restore locality after churn.

Physical page 0 is the TRASH page (``models.cache.TRASH_PAGE``):
never allocated, always a legal DMA target for masked writes.
"""
from __future__ import annotations

import numpy as np

from repro.models.cache import TRASH_PAGE


class PageManager:
    """Allocator for one shared pool of ``n_pages`` pages (page 0 = trash)
    across ``max_slots`` batch slots with ``table_width`` ring slots each.
    """

    def __init__(self, n_pages: int, page_size: int, table_width: int,
                 max_slots: int):
        assert n_pages >= 2, "need at least the trash page + one real page"
        self.n_pages = n_pages
        self.page_size = page_size
        self.table_width = table_width
        self.max_slots = max_slots
        self.tables = np.full((max_slots, table_width), TRASH_PAGE, np.int32)
        self._free = list(range(n_pages - 1, 0, -1))   # stack; 0 reserved
        self._free_slots = list(range(max_slots - 1, -1, -1))
        self._reserved = {}                            # slot -> pages still owed
        self._owned = {s: [] for s in range(max_slots)}

    # ---------------------------------------------------------- queries

    def pages_needed(self, total_len: int) -> int:
        """Exact worst-case ring slots a sequence of ``total_len`` tokens
        (prompt + prefix + n_new) ever occupies."""
        return min(self.table_width,
                   -(-total_len // self.page_size))

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def available_pages(self) -> int:
        """Free pages not yet promised to an admitted sequence."""
        return len(self._free) - sum(self._reserved.values())

    def can_admit(self, total_len: int) -> bool:
        return bool(self._free_slots) and \
            self.pages_needed(total_len) <= self.available_pages

    # ------------------------------------------------------- slot lifecycle

    def admit(self, total_len: int) -> int:
        """Reserve a batch slot + its worst-case page budget."""
        if not self.can_admit(total_len):
            raise RuntimeError("admit() without can_admit() — page pool or "
                               "slot budget exhausted")
        slot = self._free_slots.pop()
        self._reserved[slot] = self.pages_needed(total_len)
        return slot

    def touch(self, slot: int, pos: int) -> bool:
        """Ensure the ring slot covering token position ``pos`` is backed
        by a real page. Returns True when a page was newly assigned."""
        j = (pos // self.page_size) % self.table_width
        if self.tables[slot, j] != TRASH_PAGE:
            return False                               # ring reuse in place
        assert self._reserved.get(slot, 0) > 0, \
            f"slot {slot} touching beyond its reservation"
        page = self._free.pop()
        self.tables[slot, j] = page
        self._owned[slot].append(page)
        self._reserved[slot] -= 1
        return True

    def touch_range(self, slot: int, start: int, end: int) -> int:
        """Back every ring slot a prefill of [start, end) will write.
        Only the last ``table_width`` logical pages can survive the ring,
        so earlier pages are skipped entirely. Returns pages assigned."""
        if end <= start:
            return 0
        first_pg = start // self.page_size
        last_pg = (end - 1) // self.page_size
        first_pg = max(first_pg, last_pg - self.table_width + 1)
        n = 0
        for pg in range(first_pg, last_pg + 1):
            n += self.touch(slot, pg * self.page_size)
        return n

    def release(self, slot: int) -> None:
        """Free the slot's pages + remaining reservation."""
        for page in self._owned[slot]:
            self._free.append(page)
        self._owned[slot] = []
        self.tables[slot, :] = TRASH_PAGE
        self._reserved.pop(slot, None)
        self._free_slots.append(slot)

    # ------------------------------------------------------------ defrag

    def defrag(self) -> np.ndarray:
        """Compact live pages to the low indices. Returns ``perm`` with
        ``perm[old] = new`` over all ``n_pages`` (trash stays 0); the
        caller must re-gather its device pools as ``pool[perm_argsort]``
        — i.e. ``new_pool[new] = old_pool[old]`` — for every layer stack.
        Tables are rewritten in place."""
        live = sorted({int(p) for row in self._owned.values() for p in row})
        perm = np.full((self.n_pages,), -1, np.int64)
        perm[TRASH_PAGE] = TRASH_PAGE
        nxt = 1
        for p in live:
            perm[p] = nxt
            nxt += 1
        for p in range(self.n_pages):
            if perm[p] < 0:
                perm[p] = nxt
                nxt += 1
        self.tables = perm[self.tables].astype(np.int32)
        self._owned = {s: [int(perm[p]) for p in row]
                       for s, row in self._owned.items()}
        self._free = [int(perm[p]) for p in self._free]
        self._free.sort(reverse=True)
        return perm
