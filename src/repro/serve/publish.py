"""Zero-copy WA weight publishing: trainer W̿ → serving params, bit-exact.

The trainer's offline-WA state (``repro.core.offline.WindowState``) holds
W̿ as ONE packed, layout-described buffer. Publishing to the serving
engine is therefore a LAYOUT problem, not a data problem:

    repack(src_buf, src_spec, dst_spec)   # one device-side gather,
    unpack(dst_buf, dst_spec, like=params)  # zero-copy leaf views

``PackSpec.repack`` is bit-exact by contract (packing never touches
values — the training-side parity harness in tests/test_packing.py
pins this), so the served weights are bitwise the trainer's W̿ even when
the snapshot was written under a different mesh's shard-aware layout.

Publishing is double-buffered: the repack lands in the standby buffer
while the engine keeps decoding from the live one; the swap itself is a
host pointer update between steps (``engine.set_params``) — the jitted
step takes params as an argument, so there is no retrace and no skipped
step. The previous params are kept alive until the next publish so an
in-flight dispatch can never read freed memory.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.common.packing import PackSpec, pack_spec, repack, unpack


@jax.jit
def _cast_like(leaf, like):
    return leaf.astype(like.dtype)


@dataclasses.dataclass
class WeightPublisher:
    """Publishes packed WA snapshots into a serving engine's params.

    ``engine`` is any engine whose jitted steps take params as an
    argument and which exposes ``params`` + ``set_params`` (both serving
    engines do).
    """
    engine: object

    def __post_init__(self):
        self.dst_spec: PackSpec = pack_spec(self.engine.params)
        self._repack = jax.jit(repack, static_argnums=(1, 2))
        self._standby = None          # params kept alive across one swap
        self.n_published = 0

    def publish_packed(self, buf, src_spec: PackSpec):
        """Repack ``buf`` (the trainer's packed W̿ under ``src_spec``)
        into the serving layout and swap it in. Returns the new params.
        """
        if src_spec.same_layout(self.dst_spec):
            dst_buf = jnp.asarray(buf, jnp.float32)   # already our layout
        else:
            dst_buf = self._repack(jnp.asarray(buf, jnp.float32),
                                   src_spec, self.dst_spec)
        new_params = unpack(dst_buf, self.dst_spec, like=self.engine.params)
        new_params = jax.tree.map(_cast_like, new_params, self.engine.params)
        # rotate: previous live params become the standby kept alive
        # until the NEXT publish (no in-flight dispatch reads freed mem)
        self._standby = self.engine.params
        self.engine.set_params(new_params)
        self.n_published += 1
        return new_params

    def publish_window_state(self, state):
        """Publish W̿ from a live (or freshly loaded) WindowState."""
        buf, spec = wa_snapshot(state)
        return self.publish_packed(buf, spec)

    def publish_checkpoint(self, path: str):
        """Publish W̿ straight from a window-state checkpoint file."""
        from repro.checkpoint.io import load_wa_snapshot
        buf, spec = load_wa_snapshot(path)
        return self.publish_packed(buf, spec)


def wa_snapshot(state):
    """(packed W̿ f32 buffer, PackSpec) from a WindowState: ring states
    hold a running SUM (divide by count), streaming states hold the mean
    directly. Grouped runtime states (per-group buffer tuples) are merged
    to the canonical single logical buffer."""
    total = state.total
    if isinstance(total, (tuple, list)):
        from repro.common.packing import merge_groups
        total = merge_groups(total, state.spec)
    if state.kind == "streaming":
        return jnp.asarray(total, jnp.float32), state.spec
    count = jnp.maximum(state.count, 1).astype(jnp.float32)
    return jnp.asarray(total, jnp.float32) / count, state.spec
