"""Batched serving engine: prefill + greedy/temperature decode.

Works for every architecture family (KV caches, SSM states, hybrid,
multi-codebook audio). MusicGen's codebook *delay pattern* (codebook c is
shifted c steps so step t emits codebook c's frame t-c) is applied here,
in the engine — the model itself sees plain parallel streams.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.registry import LM


def apply_delay_pattern(tokens, pad_token: int = 0):
    """(B, S, CB) -> (B, S+CB-1, CB) with codebook c delayed by c steps."""
    B, S, CB = tokens.shape
    out = jnp.full((B, S + CB - 1, CB), pad_token, tokens.dtype)
    for c in range(CB):
        out = out.at[:, c:c + S, c].set(tokens[..., c])
    return out


def undo_delay_pattern(tokens, n_frames: int):
    """(B, S+CB-1, CB) -> (B, n_frames, CB)."""
    CB = tokens.shape[-1]
    cols = [tokens[:, c:c + n_frames, c] for c in range(CB)]
    return jnp.stack(cols, axis=-1)


@dataclasses.dataclass
class DecodeEngine:
    lm: LM
    params: object
    max_seq_len: int
    rules: object = None

    def __post_init__(self):
        cfg = self.lm.cfg
        self._prefill = jax.jit(
            lambda p, c, b: self.lm.prefill(p, c, b, rules=self.rules))
        self._step = jax.jit(
            lambda p, c, t: self.lm.decode_step(p, c, t, rules=self.rules))

    def _sample(self, logits, key, temperature):
        if temperature == 0.0:
            return jnp.argmax(logits, axis=-1)
        return jax.random.categorical(key, logits / temperature, axis=-1)

    def generate(self, batch, n_new_tokens: int, *, temperature: float = 0.0,
                 seed: int = 0):
        """Prefill ``batch`` then decode ``n_new_tokens`` greedily/sampled.

        Returns generated tokens: (B, n_new) or (B, n_new, CB) for audio.
        """
        cfg = self.lm.cfg
        B = batch["tokens"].shape[0]
        cache, _ = self.lm.init_cache(B, self.max_seq_len)
        logits, cache = self._prefill(self.params, cache, batch)
        key = jax.random.key(seed)
        outs = []
        tok = None
        for i in range(n_new_tokens):
            key, sub = jax.random.split(key)
            tok = self._sample(logits, sub, temperature)
            outs.append(tok)
            logits, cache = self._step(self.params, cache, tok)
        return jnp.stack(outs, axis=1)
