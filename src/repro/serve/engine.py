"""Batched serving engines: prefill + greedy/temperature decode.

Two engines share the model's pure functions:

- :class:`DecodeEngine` — the whole-batch reference: contiguous
  ``(L, B, C, Hkv, D)`` KV cache, one jitted fused sample+decode step
  (PRNG split and sampling INSIDE the jit, cache donated), static batch.
  Kept as the parity oracle for the paged engine's tests.
- :class:`PagedDecodeEngine` — the production tier: page-pool KV cache
  with per-sequence block tables, ONE decode step jitted over fixed
  (max_batch, pool) shapes so continuous-batching admissions/evictions
  never retrace (asserted via :attr:`step_traces`), fused sampling, and
  an on-device output buffer (zero per-token host syncs). Weight
  hot-swap is a host pointer swap (``set_params``) between steps — the
  step takes params as an argument, so new weights apply from the next
  step with zero downtime and zero retrace.

Works for every architecture family (KV caches, SSM states, hybrid,
multi-codebook audio). MusicGen's codebook *delay pattern* (codebook c is
shifted c steps so step t emits codebook c's frame t-c) is applied here,
in the engine — the model itself sees plain parallel streams.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tfm
from repro.models.cache import paged_table_width
from repro.models.registry import (LM, _prefix_len, lm_paged_decode_step,
                                   lm_paged_prefill_chunk,
                                   lm_paged_prefix_fill)
from repro.serve.pages import PageManager


def apply_delay_pattern(tokens, pad_token: int = 0):
    """(B, S, CB) -> (B, S+CB-1, CB) with codebook c delayed by c steps."""
    B, S, CB = tokens.shape
    out = jnp.full((B, S + CB - 1, CB), pad_token, tokens.dtype)
    for c in range(CB):
        out = out.at[:, c:c + S, c].set(tokens[..., c])
    return out


def undo_delay_pattern(tokens, n_frames: int):
    """(B, S+CB-1, CB) -> (B, n_frames, CB)."""
    CB = tokens.shape[-1]
    cols = [tokens[:, c:c + n_frames, c] for c in range(CB)]
    return jnp.stack(cols, axis=-1)


def _sample(logits, key, temperature: float):
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1)
    return jax.random.categorical(key, logits / temperature, axis=-1)


@dataclasses.dataclass
class DecodeEngine:
    """Whole-batch reference engine (static batch, contiguous cache)."""
    lm: LM
    params: object
    max_seq_len: int
    rules: object = None

    def __post_init__(self):
        self._prefill = jax.jit(
            lambda p, c, b: self.lm.prefill(p, c, b, rules=self.rules),
            donate_argnums=(1,))
        self._steps = {}     # temperature (static) -> fused jitted step

    def _fused_step(self, temperature: float):
        """sample(prev logits) + decode in ONE dispatch: the PRNG split
        happens inside the jit and the cache is donated, so temperature>0
        decode costs no host-side split and no cache re-allocation."""
        if temperature not in self._steps:
            def step(params, cache, logits, key):
                key, sub = jax.random.split(key)
                tok = _sample(logits, sub, temperature)
                logits, cache = self.lm.decode_step(params, cache, tok,
                                                    rules=self.rules)
                return tok, logits, cache, key
            self._steps[temperature] = jax.jit(step, donate_argnums=(1, 2))
        return self._steps[temperature]

    def generate(self, batch, n_new_tokens: int, *, temperature: float = 0.0,
                 seed: int = 0):
        """Prefill ``batch`` then decode ``n_new_tokens`` greedily/sampled.

        Returns generated tokens: (B, n_new) or (B, n_new, CB) for audio.
        """
        B = batch["tokens"].shape[0]
        cache, _ = self.lm.init_cache(B, self.max_seq_len)
        logits, cache = self._prefill(self.params, cache, batch)
        key = jax.random.key(seed)
        step = self._fused_step(temperature)
        outs = []
        for _ in range(n_new_tokens):
            tok, logits, cache, key = step(self.params, cache, logits, key)
            outs.append(tok)
        return jnp.stack(outs, axis=1)


# ------------------------------------------------------------------
# paged continuous-batching engine
# ------------------------------------------------------------------


def model_table_width(cfg, max_seq_len: int, page_size: int) -> int:
    """ONE table width per model: the max over the pattern's attention
    specs (a global layer forces full history; pure-windowed patterns get
    the small ring). 1 for attention-free stacks (tables unused)."""
    widths = [paged_table_width(max_seq_len, s.window, page_size)
              for s in tfm.block_pattern(cfg) if s.kind in ("attn", "hybrid")]
    return max(widths) if widths else 1


def needs_exact_prefill(cfg) -> bool:
    """Recurrent stacks (mamba/mLSTM/sLSTM) cannot absorb pad tokens in a
    chunked prefill — the engine routes them through prefix-fill +
    step-prefill instead."""
    return any(s.kind in ("hybrid", "mlstm", "slstm")
               for s in tfm.block_pattern(cfg))


@dataclasses.dataclass
class PagedDecodeEngine:
    """Fixed-shape continuous-batching engine over a paged KV pool.

    ``max_seq_len`` bounds TOTAL tokens per sequence (prefix + prompt +
    generated); ``max_new`` bounds generated tokens (sizes the on-device
    output buffer); ``prefill_chunk`` is the static padded prompt length
    of the chunk-prefill jit. ``temperature`` is static per engine (a
    different temperature is a different program).

    The host side drives :meth:`step` with small per-step control arrays
    (block tables, per-slot positions, prompt-feed masks, output
    indices); all token-rate state (caches, last sampled token, output
    buffer, PRNG key) stays on device and is donated through the single
    jitted step.
    """
    lm: LM
    params: object
    max_batch: int
    max_seq_len: int
    max_new: int
    page_size: int = 4
    n_pages: int | None = None
    prefill_chunk: int = 32
    temperature: float = 0.0
    seed: int = 0

    def __post_init__(self):
        cfg = self.lm.cfg
        self.table_width = model_table_width(cfg, self.max_seq_len,
                                             self.page_size)
        if self.n_pages is None:
            self.n_pages = 1 + self.max_batch * self.table_width
        self.needs_exact_prefill = needs_exact_prefill(cfg)
        self.prefix_len = _prefix_len(cfg)
        self._step_traces = 0
        self._prefill_traces = 0
        self._jit_step = self._build_step()
        self._jit_prefill = self._build_prefill()
        self._jit_prefix = self._build_prefix_fill()
        self.reset_state(self.seed)

    # ------------------------------------------------------------ state

    def _tok_shape(self):
        cfg = self.lm.cfg
        return (self.max_batch, cfg.n_codebooks) if cfg.family == "audio" \
            else (self.max_batch,)

    def reset_state(self, seed: int = 0):
        """Fresh caches / output buffer / PRNG key / page manager."""
        cfg = self.lm.cfg
        caches, _ = self.lm.init_paged_cache(self.max_batch, self.n_pages,
                                             self.page_size)
        out_shape = (self.max_batch, self.max_new + 1)    # last col = scratch
        if cfg.family == "audio":
            out_shape += (cfg.n_codebooks,)
        self.state = {
            "caches": caches,
            "last": jnp.zeros(self._tok_shape(), jnp.int32),
            "out": jnp.zeros(out_shape, jnp.int32),
            "key": jax.random.key(seed),
        }
        self.pages = PageManager(self.n_pages, self.page_size,
                                 self.table_width, self.max_batch)

    @property
    def scratch_idx(self) -> int:
        """Output column absorbing non-emitting steps (prompt feed)."""
        return self.max_new

    @property
    def step_traces(self) -> int:
        """Times the decode step actually traced — the structural
        no-retrace guarantee is ``step_traces == 1`` after any run."""
        return self._step_traces

    # ------------------------------------------------------------- jits

    def _build_step(self):
        cfg, ps, temp = self.lm.cfg, self.page_size, self.temperature

        def step(params, caches, last, out, key, ctrl):
            self._step_traces += 1        # host side effect: counts traces
            caches = tfm.reset_paged_states(caches, ctrl["reset"])
            up = ctrl["use_prompt"]
            upb = up if last.ndim == 1 else up[:, None]
            tok_in = jnp.where(upb, ctrl["prompt_tok"], last)
            logits, caches = lm_paged_decode_step(
                cfg, params, caches, tok_in, ctrl["pos"], ctrl["tables"], ps)
            key, sub = jax.random.split(key)
            sampled = _sample(logits, sub, temp).astype(jnp.int32)
            out = out.at[jnp.arange(out.shape[0]),
                         ctrl["out_idx"]].set(sampled)
            return caches, sampled, out, key

        return jax.jit(step, donate_argnums=(1, 2, 3))

    def _build_prefill(self):
        cfg, ps, temp = self.lm.cfg, self.page_size, self.temperature

        def prefill(params, caches, last, out, key, batch, n_valid, slot,
                    tables):
            self._prefill_traces += 1
            logits, caches = lm_paged_prefill_chunk(
                cfg, params, caches, batch, n_valid, slot, tables, ps)
            key, sub = jax.random.split(key)
            sampled = _sample(logits, sub, temp).astype(jnp.int32)[0]
            last = last.at[slot].set(sampled)
            out = out.at[slot, 0].set(sampled)
            return caches, last, out, key

        return jax.jit(prefill, donate_argnums=(1, 2, 3))

    def _build_prefix_fill(self):
        cfg, ps = self.lm.cfg, self.page_size

        def prefix_fill(params, caches, slot, tables):
            return lm_paged_prefix_fill(cfg, params, caches, slot, tables, ps)

        return jax.jit(prefix_fill, donate_argnums=(1,))

    # ------------------------------------------------------- host driver

    def set_params(self, new_params):
        """Weight hot-swap: the step takes params as an argument, so the
        next :meth:`step` runs the new weights — no retrace (identical
        shapes/dtypes), no downtime, in-flight state untouched."""
        self.params = new_params

    def step(self, ctrl: dict):
        """One fixed-shape decode step. ``ctrl`` holds host-built arrays:
        tables (B,TW) i32, pos (B,) i32, use_prompt (B,) bool,
        prompt_tok (B,)/(B,CB) i32, out_idx (B,) i32, reset (B,) bool."""
        s = self.state
        dev_ctrl = {k: jnp.asarray(v) for k, v in ctrl.items()}
        caches, last, out, key = self._jit_step(
            self.params, s["caches"], s["last"], s["out"], s["key"], dev_ctrl)
        self.state = {"caches": caches, "last": last, "out": out, "key": key}

    def prefill_into(self, slot: int, batch1: dict, n_valid: int):
        """Chunk-prefill one slot (attention-only stacks): pads the
        prompt to ``prefill_chunk``, writes its pages, samples the first
        output token into ``out[slot, 0]``. One dispatch per admission."""
        tokens = np.asarray(batch1["tokens"])
        S = tokens.shape[1]
        assert S <= self.prefill_chunk, (S, self.prefill_chunk)
        pad = self.prefill_chunk - S
        if pad:
            width = [(0, 0), (0, pad)] + [(0, 0)] * (tokens.ndim - 2)
            tokens = np.pad(tokens, width)
        padded = dict(batch1)
        padded["tokens"] = jnp.asarray(tokens)
        s = self.state
        caches, last, out, key = self._jit_prefill(
            self.params, s["caches"], s["last"], s["out"], s["key"], padded,
            jnp.asarray(n_valid, jnp.int32), jnp.asarray(slot, jnp.int32),
            jnp.asarray(self.pages.tables))
        self.state = {"caches": caches, "last": last, "out": out, "key": key}

    def prefix_fill_into(self, slot: int):
        """Run the learned prefix (meta tokens) for one slot — the exact
        static-length entry point for recurrent stacks."""
        s = self.state
        caches = self._jit_prefix(self.params, s["caches"],
                                  jnp.asarray(slot, jnp.int32),
                                  jnp.asarray(self.pages.tables))
        self.state = dict(s, caches=caches)

    def read_out(self, slot: int, n: int) -> np.ndarray:
        """Fetch one finished request's tokens — a single device→host
        copy per REQUEST, never per token."""
        return np.asarray(self.state["out"][slot, :n])

    def apply_page_perm(self, perm: np.ndarray):
        """Re-gather the device pools after ``PageManager.defrag``:
        ``perm[old] = new`` ⇒ ``new_pool[new] = old_pool[old]``."""
        inv = np.argsort(perm)
        gather = jnp.asarray(inv)

        def regather(c):
            if "pages" not in c:
                return c
            return dict(c, pages={k: v[:, gather]
                                  for k, v in c["pages"].items()})

        self.state = dict(self.state,
                          caches=[regather(c) for c in self.state["caches"]])

    def generate(self, batch, n_new_tokens: int, *, seed: int = 0):
        """Whole-batch convenience wrapper (parity with
        :meth:`DecodeEngine.generate` at temperature 0): admits all B
        sequences through the continuous scheduler at once."""
        from repro.serve.scheduler import ContinuousScheduler, Request
        B = batch["tokens"].shape[0]
        reqs = []
        for b in range(B):
            vis = np.asarray(batch["vis_embeds"][b]) \
                if "vis_embeds" in batch else None
            reqs.append(Request(rid=b, tokens=np.asarray(batch["tokens"][b]),
                                n_new=n_new_tokens, vis_embeds=vis))
        outs = ContinuousScheduler(self).run(reqs, seed=seed)
        return jnp.asarray(np.stack([outs[b] for b in range(B)], axis=0))


def make_paged_decode_bundle(lm: LM, *, max_batch: int = 2,
                             max_seq_len: int = 64, max_new: int = 4,
                             page_size: int = 4, n_pages: int | None = None,
                             temperature: float = 0.0):
    """The paged decode step as a :class:`StepBundle` for the static
    contract checker: single-device serving step — no collectives
    anywhere, exact Pallas-launch budget (1 paged-attention launch per
    pattern attention spec under ``flash_pallas``, 0 otherwise), donated
    caches/token/output buffers, no f64."""
    from repro.analysis.contracts import decode_contract
    from repro.launch.sync.bundles import StepBundle

    cfg = lm.cfg
    TW = model_table_width(cfg, max_seq_len, page_size)
    n_pages = n_pages if n_pages is not None else 1 + max_batch * TW

    def step(params, caches, last, out, key, ctrl):
        caches = tfm.reset_paged_states(caches, ctrl["reset"])
        up = ctrl["use_prompt"]
        upb = up if last.ndim == 1 else up[:, None]
        tok_in = jnp.where(upb, ctrl["prompt_tok"], last)
        logits, caches = lm_paged_decode_step(
            cfg, params, caches, tok_in, ctrl["pos"], ctrl["tables"],
            page_size)
        key, sub = jax.random.split(key)
        sampled = _sample(logits, sub, temperature).astype(jnp.int32)
        out = out.at[jnp.arange(out.shape[0]), ctrl["out_idx"]].set(sampled)
        return caches, sampled, out, key

    params_abs, _ = lm.abstract()
    caches_abs = jax.eval_shape(
        lambda: lm.init_paged_cache(max_batch, n_pages, page_size)[0])
    tokf = (max_batch, cfg.n_codebooks) if cfg.family == "audio" \
        else (max_batch,)
    out_shape = tokf[:1] + (max_new + 1,) + tokf[1:]
    ctrl_abs = {
        "tables": jax.ShapeDtypeStruct((max_batch, TW), jnp.int32),
        "pos": jax.ShapeDtypeStruct((max_batch,), jnp.int32),
        "use_prompt": jax.ShapeDtypeStruct((max_batch,), jnp.bool_),
        "prompt_tok": jax.ShapeDtypeStruct(tokf, jnp.int32),
        "out_idx": jax.ShapeDtypeStruct((max_batch,), jnp.int32),
        "reset": jax.ShapeDtypeStruct((max_batch,), jnp.bool_),
    }
    abstract_args = (
        params_abs, caches_abs,
        jax.ShapeDtypeStruct(tokf, jnp.int32),
        jax.ShapeDtypeStruct(out_shape, jnp.int32),
        jax.eval_shape(lambda: jax.random.key(0)),
        ctrl_abs,
    )
    n_attn = sum(1 for s in tfm.block_pattern(cfg)
                 if s.kind in ("attn", "hybrid"))
    launches = n_attn if cfg.attn_impl == "flash_pallas" else 0
    return StepBundle(
        fn=step, abstract_args=abstract_args, in_shardings=None,
        out_shardings=None, donate_argnums=(1, 2, 3),
        contract=decode_contract(
            launches=launches,
            notes="paged continuous-batching decode step (serving tier)"))
