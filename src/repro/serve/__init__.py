from repro.serve.engine import DecodeEngine, apply_delay_pattern, undo_delay_pattern
