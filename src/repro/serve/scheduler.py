"""Continuous-batching scheduler over the paged decode engine.

The engine (``serve.engine.PagedDecodeEngine``) is pure mechanism: ONE
jitted fixed-shape step plus per-admission prefill dispatches. This
module is the policy loop:

- **admission**: FIFO queue, admitted the moment a batch slot AND the
  request's exact worst-case page budget are free
  (``PageManager.can_admit`` — reservation up front means an admitted
  sequence can never OOM mid-decode, so no preemption path is needed).
- **prefill interleave**: attention-only stacks prefill their whole
  (padded) prompt in one chunk dispatch at admission; recurrent stacks
  (mamba/mLSTM/sLSTM) run the static-length prefix fill once, then feed
  prompt tokens THROUGH the shared decode step (``use_prompt`` lane) —
  prefilling sequences ride the same fixed-shape step as decoding ones,
  which is what makes the batching continuous.
- **eviction**: a finished request's tokens are fetched with one
  device→host copy, its pages and slot freed, and the next queued
  request admitted into the hole — all without retracing the step
  (``engine.step_traces`` stays 1).

Every step's control arrays (block tables, positions, prompt lane,
output indices) are built host-side from this module's bookkeeping; the
device never sees a data-dependent shape.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Request:
    """One serving request. ``tokens``: (S,) int32 ((S, CB) for audio);
    ``arrival``: earliest step index at which admission may happen (lets
    tests drive ragged arrival traces)."""
    rid: int
    tokens: np.ndarray
    n_new: int
    vis_embeds: np.ndarray | None = None
    arrival: int = 0


@dataclasses.dataclass
class _Active:
    req: Request
    slot: int
    pos: int              # tokens written to the cache so far
    fed: int              # prompt tokens already fed (step-prefill lane)
    emitted: int          # output tokens sampled so far
    fresh: bool = True    # first step must carry the recurrent-state reset


class ContinuousScheduler:
    """Drives admit → (prefill | decode) steps → evict until done."""

    def __init__(self, engine):
        self.engine = engine

    # ------------------------------------------------------------ admission

    def _total_len(self, req: Request) -> int:
        return self.engine.prefix_len + len(req.tokens) + req.n_new

    def _admit(self, req: Request) -> _Active:
        eng = self.engine
        total = self._total_len(req)
        assert total <= eng.max_seq_len, (total, eng.max_seq_len)
        assert req.n_new <= eng.max_new, (req.n_new, eng.max_new)
        slot = eng.pages.admit(total)
        npre = eng.prefix_len
        S = len(req.tokens)
        if not eng.needs_exact_prefill:
            # one chunk dispatch: pages for the whole prompt, first
            # output token sampled into out[slot, 0]
            eng.pages.touch_range(slot, 0, npre + S)
            batch1 = {"tokens": req.tokens[None]}
            if req.vis_embeds is not None:
                batch1["vis_embeds"] = req.vis_embeds[None]
            eng.prefill_into(slot, batch1, npre + S)
            return _Active(req=req, slot=slot, pos=npre + S, fed=S,
                           emitted=1, fresh=False)
        # recurrent stack: exact-length prefix fill, then the prompt is
        # fed through the shared decode step (use_prompt lane)
        if npre:
            eng.pages.touch_range(slot, 0, npre)
            eng.prefix_fill_into(slot)
        # prefix fill OVERWRITES the slot's recurrent state (fresh scan
        # from zeros), so only prefix-free stacks still need the reset
        return _Active(req=req, slot=slot, pos=npre, fed=0, emitted=0,
                       fresh=npre == 0)

    # ------------------------------------------------------------ main loop

    def run(self, requests: list[Request], *, seed: int = 0,
            max_steps: int | None = None) -> dict:
        """Serve ``requests`` to completion. Returns {rid: tokens
        (n_new,) or (n_new, CB)}. ``max_steps`` guards tests against a
        livelocked loop (raises instead of spinning)."""
        eng = self.engine
        eng.reset_state(seed)
        queue = sorted(requests, key=lambda r: (r.arrival, r.rid))
        active: dict[int, _Active] = {}          # slot -> state
        results: dict[int, np.ndarray] = {}
        B, scratch = eng.max_batch, eng.scratch_idx
        audio = eng.lm.cfg.family == "audio"
        cb = eng.lm.cfg.n_codebooks if audio else None
        step_i = 0
        while queue or active:
            if max_steps is not None and step_i > max_steps:
                raise RuntimeError("scheduler exceeded max_steps")
            # admit in arrival order while budget allows
            while queue and queue[0].arrival <= step_i and \
                    eng.pages.can_admit(self._total_len(queue[0])):
                act = self._admit(queue.pop(0))
                active[act.slot] = act
                self._maybe_finish(act, active, results)
            if not active:
                step_i += 1      # waiting on a future arrival
                continue

            ctrl = self._build_ctrl(active, B, scratch, audio, cb)
            eng.step(ctrl)
            step_i += 1

            for slot in list(active):
                act = active[slot]
                act.fresh = False
                act.pos += 1
                if act.fed < len(act.req.tokens):
                    act.fed += 1
                    if act.fed == len(act.req.tokens):
                        act.emitted = 1      # last prompt step emitted #0
                else:
                    act.emitted += 1
                self._maybe_finish(act, active, results)
        return results

    def _maybe_finish(self, act: _Active, active, results):
        if act.emitted >= act.req.n_new:
            eng = self.engine
            results[act.req.rid] = eng.read_out(act.slot, act.req.n_new)
            eng.pages.release(act.slot)
            active.pop(act.slot, None)

    # ----------------------------------------------------------- step ctrl

    def _build_ctrl(self, active, B, scratch, audio, cb):
        eng = self.engine
        tokf = (B, cb) if audio else (B,)
        pos = np.zeros((B,), np.int32)
        use_prompt = np.zeros((B,), bool)
        prompt_tok = np.zeros(tokf, np.int32)
        out_idx = np.full((B,), scratch, np.int32)
        reset = np.zeros((B,), bool)
        for slot, act in active.items():
            pos[slot] = act.pos
            reset[slot] = act.fresh
            eng.pages.touch(slot, act.pos)   # page for this step's write
            S = len(act.req.tokens)
            if act.fed < S:                   # prompt lane (step-prefill)
                use_prompt[slot] = True
                prompt_tok[slot] = act.req.tokens[act.fed]
                if act.fed == S - 1:
                    out_idx[slot] = 0         # samples output token #0
            else:
                out_idx[slot] = act.emitted
        return {"tables": eng.pages.tables.copy(), "pos": pos,
                "use_prompt": use_prompt, "prompt_tok": prompt_tok,
                "out_idx": out_idx, "reset": reset}
