"""Shared building blocks: initializers, norms, RoPE, activations.

Every ``init_*`` helper returns ``(params, dims)`` — parallel pytrees where
``dims`` holds the logical dim names consumed by ``repro.sharding.rules``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def normal_init(key, shape, dims, dtype, fan_in=None):
    """Truncated-normal-ish init scaled by 1/sqrt(fan_in)."""
    fan_in = fan_in if fan_in is not None else shape[-2] if len(shape) >= 2 else shape[-1]
    scale = 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape) * scale).astype(dtype), tuple(dims)


def zeros_init(shape, dims, dtype):
    return jnp.zeros(shape, dtype), tuple(dims)


def ones_init(shape, dims, dtype):
    return jnp.ones(shape, dtype), tuple(dims)


# ---------------------------------------------------------------- norms


def init_norm(cfg, d=None):
    d = d or cfg.d_model
    params = {"scale": jnp.ones((d,), jnp.float32)}
    dims = {"scale": ("embed",)}
    if cfg.norm == "layernorm":
        params["bias"] = jnp.zeros((d,), jnp.float32)
        dims["bias"] = ("embed",)
    return params, dims


def apply_norm(cfg, p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mean) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------- rope


def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                       # (half,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, half)
    cos = jnp.cos(angles)[..., None, :]                        # (..., seq, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- act


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def softcap(x, cap: float):
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)
