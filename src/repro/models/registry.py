"""LM assembly: embeddings → scanned stack → head, per architecture family.

``build_model(cfg)`` returns an :class:`LM` — a bundle of pure functions —
plus logical-dim pytrees for the sharding rules. ``LM.abstract()`` gives
(param ShapeDtypeStructs, dims) without allocating, which is what the
multi-pod dry-run lowers against.

Batch conventions (targets included in the batch dict):
  dense/moe/ssm/hybrid : tokens (B,S) int32, targets (B,S)
  vlm                  : + vis_embeds (B, n_vis, d_vis) stub frontend
  audio                : tokens/targets (B,S,n_codebooks) EnCodec streams
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import numpy as np
import jax.numpy as jnp

from repro.common.compat import shard_map
from repro.models import transformer as tfm
from repro.models.common import apply_norm, init_norm, normal_init, softcap
from repro.models.types import ModelConfig


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def init_lm(cfg: ModelConfig, key):
    # NOTE: embed/head tables are sharded on vocab (model axis) ONLY — no
    # data-axis FSDP dim. FSDP-sharding them makes XLA all-gather the full
    # table around the token gather / dembed scatter (~19 GB/device fixed
    # overhead measured in the dry-run); vocab-sharded tables lower to the
    # megatron-style local-gather + psum instead.
    dtype = _dtype(cfg)
    ks = jax.random.split(key, 6)
    params, dims = {}, {}
    if cfg.family == "audio":
        params["embed"], dims["embed"] = normal_init(
            ks[0], (cfg.n_codebooks, cfg.vocab_size, cfg.d_model),
            (None, "vocab", None), dtype, fan_in=cfg.d_model)
        params["head"], dims["head"] = normal_init(
            ks[1], (cfg.n_codebooks, cfg.d_model, cfg.vocab_size),
            (None, None, "vocab"), dtype, fan_in=cfg.d_model)
    else:
        params["embed"], dims["embed"] = normal_init(
            ks[0], (cfg.vocab_size, cfg.d_model),
            ("vocab", None), dtype, fan_in=cfg.d_model)
        params["head"], dims["head"] = normal_init(
            ks[1], (cfg.d_model, cfg.vocab_size),
            (None, "vocab"), dtype, fan_in=cfg.d_model)
    if cfg.family == "vlm":
        params["vis_proj"], dims["vis_proj"] = normal_init(
            ks[2], (cfg.d_vis, cfg.d_model), (None, "embed"), dtype,
            fan_in=cfg.d_vis)
    if cfg.n_meta_tokens:
        params["meta"], dims["meta"] = normal_init(
            ks[3], (cfg.n_meta_tokens, cfg.d_model), (None, "embed"), dtype,
            fan_in=cfg.d_model)
    params["stack"], dims["stack"] = tfm.init_stack(cfg, ks[4], dtype)
    params["ln_f"], dims["ln_f"] = init_norm(cfg)
    return params, dims


def _sharded_gather(embed, tokens, rules):
    """Megatron-style vocab-sharded embedding lookup (explicit shard_map).

    XLA's auto-partitioned gather/scatter on a vocab-sharded table
    materializes the full f32 table per device (4×8.4 GB for the 256k-vocab
    archs, measured in the dry-run). Each shard instead gathers its
    in-range ids locally and psums — the backward is a *local* scatter
    into the local table shard.
    """
    from jax.sharding import PartitionSpec as P

    mesh = rules.mesh
    vaxis = rules.rules.get("vocab", ())
    vaxis = vaxis[0] if vaxis and vaxis[0] in mesh.shape else None
    if vaxis is None or embed.shape[0] % mesh.shape[vaxis]:
        return jnp.take(embed, tokens, axis=0)
    batch_axes = tuple(a for a in rules.rules.get("batch", ())
                       if a in mesh.shape)
    bsz = int(np.prod([mesh.shape[a] for a in batch_axes])) if batch_axes else 1
    bspec = batch_axes if (bsz and tokens.shape[0] % bsz == 0) else ()

    def local(emb, ids):
        vl = emb.shape[0]
        off = jax.lax.axis_index(vaxis) * vl
        lid = ids - off
        ok = (lid >= 0) & (lid < vl)
        out = jnp.take(emb, jnp.clip(lid, 0, vl - 1), axis=0)
        out = jnp.where(ok[..., None], out, 0)
        return jax.lax.psum(out, vaxis)

    tok_rest = (None,) * (tokens.ndim - 1)
    in_specs = (P(vaxis), P(bspec if bspec else None, *tok_rest))
    out_specs = P(bspec if bspec else None, *tok_rest, None)
    fn = shard_map(local, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
    return fn(embed, tokens)


def _embed_tokens(cfg, params, tokens, rules=None):
    if rules is not None:
        gather = functools.partial(_sharded_gather, rules=rules)
    else:
        gather = lambda e, t: jnp.take(e, t, axis=0)
    if cfg.family == "audio":
        # embed: (CB, V, D); tokens (B, S, CB) -> sum over codebooks
        parts = [gather(params["embed"][c], tokens[..., c])
                 for c in range(cfg.n_codebooks)]
        return sum(parts)
    return gather(params["embed"], tokens)


def _prefix_len(cfg) -> int:
    n = cfg.n_meta_tokens
    if cfg.family == "vlm":
        n += cfg.n_vis_tokens
    return n


def _assemble_input(cfg, params, batch, rules=None):
    """Token embeddings + any learned/stub prefixes. Returns (x, positions)."""
    x = _embed_tokens(cfg, params, batch["tokens"], rules=rules)
    B = x.shape[0]
    prefix = []
    if cfg.n_meta_tokens:
        prefix.append(jnp.broadcast_to(params["meta"],
                                       (B,) + params["meta"].shape))
    if cfg.family == "vlm":
        vis = batch["vis_embeds"].astype(x.dtype) @ params["vis_proj"]
        prefix.append(vis)
    if prefix:
        x = jnp.concatenate(prefix + [x], axis=1)
    positions = jnp.arange(x.shape[1])
    return x, positions


def lm_apply(cfg: ModelConfig, params, batch, rules=None):
    """Teacher-forcing forward. Returns (logits over token positions, aux)."""
    x, positions = _assemble_input(cfg, params, batch, rules=rules)
    x, aux = tfm.apply_stack_train(cfg, params["stack"], x, positions,
                                   rules=rules)
    x = apply_norm(cfg, params["ln_f"], x)
    npre = _prefix_len(cfg)
    if npre:
        x = x[:, npre:]
    if cfg.family == "audio":
        logits = jnp.einsum("bsd,cdv->bscv", x, params["head"])
    else:
        logits = x @ params["head"]
    logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return logits, aux


def _xent(logits, targets):
    """Mean token cross-entropy in f32. logits (..., V), targets (...)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


_XENT_CHUNK = 512


def _head_and_xent(cfg, params, x, targets):
    """Final projection + cross-entropy, chunked over the sequence.

    The unchunked path materializes (B, S, V) f32 logits plus their
    gradient — ~12 GB/device for the 256k-vocab archs at 4k training
    (measured in the dry-run). Chunking the head matmul + xent over
    S/512 slices under jax.checkpoint bounds it at (B, 512, V_shard).
    Returns (loss_mean, acc_mean).
    """
    B, S = targets.shape[0], targets.shape[1]

    def head_logits(xb):
        if cfg.family == "audio":
            lg = jnp.einsum("bsd,cdv->bscv", xb, params["head"])
        else:
            lg = xb @ params["head"]
        return softcap(lg.astype(jnp.float32), cfg.final_softcap)

    if S % _XENT_CHUNK or S <= _XENT_CHUNK:
        logits = head_logits(x)
        acc = jnp.mean((jnp.argmax(logits, -1) == targets)
                       .astype(jnp.float32))
        return _xent(logits, targets), acc

    n_chunks = S // _XENT_CHUNK
    xc = jnp.moveaxis(x.reshape(B, n_chunks, _XENT_CHUNK, -1), 1, 0)
    tc = jnp.moveaxis(targets.reshape((B, n_chunks, _XENT_CHUNK)
                                      + targets.shape[2:]), 1, 0)

    @jax.checkpoint
    def chunk_fn(carry, xt):
        xb, tb = xt
        logits = head_logits(xb)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tb[..., None], axis=-1)[..., 0]
        loss_sum = jnp.sum(logz - gold)
        acc_sum = jnp.sum((jnp.argmax(logits, -1) == tb).astype(jnp.float32))
        return (carry[0] + loss_sum, carry[1] + acc_sum), None

    (loss_sum, acc_sum), _ = jax.lax.scan(
        chunk_fn, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xc, tc))
    n_tok = np.prod(targets.shape)
    return loss_sum / n_tok, acc_sum / n_tok


def lm_loss(cfg: ModelConfig, params, batch, rules=None):
    x, positions = _assemble_input(cfg, params, batch, rules=rules)
    x, aux = tfm.apply_stack_train(cfg, params["stack"], x, positions,
                                   rules=rules)
    x = apply_norm(cfg, params["ln_f"], x)
    npre = _prefix_len(cfg)
    if npre:
        x = x[:, npre:]
    loss, acc = _head_and_xent(cfg, params, x, batch["targets"])
    total = loss + cfg.router_aux_coef * aux
    return total, {"loss": loss, "aux": aux, "acc": acc}


def lm_init_cache(cfg: ModelConfig, batch_size: int, seq_len: int, dtype=None):
    dtype = dtype or _dtype(cfg)
    total = seq_len + _prefix_len(cfg)
    caches, dims = tfm.init_stack_cache(cfg, batch_size, total, dtype)
    return {"layers": caches, "pos": jnp.zeros((), jnp.int32)}, \
           {"layers": dims, "pos": ()}


def lm_prefill(cfg: ModelConfig, params, cache, batch, rules=None):
    """Batched prefill: full forward + cache population.

    Returns (last-token logits, cache positioned after the prompt).
    """
    x, positions = _assemble_input(cfg, params, batch, rules=rules)
    x, new_layers = tfm.apply_stack_prefill(cfg, params["stack"],
                                            cache["layers"], x, positions,
                                            rules=rules)
    x = apply_norm(cfg, params["ln_f"], x)[:, -1]
    if cfg.family == "audio":
        logits = jnp.einsum("bd,cdv->bcv", x, params["head"])
    else:
        logits = x @ params["head"]
    logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    total = positions.shape[0]
    return logits, {"layers": new_layers,
                    "pos": jnp.asarray(total, jnp.int32)}


def lm_decode_step(cfg: ModelConfig, params, cache, tokens, rules=None):
    """One-token decode. tokens: (B,) int32 (or (B, n_codebooks) for audio).

    Returns (logits (B, V) or (B, CB, V), new_cache).
    """
    tok = tokens[:, None] if cfg.family != "audio" else tokens[:, None, :]
    x = _embed_tokens(cfg, params, tok, rules=rules)   # (B, 1, D)
    pos = cache["pos"]
    x, new_layers = tfm.apply_stack_decode(cfg, params["stack"],
                                           cache["layers"], x, pos, rules=rules)
    x = apply_norm(cfg, params["ln_f"], x)[:, 0]
    if cfg.family == "audio":
        logits = jnp.einsum("bd,cdv->bcv", x, params["head"])
    else:
        logits = x @ params["head"]
    logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return logits, {"layers": new_layers, "pos": pos + 1}


# ------------------------------------------------------------------
# paged serving path (see docs/ARCHITECTURE.md §8)
# ------------------------------------------------------------------


def lm_init_paged_cache(cfg: ModelConfig, max_batch: int, n_pages: int,
                        page_size: int, dtype=None):
    """Serving caches: per-spec page pools + stacked recurrent states."""
    dtype = dtype or _dtype(cfg)
    return tfm.init_stack_paged_cache(cfg, max_batch, n_pages, page_size,
                                      dtype)


def lm_paged_decode_step(cfg: ModelConfig, params, caches, tokens, pos_b,
                         tables, page_size: int):
    """One fixed-shape continuous-batching token step.

    tokens: (B,) int32 ((B, CB) for audio); pos_b: (B,) per-sequence
    positions (tokens already cached — inactive slots carry pos 0 and
    write the trash page); tables: (B, TW) block tables. Returns
    (logits (B, V) or (B, CB, V), new_caches).
    """
    tok = tokens[:, None] if cfg.family != "audio" else tokens[:, None, :]
    x = _embed_tokens(cfg, params, tok)                # (B, 1, D)
    x, new_caches = tfm.apply_stack_decode_paged(
        cfg, params["stack"], caches, x, pos_b, tables, page_size)
    x = apply_norm(cfg, params["ln_f"], x)[:, 0]
    if cfg.family == "audio":
        logits = jnp.einsum("bd,cdv->bcv", x, params["head"])
    else:
        logits = x @ params["head"]
    logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return logits, new_caches


def lm_paged_prefill_chunk(cfg: ModelConfig, params, caches, batch, n_valid,
                           slot, tables, page_size: int):
    """Prefill ONE batch slot's prompt chunk into its pages.

    batch: single-sequence batch dict (tokens (1, S_pad), + vis_embeds)
    padded to the engine's static chunk length; n_valid: real token
    count INCLUDING the meta/vis prefix; slot: batch-slot index (traced
    ok). Exact for attention-only stacks at any n_valid (pad K/V goes to
    the trash page, causal masking hides pad queries); recurrent stacks
    additionally require n_valid == S_total — the engine routes those
    through :func:`lm_paged_prefix_fill` + step-prefill instead.
    Returns (next-token logits (1, V)/(1, CB, V), new_caches).
    """
    x, _ = _assemble_input(cfg, params, batch)         # (1, S_total, D)
    table_row = jnp.take(tables, slot, axis=0)         # (TW,)
    x, new_caches = tfm.apply_stack_prefill_paged(
        cfg, params["stack"], caches, x, n_valid, slot, table_row, page_size)
    x = apply_norm(cfg, params["ln_f"], x)
    x_last = jax.lax.dynamic_slice_in_dim(x, n_valid - 1, 1, axis=1)[:, 0]
    if cfg.family == "audio":
        logits = jnp.einsum("bd,cdv->bcv", x_last, params["head"])
    else:
        logits = x_last @ params["head"]
    logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return logits, new_caches


def lm_paged_prefix_fill(cfg: ModelConfig, params, caches, slot, tables,
                         page_size: int, vis_embeds=None):
    """Run the learned/stub prefix (meta tokens, vis embeds) for one slot
    — static exact length, so recurrent states stay bit-exact. The
    engine then feeds the prompt itself through the decode step
    (step-prefill). No-op (error) when the model has no prefix."""
    npre = _prefix_len(cfg)
    assert npre > 0, "prefix fill on a model without a prefix"
    parts = []
    if cfg.n_meta_tokens:
        parts.append(jnp.broadcast_to(params["meta"],
                                      (1,) + params["meta"].shape))
    if cfg.family == "vlm":
        parts.append(vis_embeds.astype(_dtype(cfg)) @ params["vis_proj"])
    x = jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]
    table_row = jnp.take(tables, slot, axis=0)
    _, new_caches = tfm.apply_stack_prefill_paged(
        cfg, params["stack"], caches, x, jnp.asarray(npre, jnp.int32), slot,
        table_row, page_size)
    return new_caches


@dataclasses.dataclass
class LM:
    cfg: ModelConfig

    def init(self, key):
        return init_lm(self.cfg, key)[0]

    def abstract(self):
        """(param ShapeDtypeStructs, logical dims) without allocation."""
        captured = {}

        def f(key):
            params, dims = init_lm(self.cfg, key)
            captured["dims"] = dims
            return params

        shapes = jax.eval_shape(f, jax.random.key(0))
        return shapes, captured["dims"]

    def apply(self, params, batch, rules=None):
        return lm_apply(self.cfg, params, batch, rules=rules)

    def loss(self, params, batch, rules=None):
        return lm_loss(self.cfg, params, batch, rules=rules)

    def init_cache(self, batch_size, seq_len, dtype=None):
        return lm_init_cache(self.cfg, batch_size, seq_len, dtype)

    def cache_abstract(self, batch_size, seq_len, dtype=None):
        captured = {}

        def f():
            cache, dims = lm_init_cache(self.cfg, batch_size, seq_len, dtype)
            captured["dims"] = dims
            return cache

        shapes = jax.eval_shape(f)
        return shapes, captured["dims"]

    def prefill(self, params, cache, batch, rules=None):
        return lm_prefill(self.cfg, params, cache, batch, rules=rules)

    def decode_step(self, params, cache, tokens, rules=None):
        return lm_decode_step(self.cfg, params, cache, tokens, rules=rules)

    # -- paged serving path --------------------------------------------

    def init_paged_cache(self, max_batch, n_pages, page_size, dtype=None):
        return lm_init_paged_cache(self.cfg, max_batch, n_pages, page_size,
                                   dtype)

    def paged_decode_step(self, params, caches, tokens, pos_b, tables,
                          page_size):
        return lm_paged_decode_step(self.cfg, params, caches, tokens, pos_b,
                                    tables, page_size)

    def paged_prefill_chunk(self, params, caches, batch, n_valid, slot,
                            tables, page_size):
        return lm_paged_prefill_chunk(self.cfg, params, caches, batch,
                                      n_valid, slot, tables, page_size)

    def paged_prefix_fill(self, params, caches, slot, tables, page_size,
                          vis_embeds=None):
        return lm_paged_prefix_fill(self.cfg, params, caches, slot, tables,
                                    page_size, vis_embeds=vis_embeds)


def build_model(cfg: ModelConfig) -> LM:
    if cfg.family == "convnet":
        raise ValueError("use repro.models.convnet directly for convnets")
    return LM(cfg)
