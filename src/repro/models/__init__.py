from repro.models.types import ModelConfig, InputShape
from repro.models.registry import build_model, LM
