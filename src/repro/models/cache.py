"""KV / recurrent-state caches for batched decode.

Cache layout (leaves carry a leading ``layers`` axis so the decode step
scans over layers with the per-layer cache as scan xs/ys):

- attention: ``k``/``v``: (L, B, C, Hkv, D) with C = min(seq_len, window);
  a ring buffer under sliding windows. ``k_pos``: (C,) global positions of
  each slot (-1 = empty, masked out).
- ssm (mamba/mLSTM/sLSTM): constant-size per-layer state tensors.

``pos`` is the number of tokens already consumed (scalar int32).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attn_cache_len(seq_len: int, window) -> int:
    return seq_len if window is None else min(seq_len, window)


def init_attn_cache(n_layers, batch, cache_len, n_kv, head_dim, dtype):
    params = {
        "k": jnp.zeros((n_layers, batch, cache_len, n_kv, head_dim), dtype),
        "v": jnp.zeros((n_layers, batch, cache_len, n_kv, head_dim), dtype),
    }
    dims = {
        "k": ("layers", "batch", "seq", "kv_heads", "head_dim"),
        "v": ("layers", "batch", "seq", "kv_heads", "head_dim"),
    }
    return params, dims


def update_attn_cache(layer_cache, k_new, v_new, pos):
    """Write one token's K/V at ring slot ``pos % C``. k_new: (B,1,Hkv,D)."""
    C = layer_cache["k"].shape[1]
    slot = jnp.mod(pos, C)
    k = jax.lax.dynamic_update_slice_in_dim(layer_cache["k"], k_new, slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(layer_cache["v"], v_new, slot, axis=1)
    return {"k": k, "v": v}


def cache_positions(cache_len: int, pos):
    """Global position held by each ring slot after ``pos+1`` writes.

    Slot s holds the largest position p <= pos with p % C == s; slots never
    written yet get -1 (masked).
    """
    slots = jnp.arange(cache_len)
    rem = jnp.mod(pos, cache_len)
    p = jnp.where(slots <= rem, pos - rem + slots, pos - rem + slots - cache_len)
    return jnp.where(p >= 0, p, -1)


# ------------------------------------------------------------------
# paged KV cache (serving tier)
# ------------------------------------------------------------------
#
# The serving engine replaces the contiguous (L, B, C, Hkv, D) cache with
# a PAGE POOL of shape (L, n_pages, page_size, Hkv, D) plus a per-sequence
# block table (table_width,) of physical page indices. The table is a
# *logical ring* at page granularity — slot j of a sequence at logical
# page m holds the largest page m' <= m with m' % table_width == j —
# the exact ``cache_positions`` recurrence lifted from tokens to pages,
# so sliding-window eviction is ring reuse (overwrite in place, zero
# copy traffic) and the table width is fixed at trace time. Physical
# page 0 is reserved as the TRASH page: inactive batch slots write/read
# it and are masked out by their zero sequence length.

#: physical page index reserved for masked writes of inactive slots
TRASH_PAGE = 0


def paged_table_width(max_seq: int, window, page_size: int) -> int:
    """Block-table slots needed so ring reuse never evicts a live key.

    Windowed: positions (pos-W, pos] span at most ceil(W/ps)+1 pages;
    ring reuse of slot (m % TW) evicts page m-TW, whose last position
    (m-TW+1)*ps-1 must already be outside the window when page m opens
    at pos = m*ps — i.e. TW >= (W-1)/ps + 1, satisfied by ceil(W/ps)+1.
    """
    n_total = -(-max_seq // page_size)
    if window is None:
        return n_total
    return min(n_total, -(-window // page_size) + 1)


def paged_slot_pages(table_width: int, cur_page):
    """Logical page held by each table slot when the sequence is at
    logical page ``cur_page`` (= pos // page_size). -1 = never written.
    ``cur_page`` may be batched: (...,) -> (..., table_width)."""
    slots = jnp.arange(table_width)
    cur = jnp.asarray(cur_page)[..., None]
    rem = jnp.mod(cur, table_width)
    p = jnp.where(slots <= rem, cur - rem + slots,
                  cur - rem + slots - table_width)
    return jnp.where(p >= 0, p, -1)


def init_paged_pool(n_layers, n_pages, page_size, n_kv, head_dim, dtype):
    """Per-layer-spec page pool; physical page indices are shared across
    the stacked layers (index [l, page] addresses layer l's copy)."""
    shape = (n_layers, n_pages, page_size, n_kv, head_dim)
    pool = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    dims = {"k": ("layers", "pages", "page_slot", "kv_heads", "head_dim"),
            "v": ("layers", "pages", "page_slot", "kv_heads", "head_dim")}
    return pool, dims


def paged_phys_pages(tables, pos_b, page_size: int):
    """Physical page + in-page slot for writing position ``pos_b``.

    tables: (B, TW) int32; pos_b: (B,). Returns (phys (B,), slot (B,)).
    """
    TW = tables.shape[1]
    tj = jnp.mod(pos_b // page_size, TW)
    phys = jnp.take_along_axis(tables, tj[:, None], axis=1)[:, 0]
    return phys, jnp.mod(pos_b, page_size)
