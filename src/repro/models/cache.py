"""KV / recurrent-state caches for batched decode.

Cache layout (leaves carry a leading ``layers`` axis so the decode step
scans over layers with the per-layer cache as scan xs/ys):

- attention: ``k``/``v``: (L, B, C, Hkv, D) with C = min(seq_len, window);
  a ring buffer under sliding windows. ``k_pos``: (C,) global positions of
  each slot (-1 = empty, masked out).
- ssm (mamba/mLSTM/sLSTM): constant-size per-layer state tensors.

``pos`` is the number of tokens already consumed (scalar int32).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attn_cache_len(seq_len: int, window) -> int:
    return seq_len if window is None else min(seq_len, window)


def init_attn_cache(n_layers, batch, cache_len, n_kv, head_dim, dtype):
    params = {
        "k": jnp.zeros((n_layers, batch, cache_len, n_kv, head_dim), dtype),
        "v": jnp.zeros((n_layers, batch, cache_len, n_kv, head_dim), dtype),
    }
    dims = {
        "k": ("layers", "batch", "seq", "kv_heads", "head_dim"),
        "v": ("layers", "batch", "seq", "kv_heads", "head_dim"),
    }
    return params, dims


def update_attn_cache(layer_cache, k_new, v_new, pos):
    """Write one token's K/V at ring slot ``pos % C``. k_new: (B,1,Hkv,D)."""
    C = layer_cache["k"].shape[1]
    slot = jnp.mod(pos, C)
    k = jax.lax.dynamic_update_slice_in_dim(layer_cache["k"], k_new, slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(layer_cache["v"], v_new, slot, axis=1)
    return {"k": k, "v": v}


def cache_positions(cache_len: int, pos):
    """Global position held by each ring slot after ``pos+1`` writes.

    Slot s holds the largest position p <= pos with p % C == s; slots never
    written yet get -1 (masked).
    """
    slots = jnp.arange(cache_len)
    rem = jnp.mod(pos, cache_len)
    p = jnp.where(slots <= rem, pos - rem + slots, pos - rem + slots - cache_len)
    return jnp.where(p >= 0, p, -1)
