"""Mixture-of-Experts layer (qwen2-moe / granite-moe style).

Default path ("tensor-parallel experts"): every device holds all experts,
sharded on the expert-hidden dim (``mlp`` → model axis). Tokens are routed
with a sort + ``jax.lax.ragged_dot`` — no (N, E, C) dispatch tensor, no
capacity drops, SPMD-friendly, differentiable.

Optional path (``cfg.expert_parallel``, requires E % model_axis == 0):
experts sharded over the model axis; tokens exchanged with an explicit
``shard_map`` + ``lax.all_to_all`` using a static per-expert capacity.
This is the collective-heavy configuration the roofline analysis studies.

SwiGLU experts; optional shared experts with a sigmoid gate (qwen2-moe has
4 always-on shared experts next to the 60 routed ones).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.common.compat import shard_map
from repro.models.common import activation, normal_init


def init_moe(cfg, key, dtype):
    D, E, F = cfg.d_model, cfg.n_experts, cfg.expert_d_ff or cfg.d_ff
    keys = jax.random.split(key, 8)
    params, dims = {}, {}
    params["router"], dims["router"] = normal_init(
        keys[0], (D, E), ("embed", "experts"), jnp.float32, fan_in=D)
    params["w_gate"], dims["w_gate"] = normal_init(
        keys[1], (E, D, F), ("experts", "embed", "mlp"), dtype, fan_in=D)
    params["w_up"], dims["w_up"] = normal_init(
        keys[2], (E, D, F), ("experts", "embed", "mlp"), dtype, fan_in=D)
    params["w_down"], dims["w_down"] = normal_init(
        keys[3], (E, F, D), ("experts", "mlp", "embed"), dtype, fan_in=F)
    if cfg.n_shared_experts:
        Fs = cfg.n_shared_experts * F
        params["sh_gate"], dims["sh_gate"] = normal_init(
            keys[4], (D, Fs), ("embed", "mlp"), dtype, fan_in=D)
        params["sh_up"], dims["sh_up"] = normal_init(
            keys[5], (D, Fs), ("embed", "mlp"), dtype, fan_in=D)
        params["sh_down"], dims["sh_down"] = normal_init(
            keys[6], (Fs, D), ("mlp", "embed"), dtype, fan_in=Fs)
        params["sh_route"], dims["sh_route"] = normal_init(
            keys[7], (D, 1), ("embed", None), jnp.float32, fan_in=D)
    return params, dims


def _route(cfg, p, xf):
    """Top-k routing. xf: (N, D) -> probs (N,k), ids (N,k), aux loss."""
    logits = (xf.astype(jnp.float32) @ p["router"])            # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)      # renormalize
    # Switch-style load-balance loss: E * sum_e f_e * P_e
    E = cfg.n_experts
    occupancy = jnp.zeros((E,), jnp.float32).at[top_i.reshape(-1)].add(1.0)
    f = occupancy / (xf.shape[0] * cfg.top_k)
    P = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f * P)
    return top_p, top_i, aux


def _expert_ffn_ragged(cfg, p, tokens, group_sizes):
    """tokens: (M, D) sorted by expert; group_sizes: (E,)."""
    act = activation(cfg.act)
    g = jax.lax.ragged_dot(tokens, p["w_gate"], group_sizes)
    u = jax.lax.ragged_dot(tokens, p["w_up"], group_sizes)
    h = (act(g.astype(jnp.float32)) * u.astype(jnp.float32)).astype(tokens.dtype)
    return jax.lax.ragged_dot(h, p["w_down"], group_sizes)


def moe_forward(cfg, p, x):
    """x: (B, S, D) -> (out, aux_loss). Tensor-parallel-experts path."""
    B, S, D = x.shape
    N = B * S
    xf = x.reshape(N, D)
    top_p, top_i, aux = _route(cfg, p, xf)

    k = cfg.top_k
    flat_e = top_i.reshape(-1)                                  # (N*k,)
    token_of = jnp.arange(N * k) // k
    order = jnp.argsort(flat_e)                                 # stable
    sorted_tok = jnp.take(xf, token_of[order], axis=0)          # (N*k, D)
    group_sizes = jnp.zeros((cfg.n_experts,), jnp.int32).at[flat_e].add(1)
    out_sorted = _expert_ffn_ragged(cfg, p, sorted_tok, group_sizes)
    out_sorted = out_sorted * top_p.reshape(-1)[order][:, None].astype(out_sorted.dtype)
    out = jnp.zeros((N, D), jnp.float32).at[token_of[order]].add(
        out_sorted.astype(jnp.float32))

    if cfg.n_shared_experts:
        act = activation(cfg.act)
        h = (act((xf @ p["sh_gate"]).astype(jnp.float32))
             * (xf @ p["sh_up"]).astype(jnp.float32)).astype(x.dtype)
        shared = (h @ p["sh_down"]).astype(jnp.float32)
        gate = jax.nn.sigmoid((xf.astype(jnp.float32) @ p["sh_route"]))
        out = out + gate * shared

    return out.reshape(B, S, D).astype(x.dtype), aux


def _capacity_ffn(cfg, p, xf, top_p, top_i, capacity_factor=1.25):
    """Capacity-based dispatch: (E, C, D) buffer + dense batched einsums.

    Replaces ``ragged_dot`` at scale — its CPU lowering materializes a
    (N·k, E·D) block-diagonal operand (129 GB/device for qwen2 train_4k).
    Tokens beyond an expert's capacity C = N·k·cf/E are dropped (standard
    Switch/GShard semantics; cf defaults to 1.25).
    """
    N, D = xf.shape
    E, k = cfg.n_experts, cfg.top_k
    C = max(int(N * k * capacity_factor) // E, 8)
    flat_e = top_i.reshape(-1)
    token_of = jnp.arange(N * k) // k
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    rank = (jnp.cumsum(onehot, axis=0) - 1)[jnp.arange(N * k), flat_e]
    keep = rank < C
    safe_rank = jnp.where(keep, rank, 0)
    buf = jnp.zeros((E, C, D), xf.dtype)
    buf = buf.at[flat_e, safe_rank].add(
        jnp.where(keep[:, None], jnp.take(xf, token_of, axis=0), 0))
    act = activation(cfg.act)
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    h = (act(g.astype(jnp.float32)) * u.astype(jnp.float32)).astype(buf.dtype)
    y = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    out_pairs = y[flat_e, safe_rank]
    out_pairs = jnp.where(keep[:, None], out_pairs, 0)
    out_pairs = out_pairs * top_p.reshape(-1)[:, None].astype(out_pairs.dtype)
    return jnp.zeros((N, D), jnp.float32).at[token_of].add(
        out_pairs.astype(jnp.float32))


def moe_forward_capacity(cfg, p, x, capacity_factor=1.25):
    """moe_forward with capacity dispatch (the at-scale kernel)."""
    B, S, D = x.shape
    xf = x.reshape(B * S, D)
    top_p, top_i, aux = _route(cfg, p, xf)
    out = _capacity_ffn(cfg, p, xf, top_p, top_i, capacity_factor)
    if cfg.n_shared_experts:
        act = activation(cfg.act)
        h = (act((xf @ p["sh_gate"]).astype(jnp.float32))
             * (xf @ p["sh_up"]).astype(jnp.float32)).astype(x.dtype)
        shared = (h @ p["sh_down"]).astype(jnp.float32)
        gate = jax.nn.sigmoid(xf.astype(jnp.float32) @ p["sh_route"])
        out = out + gate * shared
    return out.reshape(B, S, D).astype(x.dtype), aux


def moe_forward_sharded(cfg, p, x, rules):
    """Tensor-parallel-experts MoE with *local* routing (shard_map).

    Auto-partitioning the sort-based dispatch replicates the globally
    sorted (N·k, D) token buffer on every device (dry-run: 290 GB/device
    at train_4k, 2.1 TB at prefill_32k). Wrapping the layer in shard_map
    keeps argsort/gather/scatter local to each data shard; expert weights
    stay sharded on d_ff over the model axis, so the only collective is
    the partial-sum psum of the expert output over "model".
    """
    from jax.sharding import PartitionSpec as P

    mesh = rules.mesh
    data_axes = tuple(a for a in ("replica", "pod", "data")
                      if a in mesh.shape)
    maxis = "model" if "model" in mesh.shape else None
    if maxis is None:
        return moe_forward(cfg, p, x)
    F = cfg.expert_d_ff or cfg.d_ff
    if F % mesh.shape[maxis]:
        return moe_forward(cfg, p, x)
    bsz = 1
    for a in data_axes:
        bsz *= mesh.shape[a]
    bspec = data_axes if (x.shape[0] % max(bsz, 1) == 0) else ()

    def local(xl, pl):
        out, aux = moe_forward_capacity(cfg, pl, xl,
                                        cfg.moe_capacity_factor)
        out = jax.lax.psum(out.astype(jnp.float32), maxis).astype(xl.dtype)
        if bspec:
            aux = jax.lax.pmean(aux, bspec)
        return out, aux

    p_specs = {
        "router": P(),
        "w_gate": P(None, None, maxis),
        "w_up": P(None, None, maxis),
        "w_down": P(None, maxis, None),
    }
    if cfg.n_shared_experts:
        p_specs.update({"sh_gate": P(None, maxis), "sh_up": P(None, maxis),
                        "sh_down": P(maxis, None), "sh_route": P()})
    x_spec = P(bspec if bspec else None, None, None)
    fn = shard_map(local, mesh=mesh,
                       in_specs=(x_spec, p_specs),
                       out_specs=(x_spec, P()),
                       check_vma=False)
    return fn(x, p)


# ------------------------------------------------------------ EP path


def moe_forward_ep(cfg, p, x, *, mesh, axis: str = "model",
                   capacity_factor: float | None = None):
    """Expert-parallel MoE with explicit all-to-all (shard_map).

    Experts are sharded over ``axis``; each device dispatches a static
    per-expert capacity C of its local tokens, exchanges them with
    all-to-all, runs its local experts, and reverses the exchange.
    Requires cfg.n_experts % mesh.shape[axis] == 0.
    """
    from jax.sharding import PartitionSpec as P

    E = cfg.n_experts
    n_shards = mesh.shape[axis]
    assert E % n_shards == 0, (E, n_shards)
    capacity_factor = capacity_factor or cfg.moe_capacity_factor
    data_axes = tuple(a for a in ("replica", "pod", "data") if a in mesh.shape)

    def local_fn(xl, router, w_gate, w_up, w_down):
        B, S, D = xl.shape
        N = B * S
        xf = xl.reshape(N, D)
        pl = {"router": router, "w_gate": w_gate, "w_up": w_up,
              "w_down": w_down}
        top_p, top_i, aux = _route(cfg, pl, xf)
        C = max(int(N * cfg.top_k * capacity_factor) // E, 8)

        flat_e = top_i.reshape(-1)
        token_of = jnp.arange(N * cfg.top_k) // cfg.top_k
        # rank of each (token, expert) pair within its expert
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
        rank = (jnp.cumsum(onehot, axis=0) - 1)[jnp.arange(N * cfg.top_k), flat_e]
        keep = rank < C
        # dispatch buffer (E, C, D)
        buf = jnp.zeros((E, C, D), xl.dtype)
        buf = buf.at[flat_e, jnp.where(keep, rank, 0)].add(
            jnp.where(keep[:, None], jnp.take(xf, token_of, axis=0), 0))
        # exchange: (E, C, D) -> (E/n, n*C, D) on each shard (tiled form:
        # handles E > n and has a well-defined transpose under vmap/scan)
        buf = jax.lax.all_to_all(buf, axis, split_axis=0, concat_axis=1,
                                 tiled=True)
        act = activation(cfg.act)
        # local experts: leading dim already sharded by shard_map in_specs.
        # preferred_element_type keeps operands bf16 so the all_to_all VJP
        # receives a matching-dtype cotangent (explicit f32 casts here made
        # the a2a transpose fail with an f32 cotangent for a bf16 primal).
        g = jnp.einsum("ecd,edf->ecf", buf, w_gate,
                       preferred_element_type=jnp.float32)
        u = jnp.einsum("ecd,edf->ecf", buf, w_up,
                       preferred_element_type=jnp.float32)
        h = (act(g) * u).astype(buf.dtype)
        y = jnp.einsum("ecf,efd->ecd", h, w_down)
        # reverse exchange: (E/n, n*C, D) -> (E, C, D)
        y = jax.lax.all_to_all(y, axis, split_axis=1, concat_axis=0,
                               tiled=True)
        # gather back to tokens
        out_pairs = y[flat_e, jnp.where(keep, rank, 0)]
        out_pairs = jnp.where(keep[:, None], out_pairs, 0)
        out_pairs = out_pairs * top_p.reshape(-1)[:, None].astype(out_pairs.dtype)
        out = jnp.zeros((N, D), jnp.float32).at[token_of].add(
            out_pairs.astype(jnp.float32))
        if data_axes:
            aux = jax.lax.pmean(aux, data_axes)
        return out.reshape(B, S, D).astype(xl.dtype), aux

    # tokens are sharded over the model axis too (via the seq dim) so the
    # n expert-shards dispatch DISTINCT tokens — with seq replicated every
    # model rank redundantly processed identical buffers (measured 5.7×
    # FLOPs). Falls back to batch-only sharding when S % n != 0 (decode).
    seq_axis = axis if x.shape[1] % n_shards == 0 else None
    batch_spec = P(data_axes if data_axes else None, seq_axis)
    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(batch_spec, P(), P(axis), P(axis), P(axis)),
        out_specs=(batch_spec, P()),
        check_vma=False)
    out, aux = fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return out, aux
