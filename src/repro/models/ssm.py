"""Recurrent sequence-mixing cells: mLSTM + sLSTM (xLSTM) and Mamba heads
(Hymba's parallel-SSM branch).

All cells share one calling convention so training, prefill and cached
decode use the same code path:

    y, state_out = <cell>_scan(cfg, params, x, state_in)

with x: (B, T, ...) and constant-size state pytrees — T=1 with a carried
state is exactly the decode step. Training passes the zero state.

TPU note (DESIGN.md §2): the recurrences are expressed as ``lax.scan`` over
time — sequential but VMEM-resident state; the chunkwise-parallel form is
a recorded beyond-paper optimization lever, not required for HWA itself.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import apply_norm, normal_init


def _rms_head_norm(x, eps=1e-6):
    """Per-head RMS norm (GroupNorm-style) over the last dim, no params."""
    xf = x.astype(jnp.float32)
    return (xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
            ).astype(x.dtype)


def _causal_conv(x, kernel, conv_state=None):
    """Depthwise causal 1-D conv. x: (B, T, C), kernel: (K, C).

    If ``conv_state`` (B, K-1, C) is given it is prepended (decode path) and
    the updated state is returned; otherwise zero left-padding (train path).
    """
    K = kernel.shape[0]
    if conv_state is None:
        pad = jnp.zeros(x.shape[:1] + (K - 1,) + x.shape[2:], x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                     # (B, T+K-1, C)
    out = sum(xp[:, i:i + x.shape[1]] * kernel[i] for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else pad
    return out, new_state


# ===================================================================
# mLSTM (matrix-memory LSTM) — xLSTM [arXiv:2405.04517] eq. (19)-(27)
# ===================================================================


def init_mlstm(cfg, key, dtype):
    D = cfg.d_model
    H = cfg.n_heads
    d_inner = 2 * D                       # proj_factor 2 (xLSTM default)
    P = d_inner // H
    ks = jax.random.split(key, 8)
    params, dims = {}, {}
    params["w_up"], dims["w_up"] = normal_init(
        ks[0], (D, 2 * d_inner), ("embed", "mlp"), dtype, fan_in=D)
    params["conv"], dims["conv"] = normal_init(
        ks[1], (cfg.conv_kernel, d_inner), (None, "mlp"), dtype,
        fan_in=cfg.conv_kernel)
    params["w_q"], dims["w_q"] = normal_init(
        ks[2], (d_inner, d_inner), ("mlp", None), dtype, fan_in=d_inner)
    params["w_k"], dims["w_k"] = normal_init(
        ks[3], (d_inner, d_inner), ("mlp", None), dtype, fan_in=d_inner)
    params["w_v"], dims["w_v"] = normal_init(
        ks[4], (d_inner, d_inner), ("mlp", None), dtype, fan_in=d_inner)
    params["w_if"], dims["w_if"] = normal_init(
        ks[5], (d_inner, 2 * H), ("mlp", None), jnp.float32, fan_in=d_inner)
    params["b_if"] = jnp.concatenate(
        [jnp.zeros((H,), jnp.float32), 3.0 * jnp.ones((H,), jnp.float32)])
    dims["b_if"] = (None,)
    params["w_out"], dims["w_out"] = normal_init(
        ks[6], (d_inner, D), ("mlp", "embed"), dtype, fan_in=d_inner)
    return params, dims


def init_mlstm_state(cfg, batch, dtype=jnp.float32):
    D, H = cfg.d_model, cfg.n_heads
    d_inner = 2 * D
    P = d_inner // H
    return {
        "C": jnp.zeros((batch, H, P, P), jnp.float32),
        "n": jnp.zeros((batch, H, P), jnp.float32),
        "m": jnp.zeros((batch, H), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, d_inner), dtype),
    }


def mlstm_state_dims(cfg):
    return {"C": ("batch", "heads", None, None), "n": ("batch", "heads", None),
            "m": ("batch", "heads"), "conv": ("batch", None, "mlp")}


MLSTM_CHUNK = 256


def _pick_chunk(T: int, target: int) -> int:
    """Largest divisor of T ≤ target (sequences with meta-token prefixes
    are not powers of two; a non-divisible chunk would silently fall back
    to the O(T·state) sequential scan — 28 GB/device for hymba train)."""
    if target <= 0 or T < 2 * 32:
        return 0
    for b in range(min(target, T), 31, -1):
        if T % b == 0:
            return b
    return 0


def _mlstm_chunkwise(q, k, v, i_pre, f_pre, state, chunk: int):
    """Chunkwise-parallel mLSTM (xLSTM App. A parallel form + stabilizer).

    Sequential-scan backward would store the (P,P) matrix memory per time
    step (O(T·P²) residuals — the 34 GB/device OOM found in the dry-run);
    chunkwise stores it only at the T/chunk boundaries and computes
    intra-chunk interactions as a masked (L×L) decay-score matmul.
    q/k/v: (B,T,H,P); i_pre/f_pre: (B,T,H). Returns (h (B,T,H,P), state').
    """
    B, T, H, P = q.shape
    L = chunk
    nc = T // L
    f32 = jnp.float32

    def to_chunks(a, tail):  # (B,T,...) -> (nc, B, H, L, ...)
        a = jnp.moveaxis(a.reshape(B, nc, L, *tail), 1, 0)
        return jnp.swapaxes(a, 2, 3) if len(tail) == 2 else jnp.swapaxes(a, -1, -2)

    qc = to_chunks(q.astype(f32), (H, P))            # (nc,B,H,L,P)
    kc = to_chunks(k.astype(f32), (H, P))
    vc = to_chunks(v.astype(f32), (H, P))
    ic = to_chunks(i_pre.astype(f32), (H,))          # (nc,B,H,L)
    logf = -jax.nn.softplus(-f_pre.astype(f32))
    fc = to_chunks(logf, (H,))

    mask = jnp.tril(jnp.ones((L, L), bool))

    def chunk_step(carry, xs):
        C0, n0, m0 = carry                           # (B,H,P,P),(B,H,P),(B,H)
        qb, kb, vb, ib, fb = xs
        b = jnp.cumsum(fb, axis=-1)                  # (B,H,L)
        g = jax.lax.cummax(ib - b, axis=ib.ndim - 1)
        m = b + jnp.maximum(m0[..., None], g)        # (B,H,L)
        # intra-chunk decay scores: exp(b_t - m_t + i_s - b_s), s<=t
        logS = (b - m)[..., :, None] + (ib - b)[..., None, :]
        S = jnp.where(mask, jnp.exp(logS), 0.0)      # (B,H,L,L)
        qk = jnp.einsum("bhtp,bhsp->bhts", qb, kb)
        num = jnp.einsum("bhts,bhsp->bhtp", S * qk, vb)
        den = jnp.einsum("bhts,bhts->bht", S, qk)
        decay0 = jnp.exp(b + m0[..., None] - m)      # (B,H,L)
        num = num + decay0[..., None] * jnp.einsum("bhpq,bhtq->bhtp", C0, qb)
        den = den + decay0 * jnp.einsum("bhq,bhtq->bht", n0, qb)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m))[..., None]
        # carry to next chunk
        mL = m[..., -1]
        w = jnp.exp(b[..., -1:] - b + ib - mL[..., None])   # (B,H,L)
        CL = (jnp.exp(b[..., -1] + m0 - mL)[..., None, None] * C0
              + jnp.einsum("bhs,bhsp,bhsq->bhpq", w, vb, kb))
        nL = (jnp.exp(b[..., -1] + m0 - mL)[..., None] * n0
              + jnp.einsum("bhs,bhsp->bhp", w, kb))
        return (CL, nL, mL), h

    (C, n, m), hs = jax.lax.scan(
        chunk_step, (state["C"], state["n"], state["m"]),
        (qc, kc, vc, ic, fc))
    # hs: (nc, B, H, L, P) -> (B, T, H, P)
    h = jnp.moveaxis(hs, 0, 1).swapaxes(2, 3).reshape(B, T, H, P)
    return h, {"C": C, "n": n, "m": m}


def mlstm_scan(cfg, p, x, state):
    """x: (B, T, D) -> (y: (B, T, D), state')."""
    B, T, D = x.shape
    H = cfg.n_heads
    d_inner = 2 * D
    P = d_inner // H
    up = x @ p["w_up"]
    xm, z = jnp.split(up, 2, axis=-1)                           # (B,T,d_inner)
    xc, conv_state = _causal_conv(xm, p["conv"], state["conv"])
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)
    q = (xc @ p["w_q"]).reshape(B, T, H, P)
    k = (xc @ p["w_k"]).reshape(B, T, H, P) / jnp.sqrt(P).astype(x.dtype)
    v = (xm @ p["w_v"]).reshape(B, T, H, P)
    gates = xc.astype(jnp.float32) @ p["w_if"] + p["b_if"]      # (B,T,2H)
    i_pre, f_pre = jnp.split(gates, 2, axis=-1)                  # (B,T,H)

    chunk = _pick_chunk(T, MLSTM_CHUNK)
    if chunk and T >= 2 * chunk:
        hs_bthp, new_carry = _mlstm_chunkwise(q, k, v, i_pre, f_pre, state,
                                              chunk)
        h = _rms_head_norm(hs_bthp).reshape(B, T, d_inner).astype(x.dtype)
        y = (h * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)) @ p["w_out"]
        return y, {**new_carry, "conv": conv_state}

    def step(carry, t_in):
        C, n, m = carry
        qt, kt, vt, it, ft = t_in                                # (B,H,P) ...
        log_f = -jax.nn.softplus(-ft)                            # log sigmoid(f)
        m_new = jnp.maximum(log_f + m, it)
        i_g = jnp.exp(it - m_new)                                # (B,H)
        f_g = jnp.exp(log_f + m - m_new)
        kf, vf, qf = (a.astype(jnp.float32) for a in (kt, vt, qt))
        C_new = f_g[..., None, None] * C + i_g[..., None, None] * (
            vf[..., :, None] * kf[..., None, :])                 # (B,H,P,P)
        n_new = f_g[..., None] * n + i_g[..., None] * kf
        num = jnp.einsum("bhpq,bhq->bhp", C_new, qf)
        # true-scale denominator max(|n·q|, 1) expressed in stabilized space
        den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", n_new, qf)),
                          jnp.exp(-m_new))
        h = num / den[..., None]
        return (C_new, n_new, m_new), h.astype(x.dtype)

    xs = (q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
          i_pre.swapaxes(0, 1), f_pre.swapaxes(0, 1))
    (C, n, m), hs = jax.lax.scan(step, (state["C"], state["n"], state["m"]), xs)
    h = hs.swapaxes(0, 1).reshape(B, T, d_inner)                 # (B,T,H*P)
    h = _rms_head_norm(h.reshape(B, T, H, P)).reshape(B, T, d_inner)
    y = (h * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)) @ p["w_out"]
    return y, {"C": C, "n": n, "m": m, "conv": conv_state}


# ===================================================================
# sLSTM (scalar-memory LSTM with exponential gating + recurrence)
# ===================================================================


def init_slstm(cfg, key, dtype):
    D, H = cfg.d_model, cfg.n_heads
    P = D // H
    ks = jax.random.split(key, 4)
    params, dims = {}, {}
    params["w_in"], dims["w_in"] = normal_init(
        ks[0], (D, 4 * D), ("embed", "mlp"), dtype, fan_in=D)     # z,i,f,o
    params["r"], dims["r"] = normal_init(
        ks[1], (H, P, 4 * P), ("heads", None, None), jnp.float32, fan_in=P)
    params["b"] = jnp.zeros((4 * D,), jnp.float32)
    params["b"] = params["b"].at[2 * D:3 * D].set(3.0)            # f-gate bias
    dims["b"] = (None,)
    params["w_out"], dims["w_out"] = normal_init(
        ks[2], (D, D), ("embed", "embed2"), dtype, fan_in=D)
    # post-cell FFN (xLSTM sLSTM blocks carry one)
    ff = max(2 * D, 64)
    params["ff_up"], dims["ff_up"] = normal_init(
        ks[3], (D, ff), ("embed", "mlp"), dtype, fan_in=D)
    params["ff_down"], dims["ff_down"] = normal_init(
        jax.random.fold_in(ks[3], 1), (ff, D), ("mlp", "embed"), dtype, fan_in=ff)
    return params, dims


def init_slstm_state(cfg, batch, dtype=jnp.float32):
    D, H = cfg.d_model, cfg.n_heads
    P = D // H
    z = lambda: jnp.zeros((batch, H, P), jnp.float32)
    return {"c": z(), "n": z(), "m": z(), "h": z()}


def slstm_state_dims(cfg):
    d = ("batch", "heads", None)
    return {"c": d, "n": d, "m": d, "h": d}


def slstm_scan(cfg, p, x, state, rules=None):
    B, T, D = x.shape
    H = cfg.n_heads
    P = D // H
    pre_in = (x @ p["w_in"]).astype(jnp.float32) + p["b"]        # (B,T,4D)
    # NOTE: an explicit gather of the model-sharded 4D dim here was tried
    # and REFUTED (EXPERIMENTS.md §Perf pair 2-adjacent): the forced f32
    # replication + its reverse reduce-scatter cost MORE (ICI 54→165
    # GB/step) than the many small per-step permutes it removed.
    del rules

    def step(carry, pre_t):
        c, n, m, h = carry
        rec = jnp.einsum("bhp,hpq->bhq", h, p["r"])              # (B,H,4P)
        # pre_t is (B, 4D) laid out as [z | i | f | o], each (B, H, P).
        pre = pre_t.reshape(B, 4, H, P).transpose(0, 2, 1, 3).reshape(B, H, 4 * P)
        zp, ip, fp, op = jnp.split(pre + rec, 4, axis=-1)        # (B,H,P)
        z_ = jnp.tanh(zp)
        o = jax.nn.sigmoid(op)
        log_f = -jax.nn.softplus(-fp)
        m_new = jnp.maximum(log_f + m, ip)
        i_g = jnp.exp(ip - m_new)
        f_g = jnp.exp(log_f + m - m_new)
        c_new = f_g * c + i_g * z_
        n_new = f_g * n + i_g
        h_new = o * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, m_new, h_new), h_new

    (c, n, m, h), hs = jax.lax.scan(
        step, (state["c"], state["n"], state["m"], state["h"]),
        pre_in.swapaxes(0, 1))
    y = hs.swapaxes(0, 1)                                        # (B,T,H,P) f32
    y = _rms_head_norm(y).reshape(B, T, D).astype(x.dtype)
    y = y @ p["w_out"]
    ff = jax.nn.gelu((y @ p["ff_up"]).astype(jnp.float32)).astype(x.dtype)
    y = y + ff @ p["ff_down"]
    return y, {"c": c, "n": n, "m": m, "h": h}


# ===================================================================
# Mamba2-style selective-SSM heads (Hymba's parallel branch)
# ===================================================================


def init_mamba(cfg, key, dtype):
    D = cfg.d_model
    H = cfg.ssm_heads or cfg.n_heads
    N = cfg.ssm_state
    d_inner = D                                # hymba: SSM branch width = D
    ks = jax.random.split(key, 6)
    params, dims = {}, {}
    params["w_in"], dims["w_in"] = normal_init(
        ks[0], (D, 2 * d_inner), ("embed", "mlp"), dtype, fan_in=D)
    params["conv"], dims["conv"] = normal_init(
        ks[1], (cfg.conv_kernel, d_inner), (None, "mlp"), dtype,
        fan_in=cfg.conv_kernel)
    params["w_bc"], dims["w_bc"] = normal_init(
        ks[2], (d_inner, 2 * N), ("mlp", None), dtype, fan_in=d_inner)
    params["w_dt"], dims["w_dt"] = normal_init(
        ks[3], (d_inner, H), ("mlp", "ssm_heads"), jnp.float32, fan_in=d_inner)
    params["dt_bias"] = jnp.zeros((H,), jnp.float32)
    dims["dt_bias"] = ("ssm_heads",)
    params["A_log"] = jnp.log(jnp.ones((H,), jnp.float32))
    dims["A_log"] = ("ssm_heads",)
    params["D_skip"] = jnp.ones((H,), jnp.float32)
    dims["D_skip"] = ("ssm_heads",)
    params["w_out"], dims["w_out"] = normal_init(
        ks[4], (d_inner, D), ("mlp", "embed"), dtype, fan_in=d_inner)
    return params, dims


def init_mamba_state(cfg, batch, dtype=jnp.float32):
    D = cfg.d_model
    H = cfg.ssm_heads or cfg.n_heads
    N = cfg.ssm_state
    P = D // H
    return {"S": jnp.zeros((batch, H, P, N), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_kernel - 1, D), dtype)}


def mamba_state_dims(cfg):
    return {"S": ("batch", "ssm_heads", None, None),
            "conv": ("batch", None, "mlp")}


MAMBA_CHUNK = 256


def _mamba_chunkwise(xh, b_in, c_out, dt, a, state, chunk: int):
    """Chunkwise-parallel selective SSM (Mamba2 SSD form).

    Same motivation as ``_mlstm_chunkwise``: the sequential backward stores
    the (P,N) state per step; chunkwise stores it per chunk boundary. No
    stabilizer needed — the decay exp(dt·a) is ≤ 1.
    xh: (B,T,H,P); b_in/c_out: (B,T,N); dt: (B,T,H); a: (H,).
    """
    B, T, H, P = xh.shape
    N = b_in.shape[-1]
    L = chunk
    nc = T // L
    la = dt * a                                        # (B,T,H) log-decay ≤ 0

    xc_ = jnp.moveaxis(xh.reshape(B, nc, L, H, P), 1, 0).swapaxes(2, 3)
    dtc = jnp.moveaxis(dt.reshape(B, nc, L, H), 1, 0).swapaxes(-1, -2)
    lac = jnp.moveaxis(la.reshape(B, nc, L, H), 1, 0).swapaxes(-1, -2)
    bc_ = jnp.moveaxis(b_in.reshape(B, nc, L, N), 1, 0)   # (nc,B,L,N)
    cc_ = jnp.moveaxis(c_out.reshape(B, nc, L, N), 1, 0)
    mask = jnp.tril(jnp.ones((L, L), bool))

    def chunk_step(S0, xs):
        xb, dtb, lab, bb, cb = xs          # (B,H,L,P),(B,H,L),(B,H,L),(B,L,N)
        cum = jnp.cumsum(lab, axis=-1)     # (B,H,L)
        # intra: w[t,s] = exp(cum_t - cum_s) * dt_s   for s<=t
        w = jnp.exp(cum[..., :, None] - cum[..., None, :]) * dtb[..., None, :]
        w = jnp.where(mask, w, 0.0)
        bcs = jnp.einsum("btn,bsn->bts", cb, bb)        # (B,L,L)
        y = jnp.einsum("bhts,bts,bhsp->bhtp", w, bcs, xb)
        y = y + jnp.exp(cum)[..., None] * jnp.einsum(
            "bhpn,btn->bhtp", S0, cb)
        # carry
        wL = jnp.exp(cum[..., -1:] - cum) * dtb          # (B,H,L)
        SL = (jnp.exp(cum[..., -1])[..., None, None] * S0
              + jnp.einsum("bhs,bhsp,bsn->bhpn", wL, xb, bb))
        return SL, y

    S, ys = jax.lax.scan(chunk_step, state["S"], (xc_, dtc, lac, bc_, cc_))
    y = jnp.moveaxis(ys, 0, 1).swapaxes(2, 3).reshape(B, T, H, P)
    return y, S


def mamba_scan(cfg, p, x, state):
    B, T, D = x.shape
    H = cfg.ssm_heads or cfg.n_heads
    N = cfg.ssm_state
    P = D // H
    xz = x @ p["w_in"]
    xs, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = _causal_conv(xs, p["conv"], state["conv"])
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)
    bc = xc @ p["w_bc"]                                          # (B,T,2N)
    b_in, c_out = jnp.split(bc.astype(jnp.float32), 2, axis=-1)  # (B,T,N)
    dt = jax.nn.softplus(xc.astype(jnp.float32) @ p["w_dt"] + p["dt_bias"])
    a = -jnp.exp(p["A_log"])                                     # (H,)
    xh = xc.reshape(B, T, H, P).astype(jnp.float32)

    chunk = _pick_chunk(T, MAMBA_CHUNK)
    if chunk and T >= 2 * chunk:
        y_bthp, S = _mamba_chunkwise(xh, b_in, c_out, dt, a, state, chunk)
        y = y_bthp + p["D_skip"][:, None] * xh
        y = _rms_head_norm(y).reshape(B, T, D).astype(x.dtype)
        y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
        return y @ p["w_out"], {"S": S, "conv": conv_state}

    def step(S, t_in):
        xt, bt, ct, dtt = t_in                                   # (B,H,P),(B,N),(B,N),(B,H)
        dA = jnp.exp(dtt * a)                                    # (B,H)
        dBx = dtt[..., None, None] * (xt[..., :, None] * bt[:, None, None, :])
        S_new = dA[..., None, None] * S + dBx                    # (B,H,P,N)
        y = jnp.einsum("bhpn,bn->bhp", S_new, ct)
        return S_new, y

    xs_t = (xh.swapaxes(0, 1), b_in.swapaxes(0, 1), c_out.swapaxes(0, 1),
            dt.swapaxes(0, 1))
    S, ys = jax.lax.scan(step, state["S"], xs_t)
    y = ys.swapaxes(0, 1)                                        # (B,T,H,P)
    y = y + p["D_skip"][:, None] * xh
    y = _rms_head_norm(y).reshape(B, T, D).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return y @ p["w_out"], {"S": S, "conv": conv_state}
