"""Unified decoder stack for all assigned architecture families.

A model is a *pattern* of sub-layer specs (a "super-block") scanned
``n_layers / len(pattern)`` times with stacked parameters — one compiled
block body regardless of depth (bounded HLO size / compile time; see
DESIGN.md §5). Heterogeneous stacks are patterns longer than 1:

  dense / moe / vlm / audio : [attn+mlp]            (window per spec)
  gemma2                    : [local attn, global attn]  × 23
  xlstm                     : [mLSTM block, sLSTM block] × 6
  hymba                     : [parallel attn ‖ mamba + mlp]

Sub-layer kinds:
  "attn"   — GQA attention (+ MLP or MoE per cfg.family)
  "mlstm"  — xLSTM matrix-memory block
  "slstm"  — xLSTM scalar-memory block (own FFN)
  "hybrid" — Hymba parallel attention+mamba heads (+ MLP)
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.models import ssm
from repro.models.attention import run_attention
from repro.models.cache import (attn_cache_len, cache_positions,
                                init_attn_cache, init_paged_pool,
                                paged_phys_pages, update_attn_cache)
from repro.models.common import (activation, apply_norm, init_norm,
                                 normal_init, apply_rope, softcap)
from repro.models.moe import (init_moe, moe_forward, moe_forward_ep,
                              moe_forward_sharded)
from repro.models.types import ModelConfig

INT_MAX = 2**31 - 1


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str                    # attn | mlstm | slstm | hybrid
    window: int | None = None    # sliding window (None = full causal)
    use_moe: bool = False


def block_pattern(cfg: ModelConfig) -> list[LayerSpec]:
    if cfg.family == "ssm":          # xlstm: alternate mLSTM / sLSTM
        return [LayerSpec("mlstm"), LayerSpec("slstm")]
    if cfg.family == "hybrid":       # hymba: parallel attn+SSM, SWA
        return [LayerSpec("hybrid", window=cfg.sliding_window)]
    if cfg.global_every:             # gemma2: local / global alternation
        return [LayerSpec("attn", window=cfg.sliding_window,
                          use_moe=False),
                LayerSpec("attn", window=None, use_moe=False)]
    return [LayerSpec("attn", window=cfg.sliding_window,
                      use_moe=cfg.family == "moe")]


# ------------------------------------------------------------------
# per-sub-layer init/apply
# ------------------------------------------------------------------


def _init_attn(cfg, key, dtype):
    D = cfg.d_model
    H, K, P = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    params, dims = {}, {}
    params["wq"], dims["wq"] = normal_init(
        ks[0], (D, H, P), ("embed", "heads", "head_dim"), dtype, fan_in=D)
    params["wk"], dims["wk"] = normal_init(
        ks[1], (D, K, P), ("embed", "kv_heads", "head_dim"), dtype, fan_in=D)
    params["wv"], dims["wv"] = normal_init(
        ks[2], (D, K, P), ("embed", "kv_heads", "head_dim"), dtype, fan_in=D)
    params["wo"], dims["wo"] = normal_init(
        ks[3], (H, P, D), ("heads", "head_dim", "embed"), dtype, fan_in=H * P)
    return params, dims


def _init_mlp(cfg, key, dtype):
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    params, dims = {}, {}
    params["w_gate"], dims["w_gate"] = normal_init(
        ks[0], (D, F), ("embed", "mlp"), dtype, fan_in=D)
    params["w_up"], dims["w_up"] = normal_init(
        ks[1], (D, F), ("embed", "mlp"), dtype, fan_in=D)
    params["w_down"], dims["w_down"] = normal_init(
        ks[2], (F, D), ("mlp", "embed"), dtype, fan_in=F)
    return params, dims


def _apply_mlp(cfg, p, x, rules=None):
    act = activation(cfg.act)
    h = (act((x @ p["w_gate"]).astype(jnp.float32))
         * (x @ p["w_up"]).astype(jnp.float32)).astype(x.dtype)
    if rules is not None:
        # Megatron-SP: with a seq-sharded residual stream XLA otherwise
        # keeps seq sharding inside the layer and all-gathers the FULL
        # mlp weights per layer (1.4 GB/layer measured). Forcing the
        # hidden to ff-sharded makes it gather activations (16 MB) and
        # reduce-scatter the output instead.
        h = rules.constrain(h, ("batch", None, "mlp"))
    return h @ p["w_down"]


def _init_layer(cfg: ModelConfig, spec: LayerSpec, key, dtype):
    ks = jax.random.split(key, 6)
    params, dims = {}, {}
    if spec.kind in ("attn", "hybrid"):
        params["ln1"], dims["ln1"] = init_norm(cfg)
        params["ln2"], dims["ln2"] = init_norm(cfg)
        if cfg.name.startswith("gemma2"):
            params["ln1_post"], dims["ln1_post"] = init_norm(cfg)
            params["ln2_post"], dims["ln2_post"] = init_norm(cfg)
        params["attn"], dims["attn"] = _init_attn(cfg, ks[0], dtype)
        if spec.kind == "hybrid":
            params["mamba"], dims["mamba"] = ssm.init_mamba(cfg, ks[1], dtype)
            params["fuse"] = jnp.ones((2,), jnp.float32)
            dims["fuse"] = (None,)
        if spec.use_moe:
            params["moe"], dims["moe"] = init_moe(cfg, ks[2], dtype)
        else:
            params["mlp"], dims["mlp"] = _init_mlp(cfg, ks[2], dtype)
    elif spec.kind == "mlstm":
        params["ln1"], dims["ln1"] = init_norm(cfg)
        params["cell"], dims["cell"] = ssm.init_mlstm(cfg, ks[0], dtype)
    elif spec.kind == "slstm":
        params["ln1"], dims["ln1"] = init_norm(cfg)
        params["cell"], dims["cell"] = ssm.init_slstm(cfg, ks[0], dtype)
    else:
        raise ValueError(spec.kind)
    return params, dims


def _attn_shard_dims(cfg, rules, decode: bool):
    """Consistent q vs k/v activation sharding (DESIGN.md §4 table).

    The naive fallthrough (q on heads, k/v on head_dim when kv_heads
    doesn't divide the model axis) makes the score contraction cross-shard
    — the dry-run measured it at >100 GB/device of psum traffic. Policy:

    - kv_heads % model == 0: q on heads, k/v on kv_heads (groups align,
      contraction local).
    - else, train/prefill: q on heads, k/v *replicated* over model (one
      K/V all-gather per layer ≪ score psums).
    - else, decode (S == 1): q AND k/v on head_dim — the score psum is a
      (B, Hkv, G, 1, T) tile, cheap for one token, and the big KV cache
      stays sharded.
    """
    if rules is None:
        return None, None
    msize = rules.mesh.shape.get("model", 1)
    if cfg.n_kv_heads % msize == 0:
        return (("batch", None, "heads", None),
                ("batch", None, "kv_heads", None))
    if decode:
        return (("batch", None, None, "head_dim"),
                ("batch", None, None, "head_dim"))
    return (("batch", None, "heads", None), ("batch", None, None, None))


def _attn_call(cfg, p_attn, x, q_pos, k, v, k_pos, window, rules=None):
    """Project q from x, run attention against provided k/v.

    ``q_pos``: (S,) and ``k_pos``: (T,) global positions (shared over batch).
    """
    q = jnp.einsum("bsd,dhp->bshp", x, p_attn["wq"])
    q = apply_rope(q, q_pos, cfg.rope_theta)
    if rules is not None:
        q_dims, kv_dims = _attn_shard_dims(cfg, rules, decode=x.shape[1] == 1)
        q = rules.constrain(q, q_dims)
        k = rules.constrain(k, kv_dims)
        v = rules.constrain(v, kv_dims)
    out = run_attention(cfg.attn_impl, q, k, v, q_pos, k_pos, window=window,
                        logit_softcap=cfg.logit_softcap)
    return jnp.einsum("bshp,hpd->bsd", out, p_attn["wo"])


def _project_kv(cfg, p_attn, x, k_pos):
    """K/V projections with RoPE on K. ``k_pos``: (S,)."""
    k = jnp.einsum("bsd,dkp->bskp", x, p_attn["wk"])
    v = jnp.einsum("bsd,dkp->bskp", x, p_attn["wv"])
    k = apply_rope(k, k_pos, cfg.rope_theta)
    return k, v


def apply_layer_train(cfg, spec: LayerSpec, p, x, positions, rules=None):
    """Full-sequence (teacher-forcing) layer application. Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)

    def gather_seq(h):
        # Megatron-SP: explicitly all-gather the sequence dim after the
        # norm so projections run against model-sharded weights; without
        # this XLA keeps seq sharding and all-gathers full weight matrices
        # per layer instead (measured 1.4 GB/layer for the 35B config).
        if rules is None:
            return h
        return rules.constrain(h, ("batch", None, None))

    if spec.kind in ("attn", "hybrid"):
        h = gather_seq(apply_norm(cfg, p["ln1"], x))
        k, v = _project_kv(cfg, p["attn"], h, positions)
        attn_out = _attn_call(cfg, p["attn"], h, positions, k, v, positions,
                              spec.window, rules=rules)
        if spec.kind == "hybrid":
            m_out, _ = ssm.mamba_scan(
                cfg, p["mamba"], h,
                ssm.init_mamba_state(cfg, x.shape[0], x.dtype))
            w = jax.nn.softmax(p["fuse"])
            attn_out = (w[0] * attn_out.astype(jnp.float32)
                        + w[1] * m_out.astype(jnp.float32)).astype(x.dtype)
        if "ln1_post" in p:
            attn_out = apply_norm(cfg, p["ln1_post"], attn_out)
        x = x + attn_out
        h = gather_seq(apply_norm(cfg, p["ln2"], x))
        if spec.use_moe:
            if cfg.expert_parallel and rules is not None:
                mlp_out, aux = moe_forward_ep(cfg, p["moe"], h,
                                              mesh=rules.mesh)
            elif rules is not None:
                mlp_out, aux = moe_forward_sharded(cfg, p["moe"], h, rules)
            else:
                mlp_out, aux = moe_forward(cfg, p["moe"], h)
        else:
            mlp_out = _apply_mlp(cfg, p["mlp"], h, rules=rules)
        if "ln2_post" in p:
            mlp_out = apply_norm(cfg, p["ln2_post"], mlp_out)
        x = x + mlp_out
    elif spec.kind in ("mlstm", "slstm"):
        # gather_seq: under sequence parallelism a seq-sharded input makes
        # the recurrent per-timestep slices cross-shard — the dry-run
        # measured 24.7k all-reduces/step for xlstm. Gather once instead.
        h = gather_seq(apply_norm(cfg, p["ln1"], x))
        if spec.kind == "mlstm":
            y, _ = ssm.mlstm_scan(cfg, p["cell"], h,
                                  ssm.init_mlstm_state(cfg, x.shape[0],
                                                       x.dtype))
        else:
            y, _ = ssm.slstm_scan(cfg, p["cell"], h,
                                  ssm.init_slstm_state(cfg, x.shape[0],
                                                       x.dtype),
                                  rules=rules)
        x = x + y
    return x, aux


def _write_prefill_cache(attn_cache, k, v, positions):
    """Populate the ring cache from a full-sequence prefill.

    Only the last C positions can survive in a ring of size C.
    """
    C = attn_cache["k"].shape[1]
    S = k.shape[1]
    if S >= C:
        k_tail, v_tail = k[:, -C:], v[:, -C:]
        slots = positions[-C:] % C
    else:
        k_tail, v_tail = k, v
        slots = positions % C
    return {"k": attn_cache["k"].at[:, slots].set(k_tail),
            "v": attn_cache["v"].at[:, slots].set(v_tail)}


def apply_layer_prefill(cfg, spec: LayerSpec, p, cache, x, positions,
                        rules=None):
    """Full-sequence forward that also populates the cache."""
    new_cache = dict(cache)
    if spec.kind in ("attn", "hybrid"):
        h = apply_norm(cfg, p["ln1"], x)
        k, v = _project_kv(cfg, p["attn"], h, positions)
        new_cache["attn"] = _write_prefill_cache(cache["attn"], k, v, positions)
        attn_out = _attn_call(cfg, p["attn"], h, positions, k, v, positions,
                              spec.window, rules=rules)
        if spec.kind == "hybrid":
            m_out, new_cache["mamba"] = ssm.mamba_scan(
                cfg, p["mamba"], h, cache["mamba"])
            w = jax.nn.softmax(p["fuse"])
            attn_out = (w[0] * attn_out.astype(jnp.float32)
                        + w[1] * m_out.astype(jnp.float32)).astype(x.dtype)
        if "ln1_post" in p:
            attn_out = apply_norm(cfg, p["ln1_post"], attn_out)
        x = x + attn_out
        h = apply_norm(cfg, p["ln2"], x)
        if spec.use_moe:
            if rules is not None:
                mlp_out, _ = moe_forward_sharded(cfg, p["moe"], h, rules)
            else:
                mlp_out, _ = moe_forward(cfg, p["moe"], h)
        else:
            mlp_out = _apply_mlp(cfg, p["mlp"], h, rules=rules)
        if "ln2_post" in p:
            mlp_out = apply_norm(cfg, p["ln2_post"], mlp_out)
        x = x + mlp_out
    elif spec.kind in ("mlstm", "slstm"):
        h = apply_norm(cfg, p["ln1"], x)
        scan_fn = ssm.mlstm_scan if spec.kind == "mlstm" else ssm.slstm_scan
        y, new_cache["cell"] = scan_fn(cfg, p["cell"], h, cache["cell"])
        x = x + y
    return x, new_cache


def apply_stack_prefill(cfg: ModelConfig, stack_params, caches, x, positions,
                        rules=None):
    pattern = block_pattern(cfg)

    def body(x, xs):
        layer_params, layer_caches = xs
        new_caches = []
        for spec, p, c in zip(pattern, layer_params, layer_caches):
            x, nc = apply_layer_prefill(cfg, spec, p, c, x, positions,
                                        rules=rules)
            new_caches.append(nc)
        return x, tuple(new_caches)

    x, new_caches = jax.lax.scan(body, x, (tuple(stack_params), tuple(caches)))
    return x, list(new_caches)


# ------------------------------------------------------------------
# decode-path layer (cached)
# ------------------------------------------------------------------


def init_layer_cache(cfg, spec: LayerSpec, batch, seq_len, dtype):
    """Per-layer cache (no leading layers dim — the stack adds it)."""
    cache, dims = {}, {}
    if spec.kind in ("attn", "hybrid"):
        clen = attn_cache_len(seq_len, spec.window)
        (c, d) = init_attn_cache(1, batch, clen, cfg.n_kv_heads,
                                 cfg.resolved_head_dim, dtype)
        cache["attn"] = {k: v[0] for k, v in c.items()}
        dims["attn"] = {k: v[1:] for k, v in d.items()}
    if spec.kind == "hybrid":
        cache["mamba"] = ssm.init_mamba_state(cfg, batch, dtype)
        dims["mamba"] = ssm.mamba_state_dims(cfg)
    if spec.kind == "mlstm":
        cache["cell"] = ssm.init_mlstm_state(cfg, batch, dtype)
        dims["cell"] = ssm.mlstm_state_dims(cfg)
    if spec.kind == "slstm":
        cache["cell"] = ssm.init_slstm_state(cfg, batch, dtype)
        dims["cell"] = ssm.slstm_state_dims(cfg)
    return cache, dims


def apply_layer_decode(cfg, spec: LayerSpec, p, cache, x, pos, rules=None):
    """One-token layer step. x: (B, 1, D); pos: scalar int32 (tokens so far).

    Returns (x, new_cache).
    """
    new_cache = dict(cache)
    if spec.kind in ("attn", "hybrid"):
        h = apply_norm(cfg, p["ln1"], x)
        q_pos = jnp.reshape(pos, (1,))
        k_new, v_new = _project_kv(cfg, p["attn"], h, q_pos)
        new_cache["attn"] = update_attn_cache(cache["attn"], k_new, v_new, pos)
        clen = cache["attn"]["k"].shape[1]
        k_pos = cache_positions(clen, pos)
        attn_out = _attn_call(cfg, p["attn"], h, q_pos,
                              new_cache["attn"]["k"], new_cache["attn"]["v"],
                              k_pos, spec.window, rules=rules)
        if spec.kind == "hybrid":
            m_out, new_cache["mamba"] = ssm.mamba_scan(
                cfg, p["mamba"], h, cache["mamba"])
            w = jax.nn.softmax(p["fuse"])
            attn_out = (w[0] * attn_out.astype(jnp.float32)
                        + w[1] * m_out.astype(jnp.float32)).astype(x.dtype)
        if "ln1_post" in p:
            attn_out = apply_norm(cfg, p["ln1_post"], attn_out)
        x = x + attn_out
        h = apply_norm(cfg, p["ln2"], x)
        if spec.use_moe:
            if rules is not None:
                mlp_out, _ = moe_forward_sharded(cfg, p["moe"], h, rules)
            else:
                mlp_out, _ = moe_forward(cfg, p["moe"], h)
        else:
            mlp_out = _apply_mlp(cfg, p["mlp"], h, rules=rules)
        if "ln2_post" in p:
            mlp_out = apply_norm(cfg, p["ln2_post"], mlp_out)
        x = x + mlp_out
    elif spec.kind in ("mlstm", "slstm"):
        h = apply_norm(cfg, p["ln1"], x)
        scan_fn = ssm.mlstm_scan if spec.kind == "mlstm" else ssm.slstm_scan
        y, new_cache["cell"] = scan_fn(cfg, p["cell"], h, cache["cell"])
        x = x + y
    return x, new_cache


# ------------------------------------------------------------------
# the scanned stack
# ------------------------------------------------------------------


def init_stack(cfg: ModelConfig, key, dtype):
    pattern = block_pattern(cfg)
    n_blocks = cfg.n_layers // len(pattern)
    assert n_blocks * len(pattern) == cfg.n_layers, (cfg.n_layers, pattern)
    params, dims = [], []
    for i, spec in enumerate(pattern):
        keys = jax.random.split(jax.random.fold_in(key, i), n_blocks)
        stacked = jax.vmap(lambda k: _init_layer(cfg, spec, k, dtype)[0])(keys)
        _, d = _init_layer(cfg, spec, keys[0], dtype)
        d = jax.tree.map(
            lambda t: ("layers",) + t, d,
            is_leaf=lambda t: isinstance(t, tuple) and all(
                isinstance(e, (str, type(None))) for e in t))
        params.append(stacked)
        dims.append(d)
    return params, dims


def _maybe_remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def _constrain_act(x, rules):
    """Residual-stream sharding constraint (batch→data, seq→model when
    sequence_parallel; spec resolution falls through on indivisibility)."""
    if rules is None:
        return x
    return rules.constrain(x, ("batch", "act_seq") + (None,) * (x.ndim - 2))


def apply_stack_train(cfg: ModelConfig, stack_params, x, positions, rules=None):
    """x: (B, S, D) -> (y, aux_loss_sum). Scans super-blocks."""
    pattern = block_pattern(cfg)

    def block(x, layer_params):
        aux = jnp.zeros((), jnp.float32)
        x = _constrain_act(x, rules)
        for spec, p in zip(pattern, layer_params):
            x, a = apply_layer_train(cfg, spec, p, x, positions, rules=rules)
            aux = aux + a
        return _constrain_act(x, rules), aux

    block = _maybe_remat(cfg, block)

    def body(carry, layer_params):
        x, aux = carry
        x, a = block(x, layer_params)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               tuple(stack_params),
                               unroll=True if cfg.scan_unroll else 1)
    return x, aux


def init_stack_cache(cfg: ModelConfig, batch, seq_len, dtype):
    pattern = block_pattern(cfg)
    n_blocks = cfg.n_layers // len(pattern)
    caches, dims = [], []
    for spec in pattern:
        c, d = init_layer_cache(cfg, spec, batch, seq_len, dtype)
        stacked = jax.tree.map(
            lambda l: jnp.broadcast_to(l, (n_blocks,) + l.shape).copy(), c)
        d = jax.tree.map(
            lambda t: ("layers",) + t, d,
            is_leaf=lambda t: isinstance(t, tuple) and all(
                isinstance(e, (str, type(None))) for e in t))
        caches.append(stacked)
        dims.append(d)
    return caches, dims


def apply_stack_decode(cfg: ModelConfig, stack_params, caches, x, pos,
                       rules=None):
    """One-token step through all layers. Returns (y, new_caches)."""
    pattern = block_pattern(cfg)

    def body(carry, xs):
        x = carry
        layer_params, layer_caches = xs
        new_caches = []
        for spec, p, c in zip(pattern, layer_params, layer_caches):
            x, nc = apply_layer_decode(cfg, spec, p, c, x, pos, rules=rules)
            new_caches.append(nc)
        return x, tuple(new_caches)

    x, new_caches = jax.lax.scan(body, x, (tuple(stack_params), tuple(caches)))
    return x, list(new_caches)


# ------------------------------------------------------------------
# paged decode path (serving tier; see docs/ARCHITECTURE.md §8)
# ------------------------------------------------------------------
#
# Same scanned super-block structure as the contiguous decode path, with
# three serving-grade differences: (1) K/V lives in a shared page pool
# addressed through per-sequence block tables, (2) positions are
# PER-SEQUENCE (pos_b: (B,)) so ragged continuous batches decode in one
# fixed-shape step, and (3) attention runs the paged gather kernel
# (repro.kernels.paged_attention). Recurrent layers (mamba/mLSTM/sLSTM)
# keep their constant-size per-slot states and pass through unchanged.

from repro.models.cache import TRASH_PAGE  # noqa: E402  (section import)


def _paged_impl(cfg) -> str:
    return "pallas" if cfg.attn_impl == "flash_pallas" else "jnp"


def _paged_attn(cfg, q, pages, tables, lens, window):
    from repro.kernels.paged_attention import paged_attention
    return paged_attention(q, pages["k"], pages["v"], tables, lens,
                           window=window, logit_softcap=cfg.logit_softcap,
                           impl=_paged_impl(cfg))


def init_stack_paged_cache(cfg: ModelConfig, max_batch, n_pages, page_size,
                           dtype):
    """Per-spec serving caches: attention layers get a page pool (the
    physical page index space is shared across specs — one block-table
    entry is valid in every layer's pool); recurrent layers keep stacked
    constant-size per-slot states."""
    pattern = block_pattern(cfg)
    n_blocks = cfg.n_layers // len(pattern)

    def stack_state(state, state_dims):
        stacked = jax.tree.map(
            lambda l: jnp.broadcast_to(l, (n_blocks,) + l.shape).copy(),
            state)
        d = jax.tree.map(
            lambda t: ("layers",) + t, state_dims,
            is_leaf=lambda t: isinstance(t, tuple) and all(
                isinstance(e, (str, type(None))) for e in t))
        return stacked, d

    caches, dims = [], []
    for spec in pattern:
        c, d = {}, {}
        if spec.kind in ("attn", "hybrid"):
            c["pages"], d["pages"] = init_paged_pool(
                n_blocks, n_pages, page_size, cfg.n_kv_heads,
                cfg.resolved_head_dim, dtype)
        if spec.kind == "hybrid":
            c["mamba"], d["mamba"] = stack_state(
                ssm.init_mamba_state(cfg, max_batch, dtype),
                ssm.mamba_state_dims(cfg))
        if spec.kind == "mlstm":
            c["cell"], d["cell"] = stack_state(
                ssm.init_mlstm_state(cfg, max_batch, dtype),
                ssm.mlstm_state_dims(cfg))
        if spec.kind == "slstm":
            c["cell"], d["cell"] = stack_state(
                ssm.init_slstm_state(cfg, max_batch, dtype),
                ssm.slstm_state_dims(cfg))
        caches.append(c)
        dims.append(d)
    return caches, dims


def reset_paged_states(caches, reset_mask):
    """Zero the recurrent per-slot states where ``reset_mask`` (B,) is
    set — run at admission so a reused batch slot starts clean. Page
    pools need no reset: stale pages are hidden by the lens masking."""
    out = []
    for c in caches:
        nc = dict(c)
        for key in ("mamba", "cell"):
            if key in c:
                nc[key] = jax.tree.map(
                    lambda s: s * (1.0 - reset_mask.astype(s.dtype)).reshape(
                        (1, -1) + (1,) * (s.ndim - 2)), c[key])
        out.append(nc)
    return out


def apply_layer_decode_paged(cfg, spec: LayerSpec, p, cache, x, pos_b,
                             tables, page_size: int):
    """One-token layer step with per-sequence positions.

    x: (B, 1, D); pos_b: (B,) tokens already cached per sequence;
    tables: (B, TW) physical page per ring slot. Returns (x, new_cache).
    """
    new_cache = dict(cache)
    if spec.kind in ("attn", "hybrid"):
        h = apply_norm(cfg, p["ln1"], x)
        q_pos = pos_b[:, None]                        # (B, 1) per-sequence
        k_new, v_new = _project_kv(cfg, p["attn"], h, q_pos)
        phys, slot = paged_phys_pages(tables, pos_b, page_size)
        pages = {"k": cache["pages"]["k"].at[phys, slot].set(k_new[:, 0]),
                 "v": cache["pages"]["v"].at[phys, slot].set(v_new[:, 0])}
        new_cache["pages"] = pages
        q = jnp.einsum("bsd,dhp->bshp", h, p["attn"]["wq"])
        q = apply_rope(q, q_pos, cfg.rope_theta)
        out = _paged_attn(cfg, q[:, 0], pages, tables, pos_b + 1,
                          spec.window)
        attn_out = jnp.einsum("bhp,hpd->bd", out, p["attn"]["wo"])[:, None]
        if spec.kind == "hybrid":
            m_out, new_cache["mamba"] = ssm.mamba_scan(
                cfg, p["mamba"], h, cache["mamba"])
            w = jax.nn.softmax(p["fuse"])
            attn_out = (w[0] * attn_out.astype(jnp.float32)
                        + w[1] * m_out.astype(jnp.float32)).astype(x.dtype)
        if "ln1_post" in p:
            attn_out = apply_norm(cfg, p["ln1_post"], attn_out)
        x = x + attn_out
        h = apply_norm(cfg, p["ln2"], x)
        if spec.use_moe:
            mlp_out, _ = moe_forward(cfg, p["moe"], h)
        else:
            mlp_out = _apply_mlp(cfg, p["mlp"], h)
        if "ln2_post" in p:
            mlp_out = apply_norm(cfg, p["ln2_post"], mlp_out)
        x = x + mlp_out
    elif spec.kind in ("mlstm", "slstm"):
        h = apply_norm(cfg, p["ln1"], x)
        scan_fn = ssm.mlstm_scan if spec.kind == "mlstm" else ssm.slstm_scan
        y, new_cache["cell"] = scan_fn(cfg, p["cell"], h, cache["cell"])
        x = x + y
    return x, new_cache


def apply_stack_decode_paged(cfg: ModelConfig, stack_params, caches, x,
                             pos_b, tables, page_size: int):
    """One fixed-shape continuous-batching step through all layers."""
    pattern = block_pattern(cfg)

    def body(carry, xs):
        x = carry
        layer_params, layer_caches = xs
        new_caches = []
        for spec, p, c in zip(pattern, layer_params, layer_caches):
            x, nc = apply_layer_decode_paged(cfg, spec, p, c, x, pos_b,
                                             tables, page_size)
            new_caches.append(nc)
        return x, tuple(new_caches)

    x, new_caches = jax.lax.scan(body, x, (tuple(stack_params), tuple(caches)))
    return x, list(new_caches)


def apply_layer_prefill_paged(cfg, spec: LayerSpec, p, cache, x, n_valid,
                              slot_id, table_row, page_size: int):
    """Chunked prefill of ONE batch slot, writing K/V into its pages.

    x: (1, S, D) — the slot's prompt padded to the static chunk length S;
    n_valid: real token count (pad tail's K/V is routed to the trash
    page; causal masking makes pad queries invisible to real rows).
    Recurrent sub-layers scan from a FRESH zero state and store the
    result at ``slot_id`` — exact only when n_valid == S, which the
    engine guarantees by routing recurrent families through static
    exact-length chunks (prefix fill) + step-prefill.
    """
    new_cache = dict(cache)
    S = x.shape[1]
    positions = jnp.arange(S)
    if spec.kind in ("attn", "hybrid"):
        h = apply_norm(cfg, p["ln1"], x)
        k, v = _project_kv(cfg, p["attn"], h, positions)
        TW = table_row.shape[0]
        tok_page = jnp.take(table_row, (positions // page_size) % TW)
        # only the last TW*ps positions can survive the ring (mirrors
        # _write_prefill_cache); dropping older writes also keeps the
        # scatter free of duplicate (page, slot) pairs
        valid = (positions < n_valid) & (positions >= n_valid - TW * page_size)
        phys = jnp.where(valid, tok_page, TRASH_PAGE)
        pslot = jnp.where(valid, positions % page_size, 0)
        new_cache["pages"] = {
            "k": cache["pages"]["k"].at[phys, pslot].set(k[0]),
            "v": cache["pages"]["v"].at[phys, pslot].set(v[0])}
        attn_out = _attn_call(cfg, p["attn"], h, positions, k, v, positions,
                              spec.window)
        if spec.kind == "hybrid":
            m_out, m_state = ssm.mamba_scan(
                cfg, p["mamba"], h, ssm.init_mamba_state(cfg, 1, x.dtype))
            new_cache["mamba"] = jax.tree.map(
                lambda full, new: jax.lax.dynamic_update_slice_in_dim(
                    full, new.astype(full.dtype), slot_id, 0),
                cache["mamba"], m_state)
            w = jax.nn.softmax(p["fuse"])
            attn_out = (w[0] * attn_out.astype(jnp.float32)
                        + w[1] * m_out.astype(jnp.float32)).astype(x.dtype)
        if "ln1_post" in p:
            attn_out = apply_norm(cfg, p["ln1_post"], attn_out)
        x = x + attn_out
        h = apply_norm(cfg, p["ln2"], x)
        if spec.use_moe:
            mlp_out, _ = moe_forward(cfg, p["moe"], h)
        else:
            mlp_out = _apply_mlp(cfg, p["mlp"], h)
        if "ln2_post" in p:
            mlp_out = apply_norm(cfg, p["ln2_post"], mlp_out)
        x = x + mlp_out
    elif spec.kind in ("mlstm", "slstm"):
        h = apply_norm(cfg, p["ln1"], x)
        scan_fn = ssm.mlstm_scan if spec.kind == "mlstm" else ssm.slstm_scan
        init_fn = (ssm.init_mlstm_state if spec.kind == "mlstm"
                   else ssm.init_slstm_state)
        y, state = scan_fn(cfg, p["cell"], h, init_fn(cfg, 1, x.dtype))
        new_cache["cell"] = jax.tree.map(
            lambda full, new: jax.lax.dynamic_update_slice_in_dim(
                full, new.astype(full.dtype), slot_id, 0),
            cache["cell"], state)
        x = x + y
    return x, new_cache


def apply_stack_prefill_paged(cfg: ModelConfig, stack_params, caches, x,
                              n_valid, slot_id, table_row, page_size: int):
    """Chunk-prefill one slot through all layers. Returns (y, new_caches)."""
    pattern = block_pattern(cfg)

    def body(carry, xs):
        x = carry
        layer_params, layer_caches = xs
        new_caches = []
        for spec, p, c in zip(pattern, layer_params, layer_caches):
            x, nc = apply_layer_prefill_paged(cfg, spec, p, c, x, n_valid,
                                              slot_id, table_row, page_size)
            new_caches.append(nc)
        return x, tuple(new_caches)

    x, new_caches = jax.lax.scan(body, x, (tuple(stack_params), tuple(caches)))
    return x, list(new_caches)
