"""Attention: GQA, causal, sliding-window, logit softcap; naive + blockwise.

Implementations (selected by ``cfg.attn_impl``):

- ``naive``      — materializes the full score matrix. Oracle + decode path.
- ``flash_jnp``  — blockwise online-softmax (flash) in pure jnp with a
                   **custom VJP**: the backward pass recomputes block
                   scores from (q, k, v, out, lse) instead of storing the
                   O(S·T) probability tensors (which the dry-run measured
                   at >100 GB/device for 4k training). Forward is *banded*
                   under a sliding window: compute drops to O(S·W).
- ``flash_pallas`` — the Pallas TPU kernel in ``repro.kernels`` (same
                   math, VMEM-tiled), validated against ``naive``.

The flash path assumes the training/prefill layout: q_pos = k_pos =
arange. Cached decode (S == 1, ring-buffer positions) always uses naive —
it is matmul-thin and mask-irregular.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.common import softcap

NEG_INF = -1e30


def _mask(q_pos, k_pos, window):
    """(…, S, T) boolean mask: causal + optional sliding window + validity."""
    ok = (k_pos[..., None, :] <= q_pos[..., :, None]) & (k_pos[..., None, :] >= 0)
    if window is not None:
        ok &= (q_pos[..., :, None] - k_pos[..., None, :]) < window
    return ok


def naive_attention(q, k, v, q_pos, k_pos, *, window=None, logit_softcap=0.0):
    """q: (B,S,Hq,D); k/v: (B,T,Hkv,D); q_pos/k_pos: (B,S)/(B,T) or (S,)/(T,)."""
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, S, Hkv, G, D)
    # bf16 operands + f32 accumulation (preferred_element_type) — an
    # explicit .astype(f32) on k/v makes XLA hoist a full-precision copy
    # of the ENTIRE stacked KV cache out of the layer loop (5.4 GB/device
    # measured on the 35B decode dry-run).
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k,
                        preferred_element_type=jnp.float32) \
        / jnp.sqrt(D).astype(jnp.float32)
    scores = softcap(scores, logit_softcap)
    mask = _mask(q_pos, k_pos, window)
    if mask.ndim == 3:                      # (B,S,T) -> (B,1,1,S,T)
        mask = mask[:, None, None]
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, S, Hq, D).astype(q.dtype)


# ===================================================================
# flash (blockwise online softmax) with recomputing custom VJP
# ===================================================================


def _fwd_pass(q, k, v, window, logit_softcap, q_block, k_block):
    """Returns (out (B,S,Hq,D) q.dtype, lse (B,Hkv,G,S) f32)."""
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    dscale = 1.0 / jnp.sqrt(D).astype(jnp.float32)
    qg = q.reshape(B, S, Hkv, G, D)

    if window is not None:
        band = ((window + q_block + k_block - 1) // k_block + 1) * k_block
        band = min(band, T)
    else:
        band = None

    def per_qblock(i):
        qs = i * q_block
        q_blk = jax.lax.dynamic_slice_in_dim(qg, qs, q_block, 1)
        qp = qs + jnp.arange(q_block)
        o = jnp.zeros((B, Hkv, G, q_block, D), jnp.float32)
        m = jnp.full((B, Hkv, G, q_block), NEG_INF, jnp.float32)
        l = jnp.zeros((B, Hkv, G, q_block), jnp.float32)

        def accum(carry, k_blk, v_blk, kp):
            o, m, l = carry
            s = jnp.einsum("bqkgd,btkd->bkgqt", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * dscale
            s = softcap(s, logit_softcap)
            # additive (qb, kb) bias, NOT a broadcasted boolean where —
            # XLA hoists loop-invariant masks out of the layer loop and a
            # broadcasted (B,K,G,qb,kb) pred stack measured 10.7 GB/device.
            bias = jnp.where(_mask(qp, kp, window), 0.0, NEG_INF)
            s = s + bias
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = alpha * l + jnp.sum(p, axis=-1)
            o_new = alpha[..., None] * o + jnp.einsum(
                "bkgqt,btkd->bkgqd", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=jnp.float32)
            return o_new, m_new, l_new

        if band is not None:
            start = jnp.clip(qp[-1] - (band - 1), 0, T - band)
            k_band = jax.lax.dynamic_slice_in_dim(k, start, band, 1)
            v_band = jax.lax.dynamic_slice_in_dim(v, start, band, 1)
            kp = start + jnp.arange(band)
            o, m, l = accum((o, m, l), k_band, v_band, kp)
        else:
            def kv_step(carry, j):
                ks = j * k_block
                k_blk = jax.lax.dynamic_slice_in_dim(k, ks, k_block, 1)
                v_blk = jax.lax.dynamic_slice_in_dim(v, ks, k_block, 1)
                kp = ks + jnp.arange(k_block)
                return accum(carry, k_blk, v_blk, kp), None

            (o, m, l), _ = jax.lax.scan(kv_step, (o, m, l),
                                        jnp.arange(T // k_block))
        out = jnp.where(l[..., None] > 0,
                        o / jnp.maximum(l, 1e-30)[..., None], 0.0)
        lse = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, 1e-30)), NEG_INF)
        return out, lse

    outs, lses = jax.lax.map(per_qblock, jnp.arange(S // q_block))
    out = jnp.moveaxis(outs, 0, 3).reshape(B, Hkv, G, S, D)
    out = jnp.einsum("bkgsd->bskgd", out).reshape(B, S, Hq, D).astype(q.dtype)
    lse = jnp.moveaxis(lses, 0, 3).reshape(B, Hkv, G, S)
    return out, lse


def _bwd_pass(window, logit_softcap, q_block, k_block, res, dout):
    """Flash backward: recompute block scores from (q,k,v,out,lse)."""
    q, k, v, out, lse = res
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    dscale = 1.0 / jnp.sqrt(D).astype(jnp.float32)
    qg = q.reshape(B, S, Hkv, G, D).astype(jnp.float32)
    og = out.reshape(B, S, Hkv, G, D).astype(jnp.float32)
    dog = dout.reshape(B, S, Hkv, G, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # D_i = sum_d dout * out per row: (B,K,G,S)
    delta = jnp.einsum("bskgd,bskgd->bkgs", dog, og)

    nq, nk = S // q_block, T // k_block

    def block_grads(i, j):
        """Recompute p/ds for (q block i, kv block j); return (ds, p, slices)."""
        qs, ks = i * q_block, j * k_block
        q_blk = jax.lax.dynamic_slice_in_dim(qg, qs, q_block, 1)
        do_blk = jax.lax.dynamic_slice_in_dim(dog, qs, q_block, 1)
        lse_blk = jax.lax.dynamic_slice_in_dim(lse, qs, q_block, 3)
        dl_blk = jax.lax.dynamic_slice_in_dim(delta, qs, q_block, 3)
        k_blk = jax.lax.dynamic_slice_in_dim(kf, ks, k_block, 1)
        v_blk = jax.lax.dynamic_slice_in_dim(vf, ks, k_block, 1)
        qp = qs + jnp.arange(q_block)
        kp = ks + jnp.arange(k_block)
        s_pre = jnp.einsum("bqkgd,btkd->bkgqt", q_blk, k_blk) * dscale
        s_cap = softcap(s_pre, logit_softcap)
        bias = jnp.where(_mask(qp, kp, window), 0.0, NEG_INF)
        p = jnp.exp(s_cap + bias - lse_blk[..., None])        # 0 where masked
        dp = jnp.einsum("bqkgd,btkd->bkgqt", do_blk, v_blk)
        ds = p * (dp - dl_blk[..., None])
        if logit_softcap:
            # d softcap: 1 - tanh² — s_cap/cap ∈ [-1,1], no overflow
            ds = ds * (1.0 - jnp.square(s_cap / logit_softcap))
        ds = ds * dscale
        return ds, p, q_blk, do_blk, k_blk

    # pass 1 — dk/dv per kv block (accumulate over q blocks as carry)
    def per_kvblock(j):
        def q_step(carry, i):
            dk_acc, dv_acc = carry
            ds, p, q_blk, do_blk, _ = block_grads(i, j)
            dv_acc += jnp.einsum("bkgqt,bqkgd->btkd", p, do_blk)
            dk_acc += jnp.einsum("bkgqt,bqkgd->btkd", ds, q_blk)
            return (dk_acc, dv_acc), None

        zero_kv = jnp.zeros((B, k_block, Hkv, D), jnp.float32)
        (dk_j, dv_j), _ = jax.lax.scan(q_step, (zero_kv, zero_kv),
                                       jnp.arange(nq))
        return dk_j, dv_j

    dk_all, dv_all = jax.lax.map(per_kvblock, jnp.arange(nk))
    dk = jnp.moveaxis(dk_all, 0, 1).reshape(B, T, Hkv, D)
    dv = jnp.moveaxis(dv_all, 0, 1).reshape(B, T, Hkv, D)

    # pass 2 — dq per q block (accumulate over kv blocks as carry)
    def per_qblock(i):
        def kv_step(dq_acc, j):
            ds, _, _, _, k_blk = block_grads(i, j)
            dq_acc += jnp.einsum("bkgqt,btkd->bqkgd", ds, k_blk)
            return dq_acc, None

        zero_q = jnp.zeros((B, q_block, Hkv, G, D), jnp.float32)
        dq_i, _ = jax.lax.scan(kv_step, zero_q, jnp.arange(nk))
        return dq_i

    dq_all = jax.lax.map(per_qblock, jnp.arange(nq))
    dq = jnp.moveaxis(dq_all, 0, 1).reshape(B, S, Hq, D)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash(q, k, v, window, logit_softcap, q_block, k_block):
    out, _ = _fwd_pass(q, k, v, window, logit_softcap, q_block, k_block)
    return out


def _flash_fwd(q, k, v, window, logit_softcap, q_block, k_block):
    out, lse = _fwd_pass(q, k, v, window, logit_softcap, q_block, k_block)
    return out, (q, k, v, out, lse)


_flash.defvjp(_flash_fwd, _bwd_pass)


def _pick_block(n: int, target: int) -> int:
    """Largest divisor of n that is ≤ target (prefer multiples of 64 for
    MXU alignment; sequences with meta/vis prefixes are not powers of 2)."""
    best = 1
    for b in range(min(target, n), 0, -1):
        if n % b == 0:
            if b % 64 == 0:
                return b
            best = max(best, b)
            if b <= 64:
                break
    return best


def flash_attention_jnp(q, k, v, q_pos=None, k_pos=None, *, window=None,
                        logit_softcap=0.0, q_block=512, k_block=512):
    """Blockwise causal attention (training/prefill layout: positions are
    arange; ``q_pos``/``k_pos`` accepted for API parity and ignored)."""
    S, T = q.shape[1], k.shape[1]
    q_block = _pick_block(S, q_block)
    k_block = _pick_block(T, k_block)
    return _flash(q, k, v, window, logit_softcap, q_block, k_block)


def run_attention(impl: str, q, k, v, q_pos, k_pos, *, window=None,
                  logit_softcap=0.0):
    """Dispatch on implementation; decode (S==1) always uses naive."""
    if impl == "naive" or q.shape[1] == 1:
        qp = q_pos if q_pos.ndim == 2 else q_pos[None].repeat(q.shape[0], 0)
        kp = k_pos if k_pos.ndim == 2 else k_pos[None].repeat(k.shape[0], 0)
        return naive_attention(q, k, v, qp, kp, window=window,
                               logit_softcap=logit_softcap)
    if impl == "flash_pallas":
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, q_pos, k_pos, window=window,
                                    logit_softcap=logit_softcap)
    return flash_attention_jnp(q, k, v, q_pos, k_pos, window=window,
                               logit_softcap=logit_softcap)
