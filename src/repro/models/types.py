"""Model / input-shape configuration dataclasses."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                # dense | moe | ssm | hybrid | vlm | audio | convnet
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None          # default d_model // n_heads

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0                 # routed-expert hidden size
    router_aux_coef: float = 0.01
    expert_parallel: bool = False        # EP all-to-all path (needs E % model == 0)
    moe_capacity_factor: float = 1.25    # capacity-dispatch overprovision

    # --- attention ---
    sliding_window: int | None = None    # None = full causal
    global_every: int = 0                # gemma2: every 2nd layer is global
    logit_softcap: float = 0.0           # attention logit softcap
    final_softcap: float = 0.0           # final-logits softcap
    rope_theta: float = 10_000.0
    attn_impl: str = "flash_jnp"         # naive | flash_jnp | flash_pallas

    # --- ssm / hybrid ---
    ssm_state: int = 0
    ssm_heads: int = 0
    conv_kernel: int = 4

    # --- misc ---
    norm: str = "rmsnorm"                # rmsnorm | layernorm
    act: str = "silu"                    # silu | gelu | geglu
    n_meta_tokens: int = 0               # hymba learnable prefix tokens

    # --- modality frontends (stubs per assignment) ---
    n_codebooks: int = 0                 # musicgen EnCodec streams
    n_vis_tokens: int = 0                # internvl patch embeddings
    d_vis: int = 0

    # --- convnet (paper-faithful ResNet-CIFAR) ---
    widths: tuple = ()
    blocks_per_stage: int = 3
    image_size: int = 32
    n_classes: int = 0

    dtype: str = "bfloat16"
    remat: str = "full"                  # none | full | dots
    scan_unroll: bool = False            # unroll the layer scan (no XLA
    # while loop — required inside partial-auto shard_map on jax 0.4.x,
    # whose SPMD partitioner fatals on while+manual-subgroup shardings)
    source: str = ""                     # citation bracket from the assignment

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
