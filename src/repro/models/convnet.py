"""ResNet-CIFAR with BatchNorm — the paper's own architecture family.

The paper evaluates HWA on ResNet-20/32/56/110 (+VGG16, MobileNetV2) on
CIFAR. We implement the CIFAR ResNet exactly (3 stages × n blocks, widths
16/32/64, stride-2 stage transitions, identity shortcuts with zero-padding)
so the paper-faithful pipeline — SGD momentum 0.9, weight decay 5e-4,
cosine LR, HWA with H = one epoch — runs end-to-end, including the
BatchNorm-statistics recompute of Algorithm 2 line 3.

API (BN has running state, so this is not the LM API):
    params, bn_state = init_resnet(cfg, key)
    logits, new_bn_state = apply_resnet(cfg, params, bn_state, x, train=True)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import normal_init
from repro.models.types import ModelConfig

BN_MOMENTUM = 0.9


def resnet_cifar_config(depth: int = 20, n_classes: int = 10,
                        image_size: int = 32) -> ModelConfig:
    assert (depth - 2) % 6 == 0, "CIFAR ResNet depth must be 6n+2"
    n = (depth - 2) // 6
    return ModelConfig(
        name=f"resnet{depth}-cifar", family="convnet", n_layers=depth,
        d_model=64, n_heads=1, n_kv_heads=1, d_ff=0, vocab_size=n_classes,
        widths=(16, 32, 64), blocks_per_stage=n, image_size=image_size,
        n_classes=n_classes, dtype="float32",
        source="[He et al. 2016; paper §V]")


def _conv_init(key, k, cin, cout):
    fan_in = k * k * cin
    w = jax.random.normal(key, (k, k, cin, cout)) * jnp.sqrt(2.0 / fan_in)
    return w.astype(jnp.float32)


def _bn_init(c):
    params = {"scale": jnp.ones((c,), jnp.float32),
              "bias": jnp.zeros((c,), jnp.float32)}
    state = {"mean": jnp.zeros((c,), jnp.float32),
             "var": jnp.ones((c,), jnp.float32)}
    return params, state


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _bn(p, s, x, train: bool, eps=1e-5):
    if train:
        mean = jnp.mean(x, axis=(0, 1, 2))
        var = jnp.var(x, axis=(0, 1, 2))
        new_s = {"mean": BN_MOMENTUM * s["mean"] + (1 - BN_MOMENTUM) * mean,
                 "var": BN_MOMENTUM * s["var"] + (1 - BN_MOMENTUM) * var}
    else:
        mean, var = s["mean"], s["var"]
        new_s = s
    y = (x - mean) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return y, new_s


def init_resnet(cfg: ModelConfig, key):
    widths = cfg.widths
    n = cfg.blocks_per_stage
    keys = iter(jax.random.split(key, 4 + 6 * len(widths) * n))
    params, state = {}, {}
    params["stem"] = _conv_init(next(keys), 3, 3, widths[0])
    params["stem_bn"], state["stem_bn"] = _bn_init(widths[0])
    cin = widths[0]
    for si, w in enumerate(widths):
        for bi in range(n):
            name = f"s{si}b{bi}"
            stride = 2 if (si > 0 and bi == 0) else 1
            blk, blk_state = {}, {}
            blk["conv1"] = _conv_init(next(keys), 3, cin, w)
            blk["bn1"], blk_state["bn1"] = _bn_init(w)
            blk["conv2"] = _conv_init(next(keys), 3, w, w)
            blk["bn2"], blk_state["bn2"] = _bn_init(w)
            params[name], state[name] = blk, blk_state
            cin = w
    params["fc_w"] = (jax.random.normal(next(keys), (widths[-1], cfg.n_classes))
                      / jnp.sqrt(widths[-1])).astype(jnp.float32)
    params["fc_b"] = jnp.zeros((cfg.n_classes,), jnp.float32)
    return params, state


def apply_resnet(cfg: ModelConfig, params, bn_state, x, train: bool = True):
    new_state = {}
    h = _conv(x, params["stem"])
    h, new_state["stem_bn"] = _bn(params["stem_bn"], bn_state["stem_bn"], h, train)
    h = jax.nn.relu(h)
    n = cfg.blocks_per_stage
    for si, w in enumerate(cfg.widths):
        for bi in range(n):
            name = f"s{si}b{bi}"
            blk, blk_s = params[name], bn_state[name]
            stride = 2 if (si > 0 and bi == 0) else 1
            ns = {}
            y = _conv(h, blk["conv1"], stride)
            y, ns["bn1"] = _bn(blk["bn1"], blk_s["bn1"], y, train)
            y = jax.nn.relu(y)
            y = _conv(y, blk["conv2"])
            y, ns["bn2"] = _bn(blk["bn2"], blk_s["bn2"], y, train)
            if stride != 1 or h.shape[-1] != w:
                # identity shortcut: stride-2 subsample + zero-pad channels
                sc = h[:, ::stride, ::stride]
                pad = w - sc.shape[-1]
                sc = jnp.pad(sc, ((0, 0), (0, 0), (0, 0), (0, pad)))
            else:
                sc = h
            h = jax.nn.relu(y + sc)
            new_state[name] = ns
    h = jnp.mean(h, axis=(1, 2))
    logits = h @ params["fc_w"] + params["fc_b"]
    return logits, new_state


def resnet_loss(cfg, params, bn_state, batch, train: bool = True):
    logits, new_state = apply_resnet(cfg, params, bn_state,
                                     batch["tokens"], train)
    targets = batch["targets"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    loss = -jnp.mean(jnp.take_along_axis(logp, targets[:, None], axis=-1))
    acc = jnp.mean((jnp.argmax(logits, -1) == targets).astype(jnp.float32))
    return loss, {"loss": loss, "acc": acc, "bn_state": new_state}
