"""internvl2-1b [arXiv:2404.16821] — InternViT + Qwen2-0.5B-style decoder.

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655. The vision encoder
is a stub per the assignment: ``input_specs()`` supplies 256 precomputed
patch embeddings (d_vis=1024) consumed through a learned projector.
"""
from repro.models.types import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b", family="vlm",
        n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
        d_ff=4864, vocab_size=151655,
        n_vis_tokens=256, d_vis=1024,
        source="[arXiv:2404.16821]")


def smoke_config() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=56, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=128, n_vis_tokens=8, d_vis=32,
        attn_impl="naive", remat="none", dtype="float32")
