"""hymba-1.5b [arXiv:2411.13676] — parallel attention + mamba heads.

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Hymba fuses attention and SSM head outputs *in parallel* within each layer
and prepends 128 learnable meta tokens. Deviation (DESIGN.md §4): all
layers use sliding-window attention (window 1024); the original keeps 3
full-attention layers. SSM state is constant-size → native long_500k.
"""
from repro.models.types import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b", family="hybrid",
        n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
        d_ff=5504, vocab_size=32001,
        ssm_state=16, ssm_heads=25, conv_kernel=4,
        sliding_window=1024, n_meta_tokens=128,
        source="[arXiv:2411.13676]")


def smoke_config() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=128, ssm_state=4, ssm_heads=4, sliding_window=16,
        n_meta_tokens=4, attn_impl="naive", remat="none", dtype="float32")
