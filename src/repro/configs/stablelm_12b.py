"""stablelm-12b [hf:stabilityai/stablelm-2-12b] — dense GQA.

40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352 (head_dim 160).
"""
from repro.models.types import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-12b", family="dense",
        n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
        d_ff=13824, vocab_size=100352,
        source="[hf:stabilityai/stablelm-2-1_6b (12b family)]")


def smoke_config() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=128, attn_impl="naive", remat="none", dtype="float32")
