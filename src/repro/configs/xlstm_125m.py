"""xlstm-125m [arXiv:2405.04517] — alternating sLSTM + mLSTM blocks.

12L d_model=768 4H vocab=50304; d_ff=0 (the xLSTM blocks carry their own
projection factor). Constant-size recurrent state → native long_500k.
"""
from repro.models.types import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m", family="ssm",
        n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab_size=50304, conv_kernel=4,
        source="[arXiv:2405.04517]")


def smoke_config() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, vocab_size=128,
        remat="none", dtype="float32")
