"""command-r-35b [hf:CohereForAI/c4ai-command-r-v01] — dense GQA, no bias.

40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000.
"""
from repro.models.types import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="command-r-35b", family="dense",
        n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=22528, vocab_size=256000,
        source="[hf:CohereForAI/c4ai-command-r-v01]")


def smoke_config() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=128,
        vocab_size=128, attn_impl="naive", remat="none", dtype="float32")
