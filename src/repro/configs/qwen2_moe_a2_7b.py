"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=151936, MoE 60e top-4,
4 shared + 60 routed top-4. The 1408 is the routed-expert hidden size; the
shared-expert block is 4×1408 wide with a sigmoid gate (model card).
"""
from repro.models.types import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b", family="moe",
        n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1408, expert_d_ff=1408, vocab_size=151936,
        n_experts=60, top_k=4, n_shared_experts=4,
        source="[hf:Qwen/Qwen1.5-MoE-A2.7B]")


def smoke_config() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=96, expert_d_ff=96, vocab_size=128,
        n_experts=4, top_k=2, n_shared_experts=1,
        attn_impl="naive", remat="none", dtype="float32")
