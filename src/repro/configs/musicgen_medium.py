"""musicgen-medium [arXiv:2306.05284] — decoder over EnCodec tokens.

48L d_model=1536 24H (kv=24, MHA) d_ff=6144 vocab=2048, 4 codebooks.
The EnCodec audio frontend is a stub per the assignment: ``input_specs()``
feeds 4 parallel token streams; embeddings are summed, one output head per
codebook; the serving engine applies the delay pattern. Deviation: RoPE
instead of sinusoidal positions (DESIGN.md §8).
"""
from repro.models.types import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium", family="audio",
        n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
        d_ff=6144, vocab_size=2048, n_codebooks=4,
        source="[arXiv:2306.05284]")


def smoke_config() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=64, n_codebooks=2,
        attn_impl="naive", remat="none", dtype="float32")
