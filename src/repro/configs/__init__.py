"""Assigned-architecture registry (``--arch <id>``).

Each module defines ``config()`` (the exact assigned configuration, source
cited) and ``smoke_config()`` (a reduced same-family variant: ≤2 layers,
d_model ≤ 512, ≤4 experts) for CPU smoke tests. Full configs are exercised
only via the dry-run (ShapeDtypeStructs, no allocation).
"""
from __future__ import annotations

import importlib

from repro.models.types import INPUT_SHAPES, InputShape, ModelConfig

ARCH_IDS = [
    "qwen2-moe-a2.7b",
    "internvl2-1b",
    "xlstm-125m",
    "granite-moe-1b-a400m",
    "hymba-1.5b",
    "granite-3-2b",
    "stablelm-12b",
    "command-r-35b",
    "gemma2-27b",
    "musicgen-medium",
]

_MODULES = {a: "repro.configs." + a.replace("-", "_").replace(".", "_")
            for a in ARCH_IDS}


def _module(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; choose from {ARCH_IDS}")
    return importlib.import_module(_MODULES[arch_id])


def get_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).config()


def get_smoke_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).smoke_config()


def get_input_shape(name: str) -> InputShape:
    return INPUT_SHAPES[name]
