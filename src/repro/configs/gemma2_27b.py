"""gemma2-27b [arXiv:2408.00118] — local/global alternating + softcaps.

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000; head_dim=128
(model card), sliding window 4096 on local layers, attention logit softcap
50.0, final-logit softcap 30.0, GeGLU.
"""
from repro.models.types import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-27b", family="dense",
        n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16,
        d_ff=36864, vocab_size=256000, head_dim=128,
        sliding_window=4096, global_every=2,
        logit_softcap=50.0, final_softcap=30.0, act="gelu",
        source="[arXiv:2408.00118]")


def smoke_config() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=128, head_dim=16, sliding_window=16,
        attn_impl="naive", remat="none", dtype="float32")
