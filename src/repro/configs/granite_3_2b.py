"""granite-3-2b [hf:ibm-granite/granite-3.0-2b-base] — dense GQA.

40L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=49155.
"""
from repro.models.types import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-3-2b", family="dense",
        n_layers=40, d_model=2048, n_heads=32, n_kv_heads=8,
        d_ff=8192, vocab_size=49155,
        source="[hf:ibm-granite/granite-3.0-2b-base]")


def smoke_config() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=128, attn_impl="naive", remat="none", dtype="float32")
