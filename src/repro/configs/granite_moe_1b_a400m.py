"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base].

24L d_model=1024 16H (GQA kv=8) d_ff=512 vocab=49155, MoE 32e top-8.
32 % 16 == 0, so this arch also exercises the expert-parallel all-to-all
path (``expert_parallel=True`` variant) on the production meshes.
"""
from repro.models.types import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m", family="moe",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8,
        d_ff=512, expert_d_ff=512, vocab_size=49155,
        n_experts=32, top_k=8,
        source="[hf:ibm-granite/granite-3.0-1b-a400m-base]")


def smoke_config() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=64, expert_d_ff=64, vocab_size=128, n_experts=4, top_k=2,
        attn_impl="naive", remat="none", dtype="float32")
