"""Serving launcher: batched decode against a smoke-scale model.

  PYTHONPATH=src python -m repro.launch.serve --arch musicgen-medium \
      --batch 4 --prompt-len 16 --new-tokens 16

``--engine paged`` routes through the production tier (paged KV cache +
continuous-batching scheduler + single fixed-shape jitted step); the
default ``naive`` engine is the whole-batch parity reference.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models.registry import build_model
from repro.serve.engine import DecodeEngine, PagedDecodeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b", choices=ARCH_IDS)
    ap.add_argument("--engine", default="naive", choices=["naive", "paged"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--page-size", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    lm = build_model(cfg)
    params = lm.init(jax.random.key(0))
    key = jax.random.key(1)
    B, S = args.batch, args.prompt_len
    batch = {}
    if cfg.family == "audio":
        batch["tokens"] = jax.random.randint(
            key, (B, S, cfg.n_codebooks), 0, cfg.vocab_size)
    else:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    if cfg.family == "vlm":
        batch["vis_embeds"] = jax.random.normal(
            key, (B, cfg.n_vis_tokens, cfg.d_vis), jnp.float32)

    if args.engine == "paged":
        engine = PagedDecodeEngine(
            lm=lm, params=params, max_batch=B,
            max_seq_len=S + args.new_tokens + 16,
            max_new=args.new_tokens, page_size=args.page_size,
            prefill_chunk=max(S, 8), temperature=args.temperature)
        t0 = time.time()
        out = engine.generate(batch, args.new_tokens)
        dt = time.time() - t0
        extra = f" step_traces={engine.step_traces}"
    else:
        engine = DecodeEngine(lm, params, max_seq_len=S + args.new_tokens)
        t0 = time.time()
        out = engine.generate(batch, args.new_tokens,
                              temperature=args.temperature)
        dt = time.time() - t0
        extra = ""
    print(f"[serve:{args.engine}] {args.arch}: generated {out.shape} in "
          f"{dt:.2f}s ({args.new_tokens * B / dt:.1f} tok/s){extra}")
    print(out[0].tolist()[:8])


if __name__ == "__main__":
    main()
