"""Step builders: plain data+tensor-parallel training/serving steps and the
HWA-stacked variants, with in/out shardings resolved from the logical-dim
trees. These are what the dry-run lowers and what real launches would run.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common.compat import shard_map
from repro.core.hwa import (HWAConfig, hwa_inner_step, hwa_local_inner_step,
                            hwa_sync)
from repro.models.registry import LM
from repro.optim import adamw, apply_updates, sgd
from repro.sharding.rules import (ShardingRules, make_tp_rules,
                                  replicated_specs, stacked_replica_specs)

PyTree = Any


def _prefix_dims(dim_tree, name):
    """Prepend a logical dim to every dims-tuple leaf (e.g. 'replica')."""
    is_dims = lambda t: isinstance(t, tuple) and all(
        isinstance(e, (str, type(None))) for e in t)
    return jax.tree.map(lambda t: (name,) + t, dim_tree, is_leaf=is_dims)


def opt_state_dims(opt_state_abs, param_dims):
    """Logical dims for optimizer state: moments mirror the params."""
    def dims_for(path_leaf):
        return param_dims
    # adamw: {"m": params-like, "v": params-like, "count": scalar}
    # sgd(momentum): {"mu": params-like}
    out = {}
    for k, v in opt_state_abs.items():
        if k == "count":
            out[k] = ()
        else:
            out[k] = param_dims
    return out


@dataclasses.dataclass
class StepBundle:
    """A step function plus its abstract args and in/out shardings.

    ``pack_spec`` is set by the WA sync bundles: their window state (and
    returned W̿) lives in the packed layout of ``repro.common.packing``;
    consumers materialize leaf views with ``packing.unpack(buf,
    bundle.pack_spec)``.
    """
    fn: Any
    abstract_args: tuple
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple = ()
    pack_spec: Any = None

    def lower(self, mesh: Mesh):
        jitted = jax.jit(self.fn, in_shardings=self.in_shardings,
                         out_shardings=self.out_shardings,
                         donate_argnums=self.donate_argnums)
        with mesh:
            return jitted.lower(*self.abstract_args)


def _mk_optimizer(name: str):
    if name == "sgd":
        return sgd(momentum=0.9, weight_decay=5e-4)
    return adamw(weight_decay=0.1)


def make_train_step(lm: LM, rules: ShardingRules, batch_specs, batch_dims,
                    optimizer: str = "adamw", lr: float = 3e-4,
                    opt_rules: ShardingRules | None = None,
                    n_microbatches: int = 1) -> StepBundle:
    """Plain data+tensor-parallel train step (the 40-combo baseline).

    ``opt_rules`` lets the optimizer moments use a different (e.g. FSDP)
    rule table than the compute params. ``n_microbatches`` > 1 enables
    gradient accumulation: peak activation temps scale ~1/n_mb while the
    f32 grad accumulator is fully sharded — the lever that fits the ≥27B
    trainings into 16 GB/chip (EXPERIMENTS.md §Perf).
    """
    opt = _mk_optimizer(optimizer)
    params_abs, param_dims = lm.abstract()
    opt_abs = jax.eval_shape(opt.init, params_abs)
    o_dims = opt_state_dims(opt_abs, param_dims)
    opt_rules = opt_rules or rules
    loss_fn = lambda p, b: lm.loss(p, b, rules=rules)

    def step(params, opt_state, batch):
        if n_microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape((n_microbatches,
                                     x.shape[0] // n_microbatches)
                                    + x.shape[1:]), batch)

            def body(acc, mbatch):
                g_acc, l_acc, a_acc = acc
                (loss, metrics), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mbatch)
                g_acc = jax.tree.map(
                    lambda a, gi: a + gi.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + metrics["loss"],
                        a_acc + metrics["acc"]), None

            zeros = jax.tree.map(
                lambda pp: jnp.zeros(pp.shape, jnp.float32), params)
            (g_sum, l_sum, a_sum), _ = jax.lax.scan(
                body, (zeros, jnp.zeros(()), jnp.zeros(())), mb)
            grads = jax.tree.map(
                lambda g, pp: (g / n_microbatches).astype(pp.dtype),
                g_sum, params)
            metrics = {"loss": l_sum / n_microbatches,
                       "aux": jnp.zeros(()),
                       "acc": a_sum / n_microbatches}
        updates, opt_state = opt.update(grads, opt_state, params, lr)
        params = apply_updates(params, updates)
        return params, opt_state, metrics

    p_sh = rules.tree_shardings(params_abs, param_dims)
    o_sh = opt_rules.tree_shardings(opt_abs, o_dims)
    b_sh = rules.tree_shardings(batch_specs, batch_dims)
    scalar_sh = NamedSharding(rules.mesh, P())
    m_sh = {"loss": scalar_sh, "aux": scalar_sh, "acc": scalar_sh}
    return StepBundle(
        fn=step, abstract_args=(params_abs, opt_abs, batch_specs),
        in_shardings=(p_sh, o_sh, b_sh),
        out_shardings=(p_sh, o_sh, m_sh),
        donate_argnums=(0, 1))


def make_prefill_step(lm: LM, rules: ShardingRules, batch_specs, batch_dims,
                      cache_abs, cache_dims) -> StepBundle:
    def step(params, cache, batch):
        return lm.prefill(params, cache, batch, rules=rules)

    params_abs, param_dims = lm.abstract()
    p_sh = rules.tree_shardings(params_abs, param_dims)
    c_sh = rules.tree_shardings(cache_abs, cache_dims)
    b_sh = rules.tree_shardings(batch_specs, batch_dims)
    logits_abs = jax.eval_shape(step, params_abs, cache_abs, batch_specs)[0]
    logits_dims = ("batch",) + (None,) * (len(logits_abs.shape) - 2) + ("vocab",)
    l_sh = rules.tree_shardings(logits_abs, logits_dims)
    return StepBundle(
        fn=step, abstract_args=(params_abs, cache_abs, batch_specs),
        in_shardings=(p_sh, c_sh, b_sh),
        out_shardings=(l_sh, c_sh),
        donate_argnums=(1,))


def make_decode_step(lm: LM, rules: ShardingRules, token_specs, token_dims,
                     cache_abs, cache_dims) -> StepBundle:
    def step(params, cache, tokens):
        return lm.decode_step(params, cache, tokens, rules=rules)

    params_abs, param_dims = lm.abstract()
    p_sh = rules.tree_shardings(params_abs, param_dims)
    c_sh = rules.tree_shardings(cache_abs, cache_dims)
    t_sh = rules.tree_shardings(token_specs, token_dims)
    logits_abs = jax.eval_shape(step, params_abs, cache_abs, token_specs)[0]
    logits_dims = ("batch",) + (None,) * (len(logits_abs.shape) - 2) + ("vocab",)
    l_sh = rules.tree_shardings(logits_abs, logits_dims)
    return StepBundle(
        fn=step, abstract_args=(params_abs, cache_abs, token_specs),
        in_shardings=(p_sh, c_sh, t_sh),
        out_shardings=(l_sh, c_sh),
        donate_argnums=(1,))


# ------------------------------------------------------------- HWA steps


def make_hwa_train_step(lm: LM, rules: ShardingRules, batch_specs, batch_dims,
                        hwa_cfg: HWAConfig, optimizer: str = "adamw",
                        lr: float = 3e-4,
                        opt_rules: ShardingRules | None = None,
                        n_microbatches: int = 1) -> StepBundle:
    """Inner HWA step: K independent replicas, stacked on the replica axis.

    Gradient all-reduce stays *inside* each replica's data shard; nothing
    crosses the replica/pod axis here — that is the H-fold comm saving.
    """
    opt = _mk_optimizer(optimizer)
    K = hwa_cfg.n_replicas
    params_abs, param_dims = lm.abstract()
    stacked_abs = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((K,) + s.shape, s.dtype), params_abs)
    stacked_dims = _prefix_dims(param_dims, "replica")
    opt_abs = jax.eval_shape(lambda p: jax.vmap(opt.init)(p), stacked_abs)
    o_dims = opt_state_dims(opt_abs, stacked_dims)
    if "count" in o_dims:          # adamw step counter, vmapped to (K,)
        o_dims["count"] = ("replica",)
    opt_rules = opt_rules or rules
    kbatch_abs = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((K,) + s.shape, s.dtype), batch_specs)
    kbatch_dims = _prefix_dims(batch_dims, "replica")

    def loss_fn(params, batch):
        return lm.loss(params, batch, rules=rules)

    def step(inner, inner_opt, batches):
        def one(params, opt_state, batch):
            if n_microbatches == 1:
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, batch)
            else:
                mb = jax.tree.map(
                    lambda x: x.reshape((n_microbatches,
                                         x.shape[0] // n_microbatches)
                                        + x.shape[1:]), batch)

                def body(acc, mbatch):
                    g_acc, l_acc = acc
                    (l, m), g = jax.value_and_grad(
                        loss_fn, has_aux=True)(params, mbatch)
                    g_acc = jax.tree.map(
                        lambda a, gi: a + gi.astype(jnp.float32), g_acc, g)
                    return (g_acc, l_acc + m["loss"]), None

                zeros = jax.tree.map(
                    lambda pp: jnp.zeros(pp.shape, jnp.float32), params)
                (g_sum, l_sum), _ = jax.lax.scan(
                    body, (zeros, jnp.zeros(())), mb)
                grads = jax.tree.map(
                    lambda g, pp: (g / n_microbatches).astype(pp.dtype),
                    g_sum, params)
                metrics = {"loss": l_sum / n_microbatches}
            updates, opt_state = opt.update(grads, opt_state, params, lr)
            return apply_updates(params, updates), opt_state, metrics["loss"]

        inner, inner_opt, losses = jax.vmap(one)(inner, inner_opt, batches)
        return inner, inner_opt, jnp.mean(losses)

    p_sh = rules.tree_shardings(stacked_abs, stacked_dims)
    o_sh = opt_rules.tree_shardings(opt_abs, o_dims)
    b_sh = rules.tree_shardings(kbatch_abs, kbatch_dims)
    scalar_sh = NamedSharding(rules.mesh, P())
    return StepBundle(
        fn=step, abstract_args=(stacked_abs, opt_abs, kbatch_abs),
        in_shardings=(p_sh, o_sh, b_sh),
        out_shardings=(p_sh, o_sh, scalar_sh),
        donate_argnums=(0, 1))


def _norm_entry(entry) -> tuple[str, ...]:
    """A PartitionSpec entry as a tuple of mesh-axis names."""
    if entry is None:
        return ()
    return tuple(entry) if isinstance(entry, (tuple, list)) else (entry,)


def _axes_entry(axes: tuple[str, ...]):
    """A packed super-axis as a PartitionSpec entry (None/str/tuple)."""
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else tuple(axes)


def _packed_sharding(mesh: Mesh, padded: int, lead_dims: int = 0,
                     axes: tuple[str, ...] | None = None) -> NamedSharding:
    """Sharding for a packed WA buffer.

    ``axes`` is the packed super-axis of a shard-aware ``PackSpec``
    (``spec.axes``) — the packed dim is split over exactly those mesh
    axes, jointly. ``axes=None`` keeps the legacy heuristic used by the
    non-mesh-resident fallback: split over ``model`` when it divides
    (it always does — ``padded`` is an ALIGN multiple), else replicate.
    """
    if axes is None:
        ax = "model" if ("model" in mesh.shape
                         and padded % mesh.shape["model"] == 0) else None
    else:
        ax = _axes_entry(axes)
    return NamedSharding(mesh, P(*([None] * lead_dims + [ax])))


def _mesh_resident_layout(mesh: Mesh, flat_specs, flat_shapes,
                          exclude: tuple[str, ...] = ()):
    """Choose a packed super-axis aligning leaf tilings with packed ranges.

    Returns ``(axes, shard_dims)`` such that ``pack_spec(params,
    shards=prod(axes), shard_dims=..., axes=axes)`` makes packed-W̄
    assembly and W̿ unpacking shard-local (zero collectives): every leaf
    either has exactly ONE dim sharded over exactly ``axes`` (jointly, in
    order) — that dim becomes its ``shard_dim`` — or is replicated over
    the non-``exclude`` mesh axes and gets duplicated per segment.

    Candidates are the distinct PartitionSpec entries the leaves actually
    use (arbitrary mesh-axis sets, not just the single ``model`` axis),
    tried largest-device-count first; ``((), all-None)`` is returned for
    fully-replicated trees, and ``(None, None)`` when no super-axis covers
    every leaf (e.g. FSDP's mixed data/model tilings) — callers then fall
    back to the legacy redistribute-and-all-reduce assembly.
    """
    cands: list[tuple[str, ...]] = []
    for sp in flat_specs:
        for e in sp:
            t = _norm_entry(e)
            if (t and not (set(t) & set(exclude)) and t not in cands
                    and math.prod(mesh.shape[a] for a in t) > 1):
                cands.append(t)
    cands.sort(key=lambda t: -math.prod(mesh.shape[a] for a in t))
    cands.append(())
    for cand in cands:
        S = math.prod(mesh.shape[a] for a in cand) if cand else 1
        dims: list[int | None] = []
        ok = True
        for sp, shape in zip(flat_specs, flat_shapes):
            hot = []
            for i, e in enumerate(sp):
                t = _norm_entry(e)
                if not t or math.prod(mesh.shape[a] for a in t) == 1:
                    continue                      # effectively replicated
                if t == cand:
                    hot.append(i)
                else:
                    ok = False                    # sharded over another set
                    break
            if not ok or len(hot) > 1:
                ok = False
                break
            if not hot:
                dims.append(None)
            elif shape[hot[0]] % S == 0 and all(d > 0 for d in shape):
                dims.append(hot[0])
            else:
                ok = False
                break
        if ok:
            return (cand, dims) if S > 1 else ((), [None] * len(flat_specs))
    return None, None


def _warn_legacy_assembly(mesh: Mesh) -> None:
    """The legacy GSPMD packed-W̄ assembly (masked concat + param-size
    all-reduce) is MISCOMPILED by XLA 0.4.37's CPU SPMD partitioner —
    replicated shards get overcounted (~4× on the (2,2,2) test mesh), so
    the fallback silently corrupts W̿ there. It is only reachable when
    the parameter tilings admit no aligned packed layout (e.g. FSDP);
    warn loudly rather than fail, since non-CPU backends lower the same
    pattern correctly."""
    if mesh.size > 1 and jax.default_backend() == "cpu":
        import warnings
        warnings.warn(
            "HWA sync: falling back to the legacy GSPMD packed-W̄ assembly "
            "on a multi-device CPU mesh — XLA 0.4.37's CPU partitioner is "
            "known to miscompile this pattern (overcounted replicated "
            "shards). Use tilings that _mesh_resident_layout can align "
            "(see docs/ARCHITECTURE.md §1) or treat W̿ as untrusted here.",
            RuntimeWarning, stacklevel=3)


def _local_packed_sync(hwa_cfg: HWAConfig, lspec, K: int,
                       k_axes: tuple[str, ...], use_kernel: bool,
                       with_stride: bool, inner, ring, total, count,
                       next_idx, cycle):
    """Per-device body of the mesh-resident packed sync.

    Runs under a FULLY-MANUAL shard_map (every mesh axis manual), so the
    Pallas kernels see true local shapes — the per-shard (I, P/shards)
    ring slice — instead of GSPMD's global-shape view that made them
    unusable on meshes. ``lspec`` is ``pack_spec.local_spec()``: the
    device's segment of the shard-aware layout, assembled here from the
    local leaf shards alone (zero collectives by construction).

    The ONE inter-replica collective is the psum of the pre-scaled
    partial mean over ``k_axes`` (the mesh axes sharding the stacked K
    dim); with K resident on a single device (``k_axes == ()``) even that
    disappears and the whole sync fuses into one kernel launch.
    """
    from repro.common.packing import pack_stacked, unpack
    from repro.core.hwa import window_push_packed
    from repro.core.offline import WindowState, window_update_packed
    from repro.core.online import broadcast_to_replicas

    I = hwa_cfg.window
    sbuf = pack_stacked(inner, lspec)            # (K_local, seg_len) f32
    k_local = sbuf.shape[0]
    fused = (use_kernel and not k_axes and ring.dtype == jnp.float32
             and (not with_stride or hwa_cfg.window_stride == 1))
    if fused:
        # whole sync in ONE launch on the local slice: K-mean + window
        # push, (K+2) reads + 3 writes, W̄ read back from the ring slot
        from repro.kernels import ops as kops
        idx = next_idx
        full = (count >= I).astype(jnp.float32)
        new_count = jnp.minimum(count + 1, I)
        ring2, total2, avg = kops.hwa_sync_packed(
            sbuf, ring, total, idx, full,
            1.0 / new_count.astype(jnp.float32))
        mean = jax.lax.dynamic_index_in_dim(ring2, idx, keepdims=False)
        ws2 = WindowState(ring=ring2, total=total2, count=new_count,
                          next_idx=jnp.mod(idx + 1, I), window=I,
                          kind="ring", spec=lspec)
        new_cycle = cycle + 1
    else:
        if use_kernel and k_local > 1:
            from repro.kernels import ops as kops
            part = kops.online_mean_packed(sbuf, inv_k=1.0 / K)
        else:
            part = jnp.sum(sbuf, axis=0) * (1.0 / K)
        # THE weight all-reduce: pre-scaled partial sums keep the result
        # bit-identical to the fused kernel's sum×(1/K) for power-of-two K
        mean = jax.lax.psum(part, k_axes) if k_axes else part
        ws = WindowState(ring=ring, total=total, count=count,
                         next_idx=next_idx, window=I, kind="ring",
                         spec=lspec)
        if with_stride:
            ws2, avg, new_cycle = window_push_packed(
                hwa_cfg, mean, ws, cycle, use_kernel=use_kernel)
        else:
            ws2, avg = window_update_packed(ws, mean, use_kernel=use_kernel)
            new_cycle = cycle + 1
    outer = unpack(mean, lspec)                  # local leaf views, free
    wa = unpack(avg, lspec)
    new_inner = broadcast_to_replicas(outer, k_local)
    return (new_inner, ws2.ring, ws2.total, ws2.count, ws2.next_idx, wa,
            new_cycle)


def make_hwa_sync_step(lm: LM, rules: ShardingRules, hwa_cfg: HWAConfig,
                       ring_dtype=jnp.float32,
                       mesh_resident: bool | None = None) -> StepBundle:
    """Synchronization + window update: the once-per-H-steps collective.

    outer = mean over the replica axis (one all-reduce across pods);
    inner ← broadcast(outer); slide-window update on PACKED state: the
    ring is one (I, P) buffer and the total one (P,) buffer over the whole
    parameter set (``repro.common.packing``), held packed across the jit
    boundary so the donation of ring/total is a true in-place update
    step-to-step — no per-leaf launches, no per-call padding.

    **pack_spec contract.** ``bundle.pack_spec`` is the layout the caller
    MUST allocate the window buffers from — ``ring = zeros((I,
    spec.padded), ring_dtype)``, ``total = zeros((spec.padded,), f32)`` —
    and the layout W̿/checkpointed state are expressed in. It is not
    always the default contiguous layout: the mesh-resident path below
    chooses a shard-aware layout (``spec.shards > 1``) whose ``padded``
    differs, so callers must never substitute their own
    ``pack_spec(params)``. Leaf views come back via ``packing.unpack(buf,
    bundle.pack_spec)``; checkpoints written through
    ``checkpoint.save_window_state`` record the layout and repack on load
    when it changed.

    **Donation invariants.** args 0-2 (stacked inner, ring, total) are
    donated: the caller's arrays are consumed every call and the returned
    buffers must be threaded into the next call (the trainer's steady
    state — this is what makes the ring update truly in place). Scalars
    (count, next_idx) are not donated.

    **Kernel gating / mesh residency.** On a single device the fused
    Pallas path runs as-is. On a multi-device mesh a bare ``pallas_call``
    is opaque to the GSPMD partitioner — XLA runs it per-shard with
    GLOBAL-shape semantics and silently corrupts values — so multi-device
    meshes default to the MESH-RESIDENT path: the whole sync runs inside
    a fully-manual ``shard_map`` where each device assembles and updates
    its local ``(I, P/shards)`` slice of a shard-aware packed layout
    (zero assembly collectives; see ``_local_packed_sync``), driving the
    Pallas kernel on true local shapes when ``use_kernels`` and the jnp
    reference otherwise. When the parameter tilings admit no such layout
    (``_mesh_resident_layout`` → None, e.g. FSDP) the legacy GSPMD
    fallback below runs instead, paying one param-size assembly
    all-reduce per sync (and trusting the backend's partitioner with the
    packed-buffer redistribution — the 0.4.37 CPU partitioner is known
    to overcount replicated shards in exactly that pattern, one more
    reason the aligned layout is the default). ``mesh_resident`` forces
    the choice (True raises if the layout does not qualify); None picks
    automatically.

    Variants (EXPERIMENTS.md §Perf pair 3): exact f32 ring (paper),
    bf16 ring (2× window memory saving), or hwa_cfg.window_kind ==
    "streaming" (O(1) extra copies, windowed-running-mean approximation;
    always the jnp path — it is a two-pass rescale, not ring-shaped).
    """
    from repro.common.packing import pack, pack_spec, pack_stacked, unpack
    from repro.core.offline import WindowState, window_update_packed
    from repro.core.online import broadcast_to_replicas, online_average

    K = hwa_cfg.n_replicas
    I = hwa_cfg.window
    mesh = rules.mesh
    streaming = hwa_cfg.window_kind == "streaming"
    use_kernel = hwa_cfg.use_kernels and mesh.size == 1
    params_abs, param_dims = lm.abstract()
    stacked_abs = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((K,) + s.shape, s.dtype), params_abs)
    stacked_dims = _prefix_dims(param_dims, "replica")
    scalar_i = jax.ShapeDtypeStruct((), jnp.int32)

    pspec_tree = rules.tree_specs(params_abs, param_dims)
    flat_specs = jax.tree.leaves(pspec_tree)
    flat_shapes = [tuple(l.shape) for l in jax.tree.leaves(params_abs)]
    k_entry = rules.spec(("replica",), (K,))
    k_axes = _norm_entry(k_entry[0] if len(k_entry) else None)
    axes, shard_dims = _mesh_resident_layout(mesh, flat_specs, flat_shapes,
                                             exclude=k_axes)
    if mesh_resident is None:
        mesh_resident = (mesh.size > 1 and not streaming
                         and axes is not None)
    if mesh_resident and (axes is None or streaming):
        raise ValueError("mesh-resident sync needs a ring window and "
                         "leaf tilings that align with packed ranges "
                         "(_mesh_resident_layout found none)")

    if mesh_resident:
        S = math.prod(mesh.shape[a] for a in axes) if axes else 1
        spec = pack_spec(params_abs, shards=S, shard_dims=shard_dims,
                         axes=axes)
        ring_abs = jax.ShapeDtypeStruct((I, spec.padded), ring_dtype)
        total_abs = jax.ShapeDtypeStruct((spec.padded,), jnp.float32)
        stacked_pspecs = rules.tree_specs(stacked_abs, stacked_dims)
        pax = _axes_entry(axes)
        body = functools.partial(_local_packed_sync, hwa_cfg,
                                 spec.local_spec(), K, k_axes,
                                 hwa_cfg.use_kernels, False)

        def local_step(inner, ring, total, count, next_idx):
            return body(inner, ring, total, count, next_idx,
                        jnp.zeros((), jnp.int32))[:6]

        step = shard_map(
            local_step, mesh,
            in_specs=(stacked_pspecs, P(None, pax), P(pax), P(), P()),
            out_specs=(stacked_pspecs, P(None, pax), P(pax), P(), P(),
                       pspec_tree),
            check_rep=False)
        p_sh = rules.tree_shardings(stacked_abs, stacked_dims)
        w_sh = rules.tree_shardings(params_abs, param_dims)
        r_sh = _packed_sharding(mesh, spec.padded, lead_dims=1, axes=axes)
        t_sh = _packed_sharding(mesh, spec.padded, axes=axes)
        s_sh = NamedSharding(mesh, P())
        return StepBundle(
            fn=step,
            abstract_args=(stacked_abs, ring_abs, total_abs, scalar_i,
                           scalar_i),
            in_shardings=(p_sh, r_sh, t_sh, s_sh, s_sh),
            out_shardings=(p_sh, r_sh, t_sh, s_sh, s_sh, w_sh),
            donate_argnums=(0, 1, 2), pack_spec=spec)

    _warn_legacy_assembly(mesh)
    spec = pack_spec(params_abs)
    ring_abs = jax.ShapeDtypeStruct((I, spec.padded), ring_dtype)
    total_abs = jax.ShapeDtypeStruct((spec.padded,), jnp.float32)
    r_sh = _packed_sharding(rules.mesh, spec.padded, lead_dims=1)
    t_sh = _packed_sharding(rules.mesh, spec.padded)

    def mean_and_buf(inner):
        """(W̄ leaf views, packed W̄) without a pack/unpack round-trip.

        The sharding constraint pins the packed buffer to the window
        state's own sharding so the elementwise push stays shard-local
        (GSPMD otherwise computes it as distributed partial sums + a
        full-buffer all-reduce crossing every mesh axis).
        """
        if use_kernel:
            from repro.kernels import ops as kops
            buf = kops.online_mean_packed(pack_stacked(inner, spec))
            outer = unpack(buf, spec)
        else:
            outer = online_average(inner)
            buf = pack(outer, spec)
        return outer, jax.lax.with_sharding_constraint(buf, t_sh)

    def step_ring(inner, ring, total, count, next_idx):
        outer, buf = mean_and_buf(inner)
        new_inner = broadcast_to_replicas(outer, K)
        ws = WindowState(ring=ring, total=total, count=count,
                         next_idx=next_idx, window=I, kind="ring", spec=spec)
        ws2, avg = window_update_packed(ws, buf, use_kernel=use_kernel)
        wa = unpack(avg, spec)      # leaf views of W̿ (slices, no copy)
        return new_inner, ws2.ring, ws2.total, ws2.count, ws2.next_idx, wa

    def step_streaming(inner, total, count):
        outer, buf = mean_and_buf(inner)
        new_inner = broadcast_to_replicas(outer, K)
        ws = WindowState(ring=None, total=total, count=count,
                         next_idx=jnp.zeros((), jnp.int32), window=I,
                         kind="streaming", spec=spec)
        ws2, avg = window_update_packed(ws, buf)
        return new_inner, ws2.total, ws2.count, unpack(avg, spec)

    p_sh = rules.tree_shardings(stacked_abs, stacked_dims)
    w_sh = rules.tree_shardings(params_abs, param_dims)
    s_sh = NamedSharding(rules.mesh, P())
    if streaming:
        return StepBundle(
            fn=step_streaming,
            abstract_args=(stacked_abs, total_abs, scalar_i),
            in_shardings=(p_sh, t_sh, s_sh),
            out_shardings=(p_sh, t_sh, s_sh, w_sh),
            donate_argnums=(0, 1), pack_spec=spec)
    return StepBundle(
        fn=step_ring,
        abstract_args=(stacked_abs, ring_abs, total_abs, scalar_i, scalar_i),
        in_shardings=(p_sh, r_sh, t_sh, s_sh, s_sh),
        out_shardings=(p_sh, r_sh, t_sh, s_sh, s_sh, w_sh),
        donate_argnums=(0, 1, 2), pack_spec=spec)


# ----------------------------------------------- mesh-native HWA (shard_map)
#
# Same storage layout as the vmap path — stacked (K, ...) state with the
# leading dim sharded over the ``replica`` mesh axis — but the step runs
# under shard_map *manual* over replica (data/model stay auto/GSPMD):
# each replica block squeezes its (1, ...) slice and steps locally, so the
# lowered inner-step HLO provably contains no collective crossing the
# replica axis, and hwa_sync is one jax.lax.pmean over it. That makes the
# paper's H-fold inter-replica communication amortization a structural
# property of the program rather than a GSPMD-propagation accident.


def _squeeze0(tree):
    return jax.tree.map(lambda x: x[0], tree)


def _expand0(tree):
    return jax.tree.map(lambda x: x[None], tree)


def make_mesh_hwa_train_step(lm: LM, rules: ShardingRules, batch_specs,
                             batch_dims, hwa_cfg: HWAConfig,
                             optimizer: str = "adamw", lr: float = 3e-4,
                             opt_rules: ShardingRules | None = None,
                             replica_axis: str = "replica") -> StepBundle:
    """Mesh-native inner HWA step.

    Collective-free over ``replica_axis`` by construction (shard_map keeps
    the replica blocks independent; the only collectives GSPMD may insert
    live inside a block, over the data/model axes). Returns per-replica
    losses as a (K,) array sharded over the replica axis — averaging them
    to a replicated scalar would itself be a replica collective, so the
    caller takes the mean after fetching.
    """
    opt = _mk_optimizer(optimizer)
    K = hwa_cfg.n_replicas
    mesh = rules.mesh
    assert replica_axis in mesh.shape, (replica_axis, mesh.shape)
    assert K == mesh.shape[replica_axis], \
        f"mesh-native path needs K == mesh axis size ({K} != " \
        f"{mesh.shape[replica_axis]}); use the vmap path otherwise"
    auto = frozenset(a for a in mesh.axis_names if a != replica_axis)
    if not lm.cfg.scan_unroll:
        # XLA (0.4.x) fatals on a while loop under manual-subgroup
        # shardings; unrolling the layer scan keeps the body loop-free.
        from repro.models.registry import build_model
        lm = build_model(lm.cfg.with_(scan_unroll=True))
    params_abs, param_dims = lm.abstract()
    stacked_abs = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((K,) + s.shape, s.dtype), params_abs)
    stacked_dims = _prefix_dims(param_dims, "replica")
    opt_abs = jax.eval_shape(lambda p: jax.vmap(opt.init)(p), stacked_abs)
    o_dims = opt_state_dims(opt_abs, stacked_dims)
    if "count" in o_dims:
        o_dims["count"] = ("replica",)
    opt_rules = opt_rules or rules
    kbatch_abs = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((K,) + s.shape, s.dtype), batch_specs)
    kbatch_dims = _prefix_dims(batch_dims, "replica")

    # The body runs the model's pure-jnp path (rules=None): the rules-aware
    # path opens nested shard_maps (vocab-sharded gather, EP MoE) which 0.4.x
    # cannot nest inside a partial-auto map. Layouts over the auto axes are
    # still driven by the jit in/out shardings; constraints are hints only,
    # so the math is unchanged.
    def loss_fn(params, batch):
        return lm.loss(params, batch, rules=None)

    def local_step(inner, inner_opt, batch):
        params, opt_state, loss, _ = hwa_local_inner_step(
            _squeeze0(inner), _squeeze0(inner_opt), _squeeze0(batch),
            loss_fn, opt, lr)
        return _expand0(params), _expand0(opt_state), loss[None]

    step = shard_map(
        local_step, mesh,
        in_specs=(stacked_replica_specs(stacked_abs, replica_axis),
                  stacked_replica_specs(opt_abs, replica_axis),
                  stacked_replica_specs(kbatch_abs, replica_axis)),
        out_specs=(stacked_replica_specs(stacked_abs, replica_axis),
                   stacked_replica_specs(opt_abs, replica_axis),
                   P(replica_axis)),
        check_rep=False, auto=auto)

    p_sh = rules.tree_shardings(stacked_abs, stacked_dims)
    o_sh = opt_rules.tree_shardings(opt_abs, o_dims)
    b_sh = rules.tree_shardings(kbatch_abs, kbatch_dims)
    losses_sh = NamedSharding(mesh, P(replica_axis))
    return StepBundle(
        fn=step, abstract_args=(stacked_abs, opt_abs, kbatch_abs),
        in_shardings=(p_sh, o_sh, b_sh),
        out_shardings=(p_sh, o_sh, losses_sh),
        donate_argnums=(0, 1))


def make_mesh_hwa_sync_step(lm: LM, rules: ShardingRules, hwa_cfg: HWAConfig,
                            ring_dtype=jnp.float32,
                            replica_axis: str = "replica",
                            mesh_resident: bool | None = None) -> StepBundle:
    """Mesh-native synchronization: the once-per-H-steps collective.

    **Mesh-resident path (default).** The ENTIRE sync — packed-W̄
    assembly, the weight all-reduce, the slide-window push, the W̿ unpack
    — runs inside ONE fully-manual ``shard_map`` over every mesh axis
    (``_local_packed_sync``). The window state lives in a shard-aware
    packed layout (``_mesh_resident_layout`` aligns each leaf's tiling
    with its packed range), so each device assembles its own
    ``(I, P/shards)`` ring slice from its local leaf shards, psums the
    pre-scaled partial mean over ``replica_axis`` (the single
    inter-replica collective — and the single collective, period), and
    runs the window push locally: with ``use_kernels`` that is the Pallas
    kernel on true local shapes, which GSPMD could never be trusted with
    (it runs opaque custom calls per-shard with global-shape semantics).
    tests/mesh_hwa_check.py asserts both properties on the lowered HLO
    via ``launch.hlo.sync_collective_audit``: exactly one replica-axis
    all-reduce, zero collectives crossing any other axis.

    Going fully manual also sidesteps the XLA 0.4.x partial-auto caveat
    that previously forced the window push OUTSIDE the manual region:
    partial-auto manual subgroups miscompile packed-buffer assembly from
    auto-sharded leaves (a spurious replica-axis reduction doubles the
    values — the same IsManualSubgroup bug class as the scan_unroll item;
    see ROADMAP "partial-auto on new JAX"/"scan under manual subgroups").
    With no auto axes in the sync map there is no subgroup to miscompile.

    **Fallback.** When the parameter tilings admit no aligned layout
    (``_mesh_resident_layout`` → None, e.g. FSDP's mixed tilings), the
    legacy split runs instead: pmean inside a partial-auto shard_map,
    window push outside in GSPMD-land — correct, but the packed-W̄
    assembly then costs ONE param-size masked all-reduce per sync.
    ``mesh_resident`` forces the choice (True raises if the layout does
    not qualify); None picks automatically.

    **pack_spec contract.** Callers allocate the window buffers from
    ``bundle.pack_spec`` — ``ring = zeros((I, spec.padded), ring_dtype)``,
    ``total = zeros((spec.padded,), f32)`` — and read leaf views with
    ``packing.unpack(buf, bundle.pack_spec)``. The mesh-resident layout's
    ``padded`` includes per-segment alignment and replicated-leaf
    duplicates, so it is NOT interchangeable with ``pack_spec(params)``;
    checkpoints written via ``checkpoint.save_window_state`` record the
    layout and repack bit-exactly on load under a different mesh.

    **Donation invariants.** args 0-2 (stacked inner, ring, total) are
    donated — thread the returned buffers into the next call; the scalar
    counters (count, next_idx, cycle) are returned fresh, not donated.
    """
    from repro.common.packing import pack, pack_spec, unpack
    from repro.core.hwa import window_push_packed
    from repro.core.offline import WindowState
    from repro.core.online import broadcast_to_replicas, online_average_named

    K = hwa_cfg.n_replicas
    I = hwa_cfg.window
    mesh = rules.mesh
    assert replica_axis in mesh.shape and K == mesh.shape[replica_axis], \
        (K, mesh.shape)
    params_abs, param_dims = lm.abstract()
    stacked_abs = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((K,) + s.shape, s.dtype), params_abs)
    stacked_dims = _prefix_dims(param_dims, "replica")
    scalar_i = jax.ShapeDtypeStruct((), jnp.int32)
    p_sh = rules.tree_shardings(stacked_abs, stacked_dims)
    w_sh = rules.tree_shardings(params_abs, param_dims)
    s_sh = NamedSharding(mesh, P())

    pspec_tree = rules.tree_specs(params_abs, param_dims)
    flat_specs = jax.tree.leaves(pspec_tree)
    flat_shapes = [tuple(l.shape) for l in jax.tree.leaves(params_abs)]
    stacked_pspecs = rules.tree_specs(stacked_abs, stacked_dims)
    k_entry = rules.spec(("replica",), (K,))
    k_axes = _norm_entry(k_entry[0] if len(k_entry) else None)
    axes, shard_dims = _mesh_resident_layout(mesh, flat_specs, flat_shapes,
                                             exclude=k_axes or
                                             (replica_axis,))
    if mesh_resident is None:
        mesh_resident = axes is not None
    elif mesh_resident and axes is None:
        raise ValueError("mesh-resident sync: leaf tilings do not align "
                         "with any packed super-axis")

    if mesh_resident:
        S = math.prod(mesh.shape[a] for a in axes) if axes else 1
        spec = pack_spec(params_abs, shards=S, shard_dims=shard_dims,
                         axes=axes)
        ring_abs = jax.ShapeDtypeStruct((I, spec.padded), ring_dtype)
        total_abs = jax.ShapeDtypeStruct((spec.padded,), jnp.float32)
        pax = _axes_entry(axes)
        step = shard_map(
            functools.partial(_local_packed_sync, hwa_cfg,
                              spec.local_spec(), K, k_axes,
                              hwa_cfg.use_kernels, True),
            mesh,
            in_specs=(stacked_pspecs, P(None, pax), P(pax), P(), P(), P()),
            out_specs=(stacked_pspecs, P(None, pax), P(pax), P(), P(),
                       pspec_tree, P()),
            check_rep=False)
        r_sh = _packed_sharding(mesh, spec.padded, lead_dims=1, axes=axes)
        t_sh = _packed_sharding(mesh, spec.padded, axes=axes)
        return StepBundle(
            fn=step,
            abstract_args=(stacked_abs, ring_abs, total_abs, scalar_i,
                           scalar_i, scalar_i),
            in_shardings=(p_sh, r_sh, t_sh, s_sh, s_sh, s_sh),
            out_shardings=(p_sh, r_sh, t_sh, s_sh, s_sh, w_sh, s_sh),
            donate_argnums=(0, 1, 2), pack_spec=spec)

    # ------- legacy fallback: partial-auto pmean + GSPMD-land window push
    _warn_legacy_assembly(mesh)
    auto = frozenset(a for a in mesh.axis_names if a != replica_axis)
    spec = pack_spec(params_abs)
    ring_abs = jax.ShapeDtypeStruct((I, spec.padded), ring_dtype)
    total_abs = jax.ShapeDtypeStruct((spec.padded,), jnp.float32)

    def local_mean(inner):
        """The one inter-replica collective: W̄ = pmean(W^k)."""
        return online_average_named(_squeeze0(inner), replica_axis)

    mean_fn = shard_map(
        local_mean, mesh,
        in_specs=(stacked_replica_specs(stacked_abs, replica_axis),),
        out_specs=replicated_specs(params_abs),
        check_rep=False, auto=auto)

    r_sh = _packed_sharding(mesh, spec.padded, lead_dims=1)
    t_sh = _packed_sharding(mesh, spec.padded)

    def step(inner, ring, total, count, next_idx, cycle):
        outer = mean_fn(inner)
        new_inner = broadcast_to_replicas(outer, K)
        # Packing W̄ from per-leaf (data/model)-tiled shards into the
        # contiguous buffer is a real layout redistribution: GSPMD
        # materializes the concat as masked contributions + ONE
        # param-size all-reduce spanning the whole mesh, once per sync
        # (amortized by H; absent entirely on a single device, and
        # absent from the mesh-resident path above). The constraint pins
        # the buffer to the window state's sharding so the push itself
        # stays shard-local; W̿ leaf views then slice from the
        # already-assembled buffer for free.
        buf = jax.lax.with_sharding_constraint(pack(outer, spec), t_sh)
        ws = WindowState(ring=ring, total=total, count=count,
                         next_idx=next_idx, window=I, kind="ring", spec=spec)
        # bare kernels only on a single device (Pallas is opaque to GSPMD
        # — per-shard execution with global-shape semantics corrupts
        # values); on meshes kernels require the mesh-resident path
        ws2, avg, new_cycle = window_push_packed(
            hwa_cfg, buf, ws, cycle,
            use_kernel=hwa_cfg.use_kernels and mesh.size == 1)
        wa = unpack(avg, spec)
        return (new_inner, ws2.ring, ws2.total, ws2.count, ws2.next_idx,
                wa, new_cycle)

    return StepBundle(
        fn=step,
        abstract_args=(stacked_abs, ring_abs, total_abs, scalar_i, scalar_i,
                       scalar_i),
        in_shardings=(p_sh, r_sh, t_sh, s_sh, s_sh, s_sh),
        out_shardings=(p_sh, r_sh, t_sh, s_sh, s_sh, w_sh, s_sh),
        donate_argnums=(0, 1, 2), pack_spec=spec)
