"""Step builders: plain data+tensor-parallel training/serving steps and the
HWA-stacked variants, with in/out shardings resolved from the logical-dim
trees. These are what the dry-run lowers and what real launches would run.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common.compat import shard_map
from repro.core.hwa import (HWAConfig, hwa_inner_step, hwa_local_inner_step,
                            hwa_sync)
from repro.models.registry import LM
from repro.optim import adamw, apply_updates, sgd
from repro.sharding.rules import (ShardingRules, make_tp_rules,
                                  replicated_specs, stacked_replica_specs)

PyTree = Any


def _prefix_dims(dim_tree, name):
    """Prepend a logical dim to every dims-tuple leaf (e.g. 'replica')."""
    is_dims = lambda t: isinstance(t, tuple) and all(
        isinstance(e, (str, type(None))) for e in t)
    return jax.tree.map(lambda t: (name,) + t, dim_tree, is_leaf=is_dims)


def opt_state_dims(opt_state_abs, param_dims):
    """Logical dims for optimizer state: moments mirror the params."""
    def dims_for(path_leaf):
        return param_dims
    # adamw: {"m": params-like, "v": params-like, "count": scalar}
    # sgd(momentum): {"mu": params-like}
    out = {}
    for k, v in opt_state_abs.items():
        if k == "count":
            out[k] = ()
        else:
            out[k] = param_dims
    return out


@dataclasses.dataclass
class StepBundle:
    """A step function plus its abstract args and in/out shardings.

    ``pack_spec`` is set by the WA sync bundles: their window state (and
    returned W̿) lives in the packed layout of ``repro.common.packing``;
    consumers materialize leaf views with ``packing.unpack(buf,
    bundle.pack_spec)``.
    """
    fn: Any
    abstract_args: tuple
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple = ()
    pack_spec: Any = None

    def lower(self, mesh: Mesh):
        jitted = jax.jit(self.fn, in_shardings=self.in_shardings,
                         out_shardings=self.out_shardings,
                         donate_argnums=self.donate_argnums)
        with mesh:
            return jitted.lower(*self.abstract_args)


def _mk_optimizer(name: str):
    if name == "sgd":
        return sgd(momentum=0.9, weight_decay=5e-4)
    return adamw(weight_decay=0.1)


def make_train_step(lm: LM, rules: ShardingRules, batch_specs, batch_dims,
                    optimizer: str = "adamw", lr: float = 3e-4,
                    opt_rules: ShardingRules | None = None,
                    n_microbatches: int = 1) -> StepBundle:
    """Plain data+tensor-parallel train step (the 40-combo baseline).

    ``opt_rules`` lets the optimizer moments use a different (e.g. FSDP)
    rule table than the compute params. ``n_microbatches`` > 1 enables
    gradient accumulation: peak activation temps scale ~1/n_mb while the
    f32 grad accumulator is fully sharded — the lever that fits the ≥27B
    trainings into 16 GB/chip (EXPERIMENTS.md §Perf).
    """
    opt = _mk_optimizer(optimizer)
    params_abs, param_dims = lm.abstract()
    opt_abs = jax.eval_shape(opt.init, params_abs)
    o_dims = opt_state_dims(opt_abs, param_dims)
    opt_rules = opt_rules or rules
    loss_fn = lambda p, b: lm.loss(p, b, rules=rules)

    def step(params, opt_state, batch):
        if n_microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape((n_microbatches,
                                     x.shape[0] // n_microbatches)
                                    + x.shape[1:]), batch)

            def body(acc, mbatch):
                g_acc, l_acc, a_acc = acc
                (loss, metrics), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mbatch)
                g_acc = jax.tree.map(
                    lambda a, gi: a + gi.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + metrics["loss"],
                        a_acc + metrics["acc"]), None

            zeros = jax.tree.map(
                lambda pp: jnp.zeros(pp.shape, jnp.float32), params)
            (g_sum, l_sum, a_sum), _ = jax.lax.scan(
                body, (zeros, jnp.zeros(()), jnp.zeros(())), mb)
            grads = jax.tree.map(
                lambda g, pp: (g / n_microbatches).astype(pp.dtype),
                g_sum, params)
            metrics = {"loss": l_sum / n_microbatches,
                       "aux": jnp.zeros(()),
                       "acc": a_sum / n_microbatches}
        updates, opt_state = opt.update(grads, opt_state, params, lr)
        params = apply_updates(params, updates)
        return params, opt_state, metrics

    p_sh = rules.tree_shardings(params_abs, param_dims)
    o_sh = opt_rules.tree_shardings(opt_abs, o_dims)
    b_sh = rules.tree_shardings(batch_specs, batch_dims)
    scalar_sh = NamedSharding(rules.mesh, P())
    m_sh = {"loss": scalar_sh, "aux": scalar_sh, "acc": scalar_sh}
    return StepBundle(
        fn=step, abstract_args=(params_abs, opt_abs, batch_specs),
        in_shardings=(p_sh, o_sh, b_sh),
        out_shardings=(p_sh, o_sh, m_sh),
        donate_argnums=(0, 1))


def make_prefill_step(lm: LM, rules: ShardingRules, batch_specs, batch_dims,
                      cache_abs, cache_dims) -> StepBundle:
    def step(params, cache, batch):
        return lm.prefill(params, cache, batch, rules=rules)

    params_abs, param_dims = lm.abstract()
    p_sh = rules.tree_shardings(params_abs, param_dims)
    c_sh = rules.tree_shardings(cache_abs, cache_dims)
    b_sh = rules.tree_shardings(batch_specs, batch_dims)
    logits_abs = jax.eval_shape(step, params_abs, cache_abs, batch_specs)[0]
    logits_dims = ("batch",) + (None,) * (len(logits_abs.shape) - 2) + ("vocab",)
    l_sh = rules.tree_shardings(logits_abs, logits_dims)
    return StepBundle(
        fn=step, abstract_args=(params_abs, cache_abs, batch_specs),
        in_shardings=(p_sh, c_sh, b_sh),
        out_shardings=(l_sh, c_sh),
        donate_argnums=(1,))


def make_decode_step(lm: LM, rules: ShardingRules, token_specs, token_dims,
                     cache_abs, cache_dims) -> StepBundle:
    def step(params, cache, tokens):
        return lm.decode_step(params, cache, tokens, rules=rules)

    params_abs, param_dims = lm.abstract()
    p_sh = rules.tree_shardings(params_abs, param_dims)
    c_sh = rules.tree_shardings(cache_abs, cache_dims)
    t_sh = rules.tree_shardings(token_specs, token_dims)
    logits_abs = jax.eval_shape(step, params_abs, cache_abs, token_specs)[0]
    logits_dims = ("batch",) + (None,) * (len(logits_abs.shape) - 2) + ("vocab",)
    l_sh = rules.tree_shardings(logits_abs, logits_dims)
    return StepBundle(
        fn=step, abstract_args=(params_abs, cache_abs, token_specs),
        in_shardings=(p_sh, c_sh, t_sh),
        out_shardings=(l_sh, c_sh),
        donate_argnums=(1,))


# ------------------------------------------------------------- HWA steps


def make_hwa_train_step(lm: LM, rules: ShardingRules, batch_specs, batch_dims,
                        hwa_cfg: HWAConfig, optimizer: str = "adamw",
                        lr: float = 3e-4,
                        opt_rules: ShardingRules | None = None,
                        n_microbatches: int = 1) -> StepBundle:
    """Inner HWA step: K independent replicas, stacked on the replica axis.

    Gradient all-reduce stays *inside* each replica's data shard; nothing
    crosses the replica/pod axis here — that is the H-fold comm saving.
    """
    opt = _mk_optimizer(optimizer)
    K = hwa_cfg.n_replicas
    params_abs, param_dims = lm.abstract()
    stacked_abs = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((K,) + s.shape, s.dtype), params_abs)
    stacked_dims = _prefix_dims(param_dims, "replica")
    opt_abs = jax.eval_shape(lambda p: jax.vmap(opt.init)(p), stacked_abs)
    o_dims = opt_state_dims(opt_abs, stacked_dims)
    if "count" in o_dims:          # adamw step counter, vmapped to (K,)
        o_dims["count"] = ("replica",)
    opt_rules = opt_rules or rules
    kbatch_abs = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((K,) + s.shape, s.dtype), batch_specs)
    kbatch_dims = _prefix_dims(batch_dims, "replica")

    def loss_fn(params, batch):
        return lm.loss(params, batch, rules=rules)

    def step(inner, inner_opt, batches):
        def one(params, opt_state, batch):
            if n_microbatches == 1:
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, batch)
            else:
                mb = jax.tree.map(
                    lambda x: x.reshape((n_microbatches,
                                         x.shape[0] // n_microbatches)
                                        + x.shape[1:]), batch)

                def body(acc, mbatch):
                    g_acc, l_acc = acc
                    (l, m), g = jax.value_and_grad(
                        loss_fn, has_aux=True)(params, mbatch)
                    g_acc = jax.tree.map(
                        lambda a, gi: a + gi.astype(jnp.float32), g_acc, g)
                    return (g_acc, l_acc + m["loss"]), None

                zeros = jax.tree.map(
                    lambda pp: jnp.zeros(pp.shape, jnp.float32), params)
                (g_sum, l_sum), _ = jax.lax.scan(
                    body, (zeros, jnp.zeros(())), mb)
                grads = jax.tree.map(
                    lambda g, pp: (g / n_microbatches).astype(pp.dtype),
                    g_sum, params)
                metrics = {"loss": l_sum / n_microbatches}
            updates, opt_state = opt.update(grads, opt_state, params, lr)
            return apply_updates(params, updates), opt_state, metrics["loss"]

        inner, inner_opt, losses = jax.vmap(one)(inner, inner_opt, batches)
        return inner, inner_opt, jnp.mean(losses)

    p_sh = rules.tree_shardings(stacked_abs, stacked_dims)
    o_sh = opt_rules.tree_shardings(opt_abs, o_dims)
    b_sh = rules.tree_shardings(kbatch_abs, kbatch_dims)
    scalar_sh = NamedSharding(rules.mesh, P())
    return StepBundle(
        fn=step, abstract_args=(stacked_abs, opt_abs, kbatch_abs),
        in_shardings=(p_sh, o_sh, b_sh),
        out_shardings=(p_sh, o_sh, scalar_sh),
        donate_argnums=(0, 1))


def _packed_sharding(mesh: Mesh, padded: int, lead_dims: int = 0
                     ) -> NamedSharding:
    """Sharding for a packed WA buffer: split the packed dim over the
    ``model`` axis when it divides (it always does — ``padded`` is a
    multiple of 8192), else replicate. Follow-up (ROADMAP): richer packed
    sharding over multiple mesh axes."""
    ax = "model" if ("model" in mesh.shape
                     and padded % mesh.shape["model"] == 0) else None
    return NamedSharding(mesh, P(*([None] * lead_dims + [ax])))


def make_hwa_sync_step(lm: LM, rules: ShardingRules, hwa_cfg: HWAConfig,
                       ring_dtype=jnp.float32) -> StepBundle:
    """Synchronization + window update: the once-per-H-steps collective.

    outer = mean over the replica axis (one all-reduce across pods);
    inner ← broadcast(outer); slide-window update on PACKED state: the
    ring is one (I, P) buffer and the total one (P,) buffer over the whole
    parameter set (``repro.common.packing``), held packed across the jit
    boundary so the donation of ring/total is a true in-place update
    step-to-step — no per-leaf launches, no per-call padding. Callers
    allocate the buffers from ``bundle.pack_spec``; W̿ is returned as
    leaf views sliced from the packed result.

    Variants (EXPERIMENTS.md §Perf pair 3): exact f32 ring (paper),
    bf16 ring (2× window memory saving), or hwa_cfg.window_kind ==
    "streaming" (O(1) extra copies, windowed-running-mean approximation).
    """
    from repro.common.packing import pack, pack_spec, pack_stacked, unpack
    from repro.core.offline import WindowState, window_update_packed
    from repro.core.online import broadcast_to_replicas, online_average

    K = hwa_cfg.n_replicas
    I = hwa_cfg.window
    streaming = hwa_cfg.window_kind == "streaming"
    # Pallas calls are opaque to the GSPMD partitioner: on a multi-device
    # mesh XLA runs them per-shard with global-shape semantics, silently
    # corrupting values. Kernels only on a single device; multi-device
    # meshes take the identical-math jnp path (ROADMAP follow-up: wrap
    # the kernel shard_map-manual over the packed dim).
    use_kernel = hwa_cfg.use_kernels and rules.mesh.size == 1
    params_abs, param_dims = lm.abstract()
    stacked_abs = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((K,) + s.shape, s.dtype), params_abs)
    stacked_dims = _prefix_dims(param_dims, "replica")
    spec = pack_spec(params_abs)
    ring_abs = jax.ShapeDtypeStruct((I, spec.padded), ring_dtype)
    total_abs = jax.ShapeDtypeStruct((spec.padded,), jnp.float32)
    scalar_i = jax.ShapeDtypeStruct((), jnp.int32)
    r_sh = _packed_sharding(rules.mesh, spec.padded, lead_dims=1)
    t_sh = _packed_sharding(rules.mesh, spec.padded)

    def mean_and_buf(inner):
        """(W̄ leaf views, packed W̄) without a pack/unpack round-trip.

        The sharding constraint pins the packed buffer to the window
        state's own sharding so the elementwise push stays shard-local
        (GSPMD otherwise computes it as distributed partial sums + a
        full-buffer all-reduce crossing every mesh axis).
        """
        if use_kernel:
            from repro.kernels import ops as kops
            buf = kops.online_mean_packed(pack_stacked(inner, spec))
            outer = unpack(buf, spec)
        else:
            outer = online_average(inner)
            buf = pack(outer, spec)
        return outer, jax.lax.with_sharding_constraint(buf, t_sh)

    def step_ring(inner, ring, total, count, next_idx):
        outer, buf = mean_and_buf(inner)
        new_inner = broadcast_to_replicas(outer, K)
        ws = WindowState(ring=ring, total=total, count=count,
                         next_idx=next_idx, window=I, kind="ring", spec=spec)
        ws2, avg = window_update_packed(ws, buf, use_kernel=use_kernel)
        wa = unpack(avg, spec)      # leaf views of W̿ (slices, no copy)
        return new_inner, ws2.ring, ws2.total, ws2.count, ws2.next_idx, wa

    def step_streaming(inner, total, count):
        outer, buf = mean_and_buf(inner)
        new_inner = broadcast_to_replicas(outer, K)
        ws = WindowState(ring=None, total=total, count=count,
                         next_idx=jnp.zeros((), jnp.int32), window=I,
                         kind="streaming", spec=spec)
        ws2, avg = window_update_packed(ws, buf)
        return new_inner, ws2.total, ws2.count, unpack(avg, spec)

    p_sh = rules.tree_shardings(stacked_abs, stacked_dims)
    w_sh = rules.tree_shardings(params_abs, param_dims)
    s_sh = NamedSharding(rules.mesh, P())
    if streaming:
        return StepBundle(
            fn=step_streaming,
            abstract_args=(stacked_abs, total_abs, scalar_i),
            in_shardings=(p_sh, t_sh, s_sh),
            out_shardings=(p_sh, t_sh, s_sh, w_sh),
            donate_argnums=(0, 1), pack_spec=spec)
    return StepBundle(
        fn=step_ring,
        abstract_args=(stacked_abs, ring_abs, total_abs, scalar_i, scalar_i),
        in_shardings=(p_sh, r_sh, t_sh, s_sh, s_sh),
        out_shardings=(p_sh, r_sh, t_sh, s_sh, s_sh, w_sh),
        donate_argnums=(0, 1, 2), pack_spec=spec)


# ----------------------------------------------- mesh-native HWA (shard_map)
#
# Same storage layout as the vmap path — stacked (K, ...) state with the
# leading dim sharded over the ``replica`` mesh axis — but the step runs
# under shard_map *manual* over replica (data/model stay auto/GSPMD):
# each replica block squeezes its (1, ...) slice and steps locally, so the
# lowered inner-step HLO provably contains no collective crossing the
# replica axis, and hwa_sync is one jax.lax.pmean over it. That makes the
# paper's H-fold inter-replica communication amortization a structural
# property of the program rather than a GSPMD-propagation accident.


def _squeeze0(tree):
    return jax.tree.map(lambda x: x[0], tree)


def _expand0(tree):
    return jax.tree.map(lambda x: x[None], tree)


def make_mesh_hwa_train_step(lm: LM, rules: ShardingRules, batch_specs,
                             batch_dims, hwa_cfg: HWAConfig,
                             optimizer: str = "adamw", lr: float = 3e-4,
                             opt_rules: ShardingRules | None = None,
                             replica_axis: str = "replica") -> StepBundle:
    """Mesh-native inner HWA step.

    Collective-free over ``replica_axis`` by construction (shard_map keeps
    the replica blocks independent; the only collectives GSPMD may insert
    live inside a block, over the data/model axes). Returns per-replica
    losses as a (K,) array sharded over the replica axis — averaging them
    to a replicated scalar would itself be a replica collective, so the
    caller takes the mean after fetching.
    """
    opt = _mk_optimizer(optimizer)
    K = hwa_cfg.n_replicas
    mesh = rules.mesh
    assert replica_axis in mesh.shape, (replica_axis, mesh.shape)
    assert K == mesh.shape[replica_axis], \
        f"mesh-native path needs K == mesh axis size ({K} != " \
        f"{mesh.shape[replica_axis]}); use the vmap path otherwise"
    auto = frozenset(a for a in mesh.axis_names if a != replica_axis)
    if not lm.cfg.scan_unroll:
        # XLA (0.4.x) fatals on a while loop under manual-subgroup
        # shardings; unrolling the layer scan keeps the body loop-free.
        from repro.models.registry import build_model
        lm = build_model(lm.cfg.with_(scan_unroll=True))
    params_abs, param_dims = lm.abstract()
    stacked_abs = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((K,) + s.shape, s.dtype), params_abs)
    stacked_dims = _prefix_dims(param_dims, "replica")
    opt_abs = jax.eval_shape(lambda p: jax.vmap(opt.init)(p), stacked_abs)
    o_dims = opt_state_dims(opt_abs, stacked_dims)
    if "count" in o_dims:
        o_dims["count"] = ("replica",)
    opt_rules = opt_rules or rules
    kbatch_abs = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((K,) + s.shape, s.dtype), batch_specs)
    kbatch_dims = _prefix_dims(batch_dims, "replica")

    # The body runs the model's pure-jnp path (rules=None): the rules-aware
    # path opens nested shard_maps (vocab-sharded gather, EP MoE) which 0.4.x
    # cannot nest inside a partial-auto map. Layouts over the auto axes are
    # still driven by the jit in/out shardings; constraints are hints only,
    # so the math is unchanged.
    def loss_fn(params, batch):
        return lm.loss(params, batch, rules=None)

    def local_step(inner, inner_opt, batch):
        params, opt_state, loss, _ = hwa_local_inner_step(
            _squeeze0(inner), _squeeze0(inner_opt), _squeeze0(batch),
            loss_fn, opt, lr)
        return _expand0(params), _expand0(opt_state), loss[None]

    step = shard_map(
        local_step, mesh,
        in_specs=(stacked_replica_specs(stacked_abs, replica_axis),
                  stacked_replica_specs(opt_abs, replica_axis),
                  stacked_replica_specs(kbatch_abs, replica_axis)),
        out_specs=(stacked_replica_specs(stacked_abs, replica_axis),
                   stacked_replica_specs(opt_abs, replica_axis),
                   P(replica_axis)),
        check_rep=False, auto=auto)

    p_sh = rules.tree_shardings(stacked_abs, stacked_dims)
    o_sh = opt_rules.tree_shardings(opt_abs, o_dims)
    b_sh = rules.tree_shardings(kbatch_abs, kbatch_dims)
    losses_sh = NamedSharding(mesh, P(replica_axis))
    return StepBundle(
        fn=step, abstract_args=(stacked_abs, opt_abs, kbatch_abs),
        in_shardings=(p_sh, o_sh, b_sh),
        out_shardings=(p_sh, o_sh, losses_sh),
        donate_argnums=(0, 1))


def make_mesh_hwa_sync_step(lm: LM, rules: ShardingRules, hwa_cfg: HWAConfig,
                            ring_dtype=jnp.float32,
                            replica_axis: str = "replica") -> StepBundle:
    """Mesh-native synchronization: the once-per-H-steps collective.

    Inside the shard_map body each replica pmeans its weights over the
    replica axis — the *only* inter-replica collective of the whole HWA
    cycle. The slide-window update then runs OUTSIDE the manual region, in
    plain GSPMD-land of the same jit, on PACKED state ((I, P) ring + (P,)
    total over the whole parameter set) that stays packed across the jit
    boundary. Two reasons for the split: the window input W̄ is
    replica-invariant after the pmean, so the update carries zero
    replica-axis traffic by construction; and XLA 0.4.x's partial-auto
    manual subgroups miscompile the packed-buffer assembly (a gather
    across auto-sharded leaves) when it happens inside the shard_map —
    a spurious replica-axis reduction doubles the values (same
    IsManualSubgroup fragility as the scan_unroll workaround).
    """
    from repro.common.packing import pack, pack_spec, unpack
    from repro.core.hwa import window_push_packed
    from repro.core.offline import WindowState
    from repro.core.online import broadcast_to_replicas, online_average_named

    K = hwa_cfg.n_replicas
    I = hwa_cfg.window
    mesh = rules.mesh
    assert replica_axis in mesh.shape and K == mesh.shape[replica_axis], \
        (K, mesh.shape)
    auto = frozenset(a for a in mesh.axis_names if a != replica_axis)
    params_abs, param_dims = lm.abstract()
    stacked_abs = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((K,) + s.shape, s.dtype), params_abs)
    stacked_dims = _prefix_dims(param_dims, "replica")
    spec = pack_spec(params_abs)
    ring_abs = jax.ShapeDtypeStruct((I, spec.padded), ring_dtype)
    total_abs = jax.ShapeDtypeStruct((spec.padded,), jnp.float32)
    scalar_i = jax.ShapeDtypeStruct((), jnp.int32)

    def local_mean(inner):
        """The one inter-replica collective: W̄ = pmean(W^k)."""
        return online_average_named(_squeeze0(inner), replica_axis)

    mean_fn = shard_map(
        local_mean, mesh,
        in_specs=(stacked_replica_specs(stacked_abs, replica_axis),),
        out_specs=replicated_specs(params_abs),
        check_rep=False, auto=auto)

    r_sh = _packed_sharding(mesh, spec.padded, lead_dims=1)
    t_sh = _packed_sharding(mesh, spec.padded)

    def step(inner, ring, total, count, next_idx, cycle):
        outer = mean_fn(inner)
        new_inner = broadcast_to_replicas(outer, K)
        # Packing W̄ from per-leaf (data/model)-tiled shards into the
        # contiguous buffer is a real layout redistribution: GSPMD
        # materializes the concat as masked contributions + ONE
        # param-size all-reduce spanning the whole mesh, once per sync
        # (amortized by H; absent entirely on a single device). The
        # constraint pins the buffer to the window state's sharding so
        # the push itself stays shard-local; W̿ leaf views then slice
        # from the already-assembled buffer for free. Follow-up in
        # ROADMAP: align leaf tilings with packed ranges to make the
        # assembly collective-free.
        buf = jax.lax.with_sharding_constraint(pack(outer, spec), t_sh)
        ws = WindowState(ring=ring, total=total, count=count,
                         next_idx=next_idx, window=I, kind="ring", spec=spec)
        # kernels only on a single device (Pallas is opaque to GSPMD —
        # per-shard execution with global-shape semantics corrupts values)
        ws2, avg, new_cycle = window_push_packed(
            hwa_cfg, buf, ws, cycle,
            use_kernel=hwa_cfg.use_kernels and mesh.size == 1)
        wa = unpack(avg, spec)
        return (new_inner, ws2.ring, ws2.total, ws2.count, ws2.next_idx,
                wa, new_cycle)

    p_sh = rules.tree_shardings(stacked_abs, stacked_dims)
    w_sh = rules.tree_shardings(params_abs, param_dims)
    s_sh = NamedSharding(mesh, P())
    return StepBundle(
        fn=step,
        abstract_args=(stacked_abs, ring_abs, total_abs, scalar_i, scalar_i,
                       scalar_i),
        in_shardings=(p_sh, r_sh, t_sh, s_sh, s_sh, s_sh),
        out_shardings=(p_sh, r_sh, t_sh, s_sh, s_sh, w_sh, s_sh),
        donate_argnums=(0, 1, 2), pack_spec=spec)
