"""Step builders — thin re-exporting facade over ``repro.launch.sync``.

The 868-line monolith this module used to be was carved into the
``launch/sync/`` subsystem in PR 4:

- ``launch.sync.topology`` — the :class:`SyncTopology` abstraction:
  ``Flat(axis)`` (one global all-reduce per sync, the historical
  behavior) and ``TwoLevel(inner_axis, outer_axis, outer_every)`` (the
  paper's namesake hierarchy: pod-internal averaging every H steps, the
  cross-pod all-reduce + window push only every H·H₂).
- ``launch.sync.packed`` — the mesh-resident packed sync machinery:
  ``_mesh_resident_layout`` (shard-aware layout chooser),
  ``_local_packed_sync`` / ``_local_inner_sync`` (the fully-manual
  per-device bodies), ``_packed_sharding``.
- ``launch.sync.legacy`` — the legacy GSPMD fallback for non-qualifying
  layouts (e.g. FSDP), now a HARD ERROR on multi-device CPU meshes where
  XLA 0.4.37 miscompiles the packed-W̄ assembly
  (``REPRO_ALLOW_LEGACY_ASSEMBLY=1`` downgrades it to the old warning).
- ``launch.sync.bundles`` — the StepBundle builders themselves.

Every name importable from here before the split still is; new code
should import from ``repro.launch.sync`` directly.
"""
from __future__ import annotations

# The bundle builders and their public dataclasses.
from repro.launch.sync.bundles import (StepBundle, _expand0, _mk_optimizer,
                                       _prefix_dims, _squeeze0,
                                       make_decode_step, make_hwa_sync_step,
                                       make_hwa_train_step,
                                       make_mesh_hwa_inner_sync_step,
                                       make_mesh_hwa_sync_step,
                                       make_mesh_hwa_train_step,
                                       make_prefill_step, make_train_step,
                                       opt_state_dims)
# Sync topologies (new in PR 4).
from repro.launch.sync.topology import Flat, SyncTopology, TwoLevel
# Declarative bundle construction (PR 10) — the ONE public constructor;
# the make_*hwa*_step names above are deprecated wrappers around it.
from repro.launch.sync.plan import (HWABundles, SyncPlan, build_hwa_bundles,
                                    window_state_args)
# Mesh-resident packed machinery (private names kept importable — the
# ROADMAP/ARCHITECTURE docs and downstream experiments reference them).
from repro.launch.sync.packed import (_axes_entry, _grouped_resident_layout,
                                      _local_inner_sync,
                                      _local_packed_sync,
                                      _mesh_resident_layout, _norm_entry,
                                      _packed_sharding,
                                      choose_resident_spec)
# Legacy GSPMD fallback; ``check_legacy_assembly`` is the promoted hard
# error (the old ``_warn_legacy_assembly`` name stays as an alias).
from repro.launch.sync.legacy import (check_legacy_assembly,
                                      make_legacy_mesh_sync_step,
                                      make_legacy_sync_step)
# Names the monolith used to expose at module scope via its own imports;
# kept so pre-split `from repro.launch.steps import X` code still works.
from repro.core.hwa import (HWAConfig, hwa_inner_step, hwa_local_inner_step,
                            hwa_sync)
from repro.sharding.rules import (ShardingRules, make_tp_rules,
                                  replicated_specs, stacked_replica_specs)

_warn_legacy_assembly = check_legacy_assembly

__all__ = [
    "Flat", "HWABundles", "HWAConfig", "ShardingRules", "StepBundle",
    "SyncPlan", "SyncTopology", "TwoLevel", "build_hwa_bundles",
    "window_state_args",
    "check_legacy_assembly", "hwa_inner_step",
    "hwa_local_inner_step", "hwa_sync", "make_decode_step",
    "make_hwa_sync_step", "make_hwa_train_step",
    "make_legacy_mesh_sync_step", "make_legacy_sync_step",
    "make_mesh_hwa_inner_sync_step", "make_mesh_hwa_sync_step",
    "make_mesh_hwa_train_step", "make_prefill_step", "make_train_step",
    "make_tp_rules", "opt_state_dims", "replicated_specs",
    "stacked_replica_specs",
]
