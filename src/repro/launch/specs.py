"""ShapeDtypeStruct input stand-ins for every (arch × input-shape) combo.

``input_specs`` returns (abstract batch, logical dims) — weak-type-correct,
shardable, zero allocation. Decode shapes also need the cache:
``cache_specs``. VLM/audio modality frontends are stubs per the assignment:
the specs provide precomputed patch/frame embeddings / codec token streams
of the right shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.registry import LM
from repro.models.types import InputShape, ModelConfig


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: InputShape):
    """Training/prefill batch specs. Returns (specs dict, dims dict)."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "vlm":
        s_text = S - cfg.n_vis_tokens
        specs = {"tokens": _sds((B, s_text), jnp.int32),
                 "targets": _sds((B, s_text), jnp.int32),
                 "vis_embeds": _sds((B, cfg.n_vis_tokens, cfg.d_vis),
                                    jnp.bfloat16)}
        dims = {"tokens": ("batch", None), "targets": ("batch", None),
                "vis_embeds": ("batch", None, None)}
    elif cfg.family == "audio":
        specs = {"tokens": _sds((B, S, cfg.n_codebooks), jnp.int32),
                 "targets": _sds((B, S, cfg.n_codebooks), jnp.int32)}
        dims = {"tokens": ("batch", None, None),
                "targets": ("batch", None, None)}
    else:
        specs = {"tokens": _sds((B, S), jnp.int32),
                 "targets": _sds((B, S), jnp.int32)}
        dims = {"tokens": ("batch", None), "targets": ("batch", None)}
    if shape.kind != "train":
        specs.pop("targets")
        dims.pop("targets")
    return specs, dims


def decode_token_specs(cfg: ModelConfig, shape: InputShape):
    B = shape.global_batch
    if cfg.family == "audio":
        return _sds((B, cfg.n_codebooks), jnp.int32), ("batch", None)
    return _sds((B,), jnp.int32), ("batch",)


def cache_specs(lm: LM, shape: InputShape):
    """Abstract KV/state cache for decode shapes (no allocation)."""
    return lm.cache_abstract(shape.global_batch, shape.seq_len)


def adapt_config_for_shape(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Per-shape config adaptation (DESIGN.md §4).

    long_500k requires sub-quadratic attention: SSM/hybrid archs are
    native; full-attention archs run the documented sliding-window-4096
    variant (the assignment's dense carve-out). Training uses the banded
    flash path; smoke/naive stay as configured.
    """
    cfg = cfg.with_(attn_impl="flash_jnp") if cfg.attn_impl == "naive" else cfg
    if shape.name == "long_500k":
        if cfg.family not in ("ssm", "hybrid") and cfg.sliding_window is None:
            cfg = cfg.with_(sliding_window=4096, global_every=0)
        if cfg.global_every:
            # gemma2: local layers native SW; global layers fall back to a
            # 32k window at 500k decode (documented deviation).
            cfg = cfg.with_(sliding_window=cfg.sliding_window)
    return cfg
