"""Sync topologies: WHICH mesh axes the replica mean crosses, and WHEN.

The paper's namesake hierarchy (and SWAP's / Ajroldi et al.'s when-where
analysis) is about *where* the once-per-H-steps weight average reduces:

- :class:`Flat` — every sync is one global all-reduce over the whole
  replica axis set (one psum; the PR-1..3 behavior).
- :class:`TwoLevel` — replicas are carved into pods (the ``outer_axis``)
  of ``inner_axis``-many members each. Every H steps each pod pmeans over
  its OWN members only (explicit per-pod ``replica_groups``, no cross-pod
  traffic); only every H·``outer_every`` steps does the outer cross-pod
  all-reduce + slide-window push run. Cross-pod bytes per step drop by
  another ``outer_every``× on top of the paper's H× (measured:
  ``make bench-sync`` → BENCH_kernels.json ``sync/tree``).

A topology is pure structure: it owns no tensors and never touches
devices. The sync bundles (``launch.sync.bundles``) consume it through
the small API below; ``sync_collective_audit`` (``launch.hlo``) checks
the lowered HLO against the same structure per level.

**Bit-parity contract.** The two-level OUTER mean is the composition
``psum(psum(w·1/K, inner), outer)`` over CONTIGUOUS pods. With
power-of-two pod/member counts this performs exactly the additions of
the canonical contiguous-pairing halving tree
(``core.online.halving_sum_axis0``), so it is bit-identical — 0 ULP —
to the flat path's local-sum + psum and to the host reference
``core.online.online_average_grouped`` (asserted in
tests/mesh_hwa_check.py and, property-based, in
tests/test_sync_topology.py).
"""
from __future__ import annotations

import dataclasses
import math


def _norm_axes(axis) -> tuple[str, ...]:
    """An axis argument (None | str | sequence of str) as a tuple."""
    if axis is None:
        return ()
    return (axis,) if isinstance(axis, str) else tuple(axis)


@dataclasses.dataclass(frozen=True)
class Flat:
    """Single-level sync: one global all-reduce over ``axis`` per sync.

    ``axis`` may name several mesh axes jointly (e.g. ``("pod",
    "replica")`` to run FLAT sync on a pod-carved mesh — the baseline
    ``benchmarks/sync_tree.py`` compares the tree against).
    """
    axis: str | tuple[str, ...] = "replica"

    @property
    def replica_axes(self) -> tuple[str, ...]:
        """Mesh axes the stacked K dim is sharded over."""
        return _norm_axes(self.axis)

    @property
    def levels(self) -> int:
        return 1

    def n_replicas(self, mesh) -> int:
        return math.prod(mesh.shape[a] for a in self.replica_axes)

    def psum_groups(self) -> tuple[tuple[str, ...], ...]:
        """Axis groups the sync psums over, in order (here: one joint)."""
        return (self.replica_axes,)

    def is_outer(self, sync_idx) -> bool:
        """Every flat sync is global (window push + full all-reduce)."""
        return True

    def validate(self, mesh, n_replicas: int) -> None:
        missing = [a for a in self.replica_axes if a not in mesh.shape]
        if missing:
            raise ValueError(f"Flat sync axes {missing} not in mesh "
                             f"{dict(mesh.shape)}")
        if n_replicas != self.n_replicas(mesh):
            raise ValueError(
                f"mesh-native flat sync needs K == replica-axis size "
                f"({n_replicas} != {self.n_replicas(mesh)} over "
                f"{self.replica_axes})")


@dataclasses.dataclass(frozen=True)
class TwoLevel:
    """Two-level (pod-inner / pod-outer) sync tree for K > 8.

    ``inner_axis`` shards a pod's members, ``outer_axis`` the pods; the
    stacked K dim is sharded over ``(outer_axis, inner_axis)`` jointly so
    pods are CONTIGUOUS replica blocks (load-bearing for the 0-ULP
    composition — see module docstring). ``outer_every`` is H₂: sync
    index s (0-based) runs the outer level iff ``(s + 1) % outer_every
    == 0``; all other syncs are pod-internal restarts with zero cross-pod
    traffic.
    """
    inner_axis: str = "replica"
    outer_axis: str = "pod"
    outer_every: int = 1

    @property
    def replica_axes(self) -> tuple[str, ...]:
        # outer first: pod-major sharding keeps pods contiguous in K.
        return (self.outer_axis, self.inner_axis)

    @property
    def levels(self) -> int:
        return 2

    def n_replicas(self, mesh) -> int:
        return math.prod(mesh.shape[a] for a in self.replica_axes)

    def pods(self, mesh) -> int:
        return mesh.shape[self.outer_axis]

    def pod_size(self, mesh) -> int:
        """Replicas per pod (inner-axis extent)."""
        return mesh.shape[self.inner_axis]

    def psum_groups(self) -> tuple[tuple[str, ...], ...]:
        """The grouped psum composition: inner (per-pod) first, then the
        outer cross-pod all-reduce."""
        return ((self.inner_axis,), (self.outer_axis,))

    def inner_groups(self) -> tuple[tuple[str, ...], ...]:
        """The inner-only sync's reduction: one per-pod psum."""
        return ((self.inner_axis,),)

    def is_outer(self, sync_idx) -> bool:
        """True iff 0-based sync ``sync_idx`` runs the outer level (the
        H₂-th, 2·H₂-th, ... syncs). Works on ints and traced int32."""
        if self.outer_every <= 1:
            return True
        return (sync_idx + 1) % self.outer_every == 0

    def validate(self, mesh, n_replicas: int) -> None:
        if self.inner_axis == self.outer_axis:
            raise ValueError("TwoLevel inner and outer axes must differ, "
                             f"both are {self.inner_axis!r}")
        missing = [a for a in self.replica_axes if a not in mesh.shape]
        if missing:
            raise ValueError(f"TwoLevel sync axes {missing} not in mesh "
                             f"{dict(mesh.shape)}")
        if self.outer_every < 1:
            raise ValueError(f"outer_every must be >= 1, got "
                             f"{self.outer_every}")
        if n_replicas != self.n_replicas(mesh):
            raise ValueError(
                f"two-level sync needs K == pods × pod_size "
                f"({n_replicas} != {self.pods(mesh)} × "
                f"{self.pod_size(mesh)})")


SyncTopology = Flat | TwoLevel
