"""Declarative HWA bundle construction: ONE entry point over the
topology × precision × resilience × kernel matrix.

PR 4 split the step-builder monolith; PR 10 collapses its five public
``make_*hwa*_step`` builders behind a single declarative surface. A
:class:`SyncPlan` names every orthogonal choice a launch makes —

- **topology**: :class:`~repro.launch.sync.topology.Flat` (one global
  all-reduce) or :class:`~repro.launch.sync.topology.TwoLevel` (per-pod
  psum + cross-pod all-reduce every H₂-th sync);
- **precision**: ``wa_dtype`` compresses the WA ring storage (bf16, or
  block-scaled fp8 with per-segment scales; f32 total with Kahan
  compensation), ``comms_dtype`` the tree's cross-pod payload;
- **resilience**: ``HWAConfig.resilient`` (alive-masked mean);
- **kernels**: ``HWAConfig.use_kernels`` (fused Pallas vs jnp reference);
- **placement**: ``mesh_native`` (shard_map replica blocks) vs the
  stacked vmap path, ``mesh_resident`` forcing/forbidding the packed
  in-map window state —

and :func:`build_hwa_bundles` validates the combination ONCE and
assembles the matching :class:`HWABundles` (train / sync / inner-sync
StepBundles). Invalid corners (compressed comms on a Flat topology,
resilient + compressed comms, two-level on the vmap path) fail here with
one error message instead of deep inside a builder.

The historical builder names survive as deprecated wrappers in
``launch.sync.bundles``; new code should not call them.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.hwa import HWAConfig
from repro.launch.sync.topology import Flat, SyncTopology, TwoLevel
from repro.models.registry import LM
from repro.sharding.rules import ShardingRules

#: the SyncPlan-level precision tokens (see repro.common.quant)
PRECISIONS = ("f32", "bf16", "fp8")


@dataclasses.dataclass(frozen=True)
class SyncPlan:
    """Everything a launch decides about HWA synchronization, as data.

    ``wa_dtype``/``comms_dtype`` take precision tokens (``"f32"`` |
    ``"bf16"`` | ``"fp8"``); the f32 defaults keep every path
    bit-identical to the uncompressed bundles (0 ULP — the repo-wide
    guarantee). ``topology=None`` means flat sync over
    ``replica_axis``. ``mesh_native=False`` selects the stacked vmap
    path (several replicas resident per device allowed; flat only).
    ``mesh_resident`` is the packed-window-state override threaded to
    the builders (None = automatic).
    """
    hwa: HWAConfig
    topology: SyncTopology | None = None
    replica_axis: str = "replica"
    wa_dtype: str = "f32"
    comms_dtype: str = "f32"
    mesh_native: bool = True
    mesh_resident: bool | None = None
    optimizer: str = "adamw"
    lr: float = 3e-4
    n_microbatches: int = 1

    def __post_init__(self):
        from repro.common.quant import wa_token
        object.__setattr__(self, "wa_dtype", wa_token(self.wa_dtype))
        object.__setattr__(self, "comms_dtype", wa_token(self.comms_dtype))
        if self.comms_dtype != "f32":
            if not isinstance(self.topology, TwoLevel):
                raise ValueError(
                    "comms_dtype compresses the two-level tree's "
                    "cross-pod hop; a flat sync has no outer level to "
                    f"compress (got comms_dtype={self.comms_dtype!r} "
                    f"with topology {self.topology!r})")
            if self.hwa.resilient:
                raise ValueError(
                    "resilient + compressed comms is unsupported (the "
                    "alive-masked mean renormalizes after the psum)")
        if isinstance(self.topology, TwoLevel) and not self.mesh_native:
            raise ValueError(
                "the two-level sync tree is mesh-native only (the "
                "stacked vmap path has no grouped psum composition)")

    @property
    def resolved_topology(self) -> SyncTopology:
        return (self.topology if self.topology is not None
                else Flat(self.replica_axis))

    @property
    def is_tree(self) -> bool:
        return isinstance(self.topology, TwoLevel)


@dataclasses.dataclass(frozen=True)
class HWABundles:
    """The StepBundles a :class:`SyncPlan` assembles.

    ``train`` is None when :func:`build_hwa_bundles` was called without
    batch specs (sync-only callers: lint, benchmarks, checkpoints).
    ``inner_sync`` exists only for a TwoLevel topology — it runs the
    pod-internal restart on the non-outer syncs
    (``plan.resolved_topology.is_outer`` schedules which is which).
    """
    plan: SyncPlan
    sync: Any
    train: Any = None
    inner_sync: Any = None

    @property
    def pack_spec(self):
        """The packed window-state layout callers MUST allocate from."""
        return self.sync.pack_spec


def build_hwa_bundles(lm: LM, rules: ShardingRules, plan: SyncPlan,
                      batch_specs=None, batch_dims=None) -> HWABundles:
    """Assemble the train / sync / inner-sync bundles a plan describes.

    The ONE public constructor of HWA StepBundles: validates the plan's
    combination against the mesh once, then delegates to the private
    builders in ``launch.sync.bundles``. ``batch_specs``/``batch_dims``
    are required only when the caller wants the inner train step
    (sync-only consumers — lint, benchmarks, checkpoint migration —
    omit them and get ``train=None``).
    """
    from repro.launch.sync.bundles import (_make_hwa_sync_step,
                                           _make_hwa_train_step,
                                           _make_mesh_hwa_inner_sync_step,
                                           _make_mesh_hwa_sync_step,
                                           _make_mesh_hwa_train_step)
    topology = plan.resolved_topology
    want_train = batch_specs is not None
    if (batch_specs is None) != (batch_dims is None):
        raise ValueError("pass batch_specs and batch_dims together "
                         "(or neither, for sync-only bundles)")
    if plan.mesh_native:
        rep_axes = topology.replica_axes
        train = (_make_mesh_hwa_train_step(
            lm, rules, batch_specs, batch_dims, plan.hwa,
            optimizer=plan.optimizer, lr=plan.lr,
            replica_axis=rep_axes if len(rep_axes) > 1 else rep_axes[0])
            if want_train else None)
        sync = _make_mesh_hwa_sync_step(
            lm, rules, plan.hwa, ring_dtype=plan.wa_dtype,
            replica_axis=plan.replica_axis,
            mesh_resident=plan.mesh_resident,
            topology=plan.topology, comms_dtype=plan.comms_dtype)
        inner_sync = (_make_mesh_hwa_inner_sync_step(
            lm, rules, plan.hwa, topology) if plan.is_tree else None)
        return HWABundles(plan=plan, sync=sync, train=train,
                          inner_sync=inner_sync)
    train = (_make_hwa_train_step(
        lm, rules, batch_specs, batch_dims, plan.hwa,
        optimizer=plan.optimizer, lr=plan.lr,
        n_microbatches=plan.n_microbatches) if want_train else None)
    sync = _make_hwa_sync_step(lm, rules, plan.hwa,
                               ring_dtype=plan.wa_dtype,
                               mesh_resident=plan.mesh_resident)
    return HWABundles(plan=plan, sync=sync, train=train)


def window_state_args(bundles_or_sync, fill=jnp.zeros):
    """Freshly-initialized window-state arguments of a sync bundle, in
    the bundle's own argument order: ``(ring, [scales], total, [comp],
    count, next_idx[, cycle])`` — everything AFTER the stacked inner
    params. Zeroed buffers, except the fp8 ring's per-block scales,
    which start at ONES (the scale of an all-zero block). Works for
    single-range and grouped (per-group tuple) layouts alike because it
    allocates from the bundle's abstract args — the shape contract's one
    source of truth.
    """
    from repro.common.quant import needs_scales
    sync = getattr(bundles_or_sync, "sync", bundles_or_sync)
    spec = sync.pack_spec
    scales_idx = (1 if spec is not None and needs_scales(spec.ring_dtype)
                  else None)
    out = []
    for i, a in enumerate(sync.abstract_args[1:]):
        mk = jnp.ones if i == scales_idx else fill
        out.append(jax.tree.map(lambda s: mk(s.shape, s.dtype), a))
    return tuple(out)
