"""Mesh-resident packed sync: the shard-aware layout chooser and the
per-device bodies that run under a FULLY-MANUAL shard_map.

Moved out of the ``launch/steps.py`` monolith (PR 4). Everything here is
mesh-mechanics: which packed super-axis the window buffers shard over
(:func:`_mesh_resident_layout`), how they are sharded
(:func:`_packed_sharding`), and the local sync bodies
(:func:`_local_packed_sync` for full syncs — flat OR the two-level outer
composition — and :func:`_local_inner_sync` for the tree's pod-internal
restarts). The StepBundle assembly lives in ``launch.sync.bundles``; the
GSPMD fallback in ``launch.sync.legacy``.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.hwa import HWAConfig


def _norm_entry(entry) -> tuple[str, ...]:
    """A PartitionSpec entry as a tuple of mesh-axis names."""
    if entry is None:
        return ()
    return tuple(entry) if isinstance(entry, (tuple, list)) else (entry,)


def _axes_entry(axes: tuple[str, ...]):
    """A packed super-axis as a PartitionSpec entry (None/str/tuple)."""
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else tuple(axes)


def _packed_sharding(mesh: Mesh, padded: int, lead_dims: int = 0,
                     axes: tuple[str, ...] | None = None) -> NamedSharding:
    """Sharding for a packed WA buffer.

    ``axes`` is the packed super-axis of a shard-aware ``PackSpec``
    (``spec.axes``) — the packed dim is split over exactly those mesh
    axes, jointly. ``axes=None`` keeps the legacy heuristic used by the
    non-mesh-resident fallback: split over ``model`` when it divides
    (it always does — ``padded`` is an ALIGN multiple), else replicate.
    """
    if axes is None:
        ax = "model" if ("model" in mesh.shape
                         and padded % mesh.shape["model"] == 0) else None
    else:
        ax = _axes_entry(axes)
    return NamedSharding(mesh, P(*([None] * lead_dims + [ax])))


def _packed_pspecs(spec, lead_dims: int = 0):
    """shard_map PartitionSpec(s) for window buffers under ``spec``: one
    bare spec for single-range layouts, a per-group tuple for grouped
    ones (each group's buffer dim splits over its OWN super-axis)."""
    if not spec.is_grouped:
        return P(*([None] * lead_dims + [_axes_entry(spec.axes)]))
    return tuple(P(*([None] * lead_dims + [_axes_entry(g.axes)]))
                 for g in spec.group_table())


def _packed_shardings(mesh: Mesh, spec, lead_dims: int = 0):
    """NamedSharding(s) matching :func:`_packed_pspecs`."""
    if not spec.is_grouped:
        return _packed_sharding(mesh, spec.padded, lead_dims,
                                axes=spec.axes)
    return tuple(NamedSharding(mesh, p)
                 for p in _packed_pspecs(spec, lead_dims))


def _mesh_resident_layout(mesh: Mesh, flat_specs, flat_shapes,
                          exclude: tuple[str, ...] = ()):
    """Choose a packed super-axis aligning leaf tilings with packed ranges.

    Returns ``(axes, shard_dims)`` such that ``pack_spec(params,
    shards=prod(axes), shard_dims=..., axes=axes)`` makes packed-W̄
    assembly and W̿ unpacking shard-local (zero collectives): every leaf
    either has exactly ONE dim sharded over exactly ``axes`` (jointly, in
    order) — that dim becomes its ``shard_dim`` — or is replicated over
    the non-``exclude`` mesh axes and gets duplicated per segment.

    Candidates are the distinct PartitionSpec entries the leaves actually
    use (arbitrary mesh-axis sets, not just the single ``model`` axis),
    tried largest-device-count first; ``((), all-None)`` is returned for
    fully-replicated trees, and ``(None, None)`` when no super-axis covers
    every leaf (e.g. FSDP's mixed data/model tilings) — callers then fall
    back to the legacy redistribute-and-all-reduce assembly.
    """
    # zero-size leaves break the SHARDED segment-major layout (their
    # 0-element pieces make `pack_spec` reject sharded placements, and a
    # per-segment duplicate is meaningless) — the guard must apply to
    # every leaf of a shards>1 candidate, REPLICATED leaves included,
    # not just inside the sharded branch below (the historical bug: a
    # zero-size replicated leaf slipped through). The degenerate
    # shards==1 fallback is plain contiguous packing, which supports
    # empty leaves fine, so it stays available.
    has_zero = any(not all(d > 0 for d in shape) for shape in flat_shapes)
    cands: list[tuple[str, ...]] = []
    for sp in flat_specs:
        for e in sp:
            t = _norm_entry(e)
            if (t and not (set(t) & set(exclude)) and t not in cands
                    and math.prod(mesh.shape[a] for a in t) > 1):
                cands.append(t)
    cands.sort(key=lambda t: -math.prod(mesh.shape[a] for a in t))
    cands.append(())
    for cand in cands:
        S = math.prod(mesh.shape[a] for a in cand) if cand else 1
        if S > 1 and has_zero:
            continue
        dims: list[int | None] = []
        ok = True
        for sp, shape in zip(flat_specs, flat_shapes):
            hot = []
            for i, e in enumerate(sp):
                t = _norm_entry(e)
                if not t or math.prod(mesh.shape[a] for a in t) == 1:
                    continue                      # effectively replicated
                if t == cand:
                    hot.append(i)
                else:
                    ok = False                    # sharded over another set
                    break
            if not ok or len(hot) > 1:
                ok = False
                break
            if not hot:
                dims.append(None)
            elif shape[hot[0]] % S == 0:
                dims.append(hot[0])
            else:
                ok = False
                break
        if ok:
            return (cand, dims) if S > 1 else ((), [None] * len(flat_specs))
    return None, None


def _grouped_resident_layout(mesh: Mesh, flat_specs, flat_shapes,
                             exclude: tuple[str, ...] = ()):
    """Per-leaf multi-axis placements for the GROUPED mesh-resident
    layout, or None when even that cannot align the tilings.

    Where :func:`_mesh_resident_layout` needs every leaf to agree on ONE
    super-axis, this covers FSDP-style mixed tilings: each leaf may tile
    any number of dims over any non-``exclude`` axis sets (e.g. dim 1
    over ``data`` and dim 2 over ``model``), and leaves sharing a
    placement key get their own :class:`~repro.common.packing.PackGroup`
    (``packing.pack_spec_grouped``). Disqualifiers — None is returned,
    callers fall back to the legacy GSPMD assembly: a leaf sharded over
    an excluded (replica) axis, a tiled dim that does not divide by its
    axes' device count, or a zero-size leaf (same hoisted guard as the
    single-axis chooser).
    """
    placements = []
    any_hot = False
    for sp, shape in zip(flat_specs, flat_shapes):
        if not all(d > 0 for d in shape):
            return None
        pl = []
        for i, e in enumerate(sp):
            t = _norm_entry(e)
            if not t or math.prod(mesh.shape[a] for a in t) == 1:
                continue                          # effectively replicated
            if set(t) & set(exclude):
                return None                       # sharded over replica axes
            parts = math.prod(mesh.shape[a] for a in t)
            if shape[i] % parts != 0:
                return None
            pl.append((i, t))
        any_hot = any_hot or bool(pl)
        placements.append(tuple(pl))
    if not any_hot:
        return None          # fully replicated: the single-axis chooser's
                             # ((), all-None) case already covers it
    return tuple(placements)


def choose_resident_spec(mesh: Mesh, params_abs, flat_specs, flat_shapes,
                         exclude: tuple[str, ...] = ()):
    """The layout chooser the sync builders drive: the single-super-axis
    layout when one aligns every leaf (unchanged PR-3 behavior, incl. the
    degenerate fully-replicated case), else the GROUPED layout whenever
    per-leaf placements exist, else None (legacy GSPMD fallback)."""
    from repro.common.packing import pack_spec, pack_spec_grouped

    axes, shard_dims = _mesh_resident_layout(mesh, flat_specs, flat_shapes,
                                             exclude=exclude)
    if axes is not None:
        S = math.prod(mesh.shape[a] for a in axes) if axes else 1
        return pack_spec(params_abs, shards=S, shard_dims=shard_dims,
                         axes=axes)
    placements = _grouped_resident_layout(mesh, flat_specs, flat_shapes,
                                          exclude=exclude)
    if placements is None:
        return None
    return pack_spec_grouped(params_abs, placements=placements,
                             axis_sizes={a: int(mesh.shape[a])
                                         for a in mesh.axis_names})


def _psum_composition(part, psum_axes, comms_dtype: str = "f32"):
    """psum ``part`` over each axis group in sequence — the grouped
    composition of the sync topology (one group for Flat, inner-then-
    outer for TwoLevel). Empty groups are skipped (K device-local).

    ``comms_dtype`` compresses the OUTERMOST (last non-empty) group's
    payload — the tree's cross-pod hop, the one that crosses the slow
    fabric — while inner pod-local reductions stay f32:

    - ``bf16``: quantize→all-gather→dequantize→local f32 halving-sum —
      each pod's partial is rounded to bf16 once, gathered, and reduced
      locally in f32 (deterministic halving order, no second rounding
      of the sum).
    - ``fp8``: an e4m3 reduction would ACCUMULATE in fp8 (catastrophic
      over >2 pods), so the partial is block-scale quantized
      (``common.quant``), ALL-GATHERED alongside its per-ALIGN-block f32
      scales, then dequantized locally and summed with the canonical
      halving order. Payload bytes drop ~4× (1-byte elements + 1/2048
      scale overhead).

    Both compressed payloads cross the wire BITCAST to the same-width
    unsigned integer (bf16→u16, e4m3fn→u8): XLA's float-normalization
    pass on backends without native narrow-float collectives (CPU
    included) otherwise rewrites the collective to a wide one — a bf16
    all-reduce is promoted to f32 and a bf16/fp8 gather has its
    consumer convert hoisted above it — silently restoring the full
    wire bytes. Integer collectives are never normalized, so the
    bit-view pins the true 2-/1-byte payload on every backend; the
    bundle contracts budget the u16/u8 gathers explicitly.
    """
    last = None
    if comms_dtype != "f32":
        non_empty = [i for i, axes in enumerate(psum_axes) if axes]
        last = non_empty[-1] if non_empty else None
    for i, axes in enumerate(psum_axes):
        if not axes:
            continue
        if i != last:
            part = jax.lax.psum(part, axes)
        elif comms_dtype == "bf16":
            from repro.core.online import halving_sum_axis0
            q = jax.lax.bitcast_convert_type(part.astype(jnp.bfloat16),
                                             jnp.uint16)
            qg = jax.lax.all_gather(q, axes)      # (n_pods, P_local) u16
            qg = jax.lax.bitcast_convert_type(qg, jnp.bfloat16)
            part = halving_sum_axis0(qg.astype(jnp.float32))
        else:
            from repro.common.quant import (block_scales, dequantize_fp8,
                                            quantize_fp8)
            from repro.core.online import halving_sum_axis0
            s = block_scales(part)
            q = jax.lax.bitcast_convert_type(
                quantize_fp8(part, s), jnp.uint8)
            qg = jax.lax.all_gather(q, axes)      # (n_pods, P_local) u8
            qg = jax.lax.bitcast_convert_type(qg, jnp.float8_e4m3fn)
            sg = jax.lax.all_gather(s, axes)      # (n_pods, blocks) f32
            part = halving_sum_axis0(dequantize_fp8(qg, sg))
    return part


def _push_window_groups(hwa_cfg: HWAConfig, bounds, rings, scaless, totals,
                        comps, mean, count, next_idx, cycle,
                        use_kernel: bool, with_stride: bool):
    """Per-group slide-window push of the packed mean — the grouped
    generalization of ``core.offline.window_update_packed`` (and, when
    ``with_stride``, ``core.hwa.window_push_packed``): one kernel launch
    per group over its local ``(I, seg_len)`` ring slice, ONE shared set
    of counters, and the sparse-window stride cond applied once across
    all groups. Single-range layouts pass one bound/ring/total and get
    bit-identical results to the ungrouped helpers.

    ``scaless``/``comps`` are the compressed ring's per-group companions
    (all-None for the f32 default, which keeps the exact pre-compression
    arithmetic): bf16 rings take the ``*_c`` Kahan-total kernel when
    ``use_kernel``, fp8 rings always take the jnp reference (the kernel
    has no per-block scale state — ``kernels.ops.KERNEL_RING_DTYPES``)."""
    from repro.kernels.ref import wa_window_update_c_ref, \
        wa_window_update_ref

    I = hwa_cfg.window
    idx = next_idx
    full = (count >= I).astype(jnp.float32)
    new_count = jnp.minimum(count + 1, I)
    inv = 1.0 / new_count.astype(jnp.float32)

    def do_update(state):
        rs, ss, ts, cs = state
        out_r, out_s, out_t, out_c, out_a = [], [], [], [], []
        for (lo, hi), r, s, t, c in zip(bounds, rs, ss, ts, cs):
            m = jax.lax.slice_in_dim(mean, lo, hi, axis=0)
            if r.dtype == jnp.float32:
                if use_kernel:
                    from repro.kernels import ops as kops
                    r2, t2, a = kops.wa_window_update_packed(r, t, m, idx,
                                                             full, inv)
                else:
                    r2, t2, a = wa_window_update_ref(r, t, m, idx, full,
                                                     inv)
                s2, c2 = s, c
            elif use_kernel and r.dtype == jnp.bfloat16:
                from repro.kernels import ops as kops
                r2, t2, c2, a = kops.wa_window_update_packed_c(
                    r, t, c, m, idx, full, inv)
                s2 = s
            else:
                r2, s2, t2, c2, a = wa_window_update_c_ref(
                    r, s, t, c, m, idx, full, inv)
            out_r.append(r2)
            out_s.append(s2)
            out_t.append(t2)
            out_c.append(c2)
            out_a.append(a)
        return (tuple(out_r), tuple(out_s), tuple(out_t), tuple(out_c),
                tuple(out_a), new_count, jnp.mod(idx + 1, I))

    def skip_update(state):
        rs, ss, ts, cs = state
        denom = jnp.maximum(count, 1).astype(jnp.float32)
        return (tuple(rs), tuple(ss), tuple(ts), tuple(cs),
                tuple(t / denom for t in ts), count, idx)

    new_cycle = cycle + 1
    state = (tuple(rings), tuple(scaless), tuple(totals), tuple(comps))
    if not with_stride or hwa_cfg.window_stride == 1:
        rs2, ss2, ts2, cs2, avgs, cnt2, nidx2 = do_update(state)
    else:
        take = jnp.mod(new_cycle - 1, hwa_cfg.window_stride) == 0
        rs2, ss2, ts2, cs2, avgs, cnt2, nidx2 = jax.lax.cond(
            take, do_update, skip_update, state)
    if with_stride:
        # W̿ = W̄ until the window holds an entry (window_push_packed)
        avgs = tuple(
            jnp.where(cnt2 == 0,
                      jax.lax.slice_in_dim(mean, lo, hi, axis=0), a)
            for (lo, hi), a in zip(bounds, avgs))
    return rs2, ss2, ts2, cs2, avgs, cnt2, nidx2, new_cycle


def _local_packed_sync(hwa_cfg: HWAConfig, lspec, K: int,
                       psum_axes: tuple[tuple[str, ...], ...],
                       use_kernel: bool, with_stride: bool, inner, ring,
                       total, count, next_idx, cycle, scales=None,
                       comp=None, *, comms_dtype: str = "f32",
                       health_axes: tuple[str, ...] = (),
                       health_scale: int = 1):
    """Per-device body of the mesh-resident packed sync.

    Runs under a FULLY-MANUAL shard_map (every mesh axis manual), so the
    Pallas kernels see true local shapes — the per-shard (I, P/shards)
    ring slice — instead of GSPMD's global-shape view that made them
    unusable on meshes. ``lspec`` is ``pack_spec.local_spec()``: the
    device's segment of the shard-aware layout, assembled here from the
    local leaf shards alone (zero collectives by construction).

    ``lspec`` may be a GROUPED local layout (mixed/FSDP tilings): ``ring``
    and ``total`` then arrive as per-group buffer tuples (each group's
    range shards over its own super-axis, so one array cannot carry them
    all), the window push runs one kernel launch per group on its local
    slice, and the weight all-reduce still happens ONCE over the
    concatenated local partials. Single-range layouts pass bare buffers
    and behave exactly as before.

    ``psum_axes`` is the topology's grouped reduction composition
    (``SyncTopology.psum_groups()``): one group — the flat weight
    all-reduce — or inner-then-outer for the two-level tree, where the
    per-pod psum and the cross-pod psum are separate collectives with
    their own ``replica_groups``. Partial sums are pre-scaled by 1/K and
    the local stacked sum uses the canonical contiguous-pairing halving
    order, so for power-of-two replica counts the composition is
    bit-identical to the flat mean (``core.online.halving_sum_axis0``).
    With K resident on a single device (all groups empty) even the psum
    disappears and the whole sync fuses into one kernel launch.

    With ``hwa_cfg.resilient`` the K-mean becomes the alive-masked
    elastic mean (``repro.resilience.health``): per-replica health stats
    are aggregated over each replica's parameter shards with ONE psum
    over ``health_axes`` (the non-replica mesh axes of size > 1;
    ``health_scale`` is their device-count product, used for the static
    RMS denominator), the alive count crosses the replica levels as its
    own tiny psum, and the weight psum reduces
    ``halving_sum_axis0(where(alive, sbuf, 0)) * (1/k_alive)`` — bitwise
    identical to the plain path when everyone is alive (the inv pins to
    the trace-time ``f32(1/K)``; see ``resilience.health``). The
    k_alive→inv→weight-partial data dependency deliberately keeps the
    two replica-level all-reduces unmergeable by XLA's combiner, so the
    resilient collective contract is an exact count (2 per level + 1
    health crossing). Kernels are bypassed when resilient (they cannot
    mask); the returned alive mask is the 8th output.

    **Compressed state.** ``scales``/``comp`` are the compressed ring's
    companions (``packing.window_aux_buffers`` shapes, per-group tuples
    for grouped layouts; both None on the f32 default, whose arithmetic
    is bit-identical to the pre-compression body). bf16 rings fuse
    through the ``*_c`` Kahan-total kernels under the same gate as f32;
    fp8 rings (no in-kernel scale state) always take the jnp reference
    push. The restart W̄ for a compressed ring is the DECODED stored
    mean — the ring slot and the live replicas agree bitwise, and the
    kernel (slot read-back) and jnp (encode→decode) paths match.
    ``comms_dtype`` quantizes the outermost weight reduction
    (:func:`_psum_composition`); the k_alive/health collectives of the
    resilient path stay f32 (scalar/stat payloads, not worth a contract
    exception — the builders refuse resilient + compressed comms).

    Returns ``(new_inner, ring, scales, total, comp, count, next_idx,
    wa, cycle, alive)`` — scales/comp are None whenever the input was
    (callers drop them from their shard_map outputs); alive is the
    per-device ``(k_local,)`` bool mask of its resident replicas
    (all-true when not resilient).
    """
    from repro.common.packing import pack_stacked, unpack
    from repro.core.online import broadcast_to_replicas, halving_sum_axis0
    from repro.kernels.ops import KERNEL_RING_DTYPES

    I = hwa_cfg.window
    grouped = isinstance(ring, tuple)
    rings = ring if grouped else (ring,)
    totals = total if grouped else (total,)
    n_g = len(rings)
    scaless = ((scales if grouped else (scales,))
               if scales is not None else (None,) * n_g)
    comps = ((comp if grouped else (comp,))
             if comp is not None else (None,) * n_g)
    compressed = rings[0].dtype != jnp.float32
    if compressed and comps[0] is None:
        comps = tuple(jnp.zeros_like(t) for t in totals)
    gt = lspec.group_table()       # local view: one segment per group
    bounds = [(g.offset, g.offset + g.seg_len) for g in gt]
    sbuf = pack_stacked(inner, lspec)            # (K_local, P_local) f32
    k_local = sbuf.shape[0]
    collective = any(psum_axes)
    resilient = hwa_cfg.resilient
    alive = jnp.ones((k_local,), jnp.bool_)
    fused = (use_kernel and not collective
             and rings[0].dtype in KERNEL_RING_DTYPES and not resilient
             and (not with_stride or hwa_cfg.window_stride == 1))
    if fused:
        # whole sync in ONE launch per group on its local slice: K-mean +
        # window push, (K+2) reads + 3 writes, W̄ read back from the ring
        # slot — ≤ n_groups pallas_calls total
        from repro.kernels import ops as kops
        idx = next_idx
        full = (count >= I).astype(jnp.float32)
        new_count = jnp.minimum(count + 1, I)
        inv = 1.0 / new_count.astype(jnp.float32)
        rs2, ts2, cs2, means, avgs = [], [], [], [], []
        for (lo, hi), r, t, c in zip(bounds, rings, totals, comps):
            sb = jax.lax.slice_in_dim(sbuf, lo, hi, axis=1)
            if compressed:
                r2, t2, c2, a = kops.hwa_sync_packed_c(sb, r, t, c, idx,
                                                       full, inv)
            else:
                r2, t2, a = kops.hwa_sync_packed(sb, r, t, idx, full, inv)
                c2 = c
            means.append(jax.lax.dynamic_index_in_dim(
                r2, idx, keepdims=False).astype(jnp.float32))
            rs2.append(r2)
            ts2.append(t2)
            cs2.append(c2)
            avgs.append(a)
        ss2 = scaless                   # bf16 carries no scale state
        new_nidx = jnp.mod(idx + 1, I)
        new_cycle = cycle + 1
    elif resilient:
        from repro.resilience.health import (alive_from_stats,
                                             packed_health_stats,
                                             renormalized_inv)
        stats = packed_health_stats(sbuf)        # (k_local, 2) f32
        if health_axes:
            # aggregate each resident replica's stats over its parameter
            # shards — crosses ONLY non-replica axes (the contract's
            # budgeted `other_ops` all-reduce)
            stats = jax.lax.psum(stats, health_axes)
        n_elems = float(sbuf.shape[1] * health_scale)
        alive = alive_from_stats(stats, n_elems, hwa_cfg.max_param_rms)
        k_alive = _psum_composition(jnp.sum(alive.astype(jnp.float32)),
                                    psum_axes)
        # all-dead: drop the mask, degrade to today's plain mean (the
        # run is unsalvageable; k_alive==0 makes it observable instead
        # of silently restarting everyone from zeros)
        alive = alive | (k_alive == 0.0)
        k_eff = jnp.where(k_alive > 0.0, k_alive, jnp.float32(K))
        inv = renormalized_inv(k_eff, K)
        part = halving_sum_axis0(
            jnp.where(alive[:, None], sbuf, jnp.float32(0.0))) * inv
        mean = _psum_composition(part, psum_axes, comms_dtype)
        rs2, ss2, ts2, cs2, avgs, new_count, new_nidx, new_cycle = \
            _push_window_groups(hwa_cfg, bounds, rings, scaless, totals,
                                comps, mean, count, next_idx, cycle,
                                use_kernel, with_stride)
    else:
        if use_kernel and k_local == 2 and len(gt) == 1:
            # the kernel's row reduction is jnp.sum order — a single IEEE
            # add for 2 rows, so it keeps the halving/composition bits;
            # for k_local > 2 it would NOT (XLA's order is neither
            # sequential nor pairwise, measured), so the canonical
            # halving sum below takes over to preserve the 0-ULP
            # flat↔tree parity contract (docs/ARCHITECTURE.md §4).
            # Grouped layouts always take the halving sum (same single
            # IEEE add for 2 rows, bit-identical) so the launch budget
            # stays ≤ n_groups — the per-group window updates.
            from repro.kernels import ops as kops
            part = kops.online_mean_packed(sbuf, inv_k=1.0 / K)
        else:
            part = halving_sum_axis0(sbuf) * (1.0 / K)
        # THE weight all-reduce(s): computed over the CONCATENATED local
        # buffer (all groups at once) so the grouped layout still costs
        # exactly one collective per topology level; pre-scaled partial
        # sums keep the result bit-identical to the fused kernel's
        # sum×(1/K) for power-of-two K, flat psum and grouped composition
        # alike
        mean = _psum_composition(part, psum_axes, comms_dtype)
        rs2, ss2, ts2, cs2, avgs, new_count, new_nidx, new_cycle = \
            _push_window_groups(hwa_cfg, bounds, rings, scaless, totals,
                                comps, mean, count, next_idx, cycle,
                                use_kernel, with_stride)
    if fused:
        mean = (jnp.concatenate(means) if len(means) > 1 else means[0])
    elif compressed:
        # restart from the DECODED stored mean: the same bits the window
        # slot holds (group lengths are ALIGN multiples, so encoding the
        # concatenated buffer matches the per-group slot encodings) and
        # the same bits the fused kernel path reads back from the ring
        from repro.common.quant import decode_slot, encode_slot
        mean = decode_slot(*encode_slot(mean, rings[0].dtype))
    avg = (jnp.concatenate(list(avgs)) if len(avgs) > 1 else avgs[0])
    outer = unpack(mean, lspec)                  # local leaf views, free
    wa = unpack(avg, lspec)
    new_inner = broadcast_to_replicas(outer, k_local)
    ring_out = tuple(rs2) if grouped else rs2[0]
    total_out = tuple(ts2) if grouped else ts2[0]
    scales_out = (None if scales is None
                  else (tuple(ss2) if grouped else ss2[0]))
    comp_out = (None if comp is None and not compressed
                else (tuple(cs2) if grouped else cs2[0]))
    return (new_inner, ring_out, scales_out, total_out, comp_out,
            new_count, new_nidx, wa, new_cycle, alive)


def _local_inner_sync(lspec, pod_size: int,
                      psum_axes: tuple[tuple[str, ...], ...], inner):
    """Per-device body of the two-level tree's INNER (pod-local) sync.

    Same fully-manual setting as :func:`_local_packed_sync` (grouped
    local layouts included — ``pack_stacked``/``unpack`` are group-aware
    and the body touches no window buffers), but the
    reduction stops at the pod boundary: one psum whose
    ``replica_groups`` pair only same-pod devices, so the lowered HLO
    crosses NOTHING but the inner axis (audited per level by
    ``launch.hlo.sync_collective_audit``). No window state is touched —
    the slide window collects GLOBAL outer weights only, so pod-internal
    restarts leave ring/total/counters alone. Touches no Pallas kernel
    either: the body is one add tree + one psum + layout views, which
    XLA fuses fine without a custom call.
    """
    from repro.common.packing import pack_stacked, unpack
    from repro.core.online import broadcast_to_replicas, halving_sum_axis0

    sbuf = pack_stacked(inner, lspec)            # (K_local, seg_len) f32
    k_local = sbuf.shape[0]
    part = halving_sum_axis0(sbuf) * (1.0 / pod_size)
    pod_mean = _psum_composition(part, psum_axes)
    outer = unpack(pod_mean, lspec)
    return broadcast_to_replicas(outer, k_local)


def packed_sync_launch_budget(hwa_cfg: HWAConfig, *, use_kernel: bool,
                              n_groups: int, k_local: int,
                              collective: bool, with_stride: bool,
                              ring_dtype="f32",
                              resilient: bool | None = None) -> int:
    """Static Pallas-launch count of :func:`_local_packed_sync`.

    The single source of truth the builders' declared
    ``LaunchBudget`` shares with the kernel gating above — a drifted
    copy would let ``hwa-lint`` rubber-stamp a regressed launch count.
    Mirrors the gates exactly: the fused path (f32 or bf16 — the ring
    dtypes in ``kernels.ops.KERNEL_RING_DTYPES``; fp8 rings have no
    kernel and take the jnp reference everywhere) is one
    ``hwa_sync_packed``/``hwa_sync_packed_c`` per group; otherwise the
    mean kernel runs only in the ungrouped ``k_local == 2`` case and the
    window push costs one launch per group (``cond`` branches under
    ``window_stride > 1`` included — the budget is a static program
    property, not a per-call trace). The resilient (alive-masked) sync
    bypasses the fused and mean kernels — they cannot mask — leaving
    only the per-group window pushes.
    """
    from repro.common.quant import wa_dtype
    from repro.kernels.ops import KERNEL_RING_DTYPES
    if resilient is None:
        resilient = hwa_cfg.resilient
    if not use_kernel:
        return 0
    kernel_ring = jnp.dtype(wa_dtype(ring_dtype)) in KERNEL_RING_DTYPES
    fused = (not collective and kernel_ring and not resilient
             and (not with_stride or hwa_cfg.window_stride == 1))
    if fused:
        return n_groups
    mean = 1 if (k_local == 2 and n_groups == 1 and not resilient) else 0
    push = n_groups if kernel_ring else 0
    return mean + push
