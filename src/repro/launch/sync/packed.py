"""Mesh-resident packed sync: the shard-aware layout chooser and the
per-device bodies that run under a FULLY-MANUAL shard_map.

Moved out of the ``launch/steps.py`` monolith (PR 4). Everything here is
mesh-mechanics: which packed super-axis the window buffers shard over
(:func:`_mesh_resident_layout`), how they are sharded
(:func:`_packed_sharding`), and the local sync bodies
(:func:`_local_packed_sync` for full syncs — flat OR the two-level outer
composition — and :func:`_local_inner_sync` for the tree's pod-internal
restarts). The StepBundle assembly lives in ``launch.sync.bundles``; the
GSPMD fallback in ``launch.sync.legacy``.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.hwa import HWAConfig


def _norm_entry(entry) -> tuple[str, ...]:
    """A PartitionSpec entry as a tuple of mesh-axis names."""
    if entry is None:
        return ()
    return tuple(entry) if isinstance(entry, (tuple, list)) else (entry,)


def _axes_entry(axes: tuple[str, ...]):
    """A packed super-axis as a PartitionSpec entry (None/str/tuple)."""
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else tuple(axes)


def _packed_sharding(mesh: Mesh, padded: int, lead_dims: int = 0,
                     axes: tuple[str, ...] | None = None) -> NamedSharding:
    """Sharding for a packed WA buffer.

    ``axes`` is the packed super-axis of a shard-aware ``PackSpec``
    (``spec.axes``) — the packed dim is split over exactly those mesh
    axes, jointly. ``axes=None`` keeps the legacy heuristic used by the
    non-mesh-resident fallback: split over ``model`` when it divides
    (it always does — ``padded`` is an ALIGN multiple), else replicate.
    """
    if axes is None:
        ax = "model" if ("model" in mesh.shape
                         and padded % mesh.shape["model"] == 0) else None
    else:
        ax = _axes_entry(axes)
    return NamedSharding(mesh, P(*([None] * lead_dims + [ax])))


def _mesh_resident_layout(mesh: Mesh, flat_specs, flat_shapes,
                          exclude: tuple[str, ...] = ()):
    """Choose a packed super-axis aligning leaf tilings with packed ranges.

    Returns ``(axes, shard_dims)`` such that ``pack_spec(params,
    shards=prod(axes), shard_dims=..., axes=axes)`` makes packed-W̄
    assembly and W̿ unpacking shard-local (zero collectives): every leaf
    either has exactly ONE dim sharded over exactly ``axes`` (jointly, in
    order) — that dim becomes its ``shard_dim`` — or is replicated over
    the non-``exclude`` mesh axes and gets duplicated per segment.

    Candidates are the distinct PartitionSpec entries the leaves actually
    use (arbitrary mesh-axis sets, not just the single ``model`` axis),
    tried largest-device-count first; ``((), all-None)`` is returned for
    fully-replicated trees, and ``(None, None)`` when no super-axis covers
    every leaf (e.g. FSDP's mixed data/model tilings) — callers then fall
    back to the legacy redistribute-and-all-reduce assembly.
    """
    cands: list[tuple[str, ...]] = []
    for sp in flat_specs:
        for e in sp:
            t = _norm_entry(e)
            if (t and not (set(t) & set(exclude)) and t not in cands
                    and math.prod(mesh.shape[a] for a in t) > 1):
                cands.append(t)
    cands.sort(key=lambda t: -math.prod(mesh.shape[a] for a in t))
    cands.append(())
    for cand in cands:
        S = math.prod(mesh.shape[a] for a in cand) if cand else 1
        dims: list[int | None] = []
        ok = True
        for sp, shape in zip(flat_specs, flat_shapes):
            hot = []
            for i, e in enumerate(sp):
                t = _norm_entry(e)
                if not t or math.prod(mesh.shape[a] for a in t) == 1:
                    continue                      # effectively replicated
                if t == cand:
                    hot.append(i)
                else:
                    ok = False                    # sharded over another set
                    break
            if not ok or len(hot) > 1:
                ok = False
                break
            if not hot:
                dims.append(None)
            elif shape[hot[0]] % S == 0 and all(d > 0 for d in shape):
                dims.append(hot[0])
            else:
                ok = False
                break
        if ok:
            return (cand, dims) if S > 1 else ((), [None] * len(flat_specs))
    return None, None


def _psum_composition(part, psum_axes):
    """psum ``part`` over each axis group in sequence — the grouped
    composition of the sync topology (one group for Flat, inner-then-
    outer for TwoLevel). Empty groups are skipped (K device-local)."""
    for axes in psum_axes:
        if axes:
            part = jax.lax.psum(part, axes)
    return part


def _local_packed_sync(hwa_cfg: HWAConfig, lspec, K: int,
                       psum_axes: tuple[tuple[str, ...], ...],
                       use_kernel: bool, with_stride: bool, inner, ring,
                       total, count, next_idx, cycle):
    """Per-device body of the mesh-resident packed sync.

    Runs under a FULLY-MANUAL shard_map (every mesh axis manual), so the
    Pallas kernels see true local shapes — the per-shard (I, P/shards)
    ring slice — instead of GSPMD's global-shape view that made them
    unusable on meshes. ``lspec`` is ``pack_spec.local_spec()``: the
    device's segment of the shard-aware layout, assembled here from the
    local leaf shards alone (zero collectives by construction).

    ``psum_axes`` is the topology's grouped reduction composition
    (``SyncTopology.psum_groups()``): one group — the flat weight
    all-reduce — or inner-then-outer for the two-level tree, where the
    per-pod psum and the cross-pod psum are separate collectives with
    their own ``replica_groups``. Partial sums are pre-scaled by 1/K and
    the local stacked sum uses the canonical contiguous-pairing halving
    order, so for power-of-two replica counts the composition is
    bit-identical to the flat mean (``core.online.halving_sum_axis0``).
    With K resident on a single device (all groups empty) even the psum
    disappears and the whole sync fuses into one kernel launch.
    """
    from repro.common.packing import pack_stacked, unpack
    from repro.core.hwa import window_push_packed
    from repro.core.offline import WindowState, window_update_packed
    from repro.core.online import broadcast_to_replicas, halving_sum_axis0

    I = hwa_cfg.window
    sbuf = pack_stacked(inner, lspec)            # (K_local, seg_len) f32
    k_local = sbuf.shape[0]
    collective = any(psum_axes)
    fused = (use_kernel and not collective and ring.dtype == jnp.float32
             and (not with_stride or hwa_cfg.window_stride == 1))
    if fused:
        # whole sync in ONE launch on the local slice: K-mean + window
        # push, (K+2) reads + 3 writes, W̄ read back from the ring slot
        from repro.kernels import ops as kops
        idx = next_idx
        full = (count >= I).astype(jnp.float32)
        new_count = jnp.minimum(count + 1, I)
        ring2, total2, avg = kops.hwa_sync_packed(
            sbuf, ring, total, idx, full,
            1.0 / new_count.astype(jnp.float32))
        mean = jax.lax.dynamic_index_in_dim(ring2, idx, keepdims=False)
        ws2 = WindowState(ring=ring2, total=total2, count=new_count,
                          next_idx=jnp.mod(idx + 1, I), window=I,
                          kind="ring", spec=lspec)
        new_cycle = cycle + 1
    else:
        if use_kernel and k_local == 2:
            # the kernel's row reduction is jnp.sum order — a single IEEE
            # add for 2 rows, so it keeps the halving/composition bits;
            # for k_local > 2 it would NOT (XLA's order is neither
            # sequential nor pairwise, measured), so the canonical
            # halving sum below takes over to preserve the 0-ULP
            # flat↔tree parity contract (docs/ARCHITECTURE.md §4)
            from repro.kernels import ops as kops
            part = kops.online_mean_packed(sbuf, inv_k=1.0 / K)
        else:
            part = halving_sum_axis0(sbuf) * (1.0 / K)
        # THE weight all-reduce(s): pre-scaled partial sums keep the
        # result bit-identical to the fused kernel's sum×(1/K) for
        # power-of-two K, flat psum and grouped composition alike
        mean = _psum_composition(part, psum_axes)
        ws = WindowState(ring=ring, total=total, count=count,
                         next_idx=next_idx, window=I, kind="ring",
                         spec=lspec)
        if with_stride:
            ws2, avg, new_cycle = window_push_packed(
                hwa_cfg, mean, ws, cycle, use_kernel=use_kernel)
        else:
            ws2, avg = window_update_packed(ws, mean, use_kernel=use_kernel)
            new_cycle = cycle + 1
    outer = unpack(mean, lspec)                  # local leaf views, free
    wa = unpack(avg, lspec)
    new_inner = broadcast_to_replicas(outer, k_local)
    return (new_inner, ws2.ring, ws2.total, ws2.count, ws2.next_idx, wa,
            new_cycle)


def _local_inner_sync(lspec, pod_size: int,
                      psum_axes: tuple[tuple[str, ...], ...], inner):
    """Per-device body of the two-level tree's INNER (pod-local) sync.

    Same fully-manual setting as :func:`_local_packed_sync`, but the
    reduction stops at the pod boundary: one psum whose
    ``replica_groups`` pair only same-pod devices, so the lowered HLO
    crosses NOTHING but the inner axis (audited per level by
    ``launch.hlo.sync_collective_audit``). No window state is touched —
    the slide window collects GLOBAL outer weights only, so pod-internal
    restarts leave ring/total/counters alone. Touches no Pallas kernel
    either: the body is one add tree + one psum + layout views, which
    XLA fuses fine without a custom call.
    """
    from repro.common.packing import pack_stacked, unpack
    from repro.core.online import broadcast_to_replicas, halving_sum_axis0

    sbuf = pack_stacked(inner, lspec)            # (K_local, seg_len) f32
    k_local = sbuf.shape[0]
    part = halving_sum_axis0(sbuf) * (1.0 / pod_size)
    pod_mean = _psum_composition(part, psum_axes)
    outer = unpack(pod_mean, lspec)
    return broadcast_to_replicas(outer, k_local)
