"""Step builders: plain data+tensor-parallel training/serving steps and
the HWA-stacked variants, with in/out shardings resolved from the
logical-dim trees. These are what the dry-run lowers and what real
launches run.

Split of the former ``launch/steps.py`` monolith (PR 4): this module
assembles StepBundles; the sync-topology abstraction lives in
``launch.sync.topology`` (Flat / TwoLevel), the mesh-resident packed
machinery in ``launch.sync.packed``, and the legacy GSPMD fallback in
``launch.sync.legacy``. ``repro.launch.steps`` remains a re-exporting
facade, so every pre-split import keeps working.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import warnings
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.analysis.contracts import sync_contract, train_contract
from repro.common.compat import shard_map
from repro.core.hwa import HWAConfig, hwa_local_inner_step
from repro.launch.sync.legacy import (check_legacy_assembly,
                                      make_legacy_mesh_sync_step,
                                      make_legacy_sync_step)
from repro.launch.sync.packed import (_axes_entry, _local_inner_sync,
                                      _local_packed_sync, _norm_entry,
                                      _packed_pspecs, _packed_shardings,
                                      choose_resident_spec,
                                      packed_sync_launch_budget)
from repro.launch.sync.topology import Flat, SyncTopology, TwoLevel
from repro.models.registry import LM
from repro.optim import adamw, apply_updates, sgd
from repro.sharding.rules import ShardingRules, stacked_replica_specs

PyTree = Any


def _prefix_dims(dim_tree, name):
    """Prepend a logical dim to every dims-tuple leaf (e.g. 'replica')."""
    is_dims = lambda t: isinstance(t, tuple) and all(
        isinstance(e, (str, type(None))) for e in t)
    return jax.tree.map(lambda t: (name,) + t, dim_tree, is_leaf=is_dims)


def opt_state_dims(opt_state_abs, param_dims):
    """Logical dims for optimizer state: moments mirror the params."""
    # adamw: {"m": params-like, "v": params-like, "count": scalar}
    # sgd(momentum): {"mu": params-like}
    out = {}
    for k, v in opt_state_abs.items():
        if k == "count":
            out[k] = ()
        else:
            out[k] = param_dims
    return out


@dataclasses.dataclass
class StepBundle:
    """A step function plus its abstract args and in/out shardings.

    ``pack_spec`` is set by the WA sync bundles: their window state (and
    returned W̿) lives in the packed layout of ``repro.common.packing``;
    consumers materialize leaf views with ``packing.unpack(buf,
    bundle.pack_spec)``.

    ``contract`` is the bundle's declarative SPMD contract
    (:class:`repro.analysis.contracts.BundleContract`), attached by the
    builder — it knows the topology, kernel gating and pack layout it
    chose, so the declaration (collective census, Pallas-launch budget,
    dtype discipline) is exact with no second source of truth.
    ``tools/hwa_lint.py`` checks it against the compiled program; None
    means only the universal baseline applies.
    """
    fn: Any
    abstract_args: tuple
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple = ()
    pack_spec: Any = None
    contract: Any = None

    def lower(self, mesh: Mesh):
        jitted = jax.jit(self.fn, in_shardings=self.in_shardings,
                         out_shardings=self.out_shardings,
                         donate_argnums=self.donate_argnums)
        with mesh:
            return jitted.lower(*self.abstract_args)


def _mk_optimizer(name: str):
    if name == "sgd":
        return sgd(momentum=0.9, weight_decay=5e-4)
    return adamw(weight_decay=0.1)


def make_train_step(lm: LM, rules: ShardingRules, batch_specs, batch_dims,
                    optimizer: str = "adamw", lr: float = 3e-4,
                    opt_rules: ShardingRules | None = None,
                    n_microbatches: int = 1) -> StepBundle:
    """Plain data+tensor-parallel train step (the 40-combo baseline).

    ``opt_rules`` lets the optimizer moments use a different (e.g. FSDP)
    rule table than the compute params. ``n_microbatches`` > 1 enables
    gradient accumulation: peak activation temps scale ~1/n_mb while the
    f32 grad accumulator is fully sharded — the lever that fits the ≥27B
    trainings into 16 GB/chip (EXPERIMENTS.md §Perf).
    """
    opt = _mk_optimizer(optimizer)
    params_abs, param_dims = lm.abstract()
    opt_abs = jax.eval_shape(opt.init, params_abs)
    o_dims = opt_state_dims(opt_abs, param_dims)
    opt_rules = opt_rules or rules
    loss_fn = lambda p, b: lm.loss(p, b, rules=rules)

    def step(params, opt_state, batch):
        if n_microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape((n_microbatches,
                                     x.shape[0] // n_microbatches)
                                    + x.shape[1:]), batch)

            def body(acc, mbatch):
                g_acc, l_acc, a_acc = acc
                (loss, metrics), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mbatch)
                g_acc = jax.tree.map(
                    lambda a, gi: a + gi.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + metrics["loss"],
                        a_acc + metrics["acc"]), None

            zeros = jax.tree.map(
                lambda pp: jnp.zeros(pp.shape, jnp.float32), params)
            (g_sum, l_sum, a_sum), _ = jax.lax.scan(
                body, (zeros, jnp.zeros(()), jnp.zeros(())), mb)
            grads = jax.tree.map(
                lambda g, pp: (g / n_microbatches).astype(pp.dtype),
                g_sum, params)
            metrics = {"loss": l_sum / n_microbatches,
                       "aux": jnp.zeros(()),
                       "acc": a_sum / n_microbatches}
        updates, opt_state = opt.update(grads, opt_state, params, lr)
        params = apply_updates(params, updates)
        return params, opt_state, metrics

    p_sh = rules.tree_shardings(params_abs, param_dims)
    o_sh = opt_rules.tree_shardings(opt_abs, o_dims)
    b_sh = rules.tree_shardings(batch_specs, batch_dims)
    scalar_sh = NamedSharding(rules.mesh, P())
    m_sh = {"loss": scalar_sh, "aux": scalar_sh, "acc": scalar_sh}
    return StepBundle(
        fn=step, abstract_args=(params_abs, opt_abs, batch_specs),
        in_shardings=(p_sh, o_sh, b_sh),
        out_shardings=(p_sh, o_sh, m_sh),
        donate_argnums=(0, 1),
        contract=train_contract(notes="plain DP+TP train step"))


def make_prefill_step(lm: LM, rules: ShardingRules, batch_specs, batch_dims,
                      cache_abs, cache_dims) -> StepBundle:
    def step(params, cache, batch):
        return lm.prefill(params, cache, batch, rules=rules)

    params_abs, param_dims = lm.abstract()
    p_sh = rules.tree_shardings(params_abs, param_dims)
    c_sh = rules.tree_shardings(cache_abs, cache_dims)
    b_sh = rules.tree_shardings(batch_specs, batch_dims)
    logits_abs = jax.eval_shape(step, params_abs, cache_abs, batch_specs)[0]
    logits_dims = ("batch",) + (None,) * (len(logits_abs.shape) - 2) + ("vocab",)
    l_sh = rules.tree_shardings(logits_abs, logits_dims)
    return StepBundle(
        fn=step, abstract_args=(params_abs, cache_abs, batch_specs),
        in_shardings=(p_sh, c_sh, b_sh),
        out_shardings=(l_sh, c_sh),
        donate_argnums=(1,),
        contract=train_contract(notes="prefill step"))


def make_decode_step(lm: LM, rules: ShardingRules, token_specs, token_dims,
                     cache_abs, cache_dims) -> StepBundle:
    def step(params, cache, tokens):
        return lm.decode_step(params, cache, tokens, rules=rules)

    params_abs, param_dims = lm.abstract()
    p_sh = rules.tree_shardings(params_abs, param_dims)
    c_sh = rules.tree_shardings(cache_abs, cache_dims)
    t_sh = rules.tree_shardings(token_specs, token_dims)
    logits_abs = jax.eval_shape(step, params_abs, cache_abs, token_specs)[0]
    logits_dims = ("batch",) + (None,) * (len(logits_abs.shape) - 2) + ("vocab",)
    l_sh = rules.tree_shardings(logits_abs, logits_dims)
    return StepBundle(
        fn=step, abstract_args=(params_abs, cache_abs, token_specs),
        in_shardings=(p_sh, c_sh, t_sh),
        out_shardings=(l_sh, c_sh),
        donate_argnums=(1,),
        contract=train_contract(notes="decode step"))


# ------------------------------------------------------------- HWA steps


def _make_hwa_train_step(lm: LM, rules: ShardingRules, batch_specs,
                         batch_dims, hwa_cfg: HWAConfig,
                         optimizer: str = "adamw", lr: float = 3e-4,
                         opt_rules: ShardingRules | None = None,
                         n_microbatches: int = 1) -> StepBundle:
    """Inner HWA step: K independent replicas, stacked on the replica axis.

    Gradient all-reduce stays *inside* each replica's data shard; nothing
    crosses the replica/pod axis here — that is the H-fold comm saving.
    """
    opt = _mk_optimizer(optimizer)
    K = hwa_cfg.n_replicas
    params_abs, param_dims = lm.abstract()
    stacked_abs = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((K,) + s.shape, s.dtype), params_abs)
    stacked_dims = _prefix_dims(param_dims, "replica")
    opt_abs = jax.eval_shape(lambda p: jax.vmap(opt.init)(p), stacked_abs)
    o_dims = opt_state_dims(opt_abs, stacked_dims)
    if "count" in o_dims:          # adamw step counter, vmapped to (K,)
        o_dims["count"] = ("replica",)
    opt_rules = opt_rules or rules
    kbatch_abs = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((K,) + s.shape, s.dtype), batch_specs)
    kbatch_dims = _prefix_dims(batch_dims, "replica")

    def loss_fn(params, batch):
        return lm.loss(params, batch, rules=rules)

    def step(inner, inner_opt, batches):
        def one(params, opt_state, batch):
            if n_microbatches == 1:
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, batch)
            else:
                mb = jax.tree.map(
                    lambda x: x.reshape((n_microbatches,
                                         x.shape[0] // n_microbatches)
                                        + x.shape[1:]), batch)

                def body(acc, mbatch):
                    g_acc, l_acc = acc
                    (l, m), g = jax.value_and_grad(
                        loss_fn, has_aux=True)(params, mbatch)
                    g_acc = jax.tree.map(
                        lambda a, gi: a + gi.astype(jnp.float32), g_acc, g)
                    return (g_acc, l_acc + m["loss"]), None

                zeros = jax.tree.map(
                    lambda pp: jnp.zeros(pp.shape, jnp.float32), params)
                (g_sum, l_sum), _ = jax.lax.scan(
                    body, (zeros, jnp.zeros(())), mb)
                grads = jax.tree.map(
                    lambda g, pp: (g / n_microbatches).astype(pp.dtype),
                    g_sum, params)
                metrics = {"loss": l_sum / n_microbatches}
            updates, opt_state = opt.update(grads, opt_state, params, lr)
            return apply_updates(params, updates), opt_state, metrics["loss"]

        inner, inner_opt, losses = jax.vmap(one)(inner, inner_opt, batches)
        return inner, inner_opt, jnp.mean(losses)

    p_sh = rules.tree_shardings(stacked_abs, stacked_dims)
    o_sh = opt_rules.tree_shardings(opt_abs, o_dims)
    b_sh = rules.tree_shardings(kbatch_abs, kbatch_dims)
    scalar_sh = NamedSharding(rules.mesh, P())
    return StepBundle(
        fn=step, abstract_args=(stacked_abs, opt_abs, kbatch_abs),
        in_shardings=(p_sh, o_sh, b_sh),
        out_shardings=(p_sh, o_sh, scalar_sh),
        donate_argnums=(0, 1),
        # vmap path: replica independence is GSPMD-propagated, not
        # structural, so no replica-axis collective claim is declared
        contract=train_contract(notes="vmap HWA inner step"))


def _resolved_k_axes(rules: ShardingRules, K: int, topology: SyncTopology
                     ) -> tuple[str, ...]:
    """The mesh axes the rules actually shard the stacked K dim over,
    checked against the topology's replica axes (ORDER included — the
    two-level tree's 0-ULP composition needs pod-major, i.e. contiguous-
    pod, sharding of the K dim). May be empty for a Flat topology whose
    rules keep the stack device-local (K resident per device, no psum);
    a TwoLevel topology REQUIRES the sharding — without it there are no
    inner groups to reduce over."""
    k_entry = rules.spec(("replica",), (K,))
    k_axes = _norm_entry(k_entry[0] if len(k_entry) else None)
    if k_axes and k_axes != topology.replica_axes:
        raise ValueError(
            f"rules shard the stacked K dim over {k_axes} but the sync "
            f"topology expects {topology.replica_axes}; build the rules "
            f"with make_tp_rules(mesh, replica_axis="
            f"{topology.replica_axes!r})")
    if not k_axes and isinstance(topology, TwoLevel):
        raise ValueError(
            "two-level sync needs the stacked K dim sharded over "
            f"{topology.replica_axes}; build the rules with "
            f"make_tp_rules(mesh, replica_axis={topology.replica_axes!r})")
    return k_axes


def _check_outer_every(hwa_cfg: HWAConfig, topology: SyncTopology) -> None:
    """One source of truth for H₂: the driver schedules off
    ``topology.is_outer`` while ``HWAConfig.outer_every`` rides along in
    config records/checkpoints — refuse silently-disagreeing values."""
    if isinstance(topology, TwoLevel):
        if hwa_cfg.outer_every != topology.outer_every:
            raise ValueError(
                f"HWAConfig.outer_every={hwa_cfg.outer_every} disagrees "
                f"with TwoLevel.outer_every={topology.outer_every}; set "
                "both from the same value (the driver schedules off the "
                "topology)")
    elif hwa_cfg.outer_every != 1:
        raise ValueError(
            f"HWAConfig.outer_every={hwa_cfg.outer_every} would be "
            "silently ignored: this sync path is flat (every sync is "
            "outer). Use make_mesh_hwa_sync_step with a TwoLevel "
            "topology for the H·H₂ hierarchy, or leave outer_every at 1")


def _window_io(mesh: Mesh, spec, window: int, ring_dtype):
    """Ordered window-state slots of a sync bundle's argument list:
    ``(name, abstract, pspec, sharding)`` rows for ``ring``, the fp8
    ring's per-block ``scales`` (right after the ring it describes),
    ``total``, and the compressed ring's Kahan ``comp`` (right after the
    total it compensates). The f32 default contributes exactly the
    historical ``(ring, total)`` pair — THE one place the compressed
    argument ordering lives (``plan.window_state_args`` allocates real
    buffers in the same order)."""
    from repro.common.packing import window_aux_buffers, window_buffers
    ring_abs, total_abs = window_buffers(spec, window, ring_dtype,
                                         make=jax.ShapeDtypeStruct)
    scales_abs, comp_abs = window_aux_buffers(spec, window, ring_dtype,
                                              make=jax.ShapeDtypeStruct)
    rows = [("ring", ring_abs, _packed_pspecs(spec, 1),
             _packed_shardings(mesh, spec, lead_dims=1))]
    if scales_abs is not None:
        # (I, padded // align) shards over the same super-axis as the
        # ring: segment lengths are ALIGN multiples, so the per-shard
        # block counts divide exactly
        rows.append(("scales", scales_abs, _packed_pspecs(spec, 1),
                     _packed_shardings(mesh, spec, lead_dims=1)))
    rows.append(("total", total_abs, _packed_pspecs(spec),
                 _packed_shardings(mesh, spec)))
    if comp_abs is not None:
        rows.append(("comp", comp_abs, _packed_pspecs(spec),
                     _packed_shardings(mesh, spec)))
    return rows


def _precision_tokens(tok: str) -> tuple[str, ...]:
    """Allowed HLO dtype tokens for a precision token: what a bundle's
    floating args may be (ring storage) or its collective payloads may
    carry (comms) — always f32 plus the compressed dtype, if any."""
    from repro.common.quant import HLO_TOKENS
    extra = HLO_TOKENS[tok]
    return ("f32",) if extra == "f32" else ("f32", extra)


def _make_hwa_sync_step(lm: LM, rules: ShardingRules, hwa_cfg: HWAConfig,
                        ring_dtype=jnp.float32,
                        mesh_resident: bool | None = None) -> StepBundle:
    """Synchronization + window update: the once-per-H-steps collective.

    outer = mean over the replica axis (one all-reduce across pods);
    inner ← broadcast(outer); slide-window update on PACKED state: the
    ring is one (I, P) buffer and the total one (P,) buffer over the whole
    parameter set (``repro.common.packing``), held packed across the jit
    boundary so the donation of ring/total is a true in-place update
    step-to-step — no per-leaf launches, no per-call padding.

    Unlike the mesh-native builders below, the stacked K dim here may be
    LARGER than its mesh axis (several replicas resident per device);
    the local partial sums use the canonical halving order
    (``core.online.halving_sum_axis0``), which is what makes this flat
    path bit-comparable to the two-level composition.

    **pack_spec contract.** ``bundle.pack_spec`` is the layout the caller
    MUST allocate the window buffers from — ``ring = zeros((I,
    spec.padded), ring_dtype)``, ``total = zeros((spec.padded,), f32)`` —
    and the layout W̿/checkpointed state are expressed in. It is not
    always the default contiguous layout: the mesh-resident path below
    chooses a shard-aware layout (``spec.shards > 1``) whose ``padded``
    differs, so callers must never substitute their own
    ``pack_spec(params)``. Leaf views come back via ``packing.unpack(buf,
    bundle.pack_spec)``; checkpoints written through
    ``checkpoint.save_window_state`` record the layout and repack on load
    when it changed.

    **Donation invariants.** args 0-2 (stacked inner, ring, total) are
    donated: the caller's arrays are consumed every call and the returned
    buffers must be threaded into the next call (the trainer's steady
    state — this is what makes the ring update truly in place). Scalars
    (count, next_idx) are not donated.

    **Kernel gating / mesh residency.** On a single device the fused
    Pallas path runs as-is. On a multi-device mesh a bare ``pallas_call``
    is opaque to the GSPMD partitioner — XLA runs it per-shard with
    GLOBAL-shape semantics and silently corrupts values — so multi-device
    meshes default to the MESH-RESIDENT path: the whole sync runs inside
    a fully-manual ``shard_map`` where each device assembles and updates
    its local ``(I, P/shards)`` slice of a shard-aware packed layout
    (zero assembly collectives; see ``packed._local_packed_sync``),
    driving the Pallas kernel on true local shapes when ``use_kernels``
    and the jnp reference otherwise. Mixed tilings (FSDP's data/model
    splits, multi-dim placements included) take the GROUPED layout
    (``packed.choose_resident_spec`` → ``PackSpec.groups``): ring/total
    become PER-GROUP buffer tuples — allocate them with
    ``packing.window_buffers(bundle.pack_spec, I)`` — each sharded over
    its group's own super-axis, updated by one kernel launch per group,
    still with exactly one replica all-reduce and zero assembly
    collectives. The legacy GSPMD fallback (``launch.sync.legacy``) is
    now an explicitly-requested escape hatch (``mesh_resident=False``) or
    the last resort for layouts even the grouped chooser cannot align
    (zero-size leaves, params sharded over replica axes, indivisible
    tiles) — it pays one param-size assembly all-reduce per sync, and on
    multi-device CPU meshes it is a HARD ERROR (XLA 0.4.37's CPU
    partitioner miscompiles it; ``REPRO_ALLOW_LEGACY_ASSEMBLY=1``
    downgrades to a warning for HLO-introspection-only callers).
    ``mesh_resident`` forces the choice (True raises if no layout
    qualifies); None picks automatically.

    Variants (EXPERIMENTS.md §Perf pair 3): exact f32 ring (paper),
    compressed bf16/fp8 rings (``ring_dtype`` token or dtype — 2×/~4×
    window-HBM saving, Kahan-compensated f32 total, fp8 with per-block
    scales; the extra ``scales``/``comp`` args slot in as
    ``(inner, ring, [scales], total, [comp], count, next_idx)``), or
    hwa_cfg.window_kind == "streaming" (O(1) extra copies,
    windowed-running-mean approximation; always the jnp path — it is a
    two-pass rescale, not ring-shaped).
    """
    from repro.common.quant import is_compressed, wa_dtype, wa_token
    K = hwa_cfg.n_replicas
    I = hwa_cfg.window
    mesh = rules.mesh
    ring_dtype = wa_dtype(ring_dtype)
    tok = wa_token(ring_dtype)
    # this stacked/vmap path is flat-only; refuse a silently-ignored H₂
    _check_outer_every(hwa_cfg, Flat())
    streaming = hwa_cfg.window_kind == "streaming"
    use_kernel = hwa_cfg.use_kernels and mesh.size == 1
    params_abs, param_dims = lm.abstract()
    stacked_abs = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((K,) + s.shape, s.dtype), params_abs)
    stacked_dims = _prefix_dims(param_dims, "replica")
    scalar_i = jax.ShapeDtypeStruct((), jnp.int32)

    pspec_tree = rules.tree_specs(params_abs, param_dims)
    flat_specs = jax.tree.leaves(pspec_tree)
    flat_shapes = [tuple(l.shape) for l in jax.tree.leaves(params_abs)]
    k_entry = rules.spec(("replica",), (K,))
    k_axes = _norm_entry(k_entry[0] if len(k_entry) else None)
    spec = choose_resident_spec(mesh, params_abs, flat_specs, flat_shapes,
                                exclude=k_axes)
    if mesh_resident is None:
        mesh_resident = (mesh.size > 1 and not streaming
                         and spec is not None)
    if mesh_resident and (spec is None or streaming):
        raise ValueError("mesh-resident sync needs a ring window and "
                         "leaf tilings that align with packed ranges "
                         "(no single-super-axis OR grouped layout found)")

    if mesh_resident:
        resilient = hwa_cfg.resilient
        if is_compressed(tok):
            spec = spec.with_ring_dtype(ring_dtype)
        io = _window_io(mesh, spec, I, ring_dtype)
        names = [n for n, _, _, _ in io]
        has_scales = "scales" in names
        has_comp = "comp" in names
        stacked_pspecs = rules.tree_specs(stacked_abs, stacked_dims)
        # health stats are replicated over every non-replica axis the
        # params are NOT sharded over; psum over the sharded ones and let
        # health_scale cancel the replication overcount (packed.py doc).
        health_axes = tuple(a for a in mesh.axis_names
                            if a not in k_axes and mesh.shape[a] > 1)
        health_scale = math.prod(mesh.shape[a] for a in health_axes) or 1
        body = functools.partial(_local_packed_sync, hwa_cfg,
                                 spec.local_spec(), K, (k_axes,),
                                 hwa_cfg.use_kernels, False,
                                 health_axes=health_axes if resilient else (),
                                 health_scale=health_scale)

        def local_step(*args):
            it = iter(args)
            inner, ring = next(it), next(it)
            scales = next(it) if has_scales else None
            total = next(it)
            comp = next(it) if has_comp else None
            count, next_idx = next(it), next(it)
            r = body(inner, ring, total, count, next_idx,
                     jnp.zeros((), jnp.int32), scales, comp)
            out = [r[0], r[1]]
            if has_scales:
                out.append(r[2])
            out.append(r[3])
            if has_comp:
                out.append(r[4])
            out += [r[5], r[6], r[7]]
            if resilient:
                out.append(r[9])
            return tuple(out)

        alive_spec = (P(_axes_entry(k_axes)),) if resilient else ()
        win_pspecs = tuple(p for _, _, p, _ in io)
        step = shard_map(
            local_step, mesh,
            in_specs=(stacked_pspecs, *win_pspecs, P(), P()),
            out_specs=(stacked_pspecs, *win_pspecs, P(), P(), pspec_tree,
                       *alive_spec),
            check_rep=False)
        p_sh = rules.tree_shardings(stacked_abs, stacked_dims)
        w_sh = rules.tree_shardings(params_abs, param_dims)
        win_sh = tuple(s for _, _, _, s in io)
        s_sh = NamedSharding(mesh, P())
        alive_sh = (tuple(NamedSharding(mesh, s) for s in alive_spec)
                    if resilient else ())
        k_local = (K // math.prod(mesh.shape[a] for a in k_axes)
                   if k_axes else K)
        budget = packed_sync_launch_budget(
            hwa_cfg, use_kernel=hwa_cfg.use_kernels,
            n_groups=spec.n_groups, k_local=k_local,
            collective=bool(k_axes), with_stride=False, ring_dtype=tok)
        if resilient:
            # two replica-level all-reduces (k_alive, then the masked
            # weight psum — the inv data dependency keeps XLA from
            # merging them) plus one health-stats psum over the
            # non-replica axes when any exist.
            contract = sync_contract(
                k_axes, launches=budget,
                n_collectives=2 if k_axes else 0,
                other_ops={"all-reduce": 1} if health_axes else None,
                float_args=_precision_tokens(tok),
                notes="flat vmap-path sync, mesh-resident, resilient "
                      "(alive-masked mean)")
        else:
            contract = sync_contract(
                k_axes, launches=budget,
                n_collectives=1 if k_axes else 0,
                float_args=_precision_tokens(tok),
                notes="flat vmap-path sync, mesh-resident")
        return StepBundle(
            fn=step,
            abstract_args=(stacked_abs, *(a for _, a, _, _ in io),
                           scalar_i, scalar_i),
            in_shardings=(p_sh, *win_sh, s_sh, s_sh),
            out_shardings=(p_sh, *win_sh, s_sh, s_sh, w_sh, *alive_sh),
            donate_argnums=tuple(range(1 + len(io))), pack_spec=spec,
            contract=contract)

    if hwa_cfg.resilient:
        raise ValueError("resilient HWA requires the mesh-resident packed "
                         "sync path (the legacy GSPMD fallback has no "
                         "alive-masked formulation); use a layout the "
                         "packed chooser accepts or the core hwa_sync")
    check_legacy_assembly(mesh)
    return make_legacy_sync_step(lm, rules, hwa_cfg, ring_dtype, use_kernel)


# ----------------------------------------------- mesh-native HWA (shard_map)
#
# Same storage layout as the vmap path — stacked (K, ...) state with the
# leading dim sharded over the ``replica`` mesh axis (or jointly over the
# ``(pod, replica)`` pair of a two-level topology) — but the step runs
# under shard_map *manual* over those axes (data/model stay auto/GSPMD):
# each replica block squeezes its (1, ...) slice and steps locally, so the
# lowered inner-step HLO provably contains no collective crossing the
# replica axes, and hwa_sync is the topology's psum composition. That
# makes the paper's H-fold inter-replica communication amortization a
# structural property of the program rather than a GSPMD-propagation
# accident.


def _squeeze0(tree):
    return jax.tree.map(lambda x: x[0], tree)


def _expand0(tree):
    return jax.tree.map(lambda x: x[None], tree)


def _make_mesh_hwa_train_step(lm: LM, rules: ShardingRules, batch_specs,
                              batch_dims, hwa_cfg: HWAConfig,
                              optimizer: str = "adamw", lr: float = 3e-4,
                              opt_rules: ShardingRules | None = None,
                              replica_axis: str | tuple[str, ...] = "replica"
                              ) -> StepBundle:
    """Mesh-native inner HWA step.

    Collective-free over ``replica_axis`` by construction (shard_map keeps
    the replica blocks independent; the only collectives GSPMD may insert
    live inside a block, over the data/model axes). ``replica_axis`` may
    name several mesh axes jointly — a two-level topology's ``(pod,
    replica)`` — in which case the step is collective-free over ALL of
    them: the tree changes nothing about the inner step, only about the
    sync. Returns per-replica losses as a (K,) array sharded over the
    replica axes — averaging them to a replicated scalar would itself be
    a replica collective, so the caller takes the mean after fetching.

    With ``lm.cfg.attn_impl == "flash_pallas"`` the step runs under a
    FULLY-manual shard_map instead (every axis manual — Pallas kernels
    are opaque to GSPMD, see the inline comment), with data parallelism
    as an explicit grad pmean and an exact Pallas LaunchBudget
    (1 attention fwd + 2 bwd sweeps per layer) in the contract when
    remat is off.
    """
    from repro.launch.sync.topology import _norm_axes

    opt = _mk_optimizer(optimizer)
    K = hwa_cfg.n_replicas
    mesh = rules.mesh
    rep_axes = _norm_axes(replica_axis)
    rep_entry = rep_axes[0] if len(rep_axes) == 1 else rep_axes
    assert all(a in mesh.shape for a in rep_axes), (rep_axes, mesh.shape)
    rep_size = math.prod(mesh.shape[a] for a in rep_axes)
    assert K == rep_size, \
        f"mesh-native path needs K == replica-axes size ({K} != " \
        f"{rep_size} over {rep_axes}); use the vmap path otherwise"
    auto = frozenset(a for a in mesh.axis_names if a not in rep_axes)
    if not lm.cfg.scan_unroll:
        # XLA (0.4.x) fatals on a while loop under manual-subgroup
        # shardings; unrolling the layer scan keeps the body loop-free.
        from repro.models.registry import build_model
        lm = build_model(lm.cfg.with_(scan_unroll=True))
    params_abs, param_dims = lm.abstract()
    stacked_abs = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((K,) + s.shape, s.dtype), params_abs)
    stacked_dims = _prefix_dims(param_dims, "replica")
    opt_abs = jax.eval_shape(lambda p: jax.vmap(opt.init)(p), stacked_abs)
    o_dims = opt_state_dims(opt_abs, stacked_dims)
    if "count" in o_dims:
        o_dims["count"] = ("replica",)
    opt_rules = opt_rules or rules
    kbatch_abs = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((K,) + s.shape, s.dtype), batch_specs)
    kbatch_dims = _prefix_dims(batch_dims, "replica")

    # The body runs the model's pure-jnp path (rules=None): the rules-aware
    # path opens nested shard_maps (vocab-sharded gather, EP MoE) which 0.4.x
    # cannot nest inside a partial-auto map. Layouts over the auto axes are
    # still driven by the jit in/out shardings; constraints are hints only,
    # so the math is unchanged.
    def loss_fn(params, batch):
        return lm.loss(params, batch, rules=None)

    if lm.cfg.attn_impl == "flash_pallas":
        # Fully-manual variant: a bare pallas_call is OPAQUE to the GSPMD
        # partitioner — under the partial-auto map below XLA would run
        # the attention kernel per-shard with global-shape semantics and
        # silently corrupt values (the same playbook as the mesh-resident
        # sync, launch/sync/packed.py). So the flash-pallas train step
        # goes manual over EVERY mesh axis: the kernel sees true local
        # shapes, data parallelism becomes an explicit grad/loss pmean
        # over the data axes, and the model axis is redundantly
        # replicated (DP-only — TP sharding of the attention kernel is a
        # ROADMAP item). Params/opt live replicated over the non-replica
        # axes at rest, matching the manual specs (no boundary reshard).
        data_axes = tuple(a for a in rules.rules.get("batch", ())
                          if a in mesh.shape and a not in rep_axes)
        data_size = math.prod(mesh.shape[a] for a in data_axes)
        per_rep_b = jax.tree.leaves(batch_specs)[0].shape[0]
        assert not data_axes or per_rep_b % data_size == 0, \
            f"per-replica batch {per_rep_b} must divide over the data " \
            f"axes {data_axes} (size {data_size}) for the fully-manual " \
            f"flash-pallas step"
        data_entry = (data_axes if len(data_axes) > 1
                      else (data_axes[0] if data_axes else None))

        def local_step(inner, inner_opt, batch):
            params, opt_state = _squeeze0(inner), _squeeze0(inner_opt)
            (loss, _), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, _squeeze0(batch))
            if data_axes:
                grads = jax.lax.pmean(grads, data_axes)
                loss = jax.lax.pmean(loss, data_axes)
            updates, opt_state = opt.update(grads, opt_state, params, lr)
            return (_expand0(apply_updates(params, updates)),
                    _expand0(opt_state), loss[None])

        batch_pspecs = jax.tree.map(
            lambda _: (P(rep_entry, data_entry) if data_entry is not None
                       else P(rep_entry)), kbatch_abs)
        step = shard_map(
            local_step, mesh,
            in_specs=(stacked_replica_specs(stacked_abs, rep_entry),
                      stacked_replica_specs(opt_abs, rep_entry),
                      batch_pspecs),
            out_specs=(stacked_replica_specs(stacked_abs, rep_entry),
                       stacked_replica_specs(opt_abs, rep_entry),
                       P(rep_entry)),
            check_rep=False)
        to_sh = lambda specs: jax.tree.map(
            lambda s: NamedSharding(mesh, s), specs)
        p_sh = to_sh(stacked_replica_specs(stacked_abs, rep_entry))
        o_sh = to_sh(stacked_replica_specs(opt_abs, rep_entry))
        b_sh = to_sh(batch_pspecs)
        # Structural budget: the layer scan (unroll=True) is ONE jaxpr
        # eqn whose body holds 1 attention fwd + 2 recompute-bwd
        # launches, so the jaxpr count is 3 at any depth; the compiled
        # HLO carries the physical 3 × n_layers custom calls
        # (tests/mesh_hwa_check.py asserts both). Exact only when remat
        # is off (remat re-runs forwards inside the backward).
        launches = 3 if lm.cfg.remat == "none" else None
        return StepBundle(
            fn=step, abstract_args=(stacked_abs, opt_abs, kbatch_abs),
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, NamedSharding(mesh, P(rep_entry))),
            donate_argnums=(0, 1),
            contract=train_contract(
                replica_axes=rep_axes, launches=launches,
                notes="mesh-native HWA inner step, flash-pallas "
                      "attention (fully-manual, DP over data axes)"))

    def local_step(inner, inner_opt, batch):
        params, opt_state, loss, _ = hwa_local_inner_step(
            _squeeze0(inner), _squeeze0(inner_opt), _squeeze0(batch),
            loss_fn, opt, lr)
        return _expand0(params), _expand0(opt_state), loss[None]

    step = shard_map(
        local_step, mesh,
        in_specs=(stacked_replica_specs(stacked_abs, rep_entry),
                  stacked_replica_specs(opt_abs, rep_entry),
                  stacked_replica_specs(kbatch_abs, rep_entry)),
        out_specs=(stacked_replica_specs(stacked_abs, rep_entry),
                   stacked_replica_specs(opt_abs, rep_entry),
                   P(rep_entry)),
        check_rep=False, auto=auto)

    p_sh = rules.tree_shardings(stacked_abs, stacked_dims)
    o_sh = opt_rules.tree_shardings(opt_abs, o_dims)
    b_sh = rules.tree_shardings(kbatch_abs, kbatch_dims)
    losses_sh = NamedSharding(mesh, P(rep_entry))
    return StepBundle(
        fn=step, abstract_args=(stacked_abs, opt_abs, kbatch_abs),
        in_shardings=(p_sh, o_sh, b_sh),
        out_shardings=(p_sh, o_sh, losses_sh),
        donate_argnums=(0, 1),
        # THE amortization claim: zero collectives cross the replica
        # axes in the inner step (checked structurally by hwa-lint)
        contract=train_contract(replica_axes=rep_axes,
                                notes="mesh-native HWA inner step"))


def _mesh_resident_pack(lm, rules, topology):
    """Shared prologue of the mesh-native sync builders: abstract trees,
    the shard-aware packed layout — single-super-axis or grouped, or None
    when even the grouped chooser cannot align the tilings — and the
    sharding trees."""
    params_abs, param_dims = lm.abstract()
    K = math.prod(rules.mesh.shape[a] for a in topology.replica_axes)
    stacked_abs = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((K,) + s.shape, s.dtype), params_abs)
    stacked_dims = _prefix_dims(param_dims, "replica")
    pspec_tree = rules.tree_specs(params_abs, param_dims)
    flat_specs = jax.tree.leaves(pspec_tree)
    flat_shapes = [tuple(l.shape) for l in jax.tree.leaves(params_abs)]
    spec = choose_resident_spec(rules.mesh, params_abs, flat_specs,
                                flat_shapes,
                                exclude=topology.replica_axes)
    return (params_abs, param_dims, stacked_abs, stacked_dims, pspec_tree,
            spec)


def _make_mesh_hwa_sync_step(lm: LM, rules: ShardingRules,
                             hwa_cfg: HWAConfig,
                             ring_dtype=jnp.float32,
                             replica_axis: str = "replica",
                             mesh_resident: bool | None = None,
                             topology: SyncTopology | None = None,
                             comms_dtype: str = "f32") -> StepBundle:
    """Mesh-native synchronization: the once-per-H-steps collective(s).

    **Mesh-resident path (default).** The ENTIRE sync — packed-W̄
    assembly, the weight all-reduce(s), the slide-window push, the W̿
    unpack — runs inside ONE fully-manual ``shard_map`` over every mesh
    axis (``packed._local_packed_sync``). The window state lives in a
    shard-aware packed layout (``packed._mesh_resident_layout`` aligns
    each leaf's tiling with its packed range), so each device assembles
    its own ``(I, P/shards)`` ring slice from its local leaf shards,
    psums the pre-scaled partial mean over the topology's replica axes,
    and runs the window push locally: with ``use_kernels`` that is the
    Pallas kernel on true local shapes, which GSPMD could never be
    trusted with (it runs opaque custom calls per-shard with global-shape
    semantics). tests/mesh_hwa_check.py asserts the structure on the
    lowered HLO via ``launch.hlo.sync_collective_audit``.

    **Topology.** ``topology`` selects WHERE the mean reduces
    (``launch.sync.topology``): ``Flat`` (default; one all-reduce over
    ``replica_axis``) or ``TwoLevel(inner_axis, outer_axis,
    outer_every)``. For ``TwoLevel`` this builder returns the OUTER sync
    bundle — the grouped psum composition (per-pod psum, then the
    cross-pod all-reduce) + window push, bit-identical (0 ULP) to the
    flat K-replica mean for power-of-two pod/member counts — and
    :func:`make_mesh_hwa_inner_sync_step` builds the cheap pod-internal
    restart that runs on the other ``outer_every - 1`` of every
    ``outer_every`` syncs. Audit contract per level: the inner sync's
    single all-reduce crosses ONLY the inner groups; the outer sync adds
    exactly one cross-pod all-reduce on top.

    Going fully manual also sidesteps the XLA 0.4.x partial-auto caveat
    that previously forced the window push OUTSIDE the manual region:
    partial-auto manual subgroups miscompile packed-buffer assembly from
    auto-sharded leaves (a spurious replica-axis reduction doubles the
    values — the same IsManualSubgroup bug class as the scan_unroll item;
    see ROADMAP "partial-auto on new JAX"/"scan under manual subgroups").
    With no auto axes in the sync map there is no subgroup to miscompile.

    **Grouped layouts (FSDP).** Mixed tilings — leaves sharded over
    different axis sets, multi-dim data×model placements included — no
    longer fall back: ``packed.choose_resident_spec`` returns a GROUPED
    ``PackSpec`` whose window state is a PER-GROUP buffer tuple
    (allocate with ``packing.window_buffers(bundle.pack_spec, I)``),
    each group sharded over its own super-axis and pushed by its own
    kernel launch (≤ n_groups pallas_calls), with the weight all-reduce
    still computed ONCE over the concatenated local partials — the audit
    contract (one replica all-reduce, zero assembly collectives) is
    unchanged.

    **Fallback.** The legacy split (``launch.sync.legacy``) survives only
    as an explicitly-requested escape hatch (``mesh_resident=False``) or
    for layouts even the grouped chooser cannot align (zero-size leaves,
    params sharded over replica axes, indivisible tiles): pmean inside a
    partial-auto shard_map, window push outside in GSPMD-land — Flat
    only, one param-size masked all-reduce per sync, and a HARD ERROR on
    multi-device CPU meshes where XLA 0.4.37 miscompiles the assembly
    (``REPRO_ALLOW_LEGACY_ASSEMBLY=1`` downgrades to a warning).
    ``mesh_resident`` forces the choice (True raises if no layout
    qualifies); None picks automatically.

    **pack_spec contract.** Callers allocate the window buffers from
    ``bundle.pack_spec`` — ``ring = zeros((I, spec.padded), ring_dtype)``,
    ``total = zeros((spec.padded,), f32)`` — and read leaf views with
    ``packing.unpack(buf, bundle.pack_spec)``. The mesh-resident layout's
    ``padded`` includes per-segment alignment and replicated-leaf
    duplicates, so it is NOT interchangeable with ``pack_spec(params)``;
    checkpoints written via ``checkpoint.save_window_state`` record the
    layout and repack bit-exactly on load under a different mesh.

    **Donation invariants.** every window-state buffer (stacked inner,
    ring, the fp8 ring's scales, total, the compressed ring's Kahan comp)
    is donated — thread the returned buffers into the next call; the
    scalar counters (count, next_idx, cycle) are returned fresh, not
    donated.

    **Precision.** ``ring_dtype`` compresses the window STORAGE (bf16 or
    block-scaled fp8 ring; f32 total with Kahan compensation — the
    ``scales``/``comp`` args slot in as ``(inner, ring, [scales], total,
    [comp], count, next_idx, cycle)``). ``comms_dtype`` compresses the
    two-level tree's CROSS-POD hop only: the quantized partial is
    all-gathered as a same-width integer bit-view (bf16→u16; fp8→u8
    plus its f32 per-block scales — an fp8 all-reduce would ACCUMULATE
    in fp8) and reduced locally with an f32 halving-sum; the bit-view
    keeps XLA's float normalization from widening the wire payload on
    backends without native narrow-float collectives. The pod-internal
    psum stays f32 either way, so
    the inner tree level keeps its 0-ULP halving composition. Requires a
    TwoLevel topology and is mutually exclusive with ``resilient`` (the
    alive-masked mean renormalizes by k_alive after the psum — the
    quantized payload would be scaled before the mask is known). The f32
    defaults leave both paths bit-identical to the uncompressed bundles.
    """
    from repro.common.quant import is_compressed, wa_dtype, wa_token
    K = hwa_cfg.n_replicas
    I = hwa_cfg.window
    mesh = rules.mesh
    ring_dtype = wa_dtype(ring_dtype)
    tok = wa_token(ring_dtype)
    comms_tok = wa_token(comms_dtype)
    topology = topology if topology is not None else Flat(replica_axis)
    topology.validate(mesh, K)
    if comms_tok != "f32":
        if not isinstance(topology, TwoLevel):
            raise ValueError(
                "compressed comms quantize the two-level tree's cross-pod "
                "hop; a Flat sync has no outer level to compress (its one "
                "all-reduce IS the mean — quantizing it would quantize "
                f"the paper's W̄). Got comms_dtype={comms_tok!r} with "
                f"topology {topology!r}")
        if hwa_cfg.resilient:
            raise ValueError(
                "resilient + compressed comms is unsupported: the "
                "alive-masked mean renormalizes by k_alive after the "
                "psum, so the quantized payload would be scaled before "
                "the mask is known")
    _check_outer_every(hwa_cfg, topology)
    k_axes = _resolved_k_axes(rules, K, topology)
    # Flat keeps the original contract: psum over whatever axes the rules
    # shard the stack over (none → K device-local, collective-free sync).
    # TwoLevel reduces by the topology's inner-then-outer composition.
    psum_groups = (topology.psum_groups()
                   if isinstance(topology, TwoLevel) else (k_axes,))
    scalar_i = jax.ShapeDtypeStruct((), jnp.int32)
    (params_abs, param_dims, stacked_abs, stacked_dims, pspec_tree,
     spec) = _mesh_resident_pack(lm, rules, topology)
    p_sh = rules.tree_shardings(stacked_abs, stacked_dims)
    w_sh = rules.tree_shardings(params_abs, param_dims)
    s_sh = NamedSharding(mesh, P())

    if mesh_resident is None:
        mesh_resident = spec is not None
    elif mesh_resident and spec is None:
        raise ValueError("mesh-resident sync: leaf tilings do not align "
                         "with any packed super-axis or grouped layout")
    if not mesh_resident and isinstance(topology, TwoLevel):
        raise ValueError("the two-level sync tree requires the "
                         "mesh-resident packed path (no legacy GSPMD "
                         "formulation of grouped psums exists)")

    if mesh_resident:
        resilient = hwa_cfg.resilient
        stacked_pspecs = rules.tree_specs(stacked_abs, stacked_dims)
        if is_compressed(tok):
            spec = spec.with_ring_dtype(ring_dtype)
        io = _window_io(mesh, spec, I, ring_dtype)
        names = [n for n, _, _, _ in io]
        has_scales = "scales" in names
        has_comp = "comp" in names
        rep_axes = tuple(topology.replica_axes)
        health_axes = tuple(a for a in mesh.axis_names
                            if a not in rep_axes and mesh.shape[a] > 1)
        health_scale = math.prod(mesh.shape[a] for a in health_axes) or 1
        body = functools.partial(_local_packed_sync, hwa_cfg,
                                 spec.local_spec(), K, psum_groups,
                                 hwa_cfg.use_kernels, True,
                                 comms_dtype=comms_tok,
                                 health_axes=health_axes if resilient else (),
                                 health_scale=health_scale)

        def local_step(*args):
            it = iter(args)
            inner, ring = next(it), next(it)
            scales = next(it) if has_scales else None
            total = next(it)
            comp = next(it) if has_comp else None
            count, next_idx, cycle = next(it), next(it), next(it)
            r = body(inner, ring, total, count, next_idx, cycle,
                     scales, comp)
            out = [r[0], r[1]]
            if has_scales:
                out.append(r[2])
            out.append(r[3])
            if has_comp:
                out.append(r[4])
            out += [r[5], r[6], r[7], r[8]]
            if resilient:
                out.append(r[9])
            return tuple(out)

        alive_spec = (P(_axes_entry(k_axes)),) if resilient else ()
        win_pspecs = tuple(p for _, _, p, _ in io)
        step = shard_map(
            local_step, mesh,
            in_specs=(stacked_pspecs, *win_pspecs, P(), P(), P()),
            out_specs=(stacked_pspecs, *win_pspecs, P(), P(), pspec_tree,
                       P(), *alive_spec),
            check_rep=False)
        win_sh = tuple(s for _, _, _, s in io)
        alive_sh = (tuple(NamedSharding(mesh, s) for s in alive_spec)
                    if resilient else ())
        psum_axes = tuple(a for g in psum_groups for a in g)
        k_local = (K // math.prod(mesh.shape[a] for a in psum_axes)
                   if psum_axes else K)
        budget = packed_sync_launch_budget(
            hwa_cfg, use_kernel=hwa_cfg.use_kernels,
            n_groups=spec.n_groups, k_local=k_local,
            collective=any(psum_groups), with_stride=True,
            ring_dtype=tok)
        float_args = _precision_tokens(tok)
        coll_dtypes = _precision_tokens(comms_tok)
        if comms_tok != "f32":
            # The compressed cross-pod payload crosses the wire as a
            # same-width integer bit-view (bf16→u16, e4m3fn→u8): XLA's
            # float-normalization pass on backends without native
            # narrow-float collectives (CPU included) would otherwise
            # widen the payload back (bf16 all-reduce → f32 promotion,
            # fp8 gather → f16), silently restoring the full wire bytes.
            coll_dtypes = coll_dtypes + (
                "u16" if comms_tok == "bf16" else "u8",)
        # Resilient doubles each level's replica collectives: k_alive
        # first, then the masked weight psum (the inv dependency chains
        # them so the AllReduceCombiner cannot merge); the health-stats
        # psum crosses only the non-replica axes and is budgeted as an
        # `other_ops` exception rather than loosening the level counts.
        other = ({"all-reduce": 1} if (resilient and health_axes)
                 else None)
        if isinstance(topology, TwoLevel):
            # Compressed comms replace the outer all-reduce with
            # all-gathers + a local f32 halving-sum: one u16 gather for
            # bf16, a u8 payload + f32 per-block scales pair for fp8.
            outer_ops = ({"all-gather": 2} if comms_tok == "fp8" else
                         {"all-gather": 1} if comms_tok == "bf16" else
                         {"all-reduce": 2 if resilient else 1})
            contract = sync_contract(
                topology.inner_axis, launches=budget,
                outer_axis=topology.outer_axis,
                n_collectives=2 if resilient else 1,
                outer_ops=outer_ops,
                other_ops=other,
                collective_dtypes=coll_dtypes,
                float_args=float_args,
                notes="two-level outer sync: per-pod psum + cross-pod "
                      + ("fp8 all-gather pair" if comms_tok == "fp8"
                         else "bf16 (u16 bit-view) all-gather"
                         if comms_tok == "bf16" else "all-reduce")
                      + (", resilient (alive-masked)" if resilient else ""))
        else:
            contract = sync_contract(
                k_axes, launches=budget,
                n_collectives=(2 if resilient else 1) if k_axes else 0,
                other_ops=other,
                float_args=float_args,
                notes="mesh-native flat sync, mesh-resident"
                      + (", resilient (alive-masked)" if resilient else ""))
        return StepBundle(
            fn=step,
            abstract_args=(stacked_abs, *(a for _, a, _, _ in io),
                           scalar_i, scalar_i, scalar_i),
            in_shardings=(p_sh, *win_sh, s_sh, s_sh, s_sh),
            out_shardings=(p_sh, *win_sh, s_sh, s_sh, w_sh, s_sh,
                           *alive_sh),
            donate_argnums=tuple(range(1 + len(io))), pack_spec=spec,
            contract=contract)

    # ------- legacy fallback: partial-auto pmean + GSPMD-land window push
    if hwa_cfg.resilient:
        raise ValueError("resilient HWA requires the mesh-resident packed "
                         "sync path (the legacy GSPMD fallback has no "
                         "alive-masked formulation)")
    if len(topology.replica_axes) != 1:
        raise ValueError("the legacy GSPMD fallback handles a single "
                         f"replica axis only, got {topology.replica_axes}")
    check_legacy_assembly(mesh)
    return make_legacy_mesh_sync_step(lm, rules, hwa_cfg, ring_dtype,
                                      topology.replica_axes[0])


def _make_mesh_hwa_inner_sync_step(lm: LM, rules: ShardingRules,
                                   hwa_cfg: HWAConfig,
                                   topology: TwoLevel) -> StepBundle:
    """The two-level tree's INNER sync: pod-internal averaging + restart.

    Runs on the ``outer_every - 1`` of every ``outer_every`` syncs that
    are NOT outer (``topology.is_outer``). Each pod pmeans over its OWN
    members — one all-reduce whose explicit ``replica_groups`` pair only
    same-pod devices, zero cross-pod traffic, zero window-state traffic
    (the slide window collects global W̄ only, so ring/total/counters are
    untouched and are not even arguments here). Signature is simply
    stacked-inner → stacked-inner, with the input donated.

    Mesh-resident only: the pod mean is assembled/unpacked through the
    same shard-aware packed layout as the outer sync (one collective
    total); tilings that do not align raise, like the forced
    mesh-resident outer path.
    """
    K = hwa_cfg.n_replicas
    mesh = rules.mesh
    if not isinstance(topology, TwoLevel):
        raise ValueError("inner-only sync exists only for the TwoLevel "
                         f"topology, got {topology!r}")
    topology.validate(mesh, K)
    _check_outer_every(hwa_cfg, topology)
    _resolved_k_axes(rules, K, topology)
    (params_abs, param_dims, stacked_abs, stacked_dims, pspec_tree,
     spec) = _mesh_resident_pack(lm, rules, topology)
    if spec is None:
        raise ValueError("inner sync: leaf tilings do not align with any "
                         "packed super-axis or grouped layout "
                         "(mesh-resident only)")
    stacked_pspecs = rules.tree_specs(stacked_abs, stacked_dims)
    pod_size = K // topology.pods(mesh)
    step = shard_map(
        functools.partial(_local_inner_sync, spec.local_spec(), pod_size,
                          topology.inner_groups()),
        mesh,
        in_specs=(stacked_pspecs,),
        out_specs=stacked_pspecs,
        check_rep=False)
    p_sh = rules.tree_shardings(stacked_abs, stacked_dims)
    return StepBundle(
        fn=step, abstract_args=(stacked_abs,),
        in_shardings=(p_sh,), out_shardings=p_sh,
        donate_argnums=(0,), pack_spec=spec,
        contract=sync_contract(
            topology.inner_axis, launches=0,
            outer_axis=topology.outer_axis,
            n_collectives=1, outer_collectives=0,
            notes="two-level inner sync: one per-pod all-reduce, zero "
                  "cross-pod traffic, zero kernel launches"))


# ------------------------------------------------- deprecated flat names
#
# PR 10 collapsed the five HWA builders behind ONE declarative entry
# point: construct a ``launch.sync.plan.SyncPlan`` (topology × precision
# × resilience × kernels) and call ``build_hwa_bundles(lm, rules, plan)``.
# The historical names survive as thin wrappers so pre-plan callers keep
# working; they carry no logic of their own and will be removed once the
# last in-repo caller migrates.


def _deprecated(name: str) -> None:
    warnings.warn(
        f"{name} is deprecated: describe the configuration with a "
        "repro.launch.sync.plan.SyncPlan and call build_hwa_bundles "
        "instead", DeprecationWarning, stacklevel=3)


def make_hwa_train_step(*args, **kwargs) -> StepBundle:
    """Deprecated name for the vmap-path inner step (use
    ``plan.build_hwa_bundles``)."""
    _deprecated("make_hwa_train_step")
    return _make_hwa_train_step(*args, **kwargs)


def make_hwa_sync_step(*args, **kwargs) -> StepBundle:
    """Deprecated name for the flat stacked sync (use
    ``plan.build_hwa_bundles``)."""
    _deprecated("make_hwa_sync_step")
    return _make_hwa_sync_step(*args, **kwargs)


def make_mesh_hwa_train_step(*args, **kwargs) -> StepBundle:
    """Deprecated name for the mesh-native inner step (use
    ``plan.build_hwa_bundles``)."""
    _deprecated("make_mesh_hwa_train_step")
    return _make_mesh_hwa_train_step(*args, **kwargs)


def make_mesh_hwa_sync_step(*args, **kwargs) -> StepBundle:
    """Deprecated name for the mesh-native sync (use
    ``plan.build_hwa_bundles``)."""
    _deprecated("make_mesh_hwa_sync_step")
    return _make_mesh_hwa_sync_step(*args, **kwargs)


def make_mesh_hwa_inner_sync_step(*args, **kwargs) -> StepBundle:
    """Deprecated name for the two-level inner sync (use
    ``plan.build_hwa_bundles``)."""
    _deprecated("make_mesh_hwa_inner_sync_step")
    return _make_mesh_hwa_inner_sync_step(*args, **kwargs)
