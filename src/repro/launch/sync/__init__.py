"""Sync-topology subsystem (PR 4 split of the launch/steps.py monolith).

- ``topology`` — WHERE/WHEN the replica mean reduces: ``Flat`` (one
  global all-reduce) vs ``TwoLevel`` (pod-inner every H steps, pod-outer
  + window push every H·H₂).
- ``packed``  — the mesh-resident machinery: shard-aware layout chooser
  and the fully-manual per-device sync bodies.
- ``legacy``  — the GSPMD fallback for non-qualifying layouts, hard-
  errored on multi-device CPU meshes where XLA 0.4.37 miscompiles it.
- ``bundles`` — the StepBundle builders (train / prefill / decode / HWA
  / mesh-native HWA / two-level inner sync).
- ``plan``    — the declarative surface (PR 10): ``SyncPlan`` names the
  topology × precision × resilience × kernel combination and
  ``build_hwa_bundles`` assembles the matching ``HWABundles``. The five
  historical ``make_*hwa*_step`` names survive as deprecated wrappers.

``repro.launch.steps`` re-exports everything below, so existing imports
keep working.
"""
from repro.launch.sync.bundles import (StepBundle, make_decode_step,
                                       make_hwa_sync_step,
                                       make_hwa_train_step,
                                       make_mesh_hwa_inner_sync_step,
                                       make_mesh_hwa_sync_step,
                                       make_mesh_hwa_train_step,
                                       make_prefill_step, make_train_step,
                                       opt_state_dims)
from repro.launch.sync.legacy import (check_legacy_assembly,
                                      make_legacy_mesh_sync_step,
                                      make_legacy_sync_step)
from repro.launch.sync.plan import (HWABundles, SyncPlan, build_hwa_bundles,
                                    window_state_args)
from repro.launch.sync.topology import Flat, SyncTopology, TwoLevel

__all__ = [
    "Flat", "HWABundles", "StepBundle", "SyncPlan", "SyncTopology",
    "TwoLevel", "build_hwa_bundles", "check_legacy_assembly",
    "make_decode_step", "make_hwa_sync_step", "make_hwa_train_step",
    "make_legacy_mesh_sync_step", "make_legacy_sync_step",
    "make_mesh_hwa_inner_sync_step", "make_mesh_hwa_sync_step",
    "make_mesh_hwa_train_step", "make_prefill_step", "make_train_step",
    "opt_state_dims", "window_state_args",
]
