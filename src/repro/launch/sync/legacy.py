"""Legacy GSPMD sync fallback: the non-mesh-resident packed sync paths.

These run when the parameter tilings admit no aligned packed layout
(``packed._mesh_resident_layout`` → None, e.g. FSDP's mixed data/model
tilings) or on a single device. Packing W̄ from per-leaf (data/model)-
tiled shards into the contiguous buffer is then a real layout
redistribution that GSPMD lowers as masked contributions + ONE
param-size all-reduce spanning the whole mesh, once per sync.

**Hard error on CPU meshes.** XLA 0.4.37's CPU SPMD partitioner
MISCOMPILES that assembly pattern — replicated shards get overcounted
(~4× on the (2,2,2) test mesh), silently corrupting W̿ (it corrupted the
PR-2 mesh sync, masked by an oracle computed through the same path).
Non-CPU backends lower the same pattern correctly, so
:func:`check_legacy_assembly` raises ONLY for multi-device CPU meshes;
``REPRO_ALLOW_LEGACY_ASSEMBLY=1`` downgrades the raise to the old loud
warning for callers that only introspect the lowered HLO and never trust
the values (dry-run, the structural legs of mesh_hwa_check and
``make bench-kernels``).
"""
from __future__ import annotations

import os
import warnings

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.analysis.contracts import (BundleContract, LaunchBudget,
                                      sync_contract)
from repro.common.compat import shard_map
from repro.core.hwa import HWAConfig, window_push_packed
from repro.launch.sync.packed import _packed_sharding
from repro.models.registry import LM
from repro.sharding.rules import (ShardingRules, replicated_specs,
                                  stacked_replica_specs)

ALLOW_ENV = "REPRO_ALLOW_LEGACY_ASSEMBLY"

_MISCOMPILE_MSG = (
    "HWA sync: the legacy GSPMD packed-W̄ assembly on a multi-device CPU "
    "mesh is MISCOMPILED by XLA 0.4.37's CPU SPMD partitioner "
    "(replicated shards overcounted ~4× on the (2,2,2) test mesh) and "
    "silently corrupts W̿. Use tilings that _mesh_resident_layout can "
    "align (see docs/ARCHITECTURE.md §1), or set "
    f"{ALLOW_ENV}=1 if you only introspect the lowered HLO and never "
    "trust the computed values.")


def check_legacy_assembly(mesh: Mesh) -> None:
    """Refuse the legacy assembly where it is known to miscompile.

    Raises ``RuntimeError`` for multi-device CPU meshes unless
    ``REPRO_ALLOW_LEGACY_ASSEMBLY=1`` is set (escape hatch for
    HLO-introspection-only callers), in which case the PR-3 warning is
    kept. A no-op on single devices and non-CPU backends, where the
    pattern lowers correctly.
    """
    if mesh.size > 1 and jax.default_backend() == "cpu":
        if os.environ.get(ALLOW_ENV) == "1":
            warnings.warn(_MISCOMPILE_MSG, RuntimeWarning, stacklevel=3)
            return
        raise RuntimeError(_MISCOMPILE_MSG)


def make_legacy_sync_step(lm: LM, rules: ShardingRules, hwa_cfg: HWAConfig,
                          ring_dtype, use_kernel: bool):
    """The stacked-input sync WITHOUT mesh residency: packed mean +
    window push in GSPMD-land (single device, streaming windows, or the
    non-qualifying-layout fallback). Returns a StepBundle; see
    ``bundles.make_hwa_sync_step`` for the pack_spec/donation contract.
    """
    from repro.common.packing import pack, pack_spec, pack_stacked, unpack
    from repro.core.offline import WindowState, window_update_packed
    from repro.core.online import broadcast_to_replicas, online_average
    from repro.launch.sync.bundles import StepBundle, _prefix_dims

    K = hwa_cfg.n_replicas
    I = hwa_cfg.window
    streaming = hwa_cfg.window_kind == "streaming"
    params_abs, param_dims = lm.abstract()
    stacked_abs = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((K,) + s.shape, s.dtype), params_abs)
    stacked_dims = _prefix_dims(param_dims, "replica")
    scalar_i = jax.ShapeDtypeStruct((), jnp.int32)
    spec = pack_spec(params_abs)
    ring_abs = jax.ShapeDtypeStruct((I, spec.padded), ring_dtype)
    total_abs = jax.ShapeDtypeStruct((spec.padded,), jnp.float32)
    r_sh = _packed_sharding(rules.mesh, spec.padded, lead_dims=1)
    t_sh = _packed_sharding(rules.mesh, spec.padded)

    def mean_and_buf(inner):
        """(W̄ leaf views, packed W̄) without a pack/unpack round-trip.

        The sharding constraint pins the packed buffer to the window
        state's own sharding so the elementwise push stays shard-local
        (GSPMD otherwise computes it as distributed partial sums + a
        full-buffer all-reduce crossing every mesh axis).
        """
        if use_kernel:
            from repro.kernels import ops as kops
            buf = kops.online_mean_packed(pack_stacked(inner, spec))
            outer = unpack(buf, spec)
        else:
            outer = online_average(inner)
            buf = pack(outer, spec)
        return outer, jax.lax.with_sharding_constraint(buf, t_sh)

    def step_ring(inner, ring, total, count, next_idx):
        outer, buf = mean_and_buf(inner)
        new_inner = broadcast_to_replicas(outer, K)
        ws = WindowState(ring=ring, total=total, count=count,
                         next_idx=next_idx, window=I, kind="ring", spec=spec)
        ws2, avg = window_update_packed(ws, buf, use_kernel=use_kernel)
        wa = unpack(avg, spec)      # leaf views of W̿ (slices, no copy)
        return new_inner, ws2.ring, ws2.total, ws2.count, ws2.next_idx, wa

    def step_streaming(inner, total, count):
        outer, buf = mean_and_buf(inner)
        new_inner = broadcast_to_replicas(outer, K)
        ws = WindowState(ring=None, total=total, count=count,
                         next_idx=jnp.zeros((), jnp.int32), window=I,
                         kind="streaming", spec=spec)
        ws2, avg = window_update_packed(ws, buf)
        return new_inner, ws2.total, ws2.count, unpack(avg, spec)

    p_sh = rules.tree_shardings(stacked_abs, stacked_dims)
    w_sh = rules.tree_shardings(params_abs, param_dims)
    s_sh = NamedSharding(rules.mesh, P())
    ring_f32 = ring_dtype == jnp.float32
    float_args = ("f32",) if ring_f32 else ("f32", "bf16")
    # single device: collective-free by construction, exact launch count
    # (mean kernel + ring push kernel). Multi-device (escape-hatch only):
    # the assembly traffic makes the census layout-dependent — unchecked.
    if streaming:
        launches = 1 if use_kernel else 0
        contract = (sync_contract((), launches=launches, n_collectives=0,
                                  float_args=float_args,
                                  notes="legacy streaming sync")
                    if rules.mesh.size == 1 else
                    BundleContract(launch=LaunchBudget.exact(launches)))
        return StepBundle(
            fn=step_streaming,
            abstract_args=(stacked_abs, total_abs, scalar_i),
            in_shardings=(p_sh, t_sh, s_sh),
            out_shardings=(p_sh, t_sh, s_sh, w_sh),
            donate_argnums=(0, 1), pack_spec=spec, contract=contract)
    launches = (1 + (1 if ring_f32 else 0)) if use_kernel else 0
    contract = (sync_contract((), launches=launches, n_collectives=0,
                              float_args=float_args,
                              notes="legacy ring sync, single device")
                if rules.mesh.size == 1 else
                BundleContract(launch=LaunchBudget.exact(launches)))
    return StepBundle(
        fn=step_ring,
        abstract_args=(stacked_abs, ring_abs, total_abs, scalar_i, scalar_i),
        in_shardings=(p_sh, r_sh, t_sh, s_sh, s_sh),
        out_shardings=(p_sh, r_sh, t_sh, s_sh, s_sh, w_sh),
        donate_argnums=(0, 1, 2), pack_spec=spec, contract=contract)


def make_legacy_mesh_sync_step(lm: LM, rules: ShardingRules,
                               hwa_cfg: HWAConfig, ring_dtype,
                               replica_axis: str):
    """Mesh-native sync fallback: pmean inside a partial-auto shard_map,
    window push outside in GSPMD-land — correct on non-CPU backends, but
    the packed-W̄ assembly costs ONE param-size masked all-reduce per
    sync (the cost the mesh-resident aligned layout removes)."""
    from repro.common.packing import pack, pack_spec, unpack
    from repro.core.offline import WindowState
    from repro.core.online import broadcast_to_replicas, online_average_named
    from repro.launch.sync.bundles import (StepBundle, _prefix_dims,
                                           _squeeze0)

    K = hwa_cfg.n_replicas
    I = hwa_cfg.window
    mesh = rules.mesh
    params_abs, param_dims = lm.abstract()
    stacked_abs = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((K,) + s.shape, s.dtype), params_abs)
    stacked_dims = _prefix_dims(param_dims, "replica")
    scalar_i = jax.ShapeDtypeStruct((), jnp.int32)
    auto = frozenset(a for a in mesh.axis_names if a != replica_axis)
    spec = pack_spec(params_abs)
    ring_abs = jax.ShapeDtypeStruct((I, spec.padded), ring_dtype)
    total_abs = jax.ShapeDtypeStruct((spec.padded,), jnp.float32)

    def local_mean(inner):
        """The one inter-replica collective: W̄ = pmean(W^k)."""
        return online_average_named(_squeeze0(inner), replica_axis)

    mean_fn = shard_map(
        local_mean, mesh,
        in_specs=(stacked_replica_specs(stacked_abs, replica_axis),),
        out_specs=replicated_specs(params_abs),
        check_rep=False, auto=auto)

    r_sh = _packed_sharding(mesh, spec.padded, lead_dims=1)
    t_sh = _packed_sharding(mesh, spec.padded)

    def step(inner, ring, total, count, next_idx, cycle):
        outer = mean_fn(inner)
        new_inner = broadcast_to_replicas(outer, K)
        # Packing W̄ from per-leaf (data/model)-tiled shards into the
        # contiguous buffer is a real layout redistribution: GSPMD
        # materializes the concat as masked contributions + ONE
        # param-size all-reduce spanning the whole mesh, once per sync
        # (amortized by H; absent entirely on a single device, and
        # absent from the mesh-resident path). The constraint pins the
        # buffer to the window state's sharding so the push itself
        # stays shard-local; W̿ leaf views then slice from the
        # already-assembled buffer for free.
        buf = jax.lax.with_sharding_constraint(pack(outer, spec), t_sh)
        ws = WindowState(ring=ring, total=total, count=count,
                         next_idx=next_idx, window=I, kind="ring", spec=spec)
        # bare kernels only on a single device (Pallas is opaque to GSPMD
        # — per-shard execution with global-shape semantics corrupts
        # values); on meshes kernels require the mesh-resident path
        ws2, avg, new_cycle = window_push_packed(
            hwa_cfg, buf, ws, cycle,
            use_kernel=hwa_cfg.use_kernels and mesh.size == 1)
        wa = unpack(avg, spec)
        return (new_inner, ws2.ring, ws2.total, ws2.count, ws2.next_idx,
                wa, new_cycle)

    p_sh = rules.tree_shardings(stacked_abs, stacked_dims)
    w_sh = rules.tree_shardings(params_abs, param_dims)
    s_sh = NamedSharding(mesh, P())
    use_k = hwa_cfg.use_kernels and mesh.size == 1
    launches = 1 if use_k and ring_dtype == jnp.float32 else 0
    # the pmean + GSPMD assembly all-reduce both cross the mesh in
    # layout-dependent ways — only the launch budget and dtype baseline
    # are declared for this escape-hatch path
    return StepBundle(
        fn=step,
        abstract_args=(stacked_abs, ring_abs, total_abs, scalar_i, scalar_i,
                       scalar_i),
        in_shardings=(p_sh, r_sh, t_sh, s_sh, s_sh, s_sh),
        out_shardings=(p_sh, r_sh, t_sh, s_sh, s_sh, w_sh, s_sh),
        donate_argnums=(0, 1, 2), pack_spec=spec,
        contract=BundleContract(launch=LaunchBudget.exact(launches)))
