"""Post-SPMD HLO introspection: collective-traffic extraction + roofline.

The compiled module is the *per-device* program (verified: cost_analysis
flops ≈ global/chips). Collective results are parsed from ``as_text()``;
per-device traffic model (bytes moved over ICI per device):

    all-reduce        : 2 × result_bytes × (g-1)/g   (ring: RS + AG phases)
    all-gather        : result_bytes × (g-1)/g       (result = gathered)
    reduce-scatter    : result_bytes × (g-1)          (result = one shard)
    all-to-all        : result_bytes × (g-1)/g
    collective-permute: result_bytes

with g the participating group size parsed from ``replica_groups=[n,g]``.

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12     # bf16 per chip
HBM_BW = 819e9          # bytes/s per chip
ICI_BW = 50e9           # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s+(\(?[^=]*?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    bytes_by_op: dict
    traffic_bytes: float     # modeled per-device ICI traffic

    @property
    def total_result_bytes(self) -> float:
        return float(sum(self.bytes_by_op.values()))


def collective_stats(hlo_text: str) -> CollectiveStats:
    counts: dict = {}
    bytes_by_op: dict = {}
    traffic = 0.0
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        b = _shape_bytes(type_str)
        gm = _GROUPS_RE.search(line)
        g = int(gm.group(2)) if gm else 1
        if g <= 1:
            factor = 0.0
        elif op == "all-reduce":
            factor = 2.0 * (g - 1) / g
        elif op == "all-gather":
            factor = (g - 1) / g
        elif op == "reduce-scatter":
            factor = float(g - 1)
        elif op == "all-to-all":
            factor = (g - 1) / g
        else:  # collective-permute
            factor = 1.0
        counts[op] = counts.get(op, 0) + 1
        bytes_by_op[op] = bytes_by_op.get(op, 0) + b
        traffic += b * factor
    return CollectiveStats(counts=counts, bytes_by_op=bytes_by_op,
                           traffic_bytes=traffic)


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   traffic_bytes: float) -> dict:
    compute_s = flops_per_device / PEAK_FLOPS
    memory_s = bytes_per_device / HBM_BW
    collective_s = traffic_bytes / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    terms["dominant"] = dominant
    terms["bound_s"] = terms[dominant]
    return terms
