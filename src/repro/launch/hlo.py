"""HLO inspection — thin re-exporting facade over ``repro.analysis``.

The parsing/census monolith this module used to be was carved into the
``repro/analysis/`` static-analysis package in PR 6:

- ``analysis.hlo_text``    — instruction-level HLO parsing (the old
  regex soup, now with async ``-start``/``-done`` pairs counted once by
  their own opcode instead of a brittle substring skip), replica-group
  parsing, ``input_output_alias`` extraction, Pallas-launch counting.
- ``analysis.collectives`` — the collective census
  (:func:`collective_stats`), axis-crossing classification,
  :func:`sync_collective_audit`, roofline terms, and the generalized
  :func:`~repro.analysis.collectives.check_collective_contract`.
- ``analysis.contracts``   — declarative per-bundle contracts
  (:class:`~repro.analysis.contracts.BundleContract`) the builders
  attach and ``tools/hwa_lint.py`` checks.
- ``analysis.passes`` / ``analysis.lint`` — the pass framework and the
  hwa-lint bundle×mesh matrix.

Every name importable from here before the split still is, with
identical behavior; new code should import from ``repro.analysis``.
"""
from __future__ import annotations

from repro.analysis.collectives import (HBM_BW, ICI_BW, PEAK_FLOPS,
                                        CollectiveStats, collective_stats,
                                        collectives_crossing_axis,
                                        result_bytes, roofline_terms,
                                        sync_collective_audit)
from repro.analysis.hlo_text import (axis_coords, count_pallas_calls,
                                     parse_replica_groups)

__all__ = [
    "PEAK_FLOPS", "HBM_BW", "ICI_BW",
    "CollectiveStats", "collective_stats", "parse_replica_groups",
    "axis_coords", "collectives_crossing_axis", "result_bytes",
    "sync_collective_audit", "count_pallas_calls", "roofline_terms",
]
