"""Post-SPMD HLO introspection: collective-traffic extraction + roofline.

The compiled module is the *per-device* program (verified: cost_analysis
flops ≈ global/chips). Collective results are parsed from ``as_text()``;
per-device traffic model (bytes moved over ICI per device):

    all-reduce        : 2 × result_bytes × (g-1)/g   (ring: RS + AG phases)
    all-gather        : result_bytes × (g-1)/g       (result = gathered)
    reduce-scatter    : result_bytes × (g-1)          (result = one shard)
    all-to-all        : result_bytes × (g-1)/g
    collective-permute: result_bytes

with g the participating group size parsed from ``replica_groups=[n,g]``.

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12     # bf16 per chip
HBM_BW = 819e9          # bytes/s per chip
ICI_BW = 50e9           # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s+(\(?[^=]*?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    bytes_by_op: dict
    traffic_bytes: float     # modeled per-device ICI traffic

    @property
    def total_result_bytes(self) -> float:
        return float(sum(self.bytes_by_op.values()))


def collective_stats(hlo_text: str) -> CollectiveStats:
    counts: dict = {}
    bytes_by_op: dict = {}
    traffic = 0.0
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        b = _shape_bytes(type_str)
        gm = _GROUPS_RE.search(line)
        if gm:
            g = int(gm.group(2))
        else:
            # explicit-list groups ({{0,4},{1,5},...}) and permute pairs
            groups = parse_replica_groups(line)
            g = max((len(grp) for grp in groups), default=1) if groups else 1
        if g <= 1:
            factor = 0.0
        elif op == "all-reduce":
            factor = 2.0 * (g - 1) / g
        elif op == "all-gather":
            factor = (g - 1) / g
        elif op == "reduce-scatter":
            factor = float(g - 1)
        elif op == "all-to-all":
            factor = (g - 1) / g
        else:  # collective-permute
            factor = 1.0
        counts[op] = counts.get(op, 0) + 1
        bytes_by_op[op] = bytes_by_op.get(op, 0) + b
        traffic += b * factor
    return CollectiveStats(counts=counts, bytes_by_op=bytes_by_op,
                           traffic_bytes=traffic)


# ------------------------------------------------ replica-group structure
#
# Which mesh axes does each collective actually cross? XLA prints groups in
# two forms: explicit ``replica_groups={{0,4},{1,5}}`` and iota
# ``replica_groups=[n,g]<=[dims]`` with an optional ``T(perm)`` transpose.
# Mapping member device ids back to mesh coordinates tells us whether a
# collective crosses a given axis — the property the mesh-native HWA path
# is built around (no replica-axis traffic outside hwa_sync).

_GROUPS_LIST_RE = re.compile(r"replica_groups=\{(\{[\d,]*\}(?:,\{[\d,]*\})*)\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_PAIRS_RE = re.compile(r"source_target_pairs=\{((?:\{\d+,\d+\},?)*)\}")


def parse_replica_groups(line: str) -> list[list[int]] | None:
    """Participant groups of one HLO collective line, or None if absent.

    Members are *logical* partition indices (positions in the jit's
    device assignment, i.e. mesh.devices.flat order), not physical device
    ids. collective-permute carries source_target_pairs instead; each
    pair is returned as a two-member group.
    """
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return [[int(x) for x in g.split(",") if x]
                for g in re.findall(r"\{([\d,]*)\}", m.group(1))]
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        n, g = int(m.group(1)), int(m.group(2))
        dims = [int(d) for d in m.group(3).split(",")]
        import numpy as np
        arr = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            arr = arr.transpose([int(d) for d in m.group(4).split(",")])
        return [list(map(int, row)) for row in arr.reshape(n, g)]
    m = _PAIRS_RE.search(line)
    if m:
        return [[int(a), int(b)] for a, b in
                re.findall(r"\{(\d+),(\d+)\}", m.group(1))]
    return None


def axis_coords(mesh) -> dict[str, dict[int, int]]:
    """logical partition index (mesh.devices.flat position — what HLO
    replica_groups refer to) → coordinate along each mesh axis."""
    import numpy as np
    shape = mesh.devices.shape
    out: dict[str, dict[int, int]] = {a: {} for a in mesh.axis_names}
    for pos, idx in enumerate(np.ndindex(*shape)):
        for a, c in zip(mesh.axis_names, idx):
            out[a][pos] = c
    return out


def collectives_crossing_axis(hlo_text: str, mesh, axis: str
                              ) -> list[tuple[str, str]]:
    """(op, hlo line) of every collective whose groups span ``axis``.

    A group "spans" the axis when two of its members sit at different
    coordinates along it. A collective whose participants cannot be
    parsed at all is conservatively counted as crossing — a false
    positive beats silently voiding the no-replica-traffic guarantee.
    """
    coords = axis_coords(mesh)[axis]
    hits = []
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        groups = parse_replica_groups(line)
        if groups is None:
            hits.append((m.group(2), line.strip()))
            continue
        for grp in groups:
            if len({coords.get(d, -1) for d in grp}) > 1:
                hits.append((m.group(2), line.strip()))
                break
    return hits


def result_bytes(hits) -> int:
    """Total RESULT bytes of ``(op, hlo line)`` collective hits (as
    returned by :func:`collectives_crossing_axis` /
    :func:`sync_collective_audit`). Result type only — counting the whole
    line would also include operand shapes and double the figure."""
    total = 0
    for op, line in hits:
        m = _COLL_RE.search(line)
        total += _shape_bytes(m.group(1)) if m else 0
    return total


def sync_collective_audit(hlo_text: str, mesh, replica_axis: str = "replica",
                          outer_axis: str | None = None,
                          n_groups: int | None = None) -> dict:
    """Structural audit of an HWA sync step's collectives, per level.

    **Flat** (``outer_axis=None``): the mesh-resident packed sync's
    contract is exactly ONE collective — the weight all-reduce
    (pmean/psum) over the replica axis — and ZERO collectives crossing
    any other mesh axis (i.e. the packed-W̄ assembly and the W̿ unpack
    are shard-local).

    **Grouped** (``n_groups`` set): the mixed-tiling (FSDP) grouped
    layout keeps the SAME collective contract — the per-group window
    buffers change the kernel-launch budget (≤ ``n_groups``
    pallas_calls, counted separately via :func:`count_pallas_calls` on
    the jaxpr — interpret-mode HLO has no custom-call marker), not the
    traffic: partials are concatenated before the one replica
    all-reduce and every group's assembly stays shard-local. The
    ``grouped_sync_ok`` verdict asserts that HLO side.

    **Two-level** (``outer_axis`` set, e.g. ``"pod"``): each collective
    is classified by which of the two replica-population axes its
    ``replica_groups`` actually span —

    - *inner-only*: crosses ``replica_axis`` but NOT ``outer_axis`` (a
      per-pod reduction with pod-local groups);
    - *outer-only*: crosses ``outer_axis`` but NOT ``replica_axis`` (the
      cross-pod all-reduce of already-pod-reduced partials);
    - *mixed*: spans both — a MISWIRED grouping (e.g. one joint
      all-reduce where the tree promises a composition), rejected by
      both per-level verdicts below.

    The per-level expectations the tree bundles are audited against:

    - ``inner_sync_ok`` — an INNER sync crosses ONLY the inner groups:
      exactly one inner-only all-reduce, zero outer crossings, zero
      mixed, assembly-free;
    - ``outer_sync_ok`` — an OUTER sync adds exactly one cross-pod
      all-reduce on top: one inner-only + one outer-only all-reduce,
      zero mixed, assembly-free.

    Returns::

        {"replica": [(op, line), ...],   # all collectives crossing replica
         "outer":   [(op, line), ...],   # all crossing outer_axis ([] if None)
         "mixed":   [(op, line), ...],   # crossing both (miswired grouping)
         "other":   {axis: [(op, line), ...]},
         "replica_allreduce_only": bool, # replica hits are 1 all-reduce
         "assembly_free": bool,          # no crossings outside the levels
         "inner_sync_ok": bool,
         "outer_sync_ok": bool}

    Used by tests/mesh_hwa_check.py, tests/test_sync_topology.py and
    benchmarks/kernel_bench.py / benchmarks/sync_tree.py.
    """
    replica = collectives_crossing_axis(hlo_text, mesh, replica_axis)
    outer = (collectives_crossing_axis(hlo_text, mesh, outer_axis)
             if outer_axis is not None else [])
    outer_lines = {line for _, line in outer}
    replica_lines = {line for _, line in replica}
    mixed = [h for h in replica if h[1] in outer_lines]
    inner_only = [h for h in replica if h[1] not in outer_lines]
    outer_only = [h for h in outer if h[1] not in replica_lines]
    other = {ax: collectives_crossing_axis(hlo_text, mesh, ax)
             for ax in mesh.axis_names
             if ax != replica_axis and ax != outer_axis}
    assembly_free = not any(hits for hits in other.values())
    one_ar = lambda hits: len(hits) == 1 and hits[0][0] == "all-reduce"
    out = {
        "replica": replica,
        "outer": outer,
        "mixed": mixed,
        "other": other,
        "replica_allreduce_only": (
            len(replica) == 1 and replica[0][0] == "all-reduce"),
        "assembly_free": assembly_free,
        "inner_sync_ok": (one_ar(inner_only) and not outer
                          and assembly_free),
        "outer_sync_ok": (one_ar(inner_only) and one_ar(outer_only)
                          and not mixed and assembly_free),
    }
    if n_groups is not None:
        out["n_groups"] = n_groups
        out["grouped_sync_ok"] = (out["replica_allreduce_only"]
                                  and assembly_free)
    return out


# --------------------------------------------------- kernel-launch counting
#
# The packed WA path's contract is O(1) launches per sync regardless of
# parameter-leaf count. Counted structurally: ``pallas_call`` equations in
# the jaxpr (robust in interpret mode, where the lowered HLO has no
# custom-call marker), or ``custom-call`` ops targeting the TPU/Mosaic
# kernel entry points in compiled HLO text.

_PALLAS_CC_RE = re.compile(
    r'custom-call.*custom_call_target="(?:tpu_custom_call|mosaic|'
    r'__gpu\$xla\.gpu\.triton)"')


def count_pallas_calls(obj) -> int:
    """Number of Pallas kernel launches in a jaxpr (or ClosedJaxpr, or
    anything with a ``.jaxpr``) or in lowered/compiled HLO text."""
    if isinstance(obj, str):
        return sum(1 for line in obj.splitlines()
                   if _PALLAS_CC_RE.search(line))
    jaxpr = obj
    while hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    count = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            count += 1
        for param in eqn.params.values():
            for sub in (param if isinstance(param, (list, tuple)) else
                        (param,)):
                if hasattr(sub, "eqns") or hasattr(sub, "jaxpr"):
                    count += count_pallas_calls(sub)
    return count


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   traffic_bytes: float) -> dict:
    compute_s = flops_per_device / PEAK_FLOPS
    memory_s = bytes_per_device / HBM_BW
    collective_s = traffic_bytes / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    terms["dominant"] = dominant
    terms["bound_s"] = terms[dominant]
    return terms
