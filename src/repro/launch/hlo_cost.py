"""Loop-aware cost extraction from post-SPMD HLO text.

XLA's ``compiled.cost_analysis()`` counts every while-loop *body once* —
verified: a scan of 10 matmuls reports the FLOPs of one. Our stacks scan
layers (and microbatches, and xent chunks), so its numbers undercount by
the trip counts. This module re-derives per-device costs structurally:

- parse the module into computations with a per-computation symbol table
  (instruction name → shape) including signature parameters;
- FLOPs from ``dot``/``convolution`` (2 · prod(result dims) · prod(
  contraction dims), batch dims handled since they appear in the result);
- HBM bytes from operand+result sizes of memory-moving ops (fusion, dot,
  copy, gather/scatter, dynamic-(update-)slice, reduce, convert, sort,
  concatenate, broadcast, iota, transpose, reshape with layout change ≈
  fusions dominate);
- collectives: result bytes × ring-traffic factor (see factors below);
- ``while`` trip counts parsed from the loop condition's comparison
  constant; nested loops multiply (layer scan × microbatch scan).

Approximations are documented in EXPERIMENTS.md §Roofline; cross-checked
against an unrolled small model (test_hlo_cost.py).
"""
from __future__ import annotations

import dataclasses
import math
import re

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "u1": 1, "s1": 1,
}

_SHAPE_TOKEN = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(?.*?\)?)\s+"
                     r"([\w\-]+)\(")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->")
_PARAM_RE = re.compile(r"([\w.\-]+):\s*((?:\([^)]*\))|(?:[\w\[\],]+))")
_ATTR_COMP = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONTRACT_RE = re.compile(r"(lhs|rhs)_contracting_dims=\{([\d,]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops whose operands+result move through HBM. The CPU backend leaves many
# elementwise ops (convert/broadcast/transpose/copy/...) unfused that the
# TPU backend would fuse — counting them models the CPU, not the target,
# and overcounts ~100×. Count only genuinely memory-moving ops; ``fusion``
# nodes already represent fused elementwise groups.
_MEM_OPS = {"fusion", "dot", "convolution", "gather", "scatter",
            "dynamic-slice", "dynamic-update-slice", "reduce", "sort",
            "reduce-window", "select-and-scatter"}


def _shape_elems_bytes(type_str):
    elems = bytes_ = 0
    for dtype, dims in _SHAPE_TOKEN.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        bytes_ += n * _DTYPE_BYTES[dtype]
    return elems, bytes_


@dataclasses.dataclass
class _Instr:
    name: str
    type_str: str
    op: str
    line: str


def _parse_computations(text):
    comps: dict[str, list[_Instr]] = {}
    params: dict[str, dict[str, str]] = {}
    cur = None
    for line in text.splitlines():
        hdr = _COMP_HDR.match(line.strip()) if "{" in line and "->" in line else None
        if hdr and not line.strip().startswith("%constant"):
            cur = hdr.group(1)
            comps[cur] = []
            params[cur] = {m.group(1): m.group(2)
                           for m in _PARAM_RE.finditer(hdr.group(2))}
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _DEF_RE.match(line)
        if m:
            comps[cur].append(_Instr(m.group(1), m.group(2), m.group(3), line))
    return comps, params


def _operand_names(line):
    # text inside the first top-level parens after the op name
    i = line.find("(", line.find("= "))
    if i < 0:
        return []
    depth = 0
    out = []
    for j in range(i, len(line)):
        c = line[j]
        if c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
            if depth == 0:
                inner = line[i + 1:j]
                out = re.findall(r"%([\w.\-]+)", inner)
                break
    return out


def _mem_bytes(op, ins, tab, res_bytes, comps, symtab):
    """HBM bytes for one memory-moving instruction (slice-aware).

    - dynamic-slice/gather read only the slice: 2 × result;
    - dynamic-update-slice/scatter touch only the update region;
    - fusion: write result once; each operand is read fully UNLESS the
      fused computation consumes it solely through dynamic-slice/gather
      (the per-layer weight slice inside the scanned stack — counting the
      full stacked operand per iteration overcounted ~40×).
    """
    ops_ = _operand_names(ins.line)
    if op in ("dynamic-slice", "gather"):
        return 2 * res_bytes
    if op == "dynamic-update-slice":
        upd = _shape_elems_bytes(tab.get(ops_[1], ""))[1] if len(ops_) > 1 \
            else res_bytes
        return 2 * upd
    if op == "scatter":
        upd = sum(_shape_elems_bytes(tab.get(o, ""))[1] for o in ops_[2:]) \
            if len(ops_) > 2 else res_bytes
        return 2 * upd
    if op == "fusion":
        # pure dtype/layout fusions are CPU-backend artifacts — the TPU
        # backend fuses converts/copies into their consumers (bf16 MXU).
        if ins.name.startswith(("convert_", "copy_", "bitcast_",
                                "transpose_")):
            return 0.0
        called = [m.group(1) for m in _ATTR_COMP.finditer(ins.line)
                  if "calls=" in m.group(0)]
        sub = called[0] if called else None
        rd = 0.0
        sub_instrs = comps.get(sub, []) if sub else []
        sub_tab = symtab.get(sub, {}) if sub else {}
        # in-place update fusions (root = dynamic-update-slice): the write
        # is the update region, not the whole buffer, and the aliased
        # buffer operand is not re-read.
        dus_root = sub_instrs[-1] if sub_instrs and \
            sub_instrs[-1].op == "dynamic-update-slice" else None
        dus_inplace_params: set[str] = set()
        if dus_root is not None:
            r_ops = _operand_names(dus_root.line)
            upd = _shape_elems_bytes(sub_tab.get(r_ops[1], ""))[1] \
                if len(r_ops) > 1 else res_bytes
            res_bytes = 2 * upd
            if r_ops:
                dus_inplace_params.add(r_ops[0])
        # consumers of each fusion parameter inside the fused computation;
        # transparent ops (bitcast/reshape/copy/transpose/convert) are
        # followed so `param -> bitcast -> dynamic-slice` still counts as
        # a sliced read.
        param_sliced: dict[int, float] = {}
        _TRANSPARENT = ("bitcast", "reshape", "copy", "transpose", "convert")
        if sub_instrs:
            pnames = {}
            for name, tstr in sub_tab.items():
                m = re.match(r"param_(\d+)", name)
                if m:
                    pnames[name] = int(m.group(1))
            consumers: dict[str, list] = {}
            for si in sub_instrs:
                for onm in _operand_names(si.line):
                    consumers.setdefault(onm, []).append(si)

            def leaf_consumers(name, depth=0):
                out = []
                for c in consumers.get(name, []):
                    if c.op in _TRANSPARENT and depth < 6:
                        out += leaf_consumers(c.name, depth + 1)
                    else:
                        out.append((name, c))
                return out

            for pname, pidx in pnames.items():
                leaves = leaf_consumers(pname)
                if leaves and all(
                        (c.op in ("dynamic-slice", "gather")
                         and _operand_names(c.line)[:1] == [src])
                        or (c.op == "dynamic-update-slice"
                            and _operand_names(c.line)[:1] == [src])
                        for src, c in leaves):
                    param_sliced[pidx] = sum(
                        _shape_elems_bytes(c.type_str)[1]
                        for _, c in leaves
                        if c.op in ("dynamic-slice", "gather"))
        for i, onm in enumerate(ops_):
            full = _shape_elems_bytes(tab.get(onm, ""))[1]
            rd += param_sliced.get(i, full) if i in param_sliced else full
        return rd + res_bytes
    # dot / convolution / reduce / sort / ...: full operand reads + write
    rd = sum(_shape_elems_bytes(tab.get(o, ""))[1] for o in ops_)
    return rd + res_bytes


@dataclasses.dataclass
class HLOCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_traffic: float = 0.0
    coll_counts: dict = dataclasses.field(default_factory=dict)
    coll_bytes: dict = dataclasses.field(default_factory=dict)

    def scaled(self, k):
        return HLOCost(self.flops * k, self.bytes * k, self.coll_traffic * k,
                       {o: c * k for o, c in self.coll_counts.items()},
                       {o: b * k for o, b in self.coll_bytes.items()})

    def add(self, other):
        self.flops += other.flops
        self.bytes += other.bytes
        self.coll_traffic += other.coll_traffic
        for o, c in other.coll_counts.items():
            self.coll_counts[o] = self.coll_counts.get(o, 0) + c
        for o, b in other.coll_bytes.items():
            self.coll_bytes[o] = self.coll_bytes.get(o, 0) + b


def analyze_hlo(text: str) -> HLOCost:
    comps, comp_params = _parse_computations(text)
    # symbol tables: instruction name -> type string
    symtab: dict[str, dict[str, str]] = {}
    for cname, instrs in comps.items():
        tab = dict(comp_params.get(cname, {}))
        for ins in instrs:
            tab[ins.name] = ins.type_str
        symtab[cname] = tab

    memo: dict[str, HLOCost] = {}

    def trip_count(cond_name: str) -> int:
        consts = []
        for ins in comps.get(cond_name, []):
            consts += [int(c) for c in _CONST_RE.findall(ins.line)]
        return max(consts) if consts else 1

    def comp_cost(cname: str) -> HLOCost:
        if cname in memo:
            return memo[cname]
        memo[cname] = HLOCost()        # guard cycles
        total = HLOCost()
        tab = symtab.get(cname, {})
        for ins in comps.get(cname, []):
            op = ins.op
            res_elems, res_bytes = _shape_elems_bytes(ins.type_str)
            if op == "while":
                body = cond = None
                for an in _ATTR_COMP.finditer(ins.line):
                    if "body=" in an.group(0):
                        body = an.group(1)
                    elif "condition=" in an.group(0):
                        cond = an.group(1)
                if body:
                    n = trip_count(cond) if cond else 1
                    total.add(comp_cost(body).scaled(max(n, 1)))
                continue
            if op == "conditional":
                br = _BRANCHES.search(ins.line)
                subs = (re.findall(r"%?([\w.\-]+)", br.group(1)) if br else [])
                for sub in subs:
                    total.add(comp_cost(sub))
                continue
            called = [m.group(1) for m in _ATTR_COMP.finditer(ins.line)
                      if "calls=" in m.group(0) or "to_apply=" in m.group(0)]
            coll = next((c for c in COLLECTIVES if op.startswith(c)), None)
            if coll and not op.endswith("-done"):
                gm = _GROUPS_RE.search(ins.line)
                g = int(gm.group(2)) if gm else 1
                if g > 1:
                    if coll == "all-reduce":
                        factor = 2.0 * (g - 1) / g
                    elif coll == "all-gather":
                        factor = (g - 1) / g
                    elif coll == "reduce-scatter":
                        factor = float(g - 1)
                    elif coll == "all-to-all":
                        factor = (g - 1) / g
                    else:
                        factor = 1.0
                    total.coll_traffic += res_bytes * factor
                total.coll_counts[coll] = total.coll_counts.get(coll, 0) + 1
                total.coll_bytes[coll] = total.coll_bytes.get(coll, 0) + res_bytes
                total.bytes += 2 * res_bytes
                continue
            if op in ("dot", "convolution"):
                # contraction size from lhs operand shape
                ops_ = _operand_names(ins.line)
                lhs_type = tab.get(ops_[0], "") if ops_ else ""
                lhs_dims = []
                mt = _SHAPE_TOKEN.search(lhs_type)
                if mt:
                    lhs_dims = [int(d) for d in mt.group(2).split(",") if d]
                cm = dict((k, v) for k, v in _CONTRACT_RE.findall(ins.line))
                cdims = [int(d) for d in cm.get("lhs", "").split(",") if d]
                csize = math.prod(lhs_dims[d] for d in cdims) if cdims and \
                    all(d < len(lhs_dims) for d in cdims) else \
                    (lhs_dims[-1] if lhs_dims else 1)
                total.flops += 2.0 * res_elems * max(csize, 1)
            if called:
                for sub in called:
                    total.add(comp_cost(sub))
            if op in _MEM_OPS:
                total.bytes += _mem_bytes(op, ins, tab, res_bytes, comps,
                                          symtab)
        memo[cname] = total
        return total

    entry = None
    for cname in comps:
        if ".entry" in cname or cname.startswith("main"):
            entry = cname
    if entry is None and comps:
        # ENTRY computation is usually the last or named after the jit fn
        entry = list(comps.keys())[0]
    # safest: sum nothing but the entry; find via "ENTRY" marker in text
    m = re.search(r"ENTRY\s+%?([\w.\-]+)", text)
    if m and m.group(1) in comps:
        entry = m.group(1)
    return comp_cost(entry) if entry else HLOCost()
