"""Training launcher: HWA (and baselines) on any assigned architecture.

CPU-scale entry point (smoke configs by default) that exercises the full
stack: config registry → synthetic data → HWA trainer → checkpoints.
The production path for real hardware is the same Trainer with the
HWA mesh (``repro.launch.mesh.make_hwa_mesh``) — see examples/.

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
      --method hwa --steps 300 --k 2 --window 10
"""
from __future__ import annotations

import argparse
import json
import os

from repro.configs import ARCH_IDS, get_smoke_config
from repro.core.hwa import HWAConfig
from repro.data import DataPipeline, make_markov_lm_dataset
from repro.models.registry import build_model
from repro.train.trainer import TrainConfig, Trainer, lm_task


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b", choices=ARCH_IDS)
    ap.add_argument("--method", default="hwa",
                    choices=["base", "ca", "swa", "ema", "lookahead", "sam",
                             "online", "pmsgd", "hwa"])
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.3)
    ap.add_argument("--k", type=int, default=2, help="HWA replicas K")
    ap.add_argument("--sync-period", type=int, default=0, help="H (0=epoch)")
    ap.add_argument("--window", type=int, default=10, help="I")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    if cfg.family in ("vlm", "audio"):
        raise SystemExit(f"{args.arch}: use examples/serve_decode.py-style "
                         "drivers for modality-frontend archs")
    lm = build_model(cfg)
    ds = make_markov_lm_dataset(vocab=cfg.vocab_size, seq_len=args.seq_len,
                                n_train=2048, n_test=512, seed=args.seed)
    K = args.k if args.method in ("hwa", "online", "pmsgd") else 1
    pipe = DataPipeline(ds, batch_size=args.batch_size, n_replicas=K,
                        seed=args.seed)
    tc = TrainConfig(
        method=args.method, total_steps=args.steps,
        batch_size=args.batch_size, base_lr=args.lr, seed=args.seed,
        hwa=HWAConfig(n_replicas=K, sync_period=args.sync_period,
                      window=args.window))
    out = Trainer(lm_task(lm, pipe), tc).run(log=True)
    print(f"[train] {args.arch}/{args.method}: final {out['final']}, "
          f"best {out['best']}")
    if args.out:
        os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
        with open(args.out, "w") as f:
            json.dump({"final": out["final"], "best": out["best"],
                       "history": out["history"]}, f, indent=2)


if __name__ == "__main__":
    main()
