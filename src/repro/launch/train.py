"""Training launcher: HWA (and baselines) on any assigned architecture.

CPU-scale entry point (smoke configs by default) that exercises the full
stack: config registry → synthetic data → HWA trainer → checkpoints.
The production path for real hardware is the same Trainer with the
HWA mesh (``repro.launch.mesh.make_hwa_mesh``) — see examples/.

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
      --method hwa --steps 300 --k 2 --window 10

``--mesh-native`` instead runs the shard_map SPMD path: K replicas on the
``replica`` mesh axis, one weight pmean per sync (no devices? force host
devices first):

  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m repro.launch.train --mesh-native --steps 16 --sync-period 4

Add ``--sync-tree two-level --k 4 --outer-every 2`` for the hierarchical
sync tree: K replicas carved into pods, pod-internal averaging every H
steps, the cross-pod all-reduce + window push only every H·H₂ steps.
``--wa-dtype bf16`` (or ``fp8``) compresses the WA ring storage and
``--comms-dtype`` the tree's cross-pod payload — both routed through
``SyncPlan``; the f32 defaults stay bit-identical to the uncompressed
path.
"""
from __future__ import annotations

import argparse
import json
import os

from repro.configs import ARCH_IDS, get_smoke_config
from repro.core.hwa import HWAConfig
from repro.data import DataPipeline, make_markov_lm_dataset
from repro.models.registry import build_model
from repro.train.trainer import TrainConfig, Trainer, lm_task


def run_mesh_native(args) -> dict:
    """Train with the shard_map HWA steps on a (replica=K, data,
    model=--tp) mesh built from whatever devices are available — or, with
    ``--sync-tree two-level``, on a pod-carved (pod, replica, data,
    model=--tp) mesh where only every ``--outer-every``-th sync crosses
    pods (the rest are pod-internal restarts with zero cross-pod bytes).
    ``--fsdp --tp 2`` exercises the FSDP mixed data×model tilings whose
    sync runs through the GROUPED mesh-resident packed layout (per-group
    window buffers; no legacy GSPMD assembly).

    Inter-replica traffic happens only inside the sync steps — the
    paper's H-fold communication amortization (×H₂ more for cross-pod
    links under the tree), executed for real (one process, SPMD across
    the local devices).
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.common.compat import make_mesh, use_mesh
    from repro.common.quant import is_compressed, needs_scales
    from repro.launch.specs import input_specs
    from repro.launch.steps import (SyncPlan, TwoLevel, build_hwa_bundles,
                                    window_state_args)
    from repro.models.types import InputShape
    from repro.sharding.rules import make_tp_rules

    n_dev = len(jax.devices())
    K = args.k
    tp = max(args.tp, 1)
    if n_dev % (K * tp) or n_dev // (K * tp) < 1:
        raise SystemExit(
            f"--mesh-native needs a device count divisible by K×tp="
            f"{K * tp} (have {n_dev}; set XLA_FLAGS="
            "--xla_force_host_platform_device_count=<n>)")
    tree = args.sync_tree == "two-level"
    if tree:
        pods = args.pods or 2
        if K % pods or K // pods < 1:
            raise SystemExit(f"--sync-tree two-level needs K divisible by "
                             f"--pods (K={K}, pods={pods})")
        mesh = make_mesh((pods, K // pods, n_dev // (K * tp), tp),
                         ("pod", "replica", "data", "model"))
        replica_axis = ("pod", "replica")
        topo = TwoLevel("replica", "pod", outer_every=args.outer_every)
    else:
        mesh = make_mesh((K, n_dev // (K * tp), tp),
                         ("replica", "data", "model"))
        replica_axis = "replica"
        topo = None
    rules = make_tp_rules(mesh, replica_axis=replica_axis, fsdp=args.fsdp)
    cfg = get_smoke_config(args.arch)
    if args.attn_impl:
        cfg = cfg.with_(attn_impl=args.attn_impl)
    if cfg.attn_impl == "flash_pallas" and tp > 1:
        raise SystemExit("--attn-impl flash_pallas runs the fully-manual "
                         "DP-only train step; --tp must stay 1")
    if cfg.family in ("vlm", "audio"):
        raise SystemExit(f"{args.arch}: mesh-native driver supports LM "
                         "families only")
    lm = build_model(cfg)
    hwa_cfg = HWAConfig(n_replicas=K, window=args.window,
                        outer_every=args.outer_every if tree else 1,
                        resilient=args.resilient,
                        max_param_rms=args.max_param_rms or None)
    shape = InputShape("mesh_native", seq_len=args.seq_len,
                       global_batch=args.batch_size, kind="train")
    specs, dims = input_specs(cfg, shape)
    try:
        plan = SyncPlan(hwa=hwa_cfg, topology=topo,
                        wa_dtype=args.wa_dtype, comms_dtype=args.comms_dtype,
                        optimizer="sgd", lr=args.lr)
    except ValueError as e:
        raise SystemExit(f"invalid --wa-dtype/--comms-dtype combination: "
                         f"{e}") from None
    bundles = build_hwa_bundles(lm, rules, plan, specs, dims)
    train, sync = bundles.train, bundles.sync
    inner_sync = bundles.inner_sync
    H = args.sync_period or 8

    params = lm.init(jax.random.key(args.seed))
    inner = jax.tree.map(lambda x: jnp.broadcast_to(x[None], (K,) + x.shape),
                         params)
    from repro.launch.steps import _mk_optimizer
    opt = _mk_optimizer("sgd")   # must match the compiled step's optimizer
    inner_opt = jax.vmap(opt.init)(inner)
    spec = bundles.pack_spec    # window state is packed: one (I, P) ring
    # (or, under FSDP's grouped mixed-tiling layout, one ring per group).
    # The sync bundle's own argument order — (ring, [scales], total,
    # [comp], count, next_idx, cycle) — is the one source of truth for
    # what the window state holds; allocate straight from it.
    win = list(window_state_args(bundles))
    n_buf = len(win) - 3        # buffers ahead of count/next_idx/cycle
    has_scales = needs_scales(spec.ring_dtype)
    has_comp = is_compressed(spec.ring_dtype)
    cycle = win[-1]

    inject = None
    if args.inject_nan:
        s, _, r = args.inject_nan.partition(":")
        inject = (int(s), int(r))
        if not 0 <= inject[1] < K:
            raise SystemExit(f"--inject-nan replica {inject[1]} out of "
                             f"range [0, {K})")

    session = None
    if args.checkpoint_dir and args.checkpoint_every > 0:
        from repro.resilience.session import CheckpointSession
        session = CheckpointSession(args.checkpoint_dir, keep=args.keep)
    if session is None and args.resume:
        raise SystemExit("--resume needs --checkpoint-dir and "
                         "--checkpoint-every")

    def _window_like(win):
        from repro.core.offline import WindowState
        it = iter(win)
        ring = next(it)
        scales = next(it) if has_scales else None
        total = next(it)
        comp = next(it) if has_comp else None
        count, nidx = next(it), next(it)
        return WindowState(ring=ring, total=total, count=count,
                           next_idx=nidx, window=args.window, kind="ring",
                           spec=spec, comp=comp, scales=scales)

    train_c = train.lower(mesh).compile()
    sync_c = sync.lower(mesh).compile()
    inner_sync_c = inner_sync.lower(mesh).compile() if inner_sync else None
    wa = params
    loss = float("nan")
    history = []
    sync_idx = 0
    start_step = 0
    k_alive_min = K
    if session is not None and args.resume:
        latest = session.latest_intact()
        if latest is not None:
            # everything else about the run — batches, schedules — is a
            # stateless function of (seed, step): restoring the arrays
            # and the step counter IS a bit-exact resume
            inner = jax.device_put(session.load(latest, "inner", inner),
                                   train.in_shardings[0])
            inner_opt = jax.device_put(
                session.load(latest, "inner_opt", inner_opt),
                train.in_shardings[1])
            wa = jax.device_put(session.load(latest, "wa", wa),
                                sync.out_shardings[3 + n_buf])
            ws = session.load_window(latest, _window_like(win))
            restored = [ws.ring]
            if has_scales:
                restored.append(ws.scales)
            restored.append(ws.total)
            if has_comp:
                restored.append(ws.comp)
            for i, buf in enumerate(restored):
                win[i] = jax.device_put(buf, sync.in_shardings[1 + i])
            win[n_buf], win[n_buf + 1] = ws.count, ws.next_idx
            meta = session.meta(latest)
            start_step = int(meta["step"])
            cycle = win[-1] = jnp.asarray(meta["cycle"], jnp.int32)
            sync_idx = int(meta["sync_idx"])
            loss = float(meta["loss"])
            history = list(meta.get("history", []))
            print(f"[mesh-native] resumed from step {start_step} "
                  f"({session.step_dir(latest)})")
    with use_mesh(mesh):
        for step in range(start_step, args.steps):
            if inject is not None and step == inject[0]:
                from repro.resilience.faults import poison_replica
                inner = jax.device_put(poison_replica(inner, inject[1]),
                                       train.in_shardings[0])
                print(f"[mesh-native] step {step}: injected NaN into "
                      f"replica {inject[1]}")
            ks = jax.random.split(jax.random.key(1000 + step), 2)
            batch = {
                "tokens": jax.random.randint(
                    ks[0], (K, args.batch_size, args.seq_len), 0,
                    cfg.vocab_size),
                "targets": jax.random.randint(
                    ks[1], (K, args.batch_size, args.seq_len), 0,
                    cfg.vocab_size),
            }
            inner, inner_opt, losses = train_c(inner, inner_opt, batch)
            # reduce on host: jnp.mean over the replica-sharded losses
            # would launch a tiny all-reduce executable whose straggler
            # groups keep holding collective threads after float() reads
            # device 0's shard — the next dispatched step then deadlocks
            # the CPU rendezvous pool. device_get drains every shard.
            loss = float(np.mean(jax.device_get(losses)))
            if (step + 1) % H == 0:
                if inner_sync_c is not None and not topo.is_outer(sync_idx):
                    # pod-internal restart: zero cross-pod traffic, no
                    # window push (the window collects global W̄ only)
                    inner = inner_sync_c(inner)
                    history.append({"step": step + 1, "loss": loss,
                                    "sync": "inner"})
                    print(f"[mesh-native] step {step + 1} loss {loss:.4f} "
                          f"inner sync (pods avg internally)")
                else:
                    # outputs mirror the inputs: (inner, <buffers...>,
                    # count, next_idx, wa, cycle[, alive])
                    res = sync_c(inner, *win)
                    inner = res[0]
                    count, nidx, wa, cycle = res[1 + n_buf:5 + n_buf]
                    win = list(res[1:1 + n_buf]) + [count, nidx, cycle]
                    if args.resilient:
                        alive = res[5 + n_buf]
                        k_alive = int(np.sum(jax.device_get(alive)))
                        k_alive_min = min(k_alive_min, k_alive)
                        if k_alive < K:
                            # the sync already restarted the dead replica
                            # from W̄; its stale momentum goes too
                            from repro.resilience.health import \
                                quarantine_opt_state
                            inner_opt = jax.device_put(
                                quarantine_opt_state(inner_opt, alive),
                                train.in_shardings[1])
                        history.append({"step": step + 1, "loss": loss,
                                        "sync": "outer",
                                        "cycle": int(cycle),
                                        "k_alive": k_alive})
                        print(f"[mesh-native] step {step + 1} loss "
                              f"{loss:.4f} cycle {int(cycle)} "
                              f"k_alive {k_alive}/{K}")
                    else:
                        history.append({"step": step + 1, "loss": loss,
                                        "sync": "outer",
                                        "cycle": int(cycle)})
                        print(f"[mesh-native] step {step + 1} loss "
                              f"{loss:.4f} cycle {int(cycle)} (K={K}, "
                              f"mesh={dict(mesh.shape)})")
                sync_idx += 1
            if session is not None and \
                    (step + 1) % args.checkpoint_every == 0:
                session.save(
                    step + 1,
                    {"inner": inner, "inner_opt": inner_opt, "wa": wa},
                    window=_window_like(win),
                    meta={"step": step + 1, "cycle": int(cycle),
                          "sync_idx": sync_idx, "loss": loss,
                          "history": history})
    wa_finite = all(bool(np.all(np.isfinite(jax.device_get(x))))
                    for x in jax.tree.leaves(wa)
                    if jnp.issubdtype(x.dtype, jnp.floating))
    ws_final = _window_like(win)
    out = {"final_loss": loss, "cycles": int(cycle), "syncs": sync_idx,
           "history": history, "sync_tree": args.sync_tree,
           "wa_dtype": plan.wa_dtype, "comms_dtype": plan.comms_dtype,
           "wa_finite": wa_finite, "k_alive_min": k_alive_min,
           "mesh": {k: int(v) for k, v in mesh.shape.items()},
           "_state": {"inner": inner, "wa": wa, "ring": ws_final.ring,
                      "total": ws_final.total}}
    print(f"[mesh-native] done: {out['cycles']} outer cycles / "
          f"{sync_idx} syncs, final loss {out['final_loss']:.4f}, "
          f"wa_finite {wa_finite}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b", choices=ARCH_IDS)
    ap.add_argument("--method", default="hwa",
                    choices=["base", "ca", "swa", "ema", "lookahead", "sam",
                             "online", "pmsgd", "hwa"])
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.3)
    ap.add_argument("--k", type=int, default=2, help="HWA replicas K")
    ap.add_argument("--sync-period", type=int, default=0, help="H (0=epoch)")
    ap.add_argument("--window", type=int, default=10, help="I")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--attn-impl", default="",
                    choices=["", "naive", "flash_jnp", "flash_pallas"],
                    help="override the arch's attention implementation; "
                         "flash_pallas selects the Pallas custom-vjp "
                         "kernels (fully-manual DP train step under "
                         "--mesh-native; interpret mode off-TPU)")
    ap.add_argument("--out", default="")
    ap.add_argument("--mesh-native", action="store_true",
                    help="run the shard_map SPMD HWA path on the local "
                         "devices (replica axis = K)")
    ap.add_argument("--sync-tree", default="flat",
                    choices=["flat", "two-level"],
                    help="sync topology (mesh-native only): flat = one "
                         "global all-reduce per sync; two-level = pods "
                         "average internally every sync, cross-pod "
                         "all-reduce + window push every --outer-every "
                         "syncs")
    ap.add_argument("--outer-every", type=int, default=2,
                    help="H₂: outer (cross-pod) sync period of the "
                         "two-level tree, in syncs")
    ap.add_argument("--pods", type=int, default=0,
                    help="pod count for --sync-tree two-level "
                         "(0 = auto: 2)")
    ap.add_argument("--wa-dtype", default="f32",
                    choices=["f32", "bf16", "fp8"],
                    help="mesh-native only: WA ring storage dtype — bf16 "
                         "halves the window's HBM, fp8 (block-scaled, "
                         "per-ALIGN-block f32 scales) quarters it; the "
                         "running total stays f32 with Kahan "
                         "compensation. f32 (default) is bit-identical "
                         "to the uncompressed path")
    ap.add_argument("--comms-dtype", default="f32",
                    choices=["f32", "bf16", "fp8"],
                    help="mesh-native only: cross-pod sync payload dtype "
                         "(needs --sync-tree two-level; incompatible "
                         "with --resilient)")
    ap.add_argument("--fsdp", action="store_true",
                    help="mesh-native only: FSDP rule table (params + "
                         "moments sharded over the data axes too) — the "
                         "mixed data/model tilings the GROUPED "
                         "mesh-resident packed sync covers")
    ap.add_argument("--tp", type=int, default=1,
                    help="mesh-native only: model (tensor-parallel) axis "
                         "size; with --fsdp this yields true mixed "
                         "data×model leaf tilings")
    ap.add_argument("--resilient", action="store_true",
                    help="alive-masked sync: a replica whose weights go "
                         "non-finite (or whose RMS exceeds "
                         "--max-param-rms) is excluded from the K-mean "
                         "and re-seeded from W̄ at the next sync")
    ap.add_argument("--max-param-rms", type=float, default=0.0,
                    help="resilient only: divergence threshold on a "
                         "replica's parameter RMS (0 = finiteness only)")
    ap.add_argument("--inject-nan", default="",
                    help="fault injection (mesh-native only): STEP:REPLICA "
                         "— poison that replica's weights with NaN before "
                         "that step")
    ap.add_argument("--checkpoint-dir", default="",
                    help="preemption-safe checkpoint session directory "
                         "(manifest-last + CRC-verified)")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="steps between checkpoints (0 = off)")
    ap.add_argument("--keep", type=int, default=3,
                    help="checkpoints retained (older ones are GC'd)")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the newest INTACT checkpoint in "
                         "--checkpoint-dir (bit-exact: torn/corrupted "
                         "saves are skipped)")
    args = ap.parse_args()

    if args.inject_nan and not args.mesh_native:
        raise SystemExit("--inject-nan needs --mesh-native (use "
                         "tools/fault_check.py for the in-process legs)")
    if (args.wa_dtype != "f32" or args.comms_dtype != "f32") \
            and not args.mesh_native:
        raise SystemExit("--wa-dtype/--comms-dtype compress the "
                         "mesh-native packed window state; add "
                         "--mesh-native")

    if args.mesh_native:
        out = run_mesh_native(args)
        if args.out:
            os.makedirs(os.path.dirname(os.path.abspath(args.out)),
                        exist_ok=True)
            with open(args.out, "w") as f:
                # "_"-prefixed keys carry device arrays for in-process
                # callers (fault harness) — not JSON material
                json.dump({k: v for k, v in out.items()
                           if not k.startswith("_")}, f, indent=2)
        return

    cfg = get_smoke_config(args.arch)
    if args.attn_impl:
        cfg = cfg.with_(attn_impl=args.attn_impl)
    if cfg.family in ("vlm", "audio"):
        raise SystemExit(f"{args.arch}: use examples/serve_decode.py-style "
                         "drivers for modality-frontend archs")
    lm = build_model(cfg)
    ds = make_markov_lm_dataset(vocab=cfg.vocab_size, seq_len=args.seq_len,
                                n_train=2048, n_test=512, seed=args.seed)
    K = args.k if args.method in ("hwa", "online", "pmsgd") else 1
    pipe = DataPipeline(ds, batch_size=args.batch_size, n_replicas=K,
                        seed=args.seed)
    tc = TrainConfig(
        method=args.method, total_steps=args.steps,
        batch_size=args.batch_size, base_lr=args.lr, seed=args.seed,
        hwa=HWAConfig(n_replicas=K, sync_period=args.sync_period,
                      window=args.window, resilient=args.resilient,
                      max_param_rms=args.max_param_rms or None),
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every,
        checkpoint_keep=args.keep, resume=args.resume)
    out = Trainer(lm_task(lm, pipe), tc).run(log=True)
    print(f"[train] {args.arch}/{args.method}: final {out['final']}, "
          f"best {out['best']}")
    if args.out:
        os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
        with open(args.out, "w") as f:
            json.dump({"final": out["final"], "best": out["best"],
                       "history": out["history"]}, f, indent=2)


if __name__ == "__main__":
    main()
