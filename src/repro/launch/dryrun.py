import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The dry-run only lowers and INTROSPECTS compiled artifacts — no tensor
# is ever materialized, so the XLA-0.4.37 CPU miscompile of the legacy
# GSPMD packed-W̄ assembly (launch/sync/legacy.py) cannot corrupt
# anything here. Allow the FSDP hwa_sync combos to keep compiling on the
# forced-host meshes instead of tripping the hard error.
os.environ.setdefault("REPRO_ALLOW_LEGACY_ASSEMBLY", "1")

"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh)
combination against the production meshes and extract the roofline terms.

No tensor is ever allocated — inputs are ShapeDtypeStructs, and the
compiled artifact is only introspected (memory_analysis / cost_analysis /
post-SPMD HLO). A failure here (sharding mismatch, OOM at compile,
unsupported collective) is a bug in the framework.

Usage:
  python -m repro.launch.dryrun --arch all --shape all --mesh both \
      --out experiments/dryrun
  python -m repro.launch.dryrun --arch gemma2-27b --shape train_4k \
      --mesh single --step hwa_train        # HWA-stacked variant
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.compat import tree_flatten_with_path
from repro.configs import ARCH_IDS, get_config, get_input_shape
from repro.core.hwa import HWAConfig
from repro.launch.hlo import roofline_terms
from repro.launch.hlo_cost import analyze_hlo
from repro.launch.mesh import make_hwa_mesh, make_production_mesh
from repro.launch.specs import (adapt_config_for_shape, cache_specs,
                                decode_token_specs, input_specs)
from repro.launch.steps import (make_decode_step, make_hwa_sync_step,
                                make_hwa_train_step, make_prefill_step,
                                make_train_step)
from repro.models.registry import build_model
from repro.models.types import INPUT_SHAPES
from repro.sharding.rules import ShardingRules, make_tp_rules

HBM_PER_CHIP = 16e9   # v5e


def count_params(params_abs, cfg):
    total = embed = moe_routed = 0
    for path, leaf in tree_flatten_with_path(params_abs)[0]:
        n = int(np.prod(leaf.shape))
        keys = "/".join(str(getattr(p, "key", p)) for p in path)
        total += n
        if keys.startswith("embed"):
            embed += n
        if "moe" in keys and any(w in keys for w in ("w_gate", "w_up",
                                                     "w_down")):
            moe_routed += n
    active = total - moe_routed
    if cfg.n_experts:
        active += moe_routed * cfg.top_k / cfg.n_experts
    return {"total": total, "embed": embed,
            "active": active, "active_nonembed": active - embed,
            "nonembed": total - embed}


def model_flops(cfg, shape, pcount):
    n = pcount["active_nonembed"]
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch          # decode: one token/seq


def _sharded_bytes(abs_tree, dims_tree, rules):
    """Per-device bytes of a spec'd pytree under the given rules."""
    import math
    from repro.sharding.rules import spec_for_dims
    is_dims = lambda t: isinstance(t, tuple) and all(
        isinstance(e, (str, type(None))) for e in t)
    total = 0
    leaves_a = jax.tree.leaves(abs_tree)
    leaves_d = jax.tree.leaves(dims_tree, is_leaf=is_dims)
    for leaf, d in zip(leaves_a, leaves_d):
        spec = spec_for_dims(rules.mesh, rules.rules, d, leaf.shape)
        shard = 1
        for dim_size, assignment in zip(leaf.shape,
                                        tuple(spec) + (None,) * len(leaf.shape)):
            if assignment is None:
                continue
            axes = assignment if isinstance(assignment, tuple) else (assignment,)
            shard *= math.prod(rules.mesh.shape[a] for a in axes)
        total += int(np.prod(leaf.shape)) * leaf.dtype.itemsize // shard
    return total


def build_bundle(arch, shape_name, step_kind, mesh, hwa_k=2, variant=""):
    shape = get_input_shape(shape_name)
    cfg = adapt_config_for_shape(get_config(arch), shape)
    if variant == "ep":
        cfg = cfg.with_(expert_parallel=True)
    elif variant == "cf1":
        cfg = cfg.with_(moe_capacity_factor=1.0)
    lm = build_model(cfg)
    replica_axis = "replica" if "replica" in mesh.shape else None
    train_like = step_kind == "train" or step_kind.startswith("hwa_")
    # Training/prefill: full FSDP (params + moments) + sequence
    # parallelism. Decode: TP-only weights (latency path, no opt state).
    fsdp_like = train_like or step_kind == "prefill"
    rules = make_tp_rules(mesh, replica_axis=replica_axis,
                          fsdp=fsdp_like, sequence_parallel=train_like,
                          expert_parallel=cfg.expert_parallel)
    opt_rules = rules
    if step_kind == "decode":
        data_sz = 1
        for a in ("pod", "data"):
            if a in mesh.shape:
                data_sz *= mesh.shape[a]
        if shape.global_batch % data_sz:
            # batch-1 long-context decode: context-parallel KV cache
            # (cache seq dim sharded over the idle data axes)
            rules = ShardingRules(mesh=rules.mesh,
                                  rules={**rules.rules,
                                         "seq": tuple(
                                             a for a in ("pod", "data")
                                             if a in mesh.shape)})

    if step_kind == "train":
        specs, dims = input_specs(cfg, shape)
        n_params = sum(int(np.prod(l.shape))
                       for l in jax.tree.leaves(lm.abstract()[0]))
        n_mb = 4 if n_params > 2e10 else (2 if n_params > 8e9 else 1)
        bundle = make_train_step(lm, rules, specs, dims,
                                 opt_rules=opt_rules, n_microbatches=n_mb)
    elif step_kind == "prefill":
        specs, dims = input_specs(cfg, shape)
        c_abs, c_dims = cache_specs(lm, shape)
        bundle = make_prefill_step(lm, rules, specs, dims, c_abs, c_dims)
        bundle.cache_bytes_per_dev = _sharded_bytes(c_abs, c_dims, rules)
    elif step_kind == "decode":
        t_abs, t_dims = decode_token_specs(cfg, shape)
        c_abs, c_dims = cache_specs(lm, shape)
        bundle = make_decode_step(lm, rules, t_abs, t_dims, c_abs, c_dims)
        bundle.cache_bytes_per_dev = _sharded_bytes(c_abs, c_dims, rules)
    elif step_kind == "hwa_train":
        import dataclasses as dc
        per_replica = dc.replace(shape, global_batch=shape.global_batch // hwa_k)
        specs, dims = input_specs(cfg, per_replica)
        n_params = sum(int(np.prod(l.shape))
                       for l in jax.tree.leaves(lm.abstract()[0]))
        n_mb = 4 if n_params > 2e10 else (2 if n_params > 8e9 else 1)
        if cfg.n_experts:
            n_mb = max(n_mb, 2)
        bundle = make_hwa_train_step(lm, rules, specs, dims,
                                     HWAConfig(n_replicas=hwa_k),
                                     opt_rules=opt_rules,
                                     n_microbatches=n_mb)
    elif step_kind == "hwa_sync":
        bundle = make_hwa_sync_step(lm, rules, HWAConfig(n_replicas=hwa_k))
    elif step_kind == "hwa_sync_bf16ring":
        bundle = make_hwa_sync_step(lm, rules, HWAConfig(n_replicas=hwa_k),
                                    ring_dtype=jnp.bfloat16)
    elif step_kind == "hwa_sync_streaming":
        bundle = make_hwa_sync_step(
            lm, rules,
            HWAConfig(n_replicas=hwa_k, window_kind="streaming"))
    else:
        raise ValueError(step_kind)
    return cfg, lm, bundle


def run_combo(arch, shape_name, mesh_kind, step_kind="auto", hwa_k=2,
              verbose=True, variant=""):
    shape = get_input_shape(shape_name)
    if step_kind == "auto":
        step_kind = {"train": "train", "prefill": "prefill",
                     "decode": "decode"}[shape.kind]
    if step_kind.startswith("hwa_"):
        mesh = make_hwa_mesh(hwa_k, multi_pod=(mesh_kind == "multi"))
    else:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = int(np.prod(list(mesh.shape.values())))

    cfg, lm, bundle = build_bundle(arch, shape_name, step_kind, mesh, hwa_k,
                                   variant)
    t0 = time.time()
    lowered = bundle.lower(mesh)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):    # jax 0.4.x returns [dict]
        ca = ca[0] if ca else {}
    ma = compiled.memory_analysis()
    # loop-aware structural analysis (XLA cost_analysis counts while
    # bodies once — verified; analyze_hlo multiplies trip counts)
    hc = analyze_hlo(compiled.as_text())
    flops_dev = hc.flops
    bytes_dev = hc.bytes
    terms = roofline_terms(flops_dev, bytes_dev, hc.coll_traffic)
    pcount = count_params(lm.abstract()[0], cfg)
    mflops = model_flops(cfg, shape, pcount)
    peak_dev = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
                + ma.output_size_in_bytes - ma.alias_size_in_bytes)
    # CPU-backend artifact: matmuls lower as f32, so the WHOLE stacked KV
    # cache gets a hoisted f32 convert (2 copies, k+v) that the TPU bf16
    # MXU path would not materialize. Projected TPU peak removes them.
    cache_bytes = getattr(bundle, "cache_bytes_per_dev", 0)
    tpu_peak = peak_dev - 2 * cache_bytes if cache_bytes else peak_dev

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "step": step_kind, "variant": variant, "n_devices": n_dev,
        "mesh_shape": dict(mesh.shape),
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "collectives": {"counts": {k: float(v) for k, v in
                                   hc.coll_counts.items()},
                        "result_bytes_by_op": {k: float(v) for k, v in
                                               hc.coll_bytes.items()},
                        "traffic_bytes_per_device": hc.coll_traffic},
        "xla_cost_analysis_raw": {"flops_body_once": float(ca.get("flops", 0.0)),
                                  "bytes_body_once": float(ca.get("bytes accessed", 0.0))},
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_bytes": peak_dev,
            "fits_16GB": bool(peak_dev < HBM_PER_CHIP),
            "cache_bytes_per_dev": cache_bytes,
            "tpu_projected_peak_bytes": tpu_peak,
            "fits_16GB_tpu_projected": bool(tpu_peak < HBM_PER_CHIP),
        },
        "roofline": terms,
        "params": pcount,
        "model_flops_global": mflops,
        "useful_compute_ratio": (mflops / (flops_dev * n_dev)
                                 if flops_dev else 0.0),
        "lower_s": t1 - t0, "compile_s": t2 - t1,
    }
    if verbose:
        print(f"[dryrun] {arch} × {shape_name} × {mesh_kind} ({step_kind}): "
              f"OK — {flops_dev:.3e} FLOP/dev, "
              f"{bytes_dev/1e9:.2f} GB/dev HBM, "
              f"{hc.coll_traffic/1e9:.3f} GB/dev ICI, "
              f"peak {peak_dev/1e9:.2f} GB "
              f"({'fits' if rec['memory']['fits_16GB'] else 'OOM!'}; "
              f"tpu-proj "
              f"{'fits' if rec['memory']['fits_16GB_tpu_projected'] else 'OOM!'}), "
              f"dominant={terms['dominant']} "
              f"compile {t2-t1:.1f}s")
        print(f"  memory_analysis: {ma}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--step", default="auto")
    ap.add_argument("--hwa-k", type=int, default=2)
    ap.add_argument("--variant", default="", help="ep | cf1 | ''")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = list(INPUT_SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    os.makedirs(args.out, exist_ok=True)

    failures = []
    for arch in archs:
        for shape_name in shapes:
            for mesh_kind in meshes:
                tag = f"{arch}__{shape_name}__{mesh_kind}"
                if args.step != "auto":
                    tag += f"__{args.step}"
                if args.variant:
                    tag += f"__{args.variant}"
                path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[dryrun] skip {tag} (exists)")
                    continue
                try:
                    rec = run_combo(arch, shape_name, mesh_kind, args.step,
                                    args.hwa_k, variant=args.variant)
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=2)
                except Exception as e:  # noqa: BLE001 — report, keep going
                    failures.append((tag, repr(e)))
                    print(f"[dryrun] FAIL {tag}: {e}")
                    traceback.print_exc()
    print(f"[dryrun] done; {len(failures)} failures")
    for tag, err in failures:
        print("  FAIL", tag, err[:200])
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
