"""Production meshes (functions, never module-level constants — importing
this module must not touch jax device state).

Target: TPU v5e. Single pod = 16×16 = 256 chips, axes (data, model);
multi-pod = 2 pods = 512 chips, axes (pod, data, model). For HWA the
replica axis is the pod axis at multi-pod scale, or carved out of the data
axis on a single pod (DESIGN.md §2).

Mesh construction goes through ``repro.common.compat.make_mesh`` so the
same code runs on jax 0.4.x and newer releases.
"""
from __future__ import annotations

from repro.common.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_hwa_mesh(n_replicas: int = 2, *, multi_pod: bool = False):
    """Mesh with an explicit HWA replica axis.

    multi_pod: (pod=K, data, model) — one HWA replica per pod, inter-pod
    traffic only at synchronization (the paper's communication story).
    single pod: (replica=K, data=16/K, model=16).
    """
    if multi_pod:
        return make_mesh((n_replicas, 16, 16), ("replica", "data", "model"))
    assert 16 % n_replicas == 0, n_replicas
    return make_mesh((n_replicas, 16 // n_replicas, 16),
                     ("replica", "data", "model"))


def make_test_mesh(shape=(2, 2, 2), axes=("replica", "data", "model")):
    """Small mesh for CI-scale SPMD tests (requires forced host devices)."""
    return make_mesh(shape, axes)


def make_tree_test_mesh(shape=(2, 2, 2), axes=("pod", "replica", "model")):
    """Pod-carved test mesh for the two-level sync tree (8 forced host
    devices): K = 4 replicas as 2 pods × 2 members, with a real ``model``
    TP axis so the shard-aware packed layout is exercised under the tree.

    The replica population is split over TWO axes — ``pod`` (slow,
    expensive cross-pod links) and ``replica`` (fast, pod-internal) — so
    the tree's inner sync reduces over ``replica`` only and the rare
    outer sync adds the one cross-``pod`` all-reduce
    (``launch.sync.topology.TwoLevel``). Axis order is pod-major: the
    stacked K dim sharded over ``("pod", "replica")`` keeps each pod a
    CONTIGUOUS replica block, which the 0-ULP flat↔tree parity relies on
    (docs/ARCHITECTURE.md §4). At production scale the driver carves the
    same (pod, replica, data, model) shape from the real topology
    (``repro.launch.train --sync-tree two-level``).
    """
    return make_mesh(shape, axes)
