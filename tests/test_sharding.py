"""Sharding-rule resolution: divisibility fallthrough, no axis reuse, and
full-config param specs for all 10 archs on both production meshes
(pure spec logic — no devices needed)."""
import types

import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.registry import build_model
from repro.sharding.rules import make_tp_rules, spec_for_dims


class FakeMesh:
    """Only .shape (a Mapping) is needed for spec resolution."""
    def __init__(self, shape):
        self.shape = shape


SINGLE = FakeMesh({"data": 16, "model": 16})
MULTI = FakeMesh({"pod": 2, "data": 16, "model": 16})


def rules_for(mesh, **kw):
    return make_tp_rules(mesh, **kw)


def test_divisibility_fallthrough_gqa():
    rules = rules_for(SINGLE)
    # kv_heads=8 does not divide 16 -> falls through to head_dim
    spec = spec_for_dims(SINGLE, rules.rules,
                         ("embed", "kv_heads", "head_dim"), (2048, 8, 128))
    assert tuple(spec) == (None, None, "model")
    # kv_heads=16 divides -> takes model; head_dim must NOT reuse it
    spec = spec_for_dims(SINGLE, rules.rules,
                         ("embed", "kv_heads", "head_dim"), (2048, 16, 128))
    assert tuple(spec) == (None, "model")


def test_no_axis_reuse():
    rules = rules_for(SINGLE)
    spec = spec_for_dims(SINGLE, rules.rules,
                         ("vocab", "mlp"), (256000, 22528))
    assert tuple(spec) == ("model",)        # mlp can't reuse model


def test_batch_spans_pod_and_data_on_multipod():
    rules = rules_for(MULTI)
    spec = spec_for_dims(MULTI, rules.rules, ("batch", None), (256, 4096))
    assert tuple(spec) == (("pod", "data"),)
    # batch=1 (long_500k) cannot shard -> replicated
    spec = spec_for_dims(MULTI, rules.rules, ("batch", None), (1, 4096))
    assert tuple(spec) == ()


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh", [SINGLE, MULTI], ids=["single", "multi"])
def test_all_arch_param_specs_resolve(arch, mesh):
    cfg = get_config(arch)
    lm = build_model(cfg)
    params_abs, dims = lm.abstract()
    rules = rules_for(mesh, fsdp=True, sequence_parallel=True)
    import jax
    is_dims = lambda t: isinstance(t, tuple) and all(
        isinstance(e, (str, type(None))) for e in t)
    flat_p = jax.tree.leaves(params_abs)
    flat_d = jax.tree.leaves(dims, is_leaf=is_dims)
    assert len(flat_p) == len(flat_d)
    for leaf, d in zip(flat_p, flat_d):
        spec = spec_for_dims(mesh, rules.rules, d, leaf.shape)
        # every sharded dim divides the axis product
        import math
        for dim_size, assignment in zip(leaf.shape, tuple(spec)):
            if assignment is None:
                continue
            axes = assignment if isinstance(assignment, tuple) else (assignment,)
            assert dim_size % math.prod(mesh.shape[a] for a in axes) == 0


def test_replica_axis_rule():
    mesh = FakeMesh({"replica": 2, "data": 8, "model": 16})
    rules = make_tp_rules(mesh, replica_axis="replica")
    spec = spec_for_dims(mesh, rules.rules, ("replica", "embed", "mlp"),
                         (2, 2048, 8192))
    assert tuple(spec) == ("replica", None, "model")
    # batch excludes the replica axis
    spec = spec_for_dims(mesh, rules.rules, ("batch", None), (128, 64))
    assert tuple(spec) in ((("data",),), ("data",))