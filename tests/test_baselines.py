"""SWA / EMA / Lookahead / SAM baseline correctness."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.baselines import (ema_init, ema_update, lookahead_init,
                                  lookahead_update, sam_gradient, swa_init,
                                  swa_params, swa_update)


def t(seed):
    return {"w": jax.random.normal(jax.random.key(seed), (5,))}


def test_swa_running_average_exact():
    ps = [t(i) for i in range(6)]
    st = swa_init(ps[0])
    st = st.__class__(avg=jax.tree.map(jnp.zeros_like, st.avg),
                      n=st.n)  # start empty
    for p in ps:
        st = swa_update(st, p)
    expect = np.mean([np.asarray(p["w"]) for p in ps], axis=0)
    np.testing.assert_allclose(np.asarray(swa_params(st, ps[0])["w"]),
                               expect, rtol=1e-5)


def test_ema_decay():
    p0, p1 = t(0), t(1)
    st = ema_init(p0, decay=0.9)
    st = ema_update(st, p1)
    expect = 0.9 * np.asarray(p0["w"]) + 0.1 * np.asarray(p1["w"])
    np.testing.assert_allclose(np.asarray(st.avg["w"]), expect, rtol=1e-5)


def test_lookahead_pulls_fast_toward_slow():
    slow0, fast = t(0), t(1)
    st = lookahead_init(slow0, k=5, alpha=0.5)
    st, new_fast = lookahead_update(st, fast)
    expect = 0.5 * (np.asarray(slow0["w"]) + np.asarray(fast["w"]))
    np.testing.assert_allclose(np.asarray(new_fast["w"]), expect, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(st.slow["w"]), expect, rtol=1e-5)


def test_sam_gradient_differs_from_plain():
    def loss_fn(p, batch):
        l = jnp.sum(jnp.sin(p["w"]) ** 2)
        return l, {"loss": l}

    p = t(3)
    (_, _), g_plain = jax.value_and_grad(loss_fn, has_aux=True)(p, None)
    (_, _), g_sam = sam_gradient(loss_fn, p, None, rho=0.5)
    diff = float(jnp.max(jnp.abs(g_plain["w"] - g_sam["w"])))
    assert diff > 1e-5
    assert bool(jnp.all(jnp.isfinite(g_sam["w"])))
