"""Optimizer + schedule unit tests (closed-form checks)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (adamw, apply_updates, cosine_schedule, sgd,
                         step_decay_schedule, swa_constant_schedule,
                         cyclic_schedule)


def test_sgd_momentum_matches_torch_semantics():
    """mu <- m*mu + g (+wd*p);  p <- p - lr*mu."""
    p = {"w": jnp.asarray([1.0, -2.0])}
    opt = sgd(momentum=0.9, weight_decay=0.1)
    state = opt.init(p)
    g = {"w": jnp.asarray([0.5, 0.5])}
    lr = 0.1
    mu = np.zeros(2)
    pw = np.array([1.0, -2.0])
    for _ in range(3):
        upd, state = opt.update(g, state, p, lr)
        p = apply_updates(p, upd)
        geff = np.array([0.5, 0.5]) + 0.1 * pw
        mu = 0.9 * mu + geff
        pw = pw - lr * mu
        np.testing.assert_allclose(np.asarray(p["w"]), pw, rtol=1e-5)


def test_adamw_first_step_is_lr_sized():
    p = {"w": jnp.ones((4,))}
    opt = adamw(b1=0.9, b2=0.999, weight_decay=0.0)
    state = opt.init(p)
    g = {"w": jnp.full((4,), 0.3)}
    upd, state = opt.update(g, state, p, 1e-3)
    # bias-corrected first step = -lr * g/|g| = -lr (sign step)
    np.testing.assert_allclose(np.asarray(upd["w"]),
                               -1e-3 * np.ones(4), rtol=1e-3)


def test_cosine_schedule_endpoints():
    s = cosine_schedule(0.1, 100)
    assert abs(float(s(0)) - 0.1) < 1e-6
    assert float(s(100)) < 1e-6
    assert 0 < float(s(50)) < 0.1


def test_step_decay():
    s = step_decay_schedule(1.0, decay_every=10, gamma=0.1)
    np.testing.assert_allclose(float(s(0)), 1.0)
    np.testing.assert_allclose(float(s(10)), 0.1, rtol=1e-5)
    np.testing.assert_allclose(float(s(25)), 0.01, rtol=1e-5)


def test_swa_schedule_switches_to_constant():
    base = cosine_schedule(0.1, 100)
    s = swa_constant_schedule(base, swa_start_step=80, swa_lr=0.05)
    assert abs(float(s(10)) - float(base(10))) < 1e-7
    assert abs(float(s(90)) - 0.05) < 1e-7


def test_cyclic_schedule_saw():
    s = cyclic_schedule(0.1, 0.01, cycle_steps=10)
    assert abs(float(s(0)) - 0.1) < 1e-6
    assert abs(float(s(9)) - 0.01) < 1e-6
    assert abs(float(s(10)) - 0.1) < 1e-6
