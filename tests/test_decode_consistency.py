"""Cached decode must match teacher forcing exactly (all cache kinds:
KV ring buffers, sliding windows, SSM states, hybrid, multi-codebook),
and the paged serving engine must match the whole-batch engine bitwise
on every family while compiling its decode step exactly once."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_lm_batch
from repro.configs import get_smoke_config
from repro.models.registry import build_model
from repro.serve.engine import DecodeEngine, PagedDecodeEngine

ARCHS = ["granite-3-2b", "gemma2-27b", "xlstm-125m", "hymba-1.5b",
         "musicgen-medium", "internvl2-1b", "qwen2-moe-a2.7b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_teacher_forcing(arch):
    cfg = get_smoke_config(arch)
    lm = build_model(cfg)
    params = lm.init(jax.random.key(0))
    B, S = 2, 24
    batch = make_lm_batch(cfg, B=B, S=S)
    tf_logits, _ = lm.apply(params, batch)

    Sp = S - 4
    pre = dict(batch)
    pre.pop("targets")
    pre["tokens"] = batch["tokens"][:, :Sp]
    cache, _ = lm.init_cache(B, S)
    logits, cache = lm.prefill(params, cache, pre)
    errs = [float(jnp.max(jnp.abs(logits - tf_logits[:, Sp - 1])))]
    for t in range(Sp, S):
        tok = batch["tokens"][:, t]
        logits, cache = lm.decode_step(params, cache, tok)
        errs.append(float(jnp.max(jnp.abs(logits - tf_logits[:, t]))))
    assert max(errs) < 2e-4, errs


@pytest.mark.parametrize("arch", ARCHS)
def test_paged_engine_matches_whole_batch_engine(arch):
    """The paged serving path (block-table cache, chunk/step prefill,
    fixed-shape continuous step) must emit BIT-equal greedy tokens to the
    whole-batch reference engine, with exactly one step trace."""
    cfg = get_smoke_config(arch)
    lm = build_model(cfg)
    params = lm.init(jax.random.key(0))
    batch = make_lm_batch(cfg, B=2, S=9)

    ref = DecodeEngine(lm=lm, params=params, max_seq_len=64)
    want = np.asarray(ref.generate(batch, 6))

    eng = PagedDecodeEngine(lm=lm, params=params, max_batch=2,
                            max_seq_len=64, max_new=6, page_size=4,
                            prefill_chunk=16)
    got = np.asarray(eng.generate(batch, 6))
    np.testing.assert_array_equal(got, want)
    assert eng.step_traces == 1, "paged decode step retraced"
