"""Serving tier: paged-attention op matrix (jnp gather reference AND the
Pallas scalar-prefetch kernel vs the contiguous naive oracle), the page
manager's allocation/reservation/defrag invariants, the continuous
scheduler's bit-parity with the whole-batch engine under random ragged
admit/finish traces, and zero-downtime WA weight hot-swap."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_lm_batch
from repro.configs import get_smoke_config
from repro.kernels.paged_attention import paged_attention
from repro.models.attention import naive_attention
from repro.models.cache import TRASH_PAGE, paged_table_width
from repro.models.registry import build_model
from repro.serve.engine import DecodeEngine, PagedDecodeEngine
from repro.serve.pages import PageManager
from repro.serve.publish import WeightPublisher
from repro.serve.scheduler import ContinuousScheduler, Request


# ------------------------------------------------------------ op matrix


def _ring_fill(ks, vs, lens, ps, TW):
    """Host simulation of the engine's write path: allocate a page the
    first time a ring slot is touched, reuse it in place after the ring
    wraps (sliding-window eviction), write every token's K/V."""
    B, Smax = ks.shape[:2]
    NP = 1 + B * TW
    k_pages = np.zeros((NP, ps) + ks.shape[2:], ks.dtype)
    v_pages = np.zeros_like(k_pages)
    tables = np.full((B, TW), TRASH_PAGE, np.int32)
    nxt = 1
    for b in range(B):
        for pos in range(int(lens[b])):
            j = (pos // ps) % TW
            if tables[b, j] == TRASH_PAGE:
                tables[b, j] = nxt
                nxt += 1
            k_pages[tables[b, j], pos % ps] = ks[b, pos]
            v_pages[tables[b, j], pos % ps] = vs[b, pos]
    return k_pages, v_pages, tables


# (page_size, window, Hkv, G, dtype, lens): ragged lengths cross page
# boundaries; lens > window exercises in-place ring eviction; len 1 and
# exact-multiple lens hit the boundary cases; G spans the GQA matrix.
CASES = [
    (4, None, 2, 2, "float32", (12, 7)),
    (2, None, 2, 1, "float32", (9, 2)),
    (8, None, 1, 4, "float32", (17, 8)),
    (4, 5, 2, 2, "float32", (12, 3)),
    (4, 16, 2, 2, "float32", (33, 16)),     # eviction: len ≫ window
    (2, 7, 4, 1, "float32", (21, 1)),
    (4, None, 2, 2, "bfloat16", (13, 6)),
    (4, 16, 2, 4, "bfloat16", (33, 9)),
]


@pytest.mark.parametrize("ps,window,Hkv,G,dtype,lens", CASES)
@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_paged_attention_matches_contiguous_oracle(ps, window, Hkv, G,
                                                   dtype, lens, impl):
    lens = np.asarray(lens, np.int32)
    B, Smax, Hq, D = len(lens), int(lens.max()), Hkv * G, 16
    TW = paged_table_width(64, window, ps)
    ks_ = jax.random.split(jax.random.key(int(lens.sum())), 4)
    q = jax.random.normal(ks_[0], (B, Hq, D)).astype(dtype)
    kfull = jax.random.normal(ks_[1], (B, Smax, Hkv, D)).astype(dtype)
    vfull = jax.random.normal(ks_[2], (B, Smax, Hkv, D)).astype(dtype)

    k_pages, v_pages, tables = _ring_fill(np.asarray(kfull),
                                          np.asarray(vfull), lens, ps, TW)
    got = paged_attention(q, jnp.asarray(k_pages), jnp.asarray(v_pages),
                          jnp.asarray(tables), jnp.asarray(lens),
                          window=window, logit_softcap=30.0, impl=impl)

    # contiguous oracle: full history + band mask (evicted positions are
    # outside the window by the table-width invariant)
    k_pos = np.broadcast_to(np.arange(Smax), (B, Smax)).copy()
    k_pos[k_pos >= lens[:, None]] = -1
    want = naive_attention(q[:, None], kfull, vfull,
                           (lens - 1)[:, None], jnp.asarray(k_pos),
                           window=window, logit_softcap=30.0)[:, 0]
    tol = 2e-2 if dtype == "bfloat16" else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_paged_attention_zero_len_slot_is_finite():
    """An inactive batch slot (len 0, all-trash table) must produce
    zeros, not NaN — the all-masked safe-division guarantee."""
    B, Hkv, G, D, ps, TW = 2, 2, 2, 16, 4, 3
    q = jax.random.normal(jax.random.key(0), (B, Hkv * G, D))
    pool = jnp.zeros((1 + TW, ps, Hkv, D))
    tables = np.full((B, TW), TRASH_PAGE, np.int32)
    tables[0] = [1, 2, 3]
    lens = jnp.asarray([5, 0], jnp.int32)
    for impl in ("jnp", "pallas"):
        out = paged_attention(q, pool, pool, jnp.asarray(tables), lens,
                              impl=impl)
        assert bool(jnp.all(jnp.isfinite(out)))
        assert bool(jnp.all(out[1] == 0.0))


# ---------------------------------------------------------- page manager


def test_page_manager_reservation_and_ring_reuse():
    pm = PageManager(n_pages=8, page_size=4, table_width=3, max_slots=2)
    assert pm.pages_needed(4 * 3 + 5) == 3          # capped at the ring
    assert pm.can_admit(24)
    s0 = pm.admit(24)                                # reserves 3
    assert pm.available_pages == 4
    s1 = pm.admit(24)
    assert not pm.can_admit(4)                       # slots exhausted
    # lazy assignment: one page per first ring-slot touch, then reuse
    assert pm.touch(s0, 0) and pm.touch(s0, 4) and pm.touch(s0, 8)
    assert not pm.touch(s0, 12)                      # ring wrap: reuse
    assert pm.tables[s0, 0] != TRASH_PAGE
    pm.release(s0)
    assert all(pm.tables[s0] == TRASH_PAGE)
    assert pm.can_admit(24)
    pm.release(s1)
    assert pm.free_pages == 7


def test_page_manager_defrag_preserves_contents():
    pm = PageManager(n_pages=12, page_size=2, table_width=2, max_slots=3)
    slots = [pm.admit(8) for _ in range(3)]
    for s in slots:
        pm.touch_range(s, 0, 8)
    pm.release(slots[1])                             # punch a hole
    pool = np.arange(12 * 2 * 3, dtype=np.float32).reshape(12, 2, 3)
    before = {(s, j): pool[pm.tables[s, j]].copy()
              for s in (slots[0], slots[2]) for j in range(2)}
    perm = pm.defrag()
    assert perm[TRASH_PAGE] == TRASH_PAGE
    assert sorted(int(p) for row in pm.tables[[slots[0], slots[2]]]
                  for p in row) == [1, 2, 3, 4]      # compacted to front
    new_pool = pool[np.argsort(perm)]                # engine's re-gather
    for (s, j), want in before.items():
        np.testing.assert_array_equal(new_pool[pm.tables[s, j]], want)


def test_engine_apply_page_perm_matches_defrag():
    cfg = get_smoke_config("gemma2-27b")
    lm = build_model(cfg)
    params = lm.init(jax.random.key(0))
    batch = make_lm_batch(cfg, B=2, S=9)
    eng = PagedDecodeEngine(lm=lm, params=params, max_batch=2,
                            max_seq_len=64, max_new=6, page_size=4,
                            prefill_chunk=16)
    a = np.asarray(eng.generate(batch, 3))
    # defrag between requests, then serve again through remapped tables
    perm = eng.pages.defrag()
    eng.apply_page_perm(perm)
    b = np.asarray(eng.generate(batch, 3))
    np.testing.assert_array_equal(a, b)
    assert eng.step_traces == 1


# ------------------------------------------- scheduler property (parity)


@pytest.mark.parametrize("arch,seed", [("granite-3-2b", 0),
                                       ("gemma2-27b", 1)])
def test_scheduler_random_trace_bit_equals_whole_batch(arch, seed):
    """Random ragged admit/finish traces through the continuous scheduler
    must emit BIT-equal tokens to the whole-batch reference engine, while
    the decode step compiles exactly once (no admit/evict retrace)."""
    cfg = get_smoke_config(arch)
    lm = build_model(cfg)
    params = lm.init(jax.random.key(0))
    rng = np.random.RandomState(seed)
    reqs = [Request(rid=i,
                    tokens=rng.randint(0, cfg.vocab_size,
                                       size=(int(rng.randint(2, 13)),)
                                       ).astype(np.int32),
                    n_new=int(rng.randint(1, 7)),
                    arrival=int(rng.randint(0, 6)))
            for i in range(7)]
    eng = PagedDecodeEngine(lm=lm, params=params, max_batch=3,
                            max_seq_len=64, max_new=8, page_size=4,
                            prefill_chunk=16)
    outs = ContinuousScheduler(eng).run(reqs, max_steps=600)
    assert eng.step_traces == 1, "decode step retraced on admit/evict"

    ref = DecodeEngine(lm=lm, params=params, max_seq_len=64)
    for r in reqs:
        want = np.asarray(ref.generate(
            {"tokens": jnp.asarray(r.tokens[None])}, r.n_new))[0]
        np.testing.assert_array_equal(outs[r.rid], want,
                                      err_msg=f"rid {r.rid}")


def test_scheduler_step_prefill_trace_recurrent():
    """Hybrid (attn ‖ mamba) requests ride the step-prefill lane; ragged
    arrivals must still match the whole-batch engine bit-for-bit."""
    cfg = get_smoke_config("hymba-1.5b")
    lm = build_model(cfg)
    params = lm.init(jax.random.key(0))
    rng = np.random.RandomState(3)
    reqs = [Request(rid=i,
                    tokens=rng.randint(0, cfg.vocab_size,
                                       size=(int(rng.randint(2, 9)),)
                                       ).astype(np.int32),
                    n_new=int(rng.randint(1, 5)),
                    arrival=int(rng.randint(0, 4)))
            for i in range(4)]
    eng = PagedDecodeEngine(lm=lm, params=params, max_batch=2,
                            max_seq_len=64, max_new=6, page_size=4,
                            prefill_chunk=16)
    outs = ContinuousScheduler(eng).run(reqs, max_steps=600)
    assert eng.step_traces == 1
    ref = DecodeEngine(lm=lm, params=params, max_seq_len=64)
    for r in reqs:
        want = np.asarray(ref.generate(
            {"tokens": jnp.asarray(r.tokens[None])}, r.n_new))[0]
        np.testing.assert_array_equal(outs[r.rid], want,
                                      err_msg=f"rid {r.rid}")


# --------------------------------------------------------- weight hot-swap


def test_hot_swap_mid_decode_zero_downtime():
    """Publish new weights between decode steps: the repack is bit-exact
    (even from a shard-aware source layout), the continuation equals a
    run that switched params at the same step, and the step never
    retraces (zero downtime — no skipped or recompiled step)."""
    from repro.common.packing import pack, pack_spec

    cfg = get_smoke_config("granite-3-2b")
    lm = build_model(cfg)
    params1 = lm.init(jax.random.key(0))
    params2 = lm.init(jax.random.key(7))
    batch = make_lm_batch(cfg, B=2, S=10)

    def drive(engine, swap_fn):
        sched = ContinuousScheduler(engine)
        engine.reset_state(0)
        acts = [sched._admit(Request(rid=b,
                                     tokens=np.asarray(batch["tokens"][b]),
                                     n_new=8)) for b in range(2)]
        active = {a.slot: a for a in acts}

        def one_step():
            ctrl = sched._build_ctrl(active, 2, engine.scratch_idx,
                                     False, None)
            engine.step(ctrl)
            for a in active.values():
                a.fresh = False
                a.pos += 1
                a.emitted += 1
        for _ in range(3):
            one_step()
        swap_fn(engine)
        for _ in range(5):
            one_step()
        return np.stack([engine.read_out(a.slot, 8) for a in acts])

    eng_pub = PagedDecodeEngine(lm=lm, params=params1, max_batch=2,
                                max_seq_len=64, max_new=8, page_size=4,
                                prefill_chunk=16)
    pub = WeightPublisher(engine=eng_pub)
    # source buffer under a DIFFERENT (shard-aware, 2-segment) layout
    shard_dims = [None] * len(jax.tree.leaves(params2))
    src_spec = pack_spec(params2, shards=2, shard_dims=shard_dims)
    buf = pack(params2, src_spec)

    def publish(engine):
        new = pub.publish_packed(buf, src_spec)
        for a, b in zip(jax.tree.leaves(new), jax.tree.leaves(params2)):
            assert bool(jnp.all(a == b)), "repack not bit-exact"

    got = drive(eng_pub, publish)
    assert eng_pub.step_traces == 1, "hot-swap retraced the decode step"

    eng_ref = PagedDecodeEngine(lm=lm, params=params1, max_batch=2,
                                max_seq_len=64, max_new=8, page_size=4,
                                prefill_chunk=16)
    want = drive(eng_ref, lambda e: e.set_params(params2))
    np.testing.assert_array_equal(got, want)
    # and the swapped continuation really runs the NEW weights
    eng_old = PagedDecodeEngine(lm=lm, params=params1, max_batch=2,
                                max_seq_len=64, max_new=8, page_size=4,
                                prefill_chunk=16)
    stale = drive(eng_old, lambda e: None)
    assert not np.array_equal(got, stale)


def test_publish_from_checkpoint(tmp_path):
    """W̿ published straight from a window-state checkpoint equals the
    mean of the pushed outer weights, served bitwise."""
    from repro.checkpoint.io import save_window_state
    from repro.core.offline import window_init, window_update

    cfg = get_smoke_config("granite-3-2b")
    lm = build_model(cfg)
    params = lm.init(jax.random.key(0))
    state = window_init(params, window=3)
    outers = [jax.tree.map(lambda p, s=s: p + 0.1 * s, params)
              for s in (1, 2)]
    for o in outers:
        state, avg = window_update(state, o)
    path = str(tmp_path / "wa.npz")
    save_window_state(path, state)

    eng = PagedDecodeEngine(lm=lm, params=params, max_batch=1,
                            max_seq_len=32, max_new=4, page_size=4,
                            prefill_chunk=8)
    new = WeightPublisher(engine=eng).publish_checkpoint(path)
    for got, a, b in zip(jax.tree.leaves(new), jax.tree.leaves(outers[0]),
                         jax.tree.leaves(outers[1])):
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray((a + b) / 2))
    assert eng.params is new
