"""Resilience stack: alive-mask math, fault injectors, checkpoint
sessions, and bit-exact resume.

The plain (stacked) trainer's resume-exactness is checked in-process
here; the mesh-native path needs 8 forced host devices, so it runs as a
subprocess through ``tools/fault_check.py --only resume-exact`` (the
same leg `make fault-check` runs in CI)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.resilience import (CheckpointSession, InjectedIOError, KillAt,
                              SimulatedCrash, TransientIO, flip_bit,
                              masked_mean_axis0, poison_replica,
                              quarantine_opt_state, renormalized_inv,
                              replica_alive_mask, truncate_file)


# ------------------------------------------------------- alive-mask math


def _stacked(k=4, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.standard_normal((k, 3, 5)).astype(np.float32)),
        "b": jnp.asarray(rng.standard_normal((k, 7)).astype(np.float32)
                         ).astype(jnp.bfloat16),
        "count": jnp.full((k,), 3, jnp.int32),
    }


def _bits_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        xa, ya = np.asarray(x), np.asarray(y)
        assert xa.dtype == ya.dtype and xa.shape == ya.shape
        assert np.array_equal(xa, ya), (xa, ya)


def test_masked_mean_all_alive_is_plain_mean_bitwise():
    from repro.common.pytree import tree_mean_axis0
    tree = _stacked()
    alive = jnp.ones((4,), jnp.bool_)
    _bits_equal(jax.jit(masked_mean_axis0)(tree, alive),
                tree_mean_axis0(tree))


def test_masked_mean_excludes_dead_replica():
    tree = _stacked()
    dead = 1
    tree["w"] = tree["w"].at[dead].set(jnp.nan)
    alive = jnp.ones((4,), jnp.bool_).at[dead].set(False)
    got = masked_mean_axis0(tree, alive)
    assert bool(jnp.all(jnp.isfinite(got["w"])))
    keep = [i for i in range(4) if i != dead]
    ref = np.asarray(_stacked()["w"], np.float64)[keep].mean(0)
    np.testing.assert_allclose(np.asarray(got["w"], np.float64), ref,
                               atol=1e-6)


def test_masked_mean_all_dead_degrades_to_plain_mean():
    """Nothing left to average: the mask is dropped (plain mean of
    everyone) instead of restarting from zeros."""
    from repro.common.pytree import tree_mean_axis0
    tree = _stacked()
    got = masked_mean_axis0(tree, jnp.zeros((4,), jnp.bool_))
    want = tree_mean_axis0(tree)
    _bits_equal(got["count"], want["count"])
    np.testing.assert_allclose(np.asarray(got["w"]), np.asarray(want["w"]),
                               atol=1e-6)


def test_replica_alive_mask_finiteness_and_rms():
    tree = _stacked()
    assert bool(jnp.all(replica_alive_mask(tree)))
    poisoned = dict(tree)
    poisoned["w"] = tree["w"].at[2, 0, 0].set(jnp.inf)
    mask = replica_alive_mask(poisoned)
    assert [bool(m) for m in mask] == [True, True, False, True]
    # divergence probe: blow one replica up past the RMS threshold
    blown = dict(tree)
    blown["w"] = tree["w"].at[0].mul(1e4)
    mask = replica_alive_mask(blown, max_rms=100.0)
    assert not bool(mask[0]) and bool(jnp.all(mask[1:]))


def test_renormalized_inv_pins_trace_time_constant():
    for k in (2, 3, 4, 6, 8):
        pinned = renormalized_inv(jnp.float32(k), k)
        assert np.asarray(pinned).tobytes() == \
            np.float32(1.0 / k).tobytes()
    # degraded: exact 1/k_alive (and never a division by zero)
    assert float(renormalized_inv(jnp.float32(2.0), 4)) == 0.5
    assert np.isfinite(float(renormalized_inv(jnp.float32(0.0), 4)))


def test_quarantine_opt_state_zeros_dead_slots_only():
    opt = {"mu": jnp.ones((4, 3, 5)), "nu": jnp.full((4, 7), 2.0),
           "count": jnp.ones((), jnp.int32)}   # scalar: not per-replica
    alive = jnp.array([True, False, True, True])
    got = quarantine_opt_state(opt, alive)
    assert bool(jnp.all(got["mu"][1] == 0)) and bool(jnp.all(got["nu"][1] == 0))
    assert bool(jnp.all(got["mu"][0] == 1))
    assert int(got["count"]) == 1              # passed through untouched
    _bits_equal(quarantine_opt_state(opt, jnp.ones((4,), jnp.bool_)), opt)


def test_poison_replica_targets_floating_leaves():
    tree = _stacked()
    got = poison_replica(tree, 2)
    assert bool(jnp.all(jnp.isnan(got["w"][2])))
    assert bool(jnp.all(jnp.isfinite(got["w"][0])))
    _bits_equal(got["count"], tree["count"])   # int leaf untouched


# --------------------------------------------------------- fault injectors


def test_kill_at_fires_on_nth_occurrence(tmp_path):
    p = str(tmp_path / "victim.bin")
    with open(p, "wb") as f:
        f.write(b"x" * 100)
    kill = KillAt("manifest_write", occurrence=2, truncate_frac=0.5)
    kill("array_write", p)                      # wrong point: no-op
    kill("manifest_write", p)                   # occurrence 1: no-op
    assert os.path.getsize(p) == 100
    with pytest.raises(SimulatedCrash):
        kill("manifest_write", p)               # occurrence 2: truncate+die
    assert os.path.getsize(p) == 50
    # SimulatedCrash models a preemption: it must escape `except Exception`
    assert not issubclass(SimulatedCrash, Exception)
    assert issubclass(SimulatedCrash, BaseException)


def test_transient_io_raises_then_clears(tmp_path):
    t = TransientIO("array_write", times=2)
    for _ in range(2):
        with pytest.raises(InjectedIOError):
            t("array_write", "whatever")
    t("array_write", "whatever")                # healed
    assert issubclass(InjectedIOError, OSError)  # the retried class


def test_truncate_and_flip_bit(tmp_path):
    p = str(tmp_path / "blob.bin")
    payload = bytes(range(256))
    with open(p, "wb") as f:
        f.write(payload)
    truncate_file(p, frac=0.25)
    assert os.path.getsize(p) == 64
    flip_bit(p)
    with open(p, "rb") as f:
        got = f.read()
    diff = [i for i in range(64) if got[i] != payload[i]]
    assert len(diff) == 1                        # exactly one byte, one bit
    assert bin(got[diff[0]] ^ payload[diff[0]]).count("1") == 1


# ------------------------------------------------------ checkpoint session


def _demo(seed=0):
    rng = np.random.default_rng(seed)
    return {"w": rng.standard_normal((5, 7)).astype(np.float32),
            "b": rng.standard_normal((11,)).astype(np.float32)}


def test_session_roundtrip_meta_and_gc(tmp_path):
    sess = CheckpointSession(str(tmp_path), keep=2)
    for step in (4, 8, 12):
        sess.save(step, {"state": _demo(step)},
                  meta={"step": step, "note": "hi"})
    assert sess.steps() == [8, 12]               # keep=2 GC'd step 4
    assert sess.latest_intact() == 12
    assert sess.meta(12)["step"] == 12
    _bits_equal(sess.load(12, "state", _demo(0)), _demo(12))
    ok, problems = sess.verify(12)
    assert ok, problems


def test_session_falls_back_past_corruption(tmp_path):
    sess = CheckpointSession(str(tmp_path), keep=3)
    sess.save(4, {"state": _demo(4)})
    sess.save(8, {"state": _demo(8)})
    flip_bit(os.path.join(sess.step_dir(8), "state.npz"))
    ok, problems = sess.verify(8)       # CRC mismatch or unreadable zip
    assert not ok and problems, problems
    assert sess.latest_intact() == 4
    # a torn dir (no manifest) is not a checkpoint at all
    os.remove(os.path.join(sess.step_dir(4), "manifest.json"))
    assert sess.latest_intact() is None


def test_session_retries_transient_io(tmp_path):
    sess = CheckpointSession(str(tmp_path), retries=3, backoff=0.0,
                             fault_injector=TransientIO("array_write",
                                                        times=2),
                             sleep=lambda s: None)
    sess.save(4, {"state": _demo(1)})
    assert sess.io_retries == 2
    assert sess.latest_intact() == 4


def test_session_kill_mid_manifest_keeps_previous(tmp_path):
    sess = CheckpointSession(str(tmp_path),
                             fault_injector=KillAt("manifest_write",
                                                   occurrence=2,
                                                   truncate_frac=0.4))
    sess.save(4, {"state": _demo(4)})
    with pytest.raises(SimulatedCrash):
        sess.save(8, {"state": _demo(8)})
    fresh = CheckpointSession(str(tmp_path))
    assert fresh.latest_intact() == 4
    _bits_equal(fresh.load(4, "state", _demo(0)), _demo(4))


# -------------------------------------------------- bit-exact resume (plain)


def _trainer(tmp_path=None, *, steps, resume=False, every=0):
    from repro.core import HWAConfig
    from repro.data import DataPipeline, make_markov_lm_dataset
    from repro.models import build_model
    from repro.models.types import ModelConfig
    from repro.train import TrainConfig, Trainer, lm_task

    tiny = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=32,
                       n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=32,
                       attn_impl="naive", remat="none", dtype="float32")
    lm = build_model(tiny)
    ds = make_markov_lm_dataset(vocab=32, seq_len=32, n_train=256,
                                n_test=64, seed=0)
    pipe = DataPipeline(ds, batch_size=8, n_replicas=2, seed=0)
    tc = TrainConfig(method="hwa", total_steps=steps, batch_size=8,
                     base_lr=0.5, eval_every=8,
                     hwa=HWAConfig(n_replicas=2, sync_period=4, window=3),
                     checkpoint_dir=str(tmp_path) if tmp_path else "",
                     checkpoint_every=every, resume=resume)
    return Trainer(lm_task(lm, pipe), tc)


def test_trainer_resume_bit_exact(tmp_path):
    """N steps, checkpoint, kill, resume: the resumed run's final W̿ (and
    history) is bit-identical to the uninterrupted run's."""
    clean = _trainer(steps=16).run()
    # checkpointing must be observation-free on the training math
    first = _trainer(tmp_path, steps=16, every=8).run()
    _bits_equal(clean["params"], first["params"])
    # "preemption": the newest (step-16) checkpoint is corrupted on disk;
    # resume falls back to step 8 and recomputes 8..16 bit-exactly
    flip_bit(os.path.join(str(tmp_path), "step_00000016", "hwa.npz"))
    resumed = _trainer(tmp_path, steps=16, every=8, resume=True).run()
    _bits_equal(clean["params"], resumed["params"])
    assert [h["step"] for h in clean["history"]] == \
        [h["step"] for h in resumed["history"]]
    assert clean["history"][-1]["test_loss"] == \
        resumed["history"][-1]["test_loss"]


def test_trainer_resume_config_validation(tmp_path):
    import dataclasses

    with pytest.raises(ValueError, match="resume"):
        _trainer(None, steps=4, resume=True).run()
    bad = _trainer(tmp_path, steps=4, every=4)
    bad.tc = dataclasses.replace(bad.tc, method="base")
    bad.is_parallel = False
    with pytest.raises(ValueError, match="K-replica"):
        bad.run()


# ------------------------------------------- mesh-native resume (subprocess)


@pytest.mark.timeout(900)
def test_mesh_native_resume_subprocess():
    """`tools/fault_check.py --only resume-exact`: checkpoint at step 4 of
    a mesh-native run, resume to 8, final state bit-identical to the
    uninterrupted 8-step run (8 forced host devices)."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    script = os.path.join(root, "tools", "fault_check.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)       # the launcher sets the 8 host devices
    proc = subprocess.run([sys.executable, script, "--only", "resume-exact"],
                          capture_output=True, text=True, env=env,
                          timeout=850)
    print(proc.stdout)
    print(proc.stderr[-2000:] if proc.stderr else "")
    assert proc.returncode == 0, proc.stdout + proc.stderr[-2000:]
    assert "ALL_OK" in proc.stdout
