"""MoE layer: routing correctness vs a loop-over-experts reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.common import activation
from repro.models.moe import _route, init_moe, moe_forward


def _ref_moe(cfg, p, x):
    """Dense reference: run EVERY expert on every token, combine top-k."""
    B, S, D = x.shape
    N = B * S
    xf = x.reshape(N, D)
    top_p, top_i, aux = _route(cfg, p, xf)
    act = activation(cfg.act)
    expert_out = []
    for e in range(cfg.n_experts):
        h = (act((xf @ p["w_gate"][e]).astype(jnp.float32))
             * (xf @ p["w_up"][e]).astype(jnp.float32)).astype(xf.dtype)
        expert_out.append(h @ p["w_down"][e])
    expert_out = jnp.stack(expert_out, 1)                  # (N, E, D)
    out = jnp.zeros((N, D), jnp.float32)
    for j in range(cfg.top_k):
        sel = jnp.take_along_axis(expert_out, top_i[:, j, None, None],
                                  axis=1)[:, 0]
        out = out + top_p[:, j, None] * sel.astype(jnp.float32)
    if cfg.n_shared_experts:
        h = (act((xf @ p["sh_gate"]).astype(jnp.float32))
             * (xf @ p["sh_up"]).astype(jnp.float32)).astype(xf.dtype)
        shared = (h @ p["sh_down"]).astype(jnp.float32)
        gate = jax.nn.sigmoid(xf.astype(jnp.float32) @ p["sh_route"])
        out = out + gate * shared
    return out.reshape(B, S, D).astype(x.dtype), aux


@pytest.mark.parametrize("arch", ["qwen2-moe-a2.7b", "granite-moe-1b-a400m"])
def test_ragged_moe_matches_dense_reference(arch):
    cfg = get_smoke_config(arch)
    p, _ = init_moe(cfg, jax.random.key(0), jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model), jnp.float32)
    got, aux_g = moe_forward(cfg, p, x)
    want, aux_w = _ref_moe(cfg, p, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(aux_g), float(aux_w), rtol=1e-5)


def test_router_aux_loss_balanced_is_one():
    """Perfectly uniform routing gives aux loss == 1 (Switch convention)."""
    cfg = get_smoke_config("granite-moe-1b-a400m").with_(top_k=1)
    p, _ = init_moe(cfg, jax.random.key(0), jnp.float32)
    # uniform router: zero weights
    p["router"] = jnp.zeros_like(p["router"])
    N = 64
    xf = jax.random.normal(jax.random.key(1), (N, cfg.d_model))
    top_p, top_i, aux = _route(cfg, p, xf)
    # probs uniform; occupancy depends on argmax tie-break; P_e = 1/E exactly
    assert 0.9 < float(aux) < 1.6


def test_moe_gradients_flow_to_all_used_params():
    cfg = get_smoke_config("granite-moe-1b-a400m")
    p, _ = init_moe(cfg, jax.random.key(0), jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))

    def loss(p):
        out, aux = moe_forward(cfg, p, x)
        return jnp.sum(out ** 2) + aux

    g = jax.grad(loss)(p)
    assert float(jnp.sum(jnp.abs(g["router"]))) > 0
    assert float(jnp.sum(jnp.abs(g["w_gate"]))) > 0
    assert float(jnp.sum(jnp.abs(g["w_down"]))) > 0


def test_capacity_dispatch_matches_ragged_with_full_capacity():
    """The at-scale capacity kernel == the exact ragged path when no
    tokens are dropped (cf = E guarantees capacity ≥ all assignments)."""
    from repro.models.moe import moe_forward_capacity

    for arch in ["qwen2-moe-a2.7b", "granite-moe-1b-a400m"]:
        cfg = get_smoke_config(arch)
        p, _ = init_moe(cfg, jax.random.key(0), jnp.float32)
        x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
        a, aux_a = moe_forward(cfg, p, x)
        b, aux_b = moe_forward_capacity(cfg, p, x,
                                        capacity_factor=float(cfg.n_experts))
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(float(aux_a), float(aux_b), rtol=1e-6)


def test_capacity_dispatch_drops_overflow_tokens():
    """With tiny capacity, overflow tokens contribute zero (GShard drop)."""
    from repro.models.moe import _capacity_ffn

    cfg = get_smoke_config("granite-moe-1b-a400m").with_(top_k=1)
    p, _ = init_moe(cfg, jax.random.key(0), jnp.float32)
    xf = jax.random.normal(jax.random.key(1), (64, cfg.d_model))
    # synthesize routing: every token to expert 0, weight 1
    top_i = jnp.zeros((64, 1), jnp.int32)
    top_p = jnp.ones((64, 1), jnp.float32)
    out = _capacity_ffn(cfg, p, xf, top_p, top_i, capacity_factor=0.5)
    # capacity = 64*1*0.5/4 = 8 -> exactly 8 rows nonzero
    nonzero = int(jnp.sum(jnp.any(jnp.abs(out) > 0, axis=-1)))
    assert nonzero == 8, nonzero
