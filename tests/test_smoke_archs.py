"""Per-architecture smoke tests (assignment deliverable f).

Reduced same-family variants (≤2 layers, d_model ≤ 512, ≤4 experts): one
forward + one optimizer step + one decode step on CPU, asserting output
shapes and finiteness.
"""
import jax
import jax.numpy as jnp
import pytest

from conftest import make_lm_batch
from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models.registry import build_model
from repro.optim import apply_updates, sgd


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.n_experts:
        assert cfg.n_experts <= 4
    lm = build_model(cfg)
    params = lm.init(jax.random.key(0))
    batch = make_lm_batch(cfg)

    logits, aux = lm.apply(params, batch)
    if cfg.family == "audio":
        assert logits.shape == batch["targets"].shape + (cfg.vocab_size,)
    else:
        assert logits.shape == batch["targets"].shape + (cfg.vocab_size,)
    assert bool(jnp.all(jnp.isfinite(logits)))

    opt = sgd(momentum=0.9)
    opt_state = opt.init(params)
    (loss, metrics), grads = jax.value_and_grad(lm.loss, has_aux=True)(
        params, batch)
    assert bool(jnp.isfinite(loss))
    updates, opt_state = opt.update(grads, opt_state, params, 0.1)
    new_params = apply_updates(params, updates)
    moved = sum(float(jnp.sum(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(new_params),
                                jax.tree.leaves(params)))
    assert moved > 0
    for leaf in jax.tree.leaves(new_params):
        assert bool(jnp.all(jnp.isfinite(leaf)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_shapes(arch):
    cfg = get_smoke_config(arch)
    lm = build_model(cfg)
    params = lm.init(jax.random.key(0))
    B = 2
    cache, dims = lm.init_cache(B, 16)
    tok = (jnp.zeros((B,), jnp.int32) if cfg.family != "audio"
           else jnp.zeros((B, cfg.n_codebooks), jnp.int32))
    logits, cache2 = lm.decode_step(params, cache, tok)
    expected = ((B, cfg.vocab_size) if cfg.family != "audio"
                else (B, cfg.n_codebooks, cfg.vocab_size))
    assert logits.shape == expected
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(cache2["pos"]) == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The exact assigned hyperparameters (no allocation)."""
    cfg = get_config(arch)
    expected = {
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936, 60, 4),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655, 0, 0),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304, 0, 0),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155, 32, 8),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001, 0, 0),
        "granite-3-2b": (40, 2048, 32, 8, 8192, 49155, 0, 0),
        "stablelm-12b": (40, 5120, 32, 8, 13824, 100352, 0, 0),
        "command-r-35b": (40, 8192, 64, 8, 22528, 256000, 0, 0),
        "gemma2-27b": (46, 4608, 32, 16, 36864, 256000, 0, 0),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048, 0, 0),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
           cfg.vocab_size, cfg.n_experts, cfg.top_k)
    assert got == expected
    assert cfg.source
