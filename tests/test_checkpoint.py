"""Checkpoint io + outer-weight store (Algorithm 2's checkpoint path)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import OuterWeightStore, load_pytree, save_pytree
from repro.common.pytree import tree_mean_axis0, tree_stack


def params_like(seed):
    k1, k2 = jax.random.split(jax.random.key(seed))
    return {"stack": [{"w": jax.random.normal(k1, (3, 4))}],
            "b": jax.random.normal(k2, (5,)).astype(jnp.bfloat16)}


def test_roundtrip(tmp_path):
    p = params_like(0)
    path = str(tmp_path / "ckpt.npz")
    save_pytree(path, p)
    q = load_pytree(path, jax.tree.map(jnp.zeros_like, p))
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(q)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_store_window_average_matches_memory(tmp_path):
    store = OuterWeightStore(str(tmp_path / "outer"))
    outers = [params_like(i) for i in range(6)]
    for e, o in enumerate(outers):
        store.save(e, o)
    like = jax.tree.map(jnp.zeros_like, outers[0])
    wa = store.window_average(end_cycle=5, window=3, like=like)
    expect = tree_mean_axis0(tree_stack(
        [jax.tree.map(lambda x: x.astype(jnp.float32), o)
         for o in outers[3:]]))
    for a, b in zip(jax.tree.leaves(wa), jax.tree.leaves(expect)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=1e-2)


def test_store_cycles_listing(tmp_path):
    store = OuterWeightStore(str(tmp_path / "outer"))
    for e in [3, 1, 7]:
        store.save(e, params_like(e))
    assert store.cycles() == [1, 3, 7]


def test_save_truncated_mid_write_keeps_old(tmp_path, monkeypatch):
    """A crash mid-write must never clobber the published file: the
    write goes to a unique tmp name and only an fsync'd complete file is
    renamed over the old one."""
    import os

    import pytest

    path = str(tmp_path / "ckpt.npz")
    old = params_like(0)
    save_pytree(path, old)

    real_fsync = os.fsync

    def dying_fsync(fd):
        real_fsync(fd)
        raise RuntimeError("simulated kill mid-save")

    monkeypatch.setattr(os, "fsync", dying_fsync)
    with pytest.raises(RuntimeError, match="mid-save"):
        save_pytree(path, params_like(1))
    monkeypatch.undo()

    # published file is still the OLD complete checkpoint, tmp is gone
    q = load_pytree(path, jax.tree.map(jnp.zeros_like, old))
    for a, b in zip(jax.tree.leaves(old), jax.tree.leaves(q)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    leftovers = [n for n in os.listdir(tmp_path) if ".tmp." in n]
    assert leftovers == [], leftovers


def test_store_skips_partial_npz_and_verifies(tmp_path):
    """A truncated outer checkpoint inside the window is skipped with a
    warning (average renormalizes); verify() pinpoints it."""
    import warnings

    from repro.resilience.faults import truncate_file

    store = OuterWeightStore(str(tmp_path / "outer"))
    outers = [params_like(i) for i in range(3)]
    for e, o in enumerate(outers):
        store.save(e, o)
    truncate_file(store._path(1), frac=0.5)
    assert list(store.verify()) == [1]

    like = jax.tree.map(jnp.zeros_like, outers[0])
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        wa = store.window_average(end_cycle=2, window=3, like=like)
    assert any("skipping unreadable" in str(w.message) for w in caught)
    expect = tree_mean_axis0(tree_stack(
        [jax.tree.map(lambda x: x.astype(jnp.float32), o)
         for o in (outers[0], outers[2])]))
    for a, b in zip(jax.tree.leaves(wa), jax.tree.leaves(expect)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=1e-2)


def test_store_all_corrupt_raises(tmp_path):
    import pytest

    from repro.resilience.faults import truncate_file

    store = OuterWeightStore(str(tmp_path / "outer"))
    store.save(0, params_like(0))
    truncate_file(store._path(0), frac=0.3)
    like = jax.tree.map(jnp.zeros_like, params_like(0))
    with pytest.raises(ValueError, match="READABLE"):
        store.window_average(end_cycle=0, window=1, like=like)


def test_store_retention_keep_last(tmp_path):
    store = OuterWeightStore(str(tmp_path / "outer"), keep_last=2)
    for e in range(5):
        store.save(e, params_like(e))
    assert store.cycles() == [3, 4]
    import pytest
    with pytest.raises(ValueError, match="keep_last"):
        OuterWeightStore(str(tmp_path / "bad"), keep_last=0)
