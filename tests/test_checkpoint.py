"""Checkpoint io + outer-weight store (Algorithm 2's checkpoint path)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import OuterWeightStore, load_pytree, save_pytree
from repro.common.pytree import tree_mean_axis0, tree_stack


def params_like(seed):
    k1, k2 = jax.random.split(jax.random.key(seed))
    return {"stack": [{"w": jax.random.normal(k1, (3, 4))}],
            "b": jax.random.normal(k2, (5,)).astype(jnp.bfloat16)}


def test_roundtrip(tmp_path):
    p = params_like(0)
    path = str(tmp_path / "ckpt.npz")
    save_pytree(path, p)
    q = load_pytree(path, jax.tree.map(jnp.zeros_like, p))
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(q)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_store_window_average_matches_memory(tmp_path):
    store = OuterWeightStore(str(tmp_path / "outer"))
    outers = [params_like(i) for i in range(6)]
    for e, o in enumerate(outers):
        store.save(e, o)
    like = jax.tree.map(jnp.zeros_like, outers[0])
    wa = store.window_average(end_cycle=5, window=3, like=like)
    expect = tree_mean_axis0(tree_stack(
        [jax.tree.map(lambda x: x.astype(jnp.float32), o)
         for o in outers[3:]]))
    for a, b in zip(jax.tree.leaves(wa), jax.tree.leaves(expect)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=1e-2)


def test_store_cycles_listing(tmp_path):
    store = OuterWeightStore(str(tmp_path / "outer"))
    for e in [3, 1, 7]:
        store.save(e, params_like(e))
    assert store.cycles() == [1, 3, 7]
