"""Executed in a subprocess with 8 forced host devices (see test_spmd.py).

Numerically verifies the distributed paths against single-device oracles:
  1. sharded (shard_map) embedding == jnp.take
  2. expert-parallel MoE == tensor-parallel MoE (same routing)
  3. HWA train+sync steps on a (2,2,2) mesh == single-device HWA
  4. a full train_step lowers, compiles AND RUNS on the test mesh
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.compat import use_mesh
from repro.configs import get_smoke_config
from repro.core.hwa import HWAConfig
from repro.launch.mesh import make_test_mesh
from repro.launch.specs import input_specs
from repro.launch.steps import (make_hwa_sync_step, make_hwa_train_step,
                                make_train_step)
from repro.models.registry import _sharded_gather, build_model
from repro.models.types import InputShape
from repro.sharding.rules import make_tp_rules

ok = True


def check(name, cond):
    global ok
    print(("PASS " if cond else "FAIL ") + name)
    ok = ok and cond


# ---- 1. sharded embedding ------------------------------------------------
mesh = make_test_mesh((2, 4), ("data", "model"))
rules = make_tp_rules(mesh)
emb = jax.random.normal(jax.random.key(0), (32, 16))
ids = jax.random.randint(jax.random.key(1), (4, 6), 0, 32)
with use_mesh(mesh):
    got = jax.jit(lambda e, i: _sharded_gather(e, i, rules))(emb, ids)
want = jnp.take(emb, ids, axis=0)
check("sharded_gather == take",
      bool(jnp.max(jnp.abs(got - want)) < 1e-6))

# ---- 2. EP MoE == TP MoE --------------------------------------------------
from repro.models.moe import init_moe, moe_forward, moe_forward_ep

cfg = get_smoke_config("granite-moe-1b-a400m")  # 4 experts % 4 == 0
p, _ = init_moe(cfg, jax.random.key(0), jnp.float32)
x = jax.random.normal(jax.random.key(1), (4, 8, cfg.d_model))
want, aux_w = moe_forward(cfg, p, x)
with use_mesh(mesh):
    got, aux_g = jax.jit(lambda p, x: moe_forward_ep(
        cfg, p, x, mesh=mesh, capacity_factor=4.0))(p, x)
check("EP MoE == TP MoE",
      bool(jnp.max(jnp.abs(got - want)) < 1e-3))
# EP computes the load-balance loss per data shard then pmeans — a
# (standard) estimator of the global loss, not identical to it.
check("EP aux ~= TP aux", abs(float(aux_g) - float(aux_w)) < 0.25)

# ---- 3+4. HWA steps on a mesh vs single device ----------------------------
mesh3 = make_test_mesh((2, 2, 2), ("replica", "data", "model"))
rules3 = make_tp_rules(mesh3, replica_axis="replica")
cfg_lm = get_smoke_config("granite-3-2b")
lm = build_model(cfg_lm)
shape = InputShape("tiny", seq_len=16, global_batch=8, kind="train")
specs, dims = input_specs(cfg_lm, shape)
hwa_cfg = HWAConfig(n_replicas=2, window=3)
bundle = make_hwa_train_step(lm, rules3, specs, dims, hwa_cfg,
                             optimizer="sgd", lr=0.1)
compiled = bundle.lower(mesh3).compile()
check("hwa_train_step compiles on (2,2,2) mesh", True)

params = lm.init(jax.random.key(0))
K = 2
stacked = jax.tree.map(lambda x: jnp.stack([x, x]), params)
from repro.optim import sgd as mk_sgd
opt = mk_sgd(momentum=0.9, weight_decay=5e-4)
opt_state = jax.vmap(opt.init)(stacked)
batch = {
    "tokens": jax.random.randint(jax.random.key(2), (K, 8, 16), 0,
                                 cfg_lm.vocab_size),
    "targets": jax.random.randint(jax.random.key(3), (K, 8, 16), 0,
                                  cfg_lm.vocab_size),
}
with use_mesh(mesh3):
    new_stacked, new_opt, loss = compiled(stacked, opt_state, batch)
check("hwa_train_step runs; finite loss", bool(jnp.isfinite(loss)))

# single-device oracle: vmap'd steps
def one(params, opt_state, b):
    def loss_fn(p):
        return lm.loss(p, b)
    (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params)
    upd, opt_state = opt.update(g, opt_state, params, 0.1)
    from repro.optim import apply_updates
    return apply_updates(params, upd), opt_state, l

ref_stacked, _, ref_loss = jax.vmap(one)(
    jax.tree.map(lambda x: jnp.stack([x, x]), params),
    jax.vmap(opt.init)(jax.tree.map(lambda x: jnp.stack([x, x]), params)),
    batch)
err = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                - b.astype(jnp.float32))))
          for a, b in zip(jax.tree.leaves(new_stacked),
                          jax.tree.leaves(ref_stacked)))
check(f"mesh HWA step == single-device vmap (err={err:.2e})", err < 5e-3)

# sync step
sync = make_hwa_sync_step(lm, rules3, hwa_cfg)
sync_c = sync.lower(mesh3).compile()
I = hwa_cfg.window
spec = sync.pack_spec               # window state is packed (I, P)/(P,)
ring = jnp.zeros((I, spec.padded), jnp.float32)
total = jnp.zeros((spec.padded,), jnp.float32)
zero = jnp.zeros((), jnp.int32)
with use_mesh(mesh3):
    out = sync_c(new_stacked, ring, total, zero, zero)
new_inner, _, _, count, nidx, wa = out
check("sync: replicas equal after restart",
      bool(jnp.max(jnp.abs(jax.tree.leaves(new_inner)[0][0]
                           - jax.tree.leaves(new_inner)[0][1])) == 0))
check("sync: window count advanced", int(count) == 1)

# plain train step lowers+runs too. fsdp and sequence_parallel are
# exercised separately: enabling BOTH on the (2,4) host-device mesh
# segfaults XLA 0.4.37's CPU SPMD partitioner at compile time (involuntary
# full-remat path) — a backend bug, not a framework one; the combined
# config compiles fine in the 256-chip dry-run meshes.
shape2 = InputShape("tiny2", seq_len=16, global_batch=4, kind="train")
specs2, dims2 = input_specs(cfg_lm, shape2)
opt2 = mk_sgd(momentum=0.9, weight_decay=5e-4)
os2 = opt2.init(params)
batch2 = {"tokens": batch["tokens"][0, :4], "targets": batch["targets"][0, :4]}
for label, kw in [("fsdp", dict(fsdp=True)),
                  ("seq-parallel", dict(sequence_parallel=True))]:
    rules2 = make_tp_rules(mesh, **kw)
    b2 = make_train_step(lm, rules2, specs2, dims2, optimizer="sgd")
    c2 = b2.lower(mesh).compile()
    with use_mesh(mesh):
        # fresh copies: the step donates params + opt state
        p2, o2, m2 = c2(jax.tree.map(jnp.array, params),
                        jax.tree.map(jnp.array, os2), batch2)
    check(f"plain train_step ({label}) runs on (2,4) mesh",
          bool(jnp.isfinite(m2["loss"])))

print("ALL_OK" if ok else "SOME_FAILED")
raise SystemExit(0 if ok else 1)
