"""Loop-aware HLO cost analyzer: trip-count multiplication + collectives."""
import jax
import jax.numpy as jnp

from repro.launch.hlo_cost import analyze_hlo


def test_scan_trip_count_multiplied():
    x = jnp.ones((64, 64))

    def one(x):
        return x @ x

    def scanned(x):
        def body(c, _):
            return c @ c, None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    f1 = analyze_hlo(jax.jit(one).lower(x).compile().as_text()).flops
    f2 = analyze_hlo(jax.jit(scanned).lower(x).compile().as_text()).flops
    assert abs(f2 / f1 - 10.0) < 0.2


def test_nested_scans_multiply():
    x = jnp.ones((64, 64))

    def nested(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ ci, None
            c, _ = jax.lax.scan(inner, c, None, length=5)
            return c, None
        out, _ = jax.lax.scan(outer, x, None, length=3)
        return out

    f = analyze_hlo(jax.jit(nested).lower(x).compile().as_text()).flops
    assert abs(f / (15 * 2 * 64 ** 3) - 1.0) < 0.1


def test_dot_flops_exact():
    a = jnp.ones((32, 48))
    b = jnp.ones((48, 16))
    f = analyze_hlo(jax.jit(lambda a, b: a @ b).lower(a, b)
                    .compile().as_text()).flops
    assert f == 2 * 32 * 48 * 16


def test_bytes_positive_and_sane():
    a = jnp.ones((256, 256))
    cost = analyze_hlo(jax.jit(lambda a: a @ a).lower(a).compile().as_text())
    # read 2 operands + write result (f32)
    assert cost.bytes >= 3 * 256 * 256 * 4
    assert cost.bytes < 20 * 256 * 256 * 4
