"""Mesh-native HWA: numeric equivalence + HLO structure (subprocess with
8 forced host devices), plus single-device unit tests of the named-axis
core math under vmap(axis_name=...)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.core.hwa import HWAConfig, HWAState, hwa_init, hwa_sync, \
    hwa_sync_named
from repro.core.offline import window_init
from repro.core.online import online_average, online_average_named
from repro.optim import sgd


@pytest.mark.timeout(900)
def test_mesh_hwa_subprocess():
    script = os.path.join(os.path.dirname(__file__), "mesh_hwa_check.py")
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + \
        os.path.dirname(__file__) + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, script], capture_output=True,
                          text=True, env=env, timeout=850)
    print(proc.stdout)
    print(proc.stderr[-2000:] if proc.stderr else "")
    assert proc.returncode == 0, proc.stdout + proc.stderr[-2000:]
    assert "ALL_OK" in proc.stdout


def _params(seed=0):
    k1, k2 = jax.random.split(jax.random.key(seed))
    return {"w": jax.random.normal(k1, (4, 3)),
            "b": jax.random.normal(k2, (3,))}


def _stacked(seed=0, k=2):
    return {"w": jax.random.normal(jax.random.key(seed), (k, 4, 3)),
            "b": jax.random.normal(jax.random.key(seed + 1), (k, 3))}


def test_online_average_named_matches_stacked():
    stacked = _stacked()
    named = jax.vmap(lambda p: online_average_named(p, "k"),
                     axis_name="k")(stacked)
    want = online_average(stacked)
    for k in ("w", "b"):
        assert jnp.allclose(named[k][0], want[k], atol=1e-6)
        assert jnp.allclose(named[k][0], named[k][1])  # replica-invariant


def test_hwa_sync_named_matches_hwa_sync():
    """The mesh-native local sync (pmean over a named axis) computes the
    same outer weights, window state and W̿ as the stacked hwa_sync."""
    cfg = HWAConfig(n_replicas=2, window=3)
    opt = sgd(momentum=0.9)
    params = _params()
    state = hwa_init(cfg, params, opt)
    # replicas diverge: perturb the stacked inner weights
    inner = jax.tree.map(
        lambda x: x + 0.1 * jax.random.normal(jax.random.key(7), x.shape),
        _stacked())
    state = HWAState(inner=inner, inner_opt=state.inner_opt,
                     window_state=state.window_state, wa=state.wa,
                     cycle=state.cycle, step=state.step)

    stacked_state, _ = hwa_sync(cfg, state)

    ws = window_init(params, cfg.window)
    outer, ws2, wa, cycle = jax.vmap(
        lambda p: hwa_sync_named(cfg, p, ws, jnp.zeros((), jnp.int32), "k"),
        axis_name="k", out_axes=(0, None, None, None))(inner)

    for k in ("w", "b"):
        assert jnp.allclose(outer[k][0], stacked_state.inner[k][0],
                            atol=1e-6)
        assert jnp.allclose(wa[k], stacked_state.wa[k], atol=1e-6)
    assert int(cycle) == int(stacked_state.cycle) == 1
    assert int(ws2.count) == int(stacked_state.window_state.count) == 1


def test_hwa_sync_named_window_stride():
    """Cycles not matching window_stride skip the window push (sparse
    window, paper §III-B) in the named path too."""
    cfg = HWAConfig(n_replicas=2, window=4, window_stride=2)
    params = _params()
    ws = window_init(params, cfg.window)
    inner = _stacked()

    def sync_at(cycle, ws):
        return jax.vmap(
            lambda p: hwa_sync_named(cfg, p, ws,
                                     jnp.asarray(cycle, jnp.int32), "k"),
            axis_name="k", out_axes=(0, None, None, None))(inner)

    _, ws_a, _, _ = sync_at(0, ws)      # cycle 0 -> take
    assert int(ws_a.count) == 1
    _, ws_b, _, _ = sync_at(1, ws_a)    # cycle 1 -> skip
    assert int(ws_b.count) == 1
