"""repro.common.compat: both API branches of every shim, monkeypatched,
plus a checkpoint bf16 round-trip regression through the shim."""
import contextlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common import compat
from repro.checkpoint import load_pytree, save_pytree


# ----------------------------------------------------- tree_flatten_with_path

def test_tree_flatten_with_path_matches_tree_util():
    tree = {"a": [jnp.ones((2,)), jnp.zeros((3,))], "b": {"c": jnp.ones(())}}
    got_flat, got_def = compat.tree_flatten_with_path(tree)
    want_flat, want_def = jax.tree_util.tree_flatten_with_path(tree)
    assert got_def == want_def
    assert [p for p, _ in got_flat] == [p for p, _ in want_flat]


def test_tree_flatten_with_path_resolves_new_api_when_present():
    """On jax ≥0.5 the shim must pick jax.tree.flatten_with_path; on the
    pinned 0.4.x it must fall back to tree_util. Assert the resolution
    matches whichever branch this interpreter actually has."""
    if hasattr(jax.tree, "flatten_with_path"):
        assert compat.tree_flatten_with_path is jax.tree.flatten_with_path
    else:
        assert compat.tree_flatten_with_path is \
            jax.tree_util.tree_flatten_with_path


# ------------------------------------------------------------------ use_mesh

def _mesh_1d():
    return compat.make_mesh((1,), ("data",))


def test_use_mesh_new_api_branch(monkeypatch):
    calls = []

    @contextlib.contextmanager
    def fake_set_mesh(mesh):
        calls.append(mesh)
        yield

    monkeypatch.setattr(jax, "set_mesh", fake_set_mesh, raising=False)
    mesh = _mesh_1d()
    with compat.use_mesh(mesh):
        pass
    assert calls == [mesh]


def test_use_mesh_old_api_branch(monkeypatch):
    """Without set_mesh/use_mesh the shim returns the Mesh itself, whose
    own context manager installs it as the ambient mesh."""
    monkeypatch.delattr(jax, "set_mesh", raising=False)
    monkeypatch.delattr(jax.sharding, "use_mesh", raising=False)
    mesh = _mesh_1d()
    cm = compat.use_mesh(mesh)
    assert cm is mesh
    with cm:
        from jax.sharding import PartitionSpec as P
        x = jax.jit(lambda v: v * 2,
                    in_shardings=jax.sharding.NamedSharding(mesh, P()))(
            jnp.ones((4,)))
    np.testing.assert_array_equal(np.asarray(x), 2 * np.ones((4,)))


# ----------------------------------------------------------------- make_mesh

def test_make_mesh_new_api_branch():
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    assert mesh.axis_names == ("data", "model")
    assert dict(mesh.shape) == {"data": 1, "model": 1}


def test_make_mesh_fallback_branch(monkeypatch):
    monkeypatch.delattr(jax, "make_mesh", raising=False)
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    assert mesh.axis_names == ("data", "model")
    assert dict(mesh.shape) == {"data": 1, "model": 1}


# ----------------------------------------------------------------- shard_map

def test_shard_map_old_keywords():
    mesh = _mesh_1d()
    from jax.sharding import PartitionSpec as P
    f = compat.shard_map(lambda x: x * 2, mesh, in_specs=(P(),),
                         out_specs=P(), check_rep=False)
    np.testing.assert_array_equal(np.asarray(f(jnp.ones((4,)))),
                                  2 * np.ones((4,)))


def test_shard_map_check_vma_spelling():
    """New-API call sites pass check_vma; the shim maps it onto whichever
    keyword the installed jax takes."""
    mesh = _mesh_1d()
    from jax.sharding import PartitionSpec as P
    f = compat.shard_map(lambda x: x + 1, mesh, in_specs=(P(),),
                         out_specs=P(), check_vma=False)
    np.testing.assert_array_equal(np.asarray(f(jnp.zeros((4,)))),
                                  np.ones((4,)))


# --------------------------------------------- checkpoint bf16 regression

def test_save_load_bf16_roundtrip_via_shim(tmp_path):
    """save_pytree/load_pytree flatten through the compat shim; bf16
    leaves must round-trip bit-exactly (they ride as uint16 views)."""
    tree = {"w": (jnp.arange(6, dtype=jnp.float32) / 3.0)
                 .astype(jnp.bfloat16).reshape(2, 3),
            "nested": [{"b": jnp.asarray([1.5, -2.25], jnp.bfloat16)}],
            "f32": jnp.linspace(0, 1, 5)}
    path = str(tmp_path / "bf16.npz")
    save_pytree(path, tree)
    out = load_pytree(path, jax.tree.map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
