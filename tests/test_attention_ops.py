"""Flash-attention fwd/bwd op test matrix (xform-style axes).

Every case checks the Pallas kernel pipeline — forward AND the custom-vjp
gradients (dq/dk/dv via ``jax.grad``) — against ``naive_attention``
autodiff, running in interpret mode on CPU (``kernels.ops`` gates on the
backend). Axes: seq length {one block, ragged/non-block-multiple, long},
head_dim {64, 128, 72→padded-to-128}, GQA group sizes {1, 2, 4},
causal × sliding-window × logit-softcap, and bf16 inputs with f32
tolerances.

The split mirrors the repo's CI lanes: a smoke subset stays unmarked for
the PR lane; the rest carries ``slow`` (nightly runs everything) and is
additionally skipped under ``REPRO_ATTN_SMOKE=1``, the same env pattern
as hwa-lint/fault-check. The band-masking hypothesis sweep rides the
usual ``importorskip`` (hypothesis is a dev-only dep).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.models.attention import naive_attention

SMOKE = os.environ.get("REPRO_ATTN_SMOKE") == "1"
B = 2


def _case(S, Hq, Hkv, D, window, cap, dtype, smoke=False):
    marks = []
    if not smoke:
        marks.append(pytest.mark.slow)
        if SMOKE:
            marks.append(pytest.mark.skip(
                reason="REPRO_ATTN_SMOKE=1: PR-lane smoke subset only"))
    return pytest.param(
        S, Hq, Hkv, D, window, cap, dtype, marks=marks,
        id=f"S{S}-H{Hq}kv{Hkv}-D{D}-w{window}-cap{cap}-{dtype}")


# One axis varies per row (plus a kitchen-sink case); smoke rows cover
# every axis at least once.
MATRIX = [
    # seq: exactly one block / ragged (pads 80→128) / long (multi-block)
    _case(64, 4, 4, 64, None, 0.0, "float32", smoke=True),
    _case(80, 4, 2, 64, None, 0.0, "float32", smoke=True),
    _case(256, 4, 2, 64, None, 0.0, "float32"),
    # head_dim: native 128 / padded 72→128 (64 covered above)
    _case(128, 4, 2, 128, None, 0.0, "float32"),
    _case(128, 4, 2, 72, None, 0.0, "float32", smoke=True),
    # GQA group sizes 1 and 4 (G=2 covered above)
    _case(128, 4, 4, 64, None, 0.0, "float32"),
    _case(128, 4, 1, 64, None, 0.0, "float32"),
    # causal × window × softcap
    _case(128, 4, 2, 64, 32, 0.0, "float32"),
    _case(128, 4, 2, 64, None, 15.0, "float32"),
    _case(128, 4, 2, 64, 24, 15.0, "float32", smoke=True),
    # everything at once: ragged + padded head_dim + G=4 + window + cap
    _case(160, 4, 1, 72, 48, 8.0, "float32"),
    # bf16 inputs, f32 tolerances
    _case(128, 4, 2, 64, None, 0.0, "bfloat16", smoke=True),
    _case(128, 4, 4, 64, 32, 15.0, "bfloat16"),
]

MATRIX_ARGS = "S,Hq,Hkv,D,window,cap,dtype"


def _mk(S, Hq, Hkv, D, dtype, seed=0):
    ks = jax.random.split(jax.random.key(seed), 4)
    q = jax.random.normal(ks[0], (B, S, Hq, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, D)).astype(dtype)
    # a fixed f32 cotangent: sum(out * w) exercises every output element
    w = jax.random.normal(ks[3], (B, S, Hq, D), jnp.float32)
    return q, k, v, w


def _naive(q, k, v, window, cap):
    S, T = q.shape[1], k.shape[1]
    qp = jnp.arange(S)[None].repeat(q.shape[0], 0)
    kp = jnp.arange(T)[None].repeat(k.shape[0], 0)
    return naive_attention(q, k, v, qp, kp, window=window, logit_softcap=cap)


def _tols(dtype):
    # bf16 operands, f32 accumulation on both sides → f32-scale tolerances
    # loosened for the bf16 input rounding itself
    return (3e-2, 3e-2) if dtype == "bfloat16" else (2e-5, 2e-5)


@pytest.mark.parametrize(MATRIX_ARGS, MATRIX)
def test_forward_matches_naive(S, Hq, Hkv, D, window, cap, dtype):
    q, k, v, _ = _mk(S, Hq, Hkv, D, dtype)
    out = kops.flash_attention(q, k, v, window=window, logit_softcap=cap,
                               block_q=64, block_k=64)
    ref = _naive(q, k, v, window, cap)
    rtol, atol = _tols(dtype)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=rtol, atol=atol)


@pytest.mark.parametrize(MATRIX_ARGS, MATRIX)
def test_grads_match_naive(S, Hq, Hkv, D, window, cap, dtype):
    q, k, v, w = _mk(S, Hq, Hkv, D, dtype)

    def f_flash(q, k, v):
        out = kops.flash_attention(q, k, v, window=window, logit_softcap=cap,
                                   block_q=64, block_k=64)
        return jnp.sum(out.astype(jnp.float32) * w)

    def f_naive(q, k, v):
        return jnp.sum(_naive(q, k, v, window, cap).astype(jnp.float32) * w)

    got = jax.grad(f_flash, (0, 1, 2))(q, k, v)
    want = jax.grad(f_naive, (0, 1, 2))(q, k, v)
    rtol, atol = _tols(dtype)
    for name, g, r in zip(("dq", "dk", "dv"), got, want):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(r, np.float32),
                                   rtol=rtol, atol=atol, err_msg=name)


def test_flash_pallas_direct_grad():
    """The acceptance headline, without the ops.py pad/slice wrapper:
    ``jax.grad`` straight through ``flash_attention_pallas`` (interpret
    mode) matches naive autodiff."""
    S, Hq, Hkv, D = 128, 4, 2, 128
    q, k, v, w = _mk(S, Hq, Hkv, D, "float32")

    def f_flash(q, k, v):
        out = flash_attention_pallas(q, k, v, causal=True, window=32,
                                     logit_softcap=10.0, block_q=64,
                                     block_k=64, interpret=True)
        return jnp.sum(out * w)

    def f_naive(q, k, v):
        return jnp.sum(_naive(q, k, v, 32, 10.0) * w)

    got = jax.grad(f_flash, (0, 1, 2))(q, k, v)
    want = jax.grad(f_naive, (0, 1, 2))(q, k, v)
    for name, g, r in zip(("dq", "dk", "dv"), got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                   rtol=2e-5, atol=2e-5, err_msg=name)


def _fully_masked_rows(S, T, window):
    """Row i sees keys in [i-window+1, min(i, T-1)]; the band is empty —
    fully masked — once i - (T - 1) >= window."""
    return np.arange(S) - (T - 1) >= window


def test_masked_row_regression():
    """The `_finalize` l==0 fix: queries past the key horizon of a
    sliding window produce EXACTLY zero output rows and zero gradients —
    no NaN/Inf from the −1e30 fill, no bogus uniform-mean rows.

    naive_attention softmaxes a fully-masked row into a uniform mean (no
    l==0 guard), so the oracle here is ``kref.attention_ref``, which
    zeroes such rows like the kernel does.
    """
    S, T, Hq, Hkv, D, window = 128, 64, 4, 2, 64, 16
    ks = jax.random.split(jax.random.key(3), 4)
    q = jax.random.normal(ks[0], (B, S, Hq, D))
    k = jax.random.normal(ks[1], (B, T, Hkv, D))
    v = jax.random.normal(ks[2], (B, T, Hkv, D))
    w = jax.random.normal(ks[3], (B, S, Hq, D))
    dead = _fully_masked_rows(S, T, window)
    assert dead.any() and not dead.all()

    out = kops.flash_attention(q, k, v, window=window, block_q=64,
                               block_k=64)
    ref = kref.attention_ref(q, k, v, window=window)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    assert not np.asarray(out)[:, dead].any(), \
        "fully-masked rows must be exactly zero"

    def f(q, k, v):
        o = kops.flash_attention(q, k, v, window=window, block_q=64,
                                 block_k=64)
        return jnp.sum(o * w)

    dq, dk, dv = jax.grad(f, (0, 1, 2))(q, k, v)
    for name, g in (("dq", dq), ("dk", dk), ("dv", dv)):
        assert np.isfinite(np.asarray(g)).all(), f"{name} has non-finite"
    assert not np.asarray(dq)[:, dead].any(), \
        "fully-masked query rows must have exactly zero dq"


# ---------------------------------------------------------------- hypothesis
# band-masking invariant sweep — dev-only dep, slow lane (same split as
# tests/test_kernels.py)

@pytest.mark.slow
def test_band_masking_invariant_property():
    pytest.importorskip("hypothesis", reason="hypothesis not installed "
                        "(see requirements-dev.txt)")
    from hypothesis import given, settings, strategies as st

    @given(st.integers(2, 4), st.integers(1, 2),
           st.sampled_from([8, 16, 24]), st.integers(0, 1000))
    @settings(max_examples=10, deadline=None)
    def run(nq_blocks, nt_blocks, window, seed):
        # S > T so a sliding window strands the tail queries past the
        # key horizon: rows with i - (T-1) >= window are fully masked
        S, T = 64 * nq_blocks, 64 * nt_blocks
        if not _fully_masked_rows(S, T, window).any():
            return
        ks = jax.random.split(jax.random.key(seed), 4)
        q = jax.random.normal(ks[0], (1, S, 2, 64))
        k = jax.random.normal(ks[1], (1, T, 2, 64))
        v = jax.random.normal(ks[2], (1, T, 2, 64))
        w = jax.random.normal(ks[3], (1, S, 2, 64))
        dead = _fully_masked_rows(S, T, window)

        def f(q, k, v):
            o = kops.flash_attention(q, k, v, window=window, block_q=64,
                                     block_k=64)
            return jnp.sum(o * w), o

        (_, out), (dq, dk, dv) = jax.value_and_grad(
            f, (0, 1, 2), has_aux=True)(q, k, v)
        for name, x in (("out", out), ("dq", dq), ("dk", dk), ("dv", dv)):
            assert np.isfinite(np.asarray(x)).all(), f"{name} non-finite"
        assert not np.asarray(out)[:, dead].any()
        assert not np.asarray(dq)[:, dead].any()

    run()
