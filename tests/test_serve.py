"""Serving engine: greedy decode consistency + musicgen delay pattern."""
import jax
import jax.numpy as jnp
import numpy as np

from conftest import make_lm_batch
from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serve.engine import (DecodeEngine, apply_delay_pattern,
                                undo_delay_pattern)


def test_greedy_generation_matches_manual_loop():
    cfg = get_smoke_config("granite-3-2b")
    lm = build_model(cfg)
    params = lm.init(jax.random.key(0))
    batch = make_lm_batch(cfg, B=2, S=12)
    prompt = {"tokens": batch["tokens"]}
    engine = DecodeEngine(lm, params, max_seq_len=20)
    out = engine.generate(prompt, 6)
    # manual: teacher-forced re-run must reproduce the same greedy argmax
    cache, _ = lm.init_cache(2, 20)
    logits, cache = lm.prefill(params, cache, prompt)
    toks = []
    for _ in range(6):
        t = jnp.argmax(logits, -1)
        toks.append(t)
        logits, cache = lm.decode_step(params, cache, t)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(jnp.stack(toks, 1)))


def test_audio_generation_shapes():
    cfg = get_smoke_config("musicgen-medium")
    lm = build_model(cfg)
    params = lm.init(jax.random.key(0))
    prompt = {"tokens": jax.random.randint(jax.random.key(1),
                                           (2, 8, cfg.n_codebooks), 0,
                                           cfg.vocab_size)}
    engine = DecodeEngine(lm, params, max_seq_len=16)
    out = engine.generate(prompt, 4)
    assert out.shape == (2, 4, cfg.n_codebooks)


def test_delay_pattern_roundtrip():
    x = jax.random.randint(jax.random.key(0), (2, 10, 4), 0, 100)
    d = apply_delay_pattern(x)
    assert d.shape == (2, 13, 4)
    back = undo_delay_pattern(d, 10)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))
