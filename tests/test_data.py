"""Data pipeline: determinism + the paper's per-replica sampling orders."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data import DataPipeline, make_markov_lm_dataset, \
    make_prototype_image_dataset
from repro.data.pipeline import replica_batch_indices


def test_dataset_deterministic():
    a = make_markov_lm_dataset(vocab=32, seq_len=16, n_train=64, n_test=16,
                               seed=7)
    b = make_markov_lm_dataset(vocab=32, seq_len=16, n_train=64, n_test=16,
                               seed=7)
    np.testing.assert_array_equal(a.train_inputs, b.train_inputs)
    c = make_markov_lm_dataset(vocab=32, seq_len=16, n_train=64, n_test=16,
                               seed=8)
    assert not np.array_equal(np.asarray(a.train_inputs),
                              np.asarray(c.train_inputs))


def test_markov_structure_learnable():
    """Next-token distribution is non-uniform (there is structure)."""
    ds = make_markov_lm_dataset(vocab=16, seq_len=64, n_train=256,
                                n_test=64, seed=0, concentration=0.1)
    x = np.asarray(ds.train_inputs)
    y = np.asarray(ds.train_targets)
    # empirical transition matrix should be concentrated
    counts = np.zeros((16, 16))
    np.add.at(counts, (x.reshape(-1), y.reshape(-1)), 1)
    probs = counts / np.maximum(counts.sum(1, keepdims=True), 1)
    top1 = probs.max(axis=1)
    assert top1.mean() > 0.3      # uniform would be 1/16


def test_replica_sampling_orders_differ():
    """Paper Alg. 1 line 6: each replica sees its own batch order."""
    key = jax.random.key(0)
    i0 = replica_batch_indices(key, 0, step=3, n_train=256, batch_size=16)
    i1 = replica_batch_indices(key, 1, step=3, n_train=256, batch_size=16)
    assert not np.array_equal(np.asarray(i0), np.asarray(i1))


def test_epoch_is_without_replacement():
    key = jax.random.key(0)
    n, bs = 128, 16
    seen = []
    for step in range(n // bs):
        seen.append(np.asarray(
            replica_batch_indices(key, 0, step, n, bs)))
    allidx = np.concatenate(seen)
    assert sorted(allidx.tolist()) == list(range(n))


def test_stacked_batch_shapes():
    ds = make_markov_lm_dataset(vocab=32, seq_len=16, n_train=64, n_test=16)
    pipe = DataPipeline(ds, batch_size=8, n_replicas=3)
    xb, yb = pipe.stacked_batch(0)
    assert xb.shape == (3, 8, 16) and yb.shape == (3, 8, 16)


def test_image_dataset_label_noise_and_shapes():
    ds = make_prototype_image_dataset(n_classes=4, image_size=8,
                                      n_train=64, n_test=32,
                                      label_noise=0.2, seed=0)
    assert ds.train_inputs.shape == (64, 8, 8, 3)
    assert int(ds.train_targets.max()) < 4
