"""HWA state machine: the paper's Algorithms 1 & 2, exactly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.pytree import tree_mean_axis0, tree_stack
from repro.core import (HWAConfig, hwa_init, hwa_inner_step, hwa_sync,
                        broadcast_to_replicas, online_average,
                        window_init, window_update, window_average)
from repro.optim import sgd


def params_like(seed=0):
    k = jax.random.key(seed)
    k1, k2 = jax.random.split(k)
    return {"w": jax.random.normal(k1, (4, 3)),
            "b": jax.random.normal(k2, (7,))}


def test_online_average_is_mean():
    ps = [params_like(i) for i in range(3)]
    stacked = tree_stack(ps)
    outer = online_average(stacked)
    for leaf, *leaves in zip(jax.tree.leaves(outer),
                             *[jax.tree.leaves(p) for p in ps]):
        np.testing.assert_allclose(leaf, np.mean(leaves, axis=0), rtol=1e-6)


def test_broadcast_restart_resets_all_replicas():
    outer = params_like()
    inner = broadcast_to_replicas(outer, 4)
    for leaf, o in zip(jax.tree.leaves(inner), jax.tree.leaves(outer)):
        assert leaf.shape == (4,) + o.shape
        for k in range(4):
            np.testing.assert_array_equal(leaf[k], o)


@pytest.mark.parametrize("use_kernel", [False, True])
def test_window_matches_bruteforce(use_kernel):
    """Ring slide-window == mean of the last I outer weights (Alg. 2)."""
    I = 4
    p0 = params_like()
    ws = window_init(p0, I)
    outers = [params_like(100 + t) for t in range(9)]
    for t, outer in enumerate(outers):
        ws, wa = window_update(ws, outer, use_kernel=use_kernel)
        lo = max(0, t + 1 - I)
        expect = tree_mean_axis0(tree_stack(outers[lo:t + 1]))
        for a, b in zip(jax.tree.leaves(wa), jax.tree.leaves(expect)):
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_streaming_window_matches_exact_until_full():
    I = 5
    p0 = params_like()
    ws_r = window_init(p0, I, "ring")
    ws_s = window_init(p0, I, "streaming")
    for t in range(I):
        outer = params_like(200 + t)
        ws_r, wa_r = window_update(ws_r, outer)
        ws_s, wa_s = window_update(ws_s, outer)
        for a, b in zip(jax.tree.leaves(wa_r), jax.tree.leaves(wa_s)):
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def quad_loss(params, batch):
    """Simple convex loss with per-batch noise."""
    target, noise = batch
    l = sum(jnp.sum((p - target + noise) ** 2)
            for p in jax.tree.leaves(params))
    return l, {"loss": l, "acc": jnp.zeros(())}


def test_k1_i1_hwa_equals_plain_sgd():
    opt = sgd(momentum=0.9)
    cfg = HWAConfig(n_replicas=1, sync_period=2, window=1)
    p0 = params_like()
    state = hwa_init(cfg, p0, opt)
    # plain SGD reference
    ref_p, ref_o = p0, opt.init(p0)
    for step in range(6):
        batch = (0.5, 0.01 * step)
        kbatch = (jnp.full((1,), 0.5), jnp.full((1,), 0.01 * step))
        state, _ = hwa_inner_step(cfg, state, kbatch, quad_loss, opt, 0.05)
        (_, _), g = jax.value_and_grad(quad_loss, has_aux=True)(ref_p, batch)
        upd, ref_o = opt.update(g, ref_o, ref_p, 0.05)
        ref_p = jax.tree.map(lambda p, u: p + u, ref_p, upd)
        if (step + 1) % 2 == 0:
            state, _ = hwa_sync(cfg, state)
    for a, b in zip(jax.tree.leaves(state.wa), jax.tree.leaves(ref_p)):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_sync_restart_effect_and_divergence_metric():
    """After sync all replicas are equal; before sync they differ (they saw
    different batches) — the paper's Fig. 12 'restart' mechanics."""
    opt = sgd(momentum=0.0)
    cfg = HWAConfig(n_replicas=3, sync_period=4, window=2)
    state = hwa_init(cfg, params_like(), opt)
    for step in range(4):
        kbatch = (jnp.arange(3.0), jnp.arange(3.0) * 0.1)
        state, _ = hwa_inner_step(cfg, state, kbatch, quad_loss, opt, 0.05)
    w = state.inner["w"]
    assert float(jnp.max(jnp.abs(w[0] - w[1]))) > 1e-6
    state, metrics = hwa_sync(cfg, state)
    assert float(metrics["replica_divergence"]) > 0
    w = state.inner["w"]
    assert float(jnp.max(jnp.abs(w[0] - w[1]))) == 0.0
    assert int(state.cycle) == 1


def test_sparse_window_stride():
    """§III-B: with stride J only every J-th cycle enters the window."""
    opt = sgd()
    cfg = HWAConfig(n_replicas=1, sync_period=1, window=2, window_stride=2)
    state = hwa_init(cfg, params_like(), opt)
    counts = []
    for _ in range(5):
        state = jax.tree.map(lambda x: x, state)
        # force distinct inner weights per cycle
        state.inner["w"] = state.inner["w"] + 1.0
        state, _ = hwa_sync(cfg, state)
        counts.append(int(state.window_state.count))
    assert counts == [1, 1, 2, 2, 2]
