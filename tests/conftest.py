"""Shared fixtures. NOTE: no XLA device-count flags here — smoke tests and
benches must see the real single CPU device; only the dry-run (its own
process) forces 512 host devices."""
import jax
import jax.numpy as jnp
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.key(0)


def make_lm_batch(cfg, B=2, S=32, seed=1):
    ks = jax.random.split(jax.random.key(seed), 3)
    batch = {}
    if cfg.family == "audio":
        batch["tokens"] = jax.random.randint(
            ks[0], (B, S, cfg.n_codebooks), 0, cfg.vocab_size)
        batch["targets"] = jax.random.randint(
            ks[1], (B, S, cfg.n_codebooks), 0, cfg.vocab_size)
    else:
        batch["tokens"] = jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)
        batch["targets"] = jax.random.randint(ks[1], (B, S), 0,
                                              cfg.vocab_size)
    if cfg.family == "vlm":
        batch["vis_embeds"] = jax.random.normal(
            ks[2], (B, cfg.n_vis_tokens, cfg.d_vis), jnp.float32)
    return batch
