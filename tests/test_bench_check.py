"""tools/bench_check.py regression guard tests — synthetic bench /
thresholds pairs exercising the hardened failure modes: a renamed bench
block dangling its thresholds, an unknown (misspelled) thresholds
section silently un-guarding its checks, and the unguarded-block
coverage warning.
"""
import importlib.util
import json
import os
import sys

import pytest

_TOOLS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "tools")


@pytest.fixture(scope="module")
def bench_check():
    spec = importlib.util.spec_from_file_location(
        "bench_check", os.path.join(_TOOLS, "bench_check.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _write(tmp_path, name, obj):
    p = tmp_path / name
    p.write_text(json.dumps(obj))
    return str(p)


_BENCH = {"sync_fused": {"launches": 1, "us": 12.5},
          "sync/tree": {"pod_bytes": 0}}
_TH = {"_comment": "test", "required": ["sync_fused.us"],
       "bounds": {"sync_fused.launches": {"min": 1, "max": 1},
                  "sync/tree.pod_bytes": {"max": 0}}}


def test_clean_pass(bench_check, tmp_path):
    rc = bench_check.run(_write(tmp_path, "b.json", _BENCH),
                         _write(tmp_path, "t.json", _TH),
                         log=lambda *_: None)
    assert rc == 0


def test_renamed_block_fails_and_warns(bench_check, tmp_path):
    # the rename drops the guarded keys AND leaves the new block bare
    bench = {"sync_fused_v2": {"launches": 2, "us": 12.5},
             "sync/tree": {"pod_bytes": 0}}
    out = []
    rc = bench_check.run(_write(tmp_path, "b.json", bench),
                         _write(tmp_path, "t.json", _TH), log=out.append)
    assert rc == 1
    text = "\n".join(out)
    assert "missing required metric: sync_fused.us" in text
    assert "missing bounded metric: sync_fused.launches" in text
    assert "'sync_fused_v2' has no threshold" in text


def test_unknown_section_fails(bench_check, tmp_path):
    # a misspelled section would silently skip every check inside it
    th = {"requried": ["sync_fused.us"],
          "bounds": {"sync_fused.launches": {"max": 1},
                     "sync/tree.pod_bytes": {"max": 0},
                     "sync_fused.us": {"min": 0}}}
    out = []
    rc = bench_check.run(_write(tmp_path, "b.json", _BENCH),
                         _write(tmp_path, "t.json", th), log=out.append)
    assert rc == 1
    assert any("unknown thresholds section 'requried'" in ln
               for ln in out)


def test_bounds_violation_and_dotted_keys(bench_check, tmp_path):
    bench = {"sync_fused": {"launches": 3, "us": 1.0},
             "sync/tree": {"pod_bytes": 64}}
    out = []
    rc = bench_check.run(_write(tmp_path, "b.json", bench),
                         _write(tmp_path, "t.json", _TH), log=out.append)
    assert rc == 1
    text = "\n".join(out)
    assert "sync_fused.launches = 3 > max 1" in text
    # literal dotted/slashed block names resolve greedily
    assert "sync/tree.pod_bytes = 64 > max 0" in text


def test_unguarded_block_warns_but_passes(bench_check, tmp_path):
    bench = dict(_BENCH, new_bench={"us": 5.0})
    out = []
    rc = bench_check.run(_write(tmp_path, "b.json", bench),
                         _write(tmp_path, "t.json", _TH), log=out.append)
    assert rc == 0
    assert any("'new_bench' has no threshold" in ln for ln in out)


def test_real_repo_files_pass(bench_check):
    # the committed trajectory must satisfy the committed thresholds
    # with zero unguarded blocks (full schema coverage)
    out = []
    assert bench_check.run(log=out.append) == 0
    assert not any("warn:" in ln for ln in out)


def test_unreadable_bench_fails(bench_check, tmp_path):
    p = tmp_path / "broken.json"
    p.write_text("{not json")
    rc = bench_check.run(str(p), _write(tmp_path, "t.json", _TH),
                         log=lambda *_: None)
    assert rc == 1
