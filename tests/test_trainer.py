"""Trainer integration: every method runs; HWA improves over its inner
weights; loss decreases (paper's core empirical claims at micro scale)."""
import jax
import pytest

from repro.core import HWAConfig
from repro.data import DataPipeline, make_markov_lm_dataset
from repro.models import build_model
from repro.models.types import ModelConfig
from repro.train import TrainConfig, Trainer, lm_task

TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=32,
                   n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=32,
                   attn_impl="naive", remat="none", dtype="float32")


def make(method, steps=48, K=2, H=8, I=3):
    lm = build_model(TINY)
    ds = make_markov_lm_dataset(vocab=32, seq_len=32, n_train=256,
                                n_test=64, seed=0)
    k = K if method in ("hwa", "online", "pmsgd") else 1
    pipe = DataPipeline(ds, batch_size=8, n_replicas=k, seed=0)
    tc = TrainConfig(method=method, total_steps=steps, batch_size=8,
                     base_lr=0.5, eval_every=16,
                     hwa=HWAConfig(n_replicas=k, sync_period=H, window=I),
                     swa_start_frac=0.5, swa_lr=0.1)
    return Trainer(lm_task(lm, pipe), tc)


@pytest.mark.parametrize("method", ["base", "ca", "swa", "ema", "lookahead",
                                    "sam", "online", "pmsgd", "hwa"])
def test_method_runs_and_decreases_loss(method):
    out = make(method).run()
    assert len(out["history"]) >= 2
    first, last = out["history"][0], out["history"][-1]
    assert last["test_loss"] < first["test_loss"] + 0.1
    assert out["final"]["test_loss"] < 4.0   # ln(32) ≈ 3.46 at random


def test_hwa_views_recorded():
    out = make("hwa").run(eval_views=True)
    rec = out["history"][-1]
    assert "inner_loss" in rec and "outer_loss" in rec
    # W̿ should not be worse than the raw inner weights late in training
    assert rec["test_loss"] <= rec["inner_loss"] + 0.2


def test_best_tracking():
    out = make("hwa").run()
    assert out["best"]["test_acc"] >= max(
        h["test_acc"] for h in out["history"]) - 1e-9
