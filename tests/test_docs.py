"""Docs stay buildable: the ``make docs-check`` logic runs inside the
tier-1 suite too (tools/docs_check.py is the single source of truth)."""
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))
import docs_check


def test_readme_exists_with_quickstart():
    assert os.path.exists(os.path.join(docs_check.ROOT, "README.md"))
    assert os.path.exists(os.path.join(docs_check.ROOT, "docs",
                                       "ARCHITECTURE.md"))


def test_intra_repo_links_resolve():
    assert docs_check.check_links() == []


def test_quickstart_make_targets_dry_run():
    if not any(os.access(os.path.join(p, "make"), os.X_OK)
               for p in os.environ.get("PATH", "").split(os.pathsep) if p):
        pytest.skip("make not on PATH")
    assert docs_check.check_quickstart() == []
