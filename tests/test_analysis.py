"""Static-analysis package unit tests (single CPU device).

Covers the pieces of ``repro/analysis/`` that need no forced device
grid: the instruction-level HLO parsing (async ``-start``/``-done``
pairs — the regression that motivated the rewrite), the donation /
dtype / hazard passes on synthetic fixtures and tiny real jits, the
declarative collective contracts on synthetic HLO over a fake mesh, the
JSON report round-trip, the ``launch.hlo`` facade identity, and a
seeded contract violation driving the lint runner to a failing report
(the exit-nonzero path of ``tools/hwa_lint.py``). The real-bundle
matrix itself runs under ``make hwa-lint`` / the CI lint job with the
8-device grid.
"""
import json
import types
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.collectives import (check_collective_contract,
                                        collective_stats,
                                        collectives_crossing_axis)
from repro.analysis.contracts import (DEFAULT_CONTRACT, BundleContract,
                                      CollectiveContract, DtypePolicy,
                                      LaunchBudget, sync_contract,
                                      train_contract)
from repro.analysis.hlo_text import (collective_instructions, dtype_token,
                                     iter_instructions, line_dtypes,
                                     parse_input_output_alias,
                                     parse_instruction)
from repro.analysis.lint import LintCase, run_case, run_lint
from repro.analysis.passes import (PASS_NAMES, BundleArtifacts,
                                   donation_pass, dtype_pass,
                                   launch_budget_pass, manual_hazard_pass,
                                   manual_loop_hazards, run_passes)
from repro.analysis.report import (build_report, bundle_entry, report_ok,
                                   summarize, to_json)
from repro.common.compat import shard_map
from repro.launch.hlo import count_pallas_calls

# ---------------------------------------------------------------- fixtures


def _fake_mesh(shape: dict):
    dims = tuple(shape.values())
    return types.SimpleNamespace(shape=shape, axis_names=tuple(shape),
                                 devices=np.empty(dims),
                                 size=int(np.prod(dims)))


_HDR = "HloModule jit_step, entry_computation_layout={()->()}\n"

# async all-reduce pair + a collective CONSUMING the -done value: the old
# `"-done" in line` substring skip dropped that all-gather entirely
_AR_START = ('  %all-reduce-start.1 = f32[1024]{0} all-reduce-start('
             'f32[1024]{0} %p0), replica_groups={{0,1}}, to_apply=%add')
_AR_DONE = ('  %all-reduce-done.1 = f32[1024]{0} all-reduce-done('
            'f32[1024]{0} %all-reduce-start.1)')
_AG_ON_DONE = ('  %all-gather.3 = f32[2048]{0} all-gather(f32[1024]{0} '
               '%all-reduce-done.1), replica_groups=[1,2], dimensions={0}')
_ASYNC_HLO = "\n".join([_HDR, _AR_START, _AR_DONE, _AG_ON_DONE, ""])


class _TinyBundle:
    """Minimal StepBundle stand-in for single-device pass tests."""

    def __init__(self, fn, args, donate=(), contract=None):
        self.fn = fn
        self.abstract_args = args
        self.donate_argnums = donate
        self.contract = contract
        self.pack_spec = None

    def lower(self, mesh):
        return jax.jit(self.fn,
                       donate_argnums=self.donate_argnums).lower(
                           *self.abstract_args)


def _art(fn=None, args=(), donate=(), hlo_text=None):
    art = BundleArtifacts(_TinyBundle(fn or (lambda: 0), args, donate),
                          mesh=None)
    if hlo_text is not None:
        art._compiled_text = hlo_text
    return art


# ----------------------------------------------- instruction parsing


def test_parse_instruction_forms():
    i = parse_instruction(_AR_START)
    assert i.opcode == "all-reduce-start" and i.base_op == "all-reduce"
    assert i.suffix == "-start" and i.result_bytes == 4096
    i = parse_instruction(_AG_ON_DONE)
    assert i.opcode == "all-gather" and i.suffix == ""
    root = parse_instruction(
        "  ROOT %tuple.9 = (f32[8]{0}, s32[]) tuple(%a, %b)")
    assert root.opcode == "tuple"
    assert parse_instruction("// not an instruction") is None


def test_async_pair_counted_once_and_consumer_not_dropped():
    insts = list(collective_instructions(_ASYNC_HLO))
    # the -start/-done pair is ONE collective; the all-gather consuming
    # %all-reduce-done.1 is another (the old substring skip lost it)
    assert sorted(i.base_op for i in insts) == ["all-gather", "all-reduce"]
    stats = collective_stats(_ASYNC_HLO)
    assert stats.counts == {"all-reduce": 1, "all-gather": 1}
    mesh = _fake_mesh({"x": 2})
    hits = collectives_crossing_axis(_ASYNC_HLO, mesh, "x")
    assert sorted(h[0] for h in hits) == ["all-gather", "all-reduce"]


def test_line_dtypes_no_substring_false_positives():
    assert set(line_dtypes(_AR_START)) == {"f32"}
    # bf16 must not also report f16; f8e4m3fn must not report f8/…
    ln = "  %c = bf16[4]{0} convert(f8e4m3fn[4]{0} %p0)"
    assert set(line_dtypes(ln)) == {"bf16", "f8e4m3fn"}
    assert dtype_token(jnp.float32) == "f32"
    assert dtype_token(jnp.bfloat16) == "bf16"
    assert dtype_token(np.dtype("int32")) == "s32"


# ----------------------------------------------- donation / aliasing


def test_input_output_alias_parsing_end_to_end():
    x = jnp.arange(8.0)

    def f(a, b):
        return a + b, b * 2

    txt = jax.jit(f, donate_argnums=(0,)).lower(x, x).compile().as_text()
    aliased = parse_input_output_alias(txt)
    assert aliased is not None and 0 in aliased and 1 not in aliased

    def g(a):                      # smaller output: donation is DROPPED
        return a[:4] * 2.0

    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        txt2 = jax.jit(g, donate_argnums=(0,)).lower(x).compile().as_text()
    assert not (parse_input_output_alias(txt2) or set())


def test_donation_pass_applied_vs_dropped():
    x = jax.ShapeDtypeStruct((8,), jnp.float32)
    ok = donation_pass(_art(lambda a, b: (a + b, b), (x, x), donate=(0,)),
                       DEFAULT_CONTRACT)
    assert ok.ok and not ok.violations

    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        bad = donation_pass(_art(lambda a: a[:4] * 2.0, (x,), donate=(0,)),
                            DEFAULT_CONTRACT)
    assert not bad.ok
    assert any("dropped" in v for v in bad.violations)

    # rank-0 leaves are exempt by default (optimizer step counters)
    s = jax.ShapeDtypeStruct((), jnp.int32)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        res = donation_pass(_art(lambda a: a + 1.0, (s,), donate=(0,)),
                            DEFAULT_CONTRACT)
    assert res.ok


# --------------------------------------------------------------- dtype


def test_dtype_pass_forbid_and_payload_and_args():
    leak = _HDR + "  %c.1 = f64[8]{0} convert(f32[8]{0} %p0)\n"
    res = dtype_pass(_art(hlo_text=leak), DEFAULT_CONTRACT)
    assert not res.ok and any("f64" in v for v in res.violations)

    bad_payload = _HDR + (
        "  %ar.1 = bf16[64]{0} all-reduce(bf16[64]{0} %p0), "
        "replica_groups={{0,1}}, to_apply=%add\n")
    pol = BundleContract(dtypes=DtypePolicy(collective_dtypes=("f32",)))
    res = dtype_pass(_art(hlo_text=bad_payload), pol)
    assert not res.ok and any("payload" in v for v in res.violations)

    clean = _HDR + _AR_START + "\n"
    assert dtype_pass(_art(hlo_text=clean), pol).ok

    # floating arg leaves outside the allowed set
    xb = jax.ShapeDtypeStruct((4,), jnp.bfloat16)
    pol2 = BundleContract(dtypes=DtypePolicy(float_args=("f32",)))
    res = dtype_pass(_art(lambda a: a, (xb,), hlo_text=clean), pol2)
    assert not res.ok and any("bf16" in v for v in res.violations)


# ------------------------------------------------------ manual hazards


def _one_dev_mesh():
    return jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("x",))


def test_manual_hazard_scan_flagged_and_unroll_exempt():
    mesh = _one_dev_mesh()
    P = jax.sharding.PartitionSpec

    def scan_body(xs):
        return jax.lax.scan(lambda c, x: (c + x, x), jnp.zeros(()), xs)[0]

    def manual(xs):
        return shard_map(scan_body, mesh, in_specs=(P(),), out_specs=P(),
                         check_rep=False)(xs)

    jx = jax.make_jaxpr(manual)(jnp.ones((4,)))
    hz = manual_loop_hazards(jx)
    assert len(hz) == 1 and hz[0][0] == "scan"
    assert hz[0][1]["manual_axes"] == ("x",)

    def unrolled_body(xs):
        return jax.lax.scan(lambda c, x: (c + x, x), jnp.zeros(()), xs,
                            unroll=True)[0]

    def manual_unrolled(xs):
        return shard_map(unrolled_body, mesh, in_specs=(P(),),
                         out_specs=P(), check_rep=False)(xs)

    # scan_unroll=True lowers loop-free — exactly the workaround the
    # pass recommends, so it must not be flagged
    jx2 = jax.make_jaxpr(manual_unrolled)(jnp.ones((4,)))
    assert manual_loop_hazards(jx2) == []

    # no shard_map: loops are fine anywhere
    jx3 = jax.make_jaxpr(scan_body)(jnp.ones((4,)))
    assert manual_loop_hazards(jx3) == []


def test_manual_hazard_pallas_body_exempt():
    mesh = _one_dev_mesh()
    P = jax.sharding.PartitionSpec
    from repro.kernels import ops as kops

    def body(xs):
        return kops.online_mean_packed(xs)

    def manual(xs):
        return shard_map(body, mesh, in_specs=(P(),), out_specs=P(),
                         check_rep=False)(xs)

    from repro.kernels.ops import ALIGN
    jx = jax.make_jaxpr(manual)(jnp.ones((2, ALIGN), jnp.float32))
    assert count_pallas_calls(jx) == 1
    # whatever loops live inside the kernel body lower via Mosaic, never
    # the SPMD partitioner — the walker must not descend into them
    assert manual_loop_hazards(jx) == []


def test_run_passes_hazard_gates_compile():
    mesh = _one_dev_mesh()
    P = jax.sharding.PartitionSpec

    def manual(xs):
        def body(b):
            return jax.lax.scan(lambda c, x: (c + x, x),
                                jnp.zeros(()), b)[0]
        return shard_map(body, mesh, in_specs=(P(),), out_specs=P(),
                         check_rep=False)(xs)

    bundle = _TinyBundle(manual, (jnp.ones((4,)),))
    results = run_passes(bundle, mesh)
    by_name = {r.name: r for r in results}
    assert tuple(r.name for r in results) == PASS_NAMES
    assert not by_name["manual_hazard"].ok
    # the fatal it predicts is a process abort — compile-dependent passes
    # must be skipped, not run
    for name in ("collectives", "donation", "dtype"):
        assert by_name[name].skipped


# ------------------------------------------------- collective contracts


_MESH_T = _fake_mesh({"pod": 2, "replica": 2, "model": 2})
_INNER_AR = ('  %ar.0 = f32[1024]{0} all-reduce(f32[1024]{0} %p0), '
             'replica_groups={{0,2},{1,3},{4,6},{5,7}}, to_apply=%add')
_OUTER_AR = ('  %ar.1 = f32[1024]{0} all-reduce(f32[1024]{0} %ar.0), '
             'replica_groups={{0,4},{1,5},{2,6},{3,7}}, to_apply=%add')
_MODEL_AR = ('  %ar.3 = f32[1024]{0} all-reduce(f32[1024]{0} %p0), '
             'replica_groups={{0,1},{2,3},{4,5},{6,7}}, to_apply=%add')


def test_collective_contract_two_level():
    contract = CollectiveContract(axis="replica", outer_axis="pod",
                                  ops={"all-reduce": 1},
                                  outer_ops={"all-reduce": 1})
    good = "\n".join([_HDR, _INNER_AR, _OUTER_AR, ""])
    res = check_collective_contract(good, _MESH_T, contract)
    assert res["ok"], res["violations"]

    # missing outer level
    res = check_collective_contract("\n".join([_HDR, _INNER_AR, ""]),
                                    _MESH_T, contract)
    assert not res["ok"]

    # assembly traffic (model-axis all-reduce) violates assembly_free
    res = check_collective_contract(
        "\n".join([_HDR, _INNER_AR, _OUTER_AR, _MODEL_AR, ""]),
        _MESH_T, contract)
    assert not res["ok"]
    assert any("assembly" in v for v in res["violations"])


def test_collective_contract_flat_and_empty():
    flat = CollectiveContract(axis="replica", ops={"all-reduce": 1})
    mesh = _fake_mesh({"replica": 2, "data": 2, "model": 2})
    one = ('  %ar.0 = f32[64]{0} all-reduce(f32[64]{0} %p0), '
           'replica_groups={{0,4},{1,5},{2,6},{3,7}}, to_apply=%add')
    assert check_collective_contract(_HDR + one + "\n", mesh, flat)["ok"]
    # two replica all-reduces breaks the EXACT count
    two = _HDR + one + "\n" + one.replace("%ar.0", "%ar.1") + "\n"
    assert not check_collective_contract(two, mesh, flat)["ok"]
    # "no collectives anywhere"
    silent = CollectiveContract()
    assert check_collective_contract(_HDR, mesh, silent)["ok"]
    assert not check_collective_contract(_HDR + one + "\n", mesh,
                                         silent)["ok"]


def test_collective_contract_other_ops_budget():
    """The resilient sync's health-stats psum crosses ONLY non-replica
    axes; ``other_ops`` budgets it EXACTLY instead of tripping (or
    silencing) the zero-assembly claim."""
    mesh = _fake_mesh({"replica": 2, "data": 2, "model": 2})
    rep = ('  %ar.0 = f32[64]{0} all-reduce(f32[64]{0} %p0), '
           'replica_groups={{0,4},{1,5},{2,6},{3,7}}, to_apply=%add')
    # health stats: one all-reduce joint over data×model, replica-local
    stats = ('  %ar.1 = f32[4]{0} all-reduce(f32[4]{0} %p1), '
             'replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add')
    plain = CollectiveContract(axis="replica", ops={"all-reduce": 1})
    res = check_collective_contract(
        "\n".join([_HDR, rep, stats, ""]), mesh, plain)
    assert not res["ok"]
    assert any("assembly" in v for v in res["violations"])

    budgeted = CollectiveContract(axis="replica", ops={"all-reduce": 1},
                                  other_ops={"all-reduce": 1})
    res = check_collective_contract(
        "\n".join([_HDR, rep, stats, ""]), mesh, budgeted)
    assert res["ok"], res["violations"]
    # a joint data×model group is still ONE budgeted collective, not two
    assert sum("%ar.1" in ln for ln in res["evidence"]) == 1

    # the budget is EXACT both ways: a vanished health psum is a drifted
    # program, not a win
    res = check_collective_contract("\n".join([_HDR, rep, ""]), mesh,
                                    budgeted)
    assert not res["ok"]
    # and a collective spanning replica AND a non-level axis is miswired
    # level traffic — never absorbed by the other_ops budget
    mixed = ('  %ar.2 = f32[64]{0} all-reduce(f32[64]{0} %p0), '
             'replica_groups={{0,1,4,5},{2,3,6,7}}, to_apply=%add')
    res = check_collective_contract(
        "\n".join([_HDR, rep, stats, mixed, ""]), mesh, budgeted)
    assert not res["ok"]
    assert any("both the" in v for v in res["violations"])

    # factory plumbing: sync_contract pins the budget on the contract
    c = sync_contract(("replica",), launches=0,
                      other_ops={"all-reduce": 1})
    assert c.collectives.other_ops == {"all-reduce": 1}
    assert sync_contract(("replica",), launches=0) \
        .collectives.other_ops == {}


def test_contract_factories():
    c = sync_contract(("replica",), launches=1)
    assert c.collectives.ops == {"all-reduce": 1}
    assert c.launch == LaunchBudget.exact(1)
    assert c.dtypes.collective_dtypes == ("f32",)
    t = train_contract(replica_axes=("pod", "replica"))
    assert t.collectives.assembly_free is False
    assert t.launch is None and t.dtypes.forbid == ("f64",)


# ------------------------------------------------------ report + lint


def test_report_round_trip_and_ok():
    x = jax.ShapeDtypeStruct((8,), jnp.float32)
    results = run_passes(_TinyBundle(lambda a: a * 2, (x,)), None)
    rep = build_report({"case": bundle_entry(results)})
    assert rep["ok"] and report_ok(rep)
    rt = json.loads(to_json(rep))
    assert report_ok(rt) == report_ok(rep)
    assert "OK hwa-lint" in summarize(rt)
    # an empty report is NOT ok (a filtered-to-nothing matrix must fail)
    assert not report_ok(build_report({}))
    # a build error fails the report
    rep2 = build_report({"a": bundle_entry(results),
                         "b": bundle_entry([], error="boom")})
    assert not report_ok(rep2) and rep2["n_violations"] == 1
    assert "ERROR b" in summarize(rep2)


def test_seeded_violation_fails_lint():
    x = jax.ShapeDtypeStruct((8,), jnp.float32)
    # a bundle that CLAIMS one Pallas launch but compiles to zero
    bundle = _TinyBundle(lambda a: a * 2, (x,),
                         contract=BundleContract(
                             launch=LaunchBudget.exact(1)))
    case = LintCase("synthetic/seeded-launch-violation",
                    build=lambda: (bundle, None))
    report = run_lint([case], log=lambda *_: None)
    assert not report["ok"] and report["n_violations"] >= 1
    assert not report_ok(report)     # == hwa_lint exiting nonzero
    entry = report["bundles"]["synthetic/seeded-launch-violation"]
    assert not entry["passes"]["launch_budget"]["ok"]

    res = launch_budget_pass(
        _art(lambda a: a * 2, (x,)),
        BundleContract(launch=LaunchBudget.exact(0)))
    assert res.ok

    # a crashing build becomes a failing entry, not a crashed matrix
    def boom():
        raise RuntimeError("no such mesh")

    bad = run_case(LintCase("synthetic/crash", build=boom))
    assert not bad["ok"] and "no such mesh" in bad["error"]


def test_hazard_pass_result_mentions_workaround():
    mesh = _one_dev_mesh()
    P = jax.sharding.PartitionSpec

    def manual(xs):
        def body(b):
            return jax.lax.scan(lambda c, x: (c + x, x),
                                jnp.zeros(()), b)[0]
        return shard_map(body, mesh, in_specs=(P(),), out_specs=P(),
                         check_rep=False)(xs)

    art = BundleArtifacts(_TinyBundle(manual, (jnp.ones((4,)),)), mesh)
    res = manual_hazard_pass(art, DEFAULT_CONTRACT)
    assert not res.ok
    assert any("scan_unroll" in v for v in res.violations)


# ------------------------------------------------------------- facade


def test_launch_hlo_facade_identity():
    import repro.analysis as analysis
    import repro.launch.hlo as hlo

    for name in hlo.__all__:
        assert getattr(hlo, name) is getattr(analysis, name), name
    # consumers' exact historical import set
    from repro.launch.hlo import (ICI_BW, collective_stats,  # noqa: F401
                                  collectives_crossing_axis,
                                  count_pallas_calls, result_bytes,
                                  roofline_terms, sync_collective_audit)
