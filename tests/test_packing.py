"""Packed WA state (repro.common.packing): round-trip, single-launch
guarantees, exact (0 ULP) equivalence vs the per-leaf formulation, and
checkpoint round-trip + migration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.packing import (ALIGN, pack, pack_spec, pack_stacked,
                                  unpack, unpack_leaf)
from repro.core import (HWAConfig, HWAState, hwa_init, hwa_sync,
                        online_average, window_init, window_update)
from repro.kernels import ref as kref
from repro.launch.hlo import count_pallas_calls
from repro.optim import sgd


def ragged_tree(seed=0):
    """Ragged shapes, mixed dtypes, an empty leaf, a scalar."""
    ks = jax.random.split(jax.random.key(seed), 4)
    return {"w": jax.random.normal(ks[0], (37, 13)),
            "blocks": [{"m": jax.random.normal(ks[1], (8, 128)),
                        "b": jax.random.normal(ks[2], (128,)).astype(
                            jnp.bfloat16)}],
            "empty": jnp.zeros((0, 5)),
            "scale": jax.random.normal(ks[3], ()).astype(jnp.float16)}


def params_like(seed=0):
    k1, k2 = jax.random.split(jax.random.key(seed))
    return {"w": jax.random.normal(k1, (4, 3)),
            "b": jax.random.normal(k2, (7,))}


# ------------------------------------------------------------- round-trip


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_pack_unpack_roundtrip(seed):
    tree = ragged_tree(seed)
    spec = pack_spec(tree)
    assert spec.padded % ALIGN == 0 and spec.padded >= spec.size
    buf = pack(tree, spec)
    assert buf.shape == (spec.padded,) and buf.dtype == jnp.float32
    back = unpack(buf, spec)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


@pytest.mark.slow
def test_roundtrip_property():
    """Hypothesis sweep over arbitrary pytrees (shapes incl. empty/scalar,
    float dtypes that embed exactly in the f32 buffer)."""
    pytest.importorskip("hypothesis", reason="hypothesis not installed "
                        "(see requirements-dev.txt)")
    from hypothesis import given, settings, strategies as st

    shapes = st.lists(st.integers(0, 9), min_size=0, max_size=3).map(tuple)
    dtypes = st.sampled_from(["float32", "bfloat16", "float16"])

    @given(st.lists(st.tuples(shapes, dtypes), min_size=0, max_size=8),
           st.integers(0, 1000))
    @settings(max_examples=25, deadline=None)
    def run(leaf_specs, seed):
        ks = jax.random.split(jax.random.key(seed), max(len(leaf_specs), 1))
        tree = {f"l{i}": jax.random.normal(ks[i], shape).astype(dt)
                for i, (shape, dt) in enumerate(leaf_specs)}
        spec = pack_spec(tree)
        back = unpack(pack(tree, spec), spec)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            assert a.shape == b.shape and a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))

    run()


def test_unpack_leaf_and_stacked_views():
    tree = ragged_tree()
    spec = pack_spec(tree)
    buf = pack(tree, spec)
    flat = jax.tree.leaves(tree)
    for i in range(spec.n_leaves):
        np.testing.assert_array_equal(
            np.asarray(unpack_leaf(buf, spec, i), np.float32),
            np.asarray(flat[i], np.float32))
    stacked_tree = jax.tree.map(lambda x: jnp.stack([x, 2 * x]), tree)
    sbuf = pack_stacked(stacked_tree, spec)
    assert sbuf.shape == (2, spec.padded)
    np.testing.assert_array_equal(np.asarray(sbuf[0]), np.asarray(buf))
    # unpack preserves leading batch dims (ring rows never get unpacked
    # wholesale in production; this is the debugging view)
    back = unpack(sbuf, spec)
    for a, b in zip(jax.tree.leaves(stacked_tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


# ------------------------------------------------- shard-aware layout
#
# The mesh-resident sync keeps the window state in a segment-major layout
# (one segment per device of the packed super-axis) so packing is a
# purely LOCAL operation on every device. These tests pin the invariants
# that make that work (pure layout math — no mesh needed).


def sharded_tree(seed=0):
    """Leaves covering all placement cases: dim-0 sharded, dim-1 sharded,
    replicated (indivisible), scalar."""
    ks = jax.random.split(jax.random.key(seed), 4)
    return {"embed": jax.random.normal(ks[0], (8, 10)),     # shard dim 0
            "head": jax.random.normal(ks[1], (10, 8)),      # shard dim 1
            "bias": jax.random.normal(ks[2], (7,)),         # replicated
            "scale": jax.random.normal(ks[3], ())}          # replicated


SHARD_DIMS = [None, 0, 1, None]       # flatten order: bias, embed, head, scale


@pytest.mark.parametrize("shards", [2, 4])
def test_sharded_layout_roundtrip(shards):
    tree = sharded_tree()
    spec = pack_spec(tree, align=16, shards=shards, shard_dims=SHARD_DIMS,
                     axes=("model",))
    assert spec.padded == shards * spec.seg_len
    assert spec.seg_len % spec.align == 0
    buf = pack(tree, spec)
    assert buf.shape == (spec.padded,)
    back = unpack(buf, spec)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    for i in range(spec.n_leaves):
        np.testing.assert_array_equal(
            np.asarray(unpack_leaf(buf, spec, i), np.float32),
            np.asarray(jax.tree.leaves(tree)[i], np.float32))
    stacked = jax.tree.map(lambda x: jnp.stack([x, 3 * x]), tree)
    sbuf = pack_stacked(stacked, spec)
    np.testing.assert_array_equal(np.asarray(sbuf[0]), np.asarray(buf))
    np.testing.assert_array_equal(np.asarray(sbuf[1]), 3 * np.asarray(buf))


def test_local_spec_segments_are_local_packs():
    """THE mesh-resident invariant: segment s of the global pack equals
    the local pack of shard s's leaf slices under spec.local_spec()."""
    shards = 2
    tree = sharded_tree()
    spec = pack_spec(tree, align=16, shards=shards, shard_dims=SHARD_DIMS,
                     axes=("model",))
    lspec = spec.local_spec()
    assert lspec.shards == 1 and lspec.padded == spec.seg_len
    buf = np.asarray(pack(tree, spec))
    flat, _ = jax.tree.flatten(tree)
    for s in range(shards):
        local_flat = []
        for leaf, ls in zip(flat, spec.leaves):
            if ls.shard_dim is None:
                local_flat.append(leaf)
            else:
                c = leaf.shape[ls.shard_dim] // shards
                local_flat.append(jax.lax.slice_in_dim(
                    leaf, s * c, (s + 1) * c, axis=ls.shard_dim))
        local_tree = jax.tree.unflatten(spec.treedef, local_flat)
        seg = np.asarray(pack(local_tree, lspec))
        np.testing.assert_array_equal(
            buf[s * spec.seg_len:(s + 1) * spec.seg_len], seg)


def test_sharded_layout_update_bitwise_equals_contiguous():
    """The same elementwise update on both layouts yields bit-identical
    leaf views (packing is layout-only)."""
    tree = sharded_tree()
    spec_c = pack_spec(tree, align=16)
    spec_s = pack_spec(tree, align=16, shards=2, shard_dims=SHARD_DIMS)
    new = sharded_tree(7)
    outs = {}
    for name, spec in [("contig", spec_c), ("sharded", spec_s)]:
        ring = jnp.zeros((3, spec.padded))
        total = pack(tree, spec)
        ring2, total2, avg = kref.wa_window_update_ref(
            ring, total, pack(new, spec), 1, 0.0, 0.5)
        outs[name] = (unpack(ring2[1], spec), unpack(total2, spec),
                      unpack(avg, spec))
    for a, b in zip(jax.tree.leaves(outs["contig"]),
                    jax.tree.leaves(outs["sharded"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_repack_and_spec_json_roundtrip():
    from repro.common.packing import repack, spec_from_json, spec_to_json
    tree = sharded_tree()
    spec_c = pack_spec(tree, align=16)
    spec_s = pack_spec(tree, align=16, shards=2, shard_dims=SHARD_DIMS,
                       axes=("data", "model"))
    buf = pack(tree, spec_s)
    np.testing.assert_array_equal(np.asarray(repack(buf, spec_s, spec_c)),
                                  np.asarray(pack(tree, spec_c)))
    # ring-style lead dims survive repack
    ring = jnp.stack([buf, 2 * buf])
    back = repack(ring, spec_s, spec_c)
    np.testing.assert_array_equal(np.asarray(back[1]),
                                  2 * np.asarray(pack(tree, spec_c)))
    rehydrated = spec_from_json(spec_to_json(spec_s))
    assert rehydrated.same_layout(spec_s)
    assert rehydrated.axes == ("data", "model")
    # treedef-less specs still drive leaf-level ops
    np.testing.assert_array_equal(
        np.asarray(unpack_leaf(buf, rehydrated, 1)),
        np.asarray(jax.tree.leaves(tree)[1]))


def test_pack_spec_rejects_indivisible_shard_dim():
    tree = sharded_tree()
    with pytest.raises(ValueError, match="cannot shard"):
        # bias is (7,): 7 % 4 != 0
        pack_spec(tree, shards=4, shard_dims=[0, None, None, None])


# ------------------------------------------------------ grouped layout
#
# Mixed (FSDP-style) tilings: leaves shard over DIFFERENT axis sets, some
# over several dims at once. No single super-axis aligns them, so the
# grouped layout gives each placement key its own contiguous range
# (PackGroup) — its own shard count and super-axis — and replicated
# leaves a shards==1 range stored once. Pure layout math, no mesh needed.

GROUPED_SIZES = {"data": 2, "model": 3}


def grouped_tree(seed=0):
    """One leaf per placement class: 2-dim data×model tile, data-only,
    model-only, replicated vector, replicated scalar."""
    ks = jax.random.split(jax.random.key(seed), 5)
    return {"fs": jax.random.normal(ks[0], (4, 6)),    # data × model
            "emb": jax.random.normal(ks[1], (8, 5)),   # dim 0 over data
            "head": jax.random.normal(ks[2], (5, 6)),  # dim 1 over model
            "bias": jax.random.normal(ks[3], (7,)),    # replicated
            "scale": jax.random.normal(ks[4], ())}     # replicated


# flatten order: bias, emb, fs, head, scale
GROUPED_PLACEMENTS = [
    (),
    ((0, ("data",)),),
    ((0, ("data",)), (1, ("model",))),
    ((1, ("model",)),),
    (),
]


def grouped_spec(tree, align=8):
    from repro.common.packing import pack_spec_grouped
    return pack_spec_grouped(tree, align=align,
                             placements=GROUPED_PLACEMENTS,
                             axis_sizes=GROUPED_SIZES)


@pytest.mark.parametrize("seed", [0, 3])
def test_grouped_layout_roundtrip(seed):
    tree = grouped_tree(seed)
    spec = grouped_spec(tree)
    gt = spec.group_table()
    assert spec.is_grouped and spec.n_groups == 4
    assert spec.padded == sum(g.padded for g in gt)
    assert all(g.seg_len % spec.align == 0 for g in gt)
    # group ranges are contiguous and ordered by first appearance
    assert [g.offset for g in gt] == \
        [sum(h.padded for h in gt[:i]) for i in range(len(gt))]
    buf = pack(tree, spec)
    assert buf.shape == (spec.padded,)
    back = unpack(buf, spec)
    flat = jax.tree.leaves(tree)
    for a, b in zip(flat, jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    for i in range(spec.n_leaves):
        np.testing.assert_array_equal(
            np.asarray(unpack_leaf(buf, spec, i), np.float32),
            np.asarray(flat[i], np.float32))
    stacked = jax.tree.map(lambda x: jnp.stack([x, 3 * x]), tree)
    sbuf = pack_stacked(stacked, spec)
    np.testing.assert_array_equal(np.asarray(sbuf[0]), np.asarray(buf))
    np.testing.assert_array_equal(np.asarray(sbuf[1]), 3 * np.asarray(buf))


def test_grouped_segments_are_local_packs():
    """THE mesh-resident invariant, grouped: for every device coordinate
    (c_data, c_model), packing the device's LOCAL leaf blocks under
    spec.local_spec() reproduces exactly its segment of every group of
    the global pack — multi-dim tiles included."""
    tree = grouped_tree()
    spec = grouped_spec(tree)
    lspec = spec.local_spec()
    gt = spec.group_table()
    assert lspec.is_grouped and all(g.shards == 1
                                    for g in lspec.group_table())
    assert lspec.padded == sum(g.seg_len for g in gt)
    buf = np.asarray(pack(tree, spec))
    flat, treedef = jax.tree.flatten(tree)
    b, e, f, h, s = flat            # bias, emb, fs, head, scale
    nd, nm = GROUPED_SIZES["data"], GROUPED_SIZES["model"]
    for cd in range(nd):
        for cm in range(nm):
            local = jax.tree.unflatten(treedef, [
                b,
                e[cd * (8 // nd):(cd + 1) * (8 // nd)],
                f[cd * (4 // nd):(cd + 1) * (4 // nd),
                  cm * (6 // nm):(cm + 1) * (6 // nm)],
                h[:, cm * (6 // nm):(cm + 1) * (6 // nm)],
                s])
            lbuf = np.asarray(pack(local, lspec))
            # segment index per group: row-major over the group's axes
            seg = {(): 0, ("data",): cd, ("model",): cm,
                   ("data", "model"): cd * nm + cm}
            want = np.concatenate([
                buf[g.offset + seg[g.axes] * g.seg_len:
                    g.offset + (seg[g.axes] + 1) * g.seg_len]
                for g in gt])
            np.testing.assert_array_equal(lbuf, want)


def test_grouped_layout_update_bitwise_equals_contiguous():
    """The same elementwise update on the grouped and contiguous layouts
    yields bit-identical leaf views (packing is layout-only)."""
    tree = grouped_tree()
    spec_c = pack_spec(tree, align=8)
    spec_g = grouped_spec(tree)
    new = grouped_tree(9)
    outs = {}
    for name, spec in [("contig", spec_c), ("grouped", spec_g)]:
        ring = jnp.zeros((3, spec.padded))
        total = pack(tree, spec)
        ring2, total2, avg = kref.wa_window_update_ref(
            ring, total, pack(new, spec), 1, 0.0, 0.5)
        outs[name] = (unpack(ring2[1], spec), unpack(total2, spec),
                      unpack(avg, spec))
    for a, b in zip(jax.tree.leaves(outs["contig"]),
                    jax.tree.leaves(outs["grouped"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_grouped_repack_json_and_split_roundtrip():
    from repro.common.packing import (merge_groups, repack, spec_from_json,
                                      spec_to_json, split_groups)
    tree = grouped_tree()
    spec_c = pack_spec(tree, align=8)
    spec_g = grouped_spec(tree)
    buf = pack(tree, spec_g)
    # grouped <-> contiguous, both directions, bit-exact
    np.testing.assert_array_equal(np.asarray(repack(buf, spec_g, spec_c)),
                                  np.asarray(pack(tree, spec_c)))
    np.testing.assert_array_equal(
        np.asarray(repack(pack(tree, spec_c), spec_c, spec_g)),
        np.asarray(buf))
    # grouped <-> single-super-axis shard-aware layout
    spec_s = pack_spec(tree, align=8, shards=2,
                       shard_dims=[None, 0, 0, None, None],
                       axes=("data",))
    np.testing.assert_array_equal(
        np.asarray(repack(repack(buf, spec_g, spec_s), spec_s, spec_c)),
        np.asarray(pack(tree, spec_c)))
    # ring-style lead dims survive
    ring = jnp.stack([buf, 2 * buf])
    np.testing.assert_array_equal(
        np.asarray(repack(ring, spec_g, spec_c)[1]),
        2 * np.asarray(pack(tree, spec_c)))
    # JSON round-trip keeps groups and multi-dim tiles
    re = spec_from_json(spec_to_json(spec_g))
    assert re.same_layout(spec_g)
    assert re.group_table() == spec_g.group_table()
    assert any(ls.tiles is not None for ls in re.leaves)
    np.testing.assert_array_equal(
        np.asarray(unpack_leaf(buf, re, 2)),
        np.asarray(jax.tree.leaves(tree)[2]))
    # per-group runtime views merge back bit-exactly
    parts = split_groups(buf, spec_g)
    assert len(parts) == spec_g.n_groups
    np.testing.assert_array_equal(np.asarray(merge_groups(parts, spec_g)),
                                  np.asarray(buf))


def test_grouped_window_buffers_match_contract():
    from repro.common.packing import window_buffers
    tree = grouped_tree()
    spec_g = grouped_spec(tree)
    ring, total = window_buffers(spec_g, 3)
    assert isinstance(ring, tuple) and len(ring) == spec_g.n_groups
    for r, t, g in zip(ring, total, spec_g.group_table()):
        assert r.shape == (3, g.padded) and t.shape == (g.padded,)
    spec_c = pack_spec(tree, align=8)
    ring_c, total_c = window_buffers(spec_c, 3)
    assert ring_c.shape == (3, spec_c.padded)
    assert total_c.shape == (spec_c.padded,)


def test_pack_spec_grouped_rejections():
    from repro.common.packing import pack_spec_grouped
    tree = grouped_tree()
    with pytest.raises(ValueError, match="cannot tile"):
        # bias is (7,): 7 % 2 != 0
        pack_spec_grouped(tree, placements=[((0, ("data",)),), (), (), (),
                                            ()],
                          axis_sizes=GROUPED_SIZES)
    with pytest.raises(ValueError, match="ascending"):
        pack_spec_grouped(
            tree,
            placements=[(), (), ((1, ("model",)), (0, ("data",))), (), ()],
            axis_sizes=GROUPED_SIZES)


def test_grouped_window_state_checkpoint_cross_layout(tmp_path):
    """A grouped (per-group tuple) window state saves to the canonical
    single-buffer form and loads bit-exactly into a contiguous template,
    and a contiguous save loads into a grouped (tuple-buffer) template —
    grouped↔single-axis↔per-leaf migrations all repack, never copy-cast.
    """
    from repro.checkpoint import load_window_state, save_window_state
    from repro.common.packing import repack, split_groups
    from repro.core.offline import WindowState

    p = grouped_tree()
    ws = window_init(p, 3)
    for t in range(4):
        ws, _ = window_update(ws, grouped_tree(20 + t))
    spec_g = grouped_spec(p, align=8)
    ring_g = split_groups(repack(ws.ring, ws.spec, spec_g), spec_g)
    total_g = split_groups(repack(ws.total, ws.spec, spec_g), spec_g)
    ws_g = WindowState(ring=ring_g, total=total_g, count=ws.count,
                       next_idx=ws.next_idx, window=ws.window,
                       kind=ws.kind, spec=spec_g)
    path = str(tmp_path / "ws_grouped.npz")
    save_window_state(path, ws_g)
    back = load_window_state(path, window_init(p, 3))
    np.testing.assert_array_equal(np.asarray(back.ring), np.asarray(ws.ring))
    np.testing.assert_array_equal(np.asarray(back.total),
                                  np.asarray(ws.total))
    assert int(back.count) == int(ws.count)
    # contiguous save -> grouped tuple template
    path_c = str(tmp_path / "ws_contig.npz")
    save_window_state(path_c, ws)
    back_g = load_window_state(path_c, ws_g)
    assert isinstance(back_g.ring, tuple)
    for a, b in zip(back_g.ring, ring_g):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(back_g.total, total_g):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # per-leaf (pre-packing) checkpoint -> grouped template
    from repro.checkpoint import save_pytree
    old_ring = {k: np.stack([np.asarray(unpack(ws.ring[r], ws.spec)[k])
                             for r in range(3)]) for k in p}
    old_total = {k: np.asarray(unpack(ws.total, ws.spec)[k]) for k in p}
    path_l = str(tmp_path / "ws_per_leaf.npz")
    save_pytree(path_l, {"ring": old_ring, "total": old_total,
                         "count": ws.count, "next_idx": ws.next_idx})
    back_l = load_window_state(path_l, ws_g)
    for a, b in zip(back_l.ring, ring_g):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------ layout choosers


def _fake_mesh(shape: dict):
    import types
    return types.SimpleNamespace(shape=shape, axis_names=tuple(shape))


def test_mesh_resident_layout_rejects_zero_size_leaves():
    """Regression (hoisted guard): a ZERO-SIZE REPLICATED leaf used to
    slip through the chooser — the `all(d > 0)` check only ran for
    sharded leaves — and break the segment-major invariant downstream.
    Both choosers must refuse the whole tree."""
    from jax.sharding import PartitionSpec as P
    from repro.launch.sync.packed import (_grouped_resident_layout,
                                          _mesh_resident_layout)
    mesh = _fake_mesh({"data": 2, "model": 2})
    specs = [P("model"), P()]
    shapes = [(8,), (0, 5)]
    assert _mesh_resident_layout(mesh, specs, shapes) == (None, None)
    assert _grouped_resident_layout(mesh, specs, shapes) is None
    # control: dropping the zero-size leaf re-qualifies the same tree
    axes, dims = _mesh_resident_layout(mesh, specs[:1], shapes[:1])
    assert axes == ("model",) and dims == [0]
    # the degenerate fully-replicated (shards==1) layout stays available
    # — contiguous packing supports empty leaves, only SHARDED segment
    # layouts must refuse them
    axes, dims = _mesh_resident_layout(mesh, [P(), P()], [(4,), (0, 5)])
    assert axes == () and dims == [None, None]


def test_grouped_resident_layout_placements():
    from jax.sharding import PartitionSpec as P
    from repro.launch.sync.packed import _grouped_resident_layout
    mesh = _fake_mesh({"replica": 2, "data": 2, "model": 2})
    specs = [P("data"), P(None, "model"), P("data", "model"), P()]
    shapes = [(4,), (3, 6), (4, 6), (5,)]
    pl = _grouped_resident_layout(mesh, specs, shapes,
                                  exclude=("replica",))
    assert pl == (((0, ("data",)),), ((1, ("model",)),),
                  ((0, ("data",)), (1, ("model",))), ())
    # a leaf sharded over an excluded (replica) axis disqualifies
    assert _grouped_resident_layout(mesh, [P("replica")], [(4,)],
                                    exclude=("replica",)) is None
    # an indivisible tiled dim disqualifies
    assert _grouped_resident_layout(mesh, [P("model")], [(7,)]) is None
    # fully-replicated trees are the single-axis chooser's job
    assert _grouped_resident_layout(mesh, [P()], [(4,)]) is None


def test_choose_resident_spec_prefers_single_axis():
    """Uniform tilings keep the PR-3 single-super-axis layout (bit- and
    layout-compatible with existing checkpoints); only genuinely mixed
    tilings get the grouped one."""
    from jax.sharding import PartitionSpec as P
    from repro.launch.sync.packed import choose_resident_spec
    mesh = _fake_mesh({"data": 2, "model": 2})
    abs_tree = {"a": jax.ShapeDtypeStruct((8, 4), jnp.float32),
                "b": jax.ShapeDtypeStruct((6,), jnp.float32)}
    uniform = choose_resident_spec(mesh, abs_tree,
                                   [P(None, "model"), P()],
                                   [(8, 4), (6,)])
    assert not uniform.is_grouped and uniform.axes == ("model",)
    mixed = choose_resident_spec(mesh, abs_tree,
                                 [P("data", "model"), P("model")],
                                 [(8, 4), (6,)])
    assert mixed.is_grouped and mixed.n_groups == 2


# ----------------------------------------- 0 ULP vs per-leaf formulation


@pytest.mark.parametrize("use_kernel", [False, True])
def test_window_update_bitwise_equals_per_leaf(use_kernel):
    """The packed window state is bit-identical (0 ULP, f32) to running
    the reference update independently on every leaf."""
    I = 3
    p0 = params_like()
    ws = window_init(p0, I)
    leaf_ring = jax.tree.map(lambda x: jnp.zeros((I,) + x.shape), p0)
    leaf_total = jax.tree.map(jnp.zeros_like, p0)
    is3 = lambda x: isinstance(x, tuple) and len(x) == 3
    for t in range(7):
        outer = params_like(100 + t)
        ws, wa = window_update(ws, outer, use_kernel=use_kernel)
        idx, full = t % I, float(t >= I)
        inv = 1.0 / min(t + 1, I)
        triples = jax.tree.map(
            lambda r, tt, n: kref.wa_window_update_ref(
                r, tt, n, idx, full, inv), leaf_ring, leaf_total, outer)
        leaf_ring = jax.tree.map(lambda x: x[0], triples, is_leaf=is3)
        leaf_total = jax.tree.map(lambda x: x[1], triples, is_leaf=is3)
        leaf_wa = jax.tree.map(lambda x: x[2], triples, is_leaf=is3)
        for a, b in zip(jax.tree.leaves(wa), jax.tree.leaves(leaf_wa)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        unpacked_total = unpack(ws.total, ws.spec)
        for a, b in zip(jax.tree.leaves(unpacked_total),
                        jax.tree.leaves(leaf_total)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for row in range(I):
            ring_row = unpack(ws.ring[row], ws.spec)
            for a, b in zip(jax.tree.leaves(ring_row),
                            jax.tree.leaves(leaf_ring)):
                np.testing.assert_array_equal(np.asarray(a),
                                              np.asarray(b[row]))


def test_online_average_kernel_bitwise_equals_per_leaf():
    K = 4   # power of two: sum*(1/K) == sum/K bitwise
    stacked = jax.tree.map(
        lambda x: jnp.stack([x * (i + 1) for i in range(K)]),
        params_like())
    got = online_average(stacked, use_kernel=True)
    want = jax.tree.map(lambda x: jnp.mean(x, 0), stacked)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("window_stride", [1, 2])
def test_hwa_sync_kernel_path_equals_reference(window_stride):
    """Fused sync (stride 1: single launch) and the packed two-step
    (stride 2: cond'd) produce bitwise-identical state vs the jnp path
    for K=2 (1/K exact in f32)."""
    opt = sgd(momentum=0.0)
    mk = lambda uk: HWAConfig(n_replicas=2, window=3, use_kernels=uk,
                              window_stride=window_stride)
    states = {}
    for uk in (False, True):
        state = hwa_init(mk(uk), params_like(), opt)
        inner = jax.tree.map(
            lambda x: jnp.stack([x, x * 1.5]), params_like(1))
        state = HWAState(inner=inner, inner_opt=state.inner_opt,
                         window_state=state.window_state, wa=state.wa,
                         cycle=state.cycle, step=state.step)
        for _ in range(3):
            state, _ = hwa_sync(mk(uk), state)
        states[uk] = state
    a, b = states[False], states[True]
    for x, y in zip(jax.tree.leaves((a.inner, a.wa)),
                    jax.tree.leaves((b.inner, b.wa))):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    np.testing.assert_array_equal(np.asarray(a.window_state.total),
                                  np.asarray(b.window_state.total))
    np.testing.assert_array_equal(np.asarray(a.window_state.ring),
                                  np.asarray(b.window_state.ring))
    assert int(a.window_state.count) == int(b.window_state.count)


# --------------------------------------------------- one launch, always


def test_window_update_is_one_pallas_call():
    """O(1) launches regardless of leaf count (the tentpole guarantee)."""
    tree = {f"l{i}": jnp.ones((5 + i,)) for i in range(12)}
    ws = window_init(tree, 4)
    jaxpr = jax.make_jaxpr(
        lambda w, o: window_update(w, o, use_kernel=True))(ws, tree)
    assert count_pallas_calls(jaxpr) == 1


def test_online_average_is_one_pallas_call():
    tree = {f"l{i}": jnp.ones((3, 5 + i)) for i in range(12)}
    jaxpr = jax.make_jaxpr(
        lambda t: online_average(t, use_kernel=True))(tree)
    assert count_pallas_calls(jaxpr) == 1


def test_fused_sync_is_one_pallas_call_total():
    cfg = HWAConfig(n_replicas=2, window=3, use_kernels=True)
    state = hwa_init(cfg, {f"l{i}": jnp.ones((7 + i,)) for i in range(12)},
                     sgd(momentum=0.0))
    jaxpr = jax.make_jaxpr(lambda s: hwa_sync(cfg, s))(state)
    assert count_pallas_calls(jaxpr) == 1


def test_per_leaf_path_is_one_launch_per_leaf():
    """The baseline the packed path replaces: L leaves ⇒ L launches."""
    from repro.kernels import ops as kops
    tree = {f"l{i}": jnp.ones((5 + i,)) for i in range(12)}
    ring = jax.tree.map(lambda x: jnp.zeros((4,) + x.shape), tree)
    total = jax.tree.map(jnp.zeros_like, tree)
    jaxpr = jax.make_jaxpr(lambda r, t, n: jax.tree.map(
        lambda rr, tt, nn: kops.wa_window_update(rr, tt, nn, 0, 1.0, 0.25),
        r, t, n))(ring, total, tree)
    assert count_pallas_calls(jaxpr) == len(jax.tree.leaves(tree))


# ------------------------------------------------------------ checkpoint


def test_window_state_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import load_window_state, save_window_state
    ws = window_init(params_like(), 3)
    for t in range(4):
        ws, _ = window_update(ws, params_like(10 + t))
    path = str(tmp_path / "ws.npz")
    save_window_state(path, ws)
    like = window_init(params_like(), 3)
    back = load_window_state(path, like)
    np.testing.assert_array_equal(np.asarray(back.ring), np.asarray(ws.ring))
    np.testing.assert_array_equal(np.asarray(back.total),
                                  np.asarray(ws.total))
    assert int(back.count) == int(ws.count)
    assert int(back.next_idx) == int(ws.next_idx)
    assert back.spec == ws.spec


def test_window_state_migration_from_per_leaf(tmp_path):
    """Pre-packing checkpoints stored one ring/total leaf PER PARAMETER;
    loading re-packs them bit-identically."""
    from repro.checkpoint import load_window_state, save_pytree
    I = 3
    p = params_like()
    ws = window_init(p, I)
    for t in range(4):
        ws, _ = window_update(ws, params_like(10 + t))
    # write the OLD format: per-leaf (I, *shape) ring and (*shape) total
    old_ring = {k: np.stack([np.asarray(unpack(ws.ring[r], ws.spec)[k])
                             for r in range(I)]) for k in p}
    old_total = {k: np.asarray(unpack(ws.total, ws.spec)[k]) for k in p}
    path = str(tmp_path / "old_ws.npz")
    save_pytree(path, {"ring": old_ring, "total": old_total,
                       "count": ws.count, "next_idx": ws.next_idx})
    back = load_window_state(path, window_init(p, I))
    np.testing.assert_array_equal(np.asarray(back.ring), np.asarray(ws.ring))
    np.testing.assert_array_equal(np.asarray(back.total),
                                  np.asarray(ws.total))
    assert int(back.count) == int(ws.count)


def test_window_state_checkpoint_cross_layout(tmp_path):
    """A window state saved under a shard-aware (mesh) layout loads
    bit-exactly into a contiguous (single-device) template, and back —
    the save records the layout, the load repacks."""
    from repro.checkpoint import load_window_state, save_window_state
    from repro.core.offline import WindowState

    p = params_like()       # {"w": (4,3), "b": (7,)} — flatten: b, w
    ws = window_init(p, 3)
    for t in range(4):
        ws, _ = window_update(ws, params_like(10 + t))
    # re-express the same state in a 3-way sharded layout (w on dim 1)
    from repro.common.packing import repack
    spec_s = pack_spec(p, align=16, shards=3, shard_dims=[None, 1],
                       axes=("model",))
    ws_s = WindowState(ring=repack(ws.ring, ws.spec, spec_s),
                       total=repack(ws.total, ws.spec, spec_s),
                       count=ws.count, next_idx=ws.next_idx,
                       window=ws.window, kind=ws.kind, spec=spec_s)
    path = str(tmp_path / "ws_sharded.npz")
    save_window_state(path, ws_s)
    back = load_window_state(path, window_init(p, 3))
    np.testing.assert_array_equal(np.asarray(back.ring), np.asarray(ws.ring))
    np.testing.assert_array_equal(np.asarray(back.total),
                                  np.asarray(ws.total))
    assert int(back.count) == int(ws.count)
    # and the reverse direction: contiguous save -> sharded template
    path2 = str(tmp_path / "ws_contig.npz")
    save_window_state(path2, ws)
    like_s = WindowState(ring=jnp.zeros((3, spec_s.padded)),
                         total=jnp.zeros((spec_s.padded,)),
                         count=ws.count, next_idx=ws.next_idx,
                         window=ws.window, kind=ws.kind, spec=spec_s)
    back_s = load_window_state(path2, like_s)
    np.testing.assert_array_equal(np.asarray(back_s.ring),
                                  np.asarray(ws_s.ring))


def test_window_state_checkpoint_pre_metadata_into_sharded(tmp_path):
    """Checkpoints written BEFORE layout metadata existed (a single
    packed buffer, no spec_json) load into a shard-aware template: the
    only layout ever written back then was the default contiguous one,
    so the loader rederives it and repacks."""
    from repro.checkpoint import load_window_state, save_pytree
    from repro.common.packing import repack
    from repro.core.offline import WindowState

    p = params_like()
    ws = window_init(p, 3)
    for t in range(3):
        ws, _ = window_update(ws, params_like(20 + t))
    # simulate the old save: raw buffers only, no spec_json entry
    path = str(tmp_path / "old_packed.npz")
    save_pytree(path, {"ring": ws.ring, "total": ws.total,
                       "count": ws.count, "next_idx": ws.next_idx})
    spec_s = pack_spec(p, shards=3, shard_dims=[None, 1], axes=("model",))
    like_s = WindowState(ring=jnp.zeros((3, spec_s.padded)),
                         total=jnp.zeros((spec_s.padded,)),
                         count=ws.count, next_idx=ws.next_idx,
                         window=ws.window, kind=ws.kind, spec=spec_s)
    back = load_window_state(path, like_s)
    np.testing.assert_array_equal(np.asarray(back.ring),
                                  np.asarray(repack(ws.ring, ws.spec,
                                                    spec_s)))
    np.testing.assert_array_equal(np.asarray(back.total),
                                  np.asarray(repack(ws.total, ws.spec,
                                                    spec_s)))


def test_window_state_migration_rejects_mismatched_keys(tmp_path):
    """Same shapes under different key paths must NOT migrate silently —
    positional packing would put values at the wrong offsets."""
    from repro.checkpoint import load_window_state, save_pytree
    I = 2
    tmpl = {"a": jnp.zeros((3,)), "b": jnp.zeros((3,))}
    zeros = np.zeros((3,), np.float32)
    path = str(tmp_path / "bad_ws.npz")
    save_pytree(path, {
        "ring": {"c": np.zeros((I, 3), np.float32),
                 "d": np.zeros((I, 3), np.float32)},
        "total": {"c": zeros, "d": zeros},
        "count": jnp.zeros((), jnp.int32),
        "next_idx": jnp.zeros((), jnp.int32)})
    with pytest.raises(ValueError, match="key mismatch"):
        load_window_state(path, window_init(tmpl, I))


# ------------------------------------------------- serving publish path


def test_wa_snapshot_matches_window_mean(tmp_path):
    """The serving-tier snapshot (live state AND checkpoint file) is the
    bitwise packed W̿ for both window kinds."""
    from repro.checkpoint.io import load_wa_snapshot, save_window_state
    from repro.serve.publish import wa_snapshot
    for kind in ("ring", "streaming"):
        ws = window_init(params_like(), 3, kind=kind)
        want = None
        for t in range(2):
            ws, want = window_update(ws, params_like(10 + t))
        buf, spec = wa_snapshot(ws)
        np.testing.assert_array_equal(
            np.asarray(unpack(buf, spec, like=params_like())["w"]),
            np.asarray(want["w"]))
        path = str(tmp_path / f"ws_{kind}.npz")
        save_window_state(path, ws)
        buf2, spec2 = load_wa_snapshot(path)
        assert spec2.same_layout(spec)
        np.testing.assert_array_equal(np.asarray(buf2), np.asarray(buf))


def test_weight_publisher_repack_is_bit_exact():
    """Publishing from a foreign (shard-aware) layout is a pure layout
    move: served params are bitwise the source tree, double-buffered."""
    from repro.serve.publish import WeightPublisher

    class FakeEngine:
        def __init__(self, params):
            self.params = params

        def set_params(self, new):
            self.params = new

    eng = FakeEngine(params_like(0))
    pub = WeightPublisher(engine=eng)
    src_tree = params_like(5)
    src_spec = pack_spec(src_tree, align=16, shards=3,
                         shard_dims=[None, 1], axes=("model",))
    old = eng.params
    new = pub.publish_packed(pack(src_tree, src_spec), src_spec)
    assert eng.params is new and pub._standby is old
    for a, b in zip(jax.tree.leaves(new), jax.tree.leaves(src_tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert pub.n_published == 1


# ------------------------------------------------------------------ TPU


@pytest.mark.tpu
@pytest.mark.skipif(jax.default_backend() != "tpu",
                    reason="compiled (non-interpret) Pallas needs a TPU")
def test_packed_kernels_compiled_on_tpu():
    from repro.kernels.wa_update import wa_sync_fused_2d, wa_window_update_2d
    tree = ragged_tree()
    spec = pack_spec(tree)
    new = pack(tree, spec)
    ring = jnp.zeros((2, spec.padded // 1024, 1024))
    total = jnp.zeros((spec.padded // 1024, 1024))
    got = wa_window_update_2d(ring, total, new.reshape(total.shape),
                              jnp.int32(0), jnp.float32(0.0),
                              jnp.float32(1.0), interpret=False)
    want = kref.wa_window_update_ref(ring, total, new.reshape(total.shape),
                                     0, 0.0, 1.0)
    for a, b in zip(got, want):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ----------------------------------------- compressed WA precision (PR 10)


def test_wa_tokens_roundtrip_and_reject():
    from repro.common import quant
    assert quant.wa_dtype("bf16") == jnp.bfloat16
    assert quant.wa_dtype(jnp.float8_e4m3fn) == jnp.float8_e4m3fn
    for tok in ("f32", "bf16", "fp8"):
        assert quant.wa_token(quant.wa_dtype(tok)) == tok
    assert not quant.is_compressed("f32")
    assert quant.is_compressed("fp8") and quant.needs_scales("fp8")
    assert quant.is_compressed("bf16") and not quant.needs_scales("bf16")
    with pytest.raises(ValueError, match="no WA precision token"):
        quant.wa_token(jnp.float16)
    with pytest.raises(ValueError, match="not a multiple"):
        quant.n_scale_blocks(quant.SCALE_BLOCK + 1)


def test_ulp_distance_ladder():
    from repro.common.quant import max_ulp, ulp_distance
    x = np.float32(1.5)
    assert max_ulp(x, x) == 0
    assert max_ulp(x, np.nextafter(x, np.float32(2.0))) == 1
    # across the sign: the ladder counts subnormal steps, ±0 coincide
    denorm = np.nextafter(np.float32(0.0), np.float32(1.0))
    assert int(ulp_distance(np.float32(-0.0), np.float32(0.0))) == 0
    assert max_ulp(-denorm, denorm) == 2
    # mixed dtypes measure on the NARROWER ladder: two f32 values one
    # bf16 step apart are 1 apart, values rounding together are 0 apart
    a = jnp.float32(1.0)
    b = a + jnp.float32(jnp.finfo(jnp.bfloat16).eps)
    assert max_ulp(a.astype(jnp.bfloat16), b) == 1
    assert max_ulp(a.astype(jnp.bfloat16), a + jnp.float32(1e-6)) == 0
    # NaN is astronomically far from everything (budget = failure)
    assert max_ulp(np.float32(np.nan), np.float32(1.0)) > 2**30


def test_rel_ulp_error_floor_semantics():
    from repro.common.quant import rel_ulp_error
    ref = np.linspace(-2.0, 2.0, 64, dtype=np.float32)
    assert rel_ulp_error(ref, ref, "bf16") == 0.0
    # one bf16 quantization step at the working scale reads as ~1
    got = np.asarray(jnp.asarray(ref).astype(jnp.bfloat16), np.float32)
    assert 0.0 < rel_ulp_error(ref, got, "bf16") <= 1.0
    # near-zero entries do NOT blow up: the RMS floor pins the scale
    # (raw near-zero ULP distance would be in the thousands)
    ref2 = np.array([0.0, 1.0, -1.0, 0.5], np.float32)
    got2 = ref2 + np.float32(1e-4)
    assert rel_ulp_error(ref2, got2, "bf16") < 0.1


def test_kahan_add_zero_comp_is_plain_add():
    from repro.common.quant import kahan_add
    rng = np.random.default_rng(0)
    total = jnp.asarray(rng.standard_normal(256), jnp.float32)
    delta = jnp.asarray(rng.standard_normal(256), jnp.float32)
    t, _ = kahan_add(total, jnp.zeros_like(total), delta)
    np.testing.assert_array_equal(np.asarray(t), np.asarray(total + delta))


def test_kahan_add_beats_plain_f32_accumulation():
    from repro.common.quant import kahan_add
    # classic pathological sum: many increments far below the total's ULP
    n, big, small = 10_000, np.float32(1e6), np.float32(0.01)
    t = c = jnp.float32(0.0)
    plain = jnp.float32(0.0)
    t, c = kahan_add(t, c, jnp.float32(big))
    plain = plain + big
    for _ in range(n):
        t, c = kahan_add(t, c, jnp.float32(small))
        plain = plain + small
    exact = float(big) + n * float(small)
    assert abs(float(t) - exact) < abs(float(plain) - exact)
    assert abs(float(t) - exact) <= 1.0


def test_fp8_block_codec_roundtrip_and_edges():
    from repro.common import quant
    rng = np.random.default_rng(1)
    block = 16
    x = jnp.asarray(rng.standard_normal((4, 4 * block)) *
                    10.0 ** rng.integers(-3, 4, (4, 4 * block)), jnp.float32)
    s = quant.block_scales(x, block)
    assert s.shape == (4, 4) and s.dtype == jnp.float32
    q = quant.quantize_fp8(x, s, block)
    assert q.dtype == jnp.float8_e4m3fn
    back = quant.dequantize_fp8(q, s, block)
    assert bool(jnp.all(jnp.isfinite(back)))
    # e4m3 has a 3-bit mantissa: relative error ≤ 2^-4 of the block amax
    amax = np.repeat(np.asarray(s) * quant.FP8_MAX, block, axis=-1)
    assert np.max(np.abs(np.asarray(back) - np.asarray(x))) <= \
        np.max(amax) * 2.0 ** -4
    # signs survive wherever the value didn't underflow the block scale
    nz = np.asarray(back) != 0
    assert np.all(np.sign(np.asarray(back))[nz]
                  == np.sign(np.asarray(x))[nz])
    # all-zero block: scale 1.0, exact-zero round trip (no 0/0)
    z = jnp.zeros((2 * block,), jnp.float32)
    sz = quant.block_scales(z, block)
    np.testing.assert_array_equal(np.asarray(sz), np.ones(2, np.float32))
    np.testing.assert_array_equal(
        np.asarray(quant.dequantize_fp8(quant.quantize_fp8(z, sz, block),
                                        sz, block)), np.asarray(z))
    # a subnormal-scale block quantizes without NaN/inf
    tiny = jnp.full((block,), np.float32(1e-40))
    st = quant.block_scales(tiny, block)
    assert bool(jnp.all(jnp.isfinite(
        quant.dequantize_fp8(quant.quantize_fp8(tiny, st, block), st,
                             block))))


def test_encode_decode_slot_tokens():
    from repro.common.quant import decode_slot, encode_slot
    rng = np.random.default_rng(2)
    block = 32
    x = jnp.asarray(rng.standard_normal(2 * block), jnp.float32)
    # f32: bit-exact identity, no scales
    slot, s = encode_slot(x, "f32", block)
    assert s is None
    np.testing.assert_array_equal(np.asarray(decode_slot(slot)),
                                  np.asarray(x))
    # bf16: the cast, no scales
    slot, s = encode_slot(x, "bf16", block)
    assert s is None and slot.dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(decode_slot(slot)),
        np.asarray(x.astype(jnp.bfloat16).astype(jnp.float32)))
    # fp8: block-scaled, decode needs the scales
    slot, s = encode_slot(x, "fp8", block)
    assert slot.dtype == jnp.float8_e4m3fn and s.shape == (2,)
    back = decode_slot(slot, s, block)
    assert float(jnp.max(jnp.abs(back - x))) < float(jnp.max(jnp.abs(x)))


def test_window_aux_buffers_shapes():
    from repro.common.packing import window_aux_buffers, window_buffers
    from repro.common.quant import wa_dtype
    spec = pack_spec(params_like())                 # padded == ALIGN
    I = 3
    assert window_aux_buffers(spec, I, "f32") == (None, None)
    scales, comp = window_aux_buffers(spec, I, "bf16")
    assert scales is None and comp.shape == (spec.padded,) \
        and comp.dtype == jnp.float32
    scales, comp = window_aux_buffers(spec, I, "fp8")
    assert scales.shape == (I, spec.scale_blocks) \
        and bool(jnp.all(scales == 1.0))            # scale of a zero block
    ring, total = window_buffers(spec, I, wa_dtype("fp8"))
    assert ring.dtype == jnp.float8_e4m3fn and total.dtype == jnp.float32
    # grouped layouts get per-group tuples
    gspec = grouped_spec(grouped_tree(), align=8)
    gscales, gcomp = window_aux_buffers(gspec, I, "bf16")
    assert gscales is None and isinstance(gcomp, tuple) \
        and len(gcomp) == gspec.n_groups


def test_pack_spec_ring_dtype_json_and_layout_neutrality():
    from repro.common.packing import spec_from_json, spec_to_json
    spec = pack_spec(params_like())
    assert spec.ring_dtype == "float32"
    assert "ring_dtype" not in spec_to_json(spec)   # omitted == f32:
    # pre-compression checkpoints rehydrate unchanged
    for tok, name in (("bf16", "bfloat16"), ("fp8", "float8_e4m3fn")):
        sp = spec.with_ring_dtype(tok)
        assert sp.ring_dtype == name
        back = spec_from_json(spec_to_json(sp))
        assert back.ring_dtype == name
        assert sp.same_layout(spec) and spec.same_layout(sp)
    assert spec.with_ring_dtype("f32") is spec


@pytest.mark.parametrize("tok", ["bf16", "fp8"])
def test_compressed_window_update_matches_decoded_accounting(tok):
    """The compressed ring stores encode(mean); total/W̿ account for the
    DECODED values (what the ring can reproduce), Kahan-compensated, so
    W̿ == mean(decoded slots) to f32 round-off — and the f32 path stays
    exactly the pre-compression arithmetic (checked elsewhere
    bit-for-bit)."""
    from repro.common.quant import decode_slot
    from repro.core.offline import window_average_packed
    p = params_like()
    I = 3
    ws = window_init(p, I, ring_dtype=tok)
    assert ws.comp is not None and (ws.scales is None) == (tok == "bf16")
    for t in range(4):
        ws, wa = window_update(ws, params_like(10 + t))
    dec = decode_slot(ws.ring, ws.scales)
    want = np.mean(np.asarray(dec), axis=0)
    got = np.asarray(window_average_packed(ws))
    np.testing.assert_allclose(got, want, atol=1e-6, rtol=1e-6)


def test_compressed_window_update_kernel_matches_ref():
    """bf16 rings have a fused Pallas kernel (`wa_window_update_packed_c`)
    — it must agree with the jnp reference bit-for-bit."""
    p = params_like()
    ws_k = window_init(p, 3, ring_dtype="bf16")
    ws_r = window_init(p, 3, ring_dtype="bf16")
    for t in range(4):
        ws_k, wa_k = window_update(ws_k, params_like(20 + t),
                                   use_kernel=True)
        ws_r, wa_r = window_update(ws_r, params_like(20 + t),
                                   use_kernel=False)
        for a, b in zip(jax.tree.leaves(wa_k), jax.tree.leaves(wa_r)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(ws_k.ring),
                                  np.asarray(ws_r.ring))
    np.testing.assert_array_equal(np.asarray(ws_k.total),
                                  np.asarray(ws_r.total))
    np.testing.assert_array_equal(np.asarray(ws_k.comp),
                                  np.asarray(ws_r.comp))


@pytest.mark.parametrize("tok", ["bf16", "fp8"])
def test_compressed_window_state_checkpoint_bit_exact(tok):
    """Same-precision save/load round-trips the compressed ring (and its
    scales/comp companions) BIT-exactly — via integer views, a narrow
    float never round-trips through f32."""
    import tempfile

    from repro.checkpoint import load_window_state, save_window_state
    p = params_like()
    ws = window_init(p, 3, ring_dtype=tok)
    for t in range(4):
        ws, _ = window_update(ws, params_like(30 + t))
    with tempfile.TemporaryDirectory() as d:
        path = d + "/ws.npz"
        save_window_state(path, ws)
        back = load_window_state(path, window_init(p, 3, ring_dtype=tok))
    assert back.ring.dtype == ws.ring.dtype
    np.testing.assert_array_equal(
        np.asarray(back.ring.view(jnp.uint8)),
        np.asarray(ws.ring.view(jnp.uint8)))
    np.testing.assert_array_equal(np.asarray(back.total),
                                  np.asarray(ws.total))
    np.testing.assert_array_equal(np.asarray(back.comp),
                                  np.asarray(ws.comp))
    if tok == "fp8":
        np.testing.assert_array_equal(np.asarray(back.scales),
                                      np.asarray(ws.scales))


@pytest.mark.parametrize("src,dst", [("f32", "bf16"), ("f32", "fp8"),
                                     ("bf16", "f32"), ("fp8", "f32"),
                                     ("bf16", "fp8")])
def test_window_state_precision_migration(src, dst, tmp_path):
    """Loading a checkpoint into a template of a DIFFERENT ring precision
    re-encodes: ring = encode(decode(stored)), total = Σ decoded slots,
    comp reset (the compensation tracks a total that no longer exists)."""
    from repro.checkpoint import load_window_state, save_window_state
    from repro.common.quant import decode_slot, encode_slot, wa_dtype
    p = params_like()
    ws = window_init(p, 3, ring_dtype=src)
    for t in range(4):
        ws, _ = window_update(ws, params_like(40 + t))
    path = str(tmp_path / "ws.npz")
    save_window_state(path, ws)
    back = load_window_state(path, window_init(p, 3, ring_dtype=dst))
    assert back.ring.dtype == wa_dtype(dst)
    f32_ring = decode_slot(ws.ring, ws.scales)
    want_ring, want_scales = encode_slot(f32_ring, dst)
    np.testing.assert_array_equal(
        np.asarray(back.ring, np.float32),
        np.asarray(want_ring, np.float32))
    if want_scales is not None:
        np.testing.assert_array_equal(np.asarray(back.scales),
                                      np.asarray(want_scales))
    np.testing.assert_array_equal(
        np.asarray(back.total),
        np.asarray(jnp.sum(decode_slot(want_ring, want_scales), axis=0)))
    if dst == "f32":
        assert back.comp is None and back.scales is None
    else:
        np.testing.assert_array_equal(np.asarray(back.comp),
                                      np.zeros_like(np.asarray(back.total)))
    assert int(back.count) == int(ws.count)


def test_window_state_migration_into_grouped_compressed_raises(tmp_path):
    from repro.checkpoint import load_window_state, save_window_state
    from repro.common.packing import window_aux_buffers, window_buffers
    from repro.core.offline import WindowState
    p = params_like()
    ws = window_init(p, 3)
    for t in range(2):
        ws, _ = window_update(ws, params_like(50 + t))
    path = str(tmp_path / "ws.npz")
    save_window_state(path, ws)
    gtree = grouped_tree()
    gspec = grouped_spec(gtree, align=8).with_ring_dtype("bf16")
    ring, total = window_buffers(gspec, 3, jnp.bfloat16)
    _, comp = window_aux_buffers(gspec, 3, "bf16")
    like = WindowState(ring=ring, total=total,
                       count=jnp.zeros((), jnp.int32),
                       next_idx=jnp.zeros((), jnp.int32),
                       window=3, kind="ring", spec=gspec, comp=comp)
    with pytest.raises(ValueError):
        load_window_state(path, like)
