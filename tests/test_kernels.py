"""Per-kernel allclose vs the pure-jnp oracles, with hypothesis sweeps
over shapes/dtypes (assignment deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis-heavy: excluded from the CI tier1 PR lane (-m "not slow");
# the nightly full lane runs it
pytestmark = pytest.mark.slow

pytest.importorskip("hypothesis", reason="hypothesis not installed "
                    "(see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops as kops
from repro.kernels import ref as kref

SETTINGS = dict(max_examples=10, deadline=None)


@given(st.sampled_from([(7, 13), (128,), (1024,), (3, 5, 17), (8192,),
                        (2, 1024, 3)]),
       st.integers(2, 6), st.integers(0, 1000))
@settings(**SETTINGS)
def test_wa_window_update_shapes(shape, window, seed):
    ks = jax.random.split(jax.random.key(seed), 2)
    ring = jax.random.normal(ks[0], (window,) + shape, jnp.float32)
    total = jnp.sum(ring, 0)
    new = jax.random.normal(ks[1], shape, jnp.float32)
    idx = seed % window
    for full, cnt in [(1.0, window), (0.0, max(1, window - 2))]:
        got = kops.wa_window_update(ring, total, new, idx, full, 1.0 / cnt)
        want = kref.wa_window_update_ref(ring, total, new, idx, full,
                                         1.0 / cnt)
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=1e-6, atol=1e-6)


@given(st.integers(2, 4),
       st.sampled_from([(5,), (33, 7), (1024,), (2, 8, 128)]),
       st.sampled_from(["float32", "bfloat16"]), st.integers(0, 100))
@settings(**SETTINGS)
def test_online_mean_shapes_dtypes(k, shape, dtype, seed):
    x = jax.random.normal(jax.random.key(seed), (k,) + shape).astype(dtype)
    got = kops.online_mean(x)
    want = kref.online_mean_ref(x).astype(dtype)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2 if dtype == "bfloat16" else 1e-6,
                               atol=1e-6)


@pytest.mark.parametrize("B,S,Hq,Hkv,D,window,cap,dtype", [
    (1, 128, 2, 1, 16, None, 0.0, "float32"),
    (2, 128, 4, 2, 32, None, 50.0, "float32"),
    (1, 256, 2, 2, 16, 64, 0.0, "float32"),
    (1, 128, 4, 1, 8, 32, 30.0, "float32"),
    (2, 128, 4, 4, 64, None, 0.0, "bfloat16"),
    (1, 128, 8, 2, 24, None, 0.0, "float32"),   # head_dim padded to 128
])
def test_flash_pallas_vs_oracle(B, S, Hq, Hkv, D, window, cap, dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, D)).astype(dtype)
    out = kops.flash_attention(q, k, v, window=window, logit_softcap=cap,
                               block_q=64, block_k=64)
    ref = kref.attention_ref(q, k, v, window=window, logit_softcap=cap)
    tol = 2e-2 if dtype == "bfloat16" else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_jnp_custom_vjp_grads():
    """jnp flash (custom VJP) gradient == naive autodiff gradient."""
    from repro.models.attention import flash_attention_jnp, naive_attention
    B, S, Hq, Hkv, D = 2, 128, 4, 2, 16
    ks = jax.random.split(jax.random.key(0), 4)
    q, k, v = (jax.random.normal(kk, (B, S, h, D))
               for kk, h in zip(ks, [Hq, Hkv, Hkv]))
    dout = jax.random.normal(ks[3], (B, S, Hq, D))
    pos = jnp.arange(S)
    for window, cap in [(None, 0.0), (32, 0.0), (None, 30.0), (48, 20.0)]:
        def fr(q, k, v):
            return jnp.sum(naive_attention(
                q, k, v, pos[None].repeat(B, 0), pos[None].repeat(B, 0),
                window=window, logit_softcap=cap) * dout)

        def ff(q, k, v):
            return jnp.sum(flash_attention_jnp(
                q, k, v, window=window, logit_softcap=cap,
                q_block=32, k_block=32) * dout)

        gr = jax.grad(fr, (0, 1, 2))(q, k, v)
        gf = jax.grad(ff, (0, 1, 2))(q, k, v)
        for a, b in zip(gr, gf):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-5)
