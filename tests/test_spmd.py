"""Multi-device SPMD correctness, run in a subprocess so the forced
8-device host platform never leaks into other tests."""
import os
import subprocess
import sys

import pytest


@pytest.mark.timeout(900)
def test_spmd_subprocess():
    script = os.path.join(os.path.dirname(__file__), "spmd_check.py")
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + \
        os.path.dirname(__file__) + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, script], capture_output=True,
                          text=True, env=env, timeout=850)
    print(proc.stdout)
    print(proc.stderr[-2000:] if proc.stderr else "")
    assert proc.returncode == 0, proc.stdout + proc.stderr[-2000:]
    assert "ALL_OK" in proc.stdout
