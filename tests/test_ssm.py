"""SSM cells: chunkwise-parallel == sequential; state continuity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.ssm as ssm
from repro.models.types import ModelConfig

CFG = ModelConfig(name="t", family="ssm", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=4, d_ff=0, vocab_size=32, ssm_state=8,
                  ssm_heads=4, dtype="float32")


@pytest.fixture(autouse=True)
def _restore_chunks():
    mc, mm = ssm.MLSTM_CHUNK, ssm.MAMBA_CHUNK
    yield
    ssm.MLSTM_CHUNK, ssm.MAMBA_CHUNK = mc, mm


def test_mlstm_chunkwise_equals_sequential():
    p, _ = ssm.init_mlstm(CFG, jax.random.key(0), jnp.float32)
    st = ssm.init_mlstm_state(CFG, 2)
    x = jax.random.normal(jax.random.key(1), (2, 512, 64))
    ssm.MLSTM_CHUNK = 128
    y_c, s_c = ssm.mlstm_scan(CFG, p, x, st)
    ssm.MLSTM_CHUNK = 10 ** 9
    y_s, s_s = ssm.mlstm_scan(CFG, p, x, st)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_s),
                               rtol=1e-4, atol=1e-4)
    for k in ("C", "n", "m"):
        np.testing.assert_allclose(np.asarray(s_c[k]), np.asarray(s_s[k]),
                                   rtol=1e-4, atol=1e-4)


def test_mamba_chunkwise_equals_sequential():
    p, _ = ssm.init_mamba(CFG, jax.random.key(0), jnp.float32)
    st = ssm.init_mamba_state(CFG, 2)
    x = jax.random.normal(jax.random.key(1), (2, 512, 64))
    ssm.MAMBA_CHUNK = 128
    y_c, s_c = ssm.mamba_scan(CFG, p, x, st)
    ssm.MAMBA_CHUNK = 10 ** 9
    y_s, s_s = ssm.mamba_scan(CFG, p, x, st)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_s),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_c["S"]), np.asarray(s_s["S"]),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("cell", ["mlstm", "slstm", "mamba"])
def test_state_continuity_split_equals_full(cell):
    """Running [0:T] equals running [0:T/2] then [T/2:T] with carried state
    — the invariant that makes one code path serve train AND decode."""
    init_p = {"mlstm": ssm.init_mlstm, "slstm": ssm.init_slstm,
              "mamba": ssm.init_mamba}[cell]
    init_s = {"mlstm": ssm.init_mlstm_state, "slstm": ssm.init_slstm_state,
              "mamba": ssm.init_mamba_state}[cell]
    scan = {"mlstm": ssm.mlstm_scan, "slstm": ssm.slstm_scan,
            "mamba": ssm.mamba_scan}[cell]
    p, _ = init_p(CFG, jax.random.key(0), jnp.float32)
    st0 = init_s(CFG, 2)
    x = jax.random.normal(jax.random.key(1), (2, 64, 64))
    y_full, s_full = scan(CFG, p, x, st0)
    y1, s_mid = scan(CFG, p, x[:, :32], st0)
    y2, s_end = scan(CFG, p, x[:, 32:], s_mid)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)


def test_mlstm_grad_memory_path_finite():
    p, _ = ssm.init_mlstm(CFG, jax.random.key(0), jnp.float32)
    st = ssm.init_mlstm_state(CFG, 2)
    x = jax.random.normal(jax.random.key(1), (2, 512, 64))
    ssm.MLSTM_CHUNK = 128

    def loss(p, x):
        y, _ = ssm.mlstm_scan(CFG, p, x, st)
        return jnp.sum(y ** 2)

    g = jax.grad(loss, argnums=(0, 1))(p, x)
    for leaf in jax.tree.leaves(g):
        assert bool(jnp.all(jnp.isfinite(leaf)))
