"""Paper-faithful ResNet-CIFAR + BatchNorm recompute (Algorithm 2 line 3)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bnstats import recompute_bn_stats
from repro.data import make_prototype_image_dataset
from repro.models.convnet import (apply_resnet, init_resnet, resnet_loss,
                                  resnet_cifar_config)


def small_cfg():
    return resnet_cifar_config(depth=8, n_classes=4, image_size=8)


def test_resnet_forward_shapes():
    cfg = small_cfg()
    params, state = init_resnet(cfg, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 8, 8, 3))
    logits, new_state = apply_resnet(cfg, params, state, x, train=True)
    assert logits.shape == (2, 4)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_resnet_trains():
    cfg = small_cfg()
    params, state = init_resnet(cfg, jax.random.key(0))
    ds = make_prototype_image_dataset(n_classes=4, image_size=8,
                                      n_train=64, n_test=32, noise=0.3,
                                      label_noise=0.0)

    @jax.jit
    def step(params, state, x, y):
        def loss_fn(p):
            return resnet_loss(cfg, p, state, {"tokens": x, "targets": y})
        (loss, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params = jax.tree.map(lambda p, gi: p - 0.05 * gi, params, g)
        return params, metrics["bn_state"], loss

    losses = []
    for i in range(30):
        lo = (i * 16) % 64
        params, state, loss = step(params, state,
                                   ds.train_inputs[lo:lo + 16],
                                   ds.train_targets[lo:lo + 16])
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.7


def test_bn_recompute_moves_stats_to_data():
    cfg = small_cfg()
    params, state = init_resnet(cfg, jax.random.key(0))
    # shift input distribution strongly
    x = 5.0 + jax.random.normal(jax.random.key(1), (32, 8, 8, 3))
    new_state = recompute_bn_stats(cfg, params, state, [x[:16], x[16:]])
    # stem BN mean must move toward the conv output of shifted data
    _, batch_state = apply_resnet(cfg, params, state, x, train=True)
    # recomputed stats differ from init (zeros) and are finite
    assert float(jnp.max(jnp.abs(new_state["stem_bn"]["mean"]))) > 1e-3
    for leaf in jax.tree.leaves(new_state):
        assert bool(jnp.all(jnp.isfinite(leaf)))
