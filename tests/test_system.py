"""End-to-end behaviour: the paper's headline claims at micro scale.

These are the system-level acceptance tests; per-module details live in
the sibling test files.
"""
import jax
import pytest

from repro.core import HWAConfig
from repro.data import DataPipeline, make_markov_lm_dataset
from repro.models import build_model
from repro.models.types import ModelConfig
from repro.train import TrainConfig, Trainer, lm_task

TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=48,
                   n_heads=4, n_kv_heads=2, d_ff=96, vocab_size=48,
                   attn_impl="naive", remat="none", dtype="float32")


def run(method, steps=96, seed=0, base_lr=0.5):
    lm = build_model(TINY)
    ds = make_markov_lm_dataset(vocab=48, seq_len=48, n_train=512,
                                n_test=128, seed=0)
    k = 2 if method in ("hwa", "online", "pmsgd") else 1
    pipe = DataPipeline(ds, batch_size=8, n_replicas=k, seed=seed)
    tc = TrainConfig(method=method, total_steps=steps, batch_size=8,
                     base_lr=base_lr, eval_every=24, seed=seed,
                     hwa=HWAConfig(n_replicas=k, sync_period=12, window=4),
                     swa_start_frac=0.5, swa_lr=0.1)
    return Trainer(lm_task(lm, pipe), tc).run()


@pytest.fixture(scope="module")
def results():
    return {m: run(m) for m in ("ca", "online", "hwa")}


def test_all_methods_learn(results):
    for m, out in results.items():
        assert out["final"]["test_loss"] < 3.8, (m, out["final"])


def test_hwa_not_worse_than_online_only(results):
    """Table III: offline module adds on top of online WA (allow noise)."""
    assert results["hwa"]["best"]["test_loss"] <= \
        results["online"]["best"]["test_loss"] + 0.1


def test_hwa_competitive_with_cosine_baseline(results):
    """Table II at micro scale: HWA >= CA (cosine) baseline."""
    assert results["hwa"]["best"]["test_loss"] <= \
        results["ca"]["best"]["test_loss"] + 0.1
