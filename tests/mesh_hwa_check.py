"""Executed in a subprocess with 8 forced host devices (see
test_mesh_hwa.py).

Verifies the tentpole properties of mesh-native HWA on a (2,2,2)
(replica, data, model) mesh:

  1. mesh-native train step == vmap-path train step == single-device
     oracle, within 1e-5 after several steps (f32 smoke model);
  2. mesh-native sync == stacked-mean oracle; replicas restart equal;
     the slide window advances;
  3. the lowered inner train step contains NO collective crossing the
     replica mesh axis — inter-replica traffic happens only in hwa_sync
     (every H steps), which is the paper's communication amortization;
  4. every replica-crossing collective in the sync step is the weight
     all-reduce (the single pmean).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp

from repro.common.compat import use_mesh
from repro.configs import get_smoke_config
from repro.core.hwa import HWAConfig
from repro.core.offline import window_init, window_update
from repro.launch.hlo import collectives_crossing_axis
from repro.launch.mesh import make_test_mesh
from repro.launch.specs import input_specs
from repro.launch.steps import (make_hwa_train_step, make_mesh_hwa_sync_step,
                                make_mesh_hwa_train_step)
from repro.models.registry import build_model
from repro.models.types import InputShape
from repro.optim import apply_updates, sgd
from repro.sharding.rules import make_tp_rules

ok = True
K, B, S, N_STEPS, LR = 2, 8, 16, 3, 0.1


def check(name, cond):
    global ok
    print(("PASS " if cond else "FAIL ") + name)
    ok = ok and cond


def tree_err(a, b):
    return max(float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                     - y.astype(jnp.float32))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


mesh = make_test_mesh((2, 2, 2), ("replica", "data", "model"))
rules = make_tp_rules(mesh, replica_axis="replica")
cfg = get_smoke_config("granite-3-2b")
lm = build_model(cfg)
hwa_cfg = HWAConfig(n_replicas=K, window=3)
shape = InputShape("tiny", seq_len=S, global_batch=B, kind="train")
specs, dims = input_specs(cfg, shape)

params = lm.init(jax.random.key(0))
stack2 = lambda t: jax.tree.map(lambda x: jnp.stack([x, x]), t)
opt = sgd(momentum=0.9, weight_decay=5e-4)


def batches(step):
    ks = jax.random.split(jax.random.key(100 + step), 2)
    return {"tokens": jax.random.randint(ks[0], (K, B, S), 0,
                                         cfg.vocab_size),
            "targets": jax.random.randint(ks[1], (K, B, S), 0,
                                          cfg.vocab_size)}


# ---- leg A: mesh-native shard_map path ------------------------------------
mesh_train = make_mesh_hwa_train_step(lm, rules, specs, dims, hwa_cfg,
                                      optimizer="sgd", lr=LR)
mesh_train_c = mesh_train.lower(mesh).compile()
a_inner, a_opt = stack2(params), jax.vmap(opt.init)(stack2(params))
with use_mesh(mesh):
    for step in range(N_STEPS):
        a_inner, a_opt, a_losses = mesh_train_c(a_inner, a_opt,
                                                batches(step))
check("mesh-native: finite per-replica losses",
      bool(jnp.all(jnp.isfinite(a_losses))))

# ---- leg B: vmap path compiled on the same mesh ---------------------------
vmap_train = make_hwa_train_step(lm, rules, specs, dims, hwa_cfg,
                                 optimizer="sgd", lr=LR)
vmap_train_c = vmap_train.lower(mesh).compile()
b_inner, b_opt = stack2(params), jax.vmap(opt.init)(stack2(params))
with use_mesh(mesh):
    for step in range(N_STEPS):
        b_inner, b_opt, _ = vmap_train_c(b_inner, b_opt, batches(step))

# ---- leg C: single-device vmap oracle -------------------------------------
def one(p, o, b):
    (l, m), g = jax.value_and_grad(
        lambda q: lm.loss(q, b), has_aux=True)(p)
    upd, o2 = opt.update(g, o, p, LR)
    return apply_updates(p, upd), o2, l


c_inner, c_opt = stack2(params), jax.vmap(opt.init)(stack2(params))
for step in range(N_STEPS):
    c_inner, c_opt, _ = jax.vmap(one)(c_inner, c_opt, batches(step))

err_ab = tree_err(a_inner, b_inner)
err_ac = tree_err(a_inner, c_inner)
check(f"mesh-native == vmap path after {N_STEPS} steps "
      f"(err={err_ab:.2e})", err_ab < 1e-5)
check(f"mesh-native == single-device oracle (err={err_ac:.2e})",
      err_ac < 1e-5)

# ---- sync: mesh-native vs stacked oracle ----------------------------------
# oracle first: the sync bundle donates its inputs
outer_oracle = jax.tree.map(lambda x: jnp.mean(jnp.asarray(x), 0), a_inner)
ws_oracle, wa_oracle = window_update(
    window_init(params, hwa_cfg.window), outer_oracle)

sync = make_mesh_hwa_sync_step(lm, rules, hwa_cfg)
sync_c = sync.lower(mesh).compile()
spec = sync.pack_spec               # window state is packed (I, P)/(P,)
ring = jnp.zeros((hwa_cfg.window, spec.padded), jnp.float32)
total = jnp.zeros((spec.padded,), jnp.float32)
zero = jnp.zeros((), jnp.int32)
with use_mesh(mesh):
    (s_inner, s_ring, s_total, s_count, s_nidx, s_wa,
     s_cycle) = sync_c(a_inner, ring, total, zero, zero, zero)
check("sync: replicas equal after restart",
      tree_err(jax.tree.map(lambda x: x[0], s_inner),
               jax.tree.map(lambda x: x[1], s_inner)) == 0.0)
err_outer = tree_err(jax.tree.map(lambda x: x[0], s_inner), outer_oracle)
check(f"sync: restart == stacked mean (err={err_outer:.2e})",
      err_outer < 1e-5)
err_wa = tree_err(s_wa, wa_oracle)
check(f"sync: window average == oracle (err={err_wa:.2e})", err_wa < 1e-5)
check("sync: count/cycle advanced",
      int(s_count) == 1 and int(s_cycle) == 1)

# use_kernels=True on a multi-device mesh must produce the SAME values:
# Pallas is opaque to GSPMD (per-shard execution with global-shape
# semantics corrupts values), so the bundles gate the kernel path to
# single-device meshes — this leg catches any regression of that gate.
hwa_cfg_k = HWAConfig(n_replicas=K, window=3, use_kernels=True)
sync_k = make_mesh_hwa_sync_step(lm, rules, hwa_cfg_k)
sync_kc = sync_k.lower(mesh).compile()
ring_k = jnp.zeros((hwa_cfg_k.window, spec.padded), jnp.float32)
total_k = jnp.zeros((spec.padded,), jnp.float32)
with use_mesh(mesh):
    out_k = sync_kc(s_inner, ring_k, total_k, zero, zero, zero)
# s_inner replicas are all W̄ from the first sync; its window push equals
# a fresh window_update with that (replica-invariant) value
ws_k_oracle, wa_k_oracle = window_update(
    window_init(params, hwa_cfg_k.window), outer_oracle)
err_kwa = tree_err(out_k[5], wa_k_oracle)
check(f"sync(use_kernels on mesh): values correct (err={err_kwa:.2e})",
      err_kwa < 1e-5)

# ---- HLO structure: replica-axis traffic only in hwa_sync -----------------
train_hlo = mesh_train_c.as_text()
cross_train = collectives_crossing_axis(train_hlo, mesh, "replica")
check(f"train step: zero replica-crossing collectives "
      f"(found {len(cross_train)})", len(cross_train) == 0)

sync_hlo = sync_c.as_text()
cross_sync = collectives_crossing_axis(sync_hlo, mesh, "replica")
ops = {op for op, _ in cross_sync}
check(f"sync step: replica-crossing collectives are the weight "
      f"all-reduce only (ops={sorted(ops)})",
      len(cross_sync) >= 1 and ops == {"all-reduce"})

# vmap-path train step, for contrast, is *allowed* replica traffic (GSPMD
# may or may not insert it) — we only report it, the guarantee is the
# shard_map path's.
cross_vmap = collectives_crossing_axis(vmap_train_c.as_text(), mesh,
                                       "replica")
print(f"INFO vmap-path train step replica-crossing collectives: "
      f"{len(cross_vmap)}")

print("ALL_OK" if ok else "SOME_FAILED")
raise SystemExit(0 if ok else 1)
